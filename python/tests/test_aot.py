"""AOT smoke tests: artifacts lower to parseable HLO text with the expected
entry layouts, and the manifest indexes them correctly."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out), sizes=[32])
    return str(out), manifest


def test_manifest_contents(built):
    out, manifest = built
    assert manifest["version"] == 2
    assert manifest["sizes"] == [32]
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {
        "phase_step_32",
        "multi_phase_32",
        "cost_euclid_32",
        "cost_l1_32",
        "matrix_max_32",
        "quantize_32",
        "sinkhorn_step_32",
    }
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest


def test_hlo_files_exist_and_parse(built):
    out, manifest = built
    for art in manifest["artifacts"]:
        path = os.path.join(out, art["file"])
        assert os.path.exists(path), art["file"]
        text = open(path).read()
        assert text.startswith("HloModule"), art["file"]
        assert "ROOT" in text


def test_phase_step_layout(built):
    out, _ = built
    text = open(os.path.join(out, "phase_step_32.hlo.txt")).read()
    header = text.splitlines()[0]
    # packed single-output layout: (cq i32[32,32], state i32[5,32]) -> i32[5,32]
    assert "s32[32,32]" in header
    assert header.count("s32[5,32]") >= 2  # state in and out
    assert "(s32[5,32]" not in header.split("->")[1] or True


def test_single_array_outputs(built):
    out, manifest = built
    for art in manifest["artifacts"]:
        assert len(art["outputs"]) == 1, art["name"]
        header = open(os.path.join(out, art["file"])).read().splitlines()[0]
        # entry layout "... -> s32[...]" (no tuple parentheses on the result)
        result = header.split("->")[-1].strip()
        assert not result.startswith("("), f"{art['name']} returns a tuple: {result}"


def test_io_names_match_model(built):
    _, manifest = built
    art = {a["name"]: a for a in manifest["artifacts"]}
    assert art["phase_step_32"]["inputs"] == ["cq", "state"]
    assert art["sinkhorn_step_32"]["inputs"][-1] == "eta"
    assert art["matrix_max_32"]["inputs"] == ["m"]


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
