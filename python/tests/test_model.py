"""L2 correctness: phase_step vs the numpy reference (bit-exact), phase
invariants (I1)/(I2) across full solves, sinkhorn_step vs oracle, and the
end-to-end jax solve's additive guarantee vs brute force."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _random_costs(rng, n):
    return rng.random((n, n)).astype(np.float32)


class TestPhaseStep:
    @settings(max_examples=20, deadline=None)
    @given(n=st.sampled_from([4, 8, 16, 32]), seed=st.integers(0, 2**31 - 1),
           max_cost=st.sampled_from([3, 10, 40]))
    def test_full_solve_matches_ref_every_phase(self, n, seed, max_cost):
        rng = np.random.default_rng(seed)
        cq = rng.integers(0, max_cost, (n, n)).astype(np.int32)
        ya, yb, ma, mb = model.init_state(jnp.asarray(cq))
        state_j = (ya, yb, ma, mb)
        state_r = tuple(np.array(x) for x in state_j)
        for _ in range(200):
            out_j = model.phase_step(cq, *state_j)
            out_r = ref.phase_step_ref(cq, *state_r)
            for got, want in zip(out_j[:4], out_r[:4]):
                np.testing.assert_array_equal(np.array(got), want)
            assert int(out_j[4]) == out_r[4]
            assert int(out_j[5]) == out_r[5]
            ref.check_feasible_ref(cq, *out_r[:4])
            state_j = out_j[:4]
            state_r = out_r[:4]
            if out_r[4] == 0:
                break
        else:
            pytest.fail("did not converge in 200 phases")

    def test_empty_phase_is_noop(self):
        # all matched already: phase must not change anything
        n = 8
        cq = np.zeros((n, n), dtype=np.int32)
        ya = np.zeros(n, dtype=np.int32)
        yb = np.zeros(n, dtype=np.int32)
        ma = np.arange(n, dtype=np.int32)
        mb = np.arange(n, dtype=np.int32)
        out = model.phase_step(cq, ya, yb, ma, mb)
        np.testing.assert_array_equal(np.array(out[2]), ma)
        np.testing.assert_array_equal(np.array(out[3]), mb)
        assert int(out[4]) == 0

    def test_matched_vertices_of_a_stay_matched(self):
        # Lemma 2.1: A-vertices never become unmatched
        rng = np.random.default_rng(3)
        n = 16
        cq = rng.integers(0, 6, (n, n)).astype(np.int32)
        state = model.init_state(jnp.asarray(cq))
        matched_a_prev = np.zeros(n, dtype=bool)
        for _ in range(60):
            out = model.phase_step(cq, *state)
            matched_a = np.array(out[2]) >= 0
            assert (matched_a | ~matched_a_prev).all(), "an A vertex got unmatched"
            matched_a_prev = matched_a
            state = out[:4]
            if int(out[4]) == 0:
                break


class TestFullSolve:
    def brute_force(self, costs):
        n = costs.shape[0]
        best = float("inf")
        for p in itertools.permutations(range(n)):
            best = min(best, sum(costs[b, p[b]] for b in range(n)))
        return best

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), eps=st.sampled_from([0.05, 0.1, 0.3]))
    def test_additive_guarantee_vs_bruteforce(self, seed, eps):
        n = 6
        rng = np.random.default_rng(seed)
        costs = _random_costs(rng, n)
        mb, _ = model.assignment_solve(costs, eps)
        mb = np.array(mb)
        assert sorted(mb.tolist()) == list(range(n)), "not a perfect matching"
        got = sum(costs[b, mb[b]] for b in range(n))
        opt = self.brute_force(costs)
        c_max = costs.max()
        assert got <= opt + 3 * eps * n * c_max + 1e-6, (
            f"cost {got} exceeds opt {opt} + 3εn = {opt + 3 * eps * n * c_max}"
        )

    def test_phase_count_bound(self):
        rng = np.random.default_rng(0)
        eps = 0.25
        _, phases = model.assignment_solve(_random_costs(rng, 32), eps)
        assert phases <= (1 + 2 * eps) / eps**2 + 1


class TestSinkhornStep:
    @settings(max_examples=10, deadline=None)
    @given(n=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, n, seed):
        rng = np.random.default_rng(seed)
        c = rng.random((n, n)).astype(np.float32)
        u = rng.random(n).astype(np.float32) + 0.5
        v = rng.random(n).astype(np.float32) + 0.5
        r = np.full(n, 1.0 / n, dtype=np.float32)
        dem = np.full(n, 1.0 / n, dtype=np.float32)
        eta = 0.2
        gu, gv, gerr = model.sinkhorn_step(c, u, v, r, dem, eta)
        wu, wv, werr = ref.sinkhorn_step_ref(
            jnp.asarray(c), jnp.asarray(u), jnp.asarray(v), jnp.asarray(r), jnp.asarray(dem), eta
        )
        np.testing.assert_allclose(np.array(gu), np.array(wu), rtol=2e-4)
        np.testing.assert_allclose(np.array(gv), np.array(wv), rtol=2e-4)
        np.testing.assert_allclose(float(gerr[0]), float(werr), rtol=2e-3, atol=1e-6)

    def test_iteration_decreases_marginal_error(self):
        rng = np.random.default_rng(1)
        n = 16
        c = rng.random((n, n)).astype(np.float32)
        u = np.ones(n, dtype=np.float32)
        v = np.ones(n, dtype=np.float32)
        r = np.full(n, 1.0 / n, dtype=np.float32)
        dem = np.full(n, 1.0 / n, dtype=np.float32)
        errs = []
        for _ in range(30):
            u, v, err = model.sinkhorn_step(c, u, v, r, dem, 0.3)
            errs.append(float(err[0]))
        assert errs[-1] < errs[0] * 0.5, f"no convergence: {errs[0]} -> {errs[-1]}"


class TestMultiPhase:
    def test_matches_single_phase_chain(self):
        rng = np.random.default_rng(5)
        n = 24
        cq = rng.integers(0, 9, (n, n)).astype(np.int32)
        state = model.pack_phase_state(*model.init_state(jnp.asarray(cq)))
        threshold = 2
        s1 = state
        phases = 0
        while int(jnp.sum(s1[3] < 0)) > threshold:
            s1 = model.phase_step_packed(cq, s1)
            phases += 1
        s2 = model.multi_phase_step(
            cq, state, jnp.asarray([threshold, 10**6], dtype=jnp.int32)
        )
        np.testing.assert_array_equal(np.array(s1[:4]), np.array(s2[:4]))
        assert int(s2[4, 2]) == phases
        assert int(s2[4, 0]) <= threshold

    def test_respects_phase_cap(self):
        rng = np.random.default_rng(6)
        n = 16
        cq = rng.integers(0, 9, (n, n)).astype(np.int32)
        state = model.pack_phase_state(*model.init_state(jnp.asarray(cq)))
        s = model.multi_phase_step(cq, state, jnp.asarray([0, 1], dtype=jnp.int32))
        assert int(s[4, 2]) == 1

    def test_noop_when_below_threshold(self):
        n = 8
        cq = np.zeros((n, n), dtype=np.int32)
        ma = np.arange(n, dtype=np.int32)
        state = model.pack_phase_state(
            jnp.zeros(n, jnp.int32), jnp.zeros(n, jnp.int32), jnp.asarray(ma), jnp.asarray(ma)
        )
        s = model.multi_phase_step(cq, state, jnp.asarray([0, 100], dtype=jnp.int32))
        assert int(s[4, 2]) == 0


class TestCostBuilders:
    def test_euclid_quantized_pipeline(self):
        rng = np.random.default_rng(2)
        n = 32
        pb = rng.random((n, 2)).astype(np.float32)
        pa = rng.random((n, 2)).astype(np.float32)
        costs, cmax = model.cost_euclid(pb, pa)
        assert float(cmax[0]) == pytest.approx(float(np.array(costs).max()))
        eps = 0.1
        inv = 1.0 / (eps * float(cmax[0]))
        cq = np.array(model.quantize(costs, inv))
        assert cq.max() <= int(1 / eps)
        assert (cq >= 0).all()
        cq2, cmax2 = model.cost_euclid_quantized(pb, pa, jnp.asarray([inv], dtype=jnp.float32))
        np.testing.assert_array_equal(np.array(cq2), cq)
        assert float(cmax2[0]) == pytest.approx(float(cmax[0]))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
