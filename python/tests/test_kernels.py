"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
with hypothesis sweeping shapes, seeds and value ranges."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import costs as cost_kernels
from compile.kernels import ref
from compile.kernels import sinkhorn as sk
from compile.kernels.propose import propose, _tile

SIZES = st.sampled_from([4, 8, 16, 24, 32, 64])


def _rand_state(rng, nb, na, max_cost=8):
    cq = rng.integers(0, max_cost, (nb, na)).astype(np.int32)
    ya = -rng.integers(0, 4, na).astype(np.int32)
    yb = rng.integers(0, max_cost + 2, nb).astype(np.int32)
    avail = rng.integers(0, 2, na).astype(np.int32)
    active = rng.integers(0, 2, nb).astype(np.int32)
    return cq, ya, yb, avail, active


class TestPropose:
    @settings(max_examples=25, deadline=None)
    @given(nb=SIZES, na=SIZES, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, nb, na, seed):
        rng = np.random.default_rng(seed)
        args = _rand_state(rng, nb, na)
        got = propose(*[jnp.asarray(x) for x in args])
        want = ref.propose_ref(*[jnp.asarray(x) for x in args])
        np.testing.assert_array_equal(np.array(got), np.array(want))

    def test_nonsquare_tiles(self):
        rng = np.random.default_rng(0)
        args = _rand_state(rng, 48, 16)
        got = propose(*[jnp.asarray(x) for x in args], tb=16, ta=8)
        want = ref.propose_ref(*[jnp.asarray(x) for x in args])
        np.testing.assert_array_equal(np.array(got), np.array(want))

    def test_no_admissible_returns_big(self):
        nb = na = 8
        cq = np.full((nb, na), 100, dtype=np.int32)  # nothing tight
        ya = np.zeros(na, dtype=np.int32)
        yb = np.ones(nb, dtype=np.int32)
        avail = np.ones(na, dtype=np.int32)
        active = np.ones(nb, dtype=np.int32)
        got = np.array(propose(cq, ya, yb, avail, active))
        assert (got == ref.BIG).all()

    def test_inactive_rows_ignored(self):
        nb = na = 8
        cq = np.zeros((nb, na), dtype=np.int32)
        ya = np.zeros(na, dtype=np.int32)
        yb = np.ones(nb, dtype=np.int32)  # all edges admissible
        avail = np.ones(na, dtype=np.int32)
        active = np.zeros(nb, dtype=np.int32)
        active[3] = 1
        got = np.array(propose(cq, ya, yb, avail, active))
        assert got[3] == 0
        assert (np.delete(got, 3) == ref.BIG).all()

    def test_tile_helper(self):
        # default preference is 512 (see §Perf in EXPERIMENTS.md)
        assert _tile(1024) == 512
        assert _tile(256) == 256
        assert _tile(24) == 8
        assert _tile(7) == 1
        assert _tile(256, pref=128) == 128


class TestCostKernels:
    @settings(max_examples=15, deadline=None)
    @given(nb=SIZES, na=SIZES, seed=st.integers(0, 2**31 - 1))
    def test_euclid_matches_ref(self, nb, na, seed):
        rng = np.random.default_rng(seed)
        pb = rng.random((nb, 2)).astype(np.float32)
        pa = rng.random((na, 2)).astype(np.float32)
        got = cost_kernels.euclid_costs(jnp.asarray(pb), jnp.asarray(pa))
        want = ref.euclid_ref(jnp.asarray(pb), jnp.asarray(pa))
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(nb=st.sampled_from([4, 8, 16]), na=st.sampled_from([4, 8, 16]),
           d=st.sampled_from([16, 784]), seed=st.integers(0, 2**31 - 1))
    def test_l1_matches_ref(self, nb, na, d, seed):
        rng = np.random.default_rng(seed)
        xb = rng.random((nb, d)).astype(np.float32)
        xa = rng.random((na, d)).astype(np.float32)
        got = cost_kernels.l1_costs(jnp.asarray(xb), jnp.asarray(xa))
        want = ref.l1_ref(jnp.asarray(xb), jnp.asarray(xa))
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-5)

    def test_euclid_zero_distance_diagonal(self):
        pts = np.random.default_rng(1).random((16, 2)).astype(np.float32)
        c = np.array(cost_kernels.euclid_costs(pts, pts))
        np.testing.assert_allclose(np.diag(c), 0.0, atol=1e-6)


class TestSinkhornKernels:
    @settings(max_examples=15, deadline=None)
    @given(nb=SIZES, na=SIZES, seed=st.integers(0, 2**31 - 1),
           eta=st.sampled_from([0.05, 0.2, 1.0]))
    def test_kv_matches_ref(self, nb, na, seed, eta):
        rng = np.random.default_rng(seed)
        c = rng.random((nb, na)).astype(np.float32)
        v = rng.random(na).astype(np.float32)
        got = sk.sinkhorn_kv(jnp.asarray(c), jnp.asarray(v), eta)
        want = ref.sinkhorn_kv_ref(jnp.asarray(c), jnp.asarray(v), eta)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(nb=SIZES, na=SIZES, seed=st.integers(0, 2**31 - 1),
           eta=st.sampled_from([0.05, 0.2, 1.0]))
    def test_ktu_matches_ref(self, nb, na, seed, eta):
        rng = np.random.default_rng(seed)
        c = rng.random((nb, na)).astype(np.float32)
        u = rng.random(nb).astype(np.float32)
        got = sk.sinkhorn_ktu(jnp.asarray(c), jnp.asarray(u), eta)
        want = ref.sinkhorn_ktu_ref(jnp.asarray(c), jnp.asarray(u), eta)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4)

    def test_kv_identity_kernel(self):
        # eta huge -> K ~ all-ones -> Kv = sum(v)
        c = np.zeros((8, 8), dtype=np.float32)
        v = np.arange(8, dtype=np.float32)
        got = np.array(sk.sinkhorn_kv(c, v, 1.0))
        np.testing.assert_allclose(got, np.full(8, v.sum()), rtol=1e-5)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
