"""L1 Pallas kernels: pairwise cost construction.

Building the cost matrix on-device is what lets the Rust runtime keep all
per-phase state device-resident: the host uploads points/images once
(O(n·d)) instead of an O(n²) cost matrix.

* `euclid_costs` — Fig-1 workload: [n,2] points → [nb,na] distances.
* `l1_costs` — Fig-2 workload: [n,784] normalized images → L1 distances.
  The (TB, TA, D) broadcast tile is the VMEM budget driver:
  32·32·784·4B ≈ 3.2 MiB, inside the ~16 MiB VMEM of a TPU core.

Quantization to ε-units happens in L2 (`model.quantize`) because eps_abs
depends on the data max, which is only known after this kernel runs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .propose import _tile


def _euclid_kernel(pb_ref, pa_ref, o_ref):
    pb = pb_ref[...]  # [TB, 2]
    pa = pa_ref[...]  # [TA, 2]
    dx = pb[:, 0:1] - pa[None, :, 0]
    dy = pb[:, 1:2] - pa[None, :, 1]
    o_ref[...] = jnp.sqrt(dx * dx + dy * dy)


@jax.jit
def euclid_costs(pts_b, pts_a):
    """Pairwise Euclidean distance matrix, rows = B."""
    nb = pts_b.shape[0]
    na = pts_a.shape[0]
    tb, ta = _tile(nb), _tile(na)
    return pl.pallas_call(
        _euclid_kernel,
        grid=(nb // tb, na // ta),
        in_specs=[
            pl.BlockSpec((tb, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((ta, 2), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tb, ta), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, na), jnp.float32),
        interpret=True,
    )(pts_b.astype(jnp.float32), pts_a.astype(jnp.float32))


def _l1_kernel(xb_ref, xa_ref, o_ref):
    xb = xb_ref[...]  # [TB, D]
    xa = xa_ref[...]  # [TA, D]
    o_ref[...] = jnp.sum(jnp.abs(xb[:, None, :] - xa[None, :, :]), axis=-1)


@functools.partial(jax.jit, static_argnames=("tb", "ta"))
def l1_costs(imgs_b, imgs_a, tb: int = 0, ta: int = 0):
    """Pairwise L1 distance matrix between image vectors, rows = B."""
    nb, d = imgs_b.shape
    na, d2 = imgs_a.shape
    assert d == d2
    tb = tb or _tile(nb, 32)
    ta = ta or _tile(na, 32)
    return pl.pallas_call(
        _l1_kernel,
        grid=(nb // tb, na // ta),
        in_specs=[
            pl.BlockSpec((tb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((ta, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tb, ta), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, na), jnp.float32),
        interpret=True,
    )(imgs_b.astype(jnp.float32), imgs_a.astype(jnp.float32))
