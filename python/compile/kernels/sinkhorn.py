"""L1 Pallas kernels for the Sinkhorn baseline: fused exp-kernel matvecs.

The textbook implementation materializes K = exp(-C/η) (an extra n² f32
array). These kernels compute exp(-c/η) *inside the tile* instead — the
TPU-minded trade: recompute on the VPU to halve HBM traffic and VMEM
footprint. η arrives as a (1,1) block so one compiled artifact serves every
accuracy setting.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .propose import _tile


def _kv_kernel(c_ref, v_ref, eta_ref, o_ref):
    j = pl.program_id(1)
    k = jnp.exp(-c_ref[...] / eta_ref[0, 0])
    part = k @ v_ref[...]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = part

    @pl.when(j != 0)
    def _acc():
        o_ref[...] = o_ref[...] + part


@functools.partial(jax.jit, static_argnames=("tb", "ta"))
def sinkhorn_kv(costs, v, eta, tb: int = 0, ta: int = 0):
    """(K v)[b] = Σ_a exp(-C[b,a]/η) · v[a], K never materialized."""
    nb, na = costs.shape
    tb = tb or _tile(nb)
    ta = ta or _tile(na)
    eta2 = jnp.asarray(eta, dtype=jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _kv_kernel,
        grid=(nb // tb, na // ta),
        in_specs=[
            pl.BlockSpec((tb, ta), lambda i, j: (i, j)),
            pl.BlockSpec((ta,), lambda i, j: (j,)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb,), jnp.float32),
        interpret=True,
    )(costs.astype(jnp.float32), v.astype(jnp.float32), eta2)


def _ktu_kernel(c_ref, u_ref, eta_ref, o_ref):
    j = pl.program_id(1)
    # c tile is [TB, TA] with rows = b; we reduce over b for an a-tile output
    k = jnp.exp(-c_ref[...] / eta_ref[0, 0])
    part = k.T @ u_ref[...]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = part

    @pl.when(j != 0)
    def _acc():
        o_ref[...] = o_ref[...] + part


@functools.partial(jax.jit, static_argnames=("tb", "ta"))
def sinkhorn_ktu(costs, u, eta, tb: int = 0, ta: int = 0):
    """(Kᵀ u)[a] = Σ_b exp(-C[b,a]/η) · u[b]."""
    nb, na = costs.shape
    tb = tb or _tile(nb)
    ta = ta or _tile(na)
    eta2 = jnp.asarray(eta, dtype=jnp.float32).reshape(1, 1)
    # grid: (a-tiles, b-tiles); the cost block walks down column-tiles
    return pl.pallas_call(
        _ktu_kernel,
        grid=(na // ta, nb // tb),
        in_specs=[
            pl.BlockSpec((tb, ta), lambda i, j: (j, i)),
            pl.BlockSpec((tb,), lambda i, j: (j,)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ta,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((na,), jnp.float32),
        interpret=True,
    )(costs.astype(jnp.float32), u.astype(jnp.float32), eta2)
