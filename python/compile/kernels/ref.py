"""Pure-jnp / numpy oracles for every Pallas kernel (L1 correctness layer).

These are the ground truth the pytest + hypothesis suite compares the
kernels against. They are deliberately written in the most obvious way
possible — readability over speed.
"""

import jax.numpy as jnp
import numpy as np

#: Sentinel for "no admissible column" — larger than any real column index.
#: Kept as a Python int so Pallas kernels can close over it as a literal.
BIG = 1 << 30


def propose_ref(cq, ya, yb, avail_a, active_b):
    """For every active row b, the smallest column a that is *admissible*
    (tight for the paper's condition (2): ya[a] + yb[b] == cq[b,a] + 1) and
    still available. Returns BIG where no such column exists.

    Shapes: cq int32[nb, na]; ya int32[na]; yb int32[nb];
    avail_a int32[na] (0/1); active_b int32[nb] (0/1).
    """
    nb, na = cq.shape
    adm = (
        (ya[None, :] + yb[:, None] == cq + 1)
        & (avail_a[None, :] == 1)
        & (active_b[:, None] == 1)
    )
    a_ids = jnp.broadcast_to(jnp.arange(na, dtype=jnp.int32)[None, :], (nb, na))
    return jnp.min(jnp.where(adm, a_ids, BIG), axis=1)


def euclid_ref(pts_b, pts_a):
    """Pairwise Euclidean distances; rows = B points, cols = A points."""
    diff = pts_b[:, None, :] - pts_a[None, :, :]
    return jnp.sqrt(jnp.sum(diff * diff, axis=-1))


def l1_ref(imgs_b, imgs_a):
    """Pairwise L1 distances between (normalized) image vectors."""
    return jnp.sum(jnp.abs(imgs_b[:, None, :] - imgs_a[None, :, :]), axis=-1)


def quantize_ref(costs, inv_eps_abs):
    """cq = floor(c / eps_abs) as int32 (paper eq. 1, integer units)."""
    return jnp.floor(costs * inv_eps_abs).astype(jnp.int32)


def sinkhorn_kv_ref(costs, v, eta):
    """(K v)[b] with the kernel K = exp(-C/eta) materialized on the fly."""
    return jnp.exp(-costs / eta) @ v


def sinkhorn_ktu_ref(costs, u, eta):
    """(Kᵀ u)[a]."""
    return jnp.exp(-costs / eta).T @ u


def sinkhorn_step_ref(costs, u, v, r, c, eta):
    """One full Sinkhorn sweep + L1 marginal violation of the new plan."""
    kv = sinkhorn_kv_ref(costs, v, eta)
    u2 = r / kv
    ktu = sinkhorn_ktu_ref(costs, u2, eta)
    v2 = c / ktu
    # marginal violation of P = diag(u2) K diag(v2)
    kv2 = sinkhorn_kv_ref(costs, v2, eta)
    row = u2 * kv2
    ktu2 = sinkhorn_ktu_ref(costs, u2, eta)
    col = v2 * ktu2
    err = jnp.sum(jnp.abs(row - r)) + jnp.sum(jnp.abs(col - c))
    return u2, v2, err


def phase_step_ref(cq, ya, yb, match_a, match_b):
    """Numpy reference for one full push-relabel phase with propose–accept
    rounds — bit-exact semantics of `model.phase_step`:

    * every active free b proposes its smallest admissible available a;
    * each a accepts the smallest proposing b;
    * losers retry next round, non-proposers deactivate;
    * then push (with eviction) and relabel.

    Returns (ya, yb, match_a, match_b, free_count, rounds) as numpy arrays.
    """
    cq = np.asarray(cq)
    ya = np.asarray(ya).copy()
    yb = np.asarray(yb).copy()
    match_a = np.asarray(match_a).copy()
    match_b = np.asarray(match_b).copy()
    nb, na = cq.shape

    free_b = match_b < 0
    taken = np.zeros(na, dtype=bool)
    mprime = np.full(nb, -1, dtype=np.int64)
    active = free_b.copy()
    rounds = 0
    while True:
        rounds += 1
        proposals = {}
        any_prop = False
        for b in range(nb):
            if not active[b]:
                continue
            prop = -1
            for a in range(na):
                if not taken[a] and ya[a] + yb[b] == cq[b, a] + 1:
                    prop = a
                    break
            if prop < 0:
                active[b] = False
            else:
                any_prop = True
                proposals.setdefault(prop, []).append(b)
        if not any_prop:
            break
        for a, bs in proposals.items():
            winner = min(bs)
            taken[a] = True
            mprime[winner] = a
            active[winner] = False
        # losers stay active and retry

    # push + evict
    for b in range(nb):
        a = mprime[b]
        if a >= 0:
            old_b = match_a[a]
            if old_b >= 0:
                match_b[old_b] = -1
            match_a[a] = b
            match_b[b] = a
            ya[a] -= 1
    # relabel b's in B' left unmatched
    for b in range(nb):
        if free_b[b] and mprime[b] < 0:
            yb[b] += 1
    free_count = int(np.sum(match_b < 0))
    return ya, yb, match_a, match_b, free_count, rounds


def check_feasible_ref(cq, ya, yb, match_a, match_b):
    """Integer ε-feasibility checker mirroring rust `core::duals` (used by
    the python test-suite to validate phase sequences)."""
    cq = np.asarray(cq)
    ya = np.asarray(ya)
    yb = np.asarray(yb)
    match_a = np.asarray(match_a)
    match_b = np.asarray(match_b)
    nb, na = cq.shape
    assert all(yb >= 0), "I1: negative y(b)"
    assert all(ya <= 0), "I1: positive y(a)"
    for a in range(na):
        if match_a[a] < 0:
            assert ya[a] == 0, f"I1: free a={a} has y={ya[a]}"
    for b in range(nb):
        for a in range(na):
            s = cq[b, a] + 1 - ya[a] - yb[b]
            if match_b[b] == a:
                assert ya[a] + yb[b] == cq[b, a], f"(3) violated at ({b},{a})"
            else:
                assert s >= 0, f"(2) violated at ({b},{a})"
    # mirror consistency
    for b in range(nb):
        if match_b[b] >= 0:
            assert match_a[match_b[b]] == b
    for a in range(na):
        if match_a[a] >= 0:
            assert match_b[match_a[a]] == a
