"""L1 Pallas kernel: the propose scan — the O(n²) hot spot of every phase.

For each active free row b, find the smallest column a that is admissible
(`ya[a] + yb[b] == cq[b,a] + 1`) and available. This is the dense
admissibility scan that the paper's GPU implementation performs per
propose–accept round; here it is tiled (TB×TA) so every tile fits VMEM and
the reduction over column tiles accumulates into the output block (the
revisited-output pattern — the Pallas analog of the paper's threadblock
grid-stride reduction).

Hardware adaptation (DESIGN.md §2): no MXU work here — the kernel is pure
integer compare/select, which maps to the TPU VPU. VMEM per program =
TB·TA·4B (cq tile) + O(TB+TA) vectors ≈ 1 MiB at the default 512×512 tile
(§Perf: raised from 128×128 — interpret-mode grid-program overhead
dominated; on a real TPU re-tune against the ~16 MiB VMEM budget).

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; the interpreter lowers to plain HLO (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BIG


def _tile(n: int, pref: int = 512) -> int:
    """Largest power-of-two tile ≤ pref that divides n."""
    t = pref
    while t > 1 and n % t != 0:
        t //= 2
    return t


def _propose_kernel(cq_ref, ya_ref, yb_ref, avail_ref, active_ref, o_ref):
    j = pl.program_id(1)
    tb, ta = cq_ref.shape
    cq = cq_ref[...]
    ya = ya_ref[...]
    yb = yb_ref[...]
    adm = (
        (ya[None, :] + yb[:, None] == cq + 1)
        & (avail_ref[...][None, :] == 1)
        & (active_ref[...][:, None] == 1)
    )
    a_ids = j * ta + jax.lax.broadcasted_iota(jnp.int32, (tb, ta), 1)
    cand = jnp.min(jnp.where(adm, a_ids, BIG), axis=1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = cand

    @pl.when(j != 0)
    def _acc():
        o_ref[...] = jnp.minimum(o_ref[...], cand)


@functools.partial(jax.jit, static_argnames=("tb", "ta"))
def propose(cq, ya, yb, avail_a, active_b, tb: int = 0, ta: int = 0):
    """Pallas propose scan. Returns int32[nb]: smallest admissible available
    column per active row, BIG where none. Tile sizes default to the largest
    power of two ≤ 128 dividing each dimension."""
    nb, na = cq.shape
    tb = tb or _tile(nb)
    ta = ta or _tile(na)
    grid = (nb // tb, na // ta)
    return pl.pallas_call(
        _propose_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, ta), lambda i, j: (i, j)),
            pl.BlockSpec((ta,), lambda i, j: (j,)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
            pl.BlockSpec((ta,), lambda i, j: (j,)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb,), jnp.int32),
        interpret=True,
    )(
        cq.astype(jnp.int32),
        ya.astype(jnp.int32),
        yb.astype(jnp.int32),
        avail_a.astype(jnp.int32),
        active_b.astype(jnp.int32),
    )
