"""L2: the paper's per-phase compute graph in JAX, calling the L1 Pallas
kernels, plus the Sinkhorn baseline step. `aot.py` lowers these once to HLO
text; the Rust coordinator then drives the phase loop with device-resident
buffers (Python never runs at request time).

State layout (all int32, matching rust `core::*`):
    cq[nb, na]   quantized costs (ε-units)
    ya[na]       demand duals (≤ 0)        yb[nb]  supply duals (≥ 0)
    match_a[na]  partner b or -1           match_b[nb]  partner a or -1
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import costs as cost_kernels
from .kernels import sinkhorn as sk_kernels
from .kernels.propose import propose
from .kernels.ref import BIG


@jax.jit
def quantize(costs, inv_eps_abs):
    """cq = floor(c · inv_eps_abs) — paper eq. (1) in integer units."""
    return jnp.floor(costs * inv_eps_abs).astype(jnp.int32)


@jax.jit
def cost_euclid(pts_b, pts_a):
    """Fig-1 cost build: pairwise Euclidean distances + max (for ε_abs)."""
    c = cost_kernels.euclid_costs(pts_b, pts_a)
    return c, jnp.max(c).reshape(1)


@jax.jit
def cost_l1(imgs_b, imgs_a):
    """Fig-2 cost build: pairwise L1 distances + max."""
    c = cost_kernels.l1_costs(imgs_b, imgs_a)
    return c, jnp.max(c).reshape(1)


@jax.jit
def phase_step(cq, ya, yb, match_a, match_b):
    """One push-relabel phase (paper §2.2) with the greedy maximal matching
    realized as propose–accept rounds (§3.2's parallel structure):

    * propose (Pallas kernel): every active free b picks its smallest
      admissible available a;
    * accept: each a keeps the smallest proposing b (scatter-min);
    * repeat until no proposals — M' is then maximal;
    * push with eviction + relabel.

    Returns (ya, yb, match_a, match_b, free_count, rounds).
    """
    nb, na = cq.shape
    b_idx = jnp.arange(nb, dtype=jnp.int32)
    bigb = jnp.int32(nb + 7)

    free_b = match_b < 0

    def cond(state):
        return state[3]

    def body(state):
        taken, mprime, active, _, rounds = state
        avail = (taken == 0).astype(jnp.int32)
        prop = propose(cq, ya, yb, avail, active.astype(jnp.int32))
        proposed = prop < jnp.int32(na)
        prop_c = jnp.where(proposed, prop, 0)
        # accept: smallest proposing b wins each a
        win = jnp.full((na,), bigb, dtype=jnp.int32)
        win = win.at[prop_c].min(jnp.where(proposed, b_idx, bigb), mode="drop")
        won = proposed & (win[prop_c] == b_idx)
        mprime = jnp.where(won, prop, mprime)
        taken = taken.at[prop_c].max(won.astype(jnp.int32), mode="drop")
        active = active & proposed & ~won
        return (taken, mprime, active, jnp.any(proposed), rounds + 1)

    taken0 = jnp.zeros((na,), dtype=jnp.int32)
    mprime0 = jnp.full((nb,), -1, dtype=jnp.int32)
    state0 = (taken0, mprime0, free_b, jnp.array(True), jnp.int32(0))
    taken, mprime, _, _, rounds = jax.lax.while_loop(cond, body, state0)

    # --- push (matching update with eviction) ---
    matched = mprime >= 0
    mprime_c = jnp.where(matched, mprime, 0)
    old_b = match_a[mprime_c]  # previous partner of the a each b matched
    evict_idx = jnp.where(matched & (old_b >= 0), old_b, nb)
    match_b1 = match_b.at[evict_idx].set(-1, mode="drop")
    set_idx = jnp.where(matched, b_idx, nb)
    match_b2 = match_b1.at[set_idx].set(mprime, mode="drop")
    seta_idx = jnp.where(matched, mprime_c, na)
    match_a2 = match_a.at[seta_idx].set(b_idx, mode="drop")

    # --- relabel ---
    ya2 = ya - taken
    yb2 = yb + (free_b & ~matched).astype(jnp.int32)

    free_count = jnp.sum(match_b2 < 0).astype(jnp.int32)
    return ya2, yb2, match_a2, match_b2, free_count, rounds


@jax.jit
def sinkhorn_step(costs, u, v, r, c, eta):
    """One Sinkhorn sweep using the fused exp-matvec Pallas kernels, plus
    the L1 marginal violation of the updated plan (the stopping signal the
    Rust driver polls)."""
    eta = jnp.asarray(eta, dtype=jnp.float32).reshape(())
    kv = sk_kernels.sinkhorn_kv(costs, v, eta)
    u2 = r / kv
    ktu = sk_kernels.sinkhorn_ktu(costs, u2, eta)
    v2 = c / ktu
    kv2 = sk_kernels.sinkhorn_kv(costs, v2, eta)
    row = u2 * kv2
    col = v2 * ktu  # note: v2·ktu == column sums of diag(u2)·K·diag(v2)
    err = (jnp.sum(jnp.abs(row - r)) + jnp.sum(jnp.abs(col - c))).reshape(1)
    return u2, v2, err


def init_state(cq):
    """Paper §2.2 initialization: y(b)=1 unit, y(a)=0, M = ∅."""
    nb, na = cq.shape
    return (
        jnp.zeros((na,), dtype=jnp.int32),
        jnp.ones((nb,), dtype=jnp.int32),
        jnp.full((na,), -1, dtype=jnp.int32),
        jnp.full((nb,), -1, dtype=jnp.int32),
    )


def assignment_solve(costs, eps, max_phases=None):
    """Full solve in Python (test/debug path; the production loop lives in
    rust/src/runtime/xla_assignment.rs). Returns (match_b, phase_count).
    """
    costs = jnp.asarray(costs, dtype=jnp.float32)
    nb, na = costs.shape
    c_max = float(jnp.max(costs))
    eps_abs = eps * c_max if c_max > 0 else 1.0
    cq = quantize(costs, 1.0 / eps_abs)
    ya, yb, match_a, match_b = init_state(cq)
    threshold = int(eps * nb)
    if max_phases is None:
        max_phases = int(4 * (1 + 2 * eps) / (eps * eps)) + 4
    phases = 0
    while int(jnp.sum(match_b < 0)) > threshold:
        ya, yb, match_a, match_b, _, _ = phase_step(cq, ya, yb, match_a, match_b)
        phases += 1
        if phases > max_phases:
            raise RuntimeError("phase cap exceeded (bug)")
    # arbitrary completion
    mb = list(jax.device_get(match_b))
    free_a = [a for a in range(na) if int(jax.device_get(match_a)[a]) < 0]
    it = iter(free_a)
    for b in range(nb):
        if mb[b] < 0:
            try:
                mb[b] = next(it)
            except StopIteration:
                break
    return jnp.asarray(mb, dtype=jnp.int32), phases


# Convenience wrapper exercised by the AOT smoke test: a single fused
# "build costs → quantize" step for the Fig-1 pipeline.
@jax.jit
def cost_euclid_quantized(pts_b, pts_a, inv_eps_abs):
    c, cmax = cost_euclid(pts_b, pts_a)
    return quantize(c, inv_eps_abs), cmax


# ---------------------------------------------------------------------------
# Packed-state wrappers — the forms that are AOT-lowered.
#
# xla_extension 0.5.1's PJRT wrapper returns multi-output computations as a
# single *tuple buffer* that cannot be fed back into `execute_b`, so every
# artifact is lowered with return_tuple=False and exactly ONE array output.
# Solver state is therefore packed into a single tensor:
#   phase_step:    i32[5, n] rows = (ya, yb, match_a, match_b, meta)
#                  meta[0] = free_count, meta[1] = rounds of the last phase
#   sinkhorn_step: f32[3, n] rows = (u, v, meta), meta[0] = marginal err
# The Rust driver keeps cq/costs device-resident and round-trips only the
# O(n) state tensor per step (to read the termination scalar).
# ---------------------------------------------------------------------------


@jax.jit
def phase_step_packed(cq, state):
    """One phase over packed state i32[5, n] (see module docstring)."""
    ya, yb, ma, mb = state[0], state[1], state[2], state[3]
    ya2, yb2, ma2, mb2, free_count, rounds = phase_step(cq, ya, yb, ma, mb)
    n = cq.shape[0]
    meta = jnp.zeros((n,), dtype=jnp.int32).at[0].set(free_count).at[1].set(rounds)
    return jnp.stack([ya2, yb2, ma2, mb2, meta])


def pack_phase_state(ya, yb, ma, mb):
    n = ya.shape[0]
    meta = jnp.zeros((n,), dtype=jnp.int32)
    return jnp.stack([ya, yb, ma, mb, meta])


@jax.jit
def sinkhorn_step_packed(costs, state, r, c, eta):
    """One Sinkhorn sweep over packed state f32[3, n]."""
    u, v = state[0], state[1]
    u2, v2, err = sinkhorn_step(costs, u, v, r, c, eta[0])
    n = costs.shape[0]
    meta = jnp.zeros((n,), dtype=jnp.float32).at[0].set(err[0])
    return jnp.stack([u2, v2, meta])


@jax.jit
def matrix_max(m):
    """Max entry as f32[1] (feeds ε_abs computation on the Rust side)."""
    return jnp.max(m).reshape(1)


@jax.jit
def multi_phase_step(cq, state, params):
    """Run up to `params[1]` phases on-device, stopping early once the free
    count drops to `params[0]` (the ε·n termination threshold).

    This is the L2 half of the §Perf optimization in EXPERIMENTS.md: the
    per-phase host round trip (state download + dispatch) dominates small-n
    solves, so the Rust driver asks for K phases per call instead of 1.

    meta row on return: [free_count, rounds_total, phases_executed, 0...].
    """
    threshold = params[0]
    max_phases = params[1]

    def cond(carry):
        state, phases, _ = carry
        free = jnp.sum(state[3] < 0)
        return (free > threshold) & (phases < max_phases)

    def body(carry):
        state, phases, rounds = carry
        new_state = phase_step_packed(cq, state)
        rounds = rounds + new_state[4, 1]
        return (new_state, phases + 1, rounds)

    state, phases, rounds = jax.lax.while_loop(
        cond, body, (state, jnp.int32(0), jnp.int32(0))
    )
    free = jnp.sum(state[3] < 0).astype(jnp.int32)
    meta = (
        jnp.zeros((cq.shape[0],), dtype=jnp.int32)
        .at[0]
        .set(free)
        .at[1]
        .set(rounds)
        .at[2]
        .set(phases)
    )
    return jnp.concatenate([state[:4], meta[None, :]], axis=0)


__all__ = [
    "quantize",
    "cost_euclid",
    "cost_l1",
    "cost_euclid_quantized",
    "phase_step",
    "phase_step_packed",
    "pack_phase_state",
    "multi_phase_step",
    "sinkhorn_step",
    "sinkhorn_step_packed",
    "matrix_max",
    "init_state",
    "assignment_solve",
    "BIG",
]
