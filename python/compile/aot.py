"""AOT lowering: JAX (L2) + Pallas (L1) → HLO **text** artifacts + manifest.

Run once via `make artifacts` (`python -m compile.aot --out-dir ../artifacts`).
The Rust runtime (`rust/src/runtime/`) loads the text with
`HloModuleProto::from_text_file`, compiles on the PJRT CPU client, and
drives the solve loops. Two interchange constraints shape this file (see
/opt/xla-example/README and DESIGN.md §5):

* HLO **text**, not `.serialize()` — jax ≥ 0.5 emits 64-bit instruction ids
  that xla_extension 0.5.1 rejects; the text parser reassigns ids.
* **Single array output per artifact**, lowered with return_tuple=False —
  the 0.5.1 PJRT wrapper returns tuple outputs as one opaque tuple buffer
  that cannot be fed back into `execute_b`, so solver state is packed into
  one tensor (`model.phase_step_packed` / `sinkhorn_step_packed`).

Artifacts per size n (powers of two; requests are padded up by the router):
    phase_step_{n}     (cq i32[n,n], state i32[5,n]) → state'
    cost_euclid_{n}    (pts_b f32[n,2], pts_a f32[n,2]) → costs f32[n,n]
    cost_l1_{n}        (imgs_b f32[n,784], imgs_a f32[n,784]) → costs
    matrix_max_{n}     (m f32[n,n]) → f32[1]
    quantize_{n}       (costs f32[n,n], inv_eps_abs f32[1]) → cq i32[n,n]
    sinkhorn_step_{n}  (costs, state f32[3,n], r f32[n], c f32[n], eta f32[1])
                       → state'
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DEFAULT_SIZES = [256, 512, 1024, 2048, 4096]
IMG_DIM = 784


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text, single (untupled) result."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_defs(n: int):
    """(name, jitted fn, example arg specs, input names, output name)."""
    i32, f32 = jnp.int32, jnp.float32
    return [
        (
            f"phase_step_{n}",
            jax.jit(model.phase_step_packed),
            [_spec((n, n), i32), _spec((5, n), i32)],
            ["cq", "state"],
            "state",
        ),
        (
            f"multi_phase_{n}",
            jax.jit(model.multi_phase_step),
            [_spec((n, n), i32), _spec((5, n), i32), _spec((2,), i32)],
            ["cq", "state", "params"],
            "state",
        ),
        (
            f"cost_euclid_{n}",
            jax.jit(lambda pb, pa: model.cost_euclid(pb, pa)[0]),
            [_spec((n, 2), f32), _spec((n, 2), f32)],
            ["pts_b", "pts_a"],
            "costs",
        ),
        (
            f"cost_l1_{n}",
            jax.jit(lambda xb, xa: model.cost_l1(xb, xa)[0]),
            [_spec((n, IMG_DIM), f32), _spec((n, IMG_DIM), f32)],
            ["imgs_b", "imgs_a"],
            "costs",
        ),
        (
            f"matrix_max_{n}",
            jax.jit(model.matrix_max),
            [_spec((n, n), f32)],
            ["m"],
            "cmax",
        ),
        (
            f"quantize_{n}",
            jax.jit(lambda c, inv: model.quantize(c, inv[0])),
            [_spec((n, n), f32), _spec((1,), f32)],
            ["costs", "inv_eps_abs"],
            "cq",
        ),
        (
            f"sinkhorn_step_{n}",
            jax.jit(model.sinkhorn_step_packed),
            [_spec((n, n), f32), _spec((3, n), f32), _spec((n,), f32), _spec((n,), f32), _spec((1,), f32)],
            ["costs", "state", "r", "c", "eta"],
            "state",
        ),
    ]


def build(out_dir: str, sizes) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 2, "sizes": sorted(sizes), "img_dim": IMG_DIM, "artifacts": []}
    for n in sorted(sizes):
        for name, fn, specs, in_names, out_name in artifact_defs(n):
            lowered = fn.lower(*specs)
            text = to_hlo_text(lowered)
            fname = f"{name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "kind": name.rsplit("_", 1)[0],
                    "n": n,
                    "file": fname,
                    "inputs": in_names,
                    "outputs": [out_name],
                }
            )
            print(f"  wrote {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"manifest: {len(manifest['artifacts'])} artifacts in {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--sizes",
        default=",".join(str(s) for s in DEFAULT_SIZES),
        help="comma-separated artifact sizes (powers of two)",
    )
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s.strip()]
    build(args.out_dir, sizes)


if __name__ == "__main__":
    main()
