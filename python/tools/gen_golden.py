#!/usr/bin/env python3
"""Generate the golden conformance corpus under rust/testdata/golden/.

Every instance lives on a 1/16 grid (costs and masses are multiples of
1/16) so all values — and the pinned exact optima — are exactly
representable in f32/f64 and survive JSON round-trips bit-for-bit. The
cost formula mirrors `otpr::data::workloads::golden_cost`:

    c(b, a) = ((7*b + 11*a + 3*a*b + salt) % 17) / 16

Exact references are computed in exact rational arithmetic:

* assignment: O(n^3) Jonker-Volgenant shortest-augmenting-path Hungarian
  over Fractions (scales to any pin size), cross-checked against the
  O(n!) brute force on n <= 8 so the two independent oracles must agree;
* OT: masses scaled to 16 integer units, cycle-canceling min-cost flow
  from a northwest-corner start, then the result is *verified* with a
  duality certificate (Bellman-Ford potentials must be feasible and
  complementarily slack), so a bug in the optimizer cannot silently
  produce a wrong pin.
"""

import itertools
import json
import os
from fractions import Fraction

SCALE = 16
MOD = 17


def cost(b, a, salt):
    return Fraction((7 * b + 11 * a + 3 * a * b + salt) % MOD, SCALE)


ASSIGN_CASES = [
    ("assign-n4", 4, 1),
    ("assign-n5", 5, 2),
    ("assign-n6", 6, 3),
    ("assign-n8", 8, 5),
]

# (name, nb, na, salt, supply units over 16 (rows), demand units (cols))
OT_CASES = [
    ("ot-3x4", 3, 4, 7, [8, 5, 3], [4, 4, 4, 4]),
    ("ot-4x4", 4, 4, 13, [4, 4, 4, 4], [1, 2, 6, 7]),
    ("ot-5x5", 5, 5, 11, [6, 4, 3, 2, 1], [2, 2, 4, 4, 4]),
    ("ot-6x6", 6, 6, 17, [2, 2, 2, 2, 4, 4], [3, 3, 3, 3, 2, 2]),
]


def brute_force_assignment(n, salt):
    """O(n!) cross-check oracle — tiny instances only (mirrors the hard
    limit in rust solvers/hungarian.rs::brute_force_reference)."""
    assert n <= 8, f"brute force is O(n!): refusing n={n} > 8 — use hungarian_assignment"
    best = None
    for perm in itertools.permutations(range(n)):
        tot = sum(cost(b, perm[b], salt) for b in range(n))
        if best is None or tot < best:
            best = tot
    return best


def hungarian_assignment(n, salt):
    """Exact O(n^3) Jonker-Volgenant Hungarian in rational arithmetic.

    Classic 1-based formulation with dual potentials (u over rows, v over
    cols); all arithmetic in Fractions, so the pin is exact. This is the
    path golden-pin regeneration uses at any n (the brute force would
    explode beyond n=8).
    """
    INF = None  # None = +infinity sentinel (Fraction has no inf)

    def less(a, b):
        if b is INF:
            return a is not INF
        if a is INF:
            return False
        return a < b

    c = [[cost(b, a, salt) for a in range(n)] for b in range(n)]
    u = [Fraction(0)] * (n + 1)
    v = [Fraction(0)] * (n + 1)
    p = [0] * (n + 1)  # p[j] = row matched to column j
    way = [0] * (n + 1)
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = [INF] * (n + 1)
        used = [False] * (n + 1)
        while True:
            used[j0] = True
            i0 = p[j0]
            delta = INF
            j1 = 0
            for j in range(1, n + 1):
                if not used[j]:
                    cur = c[i0 - 1][j - 1] - u[i0] - v[j]
                    if less(cur, minv[j]):
                        minv[j] = cur
                        way[j] = j0
                    if less(minv[j], delta):
                        delta = minv[j]
                        j1 = j
            assert delta is not INF, "disconnected instance"
            for j in range(n + 1):
                if used[j]:
                    u[p[j]] += delta
                    v[j] -= delta
                elif minv[j] is not INF:
                    minv[j] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while True:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
            if j0 == 0:
                break
    total = Fraction(0)
    matched_rows = set()
    for j in range(1, n + 1):
        assert p[j] != 0, "imperfect matching: optimizer bug"
        matched_rows.add(p[j])
        total += c[p[j] - 1][j - 1]
    assert len(matched_rows) == n
    # duality certificate: u_i + v_j <= c_ij everywhere, tight on matched
    for b in range(1, n + 1):
        for a in range(1, n + 1):
            red = c[b - 1][a - 1] - u[b] - v[a]
            assert red >= 0, "dual infeasible: optimizer bug"
            if p[a] == b:
                assert red == 0, "slackness violated: optimizer bug"
    return total


def exact_assignment(n, salt):
    """Hungarian at any n; brute-force cross-check while it's tractable."""
    exact = hungarian_assignment(n, salt)
    if n <= 8:
        assert exact == brute_force_assignment(n, salt), \
            f"oracle disagreement at n={n}, salt={salt}"
    return exact


def exact_ot_units(nb, na, salt, supply, demand):
    """Min-cost integral flow shipping all units; returns Fraction cost.

    Cycle canceling: start from the (feasible) northwest-corner flow, then
    cancel negative residual cycles found by Bellman-Ford until none
    remain; finally verify optimality via dual feasibility + complementary
    slackness.
    """
    assert sum(supply) == sum(demand) == SCALE
    c = [[cost(b, a, salt) for a in range(na)] for b in range(nb)]
    # northwest corner start
    flow = [[0] * na for _ in range(nb)]
    s = supply[:]
    d = demand[:]
    b = a = 0
    while b < nb and a < na:
        k = min(s[b], d[a])
        flow[b][a] += k
        s[b] -= k
        d[a] -= k
        if s[b] == 0:
            b += 1
        else:
            a += 1
    # residual graph nodes: 0..nb-1 supplies, nb..nb+na-1 demands
    n_nodes = nb + na

    def edges():
        out = []
        for bb in range(nb):
            for aa in range(na):
                # forward: always available (capacity unbounded up to mass)
                out.append((bb, nb + aa, c[bb][aa], (bb, aa, 1)))
                if flow[bb][aa] > 0:
                    out.append((nb + aa, bb, -c[bb][aa], (bb, aa, -1)))
        return out

    def find_negative_cycle():
        es = edges()
        dist = [Fraction(0)] * n_nodes
        pred = [None] * n_nodes
        x = None
        for _ in range(n_nodes):
            x = None
            for (u, v, w, tag) in es:
                if dist[u] + w < dist[v]:
                    dist[v] = dist[u] + w
                    pred[v] = (u, tag)
                    x = v
        if x is None:
            return None
        # walk back n steps to land inside the cycle
        for _ in range(n_nodes):
            x = pred[x][0]
        cyc = []
        v = x
        while True:
            u, tag = pred[v]
            cyc.append(tag)
            v = u
            if v == x:
                break
        return cyc

    while True:
        cyc = find_negative_cycle()
        if cyc is None:
            break
        # max augmentation = min residual over backward arcs in the cycle
        k = min(flow[bb][aa] for (bb, aa, sgn) in cyc if sgn < 0)
        assert k > 0
        for (bb, aa, sgn) in cyc:
            flow[bb][aa] += sgn * k

    total = sum(flow[bb][aa] * c[bb][aa] for bb in range(nb) for aa in range(na))
    # duality certificate: potentials from Bellman-Ford on the residual
    # graph (no negative cycle => well-defined)
    es = edges()
    pot = [Fraction(0)] * n_nodes
    for _ in range(n_nodes):
        for (u, v, w, _) in es:
            if pot[u] + w < pot[v]:
                pot[v] = pot[u] + w
    for bb in range(nb):
        for aa in range(na):
            red = c[bb][aa] + pot[bb] - pot[nb + aa]
            assert red >= 0, "dual infeasible: optimizer bug"
            if flow[bb][aa] > 0:
                assert red == 0, "slackness violated: optimizer bug"
    # marginals
    for bb in range(nb):
        assert sum(flow[bb]) == supply[bb]
    for aa in range(na):
        assert sum(flow[bb][aa] for bb in range(nb)) == demand[aa]
    return total / SCALE  # units -> mass


def frac_to_float(x):
    f = float(x)
    assert Fraction(f) == x, f"{x} not exact in f64"
    return f


def write_case(out_dir, name, kind, nb, na, salt, payload):
    doc = {
        "name": name,
        "kind": kind,
        "nb": nb,
        "na": na,
        "salt": salt,
        "costs": [
            frac_to_float(cost(b, a, salt)) for b in range(nb) for a in range(na)
        ],
        "note": "c(b,a) = ((7b + 11a + 3ab + salt) mod 17) / 16",
    }
    doc.update(payload)
    path = os.path.join(out_dir, f"{name}.json")
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {path}: exact_cost={doc['exact_cost']}")


def main():
    root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    out_dir = os.path.join(root, "rust", "testdata", "golden")
    os.makedirs(out_dir, exist_ok=True)
    for (name, n, salt) in ASSIGN_CASES:
        exact = exact_assignment(n, salt)
        write_case(out_dir, name, "assignment", n, n, salt,
                   {"exact_cost": frac_to_float(exact)})
    for (name, nb, na, salt, supply, demand) in OT_CASES:
        exact = exact_ot_units(nb, na, salt, supply, demand)
        write_case(out_dir, name, "ot", nb, na, salt, {
            "exact_cost": frac_to_float(exact),
            "supply": [frac_to_float(Fraction(u, SCALE)) for u in supply],
            "demand": [frac_to_float(Fraction(u, SCALE)) for u in demand],
        })


if __name__ == "__main__":
    main()
