//! Serving demo: the L3 coordinator as an OT-solving service — a stream of
//! heterogeneous requests (assignment + OT, mixed sizes and accuracies)
//! flows through the router/batcher/worker pool; throughput, the latency
//! histogram, and per-engine phase counts (streamed live from the solvers'
//! progress hook) are reported at the end. When artifacts exist, large
//! assignment jobs are automatically routed to the XLA engine.
//!
//!     cargo run --release --example serve_demo

use otpr::api::SolveRequest;
use otpr::coordinator::{Coordinator, CoordinatorConfig, Engine, JobKind};
use otpr::data::workloads::Workload;
use otpr::runtime::XlaRuntime;
use otpr::util::rng::Pcg32;
use otpr::util::timer::Stopwatch;
use std::time::Duration;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let runtime = XlaRuntime::open_default()
        .map_err(|e| eprintln!("note: XLA engine disabled ({e})"))
        .ok();
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 4, ..Default::default() },
        runtime,
    );

    let mut rng = Pcg32::new(9);
    let sw = Stopwatch::start();
    let mut handles = Vec::new();
    let total_jobs: usize = 40;
    for i in 0..total_jobs {
        let roll = rng.next_below(10);
        let (kind, eps) = if roll < 6 {
            // small interactive assignment queries
            let n = 50 + rng.next_below(150) as usize;
            (JobKind::Assignment(Workload::Fig1 { n }.assignment(i as u64)), 0.2)
        } else if roll < 8 {
            // batch-sized assignment (router may pick XLA)
            (JobKind::Assignment(Workload::Fig1 { n: 512 }.assignment(i as u64)), 0.3)
        } else {
            // general OT with random masses
            let n = 30 + rng.next_below(50) as usize;
            (JobKind::Ot(Workload::Fig1 { n }.ot_with_random_masses(i as u64)), 0.25)
        };
        // every job carries a generous per-job wall-clock budget — the
        // coordinator's timeout story is just a SolveRequest field
        let request = SolveRequest::new(eps).with_budget(Duration::from_secs(30));
        handles.push(coord.submit_request(kind, request, Engine::Auto)?);
    }

    let mut ok = 0usize;
    let mut by_engine: std::collections::BTreeMap<&'static str, usize> = Default::default();
    for h in handles {
        let out = h.wait()?;
        match out.result {
            Ok(sol) => {
                match (sol.matching(), sol.plan()) {
                    (Some(m), _) => assert!(m.is_perfect()),
                    (_, Some(p)) => assert!((p.total_mass() - 1.0).abs() < 1e-9),
                    _ => unreachable!("a solution is a matching or a plan"),
                }
                assert!(!sol.is_cancelled(), "30s budget should never trip here");
                ok += 1;
            }
            Err(e) => eprintln!("job {} failed: {e}", out.id),
        }
        *by_engine.entry(out.engine_used).or_default() += 1;
    }
    let wall = sw.elapsed_secs();
    println!("\n{ok}/{total_jobs} jobs in {wall:.2}s  ({:.1} jobs/s)", ok as f64 / wall);
    println!("engine mix: {by_engine:?}");
    println!("\n--- coordinator metrics ---\n{}", coord.metrics.snapshot());
    for c in coord.metrics.engine_counters() {
        println!("live phase feed: {} ran {} phase-events over {} jobs", c.engine, c.phases, c.jobs);
    }
    coord.shutdown();
    assert_eq!(ok, total_jobs);
    println!("serve_demo OK");
    Ok(())
}
