//! Quickstart: the unified `otpr::api` solve surface in one tour —
//! registry lookup, request builder, unified `Solution`, progress
//! observation, and cancellation — verified against exact baselines.
//!
//!     cargo run --release --example quickstart

use otpr::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
use otpr::data::workloads::Workload;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // One registry, one config: every engine is a string key. `otpr
    // engines` (or api::ENGINE_SPECS) lists them all.
    let solvers = SolverRegistry::with_defaults();
    let config = SolverConfig::default();

    // --- assignment: 500 random points per side in the unit square ---
    let n = 500;
    let eps = 0.1; // overall additive target: cost ≤ OPT + ε·n·c_max
    let problem = Problem::Assignment(Workload::Fig1 { n }.assignment(42));

    // Progress observation: the solver reports (phase, free vertices) live.
    let phases_seen = Arc::new(AtomicUsize::new(0));
    let counter = phases_seen.clone();
    let request = SolveRequest::new(eps)
        .with_observer(move |_p| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
    let sol = solvers.solve("native-seq", &config, &problem, &request)?;
    println!(
        "push-relabel: cost = {:.4} in {} phases ({:.1} ms, {} progress events)",
        sol.cost,
        sol.stats.phases,
        sol.stats.seconds * 1e3,
        phases_seen.load(Ordering::Relaxed),
    );
    assert!(sol.duals.is_some(), "push-relabel ships its dual certificate");

    let exact = solvers.solve("hungarian", &config, &problem, &SolveRequest::new(0.0))?;
    let budget = eps * n as f64 * problem.costs().max() as f64;
    println!(
        "exact:        cost = {:.4} → additive error {:.4} (guarantee ≤ {budget:.4})",
        exact.cost,
        sol.cost - exact.cost
    );
    assert!(sol.cost <= exact.cost + budget + 1e-6);

    // --- general OT: random masses on the same support, same engine key ---
    let problem = Problem::Ot(Workload::Fig1 { n: 100 }.ot_with_random_masses(7));
    let sol = solvers.solve("native-seq", &config, &problem, &SolveRequest::new(eps))?;
    let exact = solvers.solve("ssp-exact", &config, &problem, &SolveRequest::new(0.0))?;
    println!(
        "OT: pr = {:.5}, exact = {:.5}, plan support = {} entries (compact!)",
        sol.cost,
        exact.cost,
        sol.plan().expect("OT returns a plan").support_size()
    );
    assert!(sol.cost <= exact.cost + eps * problem.costs().max() as f64 + 1e-9);

    // --- wall-clock budget: a zero budget cancels at the first phase ---
    let problem = Problem::Assignment(Workload::Fig1 { n: 300 }.assignment(9));
    let rushed = SolveRequest::new(0.01).with_budget(Duration::ZERO);
    let sol = solvers.solve("native-seq", &config, &problem, &rushed)?;
    assert!(sol.is_cancelled(), "budget exhaustion is reported in notes");
    assert!(sol.matching().unwrap().is_perfect(), "still a usable matching");
    println!("budgeted solve: cancelled after {} phases, still perfect", sol.stats.phases);

    println!("quickstart OK");
    Ok(())
}
