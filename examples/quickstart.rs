//! Quickstart: solve an assignment and an OT instance with the paper's
//! push-relabel algorithm, and verify the additive guarantee against exact
//! baselines.
//!
//!     cargo run --release --example quickstart

use otpr::data::workloads::Workload;
use otpr::solvers::hungarian::Hungarian;
use otpr::solvers::ot_push_relabel::OtPushRelabel;
use otpr::solvers::push_relabel::PushRelabel;
use otpr::solvers::ssp_ot::SspExactOt;
use otpr::solvers::{AssignmentSolver, OtSolver};

fn main() -> anyhow::Result<()> {
    // --- assignment: 500 random points per side in the unit square ---
    let n = 500;
    let eps = 0.1; // overall additive target: cost ≤ OPT + ε·n·c_max
    let inst = Workload::Fig1 { n }.assignment(42);
    let sol = PushRelabel::new().solve_assignment(&inst, eps)?;
    println!(
        "push-relabel: cost = {:.4} in {} phases ({:.1} ms)",
        sol.cost,
        sol.stats.phases,
        sol.stats.seconds * 1e3
    );

    let exact = Hungarian.solve_assignment(&inst, 0.0)?;
    let budget = eps * n as f64 * inst.costs.max() as f64;
    println!(
        "exact:        cost = {:.4} → additive error {:.4} (guarantee ≤ {budget:.4})",
        exact.cost,
        sol.cost - exact.cost
    );
    assert!(sol.cost <= exact.cost + budget + 1e-6);

    // --- general OT: random masses on the same support ---
    let inst = Workload::Fig1 { n: 100 }.ot_with_random_masses(7);
    let sol = OtPushRelabel::new().solve_ot(&inst, eps)?;
    let exact = SspExactOt::default().solve_ot(&inst, 0.0)?;
    println!(
        "OT: pr = {:.5}, exact = {:.5}, plan support = {} entries (compact!)",
        sol.cost,
        exact.cost,
        sol.plan.support_size()
    );
    assert!(sol.cost <= exact.cost + eps * inst.costs.max() as f64 + 1e-9);
    println!("quickstart OK");
    Ok(())
}
