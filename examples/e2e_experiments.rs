//! End-to-end experiment driver — the run recorded in EXPERIMENTS.md.
//!
//! Proves all layers compose on a real small workload:
//!   L1 Pallas kernels → L2 JAX phase graph → HLO artifacts → PJRT runtime
//!   → L3 coordinator service → paper-style figures + accuracy certificates.
//!
//! Stages:
//!   1. Figure-1 slice (synthetic geometric assignment) through the
//!      *coordinator* on all engines, with runtimes and measured additive
//!      error vs exact Hungarian.
//!   2. Figure-2 slice (MNIST-style images) the same way.
//!   3. General-OT accuracy sweep vs exact SSP.
//!   4. Headline check: push-relabel vs Sinkhorn runtime at equal accuracy
//!      targets (the paper's main experimental claim).
//!
//! Exact baselines run through the same `SolverRegistry` as everything
//! else; coordinator jobs return the unified `api::Solution`.
//!
//!     cargo run --release --example e2e_experiments

use otpr::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
use otpr::coordinator::{Coordinator, CoordinatorConfig, Engine, JobKind};
use otpr::data::workloads::Workload;
use otpr::exp::report::{figure_table, Series};
use otpr::runtime::XlaRuntime;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let runtime = XlaRuntime::open_default()
        .map_err(|e| eprintln!("note: XLA engines disabled ({e})"))
        .ok();
    let have_xla = runtime.is_some();
    let coord =
        Coordinator::start(CoordinatorConfig { workers: 2, ..Default::default() }, runtime);
    let solvers = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let exact_of = |problem: &Problem| -> Result<f64, Box<dyn std::error::Error>> {
        // the exact oracles need a slab: materialize implicit problems
        let dense;
        let problem = match problem {
            Problem::Implicit(_) => {
                dense = problem.to_dense()?;
                &dense
            }
            other => other,
        };
        let key = match problem {
            Problem::Assignment(_) => "hungarian",
            Problem::Ot(_) => "ssp-exact",
            Problem::Implicit(_) => unreachable!("materialized above"),
        };
        Ok(solvers.solve(key, &config, problem, &SolveRequest::new(0.0))?.cost)
    };

    // ---------- stage 1: Figure-1 slice through the coordinator ----------
    println!("=== stage 1: Figure-1 slice (synthetic, Euclidean costs) ===\n");
    let eps = 0.1; // overall additive target per job
    let sizes = [128usize, 256, 512];
    let mut engines: Vec<(&str, Engine)> = vec![
        ("pr-native", Engine::NativeSeq),
        ("pr-parallel", Engine::NativeParallel),
        ("sinkhorn", Engine::SinkhornNative),
    ];
    if have_xla {
        engines.push(("pr-xla", Engine::Xla));
    }
    let mut runtime_series: Vec<Series> =
        engines.iter().map(|(name, _)| Series::new(*name)).collect();
    let mut error_series = Series::new("pr-native additive error / budget");
    for &n in &sizes {
        let problem = Problem::Assignment(Workload::Fig1 { n }.assignment(42));
        let exact = exact_of(&problem)?;
        let budget = eps * n as f64 * problem.costs().max() as f64;
        for ((_, engine), series) in engines.iter().zip(&mut runtime_series) {
            let h = coord.submit(problem.clone(), eps, *engine)?;
            let out = h.wait()?;
            let sol = out.result.map_err(|e| format!("{} failed: {e}", engine.name()))?;
            series.push(n as f64, out.solve_secs);
            if *engine == Engine::NativeSeq {
                let err = (sol.cost - exact).max(0.0);
                assert!(err <= budget + 1e-6, "guarantee violated at n={n}");
                error_series.push(n as f64, err / budget);
            }
        }
    }
    println!("{}", figure_table("runtime (s) vs n, ε = 0.1 (via coordinator)", "n", &runtime_series));
    println!("{}", figure_table("accuracy: measured error as fraction of εn·c_max budget", "n", &[error_series]));

    // ---------- stage 2: Figure-2 slice ----------
    println!("=== stage 2: Figure-2 slice (MNIST-style, L1 costs, n=256) ===\n");
    let n = 256;
    let problem = Problem::Assignment(Workload::Fig2 { n }.assignment(7));
    let exact = exact_of(&problem)?;
    let eps_grid = [0.75, 0.5, 0.25, 0.1];
    let mut fig2_series: Vec<Series> =
        engines.iter().map(|(name, _)| Series::new(*name)).collect();
    for &e in &eps_grid {
        for ((_, engine), series) in engines.iter().zip(&mut fig2_series) {
            let h = coord.submit(problem.clone(), e, *engine)?;
            let out = h.wait()?;
            let sol = out.result.map_err(|er| format!("{} failed: {er}", engine.name()))?;
            series.push(e, out.solve_secs);
            if sol.matching().is_some() {
                let budget = e * n as f64 * problem.costs().max() as f64;
                assert!(
                    sol.cost <= exact + budget + 1e-6,
                    "{engine:?} violated budget at eps={e}"
                );
            }
        }
    }
    println!("{}", figure_table("runtime (s) vs ε (via coordinator)", "eps", &fig2_series));

    // ---------- stage 3: general OT accuracy ----------
    println!("=== stage 3: general OT (random masses) vs exact SSP ===\n");
    let mut ot_err = Series::new("additive error / (ε·c_max)");
    for &e in &[0.4, 0.2, 0.1] {
        let problem = Problem::Ot(Workload::Fig1 { n: 40 }.ot_with_random_masses(5));
        let exact = exact_of(&problem)?;
        let budget = e * problem.costs().max() as f64;
        let h = coord.submit(problem, e, Engine::Auto)?;
        let out = h.wait()?;
        let sol = out.result.map_err(|er| format!("OT job failed: {er}"))?;
        assert!(sol.plan().is_some(), "OT jobs return plans");
        let err = (sol.cost - exact).max(0.0);
        assert!(err <= budget + 1e-9);
        ot_err.push(e, err / budget);
    }
    println!("{}", figure_table("OT error as fraction of ε·c_max budget", "eps", &[ot_err]));

    // ---------- stage 4: headline ----------
    println!("=== stage 4: headline — PR vs Sinkhorn at equal accuracy ===\n");
    let n = 512;
    let problem = Problem::Assignment(Workload::Fig1 { n }.assignment(3));
    let mut rows = Vec::new();
    for (name, engine) in [("pr-native", Engine::NativeSeq), ("sinkhorn", Engine::SinkhornNative)]
    {
        for e in [0.1, 0.01] {
            let h = coord.submit(problem.clone(), e, engine)?;
            let out = h.wait()?;
            match out.result {
                Ok(_) => rows.push((name, e, out.solve_secs, "ok".to_string())),
                Err(err) => rows.push((name, e, f64::NAN, format!("{err}"))),
            }
        }
    }
    println!("| engine | eps | seconds | status |\n|---|---|---|---|");
    let mut pr_small = f64::NAN;
    let mut sk_small = f64::NAN;
    for (name, e, secs, status) in &rows {
        println!("| {name} | {e} | {secs:.4} | {status} |");
        if *e == 0.01 {
            if *name == "pr-native" {
                pr_small = *secs;
            } else {
                sk_small = *secs;
            }
        }
    }
    if pr_small.is_finite() && sk_small.is_finite() {
        println!(
            "\nheadline: at ε=0.01, push-relabel is {:.1}× {} than Sinkhorn",
            (sk_small / pr_small).max(pr_small / sk_small),
            if pr_small <= sk_small { "faster" } else { "slower" }
        );
    } else {
        println!("\nheadline: Sinkhorn unstable/failed at ε=0.01 while push-relabel completed (paper §5's observation)");
    }

    println!("\n--- coordinator metrics ---\n{}", coord.metrics.snapshot());
    coord.shutdown();
    println!("e2e_experiments OK");
    Ok(())
}
