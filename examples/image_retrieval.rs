//! Image retrieval with OT distances (paper §1: "The OT cost can be used
//! to measure similarity between images and for image retrieval tasks").
//!
//! A query digit image is ranked against a corpus by the ε-approximate OT
//! distance between normalized pixel-mass distributions, where the ground
//! cost is pixel-grid Euclidean distance (a true Wasserstein-1 on the
//! 28×28 grid, downsampled to keep supports small). The top hits are
//! checked against exact OT rankings.
//!
//!     cargo run --release --example image_retrieval

use otpr::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
use otpr::core::CostMatrix;
use otpr::data::images;
use otpr::util::rng::Pcg32;

const SIDE: usize = 14; // 28×28 downsampled 2× → 196-point supports

/// Downsample a 28×28 image to SIDE×SIDE and renormalize.
fn downsample(img: &[f32]) -> Vec<f64> {
    let f = images::IMG_SIDE / SIDE;
    let mut out = vec![0.0f64; SIDE * SIDE];
    for i in 0..images::IMG_SIDE {
        for j in 0..images::IMG_SIDE {
            out[(i / f) * SIDE + (j / f)] += img[i * images::IMG_SIDE + j] as f64;
        }
    }
    let sum: f64 = out.iter().sum();
    out.iter_mut().for_each(|x| *x /= sum);
    out
}

/// Ground cost: Euclidean distance between grid positions, normalized.
fn grid_costs() -> CostMatrix {
    CostMatrix::from_fn(SIDE * SIDE, SIDE * SIDE, |b, a| {
        let (bi, bj) = (b / SIDE, b % SIDE);
        let (ai, aj) = (a / SIDE, a % SIDE);
        let d2 = (bi as f32 - ai as f32).powi(2) + (bj as f32 - aj as f32).powi(2);
        d2.sqrt() / (SIDE as f32 * std::f32::consts::SQRT_2)
    })
}

fn ot_distance(
    solvers: &SolverRegistry,
    costs: &CostMatrix,
    from: &[f64],
    to: &[f64],
    eps: f64,
) -> Result<f64, Box<dyn std::error::Error>> {
    let problem = Problem::ot(costs.clone(), to.to_vec(), from.to_vec())?;
    let sol =
        solvers.solve("native-seq", &SolverConfig::default(), &problem, &SolveRequest::new(eps))?;
    Ok(sol.cost)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let solvers = SolverRegistry::with_defaults();
    let mut rng = Pcg32::new(77);
    let corpus: Vec<Vec<f64>> =
        images::synthetic_digits(12, &mut rng).iter().map(|im| downsample(im)).collect();
    let query = corpus[3].clone(); // retrieve near-duplicates of corpus[3]
    let costs = grid_costs();
    let eps = 0.05;

    let mut scored: Vec<(usize, f64)> = Vec::new();
    for (i, img) in corpus.iter().enumerate() {
        scored.push((i, ot_distance(&solvers, &costs, &query, img, eps)?));
    }
    scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

    println!("query = corpus[3]; ranking by ε-approximate OT distance:");
    for (rank, (idx, dist)) in scored.iter().take(5).enumerate() {
        println!("  #{} corpus[{idx}]  W≈{dist:.5}", rank + 1);
    }
    assert_eq!(scored[0].0, 3, "query must retrieve itself first");
    assert!(scored[0].1 <= eps * costs.max() as f64 + 1e-9, "self-distance ≈ 0 within ε");

    // cross-check the top-3 ordering against exact OT
    let exact = |img: &Vec<f64>| -> Result<f64, Box<dyn std::error::Error>> {
        let problem = Problem::ot(costs.clone(), img.clone(), query.clone())?;
        let sol = solvers.solve(
            "ssp-exact",
            &SolverConfig::default(),
            &problem,
            &SolveRequest::new(0.0),
        )?;
        Ok(sol.cost)
    };
    for (idx, approx) in scored.iter().take(3) {
        let ex = exact(&corpus[*idx])?;
        assert!(
            (approx - ex).abs() <= eps * costs.max() as f64 + 1e-9,
            "corpus[{idx}]: approx {approx} vs exact {ex}"
        );
    }
    println!("top-3 distances verified against exact OT; image_retrieval OK");
    Ok(())
}
