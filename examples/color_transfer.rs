//! Color transfer via optimal transport — the classic OT application the
//! paper's introduction motivates (transport plans as interpolators,
//! Bonneel et al. [7]).
//!
//! Two synthetic "photographs" are summarized as RGB palettes (k-means-ish
//! cluster centers with pixel-count masses). The OT plan between the
//! palettes tells every source color where to move: we apply the
//! barycentric projection to re-grade the source image toward the target's
//! look, and verify mass conservation + cost bounds.
//!
//!     cargo run --release --example color_transfer

use otpr::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
use otpr::core::CostMatrix;
use otpr::util::rng::Pcg32;

/// A palette: RGB centers in [0,1]³ with masses summing to 1.
struct Palette {
    colors: Vec<[f64; 3]>,
    masses: Vec<f64>,
}

/// Synthesize a palette clustered around a few hue themes.
fn palette(themes: &[[f64; 3]], k: usize, rng: &mut Pcg32) -> Palette {
    let mut colors = Vec::with_capacity(k);
    let mut masses = Vec::with_capacity(k);
    for _ in 0..k {
        let t = themes[rng.next_below(themes.len() as u32) as usize];
        colors.push([
            (t[0] + 0.12 * rng.normal()).clamp(0.0, 1.0),
            (t[1] + 0.12 * rng.normal()).clamp(0.0, 1.0),
            (t[2] + 0.12 * rng.normal()).clamp(0.0, 1.0),
        ]);
        masses.push(0.5 + rng.next_f64());
    }
    let sum: f64 = masses.iter().sum();
    masses.iter_mut().for_each(|m| *m /= sum);
    Palette { colors, masses }
}

fn rgb_dist(a: &[f64; 3], b: &[f64; 3]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt() as f32
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut rng = Pcg32::new(2024);
    // sunset-ish source, teal-and-orange target
    let src = palette(&[[0.9, 0.5, 0.2], [0.6, 0.2, 0.4], [0.2, 0.2, 0.3]], 48, &mut rng);
    let dst = palette(&[[0.1, 0.6, 0.6], [0.9, 0.55, 0.25], [0.05, 0.15, 0.2]], 48, &mut rng);

    // OT problem: supply = source palette (rows), demand = target palette.
    let costs = CostMatrix::from_fn(src.colors.len(), dst.colors.len(), |b, a| {
        rgb_dist(&src.colors[b], &dst.colors[a])
    });
    let problem = Problem::ot(costs, dst.masses.clone(), src.masses.clone())?;

    let solvers = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let eps = 0.05;
    let c_max = problem.costs().max() as f64;
    let sol = solvers.solve("native-seq", &config, &problem, &SolveRequest::new(eps))?;
    let exact = solvers.solve("ssp-exact", &config, &problem, &SolveRequest::new(0.0))?;
    println!(
        "transport cost: pr = {:.5}, exact = {:.5} (additive budget {:.5})",
        sol.cost,
        exact.cost,
        eps * c_max
    );
    assert!(sol.cost <= exact.cost + eps * c_max + 1e-9);
    let plan = sol.plan().expect("OT solve returns a plan");

    // Barycentric projection: each source color moves to the mass-weighted
    // average of its targets under the plan — this is the actual transfer.
    println!("\nsource color  →  transferred color (top rows)");
    for b in 0..6 {
        let mut out = [0.0f64; 3];
        let mut mass = 0.0;
        for a in 0..dst.colors.len() {
            let f = plan.at(b, a);
            if f > 0.0 {
                mass += f;
                for c in 0..3 {
                    out[c] += f * dst.colors[a][c];
                }
            }
        }
        assert!(mass > 0.0, "source color {b} transports no mass");
        for c in &mut out {
            *c /= mass;
        }
        println!(
            "  [{:.2} {:.2} {:.2}] → [{:.2} {:.2} {:.2}]  (mass {:.4})",
            src.colors[b][0], src.colors[b][1], src.colors[b][2], out[0], out[1], out[2], mass
        );
    }

    // Every unit of source mass must arrive somewhere (paper: transports
    // *all* of the supply).
    let shipped: f64 = plan.total_mass();
    assert!((shipped - 1.0).abs() < 1e-9);
    println!("\nall supply transported (Σ plan = {shipped:.9}); color_transfer OK");
    Ok(())
}
