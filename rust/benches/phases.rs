//! Micro/ablation bench: the analytical claims behind the figures.
//! A1 phases-vs-ε, A2 rounds-vs-n, A6 thread scaling, A7 complexity
//! exponent, plus per-phase cost of the shared flow kernel (the
//! Lemma 3.4 O(n·nᵢ) scan) — driven through `core::kernel` directly,
//! with one arena reused across samples the way the batch path does.

use otpr::core::kernel::{FlowKernel, ScalarKernel, VectorKernel};
use otpr::data::workloads::Workload;
use otpr::exp::ablation;
use otpr::exp::report::figure_table;
use otpr::util::bench::{run_bench, to_markdown, BenchConfig};

fn main() {
    let quick = std::env::var("OTPR_BENCH_QUICK").is_ok();
    let seed = 42;

    // A1: phases vs eps
    let eps_grid = if quick { vec![0.3, 0.1] } else { vec![0.3, 0.2, 0.1, 0.05, 0.02, 0.01] };
    let series = ablation::phases_vs_eps(512, &eps_grid, seed);
    println!("{}", figure_table("A1 — phases vs ε at n=512 (bound (1+2ε)/ε²)", "eps", &series));

    // A2: propose-accept rounds vs n
    let sizes = if quick { vec![128, 256] } else { vec![128, 256, 512, 1024, 2048] };
    let series = ablation::rounds_vs_n(&sizes, 0.1, seed);
    println!("{}", figure_table("A2 — rounds/phase vs n (ε=0.1; §3.2 predicts O(log n))", "n", &series));

    // A6: thread scaling
    let threads = if quick { vec![1, 2] } else { vec![1, 2, 4, 8, 16] };
    let series = ablation::threads(2048, 0.05, &threads, seed);
    println!("{}", figure_table("A6 — parallel solver scaling at n=2048, ε=0.05", "threads", &series));

    // A7: sequential complexity exponent
    let sizes = if quick { vec![256, 512] } else { vec![256, 512, 1024, 2048, 4096] };
    let (k, r2) = ablation::complexity_exponent(&sizes, 0.1, seed);
    println!("## A7 — sequential time ~ n^k at ε=0.1\n\nk = {k:.2} (r² = {r2:.3}); paper: O(n²/ε) ⇒ k ≈ 2\n");

    // Per-phase timing: first-phase cost scaling (Lemma 3.4's O(n·n₁)
    // scan, n₁ = n at the start). One kernel arena serves all samples —
    // `init` re-quantizes in place, so this also measures the warm-arena
    // setup cost the batch path pays per same-shape instance.
    let cfg = BenchConfig::from_env();
    let mut results = Vec::new();
    let mut kernel = ScalarKernel::new();
    for &n in &sizes {
        let costs = Workload::Fig1 { n }.costs(seed);
        results.push(run_bench(&format!("first-phase n={n} eps=0.1"), &cfg, || {
            kernel.init(&costs, 0.1, None);
            let out = kernel.run_phase();
            vec![
                ("matched".into(), out.matched_units.to_string()),
                ("free".into(), out.free_at_start.to_string()),
                ("arena-reused".into(), kernel.arena().last_init_reused.to_string()),
            ]
        }));
    }
    println!("## Per-phase cost (greedy maximal-matching scan)\n");
    println!("{}", to_markdown(&results));

    // The same first-phase sweep on the vector backend: the scalar/vector
    // ratio here is the propose-sweep speedup in isolation (results are
    // byte-identical by the kernel contract, so only the timing differs).
    let mut results = Vec::new();
    let mut kernel = VectorKernel::new();
    for &n in &sizes {
        let costs = Workload::Fig1 { n }.costs(seed);
        results.push(run_bench(&format!("vector first-phase n={n} eps=0.1"), &cfg, || {
            kernel.init(&costs, 0.1, None);
            let out = kernel.run_phase();
            vec![
                ("matched".into(), out.matched_units.to_string()),
                ("free".into(), out.free_at_start.to_string()),
            ]
        }));
    }
    println!("## Per-phase cost, vector backend (lane-blocked scan)\n");
    println!("{}", to_markdown(&results));
}
