//! Figure-1 bench (paper §5): runtime vs n on synthetic unit-square
//! points, one table per ε, comparing push-relabel vs Sinkhorn on the
//! native ("CPU") and XLA ("GPU"-analog) engines.
//!
//! `cargo bench --bench fig1` runs a CI-scale slice. Environment knobs:
//!   OTPR_FIG1_SIZES=500,1000,...   OTPR_FIG1_EPS=0.1,0.01
//!   OTPR_FIG1_REPS=30              OTPR_FIG1_ENGINES=pr-cpu,sinkhorn-cpu
//! The paper's full grid: sizes 500..10000, eps 0.1,0.01,0.005, reps 30.

use otpr::exp::fig1::{run_eps, Fig1Config};
use otpr::exp::report::{figure_csv, figure_table};
use otpr::runtime::XlaRuntime;

fn env_list_usize(key: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(key)
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn env_list_f64(key: &str, default: &[f64]) -> Vec<f64> {
    std::env::var(key)
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn main() {
    let cfg = Fig1Config {
        sizes: env_list_usize("OTPR_FIG1_SIZES", &[256, 512]),
        eps: env_list_f64("OTPR_FIG1_EPS", &[0.1, 0.01]),
        reps: std::env::var("OTPR_FIG1_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(2),
        seed: 42,
        max_secs_per_run: 120.0,
        engines: std::env::var("OTPR_FIG1_ENGINES")
            .ok()
            .map(|v| v.split(',').map(String::from).collect())
            .unwrap_or_else(|| {
                vec![
                    "pr-cpu".into(),
                    "pr-parallel".into(),
                    "pr-gpu".into(),
                    "sinkhorn-cpu".into(),
                    "sinkhorn-gpu".into(),
                ]
            }),
    };
    let registry = XlaRuntime::open_default()
        .map_err(|e| eprintln!("note: XLA engines disabled: {e}"))
        .ok();
    println!("# Figure 1 reproduction — {} reps/point\n", cfg.reps);
    for &eps in &cfg.eps {
        let series = run_eps(&cfg, eps, registry.clone());
        println!(
            "{}",
            figure_table(&format!("Figure 1 — runtime (s) vs n, ε = {eps}"), "n", &series)
        );
        println!("{}", figure_csv("n", &series));
    }
}
