//! Figure-2 bench (paper §5): runtime vs ε on MNIST-style image inputs
//! (L1 costs on normalized 28×28 images). Real MNIST is used when
//! `data/mnist/train-images-idx3-ubyte` exists; synthetic digits otherwise.
//!
//! Knobs: OTPR_FIG2_N (paper: 10000), OTPR_FIG2_EPS, OTPR_FIG2_REPS,
//!        OTPR_FIG2_ENGINES.

use otpr::exp::fig2::{run, Fig2Config};
use otpr::exp::report::{figure_csv, figure_table};
use otpr::runtime::XlaRuntime;

fn main() {
    let cfg = Fig2Config {
        n: std::env::var("OTPR_FIG2_N").ok().and_then(|v| v.parse().ok()).unwrap_or(256),
        eps: std::env::var("OTPR_FIG2_EPS")
            .ok()
            .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
            .unwrap_or_else(|| vec![0.75, 0.5, 0.25, 0.1]),
        reps: std::env::var("OTPR_FIG2_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(2),
        seed: 7,
        engines: std::env::var("OTPR_FIG2_ENGINES")
            .ok()
            .map(|v| v.split(',').map(String::from).collect())
            .unwrap_or_else(|| {
                vec![
                    "pr-cpu".into(),
                    "pr-gpu".into(),
                    "sinkhorn-cpu".into(),
                    "sinkhorn-gpu".into(),
                ]
            }),
    };
    let registry = XlaRuntime::open_default()
        .map_err(|e| eprintln!("note: XLA engines disabled: {e}"))
        .ok();
    println!("# Figure 2 reproduction — n = {}, {} reps/point\n", cfg.n, cfg.reps);
    let (series, real) = run(&cfg, registry);
    let src = if real { "real MNIST" } else { "synthetic MNIST-like (see DESIGN.md §2)" };
    println!(
        "{}",
        figure_table(&format!("Figure 2 — runtime (s) vs ε, n = {} ({src})", cfg.n), "eps", &series)
    );
    println!("{}", figure_csv("eps", &series));
}
