//! Runtime-layer bench: where the XLA ("GPU"-analog) path spends its time —
//! artifact compile, host↔device transfer, cost build, quantize, and the
//! per-phase / per-sweep step latencies that dominate Figures 1–2 on this
//! engine. Feeds EXPERIMENTS.md §Perf.

use otpr::core::OtInstance;
use otpr::data::synthetic;
use otpr::data::workloads::Workload;
use otpr::runtime::client::run1;
use otpr::runtime::{XlaAssignment, XlaRuntime, XlaSinkhorn};
use otpr::solvers::OtSolver;
use otpr::util::bench::{run_bench, to_markdown, BenchConfig};
use otpr::util::rng::Pcg32;

fn main() {
    let Ok(rt) = XlaRuntime::open_default() else {
        eprintln!("artifacts not built — run `make artifacts` first");
        return;
    };
    let cfg = BenchConfig::from_env();
    let sizes: Vec<usize> = std::env::var("OTPR_XLA_SIZES")
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| vec![256, 512, 1024]);
    let mut results = Vec::new();

    for &n in &sizes {
        // compile (cold vs cached)
        let rt2 = rt.clone();
        results.push(run_bench(&format!("compile phase_step n={n} (cached)"), &cfg, || {
            rt2.call(move |ctx| ctx.executable("phase_step", n).map(|_| ())).unwrap();
            vec![]
        }));

        // upload + cost build + quantize
        let mut rng = Pcg32::new(7);
        let pts_b = synthetic::points_to_f32(&synthetic::uniform_points(n, &mut rng));
        let pts_a = synthetic::points_to_f32(&synthetic::uniform_points(n, &mut rng));
        let rt2 = rt.clone();
        results.push(run_bench(&format!("cost_euclid+quantize n={n}"), &cfg, || {
            let (pb, pa) = (pts_b.clone(), pts_a.clone());
            rt2.call(move |ctx| {
                let fb = ctx.upload_f32(&pb, &[n, 2])?;
                let fa = ctx.upload_f32(&pa, &[n, 2])?;
                let cost_exe = ctx.executable("cost_euclid", n)?;
                let costs = run1(&cost_exe, &[&fb, &fa])?;
                let inv = ctx.upload_f32(&[10.0], &[1])?;
                let quant_exe = ctx.executable("quantize", n)?;
                let _ = run1(&quant_exe, &[&costs, &inv])?;
                Ok(())
            })
            .unwrap();
            vec![]
        }));

        // one phase_step execution (the figure-level unit of work)
        let rt2 = rt.clone();
        results.push(run_bench(&format!("phase_step exec n={n}"), &cfg, || {
            rt2.call(move |ctx| {
                let cq = ctx.upload_i32(&vec![0i32; n * n], &[n, n])?;
                let mut state = vec![0i32; 5 * n];
                state[n..2 * n].fill(1);
                state[2 * n..4 * n].fill(-1);
                let st = ctx.upload_i32(&state, &[5, n])?;
                let exe = ctx.executable("phase_step", n)?;
                let _ = run1(&exe, &[&cq, &st])?;
                Ok(())
            })
            .unwrap();
            vec![]
        }));

        // one sinkhorn sweep
        let rt2 = rt.clone();
        results.push(run_bench(&format!("sinkhorn_step exec n={n}"), &cfg, || {
            rt2.call(move |ctx| {
                let costs = ctx.upload_f32(&vec![0.5f32; n * n], &[n, n])?;
                let mut state = vec![1f32; 2 * n];
                state.extend(std::iter::repeat(0f32).take(n));
                let st = ctx.upload_f32(&state, &[3, n])?;
                let r = ctx.upload_f32(&vec![1.0 / n as f32; n], &[n])?;
                let c = ctx.upload_f32(&vec![1.0 / n as f32; n], &[n])?;
                let eta = ctx.upload_f32(&[0.05], &[1])?;
                let exe = ctx.executable("sinkhorn_step", n)?;
                let _ = run1(&exe, &[&costs, &st, &r, &c, &eta])?;
                Ok(())
            })
            .unwrap();
            vec![]
        }));
    }

    // end-to-end engine comparison at one operating point
    let n = sizes[0];
    let inst = Workload::Fig1 { n }.assignment(3);
    let solver = XlaAssignment::new(rt.clone());
    results.push(run_bench(&format!("e2e xla assignment n={n} eps=0.1"), &cfg, || {
        let sol = solver.solve_costs(&inst, 0.1).unwrap();
        vec![("phases".into(), sol.stats.phases.to_string())]
    }));
    let ot = OtInstance::uniform(inst.costs.clone()).unwrap();
    let sk = XlaSinkhorn::new(rt);
    results.push(run_bench(&format!("e2e xla sinkhorn n={n} eps=0.25"), &cfg, || {
        let sol = sk.solve_ot(&ot, 0.25).unwrap();
        vec![("iters".into(), sol.stats.phases.to_string())]
    }));

    println!("## XLA runtime micro-benchmarks\n");
    println!("{}", to_markdown(&results));
}
