//! Job types flowing through the coordinator.

use crate::core::{AssignmentInstance, OtInstance};
use crate::solvers::{AssignmentSolution, OtSolution};

/// Which solver backend executes a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Engine {
    /// Paper §2.2 sequential push-relabel (native Rust).
    NativeSeq,
    /// Propose–accept multi-threaded push-relabel (native Rust).
    NativeParallel,
    /// Device-resident push-relabel over the XLA artifacts.
    Xla,
    /// Sinkhorn baseline, native Rust (log-domain for robustness).
    SinkhornNative,
    /// Sinkhorn baseline over the XLA artifacts.
    SinkhornXla,
    /// Let the router decide (size- and artifact-aware).
    Auto,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        Some(match s {
            "native" | "seq" => Engine::NativeSeq,
            "parallel" | "par" => Engine::NativeParallel,
            "xla" | "gpu" => Engine::Xla,
            "sinkhorn" => Engine::SinkhornNative,
            "sinkhorn-xla" => Engine::SinkhornXla,
            "auto" => Engine::Auto,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::NativeSeq => "native-seq",
            Engine::NativeParallel => "native-parallel",
            Engine::Xla => "xla",
            Engine::SinkhornNative => "sinkhorn-native",
            Engine::SinkhornXla => "sinkhorn-xla",
            Engine::Auto => "auto",
        }
    }
}

/// What to solve.
#[derive(Debug, Clone)]
pub enum JobKind {
    Assignment(AssignmentInstance),
    Ot(OtInstance),
}

impl JobKind {
    pub fn n(&self) -> usize {
        match self {
            JobKind::Assignment(i) => i.n(),
            JobKind::Ot(i) => i.n(),
        }
    }
}

/// A submitted job.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub id: u64,
    pub kind: JobKind,
    /// Overall additive accuracy target (ε relative to c_max).
    pub eps: f64,
    pub engine: Engine,
}

/// Result payload.
#[derive(Debug, Clone)]
pub enum JobResult {
    Assignment(AssignmentSolution),
    Ot(OtSolution),
}

impl JobResult {
    pub fn cost(&self) -> f64 {
        match self {
            JobResult::Assignment(s) => s.cost,
            JobResult::Ot(s) => s.cost,
        }
    }

    pub fn phases(&self) -> usize {
        match self {
            JobResult::Assignment(s) => s.stats.phases,
            JobResult::Ot(s) => s.stats.phases,
        }
    }
}

/// Completed job with queueing/solve timing for the metrics layer.
#[derive(Debug)]
pub struct JobOutcome {
    pub id: u64,
    pub engine_used: &'static str,
    pub result: Result<JobResult, String>,
    pub queued_secs: f64,
    pub solve_secs: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_parsing() {
        assert_eq!(Engine::parse("xla"), Some(Engine::Xla));
        assert_eq!(Engine::parse("gpu"), Some(Engine::Xla));
        assert_eq!(Engine::parse("auto"), Some(Engine::Auto));
        assert_eq!(Engine::parse("bogus"), None);
        assert_eq!(Engine::NativeParallel.name(), "native-parallel");
    }
}
