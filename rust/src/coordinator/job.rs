//! Job types flowing through the coordinator.
//!
//! A job is a [`Problem`] plus a [`SolveRequest`] plus an [`Engine`]
//! choice. `Engine` is a thin, copyable alias over the canonical registry
//! keys of [`crate::api::registry::ENGINE_SPECS`] — parsing and printing
//! round-trip through that single table, so every name the coordinator
//! accepts is a name the registry can build.
//!
//! Payload size: a dense job carries its O(n²) cost slab, but an
//! implicit job ([`Problem::Implicit`] over point clouds or a generator)
//! ships **O(n) bytes** — the coordinator, batcher, and workers never
//! materialize costs for it, and `Auto` routes it to a no-slab lane
//! backend (vector sequentially, hybrid when threads are available).
//! Result payloads are compact too (PR 8): kernel-engine OT answers
//! carry an O(nnz) CSR `TransportPlan`, so an implicit job round-trips
//! through the coordinator in O(n) bytes end-to-end —
//! `SolveStats::plan_state_bytes` reports the figure per job, and
//! `/metrics` accumulates it per engine.

use crate::api::registry::canonical_key;
use crate::api::{Problem, SolveRequest, Solution};
use crate::core::{OtprError, Result};
use std::time::Duration;

/// Re-export: the coordinator's job payload *is* the unified API problem.
pub type JobKind = Problem;

/// Which solver backend executes a job. Variants map 1:1 onto registry
/// keys, plus `Auto` (router decides, size- and artifact-aware).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Engine {
    /// Paper §2.2 sequential push-relabel + §4 OT (native Rust).
    NativeSeq,
    /// Propose–accept multi-threaded push-relabel (native Rust).
    NativeParallel,
    /// Lane-blocked auto-vectorized kernel backend (scalar-identical).
    NativeVector,
    /// Lane-blocked sweep fanned over threads (vector × chunked hybrid).
    NativeHybrid,
    /// Vector backend + ε-scaling warm starts and batch dual reuse.
    NativeVectorWarm,
    /// Sequential backend + ε-scaling warm starts and batch dual reuse.
    NativeSeqWarm,
    /// Device-resident push-relabel over the XLA artifacts.
    Xla,
    /// Sinkhorn baseline, native Rust (log-domain for robustness).
    SinkhornNative,
    /// Sinkhorn baseline over the XLA artifacts.
    SinkhornXla,
    /// Exact Hungarian assignment oracle.
    Hungarian,
    /// Greedy matching floor.
    Greedy,
    /// LMR'19 combinatorial additive baseline.
    Lmr,
    /// Exact min-cost-flow OT oracle.
    SspExact,
    /// Let the router decide (size- and artifact-aware).
    Auto,
}

impl Engine {
    /// Every concrete (non-Auto) engine, i.e. every registry-backed one.
    pub const CONCRETE: [Engine; 13] = [
        Engine::NativeSeq,
        Engine::NativeParallel,
        Engine::NativeVector,
        Engine::NativeHybrid,
        Engine::NativeVectorWarm,
        Engine::NativeSeqWarm,
        Engine::Xla,
        Engine::SinkhornNative,
        Engine::SinkhornXla,
        Engine::Hungarian,
        Engine::Greedy,
        Engine::Lmr,
        Engine::SspExact,
    ];

    /// Canonical registry key (`"auto"` for [`Engine::Auto`]).
    pub fn key(&self) -> &'static str {
        match self {
            Engine::NativeSeq => "native-seq",
            Engine::NativeParallel => "native-parallel",
            Engine::NativeVector => "native-vector",
            Engine::NativeHybrid => "native-hybrid",
            Engine::NativeVectorWarm => "native-vector-warm",
            Engine::NativeSeqWarm => "native-seq-warm",
            Engine::Xla => "xla",
            Engine::SinkhornNative => "sinkhorn-native",
            Engine::SinkhornXla => "sinkhorn-xla",
            Engine::Hungarian => "hungarian",
            Engine::Greedy => "greedy",
            Engine::Lmr => "lmr",
            Engine::SspExact => "ssp-exact",
            Engine::Auto => "auto",
        }
    }

    /// Back-compat spelling of [`Engine::key`].
    pub fn name(&self) -> &'static str {
        self.key()
    }

    /// Variant for an exact canonical registry key.
    pub fn from_key(key: &str) -> Option<Engine> {
        Engine::CONCRETE.iter().copied().find(|e| e.key() == key)
    }

    /// Parse a key **or any registry alias** (`"gpu"`, `"pr-cpu"`, ...).
    pub fn parse(s: &str) -> Option<Engine> {
        if s == "auto" {
            return Some(Engine::Auto);
        }
        Engine::from_key(canonical_key(s)?)
    }

    /// [`Engine::parse`] with a typed error for config-input paths
    /// (CLI flags, registry round-trips) — an unknown name becomes
    /// [`OtprError::Coordinator`] instead of a silent fallback or panic.
    pub fn try_parse(s: &str) -> Result<Engine> {
        Engine::parse(s).ok_or_else(|| {
            OtprError::Coordinator(format!(
                "unknown engine {s:?} — try `otpr engines` for the registry keys and aliases"
            ))
        })
    }
}

/// Terminal disposition of a job — every submitted job reaches exactly one.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum JobStatus {
    /// Solved at the requested accuracy.
    Served,
    /// Deadline pressure resolved the job at a coarser accuracy: `eps` is
    /// the overall target the answer's attached certificate verifies
    /// against (see `DegradePolicy`). The partial-answer fallback (lazy
    /// product / arbitrary completion) also lands here, with its
    /// certificate reporting what actually holds.
    Degraded { eps: f64 },
    /// Dropped before solving because its effective deadline had already
    /// passed; `retry_after` is the coordinator's backoff hint.
    Shed { retry_after: Duration },
    /// Errored terminally after `attempts` executions.
    Failed { attempts: u32 },
}

/// A submitted job: problem + full solve request + engine choice.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub id: u64,
    pub kind: JobKind,
    /// Accuracy, budget, cancellation, and progress observation.
    pub request: SolveRequest,
    pub engine: Engine,
}

/// Completed job with queueing/solve timing for the metrics layer.
///
/// `status` is the typed disposition; `result` keeps the historical
/// Ok/Err shape (Shed and Failed statuses carry an `Err`, Served and
/// Degraded an `Ok`), so `handle.wait()?.result?` keeps working.
#[derive(Debug)]
pub struct JobOutcome {
    pub id: u64,
    pub engine_used: &'static str,
    pub status: JobStatus,
    pub result: std::result::Result<Solution, String>,
    pub queued_secs: f64,
    pub solve_secs: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SolverRegistry;

    #[test]
    fn engine_parsing() {
        assert_eq!(Engine::parse("xla"), Some(Engine::Xla));
        assert_eq!(Engine::parse("gpu"), Some(Engine::Xla));
        assert_eq!(Engine::parse("auto"), Some(Engine::Auto));
        assert_eq!(Engine::parse("hungarian"), Some(Engine::Hungarian));
        assert_eq!(Engine::parse("exact"), Some(Engine::Hungarian));
        assert_eq!(Engine::parse("bogus"), None);
        assert_eq!(Engine::NativeParallel.name(), "native-parallel");
    }

    #[test]
    fn try_parse_reports_unknown_engines_as_typed_errors() {
        assert_eq!(Engine::try_parse("auto").ok(), Some(Engine::Auto));
        assert_eq!(Engine::try_parse("simd").ok(), Some(Engine::NativeVector));
        let err = Engine::try_parse("bogus").err().map(|e| e.to_string());
        let msg = err.as_deref().unwrap_or_default();
        assert!(msg.contains("coordinator error"), "typed OtprError::Coordinator: {msg}");
        assert!(msg.contains("bogus") && msg.contains("otpr engines"), "actionable hint: {msg}");
    }

    #[test]
    fn every_registry_key_round_trips_through_engine() {
        // The dedup satellite: registry keys and Engine names are one set.
        // `try_parse` carries the diagnostic as a typed error now, so the
        // assertion reports it without a hand-rolled panic.
        let reg = SolverRegistry::with_defaults();
        for key in reg.keys() {
            let parsed = Engine::try_parse(key);
            assert!(parsed.is_ok(), "registry key {key} must parse as an Engine: {parsed:?}");
            let engine = parsed.expect("checked above");
            assert_eq!(engine.name(), key, "Engine::name must round-trip the key");
            assert_eq!(Engine::from_key(key), Some(engine));
        }
        // ...and every concrete Engine is buildable from the registry.
        let cfg = crate::api::SolverConfig::default();
        for engine in Engine::CONCRETE {
            assert!(
                reg.build(engine.key(), &cfg).is_ok(),
                "engine {} has no registry builder",
                engine.key()
            );
        }
    }

    #[test]
    fn aliases_resolve_to_canonical_engines() {
        for (alias, expect) in [
            ("native", Engine::NativeSeq),
            ("pr-cpu", Engine::NativeSeq),
            ("par", Engine::NativeParallel),
            ("vector", Engine::NativeVector),
            ("simd", Engine::NativeVector),
            ("hybrid", Engine::NativeHybrid),
            ("pr-hybrid", Engine::NativeHybrid),
            ("vector-warm", Engine::NativeVectorWarm),
            ("warm", Engine::NativeSeqWarm),
            ("sinkhorn", Engine::SinkhornNative),
            ("sinkhorn-gpu", Engine::SinkhornXla),
            ("ssp", Engine::SspExact),
            ("lmr-baseline", Engine::Lmr),
        ] {
            assert_eq!(Engine::parse(alias), Some(expect), "{alias}");
        }
    }
}
