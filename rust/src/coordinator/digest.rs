//! Streaming problem digests for the result cache.
//!
//! A digest is a 64-bit FNV-1a hash (with a SplitMix64 finalizer to
//! spread the avalanche) over the **canonical problem payload** — the
//! same data a cross-node shipper would serialize:
//!
//! * dense problems hash the raw f32 cost slab (bit patterns, LE) plus
//!   marginals, so two instances digest equal iff their slabs and masses
//!   are bit-identical;
//! * implicit (provider-backed) problems hash the provider kind and its
//!   O(n) payload — points, vectors, the metric flag, masses — never the
//!   O(n²) costs the provider implies, keeping cache keys O(n) to
//!   compute (the whole point of `Problem::Implicit`);
//! * closure-backed [`Costs::Generated`] instances have no canonical
//!   payload (the closure is opaque), so they digest to `None` and are
//!   simply uncacheable — a false cache hit is the one failure mode this
//!   module must never allow.
//!
//! Every scalar is folded as its little-endian bit pattern with a
//! type/kind tag in front, so `f64` masses can never collide with `f32`
//! costs of the same bit prefix, and an assignment instance can never
//! collide with the OT instance over the same slab.

use crate::api::Problem;
use crate::core::provider::Costs;

/// FNV-1a 64-bit streaming hasher. Tiny, dependency-free, deterministic
/// across platforms; the SplitMix64 finalizer compensates FNV's weak
/// high-bit diffusion so truncated keys stay well spread.
#[derive(Debug, Clone)]
pub struct Digest {
    state: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Default for Digest {
    fn default() -> Self {
        Self::new()
    }
}

impl Digest {
    pub fn new() -> Self {
        Self { state: FNV_OFFSET }
    }

    #[inline]
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= u64::from(b);
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
    }

    #[inline]
    pub fn write_u8(&mut self, v: u8) {
        self.write(&[v]);
    }

    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    #[inline]
    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64); // cast-ok: usize → u64 is lossless here
    }

    #[inline]
    pub fn write_f64(&mut self, v: f64) {
        self.write(&v.to_bits().to_le_bytes());
    }

    #[inline]
    pub fn write_f32(&mut self, v: f32) {
        self.write(&v.to_bits().to_le_bytes());
    }

    /// Finalize with one SplitMix64 mixing round.
    pub fn finish(&self) -> u64 {
        let mut z = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

/// Payload tags: one byte per problem/provider shape so structurally
/// different payloads occupy disjoint digest streams.
const TAG_ASSIGNMENT: u8 = 1;
const TAG_OT: u8 = 2;
const TAG_IMPLICIT_ASSIGNMENT: u8 = 3;
const TAG_IMPLICIT_OT: u8 = 4;
const TAG_COSTS_DENSE: u8 = 10;
const TAG_COSTS_POINTS: u8 = 11;
const TAG_COSTS_L1: u8 = 12;

fn fold_masses(h: &mut Digest, supply: &[f64], demand: &[f64]) {
    h.write_usize(supply.len());
    for &v in supply {
        h.write_f64(v);
    }
    h.write_usize(demand.len());
    for &v in demand {
        h.write_f64(v);
    }
}

/// Fold a cost representation, or report it uncacheable (`false`).
fn fold_costs(h: &mut Digest, costs: &Costs) -> bool {
    match costs {
        Costs::Dense(m) => {
            h.write_u8(TAG_COSTS_DENSE);
            h.write_usize(m.nb);
            h.write_usize(m.na);
            for &c in m.as_slice() {
                h.write_f32(c);
            }
            true
        }
        Costs::Points(p) => {
            h.write_u8(TAG_COSTS_POINTS);
            h.write_u8(u8::from(p.takes_sqrt()));
            h.write_usize(p.points_b().len());
            h.write_usize(p.points_a().len());
            for pt in p.points_b().iter().chain(p.points_a()) {
                h.write_f64(pt[0]);
                h.write_f64(pt[1]);
            }
            true
        }
        Costs::L1Points(p) => {
            h.write_u8(TAG_COSTS_L1);
            h.write_usize(p.vecs_b().len());
            h.write_usize(p.vecs_a().len());
            for v in p.vecs_b().iter().chain(p.vecs_a()) {
                h.write_usize(v.len());
                for &x in v {
                    h.write_f32(x);
                }
            }
            true
        }
        // The closure is opaque: no canonical payload exists, so there is
        // nothing sound to key a cache on.
        Costs::Generated(_) => false,
    }
}

/// Digest the canonical payload of `problem`, or `None` when the problem
/// has no canonical payload (closure-backed costs) and must never be
/// served from a cache.
pub fn problem_digest(problem: &Problem) -> Option<u64> {
    let mut h = Digest::new();
    match problem {
        Problem::Assignment(inst) => {
            h.write_u8(TAG_ASSIGNMENT);
            h.write_usize(inst.costs.nb);
            h.write_usize(inst.costs.na);
            for &c in inst.costs.as_slice() {
                h.write_f32(c);
            }
        }
        Problem::Ot(inst) => {
            h.write_u8(TAG_OT);
            h.write_usize(inst.costs.nb);
            h.write_usize(inst.costs.na);
            for &c in inst.costs.as_slice() {
                h.write_f32(c);
            }
            fold_masses(&mut h, &inst.supply, &inst.demand);
        }
        Problem::Implicit(inst) => {
            match &inst.masses {
                None => h.write_u8(TAG_IMPLICIT_ASSIGNMENT),
                Some((supply, demand)) => {
                    h.write_u8(TAG_IMPLICIT_OT);
                    fold_masses(&mut h, supply, demand);
                }
            }
            if !fold_costs(&mut h, &inst.costs) {
                return None;
            }
        }
    }
    Some(h.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::provider::{GeneratedCosts, SqEuclideanCosts};
    use crate::core::CostMatrix;

    fn dense_assignment(seed: f32) -> Problem {
        let c = CostMatrix::from_fn(4, 4, |b, a| seed + (b * 4 + a) as f32 / 16.0);
        Problem::assignment(c).unwrap()
    }

    #[test]
    fn equal_payloads_digest_equal_and_perturbations_differ() {
        let a = problem_digest(&dense_assignment(0.25)).unwrap();
        let b = problem_digest(&dense_assignment(0.25)).unwrap();
        assert_eq!(a, b, "same payload must digest identically");
        let c = problem_digest(&dense_assignment(0.2500001)).unwrap();
        assert_ne!(a, c, "any slab perturbation must change the digest");
    }

    #[test]
    fn kind_tags_separate_structurally_different_problems() {
        let c = CostMatrix::from_fn(3, 3, |b, a| (b + a) as f32 / 4.0);
        let assign = Problem::assignment(c.clone()).unwrap();
        let uniform = vec![1.0 / 3.0; 3];
        let ot = Problem::ot(c, uniform.clone(), uniform).unwrap();
        assert_ne!(
            problem_digest(&assign).unwrap(),
            problem_digest(&ot).unwrap(),
            "assignment and OT over one slab are different problems"
        );
    }

    #[test]
    fn implicit_points_digest_their_o_n_payload() {
        let pts = |shift: f64| {
            let b: Vec<[f64; 2]> = (0..5).map(|i| [i as f64 / 5.0 + shift, 0.5]).collect();
            let a: Vec<[f64; 2]> = (0..5).map(|i| [0.25, i as f64 / 5.0]).collect();
            Problem::implicit_assignment(Costs::points(SqEuclideanCosts::new(b, a).unwrap()))
                .unwrap()
        };
        let d0 = problem_digest(&pts(0.0)).unwrap();
        assert_eq!(d0, problem_digest(&pts(0.0)).unwrap());
        assert_ne!(d0, problem_digest(&pts(1e-9)).unwrap());
    }

    #[test]
    fn metric_flag_is_part_of_the_payload() {
        let b: Vec<[f64; 2]> = vec![[0.0, 0.0], [0.5, 0.5]];
        let a: Vec<[f64; 2]> = vec![[0.25, 0.75], [1.0, 0.0]];
        let sq = SqEuclideanCosts::new(b.clone(), a.clone()).unwrap();
        let eu = SqEuclideanCosts::euclidean(b, a).unwrap();
        let p = |c: SqEuclideanCosts| Problem::implicit_assignment(Costs::points(c)).unwrap();
        assert_ne!(
            problem_digest(&p(sq)).unwrap(),
            problem_digest(&p(eu)).unwrap(),
            "same points, different metric ⇒ different digest"
        );
    }

    #[test]
    fn generated_costs_are_uncacheable() {
        let g = GeneratedCosts::new(3, 3, |b, a| (b + a) as f32).unwrap();
        let p = Problem::implicit_assignment(Costs::generated(g)).unwrap();
        assert_eq!(problem_digest(&p), None, "opaque closures must never get cache keys");
    }
}
