//! Deterministic fault injection for the coordinator.
//!
//! Chaos behavior you cannot reproduce is chaos you cannot debug: a
//! [`FaultPlan`] is a *seeded, step-indexed* schedule of failures keyed by
//! `(job id, attempt)` — job ids are assigned sequentially at submit time,
//! so "panic the worker holding job 7" means the same thing on every run.
//! The plan is threaded through
//! [`crate::coordinator::CoordinatorConfig::faults`] and consulted by
//! workers at pickup, inside the supervised (`catch_unwind`) region, which
//! is exactly where real solver panics would fire.
//!
//! Keying on the attempt means an injured job's *retry* succeeds by
//! default — the shape real transient faults have — while
//! [`FaultPlan::at_attempt`] can pin a fault to every attempt to test
//! retry-budget exhaustion.

use std::collections::HashMap;
use std::time::Duration;

use crate::util::rng::Pcg32;

/// One injected fault, applied when a worker picks up the matching
/// `(job id, attempt)` step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Panic the worker thread mid-batch. Supervision catches it: the
    /// batch's unreplied jobs are retried or failed, `worker_panics` is
    /// incremented, and the worker is respawned under the restart budget.
    Panic,
    /// Sleep before solving the job's group (latency injection; shows up
    /// in the per-engine p95/p99 metrics).
    Delay(Duration),
    /// Fail the job with a retryable transient error instead of solving.
    Transient,
}

/// A deterministic fault schedule. Defaults to empty (no faults).
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    faults: HashMap<(u64, u32), Fault>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Panic the worker when it picks up `job` (first attempt only).
    pub fn panic_at(mut self, job: u64) -> Self {
        self.faults.insert((job, 0), Fault::Panic);
        self
    }

    /// Delay `job`'s group by `d` before solving (first attempt only).
    pub fn delay_at(mut self, job: u64, d: Duration) -> Self {
        self.faults.insert((job, 0), Fault::Delay(d));
        self
    }

    /// Fail `job` with a transient error (first attempt only).
    pub fn transient_at(mut self, job: u64) -> Self {
        self.faults.insert((job, 0), Fault::Transient);
        self
    }

    /// Pin `fault` to a specific retry attempt of `job` (attempt 0 is the
    /// first execution). Lets tests exhaust the retry budget.
    pub fn at_attempt(mut self, job: u64, attempt: u32, fault: Fault) -> Self {
        self.faults.insert((job, attempt), fault);
        self
    }

    /// Seeded random plan over jobs `1..=jobs` (the ids a fresh
    /// coordinator assigns): `panics` worker panics, `transients`
    /// transient errors, and `delays` sleeps of `delay` each, on disjoint
    /// jobs, all on the first attempt. Deterministic in `seed`.
    pub fn seeded(
        seed: u64,
        jobs: u64,
        panics: usize,
        transients: usize,
        delays: usize,
        delay: Duration,
    ) -> Self {
        let mut rng = Pcg32::with_stream(seed, 0x0fa1_75);
        let mut plan = FaultPlan::new();
        if jobs == 0 {
            return plan;
        }
        let mut pick = |plan: &FaultPlan| -> Option<u64> {
            if plan.faults.len() as u64 >= jobs {
                return None;
            }
            loop {
                let id = 1 + u64::from(rng.next_u32()) % jobs;
                if !plan.faults.contains_key(&(id, 0)) {
                    return Some(id);
                }
            }
        };
        for _ in 0..panics {
            match pick(&plan) {
                Some(id) => plan = plan.panic_at(id),
                None => return plan,
            }
        }
        for _ in 0..transients {
            match pick(&plan) {
                Some(id) => plan = plan.transient_at(id),
                None => return plan,
            }
        }
        for _ in 0..delays {
            match pick(&plan) {
                Some(id) => plan = plan.delay_at(id, delay),
                None => return plan,
            }
        }
        plan
    }

    /// The fault scheduled for this `(job, attempt)` step, if any.
    pub fn lookup(&self, job: u64, attempt: u32) -> Option<Fault> {
        self.faults.get(&(job, attempt)).copied()
    }

    pub fn len(&self) -> usize {
        self.faults.len()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Count of scheduled faults matching `f`'s discriminant class.
    pub fn count(&self, class: fn(&Fault) -> bool) -> usize {
        self.faults.values().filter(|f| class(f)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_key_on_job_and_attempt() {
        let plan = FaultPlan::new()
            .panic_at(3)
            .transient_at(5)
            .delay_at(7, Duration::from_millis(2))
            .at_attempt(5, 1, Fault::Transient);
        assert_eq!(plan.lookup(3, 0), Some(Fault::Panic));
        assert_eq!(plan.lookup(3, 1), None, "retries succeed by default");
        assert_eq!(plan.lookup(5, 0), Some(Fault::Transient));
        assert_eq!(plan.lookup(5, 1), Some(Fault::Transient));
        assert_eq!(plan.lookup(7, 0), Some(Fault::Delay(Duration::from_millis(2))));
        assert_eq!(plan.lookup(1, 0), None);
        assert_eq!(plan.len(), 4);
    }

    #[test]
    fn seeded_plans_are_deterministic_and_disjoint() {
        let a = FaultPlan::seeded(42, 64, 3, 4, 2, Duration::from_millis(1));
        let b = FaultPlan::seeded(42, 64, 3, 4, 2, Duration::from_millis(1));
        assert_eq!(a.faults, b.faults, "same seed, same plan");
        assert_eq!(a.len(), 9, "disjoint jobs: every scheduled fault lands");
        assert_eq!(a.count(|f| matches!(f, Fault::Panic)), 3);
        assert_eq!(a.count(|f| matches!(f, Fault::Transient)), 4);
        assert_eq!(a.count(|f| matches!(f, Fault::Delay(_))), 2);
        let c = FaultPlan::seeded(43, 64, 3, 4, 2, Duration::from_millis(1));
        assert_ne!(a.faults, c.faults, "different seed, different plan");
    }

    #[test]
    fn seeded_saturates_instead_of_spinning() {
        // More faults than jobs: the plan fills every job once and stops.
        let plan = FaultPlan::seeded(7, 4, 10, 10, 0, Duration::ZERO);
        assert_eq!(plan.len(), 4);
    }
}
