//! The coordinator service layer: job types, engine routing, micro-
//! batching, the worker-pool server, and metrics. This is the L3
//! "coordination contribution" host — OT solves consumable as a service
//! with backpressure and observability.

pub mod batcher;
pub mod job;
pub mod metrics;
pub mod router;
pub mod server;

pub use job::{Engine, JobKind, JobOutcome, JobRequest, JobResult};
pub use server::{Coordinator, CoordinatorConfig, JobHandle};
