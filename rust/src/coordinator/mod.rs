//! The coordinator service layer: job types, engine routing (backed by the
//! [`crate::api::SolverRegistry`]), micro-batching, the worker-pool server,
//! and metrics. This is the L3 "coordination contribution" host — OT
//! solves consumable as a service with backpressure, per-job wall-clock
//! budgets/cancellation, and live per-engine phase observability.

pub mod batcher;
pub mod job;
pub mod metrics;
pub mod router;
pub mod server;

pub use job::{Engine, JobKind, JobOutcome, JobRequest};
pub use metrics::EngineCounters;
pub use server::{Coordinator, CoordinatorConfig, JobHandle};
