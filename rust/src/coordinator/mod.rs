//! The coordinator service layer: job types, engine routing (backed by the
//! [`crate::api::SolverRegistry`]), micro-batching, the worker-pool server,
//! and metrics. This is the L3 "coordination contribution" host — OT
//! solves consumable as a service with backpressure, per-job wall-clock
//! budgets/cancellation, and live per-engine phase observability.
//!
//! Since PR 9 the server is fault-tolerant: supervised workers
//! (`catch_unwind` + respawn under a restart budget), deadline-driven
//! shedding and retries with backoff, degraded-ε answers under deadline
//! pressure ([`server::DegradePolicy`]), and deterministic fault
//! injection ([`fault::FaultPlan`]) for chaos testing. Every submitted
//! job reaches exactly one terminal [`JobStatus`].

pub mod batcher;
pub mod fault;
pub mod job;
pub mod metrics;
pub mod router;
pub mod server;

pub use fault::{Fault, FaultPlan};
pub use job::{Engine, JobKind, JobOutcome, JobRequest, JobStatus};
pub use metrics::EngineCounters;
pub use server::{Coordinator, CoordinatorConfig, DegradePolicy, JobHandle};
