//! The coordinator service layer: job types, engine routing (backed by the
//! [`crate::api::SolverRegistry`]), micro-batching, the worker-pool server,
//! and metrics. This is the L3 "coordination contribution" host — OT
//! solves consumable as a service with backpressure, per-job wall-clock
//! budgets/cancellation, and live per-engine phase observability.
//!
//! Since PR 9 the server is fault-tolerant: supervised workers
//! (`catch_unwind` + respawn under a restart budget), deadline-driven
//! shedding and retries with backoff, degraded-ε answers under deadline
//! pressure ([`server::DegradePolicy`]), and deterministic fault
//! injection ([`fault::FaultPlan`]) for chaos testing. Every submitted
//! job reaches exactly one terminal [`JobStatus`].
//!
//! Since PR 10 dispatch is **sharded by problem shape**: each
//! [`server::shape_key`] gets its own lazily-spawned worker pool pinning
//! warm kernel arenas (near-100% `arena_reused` for same-shape streams),
//! fronted by async admission ([`server::Admission`]) with per-tenant
//! quotas/deadlines ([`server::TenantQuota`]) and a byte-bounded
//! [`cache::ResultCache`] keyed by `(problem digest, ε, engine)` whose
//! hits bypass dispatch entirely.

pub mod batcher;
pub mod cache;
pub mod digest;
pub mod fault;
pub mod job;
pub mod metrics;
pub mod router;
pub mod server;

pub use cache::{CacheKey, ResultCache};
pub use digest::problem_digest;
pub use fault::{Fault, FaultPlan};
pub use job::{Engine, JobKind, JobOutcome, JobRequest, JobStatus};
pub use metrics::EngineCounters;
pub use server::{Admission, Coordinator, CoordinatorConfig, DegradePolicy, JobHandle, TenantQuota};
