//! The coordinator service: submit jobs, get handles, await results.
//!
//! Topology (std::thread + mpsc; tokio is unavailable offline):
//!
//! ```text
//! submit() ──sync_channel(backpressure)──► dispatcher ──batcher──► job queue
//!               retries (delayed) ▲            │                  ▲   │
//!                                 │            ▼                  │   ▼
//!                                 │     shed expired        workers (N)
//!                                 │                               │
//!                                 └───────────────────────────────┤
//!                                                                 ▼
//!                                   JobHandle ◄──per-job channel── execute
//!                                              supervisor respawns panicked
//!                                              workers (restart budget)
//! ```
//!
//! The dispatcher resolves `Engine::Auto` and the artifact bucket up
//! front and groups jobs by (engine, bucket) via [`Batcher`]; workers
//! execute whole closed batches through
//! [`Router::execute_batch`], so XLA executions with the same bucket
//! reuse the compiled executable back-to-back and the CPU kernel
//! engines reuse one flow-kernel arena across same-shape jobs (the
//! reuse hits land in [`Metrics::record_arena_reuse`]).
//!
//! # Fault tolerance
//!
//! Every submitted job reaches **exactly one terminal outcome** — a
//! [`JobStatus`] of Served, Degraded, Shed, or Failed — no matter what
//! panics, stalls, or dies along the way:
//!
//! - **Supervision.** Workers run each batch inside `catch_unwind`; a
//!   panic (solver bug or injected fault) marks only that batch's
//!   unreplied jobs for retry, never siblings on other workers. The
//!   panicked worker exits and a supervisor thread respawns it with
//!   exponential backoff, up to [`CoordinatorConfig::restart_budget`];
//!   when the whole pool is gone, queued jobs fail terminally instead
//!   of hanging.
//! - **Deadlines.** Each job carries an effective deadline (request
//!   budget ∧ [`CoordinatorConfig::default_deadline`]). When a tenant
//!   default is configured, expired jobs are shed at dispatch, at retry
//!   release, and at worker pickup with a `retry_after` hint; a job
//!   whose deadline comes only from its own request budget keeps the
//!   legacy semantics (the solve runs and returns a cancelled
//!   completion) except on retries, which are always shed once expired.
//!   Live deadline-carrying jobs get their solve budget clamped to the
//!   remaining time.
//! - **Retries.** Transient failures (worker death mid-batch, injected
//!   transients, arena epoch mismatches) requeue through the dispatcher
//!   with jittered exponential backoff, up to
//!   [`CoordinatorConfig::max_retries`] extra attempts.
//! - **Degradation.** Under [`DegradePolicy`], a deadline-pressured job
//!   prefers a *certified coarser-ε answer* over a cancelled one: warm
//!   ladder engines stop at a completed level
//!   (`SolveRequest::degrade_on_deadline`), other engines re-solve at
//!   geometrically coarser ε on their warm variant under a grace
//!   budget, and the final fallback ships the partial answer with an
//!   honest certificate attached.
//! - **Fault injection.** A seeded [`FaultPlan`] injects panics,
//!   delays, and transient errors at chosen `(job, attempt)` steps,
//!   deterministically, inside the supervised region — the chaos-test
//!   hook `otpr serve --fault-seed` and `tests/fault_injection.rs` use.

use crate::api::{Coupling, Solution, SolveRequest};
use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::fault::{Fault, FaultPlan};
use crate::coordinator::job::{Engine, JobKind, JobOutcome, JobRequest, JobStatus};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::{warm_variant, Router};
use crate::core::{OtprError, Result};
use crate::runtime::XlaRuntime;
use crate::util::pool;
use crate::util::rng::SplitMix64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// When and how deadline-pressured jobs trade accuracy for an answer
/// instead of returning a cancelled, guarantee-free completion.
#[derive(Debug, Clone)]
pub struct DegradePolicy {
    /// Master switch; off preserves the legacy cancel-at-deadline
    /// behavior exactly.
    pub enabled: bool,
    /// ε multiplier per coordinator-side re-solve step (warm ladders
    /// degrade on their own level schedule first).
    pub eps_factor: f64,
    /// Coarser-ε re-solve attempts before falling back to the partial
    /// answer with its certificate.
    pub max_steps: u32,
    /// Extra wall-clock granted to each re-solve step.
    pub grace: Duration,
}

impl Default for DegradePolicy {
    fn default() -> Self {
        Self { enabled: false, eps_factor: 2.0, max_steps: 2, grace: Duration::from_millis(100) }
    }
}

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Queue capacity before submit() blocks (backpressure).
    pub queue_capacity: usize,
    pub batcher: BatcherConfig,
    /// Threads each native-parallel solve may use.
    pub solver_threads: usize,
    /// Audit mode: certify every k-th successfully served job (by job id)
    /// post-solve and fold pass/fail + gap histograms into the metrics
    /// ([`Metrics::record_audit`]). `0` disables auditing; `1` certifies
    /// every job. Cancelled solves are exempt (they carry no guarantee).
    pub audit_sample_every: u64,
    /// Per-tenant default deadline applied to every job; a job's
    /// effective deadline is the tighter of this and its own request
    /// budget. `None` leaves budget-less jobs deadline-free.
    pub default_deadline: Option<Duration>,
    /// Transient-failure retry budget per job (extra attempts beyond the
    /// first; `0` fails on the first transient).
    pub max_retries: u32,
    /// Base backoff before a retry re-enters the dispatcher; doubles per
    /// attempt with deterministic per-job jitter.
    pub retry_backoff: Duration,
    /// Worker respawns allowed across the coordinator's lifetime; once
    /// exhausted, dead workers stay dead and — with the pool empty —
    /// queued jobs fail terminally rather than hang.
    pub restart_budget: u32,
    pub degrade: DegradePolicy,
    /// Deterministic fault injection (tests and chaos runs); `None`
    /// injects nothing.
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 64,
            batcher: BatcherConfig::default(),
            solver_threads: pool::default_threads(),
            audit_sample_every: 0,
            default_deadline: None,
            max_retries: 2,
            retry_backoff: Duration::from_millis(5),
            restart_budget: 4,
            degrade: DegradePolicy::default(),
            faults: None,
        }
    }
}

struct Envelope {
    req: JobRequest,
    engine: Engine,
    submitted: Instant,
    /// 0 on first execution; retries re-enter with `attempt + 1`.
    attempt: u32,
    /// Effective deadline resolved at submit (budget ∧ tenant default).
    deadline: Option<Instant>,
    reply: Sender<JobOutcome>,
}

/// Awaitable handle for one submitted job.
pub struct JobHandle {
    pub id: u64,
    rx: Receiver<JobOutcome>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobOutcome> {
        self.rx
            .recv()
            .map_err(|_| OtprError::Coordinator("worker dropped the job".into()))
    }

    pub fn wait_timeout(&self, d: Duration) -> Option<JobOutcome> {
        self.rx.recv_timeout(d).ok()
    }
}

enum DispatchMsg {
    Job(Envelope),
    Shutdown,
}

pub struct Coordinator {
    tx: SyncSender<DispatchMsg>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    default_deadline: Option<Duration>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    supervisor: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn start(config: CoordinatorConfig, runtime: Option<Arc<XlaRuntime>>) -> Self {
        let metrics = Arc::new(Metrics::new());
        let router = Arc::new(Router::new(runtime, config.solver_threads));
        let (tx, dispatch_rx) = sync_channel::<DispatchMsg>(config.queue_capacity);
        // batch queue: dispatcher -> workers
        let (batch_tx, batch_rx) = sync_channel::<Vec<Envelope>>(config.queue_capacity);
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        // retry path: workers -> dispatcher, unbounded so a worker can
        // never deadlock against a full dispatcher
        let (retry_tx, retry_rx) = channel::<(Instant, Envelope)>();

        let dispatcher = {
            let metrics = metrics.clone();
            let batcher_cfg = config.batcher.clone();
            let router = router.clone();
            let retry_backoff = config.retry_backoff;
            let shed_enabled = config.default_deadline.is_some();
            std::thread::spawn(move || {
                dispatcher_loop(
                    dispatch_rx,
                    retry_rx,
                    batch_tx,
                    batcher_cfg,
                    metrics,
                    router,
                    retry_backoff,
                    shed_enabled,
                )
            })
        };

        let ctx = Arc::new(WorkerCtx {
            router,
            metrics: metrics.clone(),
            audit_every: config.audit_sample_every,
            max_retries: config.max_retries,
            retry_backoff: config.retry_backoff,
            degrade: config.degrade.clone(),
            faults: config.faults.clone(),
            shed_enabled: config.default_deadline.is_some(),
            retry_tx,
        });
        let workers = config.workers.max(1);
        let restart_budget = config.restart_budget;
        let supervisor = std::thread::spawn(move || {
            supervisor_loop(batch_rx, ctx, workers, restart_budget)
        });

        Self {
            tx,
            metrics,
            next_id: AtomicU64::new(1),
            default_deadline: config.default_deadline,
            dispatcher: Some(dispatcher),
            supervisor: Some(supervisor),
        }
    }

    /// Submit a job at accuracy `eps` with default request settings;
    /// blocks when the queue is at capacity (backpressure).
    pub fn submit(&self, kind: JobKind, eps: f64, engine: Engine) -> Result<JobHandle> {
        self.submit_request(kind, SolveRequest::new(eps), engine)
    }

    /// Submit a job with a full [`SolveRequest`] — wall-clock budget,
    /// cancellation token, and progress observer are honored by the
    /// executing engine; progress additionally feeds the coordinator's
    /// per-engine phase metrics. The job's effective deadline is resolved
    /// here: the tighter of the request budget and the coordinator's
    /// [`CoordinatorConfig::default_deadline`].
    pub fn submit_request(
        &self,
        kind: JobKind,
        request: SolveRequest,
        engine: Engine,
    ) -> Result<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let submitted = Instant::now();
        let deadline = request.effective_deadline(submitted, self.default_deadline);
        let req = JobRequest { id, kind, request, engine };
        self.metrics.record_submit();
        self.tx
            .send(DispatchMsg::Job(Envelope {
                req,
                engine,
                submitted,
                attempt: 0,
                deadline,
                reply: reply_tx,
            }))
            .map_err(|_| {
                self.metrics.record_reject();
                OtprError::Coordinator("coordinator is shut down".into())
            })?;
        Ok(JobHandle { id, rx: reply_rx })
    }

    /// Graceful shutdown: flush batches, join threads. Retries still in
    /// backoff at this point resolve terminally (Failed) — shutdown never
    /// waits out a backoff timer and never leaves a handle hanging.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
    }
}

/// Reply to the job's handle; a receiver dropped without `wait()` is
/// counted as an abandoned job (the outcome had nowhere to land).
fn send_outcome(metrics: &Metrics, reply: &Sender<JobOutcome>, outcome: JobOutcome) {
    if reply.send(outcome).is_err() {
        metrics.record_abandoned();
    }
}

/// Terminal failure for a job that never got (or kept) a worker.
fn fail_env(metrics: &Metrics, env: Envelope, msg: &str) {
    let queued = env.submitted.elapsed().as_secs_f64();
    metrics.record_done(env.engine.name(), false, queued, 0.0);
    send_outcome(
        metrics,
        &env.reply,
        JobOutcome {
            id: env.req.id,
            engine_used: env.engine.name(),
            status: JobStatus::Failed { attempts: env.attempt },
            result: Err(msg.to_string()),
            queued_secs: queued,
            solve_secs: 0.0,
        },
    );
}

/// Shed a job whose deadline passed before it could be solved.
fn shed_env(metrics: &Metrics, env: Envelope, retry_after: Duration) {
    metrics.record_shed();
    let queued = env.submitted.elapsed().as_secs_f64();
    send_outcome(
        metrics,
        &env.reply,
        JobOutcome {
            id: env.req.id,
            engine_used: env.engine.name(),
            status: JobStatus::Shed { retry_after },
            result: Err(format!(
                "shed: deadline passed before solving; retry after {}ms",
                retry_after.as_millis()
            )),
            queued_secs: queued,
            solve_secs: 0.0,
        },
    );
}

/// Transient failures are worth retrying: worker death mid-batch,
/// injected transients, arena-reuse epoch mismatches. Anything else
/// (unknown engine, unsupported problem kind, missing runtime) is
/// deterministic and fails fast.
fn is_transient(msg: &str) -> bool {
    msg.contains("transient") || msg.contains("panic") || msg.contains("epoch mismatch")
}

/// Exponential backoff with deterministic per-(job, attempt) jitter in
/// [0.75, 1.25)× so a batch of retried siblings doesn't re-collide.
fn backoff_jitter(base: Duration, id: u64, attempt: u32) -> Duration {
    let exp = base.saturating_mul(1u32 << attempt.min(10));
    let mut mix = SplitMix64::new(id ^ (u64::from(attempt) << 32));
    let frac = (mix.next_u64() % 512) as f64 / 1024.0;
    exp.mul_f64(0.75 + frac)
}

/// Human/metrics label for a batch key: `engine` or `engine/bucket`.
fn key_label(key: &crate::coordinator::batcher::BatchKey) -> String {
    match key.1 {
        Some(bucket) => format!("{}/{bucket}", key.0),
        None => key.0.to_string(),
    }
}

#[allow(clippy::too_many_arguments)]
fn dispatcher_loop(
    rx: Receiver<DispatchMsg>,
    retry_rx: Receiver<(Instant, Envelope)>,
    batch_tx: SyncSender<Vec<Envelope>>,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
    router: Arc<Router>,
    retry_backoff: Duration,
    shed_enabled: bool,
) {
    let mut batcher: Batcher<Envelope> = Batcher::new(cfg);
    // Retries waiting out their backoff; folded into the poll timeout.
    let mut pending: Vec<(Instant, Envelope)> = Vec::new();

    // Close a batch toward the worker pool. When every worker is gone
    // (restart budget exhausted) the send fails and the batch's jobs are
    // failed terminally — queued work must never hang on a dead pool.
    let close = |batch: crate::coordinator::batcher::Batch<Envelope>| -> bool {
        metrics.record_batch(
            &key_label(&batch.key),
            batch.jobs.len(),
            batch.wait().as_micros() as u64,
        );
        match batch_tx.send(batch.jobs) {
            Ok(()) => true,
            Err(std::sync::mpsc::SendError(jobs)) => {
                for env in jobs {
                    fail_env(&metrics, env, "worker pool exhausted; job was not executed");
                }
                false
            }
        }
    };

    // Shed or enqueue one job; false = worker pool gone. Shedding applies
    // under a tenant default deadline, and always to expired retries; a
    // first-attempt job deadlined only by its own budget keeps the legacy
    // run-and-return-cancelled semantics.
    let push_job = |batcher: &mut Batcher<Envelope>, mut env: Envelope| -> bool {
        if (shed_enabled || env.attempt > 0) && env.deadline.is_some_and(|d| d <= Instant::now()) {
            shed_env(&metrics, env, retry_backoff);
            return true;
        }
        // Resolve Auto and the artifact bucket here, once, so the batch
        // key is final and workers never re-route.
        let engine = router.resolve(&env.req);
        if env.req.engine == Engine::Auto && env.attempt == 0 {
            metrics.record_auto_route(engine.name());
        }
        env.engine = engine;
        let key = (engine.name(), router.bucket(&env.req, engine));
        if env.attempt > 0 {
            // A retry already paid its accumulation wait once — close it
            // (plus any same-key waiters) toward the pool immediately.
            let batch = batcher.push_now(key, env);
            return close(batch);
        }
        match batcher.push(key, env) {
            Some(batch) => close(batch),
            None => true,
        }
    };

    let drain_retry_rx = |pending: &mut Vec<(Instant, Envelope)>| {
        while let Ok(item) = retry_rx.try_recv() {
            pending.push(item);
        }
    };
    let fail_pending = |pending: &mut Vec<(Instant, Envelope)>, msg: &str| {
        for (_, env) in pending.drain(..) {
            fail_env(&metrics, env, msg);
        }
    };

    'live: loop {
        drain_retry_rx(&mut pending);
        // Release retries whose backoff elapsed (push_job sheds the ones
        // whose deadline expired while backing off).
        let now = Instant::now();
        let mut i = 0;
        while i < pending.len() {
            if pending[i].0 <= now {
                let (_, env) = pending.swap_remove(i);
                if !push_job(&mut batcher, env) {
                    break 'live;
                }
            } else {
                i += 1;
            }
        }
        let next_retry = pending.iter().map(|(due, _)| *due).min();
        let timeout = [batcher.next_deadline(), next_retry]
            .into_iter()
            .flatten()
            .min()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50))
            .min(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(DispatchMsg::Job(env)) => {
                if !push_job(&mut batcher, env) {
                    break 'live;
                }
            }
            Ok(DispatchMsg::Shutdown) => {
                for batch in batcher.drain_all() {
                    let _ = close(batch);
                }
                drain_retry_rx(&mut pending);
                fail_pending(&mut pending, "coordinator shut down before the retry could run");
                return; // dropping batch_tx stops workers
            }
            Err(RecvTimeoutError::Timeout) => {
                let mut dead = false;
                for batch in batcher.drain_expired() {
                    if !close(batch) {
                        dead = true;
                    }
                }
                if dead {
                    break 'live;
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                for batch in batcher.drain_all() {
                    let _ = close(batch);
                }
                drain_retry_rx(&mut pending);
                fail_pending(&mut pending, "coordinator dropped before the retry could run");
                return;
            }
        }
    }

    // Worker pool exhausted: fail everything queued, then keep answering
    // (terminally) until shutdown so no submitter ever hangs or loses a
    // reply.
    for batch in batcher.drain_all() {
        let _ = close(batch);
    }
    drain_retry_rx(&mut pending);
    fail_pending(&mut pending, "worker pool exhausted; job was not executed");
    loop {
        match rx.recv() {
            Ok(DispatchMsg::Job(env)) => {
                fail_env(&metrics, env, "worker pool exhausted; job was not executed")
            }
            Ok(DispatchMsg::Shutdown) | Err(_) => return,
        }
    }
}

/// Base pause before respawning a panicked worker; doubles per restart
/// (capped) so a crash-looping batch cannot spin the supervisor.
const RESTART_BACKOFF: Duration = Duration::from_millis(2);
const RESTART_BACKOFF_CAP: Duration = Duration::from_millis(250);

/// Owns the worker pool: spawns the initial workers, collects their exit
/// events, and respawns panicked ones under the restart budget. Holds the
/// last clone of the batch receiver, so when the supervisor returns (all
/// slots empty) the dispatcher's sends start failing and queued jobs
/// resolve terminally instead of hanging.
fn supervisor_loop(
    rx: Arc<Mutex<Receiver<Vec<Envelope>>>>,
    ctx: Arc<WorkerCtx>,
    workers: usize,
    restart_budget: u32,
) {
    let (event_tx, event_rx) = channel::<bool>();
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let spawn_worker = |handles: &mut Vec<std::thread::JoinHandle<()>>| {
        let rx = rx.clone();
        let ctx = ctx.clone();
        let tx = event_tx.clone();
        handles.push(std::thread::spawn(move || {
            let panicked = worker_loop(rx, ctx);
            let _ = tx.send(panicked);
        }));
    };
    for _ in 0..workers {
        spawn_worker(&mut handles);
    }
    let mut live = workers;
    let mut restarts = 0u32;
    while live > 0 {
        // Every live worker sends exactly one exit event, so this recv
        // cannot block past the pool's lifetime.
        let Ok(panicked) = event_rx.recv() else { break };
        if panicked && restarts < restart_budget {
            let backoff =
                RESTART_BACKOFF.saturating_mul(1u32 << restarts.min(7)).min(RESTART_BACKOFF_CAP);
            std::thread::sleep(backoff);
            restarts += 1;
            ctx.metrics.record_worker_restart();
            spawn_worker(&mut handles);
        } else {
            // Clean exit (channel closed at shutdown) or restart budget
            // exhausted: the slot stays empty.
            live -= 1;
        }
    }
    for h in handles {
        let _ = h.join();
    }
}

/// Everything a worker needs besides the batch receiver.
struct WorkerCtx {
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    audit_every: u64,
    max_retries: u32,
    retry_backoff: Duration,
    degrade: DegradePolicy,
    faults: Option<Arc<FaultPlan>>,
    /// Mirror of `default_deadline.is_some()`: pickup-shedding applies
    /// under a tenant default (and always to retries), never to a
    /// first-attempt job deadlined only by its own budget.
    shed_enabled: bool,
    retry_tx: Sender<(Instant, Envelope)>,
}

/// One job being processed by a worker. `reply` is taken exactly when a
/// terminal outcome (or a retry hand-off) happens — after a caught panic,
/// any job still holding its reply is known to be unresolved.
struct Prepared {
    req: JobRequest,
    engine: Engine,
    submitted: Instant,
    attempt: u32,
    deadline: Option<Instant>,
    reply: Option<Sender<JobOutcome>>,
    phase_count: Arc<AtomicU64>,
}

/// Queue time + a per-job phase counter teed into the request's observer
/// chain (folded into the metrics lock once per job, not per phase)
/// without disturbing any caller-supplied observer.
fn prepare(batch: Vec<Envelope>) -> Vec<Prepared> {
    batch
        .into_iter()
        .map(|env| {
            let mut req = env.req;
            let phase_count = Arc::new(AtomicU64::new(0));
            let counter = phase_count.clone();
            req.request = req.request.chain_observer(move |_p| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
            Prepared {
                req,
                engine: env.engine,
                submitted: env.submitted,
                attempt: env.attempt,
                deadline: env.deadline,
                reply: Some(env.reply),
                phase_count,
            }
        })
        .collect()
}

/// Returns `true` when the worker is exiting because it caught a panic
/// (the supervisor then decides about a respawn); `false` on clean
/// shutdown (batch channel closed).
fn worker_loop(rx: Arc<Mutex<Receiver<Vec<Envelope>>>>, ctx: Arc<WorkerCtx>) -> bool {
    loop {
        let batch = {
            // A poisoned receiver lock means a sibling worker panicked
            // mid-recv; the channel itself is still sound, so keep draining
            // rather than wedging the whole worker pool.
            let guard = rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            guard.recv()
        };
        let Ok(batch) = batch else { return false };
        let mut jobs = prepare(batch);
        // The whole batch runs supervised: a panic (solver bug or injected
        // fault) unwinds to here instead of killing the process, and only
        // this batch's unresolved jobs are affected.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            process_batch(&mut jobs, &ctx);
        }));
        if caught.is_err() {
            ctx.metrics.record_worker_panic();
            // Jobs still holding their reply never reached a terminal
            // outcome — requeue (or fail) each, then exit and let the
            // supervisor decide whether this worker is replaced.
            for job in jobs {
                if job.reply.is_some() {
                    retry_or_fail(&ctx, job, "transient: worker panicked over this batch");
                }
            }
            return true;
        }
    }
}

/// Requeue a transient casualty through the dispatcher with backoff, or
/// fail it terminally when the retry budget (or the dispatcher) is gone.
fn retry_or_fail(ctx: &WorkerCtx, mut job: Prepared, reason: &str) {
    let Some(reply) = job.reply.take() else { return };
    let queued = job.submitted.elapsed().as_secs_f64();
    if is_transient(reason) && job.attempt < ctx.max_retries {
        ctx.metrics.record_retry();
        let due = Instant::now() + backoff_jitter(ctx.retry_backoff, job.req.id, job.attempt);
        let env = Envelope {
            req: job.req,
            engine: job.engine,
            submitted: job.submitted,
            attempt: job.attempt + 1,
            deadline: job.deadline,
            reply,
        };
        match ctx.retry_tx.send((due, env)) {
            Ok(()) => return,
            Err(std::sync::mpsc::SendError((_, env))) => {
                fail_env(&ctx.metrics, env, reason);
                return;
            }
        }
    }
    ctx.metrics.record_done(job.engine.name(), false, queued, 0.0);
    send_outcome(
        &ctx.metrics,
        &reply,
        JobOutcome {
            id: job.req.id,
            engine_used: job.engine.name(),
            status: JobStatus::Failed { attempts: job.attempt + 1 },
            result: Err(reason.to_string()),
            queued_secs: queued,
            solve_secs: 0.0,
        },
    );
}

/// Shed one prepared job whose deadline passed at pickup.
fn shed_prepared(ctx: &WorkerCtx, mut job: Prepared) {
    let Some(reply) = job.reply.take() else { return };
    ctx.metrics.record_shed();
    send_outcome(
        &ctx.metrics,
        &reply,
        JobOutcome {
            id: job.req.id,
            engine_used: job.engine.name(),
            status: JobStatus::Shed { retry_after: ctx.retry_backoff },
            result: Err(format!(
                "shed: deadline passed before solving; retry after {}ms",
                ctx.retry_backoff.as_millis()
            )),
            queued_secs: job.submitted.elapsed().as_secs_f64(),
            solve_secs: 0.0,
        },
    );
}

/// Shape key for intra-batch grouping: jobs that can share one kernel
/// arena (same problem kind and cost dimensions). Implicit (provider-
/// backed) jobs group separately from dense ones — the payloads are O(n),
/// and mixing storage modes in one warm-carry run buys nothing.
fn shape_key(req: &JobRequest) -> (u8, usize, usize) {
    let (nb, na) = req.kind.dims();
    match &req.kind {
        crate::api::Problem::Assignment(_) => (0, nb, na),
        crate::api::Problem::Ot(_) => (1, nb, na),
        crate::api::Problem::Implicit(i) if i.masses.is_none() => (2, nb, na),
        crate::api::Problem::Implicit(_) => (3, nb, na),
    }
}

/// The overall-semantics ε a degraded answer's certificate verifies
/// against, from the raw ladder parameter `p` it stopped at: the core
/// runs matchings at ε/3 of the overall target, and OT plans certify at
/// 6× the matching quantization (see `core::certify::degraded_request`).
fn degraded_overall_eps(sol: &Solution, p: f64) -> f64 {
    match &sol.coupling {
        Coupling::Matching(_) => 3.0 * p,
        Coupling::Plan(_) => 6.0 * p,
    }
}

/// Decide the terminal status of a successful solve, re-solving at a
/// coarser ε when deadline pressure cancelled it and the policy allows.
fn disposition_ok(ctx: &WorkerCtx, job: &Prepared, sol: Solution) -> (Solution, JobStatus) {
    if let Some(p) = sol.degraded_eps_param() {
        // The warm ladder already degraded (mechanism A): attach the
        // certificate the status promises and report the overall ε it
        // verifies against.
        ctx.metrics.record_degraded();
        let mut sol = sol;
        if sol.certificate.is_none() {
            sol.certificate =
                Some(crate::core::certify::certify(&job.req.kind, &sol, &job.req.request));
        }
        let eps = degraded_overall_eps(&sol, p);
        return (sol, JobStatus::Degraded { eps });
    }
    if sol.is_cancelled()
        && ctx.degrade.enabled
        && job.deadline.is_some()
        && !job.req.request.cancel.is_cancelled()
    {
        // The deadline — not the caller's token — cancelled a ladder-less
        // solve: trade accuracy for an answer (mechanism B).
        return resolve_degraded(ctx, job, sol);
    }
    (sol, JobStatus::Served)
}

/// Mechanism B: re-solve at geometrically coarser ε on the engine's warm
/// variant under the grace budget, asking the registry to attach a
/// certificate. Falls back to the partial (lazy-product / arbitrary-
/// completion) answer with an honest certificate when grace runs out.
fn resolve_degraded(ctx: &WorkerCtx, job: &Prepared, partial: Solution) -> (Solution, JobStatus) {
    let engine = warm_variant(job.engine);
    let mut eps = job.req.request.eps;
    for _ in 0..ctx.degrade.max_steps {
        eps *= ctx.degrade.eps_factor;
        let mut request = job.req.request.clone();
        request.eps = eps;
        request.budget = Some(ctx.degrade.grace);
        request.want_certificate = true;
        request.degrade_on_deadline = false;
        let retry = JobRequest { id: job.req.id, kind: job.req.kind.clone(), request, engine };
        if let Ok(sol) = ctx.router.execute(&retry, engine) {
            if !sol.is_cancelled() {
                ctx.metrics.record_degraded();
                return (sol, JobStatus::Degraded { eps });
            }
        }
    }
    ctx.metrics.record_degraded();
    let mut sol = partial;
    if sol.certificate.is_none() {
        sol.certificate =
            Some(crate::core::certify::certify(&job.req.kind, &sol, &job.req.request));
    }
    // No accuracy claim survives — the certificate reports what holds.
    let eps = f64::INFINITY;
    (sol, JobStatus::Degraded { eps })
}

/// Execute one batch: disposal pass (pickup-deadline shed, injected
/// faults, budget clamping), then shape-grouped solves with per-job
/// terminal dispositions. Runs entirely inside the worker's supervised
/// (`catch_unwind`) region.
fn process_batch(jobs: &mut Vec<Prepared>, ctx: &WorkerCtx) {
    // Disposal pass. Order matters: an injected panic fires before the
    // job could be shed or failed, exactly like a real solver panic.
    let mut i = 0;
    while i < jobs.len() {
        let now = Instant::now();
        let id = jobs[i].req.id;
        let attempt = jobs[i].attempt;
        let fault = ctx.faults.as_ref().and_then(|p| p.lookup(id, attempt));
        match fault {
            Some(Fault::Panic) => {
                // panic-ok: deterministic fault injection — supervision
                // must observe a real unwind exactly where a solver panic
                // would fire.
                panic!("injected fault: worker panic at job {id} (attempt {attempt})");
            }
            Some(Fault::Delay(d)) => std::thread::sleep(d),
            _ => {}
        }
        if (ctx.shed_enabled || attempt > 0) && jobs[i].deadline.is_some_and(|d| d <= now) {
            let job = jobs.swap_remove(i);
            shed_prepared(ctx, job);
            continue;
        }
        if matches!(fault, Some(Fault::Transient)) {
            let job = jobs.swap_remove(i);
            retry_or_fail(ctx, job, "injected transient fault");
            continue;
        }
        if let Some(d) = jobs[i].deadline {
            // Clamp the solve to the remaining deadline and let the policy
            // prefer a degraded answer over a cancelled one.
            let rem = d.saturating_duration_since(now);
            if jobs[i].req.request.budget.map_or(true, |b| rem < b) {
                jobs[i].req.request.budget = Some(rem);
            }
            if ctx.degrade.enabled {
                jobs[i].req.request.degrade_on_deadline = true;
            }
        }
        i += 1;
    }

    // Group same-shape jobs (the dispatcher already grouped by
    // engine+bucket) and execute each group as one closed batch, so
    // kernel-backed engines reuse one arena across the group. Each
    // group's replies flush as soon as it finishes — a fast group is
    // never held behind a slow one.
    let mut groups: Vec<((u8, usize, usize), Vec<usize>)> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        let key = shape_key(&job.req);
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((key, vec![i])),
        }
    }
    // Audit sampling clones collected here and certified only after
    // every reply is out, so the O(n²) certify pass never adds to any
    // client-observed latency (one solution clone buys that).
    let mut audits: Vec<(usize, Solution)> = Vec::new();
    for (_, idxs) in &groups {
        let engine = jobs[idxs[0]].engine;
        // queue time up to the group start; head-of-line wait behind
        // earlier items in the same group is added back below so
        // batched jobs keep honest latency accounting
        let at_group_start: Vec<f64> =
            idxs.iter().map(|&i| jobs[i].submitted.elapsed().as_secs_f64()).collect();
        let t = Instant::now();
        let reqs: Vec<&JobRequest> = idxs.iter().map(|&i| &jobs[i].req).collect();
        let outs: Vec<std::result::Result<Solution, String>> = ctx
            .router
            .execute_batch(&reqs, engine)
            .into_iter()
            .map(|r| r.map_err(|e| e.to_string()))
            .collect();
        let per_job_fallback = t.elapsed().as_secs_f64() / idxs.len() as f64;
        let mut head_wait = 0.0;
        for ((&i, result), q0) in idxs.iter().zip(outs).zip(at_group_start) {
            let solve = match &result {
                Ok(sol) if sol.stats.seconds > 0.0 => sol.stats.seconds,
                _ => per_job_fallback,
            };
            let queued = q0 + head_wait;
            head_wait += solve;
            let engine_name = jobs[i].engine.name();
            match result {
                Ok(sol) => {
                    let (sol, status) = disposition_ok(ctx, &jobs[i], sol);
                    ctx.metrics
                        .record_phases(engine_name, jobs[i].phase_count.load(Ordering::Relaxed));
                    ctx.metrics.record_done(engine_name, true, queued, solve);
                    if sol.stats.arena_reused {
                        ctx.metrics.record_arena_reuse(1);
                    }
                    if sol.stats.warm_started {
                        ctx.metrics.record_warm_start(engine_name);
                    }
                    // plan-payload accounting: O(nnz) for kernel CSR
                    // answers, the dense slab for Sinkhorn/SSP/XLA
                    ctx.metrics.record_plan_bytes(engine_name, sol.stats.plan_state_bytes);
                    // A budget-stopped solve is exempt from auditing — it
                    // deliberately ships without a guarantee.
                    if ctx.audit_every > 0
                        && jobs[i].req.id % ctx.audit_every == 0
                        && !sol.is_cancelled()
                    {
                        audits.push((i, sol.clone()));
                    }
                    if let Some(reply) = jobs[i].reply.take() {
                        send_outcome(
                            &ctx.metrics,
                            &reply,
                            JobOutcome {
                                id: jobs[i].req.id,
                                engine_used: engine_name,
                                status,
                                result: Ok(sol),
                                queued_secs: queued,
                                solve_secs: solve,
                            },
                        );
                    }
                }
                Err(msg) => {
                    ctx.metrics
                        .record_phases(engine_name, jobs[i].phase_count.load(Ordering::Relaxed));
                    if is_transient(&msg) && jobs[i].attempt < ctx.max_retries {
                        if let Some(reply) = jobs[i].reply.take() {
                            ctx.metrics.record_retry();
                            let due = Instant::now()
                                + backoff_jitter(ctx.retry_backoff, jobs[i].req.id, jobs[i].attempt);
                            let env = Envelope {
                                req: jobs[i].req.clone(),
                                engine: jobs[i].engine,
                                submitted: jobs[i].submitted,
                                attempt: jobs[i].attempt + 1,
                                deadline: jobs[i].deadline,
                                reply,
                            };
                            if let Err(std::sync::mpsc::SendError((_, env))) =
                                ctx.retry_tx.send((due, env))
                            {
                                fail_env(&ctx.metrics, env, &msg);
                            }
                        }
                    } else {
                        ctx.metrics.record_done(engine_name, false, queued, solve);
                        if let Some(reply) = jobs[i].reply.take() {
                            send_outcome(
                                &ctx.metrics,
                                &reply,
                                JobOutcome {
                                    id: jobs[i].req.id,
                                    engine_used: engine_name,
                                    status: JobStatus::Failed { attempts: jobs[i].attempt + 1 },
                                    result: Err(msg),
                                    queued_secs: queued,
                                    solve_secs: solve,
                                },
                            );
                        }
                    }
                }
            }
        }
    }
    for (i, sol) in audits {
        let job = &jobs[i];
        let cert = sol.certificate.clone().unwrap_or_else(|| {
            crate::core::certify::certify(&job.req.kind, &sol, &job.req.request)
        });
        ctx.metrics.record_audit(&cert);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;

    fn assignment_job(n: usize, seed: u64) -> JobKind {
        JobKind::Assignment(Workload::RandomCosts { n }.assignment(seed))
    }

    #[test]
    fn solves_jobs_end_to_end() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h1 = coord.submit(assignment_job(16, 1), 0.3, Engine::NativeSeq).unwrap();
        let h2 = coord.submit(assignment_job(12, 2), 0.3, Engine::Auto).unwrap();
        let o1 = h1.wait().unwrap();
        let o2 = h2.wait().unwrap();
        assert!(o1.result.is_ok());
        assert_eq!(o1.status, JobStatus::Served);
        assert!(o2.result.is_ok());
        assert_eq!(o2.engine_used, "native-seq");
        let snap = coord.metrics.snapshot();
        assert!(snap.contains("completed=2"), "{snap}");
        coord.shutdown();
    }

    #[test]
    fn many_concurrent_jobs() {
        let coord = Coordinator::start(
            CoordinatorConfig { workers: 4, ..Default::default() },
            None,
        );
        let handles: Vec<_> = (0..20)
            .map(|i| coord.submit(assignment_job(10, i), 0.4, Engine::NativeSeq).unwrap())
            .collect();
        let mut costs = Vec::new();
        for h in handles {
            let out = h.wait().unwrap();
            costs.push(out.result.unwrap().cost);
        }
        assert_eq!(costs.len(), 20);
        coord.shutdown();
    }

    #[test]
    fn failed_jobs_report_errors() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        // XLA without a registry must fail but not crash the worker
        let h = coord.submit(assignment_job(8, 1), 0.3, Engine::Xla).unwrap();
        let out = h.wait().unwrap();
        assert!(out.result.is_err());
        assert!(
            matches!(out.status, JobStatus::Failed { attempts: 1 }),
            "a deterministic error fails on the first attempt: {:?}",
            out.status
        );
        // coordinator still serves afterwards
        let h2 = coord.submit(assignment_job(8, 2), 0.3, Engine::NativeSeq).unwrap();
        assert!(h2.wait().unwrap().result.is_ok());
        coord.shutdown();
    }

    #[test]
    fn ot_jobs_flow_through() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let inst = Workload::Fig1 { n: 10 }.ot_with_random_masses(5);
        let h = coord.submit(JobKind::Ot(inst), 0.3, Engine::Auto).unwrap();
        let out = h.wait().unwrap();
        let sol = out.result.unwrap();
        assert!(sol.cost.is_finite());
        assert!(sol.plan().is_some(), "OT jobs return a transport plan");
        coord.shutdown();
    }

    #[test]
    fn expired_deadline_jobs_are_shed_with_retry_hint() {
        let coord = Coordinator::start(
            CoordinatorConfig { default_deadline: Some(Duration::ZERO), ..Default::default() },
            None,
        );
        let h = coord.submit(assignment_job(8, 1), 0.3, Engine::NativeSeq).unwrap();
        let out = h.wait().unwrap();
        assert!(matches!(out.status, JobStatus::Shed { .. }), "{:?}", out.status);
        assert!(out.result.is_err());
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(metrics.shed.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.queue_depth(), 0, "shed jobs leave the queue-depth gauge");
    }

    #[test]
    fn injected_worker_panic_is_supervised_and_the_job_retries() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                faults: Some(Arc::new(FaultPlan::new().panic_at(1))),
                ..Default::default()
            },
            None,
        );
        let h = coord.submit(assignment_job(10, 1), 0.3, Engine::NativeSeq).unwrap();
        let out = h.wait().unwrap();
        assert!(out.result.is_ok(), "the retry after the panic must serve: {:?}", out.result);
        assert_eq!(out.status, JobStatus::Served);
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(metrics.worker_panics.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.worker_restarts.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.retried.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.queue_depth(), 0);
    }

    #[test]
    fn transient_faults_exhaust_the_retry_budget_into_failed() {
        let plan = FaultPlan::new()
            .at_attempt(1, 0, Fault::Transient)
            .at_attempt(1, 1, Fault::Transient)
            .at_attempt(1, 2, Fault::Transient);
        let coord = Coordinator::start(
            CoordinatorConfig { max_retries: 2, faults: Some(Arc::new(plan)), ..Default::default() },
            None,
        );
        let h = coord.submit(assignment_job(8, 1), 0.3, Engine::NativeSeq).unwrap();
        let out = h.wait().unwrap();
        assert!(
            matches!(out.status, JobStatus::Failed { attempts: 3 }),
            "attempt 0 + 2 retries, all transient: {:?}",
            out.status
        );
        assert!(out.result.is_err());
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(metrics.retried.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.queue_depth(), 0);
    }

    #[test]
    fn dropped_handles_count_as_abandoned_jobs() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h = coord.submit(assignment_job(8, 1), 0.3, Engine::NativeSeq).unwrap();
        drop(h); // never wait()ed — the reply has nowhere to land
        let metrics = coord.metrics.clone();
        coord.shutdown(); // joins workers, so the reply attempt has happened
        assert_eq!(metrics.abandoned_jobs.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.queue_depth(), 0, "abandoned jobs still resolve terminally");
    }

    #[test]
    fn audit_mode_certifies_sampled_jobs() {
        let coord = Coordinator::start(
            CoordinatorConfig { audit_sample_every: 1, ..Default::default() },
            None,
        );
        let handles: Vec<_> = (0..4)
            .map(|i| coord.submit(assignment_job(12, i), 0.3, Engine::NativeSeq).unwrap())
            .collect();
        for h in handles {
            assert!(h.wait().unwrap().result.is_ok());
        }
        // audits run after the reply is sent: join workers before reading
        let metrics = coord.metrics.clone();
        coord.shutdown();
        let (audited, pass, fail) = metrics.audit_counters();
        assert_eq!(audited, 4, "sample_every=1 audits every job");
        assert_eq!((pass, fail), (4, 0));
        let snap = metrics.snapshot();
        assert!(snap.contains("audit: sampled=4 pass=4 fail=0"), "{snap}");
        assert!(snap.contains("audit gap/bound histogram:"), "{snap}");
    }

    #[test]
    fn audit_sampling_respects_stride() {
        let coord = Coordinator::start(
            CoordinatorConfig { audit_sample_every: 2, ..Default::default() },
            None,
        );
        // job ids 1..=4 → ids 2 and 4 get audited
        let handles: Vec<_> = (0..4)
            .map(|i| coord.submit(assignment_job(10, i), 0.4, Engine::NativeSeq).unwrap())
            .collect();
        for h in handles {
            assert!(h.wait().unwrap().result.is_ok());
        }
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(metrics.audit_counters().0, 2);
    }

    #[test]
    fn audit_off_by_default() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h = coord.submit(assignment_job(8, 1), 0.4, Engine::NativeSeq).unwrap();
        assert!(h.wait().unwrap().result.is_ok());
        assert_eq!(coord.metrics.audit_counters(), (0, 0, 0));
        assert!(!coord.metrics.snapshot().contains("audit:"));
        coord.shutdown();
    }

    #[test]
    fn closed_batches_reuse_one_kernel_arena() {
        // The batch-path acceptance scenario: 8 same-shape jobs close one
        // batch (max_batch = 8, generous max_wait so expiry can't split
        // it), the worker executes them as one group, and the kernel
        // arena is reused for all but the first — asserted via the
        // Metrics reuse-hit counter.
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_secs(5) },
                ..Default::default()
            },
            None,
        );
        let handles: Vec<_> = (0..8)
            .map(|i| coord.submit(assignment_job(14, i), 0.3, Engine::NativeSeq).unwrap())
            .collect();
        for h in handles {
            assert!(h.wait().unwrap().result.is_ok());
        }
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(
            metrics.arena_reuse_hits.load(Ordering::Relaxed),
            7,
            "8 same-shape jobs in one batch must reuse one arena 7 times"
        );
        let counters = metrics.batch_counters();
        let seq = counters.iter().find(|c| c.key == "native-seq").expect("keyed batch recorded");
        assert_eq!((seq.batches, seq.jobs), (1, 8));
        assert!((seq.occupancy() - 8.0).abs() < 1e-12);
        let snap = metrics.snapshot();
        assert!(snap.contains("batch[native-seq]"), "{snap}");
        assert!(snap.contains("kernel arena reuse hits: 7"), "{snap}");
    }

    #[test]
    fn warm_engine_jobs_pin_warm_start_metrics() {
        use crate::coordinator::batcher::BatcherConfig;
        use crate::util::minijson::Json;
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_secs(5) },
                ..Default::default()
            },
            None,
        );
        let handles: Vec<_> = (0..4)
            .map(|i| coord.submit(assignment_job(12, i), 0.3, Engine::NativeSeqWarm).unwrap())
            .collect();
        for h in handles {
            let out = h.wait().unwrap();
            assert_eq!(out.engine_used, "native-seq-warm");
            assert!(out.result.unwrap().stats.warm_started);
        }
        let metrics = coord.metrics.clone();
        coord.shutdown();
        let counters = metrics.engine_counters();
        let w = counters.iter().find(|e| e.engine == "native-seq-warm").expect("engine recorded");
        assert_eq!(w.jobs, 4);
        assert_eq!(w.warm_started, 4, "every job on the warm engine warm-starts");
        // one batch of 4 same-shape jobs → items 1..3 carry the arena duals
        assert!(metrics.arena_reuse_hits.load(Ordering::Relaxed) >= 3);
        let j = Json::parse(&metrics.to_json().to_string()).expect("valid metrics JSON");
        let warm_total: f64 = j
            .get("engines")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("warm_started_jobs").unwrap().as_f64().unwrap())
            .sum();
        assert_eq!(warm_total, 4.0);
    }

    #[test]
    fn phase_metrics_flow_from_observer() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h = coord.submit(assignment_job(32, 9), 0.2, Engine::NativeSeq).unwrap();
        assert!(h.wait().unwrap().result.is_ok());
        let counters = coord.metrics.engine_counters();
        let seq = counters.iter().find(|e| e.engine == "native-seq").expect("engine recorded");
        assert_eq!(seq.jobs, 1);
        assert!(seq.phases > 0, "solver phases must stream into metrics");
        coord.shutdown();
    }
}
