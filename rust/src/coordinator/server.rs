//! The coordinator service: submit jobs, get handles, await results.
//!
//! Topology (std::thread + mpsc; tokio is unavailable offline):
//!
//! ```text
//! submit() ──sync_channel(backpressure)──► dispatcher ──batcher──► job queue
//!                                                                 ▲   │
//!                                               workers (N) ──────┘   ▼
//!                                   JobHandle ◄──per-job channel── execute
//! ```
//!
//! The dispatcher resolves `Engine::Auto` and the artifact bucket up
//! front and groups jobs by (engine, bucket) via [`Batcher`]; workers
//! execute whole closed batches through
//! [`Router::execute_batch`], so XLA executions with the same bucket
//! reuse the compiled executable back-to-back and the CPU kernel
//! engines reuse one flow-kernel arena across same-shape jobs (the
//! reuse hits land in [`Metrics::record_arena_reuse`]).

use crate::api::{Solution, SolveRequest};
use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::job::{Engine, JobKind, JobOutcome, JobRequest};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::Router;
use crate::core::{OtprError, Result};
use crate::runtime::XlaRuntime;
use crate::util::pool;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Queue capacity before submit() blocks (backpressure).
    pub queue_capacity: usize,
    pub batcher: BatcherConfig,
    /// Threads each native-parallel solve may use.
    pub solver_threads: usize,
    /// Audit mode: certify every k-th successfully served job (by job id)
    /// post-solve and fold pass/fail + gap histograms into the metrics
    /// ([`Metrics::record_audit`]). `0` disables auditing; `1` certifies
    /// every job. Cancelled solves are exempt (they carry no guarantee).
    pub audit_sample_every: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 64,
            batcher: BatcherConfig::default(),
            solver_threads: pool::default_threads(),
            audit_sample_every: 0,
        }
    }
}

struct Envelope {
    req: JobRequest,
    engine: Engine,
    submitted: Instant,
    reply: Sender<JobOutcome>,
}

/// Awaitable handle for one submitted job.
pub struct JobHandle {
    pub id: u64,
    rx: Receiver<JobOutcome>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobOutcome> {
        self.rx
            .recv()
            .map_err(|_| OtprError::Coordinator("worker dropped the job".into()))
    }

    pub fn wait_timeout(&self, d: Duration) -> Option<JobOutcome> {
        self.rx.recv_timeout(d).ok()
    }
}

enum DispatchMsg {
    Job(Envelope),
    Shutdown,
}

pub struct Coordinator {
    tx: SyncSender<DispatchMsg>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn start(config: CoordinatorConfig, runtime: Option<Arc<XlaRuntime>>) -> Self {
        let metrics = Arc::new(Metrics::new());
        let router = Arc::new(Router::new(runtime, config.solver_threads));
        let (tx, dispatch_rx) = sync_channel::<DispatchMsg>(config.queue_capacity);
        // batch queue: dispatcher -> workers
        let (batch_tx, batch_rx) = sync_channel::<Vec<Envelope>>(config.queue_capacity);
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let dispatcher = {
            let metrics = metrics.clone();
            let batcher_cfg = config.batcher.clone();
            let router = router.clone();
            std::thread::spawn(move || {
                dispatcher_loop(dispatch_rx, batch_tx, batcher_cfg, metrics, router)
            })
        };

        let mut workers = Vec::new();
        for _ in 0..config.workers.max(1) {
            let rx = batch_rx.clone();
            let router = router.clone();
            let metrics = metrics.clone();
            let audit_every = config.audit_sample_every;
            workers.push(std::thread::spawn(move || {
                worker_loop(rx, router, metrics, audit_every)
            }));
        }

        Self { tx, metrics, next_id: AtomicU64::new(1), dispatcher: Some(dispatcher), workers }
    }

    /// Submit a job at accuracy `eps` with default request settings;
    /// blocks when the queue is at capacity (backpressure).
    pub fn submit(&self, kind: JobKind, eps: f64, engine: Engine) -> Result<JobHandle> {
        self.submit_request(kind, SolveRequest::new(eps), engine)
    }

    /// Submit a job with a full [`SolveRequest`] — wall-clock budget,
    /// cancellation token, and progress observer are honored by the
    /// executing engine; progress additionally feeds the coordinator's
    /// per-engine phase metrics.
    pub fn submit_request(
        &self,
        kind: JobKind,
        request: SolveRequest,
        engine: Engine,
    ) -> Result<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let req = JobRequest { id, kind, request, engine };
        self.metrics.record_submit();
        self.tx
            .send(DispatchMsg::Job(Envelope {
                req,
                engine,
                submitted: Instant::now(),
                reply: reply_tx,
            }))
            .map_err(|_| {
                self.metrics.record_reject();
                OtprError::Coordinator("coordinator is shut down".into())
            })?;
        Ok(JobHandle { id, rx: reply_rx })
    }

    /// Graceful shutdown: flush batches, join threads.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Human/metrics label for a batch key: `engine` or `engine/bucket`.
fn key_label(key: &crate::coordinator::batcher::BatchKey) -> String {
    match key.1 {
        Some(bucket) => format!("{}/{bucket}", key.0),
        None => key.0.to_string(),
    }
}

fn dispatcher_loop(
    rx: Receiver<DispatchMsg>,
    batch_tx: SyncSender<Vec<Envelope>>,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
    router: Arc<Router>,
) {
    let mut batcher: Batcher<Envelope> = Batcher::new(cfg);
    let close = |batch: crate::coordinator::batcher::Batch<Envelope>,
                     tx: &SyncSender<Vec<Envelope>>|
     -> bool {
        metrics.record_batch(
            &key_label(&batch.key),
            batch.jobs.len(),
            batch.wait().as_micros() as u64,
        );
        tx.send(batch.jobs).is_ok()
    };
    loop {
        // poll with a deadline so expiring batches flush promptly
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(DispatchMsg::Job(mut env)) => {
                // Resolve Auto and the artifact bucket here, once, so the
                // batch key is final and workers never re-route.
                let engine = router.resolve(&env.req);
                if env.req.engine == Engine::Auto {
                    metrics.record_auto_route(engine.name());
                }
                env.engine = engine;
                let key = (engine.name(), router.bucket(&env.req, engine));
                if let Some(batch) = batcher.push(key, env) {
                    if !close(batch, &batch_tx) {
                        return;
                    }
                }
            }
            Ok(DispatchMsg::Shutdown) => {
                for batch in batcher.drain_all() {
                    let _ = close(batch, &batch_tx);
                }
                return; // dropping batch_tx stops workers
            }
            Err(RecvTimeoutError::Timeout) => {
                for batch in batcher.drain_expired() {
                    if !close(batch, &batch_tx) {
                        return;
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                for batch in batcher.drain_all() {
                    let _ = close(batch, &batch_tx);
                }
                return;
            }
        }
    }
}

/// Shape key for intra-batch grouping: jobs that can share one kernel
/// arena (same problem kind and cost dimensions). Implicit (provider-
/// backed) jobs group separately from dense ones — the payloads are O(n),
/// and mixing storage modes in one warm-carry run buys nothing.
fn shape_key(req: &JobRequest) -> (u8, usize, usize) {
    let (nb, na) = req.kind.dims();
    match &req.kind {
        crate::api::Problem::Assignment(_) => (0, nb, na),
        crate::api::Problem::Ot(_) => (1, nb, na),
        crate::api::Problem::Implicit(i) if i.masses.is_none() => (2, nb, na),
        crate::api::Problem::Implicit(_) => (3, nb, na),
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Vec<Envelope>>>>,
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    audit_every: u64,
) {
    loop {
        let batch = {
            // A poisoned receiver lock means a sibling worker panicked
            // mid-recv; the channel itself is still sound, so keep draining
            // rather than wedging the whole worker pool.
            let guard = rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            guard.recv()
        };
        let Ok(batch) = batch else { return };

        // Prepare every job: queue time + a per-job phase counter teed
        // into the request's observer chain (folded into the metrics lock
        // once per job, not per phase) without disturbing any
        // caller-supplied observer.
        struct Prepared {
            req: JobRequest,
            engine: Engine,
            reply: Sender<JobOutcome>,
            submitted: Instant,
            phase_count: Arc<AtomicU64>,
        }
        let jobs: Vec<Prepared> = batch
            .into_iter()
            .map(|env| {
                let mut req = env.req;
                let phase_count = Arc::new(AtomicU64::new(0));
                let counter = phase_count.clone();
                req.request = req.request.chain_observer(move |_p| {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
                Prepared {
                    req,
                    engine: env.engine,
                    reply: env.reply,
                    submitted: env.submitted,
                    phase_count,
                }
            })
            .collect();

        // Group same-shape jobs (the dispatcher already grouped by
        // engine+bucket) and execute each group as one closed batch, so
        // kernel-backed engines reuse one arena across the group. Each
        // group's replies flush as soon as it finishes — a fast group is
        // never held behind a slow one.
        let mut groups: Vec<((u8, usize, usize), Vec<usize>)> = Vec::new();
        for (i, job) in jobs.iter().enumerate() {
            let key = shape_key(&job.req);
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((key, vec![i])),
            }
        }
        // Audit sampling clones collected here and certified only after
        // every reply is out, so the O(n²) certify pass never adds to any
        // client-observed latency (one solution clone buys that).
        let mut audits: Vec<(usize, Solution)> = Vec::new();
        for (_, idxs) in &groups {
            let engine = jobs[idxs[0]].engine;
            // queue time up to the group start; head-of-line wait behind
            // earlier items in the same group is added back below so
            // batched jobs keep honest latency accounting
            let at_group_start: Vec<f64> =
                idxs.iter().map(|&i| jobs[i].submitted.elapsed().as_secs_f64()).collect();
            let t = Instant::now();
            let reqs: Vec<&JobRequest> = idxs.iter().map(|&i| &jobs[i].req).collect();
            let outs: Vec<Result<Solution, String>> = router
                .execute_batch(&reqs, engine)
                .into_iter()
                .map(|r| r.map_err(|e| e.to_string()))
                .collect();
            let per_job_fallback = t.elapsed().as_secs_f64() / idxs.len() as f64;
            let mut head_wait = 0.0;
            for ((&i, result), q0) in idxs.iter().zip(outs).zip(at_group_start) {
                let job = &jobs[i];
                let solve = match &result {
                    Ok(sol) if sol.stats.seconds > 0.0 => sol.stats.seconds,
                    _ => per_job_fallback,
                };
                let queued = q0 + head_wait;
                head_wait += solve;
                metrics.record_phases(job.engine.name(), job.phase_count.load(Ordering::Relaxed));
                metrics.record_done(job.engine.name(), result.is_ok(), queued, solve);
                if let Ok(sol) = &result {
                    if sol.stats.arena_reused {
                        metrics.record_arena_reuse(1);
                    }
                    if sol.stats.warm_started {
                        metrics.record_warm_start(job.engine.name());
                    }
                    // plan-payload accounting: O(nnz) for kernel CSR
                    // answers, the dense slab for Sinkhorn/SSP/XLA
                    metrics.record_plan_bytes(job.engine.name(), sol.stats.plan_state_bytes);
                }
                // A budget-stopped solve is exempt from auditing — it
                // deliberately ships without a guarantee.
                if audit_every > 0 && job.req.id % audit_every == 0 {
                    if let Ok(sol) = &result {
                        if !sol.is_cancelled() {
                            audits.push((i, sol.clone()));
                        }
                    }
                }
                let _ = job.reply.send(JobOutcome {
                    id: job.req.id,
                    engine_used: job.engine.name(),
                    result,
                    queued_secs: queued,
                    solve_secs: solve,
                });
            }
        }
        for (i, sol) in audits {
            let job = &jobs[i];
            let cert = sol.certificate.clone().unwrap_or_else(|| {
                crate::core::certify::certify(&job.req.kind, &sol, &job.req.request)
            });
            metrics.record_audit(&cert);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;

    fn assignment_job(n: usize, seed: u64) -> JobKind {
        JobKind::Assignment(Workload::RandomCosts { n }.assignment(seed))
    }

    #[test]
    fn solves_jobs_end_to_end() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h1 = coord.submit(assignment_job(16, 1), 0.3, Engine::NativeSeq).unwrap();
        let h2 = coord.submit(assignment_job(12, 2), 0.3, Engine::Auto).unwrap();
        let o1 = h1.wait().unwrap();
        let o2 = h2.wait().unwrap();
        assert!(o1.result.is_ok());
        assert!(o2.result.is_ok());
        assert_eq!(o2.engine_used, "native-seq");
        let snap = coord.metrics.snapshot();
        assert!(snap.contains("completed=2"), "{snap}");
        coord.shutdown();
    }

    #[test]
    fn many_concurrent_jobs() {
        let coord = Coordinator::start(
            CoordinatorConfig { workers: 4, ..Default::default() },
            None,
        );
        let handles: Vec<_> = (0..20)
            .map(|i| coord.submit(assignment_job(10, i), 0.4, Engine::NativeSeq).unwrap())
            .collect();
        let mut costs = Vec::new();
        for h in handles {
            let out = h.wait().unwrap();
            costs.push(out.result.unwrap().cost);
        }
        assert_eq!(costs.len(), 20);
        coord.shutdown();
    }

    #[test]
    fn failed_jobs_report_errors() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        // XLA without a registry must fail but not crash the worker
        let h = coord.submit(assignment_job(8, 1), 0.3, Engine::Xla).unwrap();
        let out = h.wait().unwrap();
        assert!(out.result.is_err());
        // coordinator still serves afterwards
        let h2 = coord.submit(assignment_job(8, 2), 0.3, Engine::NativeSeq).unwrap();
        assert!(h2.wait().unwrap().result.is_ok());
        coord.shutdown();
    }

    #[test]
    fn ot_jobs_flow_through() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let inst = Workload::Fig1 { n: 10 }.ot_with_random_masses(5);
        let h = coord.submit(JobKind::Ot(inst), 0.3, Engine::Auto).unwrap();
        let out = h.wait().unwrap();
        let sol = out.result.unwrap();
        assert!(sol.cost.is_finite());
        assert!(sol.plan().is_some(), "OT jobs return a transport plan");
        coord.shutdown();
    }

    #[test]
    fn audit_mode_certifies_sampled_jobs() {
        let coord = Coordinator::start(
            CoordinatorConfig { audit_sample_every: 1, ..Default::default() },
            None,
        );
        let handles: Vec<_> = (0..4)
            .map(|i| coord.submit(assignment_job(12, i), 0.3, Engine::NativeSeq).unwrap())
            .collect();
        for h in handles {
            assert!(h.wait().unwrap().result.is_ok());
        }
        // audits run after the reply is sent: join workers before reading
        let metrics = coord.metrics.clone();
        coord.shutdown();
        let (audited, pass, fail) = metrics.audit_counters();
        assert_eq!(audited, 4, "sample_every=1 audits every job");
        assert_eq!((pass, fail), (4, 0));
        let snap = metrics.snapshot();
        assert!(snap.contains("audit: sampled=4 pass=4 fail=0"), "{snap}");
        assert!(snap.contains("audit gap/bound histogram:"), "{snap}");
    }

    #[test]
    fn audit_sampling_respects_stride() {
        let coord = Coordinator::start(
            CoordinatorConfig { audit_sample_every: 2, ..Default::default() },
            None,
        );
        // job ids 1..=4 → ids 2 and 4 get audited
        let handles: Vec<_> = (0..4)
            .map(|i| coord.submit(assignment_job(10, i), 0.4, Engine::NativeSeq).unwrap())
            .collect();
        for h in handles {
            assert!(h.wait().unwrap().result.is_ok());
        }
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(metrics.audit_counters().0, 2);
    }

    #[test]
    fn audit_off_by_default() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h = coord.submit(assignment_job(8, 1), 0.4, Engine::NativeSeq).unwrap();
        assert!(h.wait().unwrap().result.is_ok());
        assert_eq!(coord.metrics.audit_counters(), (0, 0, 0));
        assert!(!coord.metrics.snapshot().contains("audit:"));
        coord.shutdown();
    }

    #[test]
    fn closed_batches_reuse_one_kernel_arena() {
        // The batch-path acceptance scenario: 8 same-shape jobs close one
        // batch (max_batch = 8, generous max_wait so expiry can't split
        // it), the worker executes them as one group, and the kernel
        // arena is reused for all but the first — asserted via the
        // Metrics reuse-hit counter.
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_secs(5) },
                ..Default::default()
            },
            None,
        );
        let handles: Vec<_> = (0..8)
            .map(|i| coord.submit(assignment_job(14, i), 0.3, Engine::NativeSeq).unwrap())
            .collect();
        for h in handles {
            assert!(h.wait().unwrap().result.is_ok());
        }
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(
            metrics.arena_reuse_hits.load(Ordering::Relaxed),
            7,
            "8 same-shape jobs in one batch must reuse one arena 7 times"
        );
        let counters = metrics.batch_counters();
        let seq = counters.iter().find(|c| c.key == "native-seq").expect("keyed batch recorded");
        assert_eq!((seq.batches, seq.jobs), (1, 8));
        assert!((seq.occupancy() - 8.0).abs() < 1e-12);
        let snap = metrics.snapshot();
        assert!(snap.contains("batch[native-seq]"), "{snap}");
        assert!(snap.contains("kernel arena reuse hits: 7"), "{snap}");
    }

    #[test]
    fn warm_engine_jobs_pin_warm_start_metrics() {
        use crate::coordinator::batcher::BatcherConfig;
        use crate::util::minijson::Json;
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_secs(5) },
                ..Default::default()
            },
            None,
        );
        let handles: Vec<_> = (0..4)
            .map(|i| coord.submit(assignment_job(12, i), 0.3, Engine::NativeSeqWarm).unwrap())
            .collect();
        for h in handles {
            let out = h.wait().unwrap();
            assert_eq!(out.engine_used, "native-seq-warm");
            assert!(out.result.unwrap().stats.warm_started);
        }
        let metrics = coord.metrics.clone();
        coord.shutdown();
        let counters = metrics.engine_counters();
        let w = counters.iter().find(|e| e.engine == "native-seq-warm").expect("engine recorded");
        assert_eq!(w.jobs, 4);
        assert_eq!(w.warm_started, 4, "every job on the warm engine warm-starts");
        // one batch of 4 same-shape jobs → items 1..3 carry the arena duals
        assert!(metrics.arena_reuse_hits.load(Ordering::Relaxed) >= 3);
        let j = Json::parse(&metrics.to_json().to_string()).expect("valid metrics JSON");
        let warm_total: f64 = j
            .get("engines")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("warm_started_jobs").unwrap().as_f64().unwrap())
            .sum();
        assert_eq!(warm_total, 4.0);
    }

    #[test]
    fn phase_metrics_flow_from_observer() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h = coord.submit(assignment_job(32, 9), 0.2, Engine::NativeSeq).unwrap();
        assert!(h.wait().unwrap().result.is_ok());
        let counters = coord.metrics.engine_counters();
        let seq = counters.iter().find(|e| e.engine == "native-seq").expect("engine recorded");
        assert_eq!(seq.jobs, 1);
        assert!(seq.phases > 0, "solver phases must stream into metrics");
        coord.shutdown();
    }
}
