//! The coordinator service: submit jobs, get handles, await results.
//!
//! Topology (std::thread + mpsc; tokio is unavailable offline):
//!
//! ```text
//! admit()/submit() ──sync_channel(backpressure)──► dispatcher
//!     │  cache hit? ──► reply immediately             │ shape_key(job)
//!     ▼                                               ▼
//! quota check (per-tenant)              ┌─── shard A ───┐ ┌─ shard B ─┐
//!     retries (delayed) ▲               │ batcher       │ │  ...      │
//!                       │               │   ▼           │ └───────────┘
//!                       │               │ workers (N)   │  lazily spawned,
//!                       └───────────────┤   pinned      │  LRU-evicted at
//!                                       │   arenas      │  max_shards, idle
//!                                       └───────┬───────┘  reap after TTL
//!                                               ▼
//!                         JobHandle ◄──per-job channel── execute
//!                                    per-shard supervisor respawns
//!                                    panicked workers (restart budget)
//! ```
//!
//! The dispatcher resolves `Engine::Auto` and the artifact bucket up
//! front and routes each job by [`shape_key`] to a dedicated **shard**:
//! a lazily-spawned worker pool with its own [`Batcher`] and supervisor.
//! Workers execute whole closed batches through
//! [`Router::execute_batch_pinned`], holding their kernel solvers (and
//! therefore the flow-kernel arena) *across* batches — a same-shape job
//! stream reports `arena_reused` on every job after a worker's first
//! (the hits land in [`Metrics::record_arena_reuse`] and per shard in
//! [`Metrics::record_shard_arena_reuse`]). Shards are capped at
//! [`CoordinatorConfig::max_shards`] with LRU eviction and reaped after
//! [`CoordinatorConfig::shard_idle_ttl`] without traffic.
//!
//! # Admission, tenants, and the result cache
//!
//! [`Coordinator::admit`] is the non-blocking front door: it resolves
//! the job against its tenant's [`TenantQuota`] (max in-flight, max
//! queue depth, per-tenant default deadline) and answers with
//! [`Admission::Accepted`] or [`Admission::Backpressure`] carrying a
//! `retry_after` hint — it never blocks the caller. The blocking
//! [`Coordinator::submit`]/[`Coordinator::submit_request`] path keeps
//! its backpressure-by-blocking semantics and still resolves tenant
//! deadlines. Both consult the [`ResultCache`] first when
//! [`CoordinatorConfig::cache_bytes`] is non-zero: a hit on
//! `(problem digest, ε, engine)` replies immediately with a
//! byte-identical stored answer and bypasses dispatch entirely.
//!
//! # Fault tolerance
//!
//! Every submitted job reaches **exactly one terminal outcome** — a
//! [`JobStatus`] of Served, Degraded, Shed, or Failed — no matter what
//! panics, stalls, or dies along the way:
//!
//! - **Supervision.** Workers run each batch inside `catch_unwind`; a
//!   panic (solver bug or injected fault) marks only that batch's
//!   unreplied jobs for retry, never siblings on other workers. The
//!   panicked worker exits and a supervisor thread respawns it with
//!   exponential backoff, up to [`CoordinatorConfig::restart_budget`];
//!   when the whole pool is gone, queued jobs fail terminally instead
//!   of hanging.
//! - **Deadlines.** Each job carries an effective deadline (request
//!   budget ∧ [`CoordinatorConfig::default_deadline`]). When a tenant
//!   default is configured, expired jobs are shed at dispatch, at retry
//!   release, and at worker pickup with a `retry_after` hint; a job
//!   whose deadline comes only from its own request budget keeps the
//!   legacy semantics (the solve runs and returns a cancelled
//!   completion) except on retries, which are always shed once expired.
//!   Live deadline-carrying jobs get their solve budget clamped to the
//!   remaining time.
//! - **Retries.** Transient failures (worker death mid-batch, injected
//!   transients, arena epoch mismatches) requeue through the dispatcher
//!   with jittered exponential backoff, up to
//!   [`CoordinatorConfig::max_retries`] extra attempts.
//! - **Degradation.** Under [`DegradePolicy`], a deadline-pressured job
//!   prefers a *certified coarser-ε answer* over a cancelled one: warm
//!   ladder engines stop at a completed level
//!   (`SolveRequest::degrade_on_deadline`), other engines re-solve at
//!   geometrically coarser ε on their warm variant under a grace
//!   budget, and the final fallback ships the partial answer with an
//!   honest certificate attached.
//! - **Fault injection.** A seeded [`FaultPlan`] injects panics,
//!   delays, and transient errors at chosen `(job, attempt)` steps,
//!   deterministically, inside the supervised region — the chaos-test
//!   hook `otpr serve --fault-seed` and `tests/fault_injection.rs` use.

use crate::api::{Coupling, EpsSemantics, Solution, SolveRequest};
use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::cache::{CacheKey, ResultCache};
use crate::coordinator::digest::problem_digest;
use crate::coordinator::fault::{Fault, FaultPlan};
use crate::coordinator::job::{Engine, JobKind, JobOutcome, JobRequest, JobStatus};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::{warm_variant, PinnedSolvers, Router};
use crate::core::{OtprError, Result};
use crate::runtime::XlaRuntime;
use crate::util::pool;
use crate::util::rng::SplitMix64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// When and how deadline-pressured jobs trade accuracy for an answer
/// instead of returning a cancelled, guarantee-free completion.
#[derive(Debug, Clone)]
pub struct DegradePolicy {
    /// Master switch; off preserves the legacy cancel-at-deadline
    /// behavior exactly.
    pub enabled: bool,
    /// ε multiplier per coordinator-side re-solve step (warm ladders
    /// degrade on their own level schedule first).
    pub eps_factor: f64,
    /// Coarser-ε re-solve attempts before falling back to the partial
    /// answer with its certificate.
    pub max_steps: u32,
    /// Extra wall-clock granted to each re-solve step.
    pub grace: Duration,
}

impl Default for DegradePolicy {
    fn default() -> Self {
        Self { enabled: false, eps_factor: 2.0, max_steps: 2, grace: Duration::from_millis(100) }
    }
}

/// Per-tenant admission limits and deadline default. Resolved by tenant
/// name from [`CoordinatorConfig::tenants`]; requests whose
/// `SolveRequest::tenant` is `None` or unknown bill to
/// [`CoordinatorConfig::default_quota`].
#[derive(Debug, Clone)]
pub struct TenantQuota {
    /// Jobs this tenant may have admitted-but-not-terminal at once;
    /// [`Coordinator::admit`] answers `Backpressure` beyond it.
    pub max_in_flight: usize,
    /// Jobs this tenant may have waiting (admitted but not yet picked up
    /// by a shard worker); the queue-depth-driven shedding signal.
    pub max_queue_depth: usize,
    /// Default deadline for this tenant's jobs; a job's effective
    /// deadline is the tightest of its own request budget, this, and the
    /// coordinator-wide [`CoordinatorConfig::default_deadline`].
    pub default_deadline: Option<Duration>,
}

impl Default for TenantQuota {
    /// Permissive: no caps, no deadline — the anonymous tenant keeps the
    /// pre-quota coordinator semantics exactly.
    fn default() -> Self {
        Self { max_in_flight: usize::MAX, max_queue_depth: usize::MAX, default_deadline: None }
    }
}

/// What [`Coordinator::admit`] answers — admission never blocks.
pub enum Admission {
    /// The job is in; await the handle as usual.
    Accepted(JobHandle),
    /// The tenant's quota (or the dispatch queue) is saturated; nothing
    /// was enqueued. Come back after `retry_after`.
    Backpressure { retry_after: Duration },
}

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Workers **per shard** (each shape-keyed shard gets its own pool).
    pub workers: usize,
    /// Queue capacity before submit() blocks (backpressure).
    pub queue_capacity: usize,
    pub batcher: BatcherConfig,
    /// Threads each native-parallel solve may use.
    pub solver_threads: usize,
    /// Audit mode: certify every k-th successfully served job (by job id)
    /// post-solve and fold pass/fail + gap histograms into the metrics
    /// ([`Metrics::record_audit`]). `0` disables auditing; `1` certifies
    /// every job. Cancelled solves are exempt (they carry no guarantee).
    pub audit_sample_every: u64,
    /// Per-tenant default deadline applied to every job; a job's
    /// effective deadline is the tighter of this and its own request
    /// budget. `None` leaves budget-less jobs deadline-free.
    pub default_deadline: Option<Duration>,
    /// Transient-failure retry budget per job (extra attempts beyond the
    /// first; `0` fails on the first transient).
    pub max_retries: u32,
    /// Base backoff before a retry re-enters the dispatcher; doubles per
    /// attempt with deterministic per-job jitter.
    pub retry_backoff: Duration,
    /// Worker respawns allowed **per shard** across its lifetime; once
    /// exhausted, that shard's workers stay dead and its shape's queued
    /// jobs fail terminally rather than hang — other shards keep serving.
    pub restart_budget: u32,
    pub degrade: DegradePolicy,
    /// Deterministic fault injection (tests and chaos runs); `None`
    /// injects nothing.
    pub faults: Option<Arc<FaultPlan>>,
    /// Most shape-keyed shards alive at once; routing a new shape beyond
    /// this evicts the least-recently-used live shard (its in-flight
    /// batches drain first).
    pub max_shards: usize,
    /// A shard with no traffic for this long (and an empty batcher) is
    /// reaped; its shape respawns a fresh shard on the next job.
    pub shard_idle_ttl: Duration,
    /// Byte budget for the `(problem digest, ε, engine)` result cache;
    /// `0` disables caching entirely (the default — identical payloads
    /// are rare outside serving workloads, and the digest pass is O(n²)
    /// for dense problems).
    pub cache_bytes: u64,
    /// Named tenant quotas; see [`TenantQuota`].
    pub tenants: Vec<(String, TenantQuota)>,
    /// Quota for anonymous (`SolveRequest::tenant == None`) and unknown
    /// tenants. Permissive by default.
    pub default_quota: TenantQuota,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 64,
            batcher: BatcherConfig::default(),
            solver_threads: pool::default_threads(),
            audit_sample_every: 0,
            default_deadline: None,
            max_retries: 2,
            retry_backoff: Duration::from_millis(5),
            restart_budget: 4,
            degrade: DegradePolicy::default(),
            faults: None,
            max_shards: 8,
            shard_idle_ttl: Duration::from_secs(30),
            cache_bytes: 0,
            tenants: Vec::new(),
            default_quota: TenantQuota::default(),
        }
    }
}

/// Live admission accounting for one tenant.
struct TenantState {
    name: String,
    quota: TenantQuota,
    /// Admitted, not yet terminal.
    in_flight: AtomicU64,
    /// Admitted, not yet picked up by a shard worker.
    queued: AtomicU64,
}

impl TenantState {
    fn new(name: String, quota: TenantQuota) -> Self {
        Self { name, quota, in_flight: AtomicU64::new(0), queued: AtomicU64::new(0) }
    }

    fn saturated(&self) -> bool {
        self.in_flight.load(Ordering::Relaxed) >= self.quota.max_in_flight as u64
            || self.queued.load(Ordering::Relaxed) >= self.quota.max_queue_depth as u64
    }
}

fn saturating_dec(counter: &AtomicU64) {
    // Saturating: a stray double-decrement must not wrap the gauge.
    let _ = counter.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| d.checked_sub(1));
}

/// Drop-guard riding each admitted job through every path — dispatch,
/// retry, shed, fail, panic recovery — so the tenant's `in_flight` (and,
/// until worker pickup, `queued`) gauges release on exactly one terminal
/// outcome no matter where it happens.
struct TenantSlot {
    state: Arc<TenantState>,
    picked: bool,
}

impl TenantSlot {
    fn admit(state: Arc<TenantState>) -> Self {
        state.in_flight.fetch_add(1, Ordering::Relaxed);
        state.queued.fetch_add(1, Ordering::Relaxed);
        Self { state, picked: false }
    }

    /// A shard worker took the job off the queue (it may still retry).
    fn mark_picked(&mut self) {
        if !self.picked {
            self.picked = true;
            saturating_dec(&self.state.queued);
        }
    }
}

impl Drop for TenantSlot {
    fn drop(&mut self) {
        if !self.picked {
            saturating_dec(&self.state.queued);
        }
        saturating_dec(&self.state.in_flight);
    }
}

struct Envelope {
    req: JobRequest,
    engine: Engine,
    submitted: Instant,
    /// 0 on first execution; retries re-enter with `attempt + 1`.
    attempt: u32,
    /// Effective deadline resolved at submit (budget ∧ tenant default ∧
    /// coordinator default).
    deadline: Option<Instant>,
    /// Whether an expired deadline sheds the job pre-solve. True when any
    /// default (tenant or coordinator) contributed to the deadline; a job
    /// deadlined only by its own request budget keeps the legacy
    /// run-and-return-cancelled semantics on its first attempt.
    shed_on_expiry: bool,
    /// Result-cache key computed at admission (None: cache disabled or
    /// the payload is uncacheable). A clean `Served` outcome stores under
    /// it.
    cache_key: Option<CacheKey>,
    /// Tenant quota accounting guard; released on the terminal outcome.
    slot: Option<TenantSlot>,
    reply: Sender<JobOutcome>,
}

/// Awaitable handle for one submitted job.
pub struct JobHandle {
    pub id: u64,
    rx: Receiver<JobOutcome>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobOutcome> {
        self.rx
            .recv()
            .map_err(|_| OtprError::Coordinator("worker dropped the job".into()))
    }

    pub fn wait_timeout(&self, d: Duration) -> Option<JobOutcome> {
        self.rx.recv_timeout(d).ok()
    }
}

enum DispatchMsg {
    Job(Envelope),
    Shutdown,
}

pub struct Coordinator {
    tx: SyncSender<DispatchMsg>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    default_deadline: Option<Duration>,
    retry_backoff: Duration,
    router: Arc<Router>,
    cache: Arc<ResultCache>,
    tenants: HashMap<String, Arc<TenantState>>,
    default_tenant: Arc<TenantState>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn start(config: CoordinatorConfig, runtime: Option<Arc<XlaRuntime>>) -> Self {
        let metrics = Arc::new(Metrics::new());
        let router = Arc::new(Router::new(runtime, config.solver_threads));
        let cache = Arc::new(ResultCache::new(config.cache_bytes));
        let (tx, dispatch_rx) = sync_channel::<DispatchMsg>(config.queue_capacity);
        // retry path: workers -> dispatcher, unbounded so a worker can
        // never deadlock against a full dispatcher
        let (retry_tx, retry_rx) = channel::<(Instant, Envelope)>();

        let tenants: HashMap<String, Arc<TenantState>> = config
            .tenants
            .iter()
            .map(|(name, quota)| {
                (name.clone(), Arc::new(TenantState::new(name.clone(), quota.clone())))
            })
            .collect();
        let default_tenant =
            Arc::new(TenantState::new("anonymous".to_string(), config.default_quota.clone()));

        let host = ShardHost {
            metrics: metrics.clone(),
            router: router.clone(),
            cache: cache.clone(),
            batcher_cfg: config.batcher.clone(),
            queue_capacity: config.queue_capacity,
            workers: config.workers.max(1),
            restart_budget: config.restart_budget,
            max_shards: config.max_shards.max(1),
            idle_ttl: config.shard_idle_ttl,
            retry_backoff: config.retry_backoff,
            audit_every: config.audit_sample_every,
            max_retries: config.max_retries,
            degrade: config.degrade.clone(),
            faults: config.faults.clone(),
            retry_tx,
        };
        let dispatcher =
            std::thread::spawn(move || dispatcher_loop(dispatch_rx, retry_rx, host));

        Self {
            tx,
            metrics,
            next_id: AtomicU64::new(1),
            default_deadline: config.default_deadline,
            retry_backoff: config.retry_backoff,
            router,
            cache,
            tenants,
            default_tenant,
            dispatcher: Some(dispatcher),
        }
    }

    /// Submit a job at accuracy `eps` with default request settings;
    /// blocks when the queue is at capacity (backpressure).
    pub fn submit(&self, kind: JobKind, eps: f64, engine: Engine) -> Result<JobHandle> {
        self.submit_request(kind, SolveRequest::new(eps), engine)
    }

    /// The tenant a request bills to (named, or the anonymous default for
    /// `None` and unknown names).
    fn tenant_for(&self, request: &SolveRequest) -> Arc<TenantState> {
        request
            .tenant
            .as_ref()
            .and_then(|name| self.tenants.get(name))
            .unwrap_or(&self.default_tenant)
            .clone()
    }

    /// Deadline default for `tenant`: the tighter of its quota's
    /// `default_deadline` and the coordinator-wide one.
    fn deadline_default(&self, tenant: &TenantState) -> Option<Duration> {
        match (tenant.quota.default_deadline, self.default_deadline) {
            (Some(t), Some(g)) => Some(t.min(g)),
            (Some(t), None) => Some(t),
            (None, g) => g,
        }
    }

    /// Build the envelope + handle for one job, resolving the tenant
    /// deadline and the cache key. Does NOT touch quota gauges.
    fn make_envelope(
        &self,
        kind: JobKind,
        request: SolveRequest,
        engine: Engine,
        tenant: &Arc<TenantState>,
    ) -> (Envelope, JobHandle) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let submitted = Instant::now();
        let default = self.deadline_default(tenant);
        let deadline = request.effective_deadline(submitted, default);
        let req = JobRequest { id, kind, request, engine };
        let cache_key = self.cache_key_for(&req);
        let env = Envelope {
            req,
            engine,
            submitted,
            attempt: 0,
            deadline,
            shed_on_expiry: default.is_some(),
            cache_key,
            slot: None,
            reply: reply_tx,
        };
        (env, JobHandle { id, rx: reply_rx })
    }

    /// The result-cache key for this job, or `None` when the cache is
    /// disabled or the payload is uncacheable (generated costs).
    fn cache_key_for(&self, req: &JobRequest) -> Option<CacheKey> {
        if !self.cache.enabled() {
            return None;
        }
        let digest = problem_digest(&req.kind)?;
        let resolved = self.router.resolve(req);
        Some(CacheKey {
            digest,
            eps_bits: req.request.eps.to_bits(),
            raw_eps: req.request.eps_semantics == EpsSemantics::AlgorithmParam,
            engine: resolved.key(),
            want_certificate: req.request.want_certificate,
        })
    }

    /// Check the result cache; on a hit, reply through the envelope
    /// immediately (bypassing dispatch entirely) and hand back the
    /// handle. The stored answer is byte-identical to the fresh solve
    /// that populated it.
    fn try_cache_hit(&self, env: &Envelope, handle: JobHandle) -> std::result::Result<JobHandle, JobHandle> {
        let Some(key) = &env.cache_key else { return Err(handle) };
        let Some(sol) = self.cache.get(key) else {
            self.metrics.record_cache_miss();
            return Err(handle);
        };
        self.metrics.record_submit();
        self.metrics.record_cache_hit();
        self.metrics.record_done(key.engine, true, 0.0, 0.0);
        send_outcome(
            &self.metrics,
            &env.reply,
            JobOutcome {
                id: env.req.id,
                engine_used: key.engine,
                status: JobStatus::Served,
                result: Ok(sol),
                queued_secs: 0.0,
                solve_secs: 0.0,
            },
        );
        Ok(handle)
    }

    /// Submit a job with a full [`SolveRequest`] — wall-clock budget,
    /// cancellation token, and progress observer are honored by the
    /// executing engine; progress additionally feeds the coordinator's
    /// per-engine phase metrics. The job's effective deadline is resolved
    /// here: the tightest of the request budget, the tenant's
    /// [`TenantQuota::default_deadline`], and the coordinator's
    /// [`CoordinatorConfig::default_deadline`]. Blocks when the dispatch
    /// queue is at capacity; use [`Coordinator::admit`] for the
    /// non-blocking quota-checked front door.
    pub fn submit_request(
        &self,
        kind: JobKind,
        request: SolveRequest,
        engine: Engine,
    ) -> Result<JobHandle> {
        let tenant = self.tenant_for(&request);
        let (mut env, handle) = self.make_envelope(kind, request, engine, &tenant);
        let handle = match self.try_cache_hit(&env, handle) {
            Ok(handle) => return Ok(handle),
            Err(handle) => handle,
        };
        env.slot = Some(TenantSlot::admit(tenant));
        self.metrics.record_submit();
        self.tx.send(DispatchMsg::Job(env)).map_err(|_| {
            self.metrics.record_reject();
            OtprError::Coordinator("coordinator is shut down".into())
        })?;
        Ok(handle)
    }

    /// Non-blocking admission: answer [`Admission::Backpressure`] (with a
    /// `retry_after` hint) instead of blocking when the tenant's
    /// [`TenantQuota`] or the dispatch queue is saturated. Cache hits
    /// bypass both — a stored answer costs nothing to serve.
    pub fn admit(
        &self,
        kind: JobKind,
        request: SolveRequest,
        engine: Engine,
    ) -> Result<Admission> {
        let tenant = self.tenant_for(&request);
        let (mut env, handle) = self.make_envelope(kind, request, engine, &tenant);
        let handle = match self.try_cache_hit(&env, handle) {
            Ok(handle) => return Ok(Admission::Accepted(handle)),
            Err(handle) => handle,
        };
        if tenant.saturated() {
            self.metrics.record_backpressure(&tenant.name);
            return Ok(Admission::Backpressure { retry_after: self.retry_backoff });
        }
        let tenant_name = tenant.name.clone();
        self.metrics.record_admitted(&tenant_name);
        env.slot = Some(TenantSlot::admit(tenant));
        self.metrics.record_submit();
        match self.tx.try_send(DispatchMsg::Job(env)) {
            Ok(()) => Ok(Admission::Accepted(handle)),
            Err(TrySendError::Full(msg)) => {
                // Roll back: the job never entered the queue; dropping
                // the returned envelope releases its tenant slot.
                drop(msg);
                self.metrics.record_reject();
                self.metrics.record_backpressure(&tenant_name);
                Ok(Admission::Backpressure { retry_after: self.retry_backoff })
            }
            Err(TrySendError::Disconnected(_)) => {
                self.metrics.record_reject();
                Err(OtprError::Coordinator("coordinator is shut down".into()))
            }
        }
    }

    /// Graceful shutdown: flush batches, join shard pools. Retries still
    /// in backoff at this point resolve terminally (Failed) — shutdown
    /// never waits out a backoff timer and never leaves a handle hanging.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

/// Reply to the job's handle; a receiver dropped without `wait()` is
/// counted as an abandoned job (the outcome had nowhere to land).
fn send_outcome(metrics: &Metrics, reply: &Sender<JobOutcome>, outcome: JobOutcome) {
    if reply.send(outcome).is_err() {
        metrics.record_abandoned();
    }
}

/// Terminal failure for a job that never got (or kept) a worker.
fn fail_env(metrics: &Metrics, env: Envelope, msg: &str) {
    let queued = env.submitted.elapsed().as_secs_f64();
    metrics.record_done(env.engine.name(), false, queued, 0.0);
    send_outcome(
        metrics,
        &env.reply,
        JobOutcome {
            id: env.req.id,
            engine_used: env.engine.name(),
            status: JobStatus::Failed { attempts: env.attempt },
            result: Err(msg.to_string()),
            queued_secs: queued,
            solve_secs: 0.0,
        },
    );
}

/// Shed a job whose deadline passed before it could be solved.
fn shed_env(metrics: &Metrics, env: Envelope, retry_after: Duration) {
    metrics.record_shed();
    let queued = env.submitted.elapsed().as_secs_f64();
    send_outcome(
        metrics,
        &env.reply,
        JobOutcome {
            id: env.req.id,
            engine_used: env.engine.name(),
            status: JobStatus::Shed { retry_after },
            result: Err(format!(
                "shed: deadline passed before solving; retry after {}ms",
                retry_after.as_millis()
            )),
            queued_secs: queued,
            solve_secs: 0.0,
        },
    );
}

/// Transient failures are worth retrying: worker death mid-batch,
/// injected transients, arena-reuse epoch mismatches. Anything else
/// (unknown engine, unsupported problem kind, missing runtime) is
/// deterministic and fails fast.
fn is_transient(msg: &str) -> bool {
    msg.contains("transient") || msg.contains("panic") || msg.contains("epoch mismatch")
}

/// Exponential backoff with deterministic per-(job, attempt) jitter in
/// [0.75, 1.25)× so a batch of retried siblings doesn't re-collide.
fn backoff_jitter(base: Duration, id: u64, attempt: u32) -> Duration {
    let exp = base.saturating_mul(1u32 << attempt.min(10));
    let mut mix = SplitMix64::new(id ^ (u64::from(attempt) << 32));
    let frac = (mix.next_u64() % 512) as f64 / 1024.0;
    exp.mul_f64(0.75 + frac)
}

/// Human/metrics label for a batch key: `engine` or `engine/bucket`.
fn key_label(key: &crate::coordinator::batcher::BatchKey) -> String {
    match key.1 {
        Some(bucket) => format!("{}/{bucket}", key.0),
        None => key.0.to_string(),
    }
}

/// Terminal error for jobs whose shard (or would-be shard) has no
/// workers left.
const POOL_EXHAUSTED: &str = "worker pool exhausted; job was not executed";

/// Everything the dispatcher needs to spawn and run shape-keyed shards.
struct ShardHost {
    metrics: Arc<Metrics>,
    router: Arc<Router>,
    cache: Arc<ResultCache>,
    batcher_cfg: BatcherConfig,
    queue_capacity: usize,
    workers: usize,
    restart_budget: u32,
    max_shards: usize,
    idle_ttl: Duration,
    retry_backoff: Duration,
    audit_every: u64,
    max_retries: u32,
    degrade: DegradePolicy,
    faults: Option<Arc<FaultPlan>>,
    retry_tx: Sender<(Instant, Envelope)>,
}

/// One shape-keyed worker pool: its own batcher, batch channel, and
/// supervised workers whose pinned kernel solvers hold this shape's warm
/// arena across batches.
struct Shard {
    key: (u8, usize, usize),
    label: String,
    batcher: Batcher<Envelope>,
    batch_tx: Option<SyncSender<Vec<Envelope>>>,
    supervisor: Option<std::thread::JoinHandle<()>>,
    last_used: Instant,
    /// Restart budget exhausted: this shape fails fast; the tombstone is
    /// never evicted (a fresh shard would silently resurrect the shape).
    dead: bool,
}

impl Shard {
    /// Close one batch toward this shard's workers. When every worker is
    /// gone (restart budget exhausted) the send fails, the batch's jobs
    /// fail terminally, and the shard goes dead — queued work must never
    /// hang on a dead pool, and sibling shards keep serving.
    fn close(&mut self, batch: crate::coordinator::batcher::Batch<Envelope>, metrics: &Metrics) {
        metrics.record_batch(
            &key_label(&batch.key),
            batch.jobs.len(),
            batch.wait().as_micros() as u64,
        );
        metrics.record_shard_batch(&self.label, batch.jobs.len());
        let sent = match &self.batch_tx {
            Some(tx) => tx.send(batch.jobs).map_err(|std::sync::mpsc::SendError(jobs)| jobs),
            None => Err(batch.jobs),
        };
        if let Err(jobs) = sent {
            self.dead = true;
            self.batch_tx = None;
            if let Some(s) = self.supervisor.take() {
                let _ = s.join();
            }
            for env in jobs {
                fail_env(metrics, env, POOL_EXHAUSTED);
            }
        }
    }

    /// Flush and wind down: close open batches toward the workers, drop
    /// the channel so they exit after draining, and join the pool. Jobs
    /// already inside the workers complete normally first.
    fn retire(mut self, metrics: &Metrics) {
        let open = self.batcher.drain_all();
        for batch in open {
            self.close(batch, metrics);
        }
        self.batch_tx = None;
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
    }
}

/// Human label for a shape key, e.g. `asg/16x16` — the `/metrics` shard
/// identifier.
fn shard_label(key: &(u8, usize, usize)) -> String {
    let kind = match key.0 {
        0 => "asg",
        1 => "ot",
        2 => "imp-asg",
        _ => "imp-ot",
    };
    format!("{kind}/{}x{}", key.1, key.2)
}

/// Spawn a fresh shard for `key`: its own bounded batch channel and a
/// supervised worker pool whose context carries the shard label.
fn spawn_shard(host: &ShardHost, key: (u8, usize, usize)) -> Shard {
    let label = shard_label(&key);
    let (batch_tx, batch_rx) = sync_channel::<Vec<Envelope>>(host.queue_capacity);
    let batch_rx = Arc::new(Mutex::new(batch_rx));
    let ctx = Arc::new(WorkerCtx {
        router: host.router.clone(),
        metrics: host.metrics.clone(),
        cache: host.cache.clone(),
        audit_every: host.audit_every,
        max_retries: host.max_retries,
        retry_backoff: host.retry_backoff,
        degrade: host.degrade.clone(),
        faults: host.faults.clone(),
        retry_tx: host.retry_tx.clone(),
        shard: label.clone(),
    });
    let (workers, restart_budget) = (host.workers, host.restart_budget);
    let supervisor =
        std::thread::spawn(move || supervisor_loop(batch_rx, ctx, workers, restart_budget));
    host.metrics.record_shard_spawn(&label);
    Shard {
        key,
        label,
        batcher: Batcher::new(host.batcher_cfg.clone()),
        batch_tx: Some(batch_tx),
        supervisor: Some(supervisor),
        last_used: Instant::now(),
        dead: false,
    }
}

/// Route one job to its shape's shard — shedding expired
/// defaults-deadlined jobs first, spawning the shard lazily, and
/// LRU-evicting a live shard when `max_shards` is reached. Dead shards
/// fail their shape's jobs fast without touching siblings.
fn route_job(shards: &mut Vec<Shard>, host: &ShardHost, mut env: Envelope) {
    if (env.shed_on_expiry || env.attempt > 0) && env.deadline.is_some_and(|d| d <= Instant::now())
    {
        shed_env(&host.metrics, env, host.retry_backoff);
        return;
    }
    // Resolve Auto and the artifact bucket here, once, so the batch key
    // is final and workers never re-route.
    let engine = host.router.resolve(&env.req);
    if env.req.engine == Engine::Auto && env.attempt == 0 {
        host.metrics.record_auto_route(engine.name());
    }
    env.engine = engine;
    let bkey = (engine.name(), host.router.bucket(&env.req, engine));
    let shape = shape_key(&env.req);
    let idx = match shards.iter().position(|s| s.key == shape) {
        Some(i) => i,
        None => {
            if shards.len() >= host.max_shards {
                let lru = shards
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| !s.dead)
                    .min_by_key(|(_, s)| s.last_used)
                    .map(|(i, _)| i);
                match lru {
                    Some(i) => {
                        let evicted = shards.remove(i);
                        host.metrics.record_shard_reap(&evicted.label);
                        evicted.retire(&host.metrics);
                    }
                    // Every slot is a dead tombstone: nothing left to run
                    // this shape on.
                    None => {
                        fail_env(&host.metrics, env, POOL_EXHAUSTED);
                        return;
                    }
                }
            }
            shards.push(spawn_shard(host, shape));
            shards.len() - 1
        }
    };
    let shard = &mut shards[idx];
    shard.last_used = Instant::now();
    if shard.dead {
        fail_env(&host.metrics, env, POOL_EXHAUSTED);
        return;
    }
    if env.attempt > 0 {
        // A retry already paid its accumulation wait once — close it
        // (plus any same-key waiters) toward the pool immediately.
        let batch = shard.batcher.push_now(bkey, env);
        shard.close(batch, &host.metrics);
    } else if let Some(batch) = shard.batcher.push(bkey, env) {
        shard.close(batch, &host.metrics);
    }
    host.metrics.set_shard_pending(&shard.label, shard.batcher.pending() as u64);
}

fn dispatcher_loop(
    rx: Receiver<DispatchMsg>,
    retry_rx: Receiver<(Instant, Envelope)>,
    host: ShardHost,
) {
    let mut shards: Vec<Shard> = Vec::new();
    // Retries waiting out their backoff; folded into the poll timeout.
    let mut pending: Vec<(Instant, Envelope)> = Vec::new();

    let drain_retry_rx = |pending: &mut Vec<(Instant, Envelope)>| {
        while let Ok(item) = retry_rx.try_recv() {
            pending.push(item);
        }
    };

    // Wind down every shard (joining their pools), then fail retries
    // still in backoff — shutdown never waits out a backoff timer and
    // never leaves a handle hanging. Workers may emit retries while their
    // final batches drain, so the retry queue is drained *after* the
    // joins.
    let wind_down = |shards: &mut Vec<Shard>, pending: &mut Vec<(Instant, Envelope)>, msg: &str| {
        for shard in shards.drain(..) {
            shard.retire(&host.metrics);
        }
        while let Ok(item) = retry_rx.try_recv() {
            pending.push(item);
        }
        for (_, env) in pending.drain(..) {
            fail_env(&host.metrics, env, msg);
        }
    };

    loop {
        drain_retry_rx(&mut pending);
        // Release retries whose backoff elapsed (route_job sheds the ones
        // whose deadline expired while backing off).
        let now = Instant::now();
        let mut i = 0;
        while i < pending.len() {
            if pending[i].0 <= now {
                let (_, env) = pending.swap_remove(i);
                route_job(&mut shards, &host, env);
            } else {
                i += 1;
            }
        }
        let next_retry = pending.iter().map(|(due, _)| *due).min();
        let next_batch =
            shards.iter().filter(|s| !s.dead).filter_map(|s| s.batcher.next_deadline()).min();
        let next_reap =
            shards.iter().filter(|s| !s.dead).map(|s| s.last_used + host.idle_ttl).min();
        let timeout = [next_batch, next_retry, next_reap]
            .into_iter()
            .flatten()
            .min()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50))
            .min(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(DispatchMsg::Job(env)) => route_job(&mut shards, &host, env),
            Ok(DispatchMsg::Shutdown) => {
                wind_down(
                    &mut shards,
                    &mut pending,
                    "coordinator shut down before the retry could run",
                );
                return;
            }
            Err(RecvTimeoutError::Timeout) => {
                for shard in shards.iter_mut() {
                    let expired = shard.batcher.drain_expired();
                    for batch in expired {
                        shard.close(batch, &host.metrics);
                    }
                    host.metrics
                        .set_shard_pending(&shard.label, shard.batcher.pending() as u64);
                }
                // Reap shards idle past the TTL (nothing accumulating); a
                // reaped shard's shape respawns fresh on its next job.
                let mut i = 0;
                while i < shards.len() {
                    let idle = !shards[i].dead
                        && shards[i].batcher.pending() == 0
                        && shards[i].last_used.elapsed() >= host.idle_ttl;
                    if idle {
                        let shard = shards.remove(i);
                        host.metrics.record_shard_reap(&shard.label);
                        shard.retire(&host.metrics);
                    } else {
                        i += 1;
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                wind_down(
                    &mut shards,
                    &mut pending,
                    "coordinator dropped before the retry could run",
                );
                return;
            }
        }
    }
}

/// Base pause before respawning a panicked worker; doubles per restart
/// (capped) so a crash-looping batch cannot spin the supervisor.
const RESTART_BACKOFF: Duration = Duration::from_millis(2);
const RESTART_BACKOFF_CAP: Duration = Duration::from_millis(250);

/// Owns the worker pool: spawns the initial workers, collects their exit
/// events, and respawns panicked ones under the restart budget. Holds the
/// last clone of the batch receiver, so when the supervisor returns (all
/// slots empty) the dispatcher's sends start failing and queued jobs
/// resolve terminally instead of hanging.
fn supervisor_loop(
    rx: Arc<Mutex<Receiver<Vec<Envelope>>>>,
    ctx: Arc<WorkerCtx>,
    workers: usize,
    restart_budget: u32,
) {
    let (event_tx, event_rx) = channel::<bool>();
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let spawn_worker = |handles: &mut Vec<std::thread::JoinHandle<()>>| {
        let rx = rx.clone();
        let ctx = ctx.clone();
        let tx = event_tx.clone();
        handles.push(std::thread::spawn(move || {
            let panicked = worker_loop(rx, ctx);
            let _ = tx.send(panicked);
        }));
    };
    for _ in 0..workers {
        spawn_worker(&mut handles);
    }
    let mut live = workers;
    let mut restarts = 0u32;
    while live > 0 {
        // Every live worker sends exactly one exit event, so this recv
        // cannot block past the pool's lifetime.
        let Ok(panicked) = event_rx.recv() else { break };
        if panicked && restarts < restart_budget {
            let backoff =
                RESTART_BACKOFF.saturating_mul(1u32 << restarts.min(7)).min(RESTART_BACKOFF_CAP);
            std::thread::sleep(backoff);
            restarts += 1;
            ctx.metrics.record_worker_restart();
            spawn_worker(&mut handles);
        } else {
            // Clean exit (channel closed at shutdown) or restart budget
            // exhausted: the slot stays empty.
            live -= 1;
        }
    }
    for h in handles {
        let _ = h.join();
    }
}

/// Everything a worker needs besides the batch receiver.
struct WorkerCtx {
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    cache: Arc<ResultCache>,
    audit_every: u64,
    max_retries: u32,
    retry_backoff: Duration,
    degrade: DegradePolicy,
    faults: Option<Arc<FaultPlan>>,
    retry_tx: Sender<(Instant, Envelope)>,
    /// The owning shard's label (metrics attribution).
    shard: String,
}

/// One job being processed by a worker. `reply` is taken exactly when a
/// terminal outcome (or a retry hand-off) happens — after a caught panic,
/// any job still holding its reply is known to be unresolved.
struct Prepared {
    req: JobRequest,
    engine: Engine,
    submitted: Instant,
    attempt: u32,
    deadline: Option<Instant>,
    /// See [`Envelope::shed_on_expiry`].
    shed_on_expiry: bool,
    cache_key: Option<CacheKey>,
    /// Rides to the terminal outcome; dropped (releasing the tenant's
    /// in-flight gauge) after the reply is out, or moved back into the
    /// retry envelope.
    slot: Option<TenantSlot>,
    reply: Option<Sender<JobOutcome>>,
    phase_count: Arc<AtomicU64>,
}

/// Queue time + a per-job phase counter teed into the request's observer
/// chain (folded into the metrics lock once per job, not per phase)
/// without disturbing any caller-supplied observer.
fn prepare(batch: Vec<Envelope>) -> Vec<Prepared> {
    batch
        .into_iter()
        .map(|env| {
            let mut req = env.req;
            let phase_count = Arc::new(AtomicU64::new(0));
            let counter = phase_count.clone();
            req.request = req.request.chain_observer(move |_p| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
            let mut slot = env.slot;
            if let Some(slot) = slot.as_mut() {
                slot.mark_picked();
            }
            Prepared {
                req,
                engine: env.engine,
                submitted: env.submitted,
                attempt: env.attempt,
                deadline: env.deadline,
                shed_on_expiry: env.shed_on_expiry,
                cache_key: env.cache_key,
                slot,
                reply: Some(env.reply),
                phase_count,
            }
        })
        .collect()
}

/// Returns `true` when the worker is exiting because it caught a panic
/// (the supervisor then decides about a respawn); `false` on clean
/// shutdown (batch channel closed).
fn worker_loop(rx: Arc<Mutex<Receiver<Vec<Envelope>>>>, ctx: Arc<WorkerCtx>) -> bool {
    // This worker's pinned kernel solvers: the shard serves one problem
    // shape, so the arena inside stays the right size and every batch
    // after the first reuses it (the warm-affinity tentpole).
    let mut pinned = PinnedSolvers::default();
    loop {
        let batch = {
            // A poisoned receiver lock means a sibling worker panicked
            // mid-recv; the channel itself is still sound, so keep draining
            // rather than wedging the whole worker pool.
            let guard = rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            guard.recv()
        };
        let Ok(batch) = batch else { return false };
        let mut jobs = prepare(batch);
        // The whole batch runs supervised: a panic (solver bug or injected
        // fault) unwinds to here instead of killing the process, and only
        // this batch's unresolved jobs are affected.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            process_batch(&mut jobs, &mut pinned, &ctx);
        }));
        if caught.is_err() {
            ctx.metrics.record_worker_panic();
            // The pinned arena's state is unspecified mid-solve; a cold
            // rebuild is always correct.
            pinned.clear();
            // Jobs still holding their reply never reached a terminal
            // outcome — requeue (or fail) each, then exit and let the
            // supervisor decide whether this worker is replaced.
            for job in jobs {
                if job.reply.is_some() {
                    retry_or_fail(&ctx, job, "transient: worker panicked over this batch");
                }
            }
            return true;
        }
    }
}

/// Requeue a transient casualty through the dispatcher with backoff, or
/// fail it terminally when the retry budget (or the dispatcher) is gone.
fn retry_or_fail(ctx: &WorkerCtx, mut job: Prepared, reason: &str) {
    let Some(reply) = job.reply.take() else { return };
    let queued = job.submitted.elapsed().as_secs_f64();
    if is_transient(reason) && job.attempt < ctx.max_retries {
        ctx.metrics.record_retry();
        let due = Instant::now() + backoff_jitter(ctx.retry_backoff, job.req.id, job.attempt);
        let env = Envelope {
            req: job.req,
            engine: job.engine,
            submitted: job.submitted,
            attempt: job.attempt + 1,
            deadline: job.deadline,
            shed_on_expiry: job.shed_on_expiry,
            cache_key: job.cache_key.take(),
            slot: job.slot.take(),
            reply,
        };
        match ctx.retry_tx.send((due, env)) {
            Ok(()) => return,
            Err(std::sync::mpsc::SendError((_, env))) => {
                fail_env(&ctx.metrics, env, reason);
                return;
            }
        }
    }
    ctx.metrics.record_done(job.engine.name(), false, queued, 0.0);
    send_outcome(
        &ctx.metrics,
        &reply,
        JobOutcome {
            id: job.req.id,
            engine_used: job.engine.name(),
            status: JobStatus::Failed { attempts: job.attempt + 1 },
            result: Err(reason.to_string()),
            queued_secs: queued,
            solve_secs: 0.0,
        },
    );
}

/// Shed one prepared job whose deadline passed at pickup.
fn shed_prepared(ctx: &WorkerCtx, mut job: Prepared) {
    let Some(reply) = job.reply.take() else { return };
    ctx.metrics.record_shed();
    send_outcome(
        &ctx.metrics,
        &reply,
        JobOutcome {
            id: job.req.id,
            engine_used: job.engine.name(),
            status: JobStatus::Shed { retry_after: ctx.retry_backoff },
            result: Err(format!(
                "shed: deadline passed before solving; retry after {}ms",
                ctx.retry_backoff.as_millis()
            )),
            queued_secs: job.submitted.elapsed().as_secs_f64(),
            solve_secs: 0.0,
        },
    );
}

/// Shape key for shard routing and intra-batch grouping: jobs that can
/// share one kernel arena (same problem kind and cost dimensions).
/// Implicit (provider-backed) jobs group separately from dense ones —
/// the payloads are O(n), and mixing storage modes in one warm-carry run
/// buys nothing. Each distinct key gets its own dispatch shard, so a
/// shard worker's pinned arena always fits the next batch.
pub fn shape_key(req: &JobRequest) -> (u8, usize, usize) {
    let (nb, na) = req.kind.dims();
    match &req.kind {
        crate::api::Problem::Assignment(_) => (0, nb, na),
        crate::api::Problem::Ot(_) => (1, nb, na),
        crate::api::Problem::Implicit(i) if i.masses.is_none() => (2, nb, na),
        crate::api::Problem::Implicit(_) => (3, nb, na),
    }
}

/// The overall-semantics ε a degraded answer's certificate verifies
/// against, from the raw ladder parameter `p` it stopped at: the core
/// runs matchings at ε/3 of the overall target, and OT plans certify at
/// 6× the matching quantization (see `core::certify::degraded_request`).
fn degraded_overall_eps(sol: &Solution, p: f64) -> f64 {
    match &sol.coupling {
        Coupling::Matching(_) => 3.0 * p,
        Coupling::Plan(_) => 6.0 * p,
    }
}

/// Decide the terminal status of a successful solve, re-solving at a
/// coarser ε when deadline pressure cancelled it and the policy allows.
fn disposition_ok(ctx: &WorkerCtx, job: &Prepared, sol: Solution) -> (Solution, JobStatus) {
    if let Some(p) = sol.degraded_eps_param() {
        // The warm ladder already degraded (mechanism A): attach the
        // certificate the status promises and report the overall ε it
        // verifies against.
        ctx.metrics.record_degraded();
        let mut sol = sol;
        if sol.certificate.is_none() {
            sol.certificate =
                Some(crate::core::certify::certify(&job.req.kind, &sol, &job.req.request));
        }
        let eps = degraded_overall_eps(&sol, p);
        return (sol, JobStatus::Degraded { eps });
    }
    if sol.is_cancelled()
        && ctx.degrade.enabled
        && job.deadline.is_some()
        && !job.req.request.cancel.is_cancelled()
    {
        // The deadline — not the caller's token — cancelled a ladder-less
        // solve: trade accuracy for an answer (mechanism B).
        return resolve_degraded(ctx, job, sol);
    }
    (sol, JobStatus::Served)
}

/// Mechanism B: re-solve at geometrically coarser ε on the engine's warm
/// variant under the grace budget, asking the registry to attach a
/// certificate. Falls back to the partial (lazy-product / arbitrary-
/// completion) answer with an honest certificate when grace runs out.
fn resolve_degraded(ctx: &WorkerCtx, job: &Prepared, partial: Solution) -> (Solution, JobStatus) {
    let engine = warm_variant(job.engine);
    let mut eps = job.req.request.eps;
    for _ in 0..ctx.degrade.max_steps {
        eps *= ctx.degrade.eps_factor;
        let mut request = job.req.request.clone();
        request.eps = eps;
        request.budget = Some(ctx.degrade.grace);
        request.want_certificate = true;
        request.degrade_on_deadline = false;
        let retry = JobRequest { id: job.req.id, kind: job.req.kind.clone(), request, engine };
        if let Ok(sol) = ctx.router.execute(&retry, engine) {
            if !sol.is_cancelled() {
                ctx.metrics.record_degraded();
                return (sol, JobStatus::Degraded { eps });
            }
        }
    }
    ctx.metrics.record_degraded();
    let mut sol = partial;
    if sol.certificate.is_none() {
        sol.certificate =
            Some(crate::core::certify::certify(&job.req.kind, &sol, &job.req.request));
    }
    // No accuracy claim survives — the certificate reports what holds.
    let eps = f64::INFINITY;
    (sol, JobStatus::Degraded { eps })
}

/// Execute one batch: disposal pass (pickup-deadline shed, injected
/// faults, budget clamping), then shape-grouped solves with per-job
/// terminal dispositions. Runs entirely inside the worker's supervised
/// (`catch_unwind`) region; `pinned` carries the worker's warm kernel
/// solvers across batches.
fn process_batch(jobs: &mut Vec<Prepared>, pinned: &mut PinnedSolvers, ctx: &WorkerCtx) {
    // Disposal pass. Order matters: an injected panic fires before the
    // job could be shed or failed, exactly like a real solver panic.
    let mut i = 0;
    while i < jobs.len() {
        let now = Instant::now();
        let id = jobs[i].req.id;
        let attempt = jobs[i].attempt;
        let fault = ctx.faults.as_ref().and_then(|p| p.lookup(id, attempt));
        match fault {
            Some(Fault::Panic) => {
                // panic-ok: deterministic fault injection — supervision
                // must observe a real unwind exactly where a solver panic
                // would fire.
                panic!("injected fault: worker panic at job {id} (attempt {attempt})");
            }
            Some(Fault::Delay(d)) => std::thread::sleep(d),
            _ => {}
        }
        if (jobs[i].shed_on_expiry || attempt > 0) && jobs[i].deadline.is_some_and(|d| d <= now) {
            let job = jobs.swap_remove(i);
            shed_prepared(ctx, job);
            continue;
        }
        if matches!(fault, Some(Fault::Transient)) {
            let job = jobs.swap_remove(i);
            retry_or_fail(ctx, job, "injected transient fault");
            continue;
        }
        if let Some(d) = jobs[i].deadline {
            // Clamp the solve to the remaining deadline and let the policy
            // prefer a degraded answer over a cancelled one.
            let rem = d.saturating_duration_since(now);
            if jobs[i].req.request.budget.map_or(true, |b| rem < b) {
                jobs[i].req.request.budget = Some(rem);
            }
            if ctx.degrade.enabled {
                jobs[i].req.request.degrade_on_deadline = true;
            }
        }
        i += 1;
    }

    // Group same-shape jobs (the dispatcher already grouped by
    // engine+bucket) and execute each group as one closed batch, so
    // kernel-backed engines reuse one arena across the group. Each
    // group's replies flush as soon as it finishes — a fast group is
    // never held behind a slow one.
    let mut groups: Vec<((u8, usize, usize), Vec<usize>)> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        let key = shape_key(&job.req);
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((key, vec![i])),
        }
    }
    // Audit sampling clones collected here and certified only after
    // every reply is out, so the O(n²) certify pass never adds to any
    // client-observed latency (one solution clone buys that).
    let mut audits: Vec<(usize, Solution)> = Vec::new();
    for (_, idxs) in &groups {
        let engine = jobs[idxs[0]].engine;
        // queue time up to the group start; head-of-line wait behind
        // earlier items in the same group is added back below so
        // batched jobs keep honest latency accounting
        let at_group_start: Vec<f64> =
            idxs.iter().map(|&i| jobs[i].submitted.elapsed().as_secs_f64()).collect();
        let t = Instant::now();
        let reqs: Vec<&JobRequest> = idxs.iter().map(|&i| &jobs[i].req).collect();
        let outs: Vec<std::result::Result<Solution, String>> = ctx
            .router
            .execute_batch_pinned(pinned, &reqs, engine)
            .into_iter()
            .map(|r| r.map_err(|e| e.to_string()))
            .collect();
        let per_job_fallback = t.elapsed().as_secs_f64() / idxs.len() as f64;
        let mut head_wait = 0.0;
        for ((&i, result), q0) in idxs.iter().zip(outs).zip(at_group_start) {
            let solve = match &result {
                Ok(sol) if sol.stats.seconds > 0.0 => sol.stats.seconds,
                _ => per_job_fallback,
            };
            let queued = q0 + head_wait;
            head_wait += solve;
            let engine_name = jobs[i].engine.name();
            match result {
                Ok(sol) => {
                    let (sol, status) = disposition_ok(ctx, &jobs[i], sol);
                    ctx.metrics
                        .record_phases(engine_name, jobs[i].phase_count.load(Ordering::Relaxed));
                    ctx.metrics.record_done(engine_name, true, queued, solve);
                    if sol.stats.arena_reused {
                        ctx.metrics.record_arena_reuse(1);
                        ctx.metrics.record_shard_arena_reuse(&ctx.shard, 1);
                    }
                    // A clean full-accuracy answer populates the result
                    // cache (degraded/cancelled answers carry weaker
                    // guarantees and never do).
                    if status == JobStatus::Served && !sol.is_cancelled() {
                        if let Some(key) = jobs[i].cache_key.clone() {
                            let report = ctx.cache.insert(key, &sol);
                            ctx.metrics.record_cache_insert(report.evictions, report.bytes);
                        }
                    }
                    if sol.stats.warm_started {
                        ctx.metrics.record_warm_start(engine_name);
                    }
                    // plan-payload accounting: O(nnz) for kernel CSR
                    // answers, the dense slab for Sinkhorn/SSP/XLA
                    ctx.metrics.record_plan_bytes(engine_name, sol.stats.plan_state_bytes);
                    // A budget-stopped solve is exempt from auditing — it
                    // deliberately ships without a guarantee.
                    if ctx.audit_every > 0
                        && jobs[i].req.id % ctx.audit_every == 0
                        && !sol.is_cancelled()
                    {
                        audits.push((i, sol.clone()));
                    }
                    if let Some(reply) = jobs[i].reply.take() {
                        send_outcome(
                            &ctx.metrics,
                            &reply,
                            JobOutcome {
                                id: jobs[i].req.id,
                                engine_used: engine_name,
                                status,
                                result: Ok(sol),
                                queued_secs: queued,
                                solve_secs: solve,
                            },
                        );
                    }
                }
                Err(msg) => {
                    ctx.metrics
                        .record_phases(engine_name, jobs[i].phase_count.load(Ordering::Relaxed));
                    if is_transient(&msg) && jobs[i].attempt < ctx.max_retries {
                        if let Some(reply) = jobs[i].reply.take() {
                            ctx.metrics.record_retry();
                            let due = Instant::now()
                                + backoff_jitter(ctx.retry_backoff, jobs[i].req.id, jobs[i].attempt);
                            let env = Envelope {
                                req: jobs[i].req.clone(),
                                engine: jobs[i].engine,
                                submitted: jobs[i].submitted,
                                attempt: jobs[i].attempt + 1,
                                deadline: jobs[i].deadline,
                                shed_on_expiry: jobs[i].shed_on_expiry,
                                cache_key: jobs[i].cache_key.take(),
                                slot: jobs[i].slot.take(),
                                reply,
                            };
                            if let Err(std::sync::mpsc::SendError((_, env))) =
                                ctx.retry_tx.send((due, env))
                            {
                                fail_env(&ctx.metrics, env, &msg);
                            }
                        }
                    } else {
                        ctx.metrics.record_done(engine_name, false, queued, solve);
                        if let Some(reply) = jobs[i].reply.take() {
                            send_outcome(
                                &ctx.metrics,
                                &reply,
                                JobOutcome {
                                    id: jobs[i].req.id,
                                    engine_used: engine_name,
                                    status: JobStatus::Failed { attempts: jobs[i].attempt + 1 },
                                    result: Err(msg),
                                    queued_secs: queued,
                                    solve_secs: solve,
                                },
                            );
                        }
                    }
                }
            }
        }
    }
    for (i, sol) in audits {
        let job = &jobs[i];
        let cert = sol.certificate.clone().unwrap_or_else(|| {
            crate::core::certify::certify(&job.req.kind, &sol, &job.req.request)
        });
        ctx.metrics.record_audit(&cert);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;

    fn assignment_job(n: usize, seed: u64) -> JobKind {
        JobKind::Assignment(Workload::RandomCosts { n }.assignment(seed))
    }

    #[test]
    fn solves_jobs_end_to_end() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h1 = coord.submit(assignment_job(16, 1), 0.3, Engine::NativeSeq).unwrap();
        let h2 = coord.submit(assignment_job(12, 2), 0.3, Engine::Auto).unwrap();
        let o1 = h1.wait().unwrap();
        let o2 = h2.wait().unwrap();
        assert!(o1.result.is_ok());
        assert_eq!(o1.status, JobStatus::Served);
        assert!(o2.result.is_ok());
        assert_eq!(o2.engine_used, "native-seq");
        let snap = coord.metrics.snapshot();
        assert!(snap.contains("completed=2"), "{snap}");
        coord.shutdown();
    }

    #[test]
    fn many_concurrent_jobs() {
        let coord = Coordinator::start(
            CoordinatorConfig { workers: 4, ..Default::default() },
            None,
        );
        let handles: Vec<_> = (0..20)
            .map(|i| coord.submit(assignment_job(10, i), 0.4, Engine::NativeSeq).unwrap())
            .collect();
        let mut costs = Vec::new();
        for h in handles {
            let out = h.wait().unwrap();
            costs.push(out.result.unwrap().cost);
        }
        assert_eq!(costs.len(), 20);
        coord.shutdown();
    }

    #[test]
    fn failed_jobs_report_errors() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        // XLA without a registry must fail but not crash the worker
        let h = coord.submit(assignment_job(8, 1), 0.3, Engine::Xla).unwrap();
        let out = h.wait().unwrap();
        assert!(out.result.is_err());
        assert!(
            matches!(out.status, JobStatus::Failed { attempts: 1 }),
            "a deterministic error fails on the first attempt: {:?}",
            out.status
        );
        // coordinator still serves afterwards
        let h2 = coord.submit(assignment_job(8, 2), 0.3, Engine::NativeSeq).unwrap();
        assert!(h2.wait().unwrap().result.is_ok());
        coord.shutdown();
    }

    #[test]
    fn ot_jobs_flow_through() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let inst = Workload::Fig1 { n: 10 }.ot_with_random_masses(5);
        let h = coord.submit(JobKind::Ot(inst), 0.3, Engine::Auto).unwrap();
        let out = h.wait().unwrap();
        let sol = out.result.unwrap();
        assert!(sol.cost.is_finite());
        assert!(sol.plan().is_some(), "OT jobs return a transport plan");
        coord.shutdown();
    }

    #[test]
    fn expired_deadline_jobs_are_shed_with_retry_hint() {
        let coord = Coordinator::start(
            CoordinatorConfig { default_deadline: Some(Duration::ZERO), ..Default::default() },
            None,
        );
        let h = coord.submit(assignment_job(8, 1), 0.3, Engine::NativeSeq).unwrap();
        let out = h.wait().unwrap();
        assert!(matches!(out.status, JobStatus::Shed { .. }), "{:?}", out.status);
        assert!(out.result.is_err());
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(metrics.shed.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.queue_depth(), 0, "shed jobs leave the queue-depth gauge");
    }

    #[test]
    fn injected_worker_panic_is_supervised_and_the_job_retries() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                faults: Some(Arc::new(FaultPlan::new().panic_at(1))),
                ..Default::default()
            },
            None,
        );
        let h = coord.submit(assignment_job(10, 1), 0.3, Engine::NativeSeq).unwrap();
        let out = h.wait().unwrap();
        assert!(out.result.is_ok(), "the retry after the panic must serve: {:?}", out.result);
        assert_eq!(out.status, JobStatus::Served);
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(metrics.worker_panics.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.worker_restarts.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.retried.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.queue_depth(), 0);
    }

    #[test]
    fn transient_faults_exhaust_the_retry_budget_into_failed() {
        let plan = FaultPlan::new()
            .at_attempt(1, 0, Fault::Transient)
            .at_attempt(1, 1, Fault::Transient)
            .at_attempt(1, 2, Fault::Transient);
        let coord = Coordinator::start(
            CoordinatorConfig { max_retries: 2, faults: Some(Arc::new(plan)), ..Default::default() },
            None,
        );
        let h = coord.submit(assignment_job(8, 1), 0.3, Engine::NativeSeq).unwrap();
        let out = h.wait().unwrap();
        assert!(
            matches!(out.status, JobStatus::Failed { attempts: 3 }),
            "attempt 0 + 2 retries, all transient: {:?}",
            out.status
        );
        assert!(out.result.is_err());
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(metrics.retried.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.queue_depth(), 0);
    }

    #[test]
    fn dropped_handles_count_as_abandoned_jobs() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h = coord.submit(assignment_job(8, 1), 0.3, Engine::NativeSeq).unwrap();
        drop(h); // never wait()ed — the reply has nowhere to land
        let metrics = coord.metrics.clone();
        coord.shutdown(); // joins workers, so the reply attempt has happened
        assert_eq!(metrics.abandoned_jobs.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.queue_depth(), 0, "abandoned jobs still resolve terminally");
    }

    #[test]
    fn audit_mode_certifies_sampled_jobs() {
        let coord = Coordinator::start(
            CoordinatorConfig { audit_sample_every: 1, ..Default::default() },
            None,
        );
        let handles: Vec<_> = (0..4)
            .map(|i| coord.submit(assignment_job(12, i), 0.3, Engine::NativeSeq).unwrap())
            .collect();
        for h in handles {
            assert!(h.wait().unwrap().result.is_ok());
        }
        // audits run after the reply is sent: join workers before reading
        let metrics = coord.metrics.clone();
        coord.shutdown();
        let (audited, pass, fail) = metrics.audit_counters();
        assert_eq!(audited, 4, "sample_every=1 audits every job");
        assert_eq!((pass, fail), (4, 0));
        let snap = metrics.snapshot();
        assert!(snap.contains("audit: sampled=4 pass=4 fail=0"), "{snap}");
        assert!(snap.contains("audit gap/bound histogram:"), "{snap}");
    }

    #[test]
    fn audit_sampling_respects_stride() {
        let coord = Coordinator::start(
            CoordinatorConfig { audit_sample_every: 2, ..Default::default() },
            None,
        );
        // job ids 1..=4 → ids 2 and 4 get audited
        let handles: Vec<_> = (0..4)
            .map(|i| coord.submit(assignment_job(10, i), 0.4, Engine::NativeSeq).unwrap())
            .collect();
        for h in handles {
            assert!(h.wait().unwrap().result.is_ok());
        }
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(metrics.audit_counters().0, 2);
    }

    #[test]
    fn audit_off_by_default() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h = coord.submit(assignment_job(8, 1), 0.4, Engine::NativeSeq).unwrap();
        assert!(h.wait().unwrap().result.is_ok());
        assert_eq!(coord.metrics.audit_counters(), (0, 0, 0));
        assert!(!coord.metrics.snapshot().contains("audit:"));
        coord.shutdown();
    }

    #[test]
    fn closed_batches_reuse_one_kernel_arena() {
        // The batch-path acceptance scenario: 8 same-shape jobs close one
        // batch (max_batch = 8, generous max_wait so expiry can't split
        // it), the worker executes them as one group, and the kernel
        // arena is reused for all but the first — asserted via the
        // Metrics reuse-hit counter.
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_secs(5) },
                ..Default::default()
            },
            None,
        );
        let handles: Vec<_> = (0..8)
            .map(|i| coord.submit(assignment_job(14, i), 0.3, Engine::NativeSeq).unwrap())
            .collect();
        for h in handles {
            assert!(h.wait().unwrap().result.is_ok());
        }
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(
            metrics.arena_reuse_hits.load(Ordering::Relaxed),
            7,
            "8 same-shape jobs in one batch must reuse one arena 7 times"
        );
        let counters = metrics.batch_counters();
        let seq = counters.iter().find(|c| c.key == "native-seq").expect("keyed batch recorded");
        assert_eq!((seq.batches, seq.jobs), (1, 8));
        assert!((seq.occupancy() - 8.0).abs() < 1e-12);
        let snap = metrics.snapshot();
        assert!(snap.contains("batch[native-seq]"), "{snap}");
        assert!(snap.contains("kernel arena reuse hits: 7"), "{snap}");
    }

    #[test]
    fn warm_engine_jobs_pin_warm_start_metrics() {
        use crate::coordinator::batcher::BatcherConfig;
        use crate::util::minijson::Json;
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_secs(5) },
                ..Default::default()
            },
            None,
        );
        let handles: Vec<_> = (0..4)
            .map(|i| coord.submit(assignment_job(12, i), 0.3, Engine::NativeSeqWarm).unwrap())
            .collect();
        for h in handles {
            let out = h.wait().unwrap();
            assert_eq!(out.engine_used, "native-seq-warm");
            assert!(out.result.unwrap().stats.warm_started);
        }
        let metrics = coord.metrics.clone();
        coord.shutdown();
        let counters = metrics.engine_counters();
        let w = counters.iter().find(|e| e.engine == "native-seq-warm").expect("engine recorded");
        assert_eq!(w.jobs, 4);
        assert_eq!(w.warm_started, 4, "every job on the warm engine warm-starts");
        // one batch of 4 same-shape jobs → items 1..3 carry the arena duals
        assert!(metrics.arena_reuse_hits.load(Ordering::Relaxed) >= 3);
        let j = Json::parse(&metrics.to_json().to_string()).expect("valid metrics JSON");
        let warm_total: f64 = j
            .get("engines")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("warm_started_jobs").unwrap().as_f64().unwrap())
            .sum();
        assert_eq!(warm_total, 4.0);
    }

    #[test]
    fn phase_metrics_flow_from_observer() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h = coord.submit(assignment_job(32, 9), 0.2, Engine::NativeSeq).unwrap();
        assert!(h.wait().unwrap().result.is_ok());
        let counters = coord.metrics.engine_counters();
        let seq = counters.iter().find(|e| e.engine == "native-seq").expect("engine recorded");
        assert_eq!(seq.jobs, 1);
        assert!(seq.phases > 0, "solver phases must stream into metrics");
        coord.shutdown();
    }

    #[test]
    fn interleaved_shapes_keep_their_shards_arenas_warm() {
        // The tentpole acceptance scenario: max_batch = 1 means every job
        // is its own closed batch, so arena reuse can only come from
        // shard workers pinning their kernel solvers *across* batches.
        // Interleaving two shapes must not cool either shard.
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_secs(5) },
                ..Default::default()
            },
            None,
        );
        let mut reused = Vec::new();
        for i in 0..12u64 {
            let n = if i % 2 == 0 { 14 } else { 10 };
            let h = coord.submit(assignment_job(n, i), 0.3, Engine::NativeSeq).unwrap();
            reused.push(h.wait().unwrap().result.unwrap().stats.arena_reused);
        }
        assert!(!reused[0] && !reused[1], "each shard's first job builds its arena cold");
        assert!(
            reused[2..].iter().all(|&r| r),
            "every job after a shard's first must reuse its warm arena: {reused:?}"
        );
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(metrics.arena_reuse_hits.load(Ordering::Relaxed), 10);
        let shards = metrics.shard_counters();
        assert_eq!(shards.len(), 2, "one shard per shape");
        for label in ["asg/14x14", "asg/10x10"] {
            let s = shards.iter().find(|s| s.shard == label).expect("shard recorded");
            assert_eq!((s.spawns, s.jobs, s.arena_reuse_hits), (1, 6, 5), "{label}");
            assert!((s.arena_reuse_rate() - 5.0 / 6.0).abs() < 1e-12, "{label}");
        }
    }

    #[test]
    fn tenant_deadline_defaults_compose_with_budget_and_global() {
        // Precedence is min(request budget, tenant default, global
        // default); each leg proven to contribute.
        let coord = Coordinator::start(
            CoordinatorConfig {
                tenants: vec![
                    (
                        "instant".into(),
                        TenantQuota {
                            default_deadline: Some(Duration::ZERO),
                            ..Default::default()
                        },
                    ),
                    (
                        "slow".into(),
                        TenantQuota {
                            default_deadline: Some(Duration::from_secs(30)),
                            ..Default::default()
                        },
                    ),
                ],
                ..Default::default()
            },
            None,
        );
        // Tenant default alone sheds…
        let h = coord
            .submit_request(
                assignment_job(8, 1),
                SolveRequest::new(0.3).for_tenant("instant"),
                Engine::NativeSeq,
            )
            .unwrap();
        assert!(matches!(h.wait().unwrap().status, JobStatus::Shed { .. }));
        // …a generous tenant default serves…
        let h = coord
            .submit_request(
                assignment_job(8, 2),
                SolveRequest::new(0.3).for_tenant("slow"),
                Engine::NativeSeq,
            )
            .unwrap();
        assert_eq!(h.wait().unwrap().status, JobStatus::Served);
        // …the request budget clamps below the tenant default…
        let h = coord
            .submit_request(
                assignment_job(8, 3),
                SolveRequest::new(0.3).for_tenant("slow").with_budget(Duration::ZERO),
                Engine::NativeSeq,
            )
            .unwrap();
        assert!(matches!(h.wait().unwrap().status, JobStatus::Shed { .. }));
        // …and an unknown tenant with no default anywhere keeps the
        // legacy deadline-free semantics.
        let h = coord
            .submit_request(
                assignment_job(8, 4),
                SolveRequest::new(0.3).for_tenant("nobody"),
                Engine::NativeSeq,
            )
            .unwrap();
        assert_eq!(h.wait().unwrap().status, JobStatus::Served);
        coord.shutdown();

        // A global default tighter than the tenant's wins the min.
        let coord = Coordinator::start(
            CoordinatorConfig {
                default_deadline: Some(Duration::ZERO),
                tenants: vec![(
                    "slow".into(),
                    TenantQuota {
                        default_deadline: Some(Duration::from_secs(30)),
                        ..Default::default()
                    },
                )],
                ..Default::default()
            },
            None,
        );
        let h = coord
            .submit_request(
                assignment_job(8, 5),
                SolveRequest::new(0.3).for_tenant("slow"),
                Engine::NativeSeq,
            )
            .unwrap();
        assert!(matches!(h.wait().unwrap().status, JobStatus::Shed { .. }));
        coord.shutdown();
    }

    #[test]
    fn tenant_quota_backpressures_without_touching_siblings() {
        // A generous batcher wait keeps tenant a's job open (admitted,
        // unserved), so its in-flight gauge deterministically saturates
        // the quota — no dispatcher race, the gauge moves inside admit().
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                batcher: BatcherConfig { max_batch: 64, max_wait: Duration::from_secs(30) },
                tenants: vec![(
                    "a".into(),
                    TenantQuota { max_in_flight: 1, ..Default::default() },
                )],
                ..Default::default()
            },
            None,
        );
        let first = match coord
            .admit(assignment_job(10, 1), SolveRequest::new(0.3).for_tenant("a"), Engine::NativeSeq)
            .unwrap()
        {
            Admission::Accepted(h) => h,
            Admission::Backpressure { .. } => panic!("tenant a's first job must be admitted"),
        };
        match coord
            .admit(assignment_job(10, 2), SolveRequest::new(0.3).for_tenant("a"), Engine::NativeSeq)
            .unwrap()
        {
            Admission::Backpressure { retry_after } => assert!(retry_after > Duration::ZERO),
            Admission::Accepted(_) => panic!("tenant a is saturated at max_in_flight = 1"),
        }
        // The anonymous default tenant shares no gauge with a.
        let second = match coord
            .admit(assignment_job(10, 3), SolveRequest::new(0.3), Engine::NativeSeq)
            .unwrap()
        {
            Admission::Accepted(h) => h,
            Admission::Backpressure { .. } => panic!("a saturated quota must not leak across tenants"),
        };
        let metrics = coord.metrics.clone();
        coord.shutdown(); // flushes the open batch; both admitted jobs serve
        assert_eq!(first.wait().unwrap().status, JobStatus::Served);
        assert_eq!(second.wait().unwrap().status, JobStatus::Served);
        assert_eq!(metrics.backpressured_jobs.load(Ordering::Relaxed), 1);
        let tenants = metrics.tenant_counters();
        let a = tenants.iter().find(|t| t.tenant == "a").unwrap();
        assert_eq!((a.admitted, a.backpressured), (1, 1));
        let anon = tenants.iter().find(|t| t.tenant == "anonymous").unwrap();
        assert_eq!((anon.admitted, anon.backpressured), (1, 0));
    }

    #[test]
    fn cache_hits_are_byte_identical_to_the_fresh_solve() {
        let coord = Coordinator::start(
            CoordinatorConfig { cache_bytes: 1 << 20, ..Default::default() },
            None,
        );
        let matching_of = |sol: &crate::api::Solution| match &sol.coupling {
            Coupling::Matching(m) => m.clone(),
            Coupling::Plan(_) => panic!("assignment jobs return a matching"),
        };
        let fresh =
            coord.submit(assignment_job(12, 7), 0.3, Engine::NativeSeq).unwrap().wait().unwrap();
        assert_eq!(fresh.status, JobStatus::Served);
        // Same payload, same ε, same engine: a hit (the insert lands
        // before the first reply is sent, so this cannot race).
        let hit =
            coord.submit(assignment_job(12, 7), 0.3, Engine::NativeSeq).unwrap().wait().unwrap();
        assert_eq!(hit.status, JobStatus::Served);
        assert_eq!(hit.engine_used, "native-seq");
        let (a, b) = (fresh.result.unwrap(), hit.result.unwrap());
        assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "cached cost is bit-exact");
        assert_eq!(matching_of(&a), matching_of(&b), "cached matching is identical");
        assert_eq!(a.duals, b.duals, "cached duals are identical");
        // A different ε is a different answer — must miss and re-solve.
        let other =
            coord.submit(assignment_job(12, 7), 0.2, Engine::NativeSeq).unwrap().wait().unwrap();
        assert_eq!(other.status, JobStatus::Served);
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(metrics.cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.cache_misses.load(Ordering::Relaxed), 2);
        assert!(metrics.cache_bytes() > 0);
        let snap = metrics.snapshot();
        assert!(snap.contains("cache: hits=1 misses=2"), "{snap}");
    }

    #[test]
    fn a_panicking_shard_leaves_sibling_shards_serving() {
        // Two shapes → two shards. Job 2 lives on the 14x14 shard and
        // panics its only worker; the 10x10 shard never notices, and the
        // panicked shard recovers under its own supervisor.
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                faults: Some(Arc::new(FaultPlan::new().panic_at(2))),
                ..Default::default()
            },
            None,
        );
        let big: Vec<_> = (0..3)
            .map(|i| coord.submit(assignment_job(14, i), 0.3, Engine::NativeSeq).unwrap())
            .collect();
        let small: Vec<_> = (0..3)
            .map(|i| coord.submit(assignment_job(10, i), 0.3, Engine::NativeSeq).unwrap())
            .collect();
        for h in big {
            let out = h.wait().unwrap();
            assert_eq!(out.status, JobStatus::Served, "panicked shard recovers: {:?}", out.result);
        }
        for h in small {
            assert_eq!(h.wait().unwrap().status, JobStatus::Served);
        }
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(metrics.worker_panics.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.worker_restarts.load(Ordering::Relaxed), 1);
        let shards = metrics.shard_counters();
        assert_eq!(shards.len(), 2);
        let sibling = shards.iter().find(|s| s.shard == "asg/10x10").unwrap();
        assert_eq!(sibling.jobs, 3, "the sibling shard served all its jobs");
        assert_eq!(metrics.queue_depth(), 0);
    }

    #[test]
    fn max_shards_evicts_the_lru_shard_and_respawns_on_return() {
        let coord = Coordinator::start(
            CoordinatorConfig { workers: 1, max_shards: 1, ..Default::default() },
            None,
        );
        for (n, seed) in [(14usize, 1u64), (10, 2), (14, 3)] {
            let out =
                coord.submit(assignment_job(n, seed), 0.3, Engine::NativeSeq).unwrap().wait();
            assert_eq!(out.unwrap().status, JobStatus::Served);
        }
        let metrics = coord.metrics.clone();
        coord.shutdown();
        let shards = metrics.shard_counters();
        let big = shards.iter().find(|s| s.shard == "asg/14x14").unwrap();
        assert_eq!((big.spawns, big.reaps), (2, 1), "evicted shape respawns on its next job");
        let small = shards.iter().find(|s| s.shard == "asg/10x10").unwrap();
        assert_eq!((small.spawns, small.reaps), (1, 1));
    }

    #[test]
    fn idle_shards_are_reaped_and_respawn_on_the_next_job() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                shard_idle_ttl: Duration::from_millis(100),
                ..Default::default()
            },
            None,
        );
        let h = coord.submit(assignment_job(12, 1), 0.3, Engine::NativeSeq).unwrap();
        assert!(h.wait().unwrap().result.is_ok());
        // Past the TTL plus the dispatcher's 50ms poll cadence.
        std::thread::sleep(Duration::from_millis(400));
        let h = coord.submit(assignment_job(12, 2), 0.3, Engine::NativeSeq).unwrap();
        assert!(h.wait().unwrap().result.is_ok());
        let metrics = coord.metrics.clone();
        coord.shutdown();
        let shards = metrics.shard_counters();
        let s = shards.iter().find(|s| s.shard == "asg/12x12").unwrap();
        assert_eq!((s.spawns, s.reaps), (2, 1), "the idle shard was reaped and respawned");
    }
}
