//! The coordinator service: submit jobs, get handles, await results.
//!
//! Topology (std::thread + mpsc; tokio is unavailable offline):
//!
//! ```text
//! submit() ──sync_channel(backpressure)──► dispatcher ──batcher──► job queue
//!                                                                 ▲   │
//!                                               workers (N) ──────┘   ▼
//!                                   JobHandle ◄──per-job channel── execute
//! ```
//!
//! The dispatcher groups jobs by (engine, bucket) via [`Batcher`]; workers
//! drain whole batches so XLA executions with the same bucket reuse the
//! compiled executable back-to-back.

use crate::api::SolveRequest;
use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::job::{Engine, JobKind, JobOutcome, JobRequest};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::Router;
use crate::core::{OtprError, Result};
use crate::runtime::XlaRuntime;
use crate::util::pool;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Queue capacity before submit() blocks (backpressure).
    pub queue_capacity: usize,
    pub batcher: BatcherConfig,
    /// Threads each native-parallel solve may use.
    pub solver_threads: usize,
    /// Audit mode: certify every k-th successfully served job (by job id)
    /// post-solve and fold pass/fail + gap histograms into the metrics
    /// ([`Metrics::record_audit`]). `0` disables auditing; `1` certifies
    /// every job. Cancelled solves are exempt (they carry no guarantee).
    pub audit_sample_every: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 64,
            batcher: BatcherConfig::default(),
            solver_threads: pool::default_threads(),
            audit_sample_every: 0,
        }
    }
}

struct Envelope {
    req: JobRequest,
    engine: Engine,
    submitted: Instant,
    reply: Sender<JobOutcome>,
}

/// Awaitable handle for one submitted job.
pub struct JobHandle {
    pub id: u64,
    rx: Receiver<JobOutcome>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobOutcome> {
        self.rx
            .recv()
            .map_err(|_| OtprError::Coordinator("worker dropped the job".into()))
    }

    pub fn wait_timeout(&self, d: Duration) -> Option<JobOutcome> {
        self.rx.recv_timeout(d).ok()
    }
}

enum DispatchMsg {
    Job(Envelope),
    Shutdown,
}

pub struct Coordinator {
    tx: SyncSender<DispatchMsg>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn start(config: CoordinatorConfig, runtime: Option<Arc<XlaRuntime>>) -> Self {
        let metrics = Arc::new(Metrics::new());
        let router = Arc::new(Router::new(runtime, config.solver_threads));
        let (tx, dispatch_rx) = sync_channel::<DispatchMsg>(config.queue_capacity);
        // batch queue: dispatcher -> workers
        let (batch_tx, batch_rx) = sync_channel::<Vec<Envelope>>(config.queue_capacity);
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let dispatcher = {
            let metrics = metrics.clone();
            let batcher_cfg = config.batcher.clone();
            std::thread::spawn(move || {
                dispatcher_loop(dispatch_rx, batch_tx, batcher_cfg, metrics)
            })
        };

        let mut workers = Vec::new();
        for _ in 0..config.workers.max(1) {
            let rx = batch_rx.clone();
            let router = router.clone();
            let metrics = metrics.clone();
            let audit_every = config.audit_sample_every;
            workers.push(std::thread::spawn(move || {
                worker_loop(rx, router, metrics, audit_every)
            }));
        }

        Self { tx, metrics, next_id: AtomicU64::new(1), dispatcher: Some(dispatcher), workers }
    }

    /// Submit a job at accuracy `eps` with default request settings;
    /// blocks when the queue is at capacity (backpressure).
    pub fn submit(&self, kind: JobKind, eps: f64, engine: Engine) -> Result<JobHandle> {
        self.submit_request(kind, SolveRequest::new(eps), engine)
    }

    /// Submit a job with a full [`SolveRequest`] — wall-clock budget,
    /// cancellation token, and progress observer are honored by the
    /// executing engine; progress additionally feeds the coordinator's
    /// per-engine phase metrics.
    pub fn submit_request(
        &self,
        kind: JobKind,
        request: SolveRequest,
        engine: Engine,
    ) -> Result<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let req = JobRequest { id, kind, request, engine };
        self.metrics.record_submit();
        self.tx
            .send(DispatchMsg::Job(Envelope {
                req,
                engine,
                submitted: Instant::now(),
                reply: reply_tx,
            }))
            .map_err(|_| {
                self.metrics.record_reject();
                OtprError::Coordinator("coordinator is shut down".into())
            })?;
        Ok(JobHandle { id, rx: reply_rx })
    }

    /// Graceful shutdown: flush batches, join threads.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn dispatcher_loop(
    rx: Receiver<DispatchMsg>,
    batch_tx: SyncSender<Vec<Envelope>>,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
) {
    // Resolve engine names once per job so the batch key is 'static.
    let mut batcher: Batcher<Envelope> = Batcher::new(cfg);
    loop {
        // poll with a deadline so expiring batches flush promptly
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(DispatchMsg::Job(env)) => {
                let key = (env.engine.name(), None::<usize>);
                // bucket refinement happens in the worker (needs registry);
                // the engine name alone already separates XLA from native.
                if let Some(batch) = batcher.push(key, env) {
                    metrics.record_batch(batch.jobs.len());
                    if batch_tx.send(batch.jobs).is_err() {
                        return;
                    }
                }
            }
            Ok(DispatchMsg::Shutdown) => {
                for batch in batcher.drain_all() {
                    metrics.record_batch(batch.jobs.len());
                    let _ = batch_tx.send(batch.jobs);
                }
                return; // dropping batch_tx stops workers
            }
            Err(RecvTimeoutError::Timeout) => {
                for batch in batcher.drain_expired() {
                    metrics.record_batch(batch.jobs.len());
                    if batch_tx.send(batch.jobs).is_err() {
                        return;
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                for batch in batcher.drain_all() {
                    metrics.record_batch(batch.jobs.len());
                    let _ = batch_tx.send(batch.jobs);
                }
                return;
            }
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Vec<Envelope>>>>,
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    audit_every: u64,
) {
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(batch) = batch else { return };
        for env in batch {
            let queued = env.submitted.elapsed().as_secs_f64();
            let mut req = env.req;
            let engine = router.resolve(&req);
            // Tee solver progress into a per-job atomic (folded into the
            // metrics lock once per job, not per phase) without disturbing
            // any caller-supplied observer.
            let phase_count = Arc::new(AtomicU64::new(0));
            let counter = phase_count.clone();
            req.request = req.request.chain_observer(move |_p| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
            let t = Instant::now();
            let result = router.execute(&req, engine).map_err(|e| e.to_string());
            let solve = t.elapsed().as_secs_f64();
            metrics.record_phases(engine.name(), phase_count.load(Ordering::Relaxed));
            metrics.record_done(engine.name(), result.is_ok(), queued, solve);
            // Audit sampling: independently re-verify every k-th served
            // job and export pass/fail + gap histograms. A budget-stopped
            // solve is exempt — it deliberately ships without a guarantee.
            // The O(n²) certify pass runs *after* the reply is sent, so
            // auditing never adds to client-observed latency (one solution
            // clone buys that).
            let audit_sol = if audit_every > 0 && req.id % audit_every == 0 {
                match &result {
                    Ok(sol) if !sol.is_cancelled() => Some(sol.clone()),
                    _ => None,
                }
            } else {
                None
            };
            let _ = env.reply.send(JobOutcome {
                id: req.id,
                engine_used: engine.name(),
                result,
                queued_secs: queued,
                solve_secs: solve,
            });
            if let Some(sol) = audit_sol {
                let cert = sol.certificate.clone().unwrap_or_else(|| {
                    crate::core::certify::certify(&req.kind, &sol, &req.request)
                });
                metrics.record_audit(&cert);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;

    fn assignment_job(n: usize, seed: u64) -> JobKind {
        JobKind::Assignment(Workload::RandomCosts { n }.assignment(seed))
    }

    #[test]
    fn solves_jobs_end_to_end() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h1 = coord.submit(assignment_job(16, 1), 0.3, Engine::NativeSeq).unwrap();
        let h2 = coord.submit(assignment_job(12, 2), 0.3, Engine::Auto).unwrap();
        let o1 = h1.wait().unwrap();
        let o2 = h2.wait().unwrap();
        assert!(o1.result.is_ok());
        assert!(o2.result.is_ok());
        assert_eq!(o2.engine_used, "native-seq");
        let snap = coord.metrics.snapshot();
        assert!(snap.contains("completed=2"), "{snap}");
        coord.shutdown();
    }

    #[test]
    fn many_concurrent_jobs() {
        let coord = Coordinator::start(
            CoordinatorConfig { workers: 4, ..Default::default() },
            None,
        );
        let handles: Vec<_> = (0..20)
            .map(|i| coord.submit(assignment_job(10, i), 0.4, Engine::NativeSeq).unwrap())
            .collect();
        let mut costs = Vec::new();
        for h in handles {
            let out = h.wait().unwrap();
            costs.push(out.result.unwrap().cost);
        }
        assert_eq!(costs.len(), 20);
        coord.shutdown();
    }

    #[test]
    fn failed_jobs_report_errors() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        // XLA without a registry must fail but not crash the worker
        let h = coord.submit(assignment_job(8, 1), 0.3, Engine::Xla).unwrap();
        let out = h.wait().unwrap();
        assert!(out.result.is_err());
        // coordinator still serves afterwards
        let h2 = coord.submit(assignment_job(8, 2), 0.3, Engine::NativeSeq).unwrap();
        assert!(h2.wait().unwrap().result.is_ok());
        coord.shutdown();
    }

    #[test]
    fn ot_jobs_flow_through() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let inst = Workload::Fig1 { n: 10 }.ot_with_random_masses(5);
        let h = coord.submit(JobKind::Ot(inst), 0.3, Engine::Auto).unwrap();
        let out = h.wait().unwrap();
        let sol = out.result.unwrap();
        assert!(sol.cost.is_finite());
        assert!(sol.plan().is_some(), "OT jobs return a transport plan");
        coord.shutdown();
    }

    #[test]
    fn audit_mode_certifies_sampled_jobs() {
        let coord = Coordinator::start(
            CoordinatorConfig { audit_sample_every: 1, ..Default::default() },
            None,
        );
        let handles: Vec<_> = (0..4)
            .map(|i| coord.submit(assignment_job(12, i), 0.3, Engine::NativeSeq).unwrap())
            .collect();
        for h in handles {
            assert!(h.wait().unwrap().result.is_ok());
        }
        // audits run after the reply is sent: join workers before reading
        let metrics = coord.metrics.clone();
        coord.shutdown();
        let (audited, pass, fail) = metrics.audit_counters();
        assert_eq!(audited, 4, "sample_every=1 audits every job");
        assert_eq!((pass, fail), (4, 0));
        let snap = metrics.snapshot();
        assert!(snap.contains("audit: sampled=4 pass=4 fail=0"), "{snap}");
        assert!(snap.contains("audit gap/bound histogram:"), "{snap}");
    }

    #[test]
    fn audit_sampling_respects_stride() {
        let coord = Coordinator::start(
            CoordinatorConfig { audit_sample_every: 2, ..Default::default() },
            None,
        );
        // job ids 1..=4 → ids 2 and 4 get audited
        let handles: Vec<_> = (0..4)
            .map(|i| coord.submit(assignment_job(10, i), 0.4, Engine::NativeSeq).unwrap())
            .collect();
        for h in handles {
            assert!(h.wait().unwrap().result.is_ok());
        }
        let metrics = coord.metrics.clone();
        coord.shutdown();
        assert_eq!(metrics.audit_counters().0, 2);
    }

    #[test]
    fn audit_off_by_default() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h = coord.submit(assignment_job(8, 1), 0.4, Engine::NativeSeq).unwrap();
        assert!(h.wait().unwrap().result.is_ok());
        assert_eq!(coord.metrics.audit_counters(), (0, 0, 0));
        assert!(!coord.metrics.snapshot().contains("audit:"));
        coord.shutdown();
    }

    #[test]
    fn phase_metrics_flow_from_observer() {
        let coord = Coordinator::start(CoordinatorConfig::default(), None);
        let h = coord.submit(assignment_job(32, 9), 0.2, Engine::NativeSeq).unwrap();
        assert!(h.wait().unwrap().result.is_ok());
        let counters = coord.metrics.engine_counters();
        let seq = counters.iter().find(|e| e.engine == "native-seq").expect("engine recorded");
        assert_eq!(seq.jobs, 1);
        assert!(seq.phases > 0, "solver phases must stream into metrics");
        coord.shutdown();
    }
}
