//! Coordinator metrics: lock-free counters plus fixed-bucket latency and
//! audit gap histograms, with a text snapshot for `otpr serve --stats` and
//! tests.

use crate::core::certify::{gap_ratio_bucket, Certificate, GAP_RATIO_BUCKETS};
use crate::util::minijson::{obj, Json};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Latency histogram buckets (seconds, upper bounds).
pub const LATENCY_BUCKETS: [f64; 10] =
    [0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 30.0, f64::INFINITY];

/// Lock a metrics mutex, recovering from poisoning. Every guarded section
/// here appends or increments monotone counters, so a panicking writer
/// cannot leave state worth halting the coordinator for — losing one
/// update beats taking the serve loop down with it.
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub rejected: AtomicU64,
    /// Batches dispatched and total jobs in them (batching efficiency).
    pub batches: AtomicU64,
    pub batched_jobs: AtomicU64,
    /// Solves that reused a warm kernel arena inside a batch — the
    /// counter the batch path's amortization claim is asserted on.
    pub arena_reuse_hits: AtomicU64,
    /// Jobs dropped before solving because their effective deadline had
    /// already passed (`JobStatus::Shed`). Not counted as `failed`.
    pub shed: AtomicU64,
    /// Jobs resolved at a coarser ε under deadline pressure
    /// (`JobStatus::Degraded`); also counted in `completed`.
    pub degraded: AtomicU64,
    /// Transient failures requeued for another attempt (each requeue
    /// counts once; terminal outcomes are counted separately).
    pub retried: AtomicU64,
    /// Worker panics caught by supervision — injected or real.
    pub worker_panics: AtomicU64,
    /// Supervised worker respawns (bounded by the restart budget).
    pub worker_restarts: AtomicU64,
    /// Terminal outcomes whose `JobHandle` was dropped before `wait()` —
    /// the reply had nowhere to go.
    pub abandoned_jobs: AtomicU64,
    /// In-flight jobs (accepted, no terminal outcome yet) — the
    /// saturation gauge a load balancer sheds on.
    queue_depth: AtomicU64,
    /// Result-cache traffic: hits bypass dispatch entirely (the answer is
    /// byte-identical to the solve that populated the entry).
    pub cache_hits: AtomicU64,
    /// Cacheable lookups that missed (uncacheable payloads count neither).
    pub cache_misses: AtomicU64,
    /// Entries LRU-evicted to fit the cache's byte budget.
    pub cache_evictions: AtomicU64,
    /// Resident cache bytes right now (gauge, refreshed on each insert).
    cache_bytes: AtomicU64,
    /// Admissions answered `Backpressure` (tenant quota or queue full);
    /// nothing was enqueued for these.
    pub backpressured_jobs: AtomicU64,
    /// Per-tenant admission accounting.
    per_tenant: Mutex<Vec<TenantCounters>>,
    /// Per-shard serving accounting (shape-keyed worker pools).
    per_shard: Mutex<Vec<ShardCounters>>,
    /// Per-(engine, bucket) batch occupancy + accumulated wait.
    per_batch_key: Mutex<Vec<BatchCounters>>,
    /// Audit-mode certification outcomes (see
    /// [`crate::coordinator::CoordinatorConfig::audit_sample_every`]).
    pub audited: AtomicU64,
    pub audit_pass: AtomicU64,
    pub audit_fail: AtomicU64,
    /// gap/bound-ratio histogram over audited dual-certified solutions,
    /// buckets of [`GAP_RATIO_BUCKETS`].
    audit_gaps: [AtomicU64; GAP_RATIO_BUCKETS.len()],
    latency: [AtomicU64; 10],
    queue_secs_total: Mutex<f64>,
    solve_secs_total: Mutex<f64>,
    per_engine: Mutex<Vec<EngineCounters>>,
}

/// Per-engine accounting: completed jobs + phase events streamed live from
/// the solvers' `ProgressObserver` hook.
#[derive(Debug, Clone, Copy)]
pub struct EngineCounters {
    pub engine: &'static str,
    pub jobs: u64,
    /// Progress events (push-relabel phases / Sinkhorn stopping checks)
    /// reported while solving on this engine.
    pub phases: u64,
    /// Jobs that warm-started (ε-scaling schedule or batch dual carry;
    /// `SolveStats::warm_started`).
    pub warm_started: u64,
    /// Jobs the router sent here by resolving `Engine::Auto` — the signal
    /// that the routing table (`auto_kernel_engine`) picks this backend.
    pub auto_routed: u64,
    /// Σ `SolveStats::plan_state_bytes` over this engine's completed OT
    /// jobs — result payloads are O(nnz) for the kernel engines' CSR
    /// plans, so this stays O(n)-shaped where the dense solvers report
    /// the full nb·na·8 slab.
    pub plan_bytes: u64,
    /// Per-engine total-latency (queued + solve) histogram over
    /// [`LATENCY_BUCKETS`] — the p50/p95/p99 source.
    pub latency: [u64; LATENCY_BUCKETS.len()],
}

impl EngineCounters {
    /// (p50, p95, p99) total-latency estimates in seconds, read as the
    /// upper bound of the histogram bucket containing each quantile —
    /// `f64::INFINITY` when the quantile lands in the overflow bucket,
    /// `None` when no job has completed on this engine yet.
    pub fn latency_percentiles(&self) -> Option<(f64, f64, f64)> {
        let total: u64 = self.latency.iter().sum();
        if total == 0 {
            return None;
        }
        let at = |q: f64| -> f64 {
            let target = ((q * total as f64).ceil() as u64).max(1);
            let mut cum = 0u64;
            for (i, &c) in self.latency.iter().enumerate() {
                cum += c;
                if cum >= target {
                    return LATENCY_BUCKETS[i];
                }
            }
            f64::INFINITY
        };
        Some((at(0.50), at(0.95), at(0.99)))
    }
}

/// Per-tenant admission accounting (named quotas plus the anonymous
/// default).
#[derive(Debug, Clone)]
pub struct TenantCounters {
    pub tenant: String,
    /// Jobs accepted through the quota-checked `admit` front door.
    pub admitted: u64,
    /// Admissions answered `Backpressure` for this tenant.
    pub backpressured: u64,
}

/// Per-shard serving accounting: one entry per shape-keyed worker pool
/// the dispatcher has spawned (an evicted-then-respawned shard reuses its
/// entry and bumps `spawns`).
#[derive(Debug, Clone)]
pub struct ShardCounters {
    /// Shape label, e.g. `asg/16x16`.
    pub shard: String,
    /// Times a shard for this shape was (re)spawned.
    pub spawns: u64,
    /// Batches closed toward this shard and jobs in them.
    pub batches: u64,
    pub jobs: u64,
    /// Warm-arena reuse hits attributed to this shard's workers — the
    /// affinity claim: for a same-shape stream this approaches `jobs`.
    pub arena_reuse_hits: u64,
    /// Jobs accumulating in the shard's batcher right now (gauge).
    pub pending: u64,
    /// Times this shard was reaped (idle TTL) or LRU-evicted.
    pub reaps: u64,
}

impl ShardCounters {
    /// Mean jobs per closed batch on this shard.
    pub fn occupancy(&self) -> f64 {
        self.jobs as f64 / self.batches.max(1) as f64
    }

    /// Fraction of this shard's jobs that reused a warm arena.
    pub fn arena_reuse_rate(&self) -> f64 {
        self.arena_reuse_hits as f64 / self.jobs.max(1) as f64
    }
}

/// Per batch key (engine name + optional artifact bucket) accounting:
/// closed batches, jobs in them, and accumulated accumulation wait.
#[derive(Debug, Clone)]
pub struct BatchCounters {
    pub key: String,
    pub batches: u64,
    pub jobs: u64,
    pub wait_us_total: u64,
}

impl BatchCounters {
    /// Mean jobs per closed batch — the occupancy the `/metrics` JSON
    /// exposes.
    pub fn occupancy(&self) -> f64 {
        self.jobs as f64 / self.batches.max(1) as f64
    }

    pub fn mean_wait_us(&self) -> f64 {
        self.wait_us_total as f64 / self.batches.max(1) as f64
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        self.dec_queue_depth();
    }

    /// In-flight jobs right now (accepted, not yet terminal).
    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    fn dec_queue_depth(&self) {
        // Saturating: a stray double-decrement must not wrap the gauge.
        let _ = self
            .queue_depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| d.checked_sub(1));
    }

    /// One job shed at dispatch/pickup (deadline already passed). Shed is
    /// a terminal outcome but neither `completed` nor `failed`.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        self.dec_queue_depth();
    }

    /// One job served at a coarser ε under deadline pressure. The job also
    /// goes through [`Metrics::record_done`]; this only tags it.
    pub fn record_degraded(&self) {
        self.degraded.fetch_add(1, Ordering::Relaxed);
    }

    /// One transient failure requeued for another attempt (not terminal).
    pub fn record_retry(&self) {
        self.retried.fetch_add(1, Ordering::Relaxed);
    }

    /// One worker panic caught by supervision.
    pub fn record_worker_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// One supervised worker respawn.
    pub fn record_worker_restart(&self) {
        self.worker_restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// One terminal reply that found its `JobHandle` already dropped.
    pub fn record_abandoned(&self) {
        self.abandoned_jobs.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one closed batch: its key (engine name + optional artifact
    /// bucket), occupancy, and how long it accumulated before closing.
    pub fn record_batch(&self, key: &str, jobs: usize, wait_us: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_jobs.fetch_add(jobs as u64, Ordering::Relaxed);
        let mut per = locked(&self.per_batch_key);
        match per.iter_mut().find(|c| c.key == key) {
            Some(c) => {
                c.batches += 1;
                c.jobs += jobs as u64;
                c.wait_us_total += wait_us;
            }
            None => per.push(BatchCounters {
                key: key.to_string(),
                batches: 1,
                jobs: jobs as u64,
                wait_us_total: wait_us,
            }),
        }
    }

    /// Count kernel-arena reuse hits from a batch of solves.
    pub fn record_arena_reuse(&self, hits: u64) {
        if hits > 0 {
            self.arena_reuse_hits.fetch_add(hits, Ordering::Relaxed);
        }
    }

    /// One result-cache hit (the reply bypassed dispatch).
    pub fn record_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// One cacheable lookup that missed.
    pub fn record_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one cache insert: evictions it caused and the resident-bytes
    /// gauge after it.
    pub fn record_cache_insert(&self, evictions: u64, resident_bytes: u64) {
        if evictions > 0 {
            self.cache_evictions.fetch_add(evictions, Ordering::Relaxed);
        }
        self.cache_bytes.store(resident_bytes, Ordering::Relaxed);
    }

    /// Resident result-cache bytes as last reported by an insert.
    pub fn cache_bytes(&self) -> u64 {
        self.cache_bytes.load(Ordering::Relaxed)
    }

    /// One admission accepted through the quota-checked front door.
    pub fn record_admitted(&self, tenant: &str) {
        self.with_tenant(tenant, |t| t.admitted += 1);
    }

    /// One admission answered `Backpressure` for `tenant`.
    pub fn record_backpressure(&self, tenant: &str) {
        self.backpressured_jobs.fetch_add(1, Ordering::Relaxed);
        self.with_tenant(tenant, |t| t.backpressured += 1);
    }

    fn with_tenant(&self, tenant: &str, f: impl FnOnce(&mut TenantCounters)) {
        let mut per = locked(&self.per_tenant);
        match per.iter_mut().find(|t| t.tenant == tenant) {
            Some(t) => f(t),
            None => {
                let mut t =
                    TenantCounters { tenant: tenant.to_string(), admitted: 0, backpressured: 0 };
                f(&mut t);
                per.push(t);
            }
        }
    }

    /// Per-tenant admission snapshot.
    pub fn tenant_counters(&self) -> Vec<TenantCounters> {
        locked(&self.per_tenant).clone()
    }

    /// One shard (re)spawn for the shape labelled `shard`.
    pub fn record_shard_spawn(&self, shard: &str) {
        self.with_shard(shard, |s| s.spawns += 1);
    }

    /// One batch of `jobs` closed toward `shard`.
    pub fn record_shard_batch(&self, shard: &str, jobs: usize) {
        self.with_shard(shard, |s| {
            s.batches += 1;
            s.jobs += jobs as u64;
        });
    }

    /// Warm-arena reuse hits attributed to `shard`.
    pub fn record_shard_arena_reuse(&self, shard: &str, hits: u64) {
        if hits > 0 {
            self.with_shard(shard, |s| s.arena_reuse_hits += hits);
        }
    }

    /// Refresh `shard`'s accumulating-jobs gauge.
    pub fn set_shard_pending(&self, shard: &str, pending: u64) {
        self.with_shard(shard, |s| s.pending = pending);
    }

    /// One reap (idle TTL) or LRU eviction of `shard`.
    pub fn record_shard_reap(&self, shard: &str) {
        self.with_shard(shard, |s| {
            s.reaps += 1;
            s.pending = 0;
        });
    }

    fn with_shard(&self, shard: &str, f: impl FnOnce(&mut ShardCounters)) {
        let mut per = locked(&self.per_shard);
        match per.iter_mut().find(|s| s.shard == shard) {
            Some(s) => f(s),
            None => {
                let mut s = ShardCounters {
                    shard: shard.to_string(),
                    spawns: 0,
                    batches: 0,
                    jobs: 0,
                    arena_reuse_hits: 0,
                    pending: 0,
                    reaps: 0,
                };
                f(&mut s);
                per.push(s);
            }
        }
    }

    /// Per-shard serving snapshot.
    pub fn shard_counters(&self) -> Vec<ShardCounters> {
        locked(&self.per_shard).clone()
    }

    /// Per-key batch occupancy snapshot.
    pub fn batch_counters(&self) -> Vec<BatchCounters> {
        locked(&self.per_batch_key).clone()
    }

    pub fn record_done(&self, engine: &'static str, ok: bool, queued: f64, solve: f64) {
        if ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        self.dec_queue_depth();
        let total = queued + solve;
        let idx = LATENCY_BUCKETS.iter().position(|&ub| total <= ub).unwrap_or(9);
        self.latency[idx].fetch_add(1, Ordering::Relaxed);
        *locked(&self.queue_secs_total) += queued;
        *locked(&self.solve_secs_total) += solve;
        self.with_engine(engine, |e| {
            e.jobs += 1;
            e.latency[idx] += 1;
        });
    }

    /// Fold `count` solver progress events (phases completed) into
    /// `engine`'s counters. The worker accumulates per-job in an atomic and
    /// folds once here, so the metrics lock is taken per job, not per phase.
    pub fn record_phases(&self, engine: &'static str, count: u64) {
        if count > 0 {
            self.with_engine(engine, |e| e.phases += count);
        }
    }

    /// Count one warm-started job (ε-scaling schedule or batch dual
    /// carry) against `engine`.
    pub fn record_warm_start(&self, engine: &'static str) {
        self.with_engine(engine, |e| e.warm_started += 1);
    }

    /// Count one `Engine::Auto` job the router resolved to `engine` — the
    /// observability hook for the shared routing table.
    pub fn record_auto_route(&self, engine: &'static str) {
        self.with_engine(engine, |e| e.auto_routed += 1);
    }

    /// Accumulate a completed job's plan-representation bytes
    /// (`SolveStats::plan_state_bytes`) against `engine` — the serve
    /// layer's view of how much plan memory each backend's answers carry
    /// (O(nnz) CSR for the kernel engines vs the dense solvers' slabs).
    pub fn record_plan_bytes(&self, engine: &'static str, bytes: u64) {
        if bytes > 0 {
            self.with_engine(engine, |e| e.plan_bytes += bytes);
        }
    }

    fn with_engine(&self, engine: &'static str, f: impl FnOnce(&mut EngineCounters)) {
        let mut per = locked(&self.per_engine);
        match per.iter_mut().find(|e| e.engine == engine) {
            Some(e) => f(e),
            None => {
                let mut e = EngineCounters {
                    engine,
                    jobs: 0,
                    phases: 0,
                    warm_started: 0,
                    auto_routed: 0,
                    plan_bytes: 0,
                    latency: [0; LATENCY_BUCKETS.len()],
                };
                f(&mut e);
                per.push(e);
            }
        }
    }

    /// Fold one audit-mode certificate into the pass/fail counters and
    /// (when it carries a dual gap) the gap/bound-ratio histogram.
    pub fn record_audit(&self, cert: &Certificate) {
        self.audited.fetch_add(1, Ordering::Relaxed);
        if cert.ok() {
            self.audit_pass.fetch_add(1, Ordering::Relaxed);
        } else {
            self.audit_fail.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(gap) = cert.gap {
            self.audit_gaps[gap_ratio_bucket(gap, cert.bound)].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// (audited, pass, fail) snapshot.
    pub fn audit_counters(&self) -> (u64, u64, u64) {
        (
            self.audited.load(Ordering::Relaxed),
            self.audit_pass.load(Ordering::Relaxed),
            self.audit_fail.load(Ordering::Relaxed),
        )
    }

    /// Audit pass/fail + gap histogram as JSON (serve-layer export; same
    /// shape as the conformance runner's artifact).
    pub fn audit_json(&self) -> Json {
        let (audited, pass, fail) = self.audit_counters();
        obj(vec![
            ("audited", Json::Num(audited as f64)),
            ("pass", Json::Num(pass as f64)),
            ("fail", Json::Num(fail as f64)),
            (
                "bucket_upper_bounds",
                Json::Arr(
                    GAP_RATIO_BUCKETS
                        .iter()
                        .map(|&b| if b.is_finite() { Json::Num(b) } else { Json::Null })
                        .collect(),
                ),
            ),
            (
                "counts",
                Json::Arr(
                    self.audit_gaps
                        .iter()
                        .map(|c| Json::Num(c.load(Ordering::Relaxed) as f64))
                        .collect(),
                ),
            ),
        ])
    }

    /// Per-engine counters snapshot (jobs + phase events).
    pub fn engine_counters(&self) -> Vec<EngineCounters> {
        locked(&self.per_engine).clone()
    }

    /// Full metrics export for the serve layer's `/metrics` JSON
    /// (`otpr serve --metrics-out`): job counters, per-key batch
    /// occupancy + wait, kernel-arena reuse hits, per-engine phase
    /// counters, and the audit section.
    pub fn to_json(&self) -> Json {
        let batch_keys = self
            .batch_counters()
            .into_iter()
            .map(|c| {
                obj(vec![
                    ("key", Json::Str(c.key.clone())),
                    ("batches", Json::Num(c.batches as f64)),
                    ("jobs", Json::Num(c.jobs as f64)),
                    ("occupancy", Json::Num(c.occupancy())),
                    ("mean_wait_us", Json::Num(c.mean_wait_us())),
                ])
            })
            .collect();
        let engines = self
            .engine_counters()
            .into_iter()
            .map(|e| {
                // Percentiles as bucket upper bounds; the overflow bucket
                // and "no jobs yet" both export as null (JSON has no inf).
                let pct = e.latency_percentiles();
                let q = |pick: fn((f64, f64, f64)) -> f64| match pct.map(pick) {
                    Some(v) if v.is_finite() => Json::Num(v),
                    _ => Json::Null,
                };
                obj(vec![
                    ("engine", Json::Str(e.engine.to_string())),
                    ("jobs", Json::Num(e.jobs as f64)),
                    ("phase_events", Json::Num(e.phases as f64)),
                    ("warm_started_jobs", Json::Num(e.warm_started as f64)),
                    ("auto_routed_jobs", Json::Num(e.auto_routed as f64)),
                    ("plan_state_bytes", Json::Num(e.plan_bytes as f64)),
                    ("latency_p50_s", q(|p| p.0)),
                    ("latency_p95_s", q(|p| p.1)),
                    ("latency_p99_s", q(|p| p.2)),
                    (
                        "latency_counts",
                        Json::Arr(e.latency.iter().map(|&c| Json::Num(c as f64)).collect()),
                    ),
                ])
            })
            .collect();
        let shards = self
            .shard_counters()
            .into_iter()
            .map(|s| {
                obj(vec![
                    ("shard", Json::Str(s.shard.clone())),
                    ("spawns", Json::Num(s.spawns as f64)),
                    ("batches", Json::Num(s.batches as f64)),
                    ("jobs", Json::Num(s.jobs as f64)),
                    ("occupancy", Json::Num(s.occupancy())),
                    ("arena_reuse_hits", Json::Num(s.arena_reuse_hits as f64)),
                    ("arena_reuse_rate", Json::Num(s.arena_reuse_rate())),
                    ("pending", Json::Num(s.pending as f64)),
                    ("reaps", Json::Num(s.reaps as f64)),
                ])
            })
            .collect();
        let tenants = self
            .tenant_counters()
            .into_iter()
            .map(|t| {
                obj(vec![
                    ("tenant", Json::Str(t.tenant.clone())),
                    ("admitted", Json::Num(t.admitted as f64)),
                    ("backpressured", Json::Num(t.backpressured as f64)),
                ])
            })
            .collect();
        let cache = obj(vec![
            ("hits", Json::Num(self.cache_hits.load(Ordering::Relaxed) as f64)),
            ("misses", Json::Num(self.cache_misses.load(Ordering::Relaxed) as f64)),
            ("evictions", Json::Num(self.cache_evictions.load(Ordering::Relaxed) as f64)),
            ("bytes", Json::Num(self.cache_bytes() as f64)),
        ]);
        let batches = self.batches.load(Ordering::Relaxed);
        let batched = self.batched_jobs.load(Ordering::Relaxed);
        obj(vec![
            ("submitted", Json::Num(self.submitted.load(Ordering::Relaxed) as f64)),
            ("completed", Json::Num(self.completed.load(Ordering::Relaxed) as f64)),
            ("failed", Json::Num(self.failed.load(Ordering::Relaxed) as f64)),
            ("rejected", Json::Num(self.rejected.load(Ordering::Relaxed) as f64)),
            ("batches", Json::Num(batches as f64)),
            ("batched_jobs", Json::Num(batched as f64)),
            (
                "batch_occupancy",
                Json::Num(if batches > 0 { batched as f64 / batches as f64 } else { 0.0 }),
            ),
            (
                "arena_reuse_hits",
                Json::Num(self.arena_reuse_hits.load(Ordering::Relaxed) as f64),
            ),
            ("shed", Json::Num(self.shed.load(Ordering::Relaxed) as f64)),
            ("degraded", Json::Num(self.degraded.load(Ordering::Relaxed) as f64)),
            ("retried", Json::Num(self.retried.load(Ordering::Relaxed) as f64)),
            ("worker_panics", Json::Num(self.worker_panics.load(Ordering::Relaxed) as f64)),
            (
                "worker_restarts",
                Json::Num(self.worker_restarts.load(Ordering::Relaxed) as f64),
            ),
            ("abandoned_jobs", Json::Num(self.abandoned_jobs.load(Ordering::Relaxed) as f64)),
            (
                "backpressured_jobs",
                Json::Num(self.backpressured_jobs.load(Ordering::Relaxed) as f64),
            ),
            ("queue_depth", Json::Num(self.queue_depth() as f64)),
            ("cache", cache),
            ("shards", Json::Arr(shards)),
            ("tenants", Json::Arr(tenants)),
            ("batch_keys", Json::Arr(batch_keys)),
            ("engines", Json::Arr(engines)),
            ("audit", self.audit_json()),
        ])
    }

    pub fn snapshot(&self) -> String {
        let sub = self.submitted.load(Ordering::Relaxed);
        let done = self.completed.load(Ordering::Relaxed);
        let failed = self.failed.load(Ordering::Relaxed);
        let rejected = self.rejected.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let batched = self.batched_jobs.load(Ordering::Relaxed);
        let mut out = format!(
            "jobs: submitted={sub} completed={done} failed={failed} rejected={rejected}\n"
        );
        let shed = self.shed.load(Ordering::Relaxed);
        let degraded = self.degraded.load(Ordering::Relaxed);
        let retried = self.retried.load(Ordering::Relaxed);
        let panics = self.worker_panics.load(Ordering::Relaxed);
        let restarts = self.worker_restarts.load(Ordering::Relaxed);
        let abandoned = self.abandoned_jobs.load(Ordering::Relaxed);
        if shed + degraded + retried + panics + restarts + abandoned > 0 {
            out.push_str(&format!(
                "faults: shed={shed} degraded={degraded} retried={retried} \
                 worker-panics={panics} worker-restarts={restarts} abandoned={abandoned}\n"
            ));
        }
        let depth = self.queue_depth();
        if depth > 0 {
            out.push_str(&format!("queue depth: {depth}\n"));
        }
        if batches > 0 {
            out.push_str(&format!(
                "batches: {batches} (avg {:.2} jobs/batch)\n",
                batched as f64 / batches as f64
            ));
            for c in self.batch_counters() {
                out.push_str(&format!(
                    "  batch[{}]: {} batches, avg {:.2} jobs, avg wait {:.0}µs\n",
                    c.key,
                    c.batches,
                    c.occupancy(),
                    c.mean_wait_us()
                ));
            }
        }
        let reuse = self.arena_reuse_hits.load(Ordering::Relaxed);
        if reuse > 0 {
            out.push_str(&format!("kernel arena reuse hits: {reuse}\n"));
        }
        for s in self.shard_counters() {
            out.push_str(&format!(
                "shard[{}]: {} spawns, {} batches, {} jobs, reuse rate {:.2}, pending {}, \
                 reaps {}\n",
                s.shard,
                s.spawns,
                s.batches,
                s.jobs,
                s.arena_reuse_rate(),
                s.pending,
                s.reaps
            ));
        }
        let (hits, misses) = (
            self.cache_hits.load(Ordering::Relaxed),
            self.cache_misses.load(Ordering::Relaxed),
        );
        if hits + misses > 0 {
            out.push_str(&format!(
                "cache: hits={hits} misses={misses} evictions={} bytes={}\n",
                self.cache_evictions.load(Ordering::Relaxed),
                self.cache_bytes()
            ));
        }
        let bp = self.backpressured_jobs.load(Ordering::Relaxed);
        if bp > 0 {
            out.push_str(&format!("backpressured jobs: {bp}\n"));
        }
        for t in self.tenant_counters() {
            out.push_str(&format!(
                "tenant {}: admitted={} backpressured={}\n",
                t.tenant, t.admitted, t.backpressured
            ));
        }
        out.push_str(&format!(
            "time: queued={:.3}s solve={:.3}s\n",
            *locked(&self.queue_secs_total),
            *locked(&self.solve_secs_total)
        ));
        out.push_str("latency histogram (s):");
        for (i, ub) in LATENCY_BUCKETS.iter().enumerate() {
            let c = self.latency[i].load(Ordering::Relaxed);
            if c > 0 {
                if ub.is_infinite() {
                    out.push_str(&format!(" inf:{c}"));
                } else {
                    out.push_str(&format!(" {ub}:{c}"));
                }
            }
        }
        out.push('\n');
        let (audited, pass, fail) = self.audit_counters();
        if audited > 0 {
            out.push_str(&format!("audit: sampled={audited} pass={pass} fail={fail}\n"));
            out.push_str("audit gap/bound histogram:");
            for (i, ub) in GAP_RATIO_BUCKETS.iter().enumerate() {
                let c = self.audit_gaps[i].load(Ordering::Relaxed);
                if c > 0 {
                    if ub.is_infinite() {
                        out.push_str(&format!(" inf:{c}"));
                    } else {
                        out.push_str(&format!(" {ub}:{c}"));
                    }
                }
            }
            out.push('\n');
        }
        for e in locked(&self.per_engine).iter() {
            out.push_str(&format!(
                "engine {}: {} jobs, {} phase-events, {} warm-started, {} auto-routed, \
                 {} plan-bytes",
                e.engine, e.jobs, e.phases, e.warm_started, e.auto_routed, e.plan_bytes
            ));
            if let Some((p50, p95, p99)) = e.latency_percentiles() {
                let fmt = |v: f64| {
                    if v.is_finite() {
                        format!("{v}")
                    } else {
                        "inf".to_string()
                    }
                };
                out.push_str(&format!(
                    ", p50/p95/p99 {}/{}/{}s",
                    fmt(p50),
                    fmt(p95),
                    fmt(p99)
                ));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_submit();
        m.record_submit();
        m.record_batch("native-seq", 2, 120);
        m.record_done("native-seq", true, 0.001, 0.02);
        m.record_done("xla", false, 0.0, 0.5);
        let snap = m.snapshot();
        assert!(snap.contains("submitted=2"));
        assert!(snap.contains("completed=1"));
        assert!(snap.contains("failed=1"));
        assert!(snap.contains("engine native-seq: 1"));
        assert!(snap.contains("avg 2.00 jobs/batch"));
        assert!(snap.contains("batch[native-seq]: 1 batches, avg 2.00 jobs"), "{snap}");
    }

    #[test]
    fn batch_keys_accumulate_occupancy_and_wait() {
        let m = Metrics::new();
        m.record_batch("xla/256", 4, 100);
        m.record_batch("xla/256", 2, 300);
        m.record_batch("native-seq", 8, 50);
        let counters = m.batch_counters();
        let xla = counters.iter().find(|c| c.key == "xla/256").unwrap();
        assert_eq!((xla.batches, xla.jobs), (2, 6));
        assert!((xla.occupancy() - 3.0).abs() < 1e-12);
        assert!((xla.mean_wait_us() - 200.0).abs() < 1e-12);
        m.record_arena_reuse(7);
        m.record_arena_reuse(0); // no-op
        assert_eq!(m.arena_reuse_hits.load(Ordering::Relaxed), 7);
        assert!(m.snapshot().contains("kernel arena reuse hits: 7"));
    }

    #[test]
    fn metrics_json_exposes_batch_occupancy() {
        let m = Metrics::new();
        m.record_submit();
        m.record_batch("native-seq", 8, 1500);
        m.record_done("native-seq", true, 0.001, 0.02);
        m.record_arena_reuse(7);
        let j = Json::parse(&m.to_json().to_string()).expect("valid JSON");
        assert_eq!(j.get("batch_occupancy").unwrap().as_f64(), Some(8.0));
        assert_eq!(j.get("arena_reuse_hits").unwrap().as_f64(), Some(7.0));
        let keys = j.get("batch_keys").unwrap().as_arr().unwrap();
        assert_eq!(keys.len(), 1);
        assert_eq!(keys[0].get("occupancy").unwrap().as_f64(), Some(8.0));
        assert_eq!(keys[0].get("mean_wait_us").unwrap().as_f64(), Some(1500.0));
        assert!(j.get("audit").is_some());
        assert_eq!(j.get("engines").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn phase_events_tracked_per_engine() {
        let m = Metrics::new();
        m.record_phases("native-seq", 2);
        m.record_done("native-seq", true, 0.0, 0.1);
        m.record_phases("sinkhorn-native", 1);
        m.record_phases("sinkhorn-native", 0); // no-op, must not create churn
        let counters = m.engine_counters();
        let seq = counters.iter().find(|e| e.engine == "native-seq").unwrap();
        assert_eq!((seq.jobs, seq.phases), (1, 2));
        let sk = counters.iter().find(|e| e.engine == "sinkhorn-native").unwrap();
        assert_eq!((sk.jobs, sk.phases), (0, 1));
        assert!(m.snapshot().contains("engine native-seq: 1 jobs, 2 phase-events"));
    }

    #[test]
    fn warm_start_counter_tracked_per_engine_and_exported() {
        let m = Metrics::new();
        m.record_warm_start("native-vector-warm");
        m.record_warm_start("native-vector-warm");
        m.record_done("native-vector-warm", true, 0.0, 0.1);
        let counters = m.engine_counters();
        let e = counters.iter().find(|e| e.engine == "native-vector-warm").unwrap();
        assert_eq!((e.jobs, e.warm_started), (1, 2));
        assert!(m.snapshot().contains("2 warm-started"), "{}", m.snapshot());
        let j = Json::parse(&m.to_json().to_string()).unwrap();
        let engines = j.get("engines").unwrap().as_arr().unwrap();
        assert_eq!(engines.len(), 1);
        assert_eq!(engines[0].get("warm_started_jobs").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn auto_route_counter_tracked_per_engine_and_exported() {
        let m = Metrics::new();
        m.record_auto_route("native-hybrid");
        m.record_auto_route("native-hybrid");
        m.record_auto_route("native-seq");
        m.record_done("native-hybrid", true, 0.0, 0.1);
        let counters = m.engine_counters();
        let h = counters.iter().find(|e| e.engine == "native-hybrid").unwrap();
        assert_eq!((h.jobs, h.auto_routed), (1, 2));
        let s = counters.iter().find(|e| e.engine == "native-seq").unwrap();
        assert_eq!(s.auto_routed, 1);
        assert!(m.snapshot().contains("2 auto-routed"), "{}", m.snapshot());
        let j = Json::parse(&m.to_json().to_string()).unwrap();
        let engines = j.get("engines").unwrap().as_arr().unwrap();
        let hy = engines
            .iter()
            .find(|e| e.get("engine").unwrap().as_str() == Some("native-hybrid"))
            .unwrap();
        assert_eq!(hy.get("auto_routed_jobs").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn plan_bytes_tracked_per_engine_and_exported() {
        let m = Metrics::new();
        m.record_plan_bytes("native-vector", 640);
        m.record_plan_bytes("native-vector", 360);
        m.record_plan_bytes("native-seq", 0); // no-op, must not create churn
        m.record_done("native-vector", true, 0.0, 0.1);
        let counters = m.engine_counters();
        let v = counters.iter().find(|e| e.engine == "native-vector").unwrap();
        assert_eq!(v.plan_bytes, 1000);
        assert!(counters.iter().all(|e| e.engine != "native-seq"));
        assert!(m.snapshot().contains("1000 plan-bytes"), "{}", m.snapshot());
        let j = Json::parse(&m.to_json().to_string()).unwrap();
        let engines = j.get("engines").unwrap().as_arr().unwrap();
        assert_eq!(engines[0].get("plan_state_bytes").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn audit_counters_and_histogram() {
        let m = Metrics::new();
        let mut cert = Certificate {
            primal_ok: true,
            dual_ok: Some(true),
            gap: Some(0.05),
            dual_lower_bound: Some(0.0),
            bound: 1.0,
            cost: 0.05,
            detail: None,
        };
        m.record_audit(&cert); // ratio 0.05 → first bucket
        cert.gap = Some(2.0); // ratio 2.0 → overflow bucket, gap_ok false
        m.record_audit(&cert);
        cert.gap = None;
        cert.primal_ok = false;
        m.record_audit(&cert); // fail without a gap: counters only
        assert_eq!(m.audit_counters(), (3, 1, 2));
        let snap = m.snapshot();
        assert!(snap.contains("audit: sampled=3 pass=1 fail=2"), "{snap}");
        assert!(snap.contains("0.1:1"), "{snap}");
        assert!(snap.contains("inf:1"), "{snap}");
        let j = Json::parse(&m.audit_json().to_string()).unwrap();
        assert_eq!(j.get("audited").unwrap().as_usize(), Some(3));
        let counts: f64 = j
            .get("counts")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|c| c.as_f64().unwrap())
            .sum();
        assert_eq!(counts as u64, 2, "only dual-certified audits land in the histogram");
    }

    #[test]
    fn fault_counters_and_queue_depth_gauge() {
        let m = Metrics::new();
        assert_eq!(m.queue_depth(), 0);
        m.record_submit();
        m.record_submit();
        m.record_submit();
        m.record_submit();
        assert_eq!(m.queue_depth(), 4);
        m.record_reject();
        m.record_shed();
        m.record_retry(); // not terminal: depth unchanged
        assert_eq!(m.queue_depth(), 2);
        m.record_degraded();
        m.record_done("native-seq", true, 0.0, 0.01); // the degraded job lands
        m.record_done("native-seq", false, 0.0, 0.01);
        assert_eq!(m.queue_depth(), 0);
        m.record_worker_panic();
        m.record_worker_restart();
        m.record_abandoned();
        let snap = m.snapshot();
        assert!(
            snap.contains(
                "faults: shed=1 degraded=1 retried=1 worker-panics=1 worker-restarts=1 \
                 abandoned=1"
            ),
            "{snap}"
        );
        assert!(!snap.contains("queue depth:"), "drained gauge stays silent: {snap}");
        let j = Json::parse(&m.to_json().to_string()).expect("valid JSON");
        let keys =
            ["shed", "degraded", "retried", "worker_panics", "worker_restarts", "abandoned_jobs"];
        for key in keys {
            assert_eq!(j.get(key).and_then(|v| v.as_f64()), Some(1.0), "{key}");
        }
        assert_eq!(j.get("queue_depth").and_then(|v| v.as_f64()), Some(0.0));
        // shed/rejected jobs never count as failed
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn queue_depth_saturates_at_zero() {
        let m = Metrics::new();
        m.record_shed(); // stray decrement on an empty gauge
        assert_eq!(m.queue_depth(), 0, "gauge must not wrap");
        m.record_submit();
        assert_eq!(m.queue_depth(), 1);
    }

    #[test]
    fn per_engine_latency_percentiles() {
        let m = Metrics::new();
        for _ in 0..98 {
            m.record_done("e", true, 0.0, 0.0005); // ≤ 0.001 bucket
        }
        m.record_done("e", true, 0.0, 0.08); // ≤ 0.1 bucket
        m.record_done("e", true, 0.0, 100.0); // overflow bucket
        let counters = m.engine_counters();
        let e = counters.iter().find(|e| e.engine == "e").unwrap();
        let (p50, p95, p99) = e.latency_percentiles().unwrap();
        assert_eq!(p50, 0.001);
        assert_eq!(p95, 0.001);
        assert_eq!(p99, 0.1);
        let j = Json::parse(&m.to_json().to_string()).expect("valid JSON");
        let engines = j.get("engines").unwrap().as_arr().unwrap();
        assert_eq!(engines[0].get("latency_p50_s").unwrap().as_f64(), Some(0.001));
        assert_eq!(engines[0].get("latency_p99_s").unwrap().as_f64(), Some(0.1));
        // p100 would be inf; check the snapshot renders percentiles
        assert!(m.snapshot().contains("p50/p95/p99 0.001/0.001/0.1s"), "{}", m.snapshot());
        // untouched engines export null percentiles, not 0
        m.record_phases("idle", 1);
        let j = Json::parse(&m.to_json().to_string()).expect("valid JSON");
        let engines = j.get("engines").unwrap().as_arr().unwrap();
        let idle = engines
            .iter()
            .find(|e| e.get("engine").unwrap().as_str() == Some("idle"))
            .unwrap();
        assert!(idle.get("latency_p50_s").unwrap().as_f64().is_none());
    }

    #[test]
    fn cache_and_backpressure_counters_export() {
        let m = Metrics::new();
        m.record_cache_miss();
        m.record_cache_hit();
        m.record_cache_hit();
        m.record_cache_insert(0, 512);
        m.record_cache_insert(3, 384); // evictions accumulate, bytes is a gauge
        m.record_backpressure("tenant-a");
        m.record_admitted("tenant-a");
        m.record_admitted("tenant-b");
        assert_eq!(m.cache_bytes(), 384);
        assert_eq!(m.cache_evictions.load(Ordering::Relaxed), 3);
        let snap = m.snapshot();
        assert!(snap.contains("cache: hits=2 misses=1 evictions=3 bytes=384"), "{snap}");
        assert!(snap.contains("backpressured jobs: 1"), "{snap}");
        assert!(snap.contains("tenant tenant-a: admitted=1 backpressured=1"), "{snap}");
        let j = Json::parse(&m.to_json().to_string()).expect("valid JSON");
        let cache = j.get("cache").unwrap();
        assert_eq!(cache.get("hits").unwrap().as_f64(), Some(2.0));
        assert_eq!(cache.get("misses").unwrap().as_f64(), Some(1.0));
        assert_eq!(cache.get("evictions").unwrap().as_f64(), Some(3.0));
        assert_eq!(cache.get("bytes").unwrap().as_f64(), Some(384.0));
        assert_eq!(j.get("backpressured_jobs").unwrap().as_f64(), Some(1.0));
        let tenants = j.get("tenants").unwrap().as_arr().unwrap();
        assert_eq!(tenants.len(), 2);
    }

    #[test]
    fn shard_counters_track_occupancy_reuse_and_lifecycle() {
        let m = Metrics::new();
        m.record_shard_spawn("asg/16x16");
        m.record_shard_batch("asg/16x16", 8);
        m.record_shard_batch("asg/16x16", 4);
        m.record_shard_arena_reuse("asg/16x16", 10);
        m.record_shard_arena_reuse("asg/16x16", 0); // no-op, must not churn
        m.set_shard_pending("asg/16x16", 3);
        m.record_shard_spawn("ot/10x10");
        m.record_shard_reap("ot/10x10");
        let counters = m.shard_counters();
        let a = counters.iter().find(|s| s.shard == "asg/16x16").unwrap();
        assert_eq!((a.spawns, a.batches, a.jobs, a.arena_reuse_hits), (1, 2, 12, 10));
        assert!((a.occupancy() - 6.0).abs() < 1e-12);
        assert!((a.arena_reuse_rate() - 10.0 / 12.0).abs() < 1e-12);
        assert_eq!(a.pending, 3);
        let o = counters.iter().find(|s| s.shard == "ot/10x10").unwrap();
        assert_eq!((o.spawns, o.reaps, o.pending), (1, 1, 0));
        let snap = m.snapshot();
        assert!(snap.contains("shard[asg/16x16]: 1 spawns, 2 batches, 12 jobs"), "{snap}");
        let j = Json::parse(&m.to_json().to_string()).expect("valid JSON");
        let shards = j.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 2);
        let sa = shards
            .iter()
            .find(|s| s.get("shard").unwrap().as_str() == Some("asg/16x16"))
            .unwrap();
        assert_eq!(sa.get("occupancy").unwrap().as_f64(), Some(6.0));
        assert!((sa.get("arena_reuse_rate").unwrap().as_f64().unwrap() - 10.0 / 12.0).abs() < 1e-9);
    }

    #[test]
    fn no_audit_lines_when_unused() {
        let m = Metrics::new();
        m.record_done("e", true, 0.0, 0.1);
        assert!(!m.snapshot().contains("audit:"));
    }

    #[test]
    fn histogram_bucketing() {
        let m = Metrics::new();
        m.record_done("e", true, 0.0, 0.0005); // ≤ 0.001
        m.record_done("e", true, 0.0, 100.0); // inf bucket
        let snap = m.snapshot();
        assert!(snap.contains("0.001:1"), "{snap}");
        assert!(snap.contains("inf:1"), "{snap}");
    }
}
