//! The `(problem digest, ε, engine)` result cache.
//!
//! Serving real traffic means serving *repeated* traffic: the same
//! distributions re-solved at the same ε (dashboards, retries, fan-out
//! consumers). A hit here bypasses dispatch entirely — no shard, no
//! batcher, no kernel — and returns a `Solution` byte-identical to the
//! fresh solve that populated the entry (golden-pinned in
//! `tests/serving_layer.rs`).
//!
//! Keys combine [`crate::coordinator::digest::problem_digest`] with every
//! request knob that changes the answer payload: ε bits, ε semantics, the
//! *resolved* engine (an `Engine::Auto` job is keyed under the engine it
//! actually routes to), and whether a certificate was requested. Jobs
//! whose problems have no canonical payload (closure-backed costs) never
//! reach the cache at all.
//!
//! Capacity is bounded by bytes, not entries — entry weight reuses the
//! `plan_state_bytes`/`cost_state_bytes` style of accounting (the CSR
//! wire bytes we actually store, duals, certificate, fixed overhead) —
//! with least-recently-used eviction on overflow. CSR plans are stored in
//! the compact [`TransportPlan::to_bytes`] wire form and re-validated on
//! the way out, so the cache holds O(nnz) bytes per OT entry, not O(n²).

use crate::api::{Certificate, Coupling, Solution};
use crate::core::{DualWeights, Matching, TransportPlan};
use crate::solvers::SolveStats;
use std::collections::HashMap;
use std::sync::{Mutex, PoisonError};

/// What makes two jobs share an answer. `engine` is the canonical
/// registry key of the engine that actually ran (Auto resolves first).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// [`crate::coordinator::digest::problem_digest`] of the payload.
    pub digest: u64,
    /// `eps.to_bits()` — exact, no float comparisons.
    pub eps_bits: u64,
    /// `true` for [`crate::api::EpsSemantics::AlgorithmParam`] requests.
    pub raw_eps: bool,
    /// Canonical engine key the job resolved to.
    pub engine: &'static str,
    /// Certified and uncertified answers are different payloads.
    pub want_certificate: bool,
}

/// A stored coupling: matchings and the rare dense/product plans are kept
/// as-is; CSR plans live as compact wire bytes.
enum StoredCoupling {
    Matching(Matching),
    PlanBytes(Vec<u8>),
    Plan(TransportPlan),
}

struct StoredSolution {
    coupling: StoredCoupling,
    cost: f64,
    duals: Option<DualWeights>,
    certificate: Option<Certificate>,
    stats: SolveStats,
}

struct Entry {
    value: StoredSolution,
    bytes: u64,
    /// Monotone LRU clock value of the last touch.
    tick: u64,
}

#[derive(Default)]
struct Inner {
    map: HashMap<CacheKey, Entry>,
    bytes: u64,
    tick: u64,
}

/// Outcome of an insert, for the metrics layer.
#[derive(Debug, Default, Clone, Copy)]
pub struct InsertReport {
    /// Entries evicted to make room (0 when the value didn't fit at all).
    pub evictions: u64,
    /// Resident bytes after the insert.
    pub bytes: u64,
    /// Whether the value was actually stored (false ⇒ larger than the
    /// whole cache budget).
    pub stored: bool,
}

/// Byte-bounded LRU result cache. All methods take `&self`; one mutex
/// guards the map (lookups are rare relative to solves, and entries are
/// swapped out by value, so the critical sections stay short).
pub struct ResultCache {
    cap_bytes: u64,
    inner: Mutex<Inner>,
}

/// Poison recovery, same convention as `coordinator::metrics`: a panicked
/// worker died *between* atomic updates, never mid-invariant — recover the
/// guard rather than cascading the panic into every later caller.
fn locked<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl ResultCache {
    /// `cap_bytes == 0` disables the cache (every lookup misses, every
    /// insert is dropped) — the default, so serving behavior only changes
    /// when a deployment opts in.
    pub fn new(cap_bytes: u64) -> Self {
        Self { cap_bytes, inner: Mutex::new(Inner::default()) }
    }

    pub fn enabled(&self) -> bool {
        self.cap_bytes > 0
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.cap_bytes
    }

    pub fn bytes(&self) -> u64 {
        locked(&self.inner).bytes
    }

    pub fn len(&self) -> usize {
        locked(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up a stored answer, refreshing its LRU position. Returns a
    /// freshly materialized `Solution` (stored wire bytes are re-validated
    /// through `from_bytes` → `from_csr` on every hit).
    pub fn get(&self, key: &CacheKey) -> Option<Solution> {
        if !self.enabled() {
            return None;
        }
        let mut inner = locked(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        let entry = inner.map.get_mut(key)?;
        entry.tick = tick;
        let sol = materialize(&entry.value);
        if sol.is_none() {
            // A stored entry that no longer decodes is a corrupt entry;
            // drop it so it cannot shadow fresh solves.
            let bytes = entry.bytes;
            inner.map.remove(key);
            inner.bytes = inner.bytes.saturating_sub(bytes);
        }
        sol
    }

    /// Store a fresh answer under `key`, evicting least-recently-used
    /// entries until it fits. Oversized values (weight > whole budget) are
    /// rejected rather than flushing the entire cache for one entry.
    pub fn insert(&self, key: CacheKey, sol: &Solution) -> InsertReport {
        if !self.enabled() {
            return InsertReport::default();
        }
        let value = store(sol);
        let weight = weigh(&value);
        let mut report = InsertReport { stored: weight <= self.cap_bytes, ..Default::default() };
        let mut inner = locked(&self.inner);
        if !report.stored {
            report.bytes = inner.bytes;
            return report;
        }
        if let Some(old) = inner.map.remove(&key) {
            inner.bytes = inner.bytes.saturating_sub(old.bytes);
        }
        while inner.bytes + weight > self.cap_bytes {
            let Some(lru) = inner.map.iter().min_by_key(|(_, e)| e.tick).map(|(k, _)| k.clone())
            else {
                break;
            };
            if let Some(evicted) = inner.map.remove(&lru) {
                inner.bytes = inner.bytes.saturating_sub(evicted.bytes);
                report.evictions += 1;
            }
        }
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(key, Entry { value, bytes: weight, tick });
        inner.bytes += weight;
        report.bytes = inner.bytes;
        report
    }
}

/// Convert a live solution into its stored form. CSR plans go to wire
/// bytes; everything else is cloned structurally.
fn store(sol: &Solution) -> StoredSolution {
    let coupling = match &sol.coupling {
        Coupling::Matching(m) => StoredCoupling::Matching(m.clone()),
        Coupling::Plan(p) => match p.to_bytes() {
            Some(bytes) => StoredCoupling::PlanBytes(bytes),
            None => StoredCoupling::Plan(p.clone()),
        },
    };
    StoredSolution {
        coupling,
        cost: sol.cost,
        duals: sol.duals.clone(),
        certificate: sol.certificate.clone(),
        stats: sol.stats.clone(),
    }
}

/// Rebuild the `Solution` a hit returns. `None` only if stored bytes fail
/// re-validation, which [`ResultCache::get`] treats as a dropped entry.
fn materialize(stored: &StoredSolution) -> Option<Solution> {
    let coupling = match &stored.coupling {
        StoredCoupling::Matching(m) => Coupling::Matching(m.clone()),
        StoredCoupling::PlanBytes(bytes) => {
            Coupling::Plan(TransportPlan::from_bytes(bytes).ok()?)
        }
        StoredCoupling::Plan(p) => Coupling::Plan(p.clone()),
    };
    Some(Solution {
        coupling,
        cost: stored.cost,
        duals: stored.duals.clone(),
        certificate: stored.certificate.clone(),
        stats: stored.stats.clone(),
    })
}

/// Entry weight in resident bytes — the same style of accounting as
/// `SolveStats::{plan_state_bytes, cost_state_bytes}`: count what this
/// representation actually keeps resident, plus a fixed overhead for the
/// key, map slot, and scalar fields.
fn weigh(v: &StoredSolution) -> u64 {
    const FIXED: u64 = 256;
    let coupling = match &v.coupling {
        StoredCoupling::Matching(m) => ((m.match_b.len() + m.match_a.len()) * 4) as u64,
        StoredCoupling::PlanBytes(bytes) => bytes.len() as u64,
        StoredCoupling::Plan(p) => p.state_bytes(),
    };
    let duals = v.duals.as_ref().map_or(0, |d| ((d.ya.len() + d.yb.len()) * 4) as u64);
    let notes: u64 = v.stats.notes.iter().map(|n| n.len() as u64).sum();
    FIXED + coupling + duals + notes
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(digest: u64) -> CacheKey {
        CacheKey {
            digest,
            eps_bits: 0.1f64.to_bits(),
            raw_eps: false,
            engine: "native-seq",
            want_certificate: false,
        }
    }

    fn csr_solution(nnz_rows: usize) -> Solution {
        let mut row_ptr = vec![0usize];
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        for b in 0..nnz_rows {
            col_idx.push(b as u32);
            vals.push(1.0 / nnz_rows as f64);
            row_ptr.push(col_idx.len());
        }
        let plan = TransportPlan::from_csr(nnz_rows, nnz_rows, row_ptr, col_idx, vals).unwrap();
        Solution {
            coupling: Coupling::Plan(plan),
            cost: 0.5,
            duals: Some(DualWeights { ya: vec![0; nnz_rows], yb: vec![1; nnz_rows] }),
            certificate: None,
            stats: SolveStats::default(),
        }
    }

    fn plan_bits(sol: &Solution) -> Vec<u64> {
        match &sol.coupling {
            Coupling::Plan(p) => {
                let (_, _, vals) = p.csr_view().unwrap();
                vals.iter().map(|v| v.to_bits()).collect()
            }
            Coupling::Matching(_) => panic!("expected a plan"),
        }
    }

    #[test]
    fn round_trips_solutions_bit_for_bit() {
        let cache = ResultCache::new(1 << 20);
        let sol = csr_solution(8);
        assert!(cache.insert(key(1), &sol).stored);
        let hit = cache.get(&key(1)).expect("hit");
        assert_eq!(hit.cost.to_bits(), sol.cost.to_bits());
        assert_eq!(plan_bits(&hit), plan_bits(&sol));
        assert_eq!(hit.duals.as_ref().unwrap().yb, sol.duals.as_ref().unwrap().yb);
        assert!(cache.get(&key(2)).is_none(), "different digest must miss");
    }

    #[test]
    fn zero_capacity_disables_everything() {
        let cache = ResultCache::new(0);
        let sol = csr_solution(4);
        assert!(!cache.insert(key(1), &sol).stored);
        assert!(cache.get(&key(1)).is_none());
        assert_eq!(cache.bytes(), 0);
    }

    #[test]
    fn lru_eviction_respects_recency_and_byte_bound() {
        let sol = csr_solution(4);
        let one = weigh(&store(&sol));
        // room for exactly two entries
        let cache = ResultCache::new(2 * one);
        assert!(cache.insert(key(1), &sol).stored);
        assert!(cache.insert(key(2), &sol).stored);
        // touch 1 so 2 becomes the LRU victim
        assert!(cache.get(&key(1)).is_some());
        let report = cache.insert(key(3), &sol);
        assert!(report.stored);
        assert_eq!(report.evictions, 1);
        assert!(cache.get(&key(1)).is_some(), "recently used survives");
        assert!(cache.get(&key(2)).is_none(), "LRU evicted");
        assert!(cache.get(&key(3)).is_some());
        assert!(cache.bytes() <= 2 * one);
    }

    #[test]
    fn oversized_values_are_rejected_not_flushed() {
        let small = csr_solution(2);
        let big = csr_solution(512);
        let cache = ResultCache::new(weigh(&store(&small)) + 8);
        assert!(cache.insert(key(1), &small).stored);
        let report = cache.insert(key(2), &big);
        assert!(!report.stored);
        assert_eq!(report.evictions, 0);
        assert!(cache.get(&key(1)).is_some(), "existing entries survive an oversized insert");
    }

    #[test]
    fn reinserting_a_key_replaces_its_bytes() {
        let cache = ResultCache::new(1 << 20);
        cache.insert(key(1), &csr_solution(4));
        let b1 = cache.bytes();
        cache.insert(key(1), &csr_solution(4));
        assert_eq!(cache.bytes(), b1, "same key re-insert must not leak bytes");
        assert_eq!(cache.len(), 1);
    }
}
