//! Routing: resolve `Engine::Auto`, pick the artifact bucket for batching,
//! and execute jobs through the [`SolverRegistry`] — the coordinator holds
//! no per-engine construction code of its own.
//!
//! Auto policy (mirrors how the paper splits CPU vs GPU work): small
//! instances go to the native sequential solver (per-phase scan is
//! cache-friendly and has no dispatch overhead); larger ones go to the XLA
//! path when an artifact bucket exists, else to the multi-threaded native
//! solver.

use crate::api::{Problem, Solution, SolverConfig, SolverRegistry};
use crate::coordinator::job::{Engine, JobRequest};
use crate::core::Result;
use crate::runtime::XlaRuntime;
use std::sync::Arc;

/// Instances below this size always run natively under `Auto`.
pub const AUTO_NATIVE_CUTOFF: usize = 512;

pub struct Router {
    registry: SolverRegistry,
    config: SolverConfig,
}

impl Router {
    pub fn new(runtime: Option<Arc<XlaRuntime>>, threads: usize) -> Self {
        Self::with_registry(SolverRegistry::with_defaults(), runtime, threads)
    }

    /// Custom registry (tests, alternative backends) with the same routing.
    pub fn with_registry(
        registry: SolverRegistry,
        runtime: Option<Arc<XlaRuntime>>,
        threads: usize,
    ) -> Self {
        let config = SolverConfig::default().with_threads(threads).with_runtime(runtime);
        Self { registry, config }
    }

    pub fn runtime(&self) -> Option<&Arc<XlaRuntime>> {
        self.config.xla_runtime.as_ref()
    }

    /// Resolve Auto to a concrete engine for this job.
    pub fn resolve(&self, req: &JobRequest) -> Engine {
        match req.engine {
            Engine::Auto => {
                let n = req.kind.n();
                let xla_ok = self
                    .runtime()
                    .map(|r| r.registry.bucket_for(n).is_ok())
                    .unwrap_or(false);
                match req.kind {
                    Problem::Assignment(_) if n >= AUTO_NATIVE_CUTOFF && xla_ok => Engine::Xla,
                    Problem::Assignment(_) if n >= AUTO_NATIVE_CUTOFF => Engine::NativeParallel,
                    Problem::Assignment(_) => Engine::NativeSeq,
                    // OT has no XLA phase-loop (assignment only); route native
                    Problem::Ot(_) => Engine::NativeSeq,
                    // Implicit costs: the vector backend keeps only the
                    // block-min cache resident — the no-slab path.
                    Problem::Implicit(_) => Engine::NativeVector,
                }
            }
            e => e,
        }
    }

    /// The artifact size bucket a job lands in (batching key); None for
    /// native engines.
    pub fn bucket(&self, req: &JobRequest, engine: Engine) -> Option<usize> {
        match engine {
            Engine::Xla | Engine::SinkhornXla => {
                self.runtime().and_then(|r| r.registry.bucket_for(req.kind.n()).ok())
            }
            _ => None,
        }
    }

    /// Execute the job on `engine` (must be concrete, not Auto) via the
    /// registry, honoring the job's full [`crate::api::SolveRequest`].
    pub fn execute(&self, req: &JobRequest, engine: Engine) -> Result<Solution> {
        debug_assert!(engine != Engine::Auto, "resolve() before execute()");
        self.registry.solve(engine.key(), &self.config, &req.kind, &req.request)
    }

    /// Execute a closed batch of jobs that share one engine, building the
    /// solver once so kernel-backed engines reuse their arena across
    /// same-shape instances. Per-job results come back in input order;
    /// each job's own request (budget/cancel/observer) is honored.
    pub fn execute_batch(&self, reqs: &[&JobRequest], engine: Engine) -> Vec<Result<Solution>> {
        debug_assert!(engine != Engine::Auto, "resolve() before execute_batch()");
        let items: Vec<(&crate::api::Problem, &crate::api::SolveRequest)> =
            reqs.iter().map(|r| (&r.kind, &r.request)).collect();
        match self.registry.solve_each(engine.key(), &self.config, &items) {
            Ok(results) => results,
            // unknown engine: replicate the error per job so every reply
            // channel still gets an outcome
            Err(e) => {
                let msg = e.to_string();
                reqs.iter()
                    .map(|_| Err(crate::core::OtprError::Coordinator(msg.clone())))
                    .collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SolveRequest;
    use crate::coordinator::job::JobKind;
    use crate::data::workloads::Workload;

    fn req(n: usize, engine: Engine) -> JobRequest {
        JobRequest {
            id: 1,
            kind: JobKind::Assignment(Workload::RandomCosts { n }.assignment(1)),
            request: SolveRequest::new(0.3),
            engine,
        }
    }

    #[test]
    fn auto_routes_small_to_native() {
        let r = Router::new(None, 2);
        assert_eq!(r.resolve(&req(16, Engine::Auto)), Engine::NativeSeq);
        assert_eq!(r.resolve(&req(1000, Engine::Auto)), Engine::NativeParallel);
    }

    #[test]
    fn explicit_engine_respected() {
        let r = Router::new(None, 2);
        assert_eq!(r.resolve(&req(16, Engine::NativeParallel)), Engine::NativeParallel);
    }

    #[test]
    fn executes_native_assignment() {
        let r = Router::new(None, 2);
        let rq = req(12, Engine::NativeSeq);
        let out = r.execute(&rq, Engine::NativeSeq).unwrap();
        assert!(out.cost > 0.0);
        assert!(out.matching().unwrap().is_perfect());
    }

    #[test]
    fn xla_without_registry_fails_cleanly() {
        let r = Router::new(None, 2);
        let rq = req(12, Engine::Xla);
        assert!(r.execute(&rq, Engine::Xla).is_err());
    }

    #[test]
    fn ot_jobs_route_native() {
        let r = Router::new(None, 2);
        let rq = JobRequest {
            id: 2,
            kind: JobKind::Ot(Workload::Fig1 { n: 10 }.ot_with_random_masses(3)),
            request: SolveRequest::new(0.3),
            engine: Engine::Auto,
        };
        assert_eq!(r.resolve(&rq), Engine::NativeSeq);
        let out = r.execute(&rq, Engine::NativeSeq).unwrap();
        assert!(out.plan().is_some());
    }

    #[test]
    fn execute_batch_matches_per_job_results_and_reuses_arena() {
        let r = Router::new(None, 2);
        let reqs: Vec<JobRequest> = (0..4u64)
            .map(|i| JobRequest {
                id: i,
                kind: JobKind::Assignment(Workload::RandomCosts { n: 10 }.assignment(i)),
                request: SolveRequest::new(0.3),
                engine: Engine::NativeSeq,
            })
            .collect();
        let refs: Vec<&JobRequest> = reqs.iter().collect();
        let batch = r.execute_batch(&refs, Engine::NativeSeq);
        assert_eq!(batch.len(), 4);
        let reused = batch
            .iter()
            .filter(|o| matches!(o, Ok(s) if s.stats.arena_reused))
            .count();
        assert_eq!(reused, 3, "same-shape batch reuses one arena");
        for (rq, out) in reqs.iter().zip(&batch) {
            let single = r.execute(rq, Engine::NativeSeq).unwrap();
            assert_eq!(single.matching(), out.as_ref().unwrap().matching());
        }
    }

    #[test]
    fn baseline_engines_execute_via_registry() {
        let r = Router::new(None, 2);
        let approx = r.execute(&req(10, Engine::NativeSeq), Engine::NativeSeq).unwrap();
        let exact = r.execute(&req(10, Engine::Hungarian), Engine::Hungarian).unwrap();
        assert!(approx.cost >= exact.cost - 1e-9);
        let greedy = r.execute(&req(10, Engine::Greedy), Engine::Greedy).unwrap();
        assert!(greedy.cost >= exact.cost - 1e-9);
    }
}
