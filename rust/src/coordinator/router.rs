//! Routing: resolve `Engine::Auto`, pick the artifact bucket for batching,
//! and execute jobs through the [`SolverRegistry`] — the coordinator holds
//! no per-engine construction code of its own.
//!
//! Auto policy (mirrors how the paper splits CPU vs GPU work): one shared
//! table — [`auto_kernel_engine`] — picks the native kernel backend by
//! (n, available threads, dense-vs-implicit): small instances stay
//! sequential (per-phase scan is cache-friendly and has no dispatch
//! overhead; implicit ones on the no-slab vector backend), large ones fan
//! the lane sweep over threads via the hybrid backend — never when only
//! one thread is available. Large dense assignment still prefers the XLA
//! path when an artifact bucket exists.

use crate::api::{Problem, Solution, SolverConfig, SolverRegistry, WarmKernelSolver};
use crate::coordinator::job::{Engine, JobRequest};
use crate::core::Result;
use crate::runtime::XlaRuntime;
use std::collections::HashMap;
use std::sync::Arc;

/// Instances below this size always run natively under `Auto`.
pub const AUTO_NATIVE_CUTOFF: usize = 512;

/// The one `Auto` kernel-routing table — the small-instance fast path,
/// the implicit route, and the hybrid route all read from here, so the
/// thresholds cannot drift apart again (the pre-PR-7 bug: resolve still
/// hardcoded `native-parallel` for large dense and `native-vector` for
/// implicit, leaving every core on the slow scalar sweep).
///
/// * `threads <= 1` resolves to a **sequential** engine, never hybrid:
///   the no-slab vector backend for implicit costs, the plain sequential
///   kernel below the cutoff, the lane-blocked vector sweep above it.
/// * `threads >= 2` and `n >= AUTO_NATIVE_CUTOFF` fan the lane sweep
///   over threads: [`Engine::NativeHybrid`], dense *and* implicit.
/// * Small instances stay sequential regardless of thread count — the
///   per-phase scan is cache-friendly and fan-out dispatch would cost
///   more than it saves.
pub fn auto_kernel_engine(n: usize, threads: usize, implicit: bool) -> Engine {
    let large = n >= AUTO_NATIVE_CUTOFF;
    if large && threads > 1 {
        return Engine::NativeHybrid;
    }
    if implicit || large {
        // lane backend: no-slab streaming for implicit, block-min skip
        // for large dense — the fastest sequential sweep either way
        return Engine::NativeVector;
    }
    Engine::NativeSeq
}

/// The warm-ladder sibling of a kernel engine — what the coordinator's
/// `DegradePolicy` re-solves on when a deadline-pressured job needs a
/// coarser-ε answer fast: the ε-scaling schedule makes the coarse levels
/// cheap and stoppable at certified boundaries. Engines without a warm
/// variant (exact oracles, Sinkhorn, XLA) degrade on themselves by just
/// re-solving at the coarser ε.
pub fn warm_variant(engine: Engine) -> Engine {
    match engine {
        Engine::NativeSeq => Engine::NativeSeqWarm,
        Engine::NativeVector | Engine::NativeParallel | Engine::NativeHybrid => {
            Engine::NativeVectorWarm
        }
        e => e,
    }
}

pub struct Router {
    registry: SolverRegistry,
    config: SolverConfig,
}

impl Router {
    pub fn new(runtime: Option<Arc<XlaRuntime>>, threads: usize) -> Self {
        Self::with_registry(SolverRegistry::with_defaults(), runtime, threads)
    }

    /// Custom registry (tests, alternative backends) with the same routing.
    pub fn with_registry(
        registry: SolverRegistry,
        runtime: Option<Arc<XlaRuntime>>,
        threads: usize,
    ) -> Self {
        let config = SolverConfig::default().with_threads(threads).with_runtime(runtime);
        Self { registry, config }
    }

    pub fn runtime(&self) -> Option<&Arc<XlaRuntime>> {
        self.config.xla_runtime.as_ref()
    }

    /// Resolve Auto to a concrete engine for this job: XLA when a dense
    /// assignment is large and an artifact bucket exists, otherwise the
    /// shared [`auto_kernel_engine`] table.
    pub fn resolve(&self, req: &JobRequest) -> Engine {
        match req.engine {
            Engine::Auto => {
                let n = req.kind.n();
                let threads = self.config.threads;
                let xla_ok = self
                    .runtime()
                    .map(|r| r.registry.bucket_for(n).is_ok())
                    .unwrap_or(false);
                match req.kind {
                    Problem::Assignment(_) if n >= AUTO_NATIVE_CUTOFF && xla_ok => Engine::Xla,
                    // OT has no XLA phase-loop (assignment only); the
                    // kernel engines all serve both problem kinds
                    Problem::Assignment(_) | Problem::Ot(_) => {
                        auto_kernel_engine(n, threads, false)
                    }
                    Problem::Implicit(_) => auto_kernel_engine(n, threads, true),
                }
            }
            e => e,
        }
    }

    /// The artifact size bucket a job lands in (batching key); None for
    /// native engines.
    pub fn bucket(&self, req: &JobRequest, engine: Engine) -> Option<usize> {
        match engine {
            Engine::Xla | Engine::SinkhornXla => {
                self.runtime().and_then(|r| r.registry.bucket_for(req.kind.n()).ok())
            }
            _ => None,
        }
    }

    /// Execute the job on `engine` (must be concrete, not Auto) via the
    /// registry, honoring the job's full [`crate::api::SolveRequest`].
    pub fn execute(&self, req: &JobRequest, engine: Engine) -> Result<Solution> {
        debug_assert!(engine != Engine::Auto, "resolve() before execute()");
        self.registry.solve(engine.key(), &self.config, &req.kind, &req.request)
    }

    /// Execute a closed batch of jobs that share one engine, building the
    /// solver once so kernel-backed engines reuse their arena across
    /// same-shape instances. Per-job results come back in input order;
    /// each job's own request (budget/cancel/observer) is honored.
    pub fn execute_batch(&self, reqs: &[&JobRequest], engine: Engine) -> Vec<Result<Solution>> {
        debug_assert!(engine != Engine::Auto, "resolve() before execute_batch()");
        let items: Vec<(&crate::api::Problem, &crate::api::SolveRequest)> =
            reqs.iter().map(|r| (&r.kind, &r.request)).collect();
        match self.registry.solve_each(engine.key(), &self.config, &items) {
            Ok(results) => results,
            // unknown engine: replicate the error per job so every reply
            // channel still gets an outcome
            Err(e) => {
                let msg = e.to_string();
                reqs.iter()
                    .map(|_| Err(crate::core::OtprError::Coordinator(msg.clone())))
                    .collect()
            }
        }
    }

    /// Like [`Router::execute_batch`], but kernel engines run on a
    /// [`WarmKernelSolver`] held in `pinned` — the shard worker's
    /// arena-affinity state — so the warm arena survives *across*
    /// batches, not just within one. Non-kernel engines fall back to the
    /// per-call path. Certificates are attached per item when its request
    /// asks, mirroring the registry path exactly.
    pub fn execute_batch_pinned(
        &self,
        pinned: &mut PinnedSolvers,
        reqs: &[&JobRequest],
        engine: Engine,
    ) -> Vec<Result<Solution>> {
        debug_assert!(engine != Engine::Auto, "resolve() before execute_batch_pinned()");
        use std::collections::hash_map::Entry;
        let key = engine.key();
        let solver = match pinned.by_engine.entry(key) {
            Entry::Occupied(o) => Some(o.into_mut()),
            Entry::Vacant(v) => {
                WarmKernelSolver::for_engine(key, &self.config).map(|s| v.insert(s))
            }
        };
        let Some(solver) = solver else {
            return self.execute_batch(reqs, engine);
        };
        let items: Vec<(&crate::api::Problem, &crate::api::SolveRequest)> =
            reqs.iter().map(|r| (&r.kind, &r.request)).collect();
        let mut results = solver.solve_each(&items);
        for (result, rq) in results.iter_mut().zip(reqs) {
            if let Ok(sol) = result {
                if rq.request.want_certificate {
                    sol.certificate =
                        Some(crate::core::certify::certify(&rq.kind, sol, &rq.request));
                }
            }
        }
        results
    }
}

/// A shard worker's pinned kernel engines, keyed by canonical engine
/// name. One shard serves one problem shape, so each entry holds exactly
/// one warm arena of that shape; a worker that catches a panic must
/// [`PinnedSolvers::clear`] (the arena state is unspecified mid-solve,
/// and a cold rebuild is always correct).
#[derive(Default)]
pub struct PinnedSolvers {
    by_engine: HashMap<&'static str, WarmKernelSolver>,
}

impl PinnedSolvers {
    pub fn clear(&mut self) {
        self.by_engine.clear();
    }

    /// How many engines this worker currently pins (metrics/tests).
    pub fn len(&self) -> usize {
        self.by_engine.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_engine.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SolveRequest;
    use crate::coordinator::job::JobKind;
    use crate::data::workloads::Workload;

    fn req(n: usize, engine: Engine) -> JobRequest {
        JobRequest {
            id: 1,
            kind: JobKind::Assignment(Workload::RandomCosts { n }.assignment(1)),
            request: SolveRequest::new(0.3),
            engine,
        }
    }

    #[test]
    fn auto_routes_small_to_native() {
        let r = Router::new(None, 2);
        assert_eq!(r.resolve(&req(16, Engine::Auto)), Engine::NativeSeq);
        assert_eq!(r.resolve(&req(1000, Engine::Auto)), Engine::NativeHybrid);
    }

    /// Every branch of the shared Auto table, including the `threads == 1`
    /// degenerate case — which must resolve to a sequential engine, never
    /// hybrid (a single-thread fan-out is pure dispatch overhead).
    #[test]
    fn auto_kernel_table_covers_every_branch() {
        let big = AUTO_NATIVE_CUTOFF;
        // threads == 1: sequential engines only
        assert_eq!(auto_kernel_engine(16, 1, false), Engine::NativeSeq);
        assert_eq!(auto_kernel_engine(16, 1, true), Engine::NativeVector);
        assert_eq!(auto_kernel_engine(big, 1, false), Engine::NativeVector);
        assert_eq!(auto_kernel_engine(big, 1, true), Engine::NativeVector);
        // threads >= 2, small: still sequential (fan-out costs more than
        // it saves below the cutoff)
        assert_eq!(auto_kernel_engine(big - 1, 8, false), Engine::NativeSeq);
        assert_eq!(auto_kernel_engine(big - 1, 8, true), Engine::NativeVector);
        // threads >= 2, large: hybrid, dense and implicit alike
        assert_eq!(auto_kernel_engine(big, 2, false), Engine::NativeHybrid);
        assert_eq!(auto_kernel_engine(big, 2, true), Engine::NativeHybrid);
        // threads == 0 behaves like 1 (never hybrid)
        assert_eq!(auto_kernel_engine(big, 0, false), Engine::NativeVector);
    }

    #[test]
    fn auto_single_thread_router_never_picks_hybrid() {
        let r = Router::new(None, 1);
        assert_eq!(r.resolve(&req(16, Engine::Auto)), Engine::NativeSeq);
        assert_eq!(r.resolve(&req(1000, Engine::Auto)), Engine::NativeVector);
    }

    #[test]
    fn auto_routes_implicit_through_the_shared_table() {
        let mk = |n: usize| JobRequest {
            id: 7,
            kind: JobKind::implicit_assignment(
                Workload::Fig1 { n }.implicit_costs(5).expect("fig1 implicit"),
            )
            .expect("implicit problem"),
            request: SolveRequest::new(0.3),
            engine: Engine::Auto,
        };
        let r2 = Router::new(None, 2);
        assert_eq!(r2.resolve(&mk(16)), Engine::NativeVector);
        assert_eq!(r2.resolve(&mk(1000)), Engine::NativeHybrid);
        let r1 = Router::new(None, 1);
        assert_eq!(r1.resolve(&mk(1000)), Engine::NativeVector);
    }

    #[test]
    fn warm_variant_maps_kernel_engines_onto_ladders() {
        assert_eq!(warm_variant(Engine::NativeSeq), Engine::NativeSeqWarm);
        assert_eq!(warm_variant(Engine::NativeVector), Engine::NativeVectorWarm);
        assert_eq!(warm_variant(Engine::NativeHybrid), Engine::NativeVectorWarm);
        assert_eq!(warm_variant(Engine::NativeParallel), Engine::NativeVectorWarm);
        assert_eq!(warm_variant(Engine::NativeSeqWarm), Engine::NativeSeqWarm);
        assert_eq!(warm_variant(Engine::Hungarian), Engine::Hungarian);
        assert_eq!(warm_variant(Engine::SinkhornNative), Engine::SinkhornNative);
    }

    #[test]
    fn explicit_engine_respected() {
        let r = Router::new(None, 2);
        assert_eq!(r.resolve(&req(16, Engine::NativeParallel)), Engine::NativeParallel);
    }

    #[test]
    fn executes_native_assignment() {
        let r = Router::new(None, 2);
        let rq = req(12, Engine::NativeSeq);
        let out = r.execute(&rq, Engine::NativeSeq).unwrap();
        assert!(out.cost > 0.0);
        assert!(out.matching().unwrap().is_perfect());
    }

    #[test]
    fn xla_without_registry_fails_cleanly() {
        let r = Router::new(None, 2);
        let rq = req(12, Engine::Xla);
        assert!(r.execute(&rq, Engine::Xla).is_err());
    }

    #[test]
    fn ot_jobs_route_native() {
        let r = Router::new(None, 2);
        let rq = JobRequest {
            id: 2,
            kind: JobKind::Ot(Workload::Fig1 { n: 10 }.ot_with_random_masses(3)),
            request: SolveRequest::new(0.3),
            engine: Engine::Auto,
        };
        assert_eq!(r.resolve(&rq), Engine::NativeSeq);
        let out = r.execute(&rq, Engine::NativeSeq).unwrap();
        assert!(out.plan().is_some());
    }

    #[test]
    fn execute_batch_matches_per_job_results_and_reuses_arena() {
        let r = Router::new(None, 2);
        let reqs: Vec<JobRequest> = (0..4u64)
            .map(|i| JobRequest {
                id: i,
                kind: JobKind::Assignment(Workload::RandomCosts { n: 10 }.assignment(i)),
                request: SolveRequest::new(0.3),
                engine: Engine::NativeSeq,
            })
            .collect();
        let refs: Vec<&JobRequest> = reqs.iter().collect();
        let batch = r.execute_batch(&refs, Engine::NativeSeq);
        assert_eq!(batch.len(), 4);
        let reused = batch
            .iter()
            .filter(|o| matches!(o, Ok(s) if s.stats.arena_reused))
            .count();
        assert_eq!(reused, 3, "same-shape batch reuses one arena");
        for (rq, out) in reqs.iter().zip(&batch) {
            let single = r.execute(rq, Engine::NativeSeq).unwrap();
            assert_eq!(single.matching(), out.as_ref().unwrap().matching());
        }
    }

    #[test]
    fn pinned_batches_reuse_the_arena_across_calls() {
        let r = Router::new(None, 2);
        let mut pinned = PinnedSolvers::default();
        let mk = |i: u64| JobRequest {
            id: i,
            kind: JobKind::Assignment(Workload::RandomCosts { n: 10 }.assignment(i)),
            request: SolveRequest::new(0.3),
            engine: Engine::NativeSeq,
        };
        // three separate one-job batches: execute_batch would rebuild the
        // kernel each time and report zero reuse
        let jobs: Vec<JobRequest> = (0..3).map(mk).collect();
        let mut reused = Vec::new();
        for rq in &jobs {
            let out = r.execute_batch_pinned(&mut pinned, &[rq], Engine::NativeSeq);
            reused.push(out[0].as_ref().unwrap().stats.arena_reused);
        }
        assert_eq!(reused, vec![false, true, true], "arena survives batch boundaries");
        assert_eq!(pinned.len(), 1);
        // per-call path for comparison: never reuses across calls
        let cold = r.execute_batch(&[&jobs[2]], Engine::NativeSeq);
        assert!(!cold[0].as_ref().unwrap().stats.arena_reused);
        // results agree with the unpinned path
        let a = r.execute(&jobs[1], Engine::NativeSeq).unwrap();
        let b = r.execute_batch_pinned(&mut pinned, &[&jobs[1]], Engine::NativeSeq);
        assert_eq!(a.matching(), b[0].as_ref().unwrap().matching());
        // non-kernel engines fall back (and pin nothing)
        let h = JobRequest { engine: Engine::Hungarian, ..mk(9) };
        let out = r.execute_batch_pinned(&mut pinned, &[&h], Engine::Hungarian);
        assert!(out[0].is_ok());
        assert_eq!(pinned.len(), 1);
        pinned.clear();
        assert!(pinned.is_empty());
    }

    #[test]
    fn pinned_batches_attach_certificates_like_the_registry_path() {
        let r = Router::new(None, 2);
        let mut pinned = PinnedSolvers::default();
        let rq = JobRequest {
            id: 1,
            kind: JobKind::Assignment(Workload::RandomCosts { n: 8 }.assignment(3)),
            request: SolveRequest::new(0.3).certify(true),
            engine: Engine::NativeSeq,
        };
        let out = r.execute_batch_pinned(&mut pinned, &[&rq], Engine::NativeSeq);
        let cert = out[0].as_ref().unwrap().certificate.as_ref().expect("certificate attached");
        assert!(cert.ok(), "{}", cert.summary());
    }

    #[test]
    fn baseline_engines_execute_via_registry() {
        let r = Router::new(None, 2);
        let approx = r.execute(&req(10, Engine::NativeSeq), Engine::NativeSeq).unwrap();
        let exact = r.execute(&req(10, Engine::Hungarian), Engine::Hungarian).unwrap();
        assert!(approx.cost >= exact.cost - 1e-9);
        let greedy = r.execute(&req(10, Engine::Greedy), Engine::Greedy).unwrap();
        assert!(greedy.cost >= exact.cost - 1e-9);
    }
}
