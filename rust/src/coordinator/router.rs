//! Routing: resolve `Engine::Auto`, validate a job against the available
//! backends, and execute it on the chosen one.
//!
//! Policy (mirrors how the paper splits CPU vs GPU work): small instances
//! go to the native sequential solver (per-phase scan is cache-friendly
//! and has no dispatch overhead); larger ones go to the XLA path when an
//! artifact bucket exists, else to the multi-threaded native solver.

use crate::coordinator::job::{Engine, JobKind, JobRequest, JobResult};
use crate::core::{OtInstance, OtprError, Result};
use crate::runtime::{XlaAssignment, XlaRuntime, XlaSinkhorn};
use crate::solvers::ot_push_relabel::OtPushRelabel;
use crate::solvers::parallel_pr::ParallelPushRelabel;
use crate::solvers::push_relabel::PushRelabel;
use crate::solvers::sinkhorn::Sinkhorn;
use crate::solvers::{AssignmentSolver, OtSolver};
use std::sync::Arc;

/// Instances below this size always run natively under `Auto`.
pub const AUTO_NATIVE_CUTOFF: usize = 512;

pub struct Router {
    pub runtime: Option<Arc<XlaRuntime>>,
    pub threads: usize,
}

impl Router {
    pub fn new(runtime: Option<Arc<XlaRuntime>>, threads: usize) -> Self {
        Self { runtime, threads }
    }

    /// Resolve Auto to a concrete engine for this job.
    pub fn resolve(&self, req: &JobRequest) -> Engine {
        match req.engine {
            Engine::Auto => {
                let n = req.kind.n();
                let xla_ok = self
                    .runtime
                    .as_ref()
                    .map(|r| r.registry.bucket_for(n).is_ok())
                    .unwrap_or(false);
                match req.kind {
                    JobKind::Assignment(_) if n >= AUTO_NATIVE_CUTOFF && xla_ok => Engine::Xla,
                    JobKind::Assignment(_) if n >= AUTO_NATIVE_CUTOFF => Engine::NativeParallel,
                    JobKind::Assignment(_) => Engine::NativeSeq,
                    // OT has no XLA phase-loop (assignment only); route native
                    JobKind::Ot(_) => Engine::NativeSeq,
                }
            }
            e => e,
        }
    }

    /// The artifact size bucket a job lands in (batching key); None for
    /// native engines.
    pub fn bucket(&self, req: &JobRequest, engine: Engine) -> Option<usize> {
        match engine {
            Engine::Xla | Engine::SinkhornXla => {
                self.runtime.as_ref().and_then(|r| r.registry.bucket_for(req.kind.n()).ok())
            }
            _ => None,
        }
    }

    /// Execute the job on `engine` (must be concrete, not Auto).
    pub fn execute(&self, req: &JobRequest, engine: Engine) -> Result<JobResult> {
        match (&req.kind, engine) {
            (JobKind::Assignment(inst), Engine::NativeSeq) => Ok(JobResult::Assignment(
                PushRelabel::new().solve_assignment(inst, req.eps)?,
            )),
            (JobKind::Assignment(inst), Engine::NativeParallel) => Ok(JobResult::Assignment(
                ParallelPushRelabel::with_threads(self.threads).solve_assignment(inst, req.eps)?,
            )),
            (JobKind::Assignment(inst), Engine::Xla) => {
                let reg = self.require_runtime()?;
                Ok(JobResult::Assignment(
                    XlaAssignment::new(reg).solve_assignment(inst, req.eps)?,
                ))
            }
            (JobKind::Assignment(inst), Engine::SinkhornNative) => {
                // assignment via uniform-mass OT (how the paper benchmarks
                // Sinkhorn on assignment inputs)
                let ot = OtInstance::uniform(inst.costs.clone())?;
                Ok(JobResult::Ot(Sinkhorn::log_domain().solve_ot(&ot, req.eps)?))
            }
            (JobKind::Assignment(inst), Engine::SinkhornXla) => {
                let reg = self.require_runtime()?;
                let ot = OtInstance::uniform(inst.costs.clone())?;
                Ok(JobResult::Ot(XlaSinkhorn::new(reg).solve_ot(&ot, req.eps)?))
            }
            (JobKind::Ot(inst), Engine::NativeSeq | Engine::NativeParallel) => {
                Ok(JobResult::Ot(OtPushRelabel::new().solve_ot(inst, req.eps)?))
            }
            (JobKind::Ot(inst), Engine::SinkhornNative) => {
                Ok(JobResult::Ot(Sinkhorn::log_domain().solve_ot(inst, req.eps)?))
            }
            (JobKind::Ot(inst), Engine::SinkhornXla) => {
                let reg = self.require_runtime()?;
                Ok(JobResult::Ot(XlaSinkhorn::new(reg).solve_ot(inst, req.eps)?))
            }
            (JobKind::Ot(_), Engine::Xla) => Err(OtprError::Coordinator(
                "XLA engine supports assignment jobs only (OT runs native)".into(),
            )),
            (_, Engine::Auto) => unreachable!("resolve() before execute()"),
        }
    }

    fn require_runtime(&self) -> Result<Arc<XlaRuntime>> {
        self.runtime
            .clone()
            .ok_or_else(|| OtprError::Coordinator("no XLA runtime loaded".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;

    fn req(n: usize, engine: Engine) -> JobRequest {
        JobRequest {
            id: 1,
            kind: JobKind::Assignment(Workload::RandomCosts { n }.assignment(1)),
            eps: 0.3,
            engine,
        }
    }

    #[test]
    fn auto_routes_small_to_native() {
        let r = Router::new(None, 2);
        assert_eq!(r.resolve(&req(16, Engine::Auto)), Engine::NativeSeq);
        assert_eq!(r.resolve(&req(1000, Engine::Auto)), Engine::NativeParallel);
    }

    #[test]
    fn explicit_engine_respected() {
        let r = Router::new(None, 2);
        assert_eq!(r.resolve(&req(16, Engine::NativeParallel)), Engine::NativeParallel);
    }

    #[test]
    fn executes_native_assignment() {
        let r = Router::new(None, 2);
        let rq = req(12, Engine::NativeSeq);
        let out = r.execute(&rq, Engine::NativeSeq).unwrap();
        assert!(out.cost() > 0.0);
    }

    #[test]
    fn xla_without_registry_fails_cleanly() {
        let r = Router::new(None, 2);
        let rq = req(12, Engine::Xla);
        assert!(r.execute(&rq, Engine::Xla).is_err());
    }

    #[test]
    fn ot_jobs_route_native() {
        let r = Router::new(None, 2);
        let rq = JobRequest {
            id: 2,
            kind: JobKind::Ot(Workload::Fig1 { n: 10 }.ot_with_random_masses(3)),
            eps: 0.3,
            engine: Engine::Auto,
        };
        assert_eq!(r.resolve(&rq), Engine::NativeSeq);
        let out = r.execute(&rq, Engine::NativeSeq).unwrap();
        assert!(matches!(out, JobResult::Ot(_)));
    }
}
