//! Micro-batching: jobs destined for the same (engine, artifact bucket)
//! are dispatched together so workers reuse the compiled executable and
//! its warm device state — the dynamic-batching idea from serving systems
//! (vLLM-style), scaled to this coordinator.
//!
//! Policy: a batch closes when it reaches `max_batch` jobs OR `max_wait`
//! elapsed since its first job. Different keys never mix.

use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Batching key: engine name + optional artifact bucket.
pub type BatchKey = (&'static str, Option<usize>);

#[derive(Debug)]
pub struct Batch<T> {
    pub key: BatchKey,
    pub jobs: Vec<T>,
    opened: Instant,
}

impl<T> Batch<T> {
    /// How long the batch accumulated before being closed — the wait the
    /// first job paid for amortization, recorded into
    /// [`crate::coordinator::metrics::Metrics::record_batch`].
    pub fn wait(&self) -> Duration {
        self.opened.elapsed()
    }
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Accumulates jobs into per-key open batches.
pub struct Batcher<T> {
    config: BatcherConfig,
    open: HashMap<BatchKey, Batch<T>>,
}

impl<T> Batcher<T> {
    pub fn new(config: BatcherConfig) -> Self {
        Self { config, open: HashMap::new() }
    }

    /// Add a job; returns a closed batch if this push filled one.
    pub fn push(&mut self, key: BatchKey, job: T) -> Option<Batch<T>> {
        let batch = self
            .open
            .entry(key)
            .or_insert_with(|| Batch { key, jobs: Vec::new(), opened: Instant::now() });
        batch.jobs.push(job);
        if batch.jobs.len() >= self.config.max_batch {
            return self.open.remove(&key);
        }
        None
    }

    /// Close `job` into a batch immediately, bypassing accumulation; any
    /// same-key jobs already waiting ride along. Retries use this — they
    /// paid their accumulation wait on the first attempt, and stacking
    /// `max_wait` on top of the retry backoff would double-charge them.
    pub fn push_now(&mut self, key: BatchKey, job: T) -> Batch<T> {
        match self.open.remove(&key) {
            Some(mut batch) => {
                batch.jobs.push(job);
                batch
            }
            None => Batch { key, jobs: vec![job], opened: Instant::now() },
        }
    }

    /// Batches whose max_wait expired (call periodically).
    pub fn drain_expired(&mut self) -> Vec<Batch<T>> {
        let now = Instant::now();
        let expired: Vec<BatchKey> = self
            .open
            .iter()
            .filter(|(_, b)| now.duration_since(b.opened) >= self.config.max_wait)
            .map(|(k, _)| *k)
            .collect();
        expired.into_iter().filter_map(|k| self.open.remove(&k)).collect()
    }

    /// Flush everything (shutdown).
    pub fn drain_all(&mut self) -> Vec<Batch<T>> {
        self.open.drain().map(|(_, b)| b).collect()
    }

    pub fn pending(&self) -> usize {
        self.open.values().map(|b| b.jobs.len()).sum()
    }

    /// The shortest deadline among open batches (dispatcher poll hint).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.open.values().map(|b| b.opened + self.config.max_wait).min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closes_on_max_batch() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(9) });
        assert!(b.push(("xla", Some(256)), 1).is_none());
        assert!(b.push(("xla", Some(256)), 2).is_none());
        let batch = b.push(("xla", Some(256)), 3).unwrap();
        assert_eq!(batch.jobs, vec![1, 2, 3]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn keys_do_not_mix() {
        let mut b: Batcher<u32> = Batcher::new(BatcherConfig::default());
        b.push(("xla", Some(256)), 1);
        b.push(("xla", Some(512)), 2);
        b.push(("native-seq", None), 3);
        assert_eq!(b.pending(), 3);
        assert_eq!(b.open.len(), 3);
    }

    #[test]
    fn push_now_closes_immediately_and_takes_waiters_along() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 8, max_wait: Duration::from_secs(9) });
        // empty key: a one-job batch closes with no accumulation wait
        let solo = b.push_now(("e", None), 1);
        assert_eq!(solo.jobs, vec![1]);
        assert_eq!(b.pending(), 0);
        // open key: the waiting job rides along with the immediate one
        assert!(b.push(("e", None), 2).is_none());
        let joint = b.push_now(("e", None), 3);
        assert_eq!(joint.jobs, vec![2, 3]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn expiry_drains() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_millis(1),
        });
        b.push(("e", None), 7);
        std::thread::sleep(Duration::from_millis(3));
        let out = b.drain_expired();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].jobs, vec![7]);
        assert!(b.drain_expired().is_empty());
    }

    #[test]
    fn drain_all_flushes() {
        let mut b: Batcher<u32> = Batcher::new(BatcherConfig::default());
        b.push(("a", None), 1);
        b.push(("b", None), 2);
        let all = b.drain_all();
        assert_eq!(all.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn wait_measures_accumulation_time() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 2, max_wait: Duration::from_secs(9) });
        b.push(("e", None), 1);
        std::thread::sleep(Duration::from_millis(2));
        let batch = b.push(("e", None), 2).unwrap();
        assert!(batch.wait() >= Duration::from_millis(2));
    }
}
