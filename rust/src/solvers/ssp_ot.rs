//! Exact OT baseline: successive-shortest-path min-cost flow with Dijkstra
//! potentials on the bipartite transportation graph.
//!
//! Masses are quantized to integer units (largest-remainder rounding at
//! resolution θ) and flow is integral; costs stay at full f64 precision, so
//! the result is the *exact* optimum of the quantized-mass instance. With
//! the default θ = 2³² the mass quantization error (≤ n/θ per side) is
//! negligible relative to the ε targets under test. Runs in
//! O(augmentations · (n+m)²) — an oracle for tests/ablations, not a
//! competitor in the figures.

use crate::core::{OtInstance, OtprError, Result, TransportPlan};
use crate::solvers::{OtSolution, OtSolver, SolveStats};
use crate::util::timer::Stopwatch;

/// Largest-remainder quantization of a probability vector to exactly
/// `total` integer units.
pub fn quantize_masses(masses: &[f64], total: u64) -> Vec<u64> {
    let n = masses.len();
    let mut units: Vec<u64> = masses.iter().map(|&m| (m * total as f64).floor() as u64).collect();
    let assigned: u64 = units.iter().sum();
    let mut remainder: i64 = total as i64 - assigned as i64;
    debug_assert!(remainder >= 0);
    // distribute leftover units to the largest fractional parts
    let mut fracs: Vec<(f64, usize)> = masses
        .iter()
        .enumerate()
        .map(|(i, &m)| (m * total as f64 - (m * total as f64).floor(), i))
        .collect();
    fracs.sort_by(|a, b| b.0.total_cmp(&a.0));
    let mut k = 0;
    while remainder > 0 && n > 0 {
        units[fracs[k % n].1] += 1;
        remainder -= 1;
        k += 1;
    }
    units
}

/// Exact min-cost transportation on integer unit masses.
/// Returns (dense flow in units, total cost in original cost units).
pub fn solve_units(
    costs: &crate::core::CostMatrix,
    supply_units: &[u64],
    demand_units: &[u64],
) -> Result<(Vec<u64>, f64)> {
    let nb = costs.nb;
    let na = costs.na;
    if supply_units.len() != nb || demand_units.len() != na {
        return Err(OtprError::InvalidInstance("unit mass dimension mismatch".into()));
    }
    let total_supply: u64 = supply_units.iter().sum();
    let total_demand: u64 = demand_units.iter().sum();
    if total_supply > total_demand {
        return Err(OtprError::Infeasible(format!(
            "supply {total_supply} exceeds demand {total_demand}"
        )));
    }
    let mut res_supply = supply_units.to_vec();
    let mut res_demand = demand_units.to_vec();
    let mut flow = vec![0u64; nb * na];
    // node ids: 0..nb = supply, nb..nb+na = demand
    let v = nb + na;
    let mut pot = vec![0.0f64; v];
    let mut shipped = 0u64;
    let mut iterations = 0usize;
    let iter_cap = 4 * (nb + na) * (nb + na) + 64;
    while shipped < total_supply {
        iterations += 1;
        if iterations > iter_cap {
            return Err(OtprError::Infeasible(format!(
                "SSP iteration cap {iter_cap} exceeded (nb={nb}, na={na})"
            )));
        }
        // Dijkstra (dense O(V²)) from all b with residual supply.
        const INF: f64 = f64::INFINITY;
        let mut dist = vec![INF; v];
        let mut parent = vec![usize::MAX; v];
        let mut done = vec![false; v];
        for b in 0..nb {
            if res_supply[b] > 0 {
                dist[b] = 0.0;
            }
        }
        loop {
            let mut u = usize::MAX;
            let mut best = INF;
            for i in 0..v {
                if !done[i] && dist[i] < best {
                    best = dist[i];
                    u = i;
                }
            }
            if u == usize::MAX {
                break;
            }
            done[u] = true;
            if u < nb {
                // forward arcs b -> a (infinite capacity)
                let b = u;
                let row = costs.row(b);
                for a in 0..na {
                    let w = row[a] as f64 + pot[b] - pot[nb + a];
                    debug_assert!(w > -1e-7, "negative reduced cost {w}");
                    let nd = dist[u] + w.max(0.0);
                    if nd < dist[nb + a] {
                        dist[nb + a] = nd;
                        parent[nb + a] = u;
                    }
                }
            } else {
                // backward arcs a -> b (capacity = flow on (b,a))
                let a = u - nb;
                for b in 0..nb {
                    if flow[b * na + a] > 0 {
                        let w = -(costs.at(b, a) as f64) + pot[nb + a] - pot[b];
                        let nd = dist[u] + w.max(0.0);
                        if nd < dist[b] {
                            dist[b] = nd;
                            parent[b] = u;
                        }
                    }
                }
            }
        }
        // pick reachable demand node with residual capacity, smallest dist
        let mut target = usize::MAX;
        let mut best = INF;
        for a in 0..na {
            if res_demand[a] > 0 && dist[nb + a] < best {
                best = dist[nb + a];
                target = nb + a;
            }
        }
        if target == usize::MAX {
            return Err(OtprError::Infeasible("no augmenting path found".into()));
        }
        // bottleneck along the path
        let start_a = target - nb;
        let mut bottleneck = res_demand[start_a];
        {
            let mut node = target;
            while parent[node] != usize::MAX {
                let p = parent[node];
                if p >= nb {
                    // backward arc a(p) -> b(node): capacity = flow[node][p-nb]
                    bottleneck = bottleneck.min(flow[node * na + (p - nb)]);
                }
                node = p;
            }
            bottleneck = bottleneck.min(res_supply[node]);
        }
        debug_assert!(bottleneck > 0);
        // apply augmentation
        let mut node = target;
        while parent[node] != usize::MAX {
            let p = parent[node];
            if p < nb {
                flow[p * na + (node - nb)] += bottleneck;
            } else {
                flow[node * na + (p - nb)] -= bottleneck;
            }
            node = p;
        }
        res_supply[node] -= bottleneck;
        res_demand[start_a] -= bottleneck;
        shipped += bottleneck;
        // update potentials (Johnson): pot += dist for reached nodes
        for i in 0..v {
            if dist[i].is_finite() {
                pot[i] += dist[i];
            }
        }
    }
    let cost: f64 = flow
        .iter()
        .zip(costs.as_slice())
        .map(|(&f, &c)| f as f64 * c as f64)
        .sum();
    Ok((flow, cost))
}

/// Exact OT solver (mass-quantized at `theta`); implements [`OtSolver`].
#[derive(Debug, Clone)]
pub struct SspExactOt {
    pub theta: u64,
}

impl Default for SspExactOt {
    fn default() -> Self {
        Self { theta: 1 << 32 }
    }
}

impl OtSolver for SspExactOt {
    fn name(&self) -> &'static str {
        "ssp-exact"
    }

    fn solve_ot(&self, inst: &OtInstance, _eps: f64) -> Result<OtSolution> {
        let sw = Stopwatch::start();
        let supply = quantize_masses(&inst.supply, self.theta);
        let demand = quantize_masses(&inst.demand, self.theta);
        let (flow, cost_units) = solve_units(&inst.costs, &supply, &demand)?;
        let mut plan = TransportPlan::zeros(inst.costs.nb, inst.costs.na);
        let inv = 1.0 / self.theta as f64;
        for b in 0..inst.costs.nb {
            for a in 0..inst.costs.na {
                let f = flow[b * inst.costs.na + a];
                if f > 0 {
                    plan.set(b, a, f as f64 * inv);
                }
            }
        }
        Ok(OtSolution {
            plan,
            cost: cost_units * inv,
            // exact f64 potentials don't fit the ε-unit DualWeights shape
            duals: None,
            stats: SolveStats { seconds: sw.elapsed_secs(), ..Default::default() },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::CostMatrix;
    use crate::data::workloads::Workload;
    use crate::solvers::hungarian;

    #[test]
    fn quantize_conserves_total() {
        let m = vec![0.3, 0.3, 0.4];
        let u = quantize_masses(&m, 1000);
        assert_eq!(u.iter().sum::<u64>(), 1000);
        assert_eq!(u, vec![300, 300, 400]);
        let m = vec![1.0 / 3.0; 3];
        let u = quantize_masses(&m, 100);
        assert_eq!(u.iter().sum::<u64>(), 100);
    }

    #[test]
    fn matches_hungarian_on_unit_masses() {
        // supply=demand=1 unit each ⇒ min-cost flow == assignment
        for seed in 0..4 {
            let c = Workload::RandomCosts { n: 8 }.costs(seed);
            let (flow, cost) = solve_units(&c, &[1; 8], &[1; 8]).unwrap();
            let (_, hcost, _, _) = hungarian::solve_exact(&c).unwrap();
            assert!((cost - hcost).abs() < 1e-6, "ssp {cost} vs hungarian {hcost}");
            assert!(flow.iter().all(|&f| f <= 1));
        }
    }

    #[test]
    fn simple_transport_instance() {
        // 2 supplies (3,1), 2 demands (2,2); cheapest plan is forced
        let c = CostMatrix::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]).unwrap();
        let (flow, cost) = solve_units(&c, &[3, 1], &[2, 2]).unwrap();
        // b0 ships 2 to a0 (cost 0) and 1 to a1 (cost 1); b1 ships 1 to a1 (0)
        assert_eq!(flow, vec![2, 1, 0, 1]);
        assert!((cost - 1.0).abs() < 1e-9);
    }

    #[test]
    fn unbalanced_supply_leq_demand() {
        let c = CostMatrix::from_vec(1, 2, vec![1.0, 0.5]).unwrap();
        let (flow, cost) = solve_units(&c, &[2], &[2, 1]).unwrap();
        // ship 1 to a1 (0.5) and 1 to a0 (1.0)
        assert_eq!(flow[1], 1);
        assert_eq!(flow[0], 1);
        assert!((cost - 1.5).abs() < 1e-9);
        assert!(solve_units(&c, &[4], &[2, 1]).is_err());
    }

    #[test]
    fn ot_solver_end_to_end() {
        let inst = Workload::Fig1 { n: 10 }.ot_with_random_masses(3);
        let sol = SspExactOt::default().solve_ot(&inst, 0.0).unwrap();
        sol.plan.check(&inst.supply, &inst.demand, 1e-6).unwrap();
        // optimum is never above the independent Sinkhorn-rounded plan
        let sk = crate::solvers::sinkhorn::Sinkhorn::log_domain()
            .solve_ot(&inst, 0.2)
            .unwrap();
        assert!(sol.cost <= sk.cost + 1e-6);
    }

    #[test]
    fn plan_support_is_compact() {
        // SSP plans stay sparse (near the basic-solution bound nb+na−1);
        // allow 2× slack since SSP need not return an extreme point.
        let inst = Workload::Fig1 { n: 12 }.ot_with_random_masses(5);
        let sol = SspExactOt::default().solve_ot(&inst, 0.0).unwrap();
        assert!(
            sol.plan.support_size() <= 2 * (12 + 12),
            "support {} too large",
            sol.plan.support_size()
        );
    }
}
