//! Exact assignment baseline: shortest-augmenting-path Hungarian algorithm
//! with dual potentials (Jonker–Volgenant formulation), O(n²·m) time.
//!
//! This is the ground-truth oracle the accuracy experiments (A3) and the
//! property suite compare the push-relabel approximation against. Supports
//! rectangular instances with `nb ≤ na` (every row gets matched), which the
//! OT tests use for unbalanced checks.

use crate::core::matching::Matching;
use crate::core::{AssignmentInstance, CostMatrix, OtprError, Result};
use crate::solvers::{AssignmentSolution, AssignmentSolver, SolveStats};
use crate::util::timer::Stopwatch;

/// Exact minimum-cost matching that saturates all rows. Returns the matching
/// and the dual potentials (u over rows, v over cols) certifying optimality.
pub fn solve_exact(costs: &CostMatrix) -> Result<(Matching, f64, Vec<f64>, Vec<f64>)> {
    let n = costs.nb; // rows (B)
    let m = costs.na; // cols (A)
    if n > m {
        return Err(OtprError::InvalidInstance(format!(
            "hungarian requires nb <= na, got {n} > {m}"
        )));
    }
    if n == 0 {
        return Ok((Matching::empty(0, m), 0.0, vec![], vec![0.0; m]));
    }
    const INF: f64 = f64::INFINITY;
    // 1-based arrays in the classic formulation; p[j] = row matched to col j.
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; m + 1];
    let mut p = vec![0usize; m + 1];
    let mut way = vec![0usize; m + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; m + 1];
        let mut used = vec![false; m + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            let row = costs.row(i0 - 1);
            for j in 1..=m {
                if !used[j] {
                    let cur = row[j - 1] as f64 - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            debug_assert!(delta.is_finite(), "disconnected instance");
            for j in 0..=m {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        // augment along the alternating path
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut matching = Matching::empty(n, m);
    for j in 1..=m {
        if p[j] != 0 {
            matching.link(p[j] - 1, j - 1);
        }
    }
    let cost = matching.cost(costs);
    Ok((matching, cost, u[1..].to_vec(), v[1..].to_vec()))
}

/// Exhaustive O(n!) reference for *tiny* square instances only — the
/// cross-check oracle for [`solve_exact`] itself. Hard-errors above
/// n = 8 with a clear message instead of exploding combinatorially:
/// exact baselines at n ≥ 10 must use the O(n³) [`solve_exact`]
/// (golden-pin regeneration in `python/tools/gen_golden.py` follows the
/// same rule with a rational-arithmetic Jonker–Volgenant).
pub fn brute_force_reference(costs: &CostMatrix) -> Result<f64> {
    let n = costs.nb;
    if n != costs.na {
        return Err(OtprError::InvalidInstance(format!(
            "brute force needs square costs, got {}x{}",
            costs.nb, costs.na
        )));
    }
    if n > 8 {
        return Err(OtprError::InvalidInstance(format!(
            "brute-force reference is O(n!): refusing n = {n} > 8 — use solve_exact (O(n³))"
        )));
    }
    if n == 0 {
        return Ok(0.0);
    }
    // iterative Heap's algorithm over column permutations
    let mut perm: Vec<usize> = (0..n).collect();
    let mut c = vec![0usize; n];
    let total = |p: &[usize]| -> f64 { (0..n).map(|b| costs.at(b, p[b]) as f64).sum() };
    let mut best = total(&perm);
    let mut i = 0;
    while i < n {
        if c[i] < i {
            if i % 2 == 0 {
                perm.swap(0, i);
            } else {
                perm.swap(c[i], i);
            }
            best = best.min(total(&perm));
            c[i] += 1;
            i = 0;
        } else {
            c[i] = 0;
            i += 1;
        }
    }
    Ok(best)
}

/// Exact solver as an [`AssignmentSolver`] (ignores `eps`).
#[derive(Debug, Clone, Default)]
pub struct Hungarian;

impl AssignmentSolver for Hungarian {
    fn name(&self) -> &'static str {
        "hungarian-exact"
    }

    fn solve_assignment(&self, inst: &AssignmentInstance, _eps: f64) -> Result<AssignmentSolution> {
        let sw = Stopwatch::start();
        let (matching, cost, _, _) = solve_exact(&inst.costs)?;
        Ok(AssignmentSolution {
            matching,
            cost,
            // exact f64 potentials don't fit the ε-unit DualWeights shape
            duals: None,
            stats: SolveStats { seconds: sw.elapsed_secs(), ..Default::default() },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;
    use crate::util::rng::Pcg32;

    #[test]
    fn trivial_2x2() {
        // optimal picks the anti-diagonal: 1 + 2 = 3 vs diagonal 10 + 10
        let c = CostMatrix::from_vec(2, 2, vec![10.0, 1.0, 2.0, 10.0]).unwrap();
        let (m, cost, _, _) = solve_exact(&c).unwrap();
        assert_eq!(m.match_b, vec![1, 0]);
        assert!((cost - 3.0).abs() < 1e-9);
    }

    #[test]
    fn matches_bruteforce_on_random_small_instances() {
        let mut rng = Pcg32::new(42);
        for n in [4usize, 5, 6] {
            for _ in 0..8 {
                let c = CostMatrix::from_fn(n, n, |_, _| rng.next_f32());
                let (_, cost, _, _) = solve_exact(&c).unwrap();
                let best = brute_force_reference(&c).unwrap();
                assert!((cost - best).abs() < 1e-6, "hungarian {cost} != brute {best} (n={n})");
            }
        }
    }

    #[test]
    fn brute_force_hard_errors_above_n8() {
        let c = CostMatrix::zeros(10, 10);
        let err = brute_force_reference(&c).unwrap_err();
        assert!(err.to_string().contains("O(n!)"), "{err}");
        assert!(err.to_string().contains("solve_exact"), "{err}");
        assert!(brute_force_reference(&CostMatrix::zeros(8, 8)).is_ok());
        assert!(brute_force_reference(&CostMatrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn rectangular_saturates_rows() {
        let mut rng = Pcg32::new(7);
        let c = CostMatrix::from_fn(3, 6, |_, _| rng.next_f32());
        let (m, _, _, _) = solve_exact(&c).unwrap();
        assert_eq!(m.size(), 3);
        assert!(m.check_consistent().is_ok());
        assert!(solve_exact(&c.transposed()).is_err(), "nb > na must be rejected");
    }

    #[test]
    fn duals_certify_optimality() {
        // complementary slackness: u_i + v_j <= c_ij for all, == on matched
        let mut rng = Pcg32::new(9);
        let c = CostMatrix::from_fn(6, 6, |_, _| rng.next_f32());
        let (m, _, u, v) = solve_exact(&c).unwrap();
        for b in 0..6 {
            for a in 0..6 {
                let red = c.at(b, a) as f64 - u[b] - v[a];
                assert!(red >= -1e-9, "dual infeasible at ({b},{a}): {red}");
                if m.match_b[b] == a as i32 {
                    assert!(red.abs() < 1e-9, "slack on matched edge {red}");
                }
            }
        }
    }

    #[test]
    fn geometric_instance() {
        let i = Workload::Fig1 { n: 30 }.assignment(3);
        let sol = Hungarian.solve_assignment(&i, 0.0).unwrap();
        assert!(sol.matching.is_perfect());
        assert!(sol.cost > 0.0);
    }
}
