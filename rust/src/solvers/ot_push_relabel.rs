//! Push-relabel OT solver (paper §4): scale masses by θ = 4n/ε, round
//! demands up / supplies down to integer units, and run the unbalanced
//! matching algorithm over the *conceptual* unit copies — without ever
//! materializing them.
//!
//! Copy compression relies on two structural facts the paper proves:
//!
//! * free copies of a supply vertex b are kept at the maximum dual among
//!   b's copies (the §4 speed-up invariant), so they form one cluster with
//!   a single dual `y_free[b]`;
//! * Lemma 4.1: copies of any vertex carry at most **two** distinct dual
//!   values at any time, so the matched copies of a demand vertex a are
//!   grouped into ≤ 2 [`AClass`] clusters (dual value → copy count →
//!   partner multiset). The per-phase scan is then O(na · |B'|) over
//!   original vertices, giving the paper's O(n²/ε²) total (Theorem 4.2).
//!
//! Error budget at target ε (additive ε·c_max on unit total mass):
//! mass rounding ≤ ε/4 + matching at ε_m = ε/6 contributes 3·ε_m = ε/2
//! + residual supply shipped greedily ≤ ε/4.

use crate::core::control::{SolveControl, CANCELLED_NOTE};
use crate::core::{
    CostMatrix, DualWeights, OtInstance, OtprError, QuantizedCosts, Result, ScaledOtInstance,
    TransportPlan,
};
use crate::solvers::{OtSolution, OtSolver, SolveStats};
use crate::util::timer::Stopwatch;
use std::collections::BTreeMap;

/// Hard safety cap on OT phases at matching parameter `eps` (the OT
/// analog of [`crate::solvers::push_relabel::assignment_phase_cap`]).
fn ot_phase_cap(eps: f64) -> usize {
    (8.0 * (1.0 + 2.0 * eps) / (eps * eps)).ceil() as usize + 16
}

/// A cluster of matched copies of demand vertex `a` sharing dual `y`.
#[derive(Debug, Clone)]
struct AClass {
    /// Dual value (units, ≤ 0).
    y: i32,
    /// Number of matched a-copies in this cluster.
    count: u64,
    /// Partner multiset: supply vertex b → units matched to it.
    flow: BTreeMap<u32, u64>,
}

/// Pending M' match recorded during the greedy step.
#[derive(Debug, Clone, Copy)]
struct NewMatch {
    a: usize,
    /// Dual of the a-copies *before* the phase's relabel.
    y_pre: i32,
    b: usize,
    units: u64,
}

/// Solver state over original vertices + clusters.
pub struct OtPrState {
    pub q: QuantizedCosts,
    /// Free demand units per a (these copies have dual 0).
    a_free: Vec<u64>,
    /// Matched demand clusters per a (≤ 2 by Lemma 4.1).
    a_classes: Vec<Vec<AClass>>,
    /// Free supply units per b.
    b_free: Vec<u64>,
    /// Dual of b's free copies (= max dual among b's copies).
    y_free: Vec<i32>,
    pub total_supply_units: u64,
    pub phases: usize,
    pub total_free_processed: u64,
    /// Largest number of simultaneous clusters on any vertex (A4 ablation;
    /// Lemma 4.1 says this never exceeds 2).
    pub max_classes_seen: usize,
}

impl OtPrState {
    pub fn new(costs: &CostMatrix, scaled: &ScaledOtInstance, eps_match: f64) -> Self {
        let q = QuantizedCosts::new(costs, eps_match);
        let total_supply_units = scaled.total_supply_units();
        Self {
            a_free: scaled.demand_units.clone(),
            a_classes: vec![Vec::new(); costs.na],
            b_free: scaled.supply_units.clone(),
            y_free: vec![1; costs.nb],
            q,
            total_supply_units,
            phases: 0,
            total_free_processed: 0,
            max_classes_seen: 0,
        }
    }

    pub fn free_units(&self) -> u64 {
        self.b_free.iter().sum()
    }

    fn threshold(&self) -> u64 {
        (self.q.eps * self.total_supply_units as f64).floor() as u64
    }

    /// One phase over unit copies. Returns false when terminated.
    pub fn run_phase(&mut self) -> bool {
        let free_now = self.free_units();
        if free_now <= self.threshold() {
            return false;
        }
        self.phases += 1;
        self.total_free_processed += free_now;
        let na = self.q.na;

        // Budget = free units at phase start (evicted units arriving during
        // the phase join b_free but not this phase's B').
        let budgets: Vec<(usize, u64)> = (0..self.q.nb)
            .filter(|&b| self.b_free[b] > 0)
            .map(|b| (b, self.b_free[b]))
            .collect();

        let mut pending: Vec<NewMatch> = Vec::new();
        let mut matched_of_b: Vec<u64> = vec![0; self.q.nb];

        for &(b, budget) in &budgets {
            let mut need = budget;
            let yb = self.y_free[b];
            let row = self.q.row(b);
            for a in 0..na {
                if need == 0 {
                    break;
                }
                let cq1 = row[a] + 1;
                // free a-copies (dual 0)
                if yb == cq1 && self.a_free[a] > 0 {
                    let take = need.min(self.a_free[a]);
                    self.a_free[a] -= take;
                    need -= take;
                    pending.push(NewMatch { a, y_pre: 0, b, units: take });
                }
                if need == 0 {
                    break;
                }
                // matched clusters (steal; evicts the victims' supply units)
                let mut ci = 0;
                while ci < self.a_classes[a].len() && need > 0 {
                    let y_cls = self.a_classes[a][ci].y;
                    if y_cls + yb == cq1 && self.a_classes[a][ci].count > 0 {
                        let take = need.min(self.a_classes[a][ci].count);
                        Self::steal_from_class(
                            &mut self.a_classes[a][ci],
                            take,
                            &mut self.b_free,
                        );
                        need -= take;
                        pending.push(NewMatch { a, y_pre: y_cls, b, units: take });
                    }
                    ci += 1;
                }
                self.a_classes[a].retain(|c| c.count > 0);
            }
            matched_of_b[b] = budget - need;
            // Matched units leave b's free pool now so eviction bookkeeping
            // stays exact (b_free may also have grown through evictions).
            self.b_free[b] -= matched_of_b[b];
        }

        // Apply M': matched a-copies relabel down by 1 and join the cluster
        // at y_pre − 1 with their new partner recorded.
        for nm in &pending {
            let new_y = nm.y_pre - 1;
            let classes = &mut self.a_classes[nm.a];
            let cls = match classes.iter_mut().find(|c| c.y == new_y) {
                Some(c) => c,
                None => {
                    classes.push(AClass { y: new_y, count: 0, flow: BTreeMap::new() });
                    classes.last_mut().unwrap()
                }
            };
            cls.count += nm.units;
            *cls.flow.entry(nm.b as u32).or_insert(0) += nm.units;
        }
        // Track cluster multiplicity (Lemma 4.1 check): distinct dual values
        // among a's copies = matched clusters + (free copies at dual 0).
        for a in 0..na {
            let distinct =
                self.a_classes[a].len() + usize::from(self.a_free[a] > 0);
            self.max_classes_seen = self.max_classes_seen.max(distinct);
            debug_assert!(
                self.a_classes[a].len() <= 2,
                "Lemma 4.1 violated at a={a}: {} matched clusters",
                self.a_classes[a].len()
            );
        }

        // Relabel: b's whose B'-budget wasn't fully matched move up. All of
        // b's free copies share y_free (evicted copies are raised to the
        // max — feasible because copies share b's cost row).
        for &(b, budget) in &budgets {
            if matched_of_b[b] < budget {
                self.y_free[b] += 1;
            }
        }
        true
    }

    fn steal_from_class(cls: &mut AClass, mut take: u64, b_free: &mut [u64]) {
        cls.count -= take;
        let mut emptied: Vec<u32> = Vec::new();
        for (&b_old, units) in cls.flow.iter_mut() {
            if take == 0 {
                break;
            }
            let k = take.min(*units);
            *units -= k;
            take -= k;
            // evicted copies of b_old become free (raised to y_free[b_old])
            b_free[b_old as usize] += k;
            if *units == 0 {
                emptied.push(b_old);
            }
        }
        debug_assert_eq!(take, 0, "class accounting out of sync");
        for b_old in emptied {
            cls.flow.remove(&b_old);
        }
    }

    pub fn run_to_termination(&mut self) -> Result<()> {
        let cap = ot_phase_cap(self.q.eps);
        while self.run_phase() {
            if self.phases > cap {
                return Err(OtprError::Infeasible(format!(
                    "OT phase cap {cap} exceeded (bug)"
                )));
            }
        }
        Ok(())
    }

    /// Export one ε-unit dual per *original* vertex for certification: the
    /// maximum dual among a vertex's conceptual copies. For supply b that
    /// is `y_free[b]` (the §4 free-copies-at-max invariant); for demand a
    /// it is 0 while free copies remain, else the largest cluster dual.
    /// Every copy pair satisfies `y(a)+y(b) ≤ cq+1` (conditions (2)/(3)),
    /// and the componentwise max of each side is itself a copy pair, so
    /// the exported vector inherits the relaxed feasibility the
    /// [`crate::core::certify`] lower bound needs.
    pub fn export_duals(&self) -> DualWeights {
        let ya = (0..self.q.na)
            .map(|a| {
                if self.a_free[a] > 0 {
                    0
                } else if let Some(y) = self.a_classes[a].iter().map(|c| c.y).max() {
                    y
                } else {
                    // Zero-mass demand vertex: no copies constrain it; pick
                    // the largest edge-feasible value (clamped to the sign
                    // invariant) so the exported vector stays checkable.
                    (0..self.q.nb)
                        .map(|b| self.q.at(b, a) + 1 - self.y_free[b])
                        .min()
                        .unwrap_or(0)
                        .min(0)
                }
            })
            .collect();
        DualWeights { ya, yb: self.y_free.clone() }
    }

    /// Extract the unit flow as a dense (b, a) matrix.
    pub fn unit_flow(&self) -> Vec<u64> {
        let mut flow = vec![0u64; self.q.nb * self.q.na];
        for (a, classes) in self.a_classes.iter().enumerate() {
            for cls in classes {
                for (&b, &units) in &cls.flow {
                    flow[b as usize * self.q.na + a] += units;
                }
            }
        }
        flow
    }

    /// Structural feasibility of the cluster state: counts consistent,
    /// dual signs, ε-feasibility (2)/(3) of every cluster pair, and the
    /// free-copies-at-max invariant. O(n²) — tests only.
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        for b in 0..self.q.nb {
            if self.y_free[b] < 0 {
                return Err(format!("y_free[{b}] = {} < 0", self.y_free[b]));
            }
        }
        for a in 0..self.q.na {
            for cls in &self.a_classes[a] {
                if cls.y > 0 {
                    return Err(format!("matched a-class at a={a} has positive dual"));
                }
                let total: u64 = cls.flow.values().sum();
                if total != cls.count {
                    return Err(format!("class count mismatch at a={a}"));
                }
                // (3) for matched copies: implicit b-copy dual = cq − y_cls
                // must not exceed y_free[b] (free copies are the max).
                for (&b, _) in &cls.flow {
                    let b = b as usize;
                    let implied_yb = self.q.at(b, a) - cls.y;
                    if implied_yb > self.y_free[b] {
                        return Err(format!(
                            "max-dual invariant violated: b={b} matched copy dual {} > y_free {}",
                            implied_yb, self.y_free[b]
                        ));
                    }
                }
            }
            // (2) for free b copies against free a copies (dual 0) and
            // against matched clusters.
            for b in 0..self.q.nb {
                let cq1 = self.q.at(b, a) + 1;
                if self.a_free[a] > 0 && self.b_free[b] > 0 && self.y_free[b] > cq1 {
                    return Err(format!(
                        "(2) violated free-free at (b={b},a={a}): y_free {} > cq+1 {cq1}",
                        self.y_free[b]
                    ));
                }
                if self.b_free[b] > 0 {
                    for cls in &self.a_classes[a] {
                        if cls.y + self.y_free[b] > cq1 {
                            return Err(format!(
                                "(2) violated free-b vs class at (b={b},a={a},y={})",
                                cls.y
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// The §4 OT solver. `eps` on the trait is the overall additive target
/// (error ≤ eps · c_max for unit total mass).
#[derive(Debug, Clone, Default)]
pub struct OtPushRelabel {
    /// Verify cluster invariants after every phase (tests only).
    pub paranoid: bool,
}

impl OtPushRelabel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Solve with explicit mass-scaling ε and matching ε parameters.
    pub fn solve_with_params(
        &self,
        inst: &OtInstance,
        eps_mass: f64,
        eps_match: f64,
    ) -> Result<OtSolution> {
        self.solve_with_params_ctl(inst, eps_mass, eps_match, &SolveControl::none())
    }

    /// Control-aware entry: polls `ctl` between phases and reports
    /// (phase, free supply units remaining) through its observer. A stopped
    /// solve still ships all supply (completion is unconditional) and notes
    /// `"cancelled"`.
    pub fn solve_with_params_ctl(
        &self,
        inst: &OtInstance,
        eps_mass: f64,
        eps_match: f64,
        ctl: &SolveControl,
    ) -> Result<OtSolution> {
        let sw = Stopwatch::start();
        let scaled = ScaledOtInstance::build(inst, eps_mass);
        let mut st = OtPrState::new(&inst.costs, &scaled, eps_match);
        let cap = ot_phase_cap(st.q.eps);
        let mut cancelled = false;
        loop {
            if ctl.should_stop() {
                cancelled = true;
                break;
            }
            let progressed = st.run_phase();
            if self.paranoid {
                st.check_invariants().map_err(OtprError::Infeasible)?;
            }
            if !progressed {
                break;
            }
            ctl.report(st.phases, st.free_units() as f64);
            if st.phases > cap {
                return Err(OtprError::Infeasible(format!("OT phase cap {cap} exceeded (bug)")));
            }
        }

        // Completion: remaining free supply units go to any demand with
        // residual unit capacity (first fit — the paper's "arbitrarily").
        let mut flow = st.unit_flow();
        let na = inst.costs.na;
        let mut a_free = st.a_free.clone();
        let mut cursor = 0usize;
        for b in 0..inst.costs.nb {
            let mut need = st.b_free[b];
            while need > 0 {
                while cursor < na && a_free[cursor] == 0 {
                    cursor += 1;
                }
                if cursor == na {
                    return Err(OtprError::Infeasible(
                        "no demand capacity left for completion".into(),
                    ));
                }
                let k = need.min(a_free[cursor]);
                flow[b * na + cursor] += k;
                a_free[cursor] -= k;
                need -= k;
            }
        }

        // Units → mass, then ship the sub-unit supply residuals into real
        // remaining demand capacity (greedy by capacity; ≤ ε/4 mass total).
        let mut plan = TransportPlan::zeros(inst.costs.nb, na);
        let inv = 1.0 / scaled.theta;
        for b in 0..inst.costs.nb {
            for a in 0..na {
                let f = flow[b * na + a];
                if f > 0 {
                    plan.set(b, a, f as f64 * inv);
                }
            }
        }
        let mut received = plan.demand_marginal();
        for b in 0..inst.costs.nb {
            let mut resid = scaled.supply_residual[b];
            if resid <= 0.0 {
                continue;
            }
            for a in 0..na {
                let cap = inst.demand[a] - received[a];
                if cap > 1e-15 {
                    let k = resid.min(cap);
                    plan.add(b, a, k);
                    received[a] += k;
                    resid -= k;
                    if resid <= 1e-18 {
                        break;
                    }
                }
            }
            // tiny float leftovers: dump on the last demand node
            if resid > 0.0 {
                plan.add(b, na - 1, resid);
            }
        }

        let cost = plan.cost(&inst.costs);
        let mut notes = vec![format!("max_clusters={}", st.max_classes_seen)];
        if cancelled {
            notes.push(CANCELLED_NOTE.to_string());
        }
        Ok(OtSolution {
            plan,
            cost,
            duals: Some(st.export_duals()),
            stats: SolveStats {
                phases: st.phases,
                total_free_processed: st.total_free_processed,
                rounds: 0,
                seconds: sw.elapsed_secs(),
                notes,
            },
        })
    }
}

impl OtSolver for OtPushRelabel {
    fn name(&self) -> &'static str {
        "push-relabel-ot"
    }

    fn solve_ot(&self, inst: &OtInstance, eps: f64) -> Result<OtSolution> {
        self.solve_with_params(inst, eps, eps / 6.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;
    use crate::solvers::ssp_ot::SspExactOt;

    fn check_additive(n: usize, seed: u64, eps: f64) {
        let inst = Workload::Fig1 { n }.ot_with_random_masses(seed);
        let sol = OtPushRelabel::new().solve_ot(&inst, eps).unwrap();
        // feasibility: all supply shipped; demands may exceed by the unit
        // rounding artifact ≤ 1/θ per node
        let theta = 4.0 * n as f64 / eps;
        sol.plan
            .check(&inst.supply, &inst.demand, 2.0 / theta + 1e-9)
            .unwrap();
        let exact = SspExactOt::default().solve_ot(&inst, 0.0).unwrap();
        let c_max = inst.costs.max() as f64;
        assert!(
            sol.cost <= exact.cost + eps * c_max + 1e-9,
            "n={n} seed={seed}: pr-ot {} > exact {} + {}",
            sol.cost,
            exact.cost,
            eps * c_max
        );
        assert!(sol.cost >= exact.cost - 2.0 * n as f64 / theta * c_max - 1e-9);
    }

    #[test]
    fn additive_guarantee_uniform_sizes() {
        for (n, eps) in [(8, 0.3), (16, 0.2), (24, 0.15)] {
            check_additive(n, 7, eps);
        }
    }

    #[test]
    fn additive_guarantee_various_seeds() {
        for seed in 0..4 {
            check_additive(12, seed, 0.25);
        }
    }

    #[test]
    fn invariants_hold_every_phase() {
        let inst = Workload::Fig1 { n: 10 }.ot_with_random_masses(3);
        let sol = OtPushRelabel { paranoid: true }.solve_ot(&inst, 0.3).unwrap();
        assert!(sol.cost.is_finite());
    }

    #[test]
    fn lemma_4_1_cluster_bound() {
        let inst = Workload::Fig1 { n: 20 }.ot_with_random_masses(5);
        let scaled = ScaledOtInstance::build(&inst, 0.2);
        let mut st = OtPrState::new(&inst.costs, &scaled, 0.2 / 6.0);
        st.run_to_termination().unwrap();
        assert!(
            st.max_classes_seen <= 2,
            "observed {} clusters, Lemma 4.1 bounds 2",
            st.max_classes_seen
        );
    }

    #[test]
    fn uniform_masses_match_assignment_route() {
        // uniform OT ≈ assignment optimum / n
        let n = 12;
        let inst = OtInstance::uniform(Workload::Fig1 { n }.costs(2)).unwrap();
        let eps = 0.2;
        let sol = OtPushRelabel::new().solve_ot(&inst, eps).unwrap();
        let (_, exact_match, _, _) =
            crate::solvers::hungarian::solve_exact(&inst.costs).unwrap();
        let exact = exact_match / n as f64;
        let c_max = inst.costs.max() as f64;
        assert!(sol.cost <= exact + eps * c_max + 1e-9);
    }

    #[test]
    fn all_supply_shipped() {
        let inst = Workload::Fig1 { n: 15 }.ot_with_random_masses(9);
        let sol = OtPushRelabel::new().solve_ot(&inst, 0.25).unwrap();
        assert!((sol.plan.total_mass() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn phases_reported() {
        let inst = Workload::Fig1 { n: 10 }.ot_with_random_masses(1);
        let sol = OtPushRelabel::new().solve_ot(&inst, 0.3).unwrap();
        assert!(sol.stats.phases > 0);
        assert!(sol.stats.notes[0].starts_with("max_clusters="));
    }
}
