//! Push-relabel OT solver (paper §4) as a thin driver over the shared
//! flow kernel: scale masses by θ = 4n/ε, round demands up / supplies
//! down to integer units, and run the unbalanced matching algorithm over
//! the *conceptual* unit copies — without ever materializing them.
//!
//! Copy compression lives in the kernel arena
//! ([`crate::core::kernel::KernelArena`]): free copies of a supply
//! vertex share one dual (the §4 speed-up invariant) and matched demand
//! copies group into ≤ 2 dual clusters (Lemma 4.1), stored in
//! fixed-width slots with pooled partner edges. The per-phase scan is
//! O(na · |B'|) over original vertices, giving the paper's O(n²/ε²)
//! total (Theorem 4.2). This driver owns the OT-specific policy: the
//! ε budget split, θ-scaling, the phase cap, and the completion that
//! ships residual supply.
//!
//! Error budget at target ε (additive ε·c_max on unit total mass):
//! mass rounding ≤ ε/4 + matching at ε_m = ε/6 contributes 3·ε_m = ε/2
//! + residual supply shipped greedily ≤ ε/4.

use crate::core::control::{SolveControl, CANCELLED_NOTE, DEGRADED_NOTE_PREFIX};
use crate::core::kernel::{ChunkedKernel, FlowKernel, ScalarKernel, WarmStart};
use crate::core::provider::CostSource;
use crate::core::{OtInstance, OtprError, Result, ScaledOtInstance, TransportPlan};
use crate::solvers::{OtSolution, OtSolver, SolveStats};
use crate::util::timer::Stopwatch;

/// Hard safety cap on OT phases at matching parameter `eps` (the OT
/// analog of [`crate::solvers::push_relabel::assignment_phase_cap`]).
pub fn ot_phase_cap(eps: f64) -> usize {
    (8.0 * (1.0 + 2.0 * eps) / (eps * eps)).ceil() as usize + 16
}

/// Accumulate `amount` at column `a` of a sorted sparse row — the CSR
/// equivalent of `flow[b·na+a] += amount` on the old dense slab (same
/// single f64 addition when the entry exists).
fn row_add(row: &mut Vec<(u32, f64)>, a: u32, amount: f64) {
    match row.binary_search_by_key(&a, |&(c, _)| c) {
        Ok(i) => row[i].1 += amount,
        Err(i) => row.insert(i, (a, amount)),
    }
}

/// Drive any [`FlowKernel`] backend through a full OT solve: θ-scale,
/// loop phases under the cap with `ctl` polled at every boundary, then
/// complete (leftover units + sub-unit residuals) into a feasible plan.
/// The *only* OT phase loop in the crate; the engines differ purely in
/// the backend and [`WarmStart`] policy passed here.
///
/// Warm starts schedule the **matching** ε (the kernel quantization);
/// the mass scaling θ = 4n/ε_mass is fixed across levels, so the unit
/// masses never change — only costs requantize and duals/flow carry.
pub(crate) fn drive_ot(
    kernel: &mut dyn FlowKernel,
    inst: &OtInstance,
    eps_mass: f64,
    eps_match: f64,
    ctl: &SolveControl,
    paranoid: bool,
    warm: WarmStart,
) -> Result<OtSolution> {
    drive_ot_src(
        kernel,
        &CostSource::Dense(&inst.costs),
        &inst.supply,
        &inst.demand,
        eps_mass,
        eps_match,
        ctl,
        paranoid,
        warm,
    )
}

/// [`drive_ot`] over either cost representation: masses are plain O(n)
/// marginal vectors, costs stream through the [`CostSource`] — an
/// implicit OT solve holds no O(n²) cost state, and since PR 8 the plan
/// comes back in O(nnz) CSR form too (assembled below straight from
/// [`FlowKernel::extract_plan_sparse`]; no nb·na slab on the solve path).
#[allow(clippy::too_many_arguments)]
pub(crate) fn drive_ot_src(
    kernel: &mut dyn FlowKernel,
    src: &CostSource<'_>,
    supply: &[f64],
    demand: &[f64],
    eps_mass: f64,
    eps_match: f64,
    ctl: &SolveControl,
    paranoid: bool,
    warm: WarmStart,
) -> Result<OtSolution> {
    let sw = Stopwatch::start();
    let (nb, na) = (src.nb(), src.na());
    // Level plan shared with drive_assignment via WarmStart::plan.
    let (schedule, carried, warm_started) = warm.plan(kernel.arena(), nb, na, eps_match);
    // Degrade mode (opt-in, multi-level ladders only): honor the deadline
    // at level boundaries, where the arena is a terminated — certifiable —
    // solve at that level's matching ε; mid-level only the token stops us.
    // θ is fixed across levels, so a degraded plan is still mass-feasible.
    let degrade = ctl.degrade_on_deadline() && schedule.len() >= 2;
    // Already stopped (e.g. a shared batch token fired): skip θ-scaling
    // and the arena init entirely and ship the feasible product coupling
    // ν⊗μ — the same cancelled-at-phase-0 answer the adapter layer uses.
    // Degrade-mode deadline expiry instead falls through to run the
    // coarsest level (capped work, certified answer).
    if ctl.cancel_requested() || (!degrade && ctl.should_stop()) {
        // `product` is lazy since PR 8: O(nb+na) resident, never an n²
        // slab unless a caller later forces `as_slice()`.
        let plan = TransportPlan::product(supply, demand);
        let cost = src.plan_cost(&plan);
        return Ok(OtSolution {
            cost,
            duals: None,
            stats: SolveStats {
                seconds: sw.elapsed_secs(),
                plan_state_bytes: plan.state_bytes(),
                notes: vec![CANCELLED_NOTE.to_string()],
                ..Default::default()
            },
            plan,
        });
    }
    let scaled = ScaledOtInstance::from_parts(supply, demand, nb.max(na), eps_mass);
    let masses = Some((&scaled.supply_units[..], &scaled.demand_units[..]));
    if carried {
        kernel.arena_mut().warm_reinit_src(src, eps_match, masses);
    } else {
        kernel.init_src(src, schedule[0], masses);
    }
    let mut cancelled = false;
    let mut degraded_at: Option<f64> = None;
    let mut last_completed: Option<f64> = None;
    let mut last_level_secs = 0.0f64;
    let mut levels_run = 0u32;
    let mut levels_skipped = 0u32;
    let mut li = 0usize;
    'levels: while li < schedule.len() {
        let eps_l = schedule[li];
        if degrade && levels_run > 0 {
            // Boundary degrade gate, mirroring drive_assignment: stop with
            // the previous level's certified answer when the deadline
            // passed or the remaining budget cannot cover another level.
            let pressed = ctl.should_stop()
                || ctl.remaining().is_some_and(|r| r.as_secs_f64() < last_level_secs);
            if pressed {
                if ctl.cancel_requested() {
                    cancelled = true;
                } else {
                    degraded_at = last_completed;
                }
                break 'levels;
            }
        }
        if levels_run > 0 {
            kernel.arena_mut().rescale_src(src, eps_l);
        }
        levels_run += 1;
        let level_sw = Stopwatch::start();
        let cap = ot_phase_cap(eps_l);
        let level_start = kernel.arena().phases;
        loop {
            // Mid-level, degrade mode only honors the caller's token —
            // the deadline is deferred to the next level boundary.
            let interrupt = if degrade { ctl.cancel_requested() } else { ctl.should_stop() };
            if interrupt {
                cancelled = true;
                break 'levels;
            }
            let out = kernel.run_phase();
            if paranoid {
                kernel.check_invariants().map_err(OtprError::Infeasible)?;
            }
            if out.terminated {
                break;
            }
            ctl.report(kernel.arena().phases, kernel.arena().free_units() as f64);
            if kernel.arena().phases - level_start > cap {
                return Err(OtprError::Infeasible(format!(
                    "OT phase cap {cap} exceeded at eps={eps_l} (bug)"
                )));
            }
        }
        last_level_secs = level_sw.elapsed_secs();
        last_completed = Some(eps_l);
        // Warm-start early-stop, mirroring drive_assignment: a level done
        // in ≤ 1 phase jumps the schedule straight to the target ε.
        let used = kernel.arena().phases - level_start;
        if used <= 1 && li + 1 < schedule.len() - 1 {
            levels_skipped += (schedule.len() - 2 - li) as u32;
            li = schedule.len() - 1;
        } else {
            li += 1;
        }
    }

    // Completion: remaining free supply units go to any demand with
    // residual unit capacity (first fit — the paper's "arbitrarily").
    // The solved flow leaves the arena already sparse (canonical-order
    // CSR, no nb·na densification); completion is recorded as a sparse
    // (b, a, units) list. The global first-fit cursor only moves forward,
    // so the list arrives b-ascending with strictly a-ascending entries
    // per row — mergeable against the CSR in one pass.
    let base = kernel.extract_plan_sparse();
    let mut a_free = kernel.arena().a_free().to_vec();
    let b_free = kernel.arena().b_free();
    let mut cursor = 0usize;
    let mut extra: Vec<(usize, u32, u64)> = Vec::new();
    for b in 0..nb {
        let mut need = b_free[b];
        while need > 0 {
            while cursor < na && a_free[cursor] == 0 {
                cursor += 1;
            }
            if cursor == na {
                return Err(OtprError::Infeasible(
                    "no demand capacity left for completion".into(),
                ));
            }
            let k = need.min(a_free[cursor]);
            extra.push((b, cursor as u32, k));
            a_free[cursor] -= k;
            need -= k;
        }
    }

    // Units → mass in canonical order: merge each solved CSR row with its
    // completion entries (both a-ascending), scaling units by 1/θ exactly
    // as the dense path did — a completion unit landing on an existing
    // entry sums in units first, so the produced value is bit-identical
    // to the old `flow[b·na+a] += k; f as f64 * inv` slab arithmetic.
    let inv = 1.0 / scaled.theta;
    let mut rows: Vec<Vec<(u32, f64)>> = Vec::with_capacity(nb);
    let mut ei = 0usize;
    for b in 0..nb {
        let (lo, hi) = (base.row_ptr[b], base.row_ptr[b + 1]);
        let mut row: Vec<(u32, f64)> = Vec::with_capacity(hi - lo + 1);
        let mut i = lo;
        while ei < extra.len() && extra[ei].0 == b {
            let (_, a, k) = extra[ei];
            while i < hi && base.col_idx[i] < a {
                row.push((base.col_idx[i], base.units[i] as f64 * inv));
                i += 1;
            }
            if i < hi && base.col_idx[i] == a {
                row.push((a, (base.units[i] + k) as f64 * inv));
                i += 1;
            } else {
                row.push((a, k as f64 * inv));
            }
            ei += 1;
        }
        while i < hi {
            row.push((base.col_idx[i], base.units[i] as f64 * inv));
            i += 1;
        }
        rows.push(row);
    }

    // Ship the sub-unit supply residuals into real remaining demand
    // capacity (greedy by capacity; ≤ ε/4 mass total). `received` is
    // accumulated per column in b-ascending order — the same fold
    // `demand_marginal` runs on the dense slab, so every comparison below
    // sees bit-identical values.
    let mut received = vec![0.0; na];
    for row in &rows {
        for &(a, v) in row {
            received[a as usize] += v;
        }
    }
    for b in 0..nb {
        let mut resid = scaled.supply_residual[b];
        if resid <= 0.0 {
            continue;
        }
        for a in 0..na {
            let cap = demand[a] - received[a];
            if cap > 1e-15 {
                let k = resid.min(cap);
                row_add(&mut rows[b], a as u32, k);
                received[a] += k;
                resid -= k;
                if resid <= 1e-18 {
                    break;
                }
            }
        }
        // tiny float leftovers: dump on the last demand node
        if resid > 0.0 {
            row_add(&mut rows[b], (na - 1) as u32, resid);
        }
    }

    // Flatten into the canonical-order CSR plan (validated on entry).
    let nnz: usize = rows.iter().map(Vec::len).sum();
    let mut row_ptr = Vec::with_capacity(nb + 1);
    let mut col_idx = Vec::with_capacity(nnz);
    let mut vals = Vec::with_capacity(nnz);
    row_ptr.push(0);
    for row in &rows {
        for &(a, v) in row {
            col_idx.push(a);
            vals.push(v);
        }
        row_ptr.push(col_idx.len());
    }
    let plan = TransportPlan::from_csr(nb, na, row_ptr, col_idx, vals)
        .map_err(|e| OtprError::Infeasible(format!("sparse plan assembly: {e}")))?;

    let cost = src.plan_cost(&plan);
    let arena = kernel.arena();
    let mut notes = vec![format!("max_clusters={}", arena.max_classes_seen)];
    if cancelled {
        notes.push(CANCELLED_NOTE.to_string());
    }
    if let Some(eps_l) = degraded_at {
        notes.push(format!("{DEGRADED_NOTE_PREFIX}{eps_l}"));
    }
    if levels_skipped > 0 {
        notes.push(format!("warm_skip={levels_skipped}"));
    }
    Ok(OtSolution {
        cost,
        duals: Some(kernel.duals()),
        stats: SolveStats {
            phases: arena.phases,
            total_free_processed: arena.total_free_processed,
            rounds: arena.rounds,
            seconds: sw.elapsed_secs(),
            arena_reused: arena.last_init_reused,
            warm_started,
            // levels actually entered — a cancellation or an early-stop
            // mid-schedule must not report levels that never ran
            eps_levels: levels_run.max(1),
            cost_state_bytes: arena.cost_state_bytes(),
            plan_state_bytes: plan.state_bytes(),
            notes,
        },
        plan,
    })
}

/// The §4 OT solver. `eps` on the trait is the overall additive target
/// (error ≤ eps · c_max for unit total mass). `threads = 1` runs the
/// scalar kernel backend; more runs the chunked thread-sweep — both
/// produce identical plans and duals (the kernel contract).
#[derive(Debug, Clone, Default)]
pub struct OtPushRelabel {
    /// Verify cluster invariants after every phase (tests only).
    pub paranoid: bool,
    /// 0 or 1 → scalar backend; ≥ 2 → chunked backend.
    pub threads: usize,
    /// ε-scaling warm-start levels on the matching ε (0/1 = cold).
    pub warm_levels: u32,
}

impl OtPushRelabel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run the chunked kernel backend with `threads` sweep threads.
    pub fn with_threads(threads: usize) -> Self {
        Self { paranoid: false, threads, warm_levels: 0 }
    }

    /// Solve with explicit mass-scaling ε and matching ε parameters.
    pub fn solve_with_params(
        &self,
        inst: &OtInstance,
        eps_mass: f64,
        eps_match: f64,
    ) -> Result<OtSolution> {
        self.solve_with_params_ctl(inst, eps_mass, eps_match, &SolveControl::none())
    }

    /// Control-aware entry: polls `ctl` between phases and reports
    /// (phase, free supply units remaining) through its observer. A stopped
    /// solve still ships all supply (completion is unconditional) and notes
    /// `"cancelled"`.
    pub fn solve_with_params_ctl(
        &self,
        inst: &OtInstance,
        eps_mass: f64,
        eps_match: f64,
        ctl: &SolveControl,
    ) -> Result<OtSolution> {
        let warm = WarmStart { levels: self.warm_levels, carry: false };
        if self.threads >= 2 {
            let mut kernel = ChunkedKernel::new(self.threads);
            drive_ot(&mut kernel, inst, eps_mass, eps_match, ctl, self.paranoid, warm)
        } else {
            let mut kernel = ScalarKernel::new();
            drive_ot(&mut kernel, inst, eps_mass, eps_match, ctl, self.paranoid, warm)
        }
    }
}

impl OtSolver for OtPushRelabel {
    fn name(&self) -> &'static str {
        "push-relabel-ot"
    }

    fn solve_ot(&self, inst: &OtInstance, eps: f64) -> Result<OtSolution> {
        self.solve_with_params(inst, eps, eps / 6.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;
    use crate::solvers::ssp_ot::SspExactOt;

    fn check_additive(n: usize, seed: u64, eps: f64) {
        let inst = Workload::Fig1 { n }.ot_with_random_masses(seed);
        let sol = OtPushRelabel::new().solve_ot(&inst, eps).unwrap();
        // feasibility: all supply shipped; demands may exceed by the unit
        // rounding artifact ≤ 1/θ per node
        let theta = 4.0 * n as f64 / eps;
        sol.plan
            .check(&inst.supply, &inst.demand, 2.0 / theta + 1e-9)
            .unwrap();
        let exact = SspExactOt::default().solve_ot(&inst, 0.0).unwrap();
        let c_max = inst.costs.max() as f64;
        assert!(
            sol.cost <= exact.cost + eps * c_max + 1e-9,
            "n={n} seed={seed}: pr-ot {} > exact {} + {}",
            sol.cost,
            exact.cost,
            eps * c_max
        );
        assert!(sol.cost >= exact.cost - 2.0 * n as f64 / theta * c_max - 1e-9);
    }

    #[test]
    fn additive_guarantee_uniform_sizes() {
        for (n, eps) in [(8, 0.3), (16, 0.2), (24, 0.15)] {
            check_additive(n, 7, eps);
        }
    }

    #[test]
    fn additive_guarantee_various_seeds() {
        for seed in 0..4 {
            check_additive(12, seed, 0.25);
        }
    }

    #[test]
    fn invariants_hold_every_phase() {
        let inst = Workload::Fig1 { n: 10 }.ot_with_random_masses(3);
        let sol = OtPushRelabel { paranoid: true, threads: 0, warm_levels: 0 }
            .solve_ot(&inst, 0.3)
            .unwrap();
        assert!(sol.cost.is_finite());
    }

    #[test]
    fn lemma_4_1_cluster_bound() {
        let inst = Workload::Fig1 { n: 20 }.ot_with_random_masses(5);
        let scaled = ScaledOtInstance::build(&inst, 0.2);
        let mut k = ScalarKernel::new();
        k.init(
            &inst.costs,
            0.2 / 6.0,
            Some((&scaled.supply_units[..], &scaled.demand_units[..])),
        );
        k.run_to_termination(ot_phase_cap(0.2 / 6.0)).unwrap();
        assert!(
            k.arena().max_classes_seen <= 2,
            "observed {} clusters, Lemma 4.1 bounds 2",
            k.arena().max_classes_seen
        );
    }

    #[test]
    fn uniform_masses_match_assignment_route() {
        // uniform OT ≈ assignment optimum / n
        let n = 12;
        let inst = OtInstance::uniform(Workload::Fig1 { n }.costs(2)).unwrap();
        let eps = 0.2;
        let sol = OtPushRelabel::new().solve_ot(&inst, eps).unwrap();
        let (_, exact_match, _, _) =
            crate::solvers::hungarian::solve_exact(&inst.costs).unwrap();
        let exact = exact_match / n as f64;
        let c_max = inst.costs.max() as f64;
        assert!(sol.cost <= exact + eps * c_max + 1e-9);
    }

    #[test]
    fn all_supply_shipped() {
        let inst = Workload::Fig1 { n: 15 }.ot_with_random_masses(9);
        let sol = OtPushRelabel::new().solve_ot(&inst, 0.25).unwrap();
        assert!((sol.plan.total_mass() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn phases_reported() {
        let inst = Workload::Fig1 { n: 10 }.ot_with_random_masses(1);
        let sol = OtPushRelabel::new().solve_ot(&inst, 0.3).unwrap();
        assert!(sol.stats.phases > 0);
        assert!(sol.stats.notes[0].starts_with("max_clusters="));
    }

    #[test]
    fn warm_started_ot_keeps_the_additive_guarantee() {
        let inst = Workload::Fig1 { n: 14 }.ot_with_random_masses(6);
        let eps = 0.25;
        let warm = OtPushRelabel { paranoid: true, threads: 0, warm_levels: 3 }
            .solve_ot(&inst, eps)
            .unwrap();
        assert!(warm.stats.warm_started);
        assert!(warm.stats.eps_levels >= 2);
        assert!((warm.plan.total_mass() - 1.0).abs() < 1e-9, "all supply shipped");
        let exact = SspExactOt::default().solve_ot(&inst, 0.0).unwrap();
        let c_max = inst.costs.max() as f64;
        assert!(
            warm.cost <= exact.cost + eps * c_max + 1e-9,
            "warm {} > exact {} + {}",
            warm.cost,
            exact.cost,
            eps * c_max
        );
    }

    #[test]
    fn chunked_backend_identical_to_scalar_on_ot() {
        for seed in [1u64, 4] {
            let inst = Workload::Fig1 { n: 14 }.ot_with_random_masses(seed);
            let scalar = OtPushRelabel::new().solve_ot(&inst, 0.25).unwrap();
            for threads in [2usize, 4] {
                let par = OtPushRelabel::with_threads(threads).solve_ot(&inst, 0.25).unwrap();
                assert_eq!(
                    scalar.plan.as_slice(),
                    par.plan.as_slice(),
                    "seed {seed} threads {threads}"
                );
                assert_eq!(scalar.duals, par.duals);
            }
        }
    }
}
