//! The paper's push-relabel algorithm for the assignment problem (§2.2),
//! sequential implementation with the per-phase structure of Lemma 3.4.
//!
//! State is an ε-feasible pair (M, y) in integer ε-units. Each phase:
//!
//! 1. collect B' (free supply vertices); stop when `|B'| ≤ ε·nb`;
//! 2. **greedy step** — maximal matching M' over admissible edges incident
//!    to B' (scan each b's row for the first admissible a not yet taken);
//! 3. **matching update (push)** — add M' to M, evicting the old partner of
//!    any re-matched a;
//! 4. **dual update (relabel)** — `y(a) -= 1` for a ∈ M', `y(b) += 1` for
//!    b ∈ B' left unmatched by M'.
//!
//! The final ≤ ε·nb free vertices are matched arbitrarily, for a total
//! additive error ≤ 3ε·n·c_max (rounding + feasibility + completion).
//! [`PrState`] exposes single phases so property tests can verify the
//! invariants (I1)/(I2) after *every* phase, not just at the end.

use crate::core::control::{SolveControl, CANCELLED_NOTE};
use crate::core::duals::{check_feasible, DualWeights};
use crate::core::matching::{Matching, FREE};
use crate::core::quantize::QuantizedCosts;
use crate::core::{AssignmentInstance, CostMatrix, OtprError, Result};
use crate::solvers::{AssignmentSolution, AssignmentSolver, SolveStats};
use crate::util::timer::Stopwatch;

/// Hard safety cap on assignment phases at parameter `eps`: 4× the
/// Lemma 3.2/3.3 bound (1+2ε)/ε², plus slack. Exceeding it means the
/// phase-count bound is violated — a bug, not a slow instance. Shared by
/// the sequential, parallel, and XLA phase loops.
pub(crate) fn assignment_phase_cap(eps: f64) -> usize {
    (4.0 * (1.0 + 2.0 * eps) / (eps * eps)).ceil() as usize + 4
}

/// Outcome of one phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseOutcome {
    /// |B'| at the start of the phase (0 ⇒ nothing to do).
    pub free_at_start: usize,
    /// Edges matched by the greedy step M'.
    pub matched: usize,
    /// True when the termination condition |B'| ≤ ε·nb held (no phase run).
    pub terminated: bool,
}

/// Mutable solver state; drives the paper's main routine phase by phase.
#[derive(Debug, Clone)]
pub struct PrState {
    pub q: QuantizedCosts,
    pub m: Matching,
    pub y: DualWeights,
    pub phases: usize,
    pub total_free_processed: u64,
    /// Scratch: a ∈ A taken by M' in the current phase.
    taken: Vec<bool>,
    /// Scratch: M' pairs of the current phase.
    mprime: Vec<(usize, usize)>,
}

impl PrState {
    /// Initialize from costs at algorithm parameter `eps` (the paper's ε:
    /// the result is a 3ε-approximation). y(b)=1 unit, y(a)=0, M=∅.
    pub fn new(costs: &CostMatrix, eps: f64) -> Self {
        let q = QuantizedCosts::new(costs, eps);
        let (nb, na) = (q.nb, q.na);
        Self {
            q,
            m: Matching::empty(nb, na),
            y: DualWeights::init(nb, na),
            phases: 0,
            total_free_processed: 0,
            taken: vec![false; na],
            mprime: Vec::new(),
        }
    }

    /// Termination threshold: phase runs only while |B'| > ε·nb.
    pub fn threshold(&self) -> usize {
        (self.q.eps * self.q.nb as f64).floor() as usize
    }

    pub fn free_b_count(&self) -> usize {
        self.m.match_b.iter().filter(|&&a| a == FREE).count()
    }

    /// Run one phase. Returns the outcome; `terminated` means the stopping
    /// condition held and no work was done.
    pub fn run_phase(&mut self) -> PhaseOutcome {
        let free_b: Vec<usize> = self.m.free_b();
        if free_b.len() <= self.threshold() {
            return PhaseOutcome { free_at_start: free_b.len(), matched: 0, terminated: true };
        }
        self.phases += 1;
        self.total_free_processed += free_b.len() as u64;

        // (I) Greedy step: maximal matching M' over admissible edges with an
        // endpoint in B'. Processing each b and taking its first admissible
        // available a is exactly the greedy of Lemma 3.4.
        self.taken.fill(false);
        self.mprime.clear();
        let na = self.q.na;
        for &b in &free_b {
            let yb = self.y.yb[b];
            let row = self.q.row(b);
            let ya = &self.y.ya;
            let mut found = usize::MAX;
            for a in 0..na {
                // admissible ⟺ tight for (2): y(a)+y(b) == cq+1
                if !self.taken[a] && ya[a] + yb == row[a] + 1 {
                    found = a;
                    break;
                }
            }
            if found != usize::MAX {
                self.taken[found] = true;
                self.mprime.push((b, found));
            }
        }

        // (II) Matching update: add M' evicting old partners of re-matched
        // a's (Matching::link handles the eviction), then (III.a) relabel
        // matched a's downward.
        for &(b, a) in &self.mprime {
            self.m.link(b, a);
            self.y.ya[a] -= 1;
        }

        // (III.b) Relabel: b ∈ B' not matched by M' moves up. A b ∈ B'
        // matched by M' cannot be evicted within the same phase (each a is
        // taken at most once), so "unmatched by M'" ⟺ still free in M.
        for &b in &free_b {
            if self.m.match_b[b] == FREE {
                self.y.yb[b] += 1;
            }
        }

        PhaseOutcome {
            free_at_start: free_b.len(),
            matched: self.mprime.len(),
            terminated: false,
        }
    }

    /// Run phases until the termination condition, with the
    /// [`assignment_phase_cap`] safety cap.
    pub fn run_to_termination(&mut self) -> Result<()> {
        let cap = assignment_phase_cap(self.q.eps);
        loop {
            let out = self.run_phase();
            if out.terminated {
                return Ok(());
            }
            if self.phases > cap {
                return Err(OtprError::Infeasible(format!(
                    "phase cap {cap} exceeded — phase-count bound violated (bug)"
                )));
            }
        }
    }

    /// ε-feasibility + invariants; used by tests after every phase.
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        check_feasible(&self.q, &self.m, &self.y)
    }
}

/// The paper's algorithm as an [`AssignmentSolver`].
///
/// `eps` passed to [`AssignmentSolver::solve_assignment`] is the **overall**
/// additive target (error ≤ eps·n·c_max): the core routine runs at ε/3
/// (paper §1 "Organization"). Use [`PushRelabel::solve_with_param`] to drive
/// the algorithm at a raw ε (3ε guarantee) — that is what the experiment
/// harness does, matching the paper's own plots.
#[derive(Debug, Clone, Default)]
pub struct PushRelabel {
    /// Verify invariants after every phase (tests; O(n²) per phase).
    pub paranoid: bool,
}

impl PushRelabel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run at raw algorithm parameter `eps_param` (additive 3·ε·n·c_max).
    pub fn solve_with_param(
        &self,
        inst: &AssignmentInstance,
        eps_param: f64,
    ) -> Result<AssignmentSolution> {
        self.solve_with_param_ctl(inst, eps_param, &SolveControl::none())
    }

    /// Control-aware entry: polls `ctl` between phases (cancellation /
    /// wall-clock budget) and reports (phase, free vertices remaining)
    /// through its observer. A stopped solve completes arbitrarily like the
    /// normal path and notes `"cancelled"` — it is still a perfect
    /// matching, just without the additive guarantee.
    pub fn solve_with_param_ctl(
        &self,
        inst: &AssignmentInstance,
        eps_param: f64,
        ctl: &SolveControl,
    ) -> Result<AssignmentSolution> {
        let sw = Stopwatch::start();
        let n = inst.n();
        if n == 0 {
            return Ok(AssignmentSolution {
                matching: Matching::empty(0, 0),
                cost: 0.0,
                duals: None,
                stats: SolveStats::default(),
            });
        }
        let mut st = PrState::new(&inst.costs, eps_param);
        let cap = assignment_phase_cap(eps_param);
        let mut cancelled = false;
        loop {
            if ctl.should_stop() {
                cancelled = true;
                break;
            }
            let out = st.run_phase();
            if self.paranoid {
                st.check_invariants().map_err(OtprError::Infeasible)?;
            }
            if out.terminated {
                break;
            }
            // Recount rather than free_at_start - matched: pushes can evict
            // already-matched partners, which return to the free pool.
            let free_left = st.m.match_b.iter().filter(|&&a| a == FREE).count();
            ctl.report(st.phases, free_left as f64);
            if st.phases > cap {
                return Err(OtprError::Infeasible(format!(
                    "phase cap {cap} exceeded — phase-count bound violated (bug)"
                )));
            }
        }
        // arbitrary completion of the ≤ εn leftover free vertices
        st.m.complete_arbitrarily();
        debug_assert!(st.m.is_perfect());
        let cost = st.m.cost(&inst.costs);
        let mut notes = Vec::new();
        if cancelled {
            notes.push(CANCELLED_NOTE.to_string());
        }
        Ok(AssignmentSolution {
            matching: st.m,
            cost,
            duals: Some(st.y),
            stats: SolveStats {
                phases: st.phases,
                total_free_processed: st.total_free_processed,
                rounds: 0,
                seconds: sw.elapsed_secs(),
                notes,
            },
        })
    }
}

impl AssignmentSolver for PushRelabel {
    fn name(&self) -> &'static str {
        "push-relabel"
    }

    fn solve_assignment(&self, inst: &AssignmentInstance, eps: f64) -> Result<AssignmentSolution> {
        self.solve_with_param(inst, eps / 3.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;

    fn inst(n: usize, seed: u64) -> AssignmentInstance {
        Workload::Fig1 { n }.assignment(seed)
    }

    #[test]
    fn produces_perfect_matching() {
        let i = inst(40, 1);
        let sol = PushRelabel::new().solve_with_param(&i, 0.1).unwrap();
        assert!(sol.matching.is_perfect());
        assert!(sol.matching.check_consistent().is_ok());
        assert!(sol.cost > 0.0);
    }

    #[test]
    fn invariants_hold_every_phase() {
        let i = inst(30, 2);
        let sol = PushRelabel { paranoid: true }.solve_with_param(&i, 0.2).unwrap();
        assert!(sol.matching.is_perfect());
    }

    #[test]
    fn phase_count_within_bound() {
        let i = inst(60, 3);
        let eps = 0.1;
        let sol = PushRelabel::new().solve_with_param(&i, eps).unwrap();
        let bound = ((1.0 + 2.0 * eps) / (eps * eps)).ceil() as usize;
        assert!(
            sol.stats.phases <= bound,
            "phases {} > bound {bound}",
            sol.stats.phases
        );
    }

    #[test]
    fn total_free_processed_bound() {
        // eq. (4): Σ n_i ≤ n(1+2ε)/ε
        let i = inst(80, 4);
        let eps = 0.2;
        let sol = PushRelabel::new().solve_with_param(&i, eps).unwrap();
        let bound = (80.0 * (1.0 + 2.0 * eps) / eps).ceil() as u64;
        assert!(
            sol.stats.total_free_processed <= bound,
            "{} > {bound}",
            sol.stats.total_free_processed
        );
    }

    #[test]
    fn smaller_eps_no_worse_cost() {
        let i = inst(50, 5);
        let hi = PushRelabel::new().solve_with_param(&i, 0.5).unwrap();
        let lo = PushRelabel::new().solve_with_param(&i, 0.02).unwrap();
        assert!(lo.cost <= hi.cost + 1e-6, "lo={} hi={}", lo.cost, hi.cost);
    }

    #[test]
    fn termination_on_tiny_instances() {
        for n in [1usize, 2, 3] {
            let i = inst(n, 6);
            let sol = PushRelabel::new().solve_with_param(&i, 0.3).unwrap();
            assert!(sol.matching.is_perfect(), "n={n}");
        }
    }

    #[test]
    fn zero_cost_instance() {
        let i = AssignmentInstance::new(CostMatrix::zeros(5, 5)).unwrap();
        let sol = PushRelabel::new().solve_with_param(&i, 0.1).unwrap();
        assert!(sol.matching.is_perfect());
        assert_eq!(sol.cost, 0.0);
    }

    #[test]
    fn trait_entry_divides_eps() {
        let i = inst(20, 7);
        let s = PushRelabel::new();
        let via_trait = s.solve_assignment(&i, 0.3).unwrap();
        let via_param = s.solve_with_param(&i, 0.3 / 3.0).unwrap();
        assert_eq!(via_trait.matching, via_param.matching);
    }

    #[test]
    fn dual_certificate_bounds_cost() {
        // Lemma 3.1 machinery: rounded cost of produced matching before
        // completion ≤ Σy ≤ OPT̄ + εn. Here we sanity-check the final cost
        // against the dual lower bound certificate.
        let i = inst(40, 8);
        let eps = 0.1;
        let mut st = PrState::new(&i.costs, eps);
        st.run_to_termination().unwrap();
        st.check_invariants().unwrap();
        // rounded matching cost in units == Σ_{(a,b)∈M} cq = Σ y(a)+y(b) over M
        let mut cost_units: i64 = 0;
        for (b, &a) in st.m.match_b.iter().enumerate() {
            if a != FREE {
                cost_units += st.q.at(b, a as usize) as i64;
            }
        }
        let dual_total: i64 = st.y.ya.iter().map(|&v| v as i64).sum::<i64>()
            + st.y.yb.iter().map(|&v| v as i64).sum::<i64>();
        assert!(cost_units <= dual_total, "matched cost {cost_units} > Σy {dual_total}");
    }
}
