//! The paper's push-relabel algorithm for the assignment problem (§2.2)
//! as a thin **driver** over the shared flow kernel
//! ([`crate::core::kernel`]): the driver owns policy (ε semantics, the
//! Lemma 3.2/3.3 phase cap, cancellation polling, arbitrary completion)
//! while the kernel owns the per-phase mechanics of Lemma 3.4 —
//! greedy maximal matching over admissible edges, push, relabel — in
//! one flat arena shared with the parallel and OT drivers.
//!
//! Assignment is the unit-mass special case of the kernel's §4 state:
//! every vertex carries one conceptual copy, the termination threshold
//! `|B'| ≤ ε·nb` falls out of the unit-mass form of `ε·U`, and the final
//! ≤ ε·nb free vertices are matched arbitrarily for a total additive
//! error ≤ 3ε·n·c_max (rounding + feasibility + completion).

use crate::core::control::{SolveControl, CANCELLED_NOTE, DEGRADED_NOTE_PREFIX};
use crate::core::duals::check_feasible;
use crate::core::kernel::{FlowKernel, ScalarKernel, WarmStart};
use crate::core::matching::Matching;
use crate::core::provider::CostSource;
use crate::core::{AssignmentInstance, OtprError, Result};
use crate::solvers::{AssignmentSolution, AssignmentSolver, SolveStats};
use crate::util::timer::Stopwatch;

/// Hard safety cap on assignment phases at parameter `eps`: 4× the
/// Lemma 3.2/3.3 bound (1+2ε)/ε², plus slack. Exceeding it means the
/// phase-count bound is violated — a bug, not a slow instance. Shared by
/// the sequential, parallel, and XLA phase loops.
pub fn assignment_phase_cap(eps: f64) -> usize {
    (4.0 * (1.0 + 2.0 * eps) / (eps * eps)).ceil() as usize + 4
}

/// Drive any [`FlowKernel`] backend through a full assignment solve:
/// init (or warm-start), loop phases under the cap with `ctl` polled at
/// every boundary, then complete arbitrarily and extract. This is the
/// *only* assignment phase loop in the crate — the engines differ purely
/// in the kernel backend and [`WarmStart`] policy they pass.
///
/// Warm starts: a `warm.levels ≥ 2` request solves the geometric ε
/// schedule (4ε → 2ε → ε), rescaling the arena in place between levels;
/// `warm.carry` additionally reuses the arena's duals from a previous
/// same-shape solve (the batch path) and jumps straight to the target ε.
/// Either way the final state is exactly as ε-feasible as a cold solve,
/// so the Theorem 1 guarantee and every certificate check carry over.
pub(crate) fn drive_assignment(
    kernel: &mut dyn FlowKernel,
    inst: &AssignmentInstance,
    eps_param: f64,
    ctl: &SolveControl,
    paranoid: bool,
    warm: WarmStart,
) -> Result<AssignmentSolution> {
    drive_assignment_src(kernel, &CostSource::Dense(&inst.costs), eps_param, ctl, paranoid, warm)
}

/// [`drive_assignment`] over either cost representation
/// ([`CostSource::Dense`] is the historical byte-identical path;
/// [`CostSource::Implicit`] streams rows from a
/// [`crate::core::CostProvider`] and never materializes the O(n²) slab).
pub(crate) fn drive_assignment_src(
    kernel: &mut dyn FlowKernel,
    src: &CostSource<'_>,
    eps_param: f64,
    ctl: &SolveControl,
    paranoid: bool,
    warm: WarmStart,
) -> Result<AssignmentSolution> {
    let sw = Stopwatch::start();
    let (nb, na) = (src.nb(), src.na());
    if nb.max(na) == 0 {
        return Ok(AssignmentSolution {
            matching: Matching::empty(0, 0),
            cost: 0.0,
            duals: None,
            stats: SolveStats::default(),
        });
    }
    // Level plan (shared with drive_ot via WarmStart::plan): a batch
    // carry reuses the arena's duals and jumps straight to the target ε;
    // otherwise a multi-level warm start solves the geometric schedule,
    // rescaling the arena between levels.
    let (schedule, carried, warm_started) = warm.plan(kernel.arena(), nb, na, eps_param);
    // Degrade mode (opt-in, multi-level ladders only): the deadline is
    // honored at level *boundaries*, where the arena state is a terminated
    // — hence certifiable — solve at that level's ε. Mid-level the state is
    // worthless to return, so only the caller's token interrupts phases.
    let degrade = ctl.degrade_on_deadline() && schedule.len() >= 2;
    // Already stopped (e.g. a shared batch token fired): skip the arena
    // init entirely — remaining batch items abandon near-free with the
    // same cancelled-at-phase-0 coupling a mid-run stop produces. A
    // degrade-mode deadline expiry instead falls through and runs the
    // coarsest level: its cost is bounded by the level phase cap and it
    // yields a certified answer where cancellation yields none.
    if ctl.cancel_requested() || (!degrade && ctl.should_stop()) {
        let matching = Matching::arbitrary_complete(nb, na);
        let cost = src.matching_cost(&matching);
        return Ok(AssignmentSolution {
            matching,
            cost,
            duals: None,
            stats: SolveStats {
                seconds: sw.elapsed_secs(),
                notes: vec![CANCELLED_NOTE.to_string()],
                ..Default::default()
            },
        });
    }
    if carried {
        kernel.arena_mut().warm_reinit_src(src, eps_param, None);
    } else {
        kernel.init_src(src, schedule[0], None);
    }
    let mut cancelled = false;
    let mut degraded_at: Option<f64> = None;
    let mut last_completed: Option<f64> = None;
    let mut last_level_secs = 0.0f64;
    let mut levels_run = 0u32;
    let mut levels_skipped = 0u32;
    let mut li = 0usize;
    'levels: while li < schedule.len() {
        let eps_l = schedule[li];
        if degrade && levels_run > 0 {
            // Boundary degrade gate: stop with the previous level's
            // certified answer when the deadline passed, or when the
            // remaining budget cannot cover a level at least as expensive
            // as the one just finished (finer levels only cost more).
            let pressed = ctl.should_stop()
                || ctl.remaining().is_some_and(|r| r.as_secs_f64() < last_level_secs);
            if pressed {
                if ctl.cancel_requested() {
                    cancelled = true;
                } else {
                    degraded_at = last_completed;
                }
                break 'levels;
            }
        }
        if levels_run > 0 {
            kernel.arena_mut().rescale_src(src, eps_l);
        }
        levels_run += 1;
        let level_sw = Stopwatch::start();
        let cap = assignment_phase_cap(eps_l);
        let level_start = kernel.arena().phases;
        loop {
            // Mid-level, degrade mode only honors the caller's token —
            // the deadline is deferred to the next level boundary.
            let interrupt = if degrade { ctl.cancel_requested() } else { ctl.should_stop() };
            if interrupt {
                cancelled = true;
                break 'levels;
            }
            let out = kernel.run_phase();
            if paranoid {
                kernel.check_invariants().map_err(OtprError::Infeasible)?;
                check_feasible(&kernel.arena().q, &kernel.extract_matching(), &kernel.duals())
                    .map_err(OtprError::Infeasible)?;
            }
            if out.terminated {
                break;
            }
            // Recount rather than free_at_start - matched: pushes can evict
            // already-matched partners, which return to the free pool.
            ctl.report(kernel.arena().phases, kernel.arena().free_units() as f64);
            if kernel.arena().phases - level_start > cap {
                return Err(OtprError::Infeasible(format!(
                    "phase cap {cap} exceeded at eps={eps_l} — phase-count bound violated (bug)"
                )));
            }
        }
        last_level_secs = level_sw.elapsed_secs();
        last_completed = Some(eps_l);
        // Warm-start early-stop: a level that terminated in ≤ 1 phase
        // says the carried duals are already essentially feasible at this
        // coarseness — intermediate levels would only rescale state that
        // no longer changes, so jump straight to the target ε. (The ε
        // ratio stays a power of two, which the rescale contract needs.)
        let used = kernel.arena().phases - level_start;
        if used <= 1 && li + 1 < schedule.len() - 1 {
            levels_skipped += (schedule.len() - 2 - li) as u32;
            li = schedule.len() - 1;
        } else {
            li += 1;
        }
    }
    // arbitrary completion of the ≤ εn leftover free vertices
    let mut matching = kernel.extract_matching();
    matching.complete_arbitrarily();
    debug_assert!(nb > na || matching.is_perfect());
    let cost = src.matching_cost(&matching);
    let duals = kernel.duals();
    let mut notes = Vec::new();
    if cancelled {
        notes.push(CANCELLED_NOTE.to_string());
    }
    if let Some(eps_l) = degraded_at {
        notes.push(format!("{DEGRADED_NOTE_PREFIX}{eps_l}"));
    }
    if levels_skipped > 0 {
        notes.push(format!("warm_skip={levels_skipped}"));
    }
    let arena = kernel.arena();
    Ok(AssignmentSolution {
        matching,
        cost,
        duals: Some(duals),
        stats: SolveStats {
            phases: arena.phases,
            total_free_processed: arena.total_free_processed,
            rounds: arena.rounds,
            seconds: sw.elapsed_secs(),
            arena_reused: arena.last_init_reused,
            warm_started,
            // levels actually entered — a cancellation or an early-stop
            // mid-schedule must not report levels that never ran
            eps_levels: levels_run.max(1),
            cost_state_bytes: arena.cost_state_bytes(),
            // assignment solves return a matching, not a plan
            plan_state_bytes: 0,
            notes,
        },
    })
}

/// The paper's algorithm as an [`AssignmentSolver`], sequential backend.
///
/// `eps` passed to [`AssignmentSolver::solve_assignment`] is the **overall**
/// additive target (error ≤ eps·n·c_max): the core routine runs at ε/3
/// (paper §1 "Organization"). Use [`PushRelabel::solve_with_param`] to drive
/// the algorithm at a raw ε (3ε guarantee) — that is what the experiment
/// harness does, matching the paper's own plots.
#[derive(Debug, Clone, Default)]
pub struct PushRelabel {
    /// Verify invariants after every phase (tests; O(n²) per phase).
    pub paranoid: bool,
    /// ε-scaling warm-start levels (0 or 1 = the historical cold solve;
    /// ≥ 2 = geometric schedule, see [`WarmStart`]).
    pub warm_levels: u32,
}

impl PushRelabel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run at raw algorithm parameter `eps_param` (additive 3·ε·n·c_max).
    pub fn solve_with_param(
        &self,
        inst: &AssignmentInstance,
        eps_param: f64,
    ) -> Result<AssignmentSolution> {
        self.solve_with_param_ctl(inst, eps_param, &SolveControl::none())
    }

    /// Control-aware entry: polls `ctl` between phases (cancellation /
    /// wall-clock budget) and reports (phase, free vertices remaining)
    /// through its observer. A stopped solve completes arbitrarily like the
    /// normal path and notes `"cancelled"` — it is still a perfect
    /// matching, just without the additive guarantee.
    pub fn solve_with_param_ctl(
        &self,
        inst: &AssignmentInstance,
        eps_param: f64,
        ctl: &SolveControl,
    ) -> Result<AssignmentSolution> {
        let mut kernel = ScalarKernel::new();
        let warm = WarmStart { levels: self.warm_levels, carry: false };
        drive_assignment(&mut kernel, inst, eps_param, ctl, self.paranoid, warm)
    }
}

impl AssignmentSolver for PushRelabel {
    fn name(&self) -> &'static str {
        "push-relabel"
    }

    fn solve_assignment(&self, inst: &AssignmentInstance, eps: f64) -> Result<AssignmentSolution> {
        self.solve_with_param(inst, eps / 3.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::matching::FREE;
    use crate::core::CostMatrix;
    use crate::data::workloads::Workload;

    fn inst(n: usize, seed: u64) -> AssignmentInstance {
        Workload::Fig1 { n }.assignment(seed)
    }

    #[test]
    fn produces_perfect_matching() {
        let i = inst(40, 1);
        let sol = PushRelabel::new().solve_with_param(&i, 0.1).unwrap();
        assert!(sol.matching.is_perfect());
        assert!(sol.matching.check_consistent().is_ok());
        assert!(sol.cost > 0.0);
    }

    #[test]
    fn invariants_hold_every_phase() {
        let i = inst(30, 2);
        let sol = PushRelabel { paranoid: true, warm_levels: 0 }.solve_with_param(&i, 0.2).unwrap();
        assert!(sol.matching.is_perfect());
    }

    #[test]
    fn phase_count_within_bound() {
        let i = inst(60, 3);
        let eps = 0.1;
        let sol = PushRelabel::new().solve_with_param(&i, eps).unwrap();
        let bound = ((1.0 + 2.0 * eps) / (eps * eps)).ceil() as usize;
        assert!(
            sol.stats.phases <= bound,
            "phases {} > bound {bound}",
            sol.stats.phases
        );
    }

    #[test]
    fn total_free_processed_bound() {
        // eq. (4): Σ n_i ≤ n(1+2ε)/ε
        let i = inst(80, 4);
        let eps = 0.2;
        let sol = PushRelabel::new().solve_with_param(&i, eps).unwrap();
        let bound = (80.0 * (1.0 + 2.0 * eps) / eps).ceil() as u64;
        assert!(
            sol.stats.total_free_processed <= bound,
            "{} > {bound}",
            sol.stats.total_free_processed
        );
    }

    #[test]
    fn smaller_eps_tightens_toward_exact() {
        // Regression power comes from the exact oracle: each ε must land
        // inside its own 3ε·n·c_max envelope around OPT, and the fine-ε
        // solve must actually be near-exact (not merely within the coarse
        // budget) — a broken relabel that drifts inside the coarse
        // envelope still fails the fine-ε assertion.
        let i = inst(50, 5);
        let c_max = i.costs.max() as f64;
        let exact = crate::solvers::hungarian::solve_exact(&i.costs).unwrap().1;
        let hi = PushRelabel::new().solve_with_param(&i, 0.5).unwrap();
        let lo = PushRelabel::new().solve_with_param(&i, 0.02).unwrap();
        for (sol, eps) in [(&hi, 0.5), (&lo, 0.02)] {
            let budget = 3.0 * eps * 50.0 * c_max;
            assert!(
                sol.cost <= exact + budget + 1e-6,
                "eps={eps}: {} > exact {exact} + {budget}",
                sol.cost
            );
            assert!(sol.cost >= exact - 1e-9, "cannot beat exact");
        }
    }

    #[test]
    fn termination_on_tiny_instances() {
        for n in [1usize, 2, 3] {
            let i = inst(n, 6);
            let sol = PushRelabel::new().solve_with_param(&i, 0.3).unwrap();
            assert!(sol.matching.is_perfect(), "n={n}");
        }
    }

    #[test]
    fn zero_cost_instance() {
        let i = AssignmentInstance::new(CostMatrix::zeros(5, 5)).unwrap();
        let sol = PushRelabel::new().solve_with_param(&i, 0.1).unwrap();
        assert!(sol.matching.is_perfect());
        assert_eq!(sol.cost, 0.0);
    }

    #[test]
    fn trait_entry_divides_eps() {
        let i = inst(20, 7);
        let s = PushRelabel::new();
        let via_trait = s.solve_assignment(&i, 0.3).unwrap();
        let via_param = s.solve_with_param(&i, 0.3 / 3.0).unwrap();
        assert_eq!(via_trait.matching, via_param.matching);
    }

    #[test]
    fn dual_certificate_bounds_cost() {
        // Lemma 3.1 machinery: rounded cost of produced matching before
        // completion ≤ Σy. Drive the kernel directly, as the property
        // suite does, and check matched cost against the dual total.
        let i = inst(40, 8);
        let eps = 0.1;
        let mut k = ScalarKernel::new();
        k.init(&i.costs, eps, None);
        k.run_to_termination(assignment_phase_cap(eps)).unwrap();
        k.check_invariants().unwrap();
        let m = k.extract_matching();
        let y = k.duals();
        check_feasible(&k.arena().q, &m, &y).unwrap();
        let mut cost_units: i64 = 0;
        for (b, &a) in m.match_b.iter().enumerate() {
            if a != FREE {
                cost_units += k.arena().q.at(b, a as usize) as i64;
            }
        }
        let dual_total: i64 = y.ya.iter().map(|&v| v as i64).sum::<i64>()
            + y.yb.iter().map(|&v| v as i64).sum::<i64>();
        assert!(cost_units <= dual_total, "matched cost {cost_units} > Σy {dual_total}");
    }

    #[test]
    fn driver_reports_rounds_and_reuse_flag() {
        let i = inst(32, 9);
        let sol = PushRelabel::new().solve_with_param(&i, 0.2).unwrap();
        assert!(sol.stats.rounds >= sol.stats.phases, "each phase uses ≥ 1 round");
        assert!(!sol.stats.arena_reused, "fresh kernel per solve on this path");
        assert!(!sol.stats.warm_started, "cold by default");
        assert_eq!(sol.stats.eps_levels, 1);
    }

    #[test]
    fn warm_start_keeps_the_additive_guarantee() {
        let i = inst(40, 10);
        let c_max = i.costs.max() as f64;
        let exact = crate::solvers::hungarian::solve_exact(&i.costs).unwrap().1;
        for eps in [0.2, 0.1, 0.05] {
            let warm = PushRelabel { paranoid: true, warm_levels: 3 }
                .solve_with_param(&i, eps)
                .unwrap();
            assert!(warm.matching.is_perfect());
            assert!(warm.stats.warm_started);
            assert!(warm.stats.eps_levels >= 2, "eps={eps} should run ≥ 2 levels");
            let budget = 3.0 * eps * 40.0 * c_max;
            assert!(
                warm.cost <= exact + budget + 1e-6,
                "eps={eps}: warm {} > exact {exact} + {budget}",
                warm.cost
            );
        }
    }

    #[test]
    fn warm_early_stop_skips_intermediate_levels() {
        // A zero-cost instance terminates every level in one phase, so the
        // coarsest level must early-stop the schedule straight to the
        // target ε: 3 requested levels, 2 actually run, skip recorded.
        let i = AssignmentInstance::new(CostMatrix::zeros(12, 12)).unwrap();
        let sol =
            PushRelabel { paranoid: true, warm_levels: 3 }.solve_with_param(&i, 0.1).unwrap();
        assert!(sol.matching.is_perfect());
        assert!(sol.stats.warm_started);
        assert_eq!(sol.stats.eps_levels, 2, "coarse + target only");
        assert!(
            sol.stats.notes.iter().any(|n| n == "warm_skip=1"),
            "skip must be recorded: {:?}",
            sol.stats.notes
        );
        // a 2-level schedule has no intermediate level to skip
        let sol =
            PushRelabel { paranoid: false, warm_levels: 2 }.solve_with_param(&i, 0.1).unwrap();
        assert_eq!(sol.stats.eps_levels, 2);
        assert!(!sol.stats.notes.iter().any(|n| n.starts_with("warm_skip")));
    }

    #[test]
    fn warm_schedule_drops_infeasible_coarse_levels() {
        // 2·0.6 ≥ 1 is unquantizable, so only the target level runs.
        let i = inst(16, 11);
        let sol =
            PushRelabel { paranoid: false, warm_levels: 3 }.solve_with_param(&i, 0.6).unwrap();
        assert_eq!(sol.stats.eps_levels, 1);
        assert!(!sol.stats.warm_started, "single-level schedule is a cold solve");
        assert!(sol.matching.is_perfect());
    }
}
