//! Sinkhorn baseline (Cuturi 2013), parameterized for *additive* ε accuracy
//! following Altschuler–Weed–Rigollet (NeurIPS 2017): regularization
//! η = ε·c_max / (4·ln n) and marginal-violation stopping threshold
//! ε/(8·c_max), followed by their rounding step so the returned plan is a
//! *feasible* transport plan (like our solver's, unlike raw Sinkhorn output).
//!
//! Both the standard (exp-kernel) and log-domain updates are implemented;
//! the standard one reproduces the numerical instability at small ε that
//! the paper's §5 observes (ablation A5) — underflow of exp(-C/η) produces
//! zero row sums and the solve aborts with a note.

use crate::core::control::{SolveControl, CANCELLED_NOTE};
use crate::core::{OtInstance, OtprError, Result, TransportPlan};
use crate::solvers::{OtSolution, OtSolver, SolveStats};
use crate::util::timer::Stopwatch;

#[derive(Debug, Clone)]
pub struct SinkhornConfig {
    /// Explicit regularization; `None` derives η from ε per AWR'17.
    pub eta: Option<f64>,
    /// Hard iteration cap (each iteration is one u,v sweep).
    pub max_iters: usize,
    /// Use numerically-stable log-domain updates.
    pub log_domain: bool,
    /// Check the stopping criterion every this many iterations.
    pub check_every: usize,
}

impl Default for SinkhornConfig {
    fn default() -> Self {
        Self { eta: None, max_iters: 100_000, log_domain: false, check_every: 10 }
    }
}

#[derive(Debug, Clone, Default)]
pub struct Sinkhorn {
    pub config: SinkhornConfig,
}

impl Sinkhorn {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn log_domain() -> Self {
        Self { config: SinkhornConfig { log_domain: true, ..Default::default() } }
    }

    fn eta_for(&self, eps: f64, c_max: f64, n: usize) -> f64 {
        self.config.eta.unwrap_or_else(|| {
            let ln_n = (n.max(2) as f64).ln();
            (eps * c_max / (4.0 * ln_n)).max(1e-12)
        })
    }

    /// Control-aware entry: polls `ctl` every sweep and reports
    /// (iteration, marginal violation) at each stopping-rule check. A
    /// stopped solve rounds its current iterate to a feasible plan and
    /// notes `"cancelled"`.
    pub fn solve_ot_ctl(
        &self,
        inst: &OtInstance,
        eps: f64,
        ctl: &SolveControl,
    ) -> Result<OtSolution> {
        let sw = Stopwatch::start();
        let nb = inst.costs.nb;
        let na = inst.costs.na;
        let c_max = (inst.costs.max() as f64).max(1e-30);
        let eta = self.eta_for(eps, c_max, nb.max(na));
        let tol = eps / 8.0; // marginal L1 violation target (costs ≤ c_max)
        let r = &inst.supply; // rows
        let c = &inst.demand; // cols

        let mut stats = SolveStats::default();
        let plan = if self.config.log_domain {
            solve_log_domain(inst, eta, tol, &self.config, ctl, &mut stats)?
        } else {
            solve_standard(inst, eta, tol, &self.config, ctl, &mut stats)?
        };
        // Altschuler rounding → exactly feasible plan.
        let plan = round_to_feasible(&plan, r, c);
        debug_assert!(plan.check(r, c, 1e-6).is_ok());
        let cost = plan.cost(&inst.costs);
        stats.seconds = sw.elapsed_secs();
        // No dual certificate: Sinkhorn's scaling potentials do not fit
        // the ε-unit DualWeights shape (the §5 comparison point).
        Ok(OtSolution { plan, cost, duals: None, stats })
    }
}

impl OtSolver for Sinkhorn {
    fn name(&self) -> &'static str {
        if self.config.log_domain {
            "sinkhorn-log"
        } else {
            "sinkhorn"
        }
    }

    fn solve_ot(&self, inst: &OtInstance, eps: f64) -> Result<OtSolution> {
        self.solve_ot_ctl(inst, eps, &SolveControl::none())
    }
}

fn solve_standard(
    inst: &OtInstance,
    eta: f64,
    tol: f64,
    cfg: &SinkhornConfig,
    ctl: &SolveControl,
    stats: &mut SolveStats,
) -> Result<TransportPlan> {
    let nb = inst.costs.nb;
    let na = inst.costs.na;
    let cm = inst.costs.as_slice();
    // kernel K = exp(-C/eta), row-major (b, a)
    let k: Vec<f64> = cm.iter().map(|&c| (-(c as f64) / eta).exp()).collect();
    let mut u = vec![1.0f64; nb];
    let mut v = vec![1.0f64; na];
    let mut kv = vec![0.0f64; nb];
    let mut ktu = vec![0.0f64; na];
    for it in 0..cfg.max_iters {
        if ctl.should_stop() {
            stats.notes.push(CANCELLED_NOTE.to_string());
            break;
        }
        // u = r ./ (K v)
        for b in 0..nb {
            let row = &k[b * na..(b + 1) * na];
            let s: f64 = row.iter().zip(&v).map(|(&kk, &vv)| kk * vv).sum();
            kv[b] = s;
            u[b] = inst.supply[b] / s;
        }
        // v = c ./ (Kᵀ u)
        ktu.iter_mut().for_each(|x| *x = 0.0);
        for b in 0..nb {
            let row = &k[b * na..(b + 1) * na];
            let ub = u[b];
            for a in 0..na {
                ktu[a] += row[a] * ub;
            }
        }
        for a in 0..na {
            v[a] = inst.demand[a] / ktu[a];
        }
        stats.phases = it + 1;
        let bad = u.iter().chain(v.iter()).any(|x| !x.is_finite());
        if bad {
            stats.notes.push(format!("numerical instability at iter {} (eta={eta:.3e})", it + 1));
            return Err(OtprError::Infeasible(format!(
                "sinkhorn diverged (underflow) at eta={eta:.3e}; use log-domain"
            )));
        }
        if (it + 1) % cfg.check_every == 0 {
            let err = marginal_violation(&k, &u, &v, &inst.supply, &inst.demand, nb, na);
            ctl.report(it + 1, err);
            if err < tol {
                break;
            }
        }
    }
    let mut plan = TransportPlan::zeros(nb, na);
    for b in 0..nb {
        for a in 0..na {
            plan.set(b, a, u[b] * k[b * na + a] * v[a]);
        }
    }
    Ok(plan)
}

fn solve_log_domain(
    inst: &OtInstance,
    eta: f64,
    tol: f64,
    cfg: &SinkhornConfig,
    ctl: &SolveControl,
    stats: &mut SolveStats,
) -> Result<TransportPlan> {
    let nb = inst.costs.nb;
    let na = inst.costs.na;
    let cm = inst.costs.as_slice();
    let log_r: Vec<f64> = inst.supply.iter().map(|&x| x.max(1e-300).ln()).collect();
    let log_c: Vec<f64> = inst.demand.iter().map(|&x| x.max(1e-300).ln()).collect();
    let mut f = vec![0.0f64; nb]; // f = eta * log u
    let mut g = vec![0.0f64; na];
    let mut buf = vec![0.0f64; na.max(nb)];
    for it in 0..cfg.max_iters {
        if ctl.should_stop() {
            stats.notes.push(CANCELLED_NOTE.to_string());
            break;
        }
        // f_b = eta*(log r_b - LSE_a((g_a - C_ba)/eta))
        for b in 0..nb {
            let row = &cm[b * na..(b + 1) * na];
            for a in 0..na {
                buf[a] = (g[a] - row[a] as f64) / eta;
            }
            f[b] = eta * (log_r[b] - lse(&buf[..na]));
        }
        // g_a = eta*(log c_a - LSE_b((f_b - C_ba)/eta))
        for a in 0..na {
            for b in 0..nb {
                buf[b] = (f[b] - cm[b * na + a] as f64) / eta;
            }
            g[a] = eta * (log_c[a] - lse(&buf[..nb]));
        }
        stats.phases = it + 1;
        if (it + 1) % cfg.check_every == 0 {
            // marginal violation of P = exp((f+g-C)/eta)
            let mut err = 0.0;
            for b in 0..nb {
                let row = &cm[b * na..(b + 1) * na];
                let s: f64 =
                    (0..na).map(|a| ((f[b] + g[a] - row[a] as f64) / eta).exp()).sum();
                err += (s - inst.supply[b]).abs();
            }
            for a in 0..na {
                let s: f64 = (0..nb)
                    .map(|b| ((f[b] + g[a] - cm[b * na + a] as f64) / eta).exp())
                    .sum();
                err += (s - inst.demand[a]).abs();
            }
            ctl.report(it + 1, err);
            if err < tol {
                break;
            }
        }
    }
    let mut plan = TransportPlan::zeros(nb, na);
    for b in 0..nb {
        for a in 0..na {
            plan.set(b, a, ((f[b] + g[a] - cm[b * na + a] as f64) / eta).exp());
        }
    }
    Ok(plan)
}

#[inline]
#[allow(clippy::float_cmp)]
fn lse(xs: &[f64]) -> f64 {
    let m = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    // float-eq-ok: −∞ is the exact fold identity, only hit on empty/all-−∞ input
    if m == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    m + xs.iter().map(|&x| (x - m).exp()).sum::<f64>().ln()
}

fn marginal_violation(
    k: &[f64],
    u: &[f64],
    v: &[f64],
    r: &[f64],
    c: &[f64],
    nb: usize,
    na: usize,
) -> f64 {
    let mut err = 0.0;
    let mut col = vec![0.0f64; na];
    for b in 0..nb {
        let row = &k[b * na..(b + 1) * na];
        let mut s = 0.0;
        for a in 0..na {
            let p = u[b] * row[a] * v[a];
            s += p;
            col[a] += p;
        }
        err += (s - r[b]).abs();
    }
    for a in 0..na {
        err += (col[a] - c[a]).abs();
    }
    err
}

/// Altschuler et al. rounding (Algorithm 2): scale rows then columns down to
/// the marginal caps, then add the rank-one completion of the deficiencies.
/// The output satisfies the marginals exactly.
#[allow(clippy::float_cmp)] // exact-zero skip below, annotated inline
pub fn round_to_feasible(p: &TransportPlan, r: &[f64], c: &[f64]) -> TransportPlan {
    let nb = p.nb;
    let na = p.na;
    let mut q = TransportPlan::zeros(nb, na);
    let rows = p.supply_marginal();
    for b in 0..nb {
        let scale = if rows[b] > r[b] && rows[b] > 0.0 { r[b] / rows[b] } else { 1.0 };
        for a in 0..na {
            q.set(b, a, p.at(b, a) * scale);
        }
    }
    let cols = q.demand_marginal();
    for a in 0..na {
        let scale = if cols[a] > c[a] && cols[a] > 0.0 { c[a] / cols[a] } else { 1.0 };
        if scale < 1.0 {
            for b in 0..nb {
                q.set(b, a, q.at(b, a) * scale);
            }
        }
    }
    let rows = q.supply_marginal();
    let cols = q.demand_marginal();
    let err_r: Vec<f64> = r.iter().zip(&rows).map(|(&w, &g)| (w - g).max(0.0)).collect();
    let err_c: Vec<f64> = c.iter().zip(&cols).map(|(&w, &g)| (w - g).max(0.0)).collect();
    let total: f64 = err_r.iter().sum();
    if total > 1e-300 {
        for b in 0..nb {
            // float-eq-ok: exact-zero skip of rows .max(0.0) clamped to 0
            if err_r[b] == 0.0 {
                continue;
            }
            for a in 0..na {
                q.add(b, a, err_r[b] * err_c[a] / total);
            }
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::CostMatrix;
    use crate::data::workloads::Workload;
    use crate::solvers::hungarian;

    fn uniform_inst(n: usize, seed: u64) -> OtInstance {
        OtInstance::uniform(Workload::Fig1 { n }.costs(seed)).unwrap()
    }

    #[test]
    fn produces_feasible_plan() {
        let inst = uniform_inst(16, 1);
        let sol = Sinkhorn::new().solve_ot(&inst, 0.25).unwrap();
        sol.plan.check(&inst.supply, &inst.demand, 1e-6).unwrap();
        assert!(sol.cost > 0.0);
        assert!(sol.stats.phases > 0);
    }

    #[test]
    fn accuracy_close_to_exact() {
        // exact OT for uniform masses == assignment optimum / n
        let inst = uniform_inst(12, 2);
        let (_, exact_cost, _, _) = hungarian::solve_exact(&inst.costs).unwrap();
        let exact = exact_cost / 12.0;
        let eps = 0.15;
        let sol = Sinkhorn::log_domain().solve_ot(&inst, eps).unwrap();
        let c_max = inst.costs.max() as f64;
        assert!(
            sol.cost <= exact + eps * c_max + 1e-9,
            "sinkhorn {} vs exact {exact} (allow +{})",
            sol.cost,
            eps * c_max
        );
        assert!(sol.cost >= exact - 1e-9, "cannot beat exact: {} < {exact}", sol.cost);
    }

    #[test]
    fn standard_kernel_underflows_at_tiny_eps() {
        // eta ~ eps/(4 ln n); with eps=1e-4 and costs ~1, exp(-1/eta)
        // underflows f64 -> divergence note (paper §5's observed instability).
        let inst = uniform_inst(10, 3);
        let res = Sinkhorn::new().solve_ot(&inst, 1e-4);
        assert!(res.is_err(), "expected instability at tiny eps");
    }

    #[test]
    fn log_domain_survives_tiny_eps() {
        let mut s = Sinkhorn::log_domain();
        s.config.max_iters = 200; // don't wait for full convergence
        let inst = uniform_inst(8, 4);
        let sol = s.solve_ot(&inst, 1e-4).unwrap();
        sol.plan.check(&inst.supply, &inst.demand, 1e-6).unwrap();
    }

    #[test]
    fn rounding_restores_marginals() {
        let mut p = TransportPlan::zeros(2, 2);
        // infeasible: row 0 overshoots, row 1 undershoots
        p.set(0, 0, 0.8);
        p.set(1, 1, 0.1);
        let q = round_to_feasible(&p, &[0.5, 0.5], &[0.5, 0.5]);
        q.check(&[0.5, 0.5], &[0.5, 0.5], 1e-9).unwrap();
    }

    #[test]
    fn explicit_eta_respected() {
        let inst = uniform_inst(6, 5);
        let mut s = Sinkhorn::new();
        s.config.eta = Some(0.5);
        s.config.max_iters = 50;
        let sol = s.solve_ot(&inst, 0.5).unwrap();
        sol.plan.check(&inst.supply, &inst.demand, 1e-6).unwrap();
    }

    #[test]
    fn nonuniform_masses() {
        let c = CostMatrix::from_fn(3, 4, |b, a| ((b + 2 * a) % 5) as f32 / 4.0);
        let inst =
            OtInstance::new(c, vec![0.4, 0.3, 0.2, 0.1], vec![0.5, 0.25, 0.25]).unwrap();
        let sol = Sinkhorn::log_domain().solve_ot(&inst, 0.2).unwrap();
        sol.plan.check(&inst.supply, &inst.demand, 1e-6).unwrap();
    }
}
