//! Multi-threaded push-relabel solver — the CPU analog of the paper's GPU
//! implementation, and the same round structure as the XLA `phase_step`
//! artifact.
//!
//! The greedy maximal matching of each phase is realized as Israeli–Itai
//! style **propose–accept rounds**:
//!
//! * propose: every still-active free b scans (in parallel) for its first
//!   admissible a not yet taken — reads a *snapshot* of the taken set, so
//!   rounds are deterministic regardless of thread count;
//! * accept: each proposed-to a accepts the smallest proposing b (sequential
//!   O(proposals) pass);
//! * losers stay active for the next round; b's with no admissible available
//!   a deactivate.
//!
//! Rounds repeat until no proposals — at that point M' is maximal over the
//! admissible graph (every admissible edge from a still-free b points at a
//! taken a). §3.2 predicts O(log n) expected rounds; ablation A2 measures it.

use crate::core::control::{SolveControl, CANCELLED_NOTE};
use crate::core::duals::DualWeights;
use crate::core::matching::{Matching, FREE};
use crate::core::quantize::QuantizedCosts;
use crate::core::{AssignmentInstance, CostMatrix, OtprError, Result};
use crate::solvers::{AssignmentSolution, AssignmentSolver, SolveStats};
use crate::util::pool;
use crate::util::timer::Stopwatch;
use std::sync::atomic::{AtomicI64, Ordering};

/// Parallel phase state; also reused by the ablation bench to count rounds.
#[derive(Debug, Clone)]
pub struct ParallelPrState {
    pub q: QuantizedCosts,
    pub m: Matching,
    pub y: DualWeights,
    pub phases: usize,
    pub rounds: usize,
    pub total_free_processed: u64,
    pub threads: usize,
}

impl ParallelPrState {
    pub fn new(costs: &CostMatrix, eps: f64, threads: usize) -> Self {
        let q = QuantizedCosts::new(costs, eps);
        let (nb, na) = (q.nb, q.na);
        Self {
            q,
            m: Matching::empty(nb, na),
            y: DualWeights::init(nb, na),
            phases: 0,
            rounds: 0,
            total_free_processed: 0,
            threads: threads.max(1),
        }
    }

    pub fn threshold(&self) -> usize {
        (self.q.eps * self.q.nb as f64).floor() as usize
    }

    /// One phase; returns (free_at_start, rounds_used) or None if terminated.
    pub fn run_phase(&mut self) -> Option<(usize, usize)> {
        let free_b: Vec<usize> = self.m.free_b();
        if free_b.len() <= self.threshold() {
            return None;
        }
        self.phases += 1;
        self.total_free_processed += free_b.len() as u64;

        let na = self.q.na;
        let mut taken = vec![false; na];
        let mut active: Vec<usize> = free_b.clone();
        let mut mprime: Vec<(usize, usize)> = Vec::with_capacity(free_b.len());
        let mut rounds_this_phase = 0;

        while !active.is_empty() {
            rounds_this_phase += 1;
            // --- propose (parallel over active b's; `taken` is a frozen
            // snapshot for the whole round) ---
            let proposals: Vec<i64> = {
                let props: Vec<AtomicI64> =
                    active.iter().map(|_| AtomicI64::new(-1)).collect();
                let q = &self.q;
                let y = &self.y;
                let taken_ref = &taken;
                let active_ref = &active;
                pool::parallel_chunks(active_ref.len(), self.threads, |_, range| {
                    for i in range {
                        let b = active_ref[i];
                        let yb = y.yb[b];
                        let row = q.row(b);
                        for a in 0..na {
                            if !taken_ref[a] && y.ya[a] + yb == row[a] + 1 {
                                props[i].store(a as i64, Ordering::Relaxed);
                                break;
                            }
                        }
                    }
                });
                props.into_iter().map(|p| p.into_inner()).collect()
            };

            // --- accept: smallest proposing b wins each a (sequential) ---
            let mut winner_of_a: Vec<i64> = Vec::new(); // lazily sized
            let mut any_proposal = false;
            for (i, &p) in proposals.iter().enumerate() {
                if p >= 0 {
                    any_proposal = true;
                    if winner_of_a.is_empty() {
                        winner_of_a = vec![i64::MAX; na];
                    }
                    let a = p as usize;
                    let b = active[i] as i64;
                    if b < winner_of_a[a] {
                        winner_of_a[a] = b;
                    }
                }
            }
            if !any_proposal {
                break; // M' is maximal
            }
            // apply winners; losers and non-proposers filtered into next round
            let mut next_active = Vec::with_capacity(active.len());
            for (i, &p) in proposals.iter().enumerate() {
                let b = active[i];
                if p < 0 {
                    continue; // no admissible available a: deactivate
                }
                let a = p as usize;
                if winner_of_a[a] == b as i64 {
                    taken[a] = true;
                    mprime.push((b, a));
                } else {
                    next_active.push(b);
                }
            }
            active = next_active;
        }

        // (II) push + (III.a) relabel a's
        for &(b, a) in &mprime {
            self.m.link(b, a);
            self.y.ya[a] -= 1;
        }
        // (III.b) relabel b's left free
        for &b in &free_b {
            if self.m.match_b[b] == FREE {
                self.y.yb[b] += 1;
            }
        }
        self.rounds += rounds_this_phase;
        Some((free_b.len(), rounds_this_phase))
    }

    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        crate::core::duals::check_feasible(&self.q, &self.m, &self.y)
    }
}

/// The parallel solver as an [`AssignmentSolver`]. `eps` is the overall
/// additive target; the core runs at ε/3 like [`super::push_relabel`].
#[derive(Debug, Clone)]
pub struct ParallelPushRelabel {
    pub threads: usize,
}

impl Default for ParallelPushRelabel {
    fn default() -> Self {
        Self { threads: pool::default_threads() }
    }
}

impl ParallelPushRelabel {
    pub fn with_threads(threads: usize) -> Self {
        Self { threads }
    }

    pub fn solve_with_param(
        &self,
        inst: &AssignmentInstance,
        eps_param: f64,
    ) -> Result<AssignmentSolution> {
        self.solve_with_param_ctl(inst, eps_param, &SolveControl::none())
    }

    /// Control-aware entry (see [`crate::solvers::push_relabel`]): polls
    /// `ctl` between phases and reports progress through its observer.
    pub fn solve_with_param_ctl(
        &self,
        inst: &AssignmentInstance,
        eps_param: f64,
        ctl: &SolveControl,
    ) -> Result<AssignmentSolution> {
        let sw = Stopwatch::start();
        if inst.n() == 0 {
            return Ok(AssignmentSolution {
                matching: Matching::empty(0, 0),
                cost: 0.0,
                duals: None,
                stats: SolveStats::default(),
            });
        }
        let mut st = ParallelPrState::new(&inst.costs, eps_param, self.threads);
        let cap = crate::solvers::push_relabel::assignment_phase_cap(eps_param);
        let mut cancelled = false;
        loop {
            if ctl.should_stop() {
                cancelled = true;
                break;
            }
            let Some((free_at_start, _rounds)) = st.run_phase() else { break };
            let free_left = st.m.match_b.iter().filter(|&&a| a == FREE).count();
            debug_assert!(free_left <= free_at_start);
            ctl.report(st.phases, free_left as f64);
            if st.phases > cap {
                return Err(OtprError::Infeasible("phase cap exceeded (bug)".into()));
            }
        }
        st.m.complete_arbitrarily();
        let cost = st.m.cost(&inst.costs);
        let mut notes = vec![format!("threads={}", self.threads)];
        if cancelled {
            notes.push(CANCELLED_NOTE.to_string());
        }
        Ok(AssignmentSolution {
            matching: st.m,
            cost,
            duals: Some(st.y),
            stats: SolveStats {
                phases: st.phases,
                total_free_processed: st.total_free_processed,
                rounds: st.rounds,
                seconds: sw.elapsed_secs(),
                notes,
            },
        })
    }
}

impl AssignmentSolver for ParallelPushRelabel {
    fn name(&self) -> &'static str {
        "push-relabel-parallel"
    }

    fn solve_assignment(&self, inst: &AssignmentInstance, eps: f64) -> Result<AssignmentSolution> {
        self.solve_with_param(inst, eps / 3.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;
    use crate::solvers::push_relabel::PushRelabel;

    #[test]
    fn perfect_matching_and_invariants() {
        let i = Workload::Fig1 { n: 40 }.assignment(1);
        let mut st = ParallelPrState::new(&i.costs, 0.1, 4);
        while st.run_phase().is_some() {
            st.check_invariants().unwrap();
        }
        st.m.complete_arbitrarily();
        assert!(st.m.is_perfect());
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let i = Workload::Fig1 { n: 30 }.assignment(2);
        let s1 = ParallelPushRelabel::with_threads(1).solve_with_param(&i, 0.15).unwrap();
        let s4 = ParallelPushRelabel::with_threads(4).solve_with_param(&i, 0.15).unwrap();
        assert_eq!(s1.matching, s4.matching, "snapshot rounds must be thread-invariant");
        assert_eq!(s1.stats.rounds, s4.stats.rounds);
    }

    #[test]
    fn cost_within_3eps_of_sequential_guarantee() {
        let i = Workload::Fig1 { n: 50 }.assignment(3);
        let eps = 0.1;
        let par = ParallelPushRelabel::with_threads(4).solve_with_param(&i, eps).unwrap();
        let seq = PushRelabel::new().solve_with_param(&i, eps).unwrap();
        let c_max = i.costs.max() as f64;
        let budget = 3.0 * eps * 50.0 * c_max;
        // both satisfy the additive bound; they may differ from each other
        assert!(par.cost <= seq.cost + budget + 1e-9);
        assert!(seq.cost <= par.cost + budget + 1e-9);
    }

    #[test]
    fn rounds_grow_slowly() {
        // O(log n) expected rounds per phase: rounds/phase should stay small
        let i = Workload::Fig1 { n: 120 }.assignment(4);
        let sol = ParallelPushRelabel::with_threads(4).solve_with_param(&i, 0.2).unwrap();
        let per_phase = sol.stats.rounds as f64 / sol.stats.phases.max(1) as f64;
        assert!(per_phase < 32.0, "rounds/phase = {per_phase}");
    }

    #[test]
    fn tiny_instances() {
        for n in [1usize, 2] {
            let i = Workload::RandomCosts { n }.assignment(5);
            let sol = ParallelPushRelabel::with_threads(2).solve_with_param(&i, 0.4).unwrap();
            assert!(sol.matching.is_perfect());
        }
    }
}
