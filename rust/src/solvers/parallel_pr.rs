//! Multi-threaded push-relabel solver — the CPU analog of the paper's GPU
//! implementation, now a thin driver over the shared flow kernel's
//! **chunked** backend ([`crate::core::kernel::ChunkedKernel`]).
//!
//! Each phase's greedy maximal matching runs as propose–accept rounds:
//! active free vertices scan for their next admissible target in
//! parallel against a stable round snapshot, then grants commit
//! sequentially in ascending vertex order. Because proposals depend only
//! on the snapshot and commits are ordered, the result is deterministic
//! for every thread count **and identical to the sequential engine** —
//! the two backends share one phase semantics, so the additive guarantee
//! and every invariant transfer unchanged. §3.2 predicts O(log n)
//! expected rounds; ablation A2 measures it.

use crate::core::control::SolveControl;
use crate::core::kernel::{ChunkedKernel, WarmStart};
use crate::core::{AssignmentInstance, Result};
use crate::solvers::push_relabel::drive_assignment;
use crate::solvers::{AssignmentSolution, AssignmentSolver};
use crate::util::pool;

/// The parallel solver as an [`AssignmentSolver`]. `eps` is the overall
/// additive target; the core runs at ε/3 like [`super::push_relabel`].
#[derive(Debug, Clone)]
pub struct ParallelPushRelabel {
    pub threads: usize,
    /// Verify invariants after every phase (tests; O(n²) per phase).
    pub paranoid: bool,
}

impl Default for ParallelPushRelabel {
    fn default() -> Self {
        Self { threads: pool::default_threads(), paranoid: false }
    }
}

impl ParallelPushRelabel {
    pub fn with_threads(threads: usize) -> Self {
        Self { threads, paranoid: false }
    }

    pub fn solve_with_param(
        &self,
        inst: &AssignmentInstance,
        eps_param: f64,
    ) -> Result<AssignmentSolution> {
        self.solve_with_param_ctl(inst, eps_param, &SolveControl::none())
    }

    /// Control-aware entry (see [`crate::solvers::push_relabel`]): polls
    /// `ctl` between phases and reports progress through its observer.
    pub fn solve_with_param_ctl(
        &self,
        inst: &AssignmentInstance,
        eps_param: f64,
        ctl: &SolveControl,
    ) -> Result<AssignmentSolution> {
        let mut kernel = ChunkedKernel::new(self.threads);
        let mut sol =
            drive_assignment(&mut kernel, inst, eps_param, ctl, self.paranoid, WarmStart::COLD)?;
        sol.stats.notes.insert(0, format!("threads={}", self.threads.max(1)));
        Ok(sol)
    }
}

impl AssignmentSolver for ParallelPushRelabel {
    fn name(&self) -> &'static str {
        "push-relabel-parallel"
    }

    fn solve_assignment(&self, inst: &AssignmentInstance, eps: f64) -> Result<AssignmentSolution> {
        self.solve_with_param(inst, eps / 3.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::kernel::FlowKernel;
    use crate::data::workloads::Workload;
    use crate::solvers::push_relabel::{assignment_phase_cap, PushRelabel};

    #[test]
    fn perfect_matching_and_invariants() {
        let i = Workload::Fig1 { n: 40 }.assignment(1);
        let mut k = ChunkedKernel::new(4);
        k.init(&i.costs, 0.1, None);
        loop {
            let out = k.run_phase();
            k.check_invariants().unwrap();
            if out.terminated {
                break;
            }
            assert!(k.arena().phases <= assignment_phase_cap(0.1));
        }
        let mut m = k.extract_matching();
        m.complete_arbitrarily();
        assert!(m.is_perfect());
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let i = Workload::Fig1 { n: 30 }.assignment(2);
        let s1 = ParallelPushRelabel::with_threads(1).solve_with_param(&i, 0.15).unwrap();
        let s4 = ParallelPushRelabel::with_threads(4).solve_with_param(&i, 0.15).unwrap();
        assert_eq!(s1.matching, s4.matching, "snapshot rounds must be thread-invariant");
        assert_eq!(s1.stats.rounds, s4.stats.rounds);
        assert_eq!(s1.duals, s4.duals, "duals byte-identical across thread counts");
    }

    #[test]
    fn identical_to_sequential_engine() {
        // The kernel contract: scalar and chunked backends share one
        // phase semantics, so the engines agree exactly.
        let i = Workload::Fig1 { n: 50 }.assignment(3);
        let eps = 0.1;
        let par = ParallelPushRelabel::with_threads(4).solve_with_param(&i, eps).unwrap();
        let seq = PushRelabel::new().solve_with_param(&i, eps).unwrap();
        assert_eq!(par.matching, seq.matching);
        assert_eq!(par.duals, seq.duals);
        assert!((par.cost - seq.cost).abs() < 1e-12);
    }

    #[test]
    fn rounds_grow_slowly() {
        // O(log n) expected rounds per phase: rounds/phase should stay small
        let i = Workload::Fig1 { n: 120 }.assignment(4);
        let sol = ParallelPushRelabel::with_threads(4).solve_with_param(&i, 0.2).unwrap();
        let per_phase = sol.stats.rounds as f64 / sol.stats.phases.max(1) as f64;
        assert!(per_phase < 32.0, "rounds/phase = {per_phase}");
    }

    #[test]
    fn tiny_instances() {
        for n in [1usize, 2] {
            let i = Workload::RandomCosts { n }.assignment(5);
            let sol = ParallelPushRelabel::with_threads(2).solve_with_param(&i, 0.4).unwrap();
            assert!(sol.matching.is_perfect());
        }
    }

    #[test]
    fn threads_note_present() {
        let i = Workload::RandomCosts { n: 12 }.assignment(6);
        let sol = ParallelPushRelabel::with_threads(3).solve_with_param(&i, 0.3).unwrap();
        assert_eq!(sol.stats.notes[0], "threads=3");
    }
}
