//! LMR'19 baseline: Lahn–Mulchandani–Raghvendra, *"A Graph Theoretic
//! Additive Approximation of Optimal Transport"* (NeurIPS 2019) — the
//! "other combinatorial approach" the paper contrasts with (§1).
//!
//! LMR adapts Gabow–Tarjan scaling: costs are rounded to ε-units and an
//! ε-feasible matching is grown by **Dijkstra-based augmenting paths**
//! until ≤ εn vertices remain free, then completed arbitrarily. Its
//! sequential profile is excellent, but the Ω(n) sequential flow
//! augmentations are exactly what makes it hard to parallelize — the gap
//! the push-relabel paper closes.
//!
//! This implementation follows the augmenting-path structure (multi-source
//! Dijkstra over slack weights with Johnson potentials, one augmentation
//! per search, early termination at (1−ε)n) rather than GT's batched
//! variant; sequential behaviour and the additive guarantee match, which
//! is what the baseline comparison needs. Guarantee: ≤ OPT + 2εn·c_max
//! (rounding εn + completion εn).

use crate::core::matching::{Matching, FREE};
use crate::core::quantize::QuantizedCosts;
use crate::core::{AssignmentInstance, OtprError, Result};
use crate::solvers::{AssignmentSolution, AssignmentSolver, SolveStats};
use crate::util::timer::Stopwatch;

/// One Dijkstra-based augmentation. Returns false when no free A vertex is
/// reachable (graph exhausted).
///
/// Node order: 0..nb are B vertices, nb..nb+na are A vertices. Edge
/// weights are reduced slacks `cq(b,a) − y(b) − y(a)` (≥ 0 by invariant);
/// matched edges are traversed backwards at zero reduced cost.
fn augment_once(
    q: &QuantizedCosts,
    m: &mut Matching,
    yb: &mut [i64],
    ya: &mut [i64],
) -> bool {
    let nb = q.nb;
    let na = q.na;
    const INF: i64 = i64::MAX / 4;
    let v = nb + na;
    let mut dist = vec![INF; v];
    let mut parent = vec![usize::MAX; v];
    let mut done = vec![false; v];
    for b in 0..nb {
        if m.is_b_free(b) {
            dist[b] = 0;
        }
    }
    let mut best_target = usize::MAX;
    let mut best_dist = INF;
    loop {
        // dense extract-min (O(V) per pop; O(V²+E) total — fine for the
        // dense bipartite graphs this baseline runs on)
        let mut u = usize::MAX;
        let mut du = INF;
        for i in 0..v {
            if !done[i] && dist[i] < du {
                du = dist[i];
                u = i;
            }
        }
        if u == usize::MAX || du >= best_dist {
            break;
        }
        done[u] = true;
        if u < nb {
            let b = u;
            let row = q.row(b);
            for a in 0..na {
                if m.match_b[b] == a as i32 {
                    continue; // matched edge is backward-only
                }
                let slack = row[a] as i64 - yb[b] - ya[a];
                debug_assert!(slack >= 0, "negative slack {slack}");
                let nd = du + slack;
                let node = nb + a;
                if nd < dist[node] {
                    dist[node] = nd;
                    parent[node] = u;
                    if m.is_a_free(a) && nd < best_dist {
                        best_dist = nd;
                        best_target = node;
                    }
                }
            }
        } else {
            let a = u - nb;
            let b = m.match_a[a];
            if b != FREE {
                // traverse the matched edge backwards; tight by (3)
                let b = b as usize;
                if du < dist[b] {
                    dist[b] = du;
                    parent[b] = u;
                }
            }
        }
    }
    if best_target == usize::MAX {
        return false;
    }
    // Dual update (Johnson potentials): for reached nodes with d ≤ D set
    // y(b) += D − d(b) and y(a) −= D − d(a). Standard SSP algebra shows new
    // slacks stay ≥ 0, matched edges stay tight, and every shortest-path
    // edge becomes tight — so the augmentation below preserves tightness.
    let d_star = best_dist;
    for b in 0..nb {
        if dist[b] <= d_star {
            yb[b] += d_star - dist[b];
        }
    }
    for a in 0..na {
        let da = dist[nb + a];
        if da <= d_star {
            ya[a] -= d_star - da;
        }
    }
    // Augment: walk parents target(a) ← b ← a' ← b' ... ← free source b.
    // `link` frees b's previous partner, which is exactly the a the next
    // iteration re-links to the previous b on the path.
    let mut a_node = best_target;
    loop {
        let b = parent[a_node];
        debug_assert!(b < nb, "a-node parent must be a b-node");
        let prev_a = parent[b];
        m.link(b, a_node - nb);
        if prev_a == usize::MAX {
            break; // b was a free source
        }
        a_node = prev_a;
    }
    true
}

/// The LMR-style baseline solver. `eps` on the trait is the overall
/// additive target (ε·n·c_max); the core runs at ε/2 to cover rounding +
/// completion.
#[derive(Debug, Clone, Default)]
pub struct LmrBaseline;

impl LmrBaseline {
    /// Run at raw parameter `eps_param` (additive ≤ 2·ε·n·c_max).
    pub fn solve_with_param(
        &self,
        inst: &AssignmentInstance,
        eps_param: f64,
    ) -> Result<AssignmentSolution> {
        let sw = Stopwatch::start();
        let n = inst.n();
        if n == 0 {
            return Ok(AssignmentSolution {
                matching: Matching::empty(0, 0),
                cost: 0.0,
                duals: None,
                stats: SolveStats::default(),
            });
        }
        let q = QuantizedCosts::new(&inst.costs, eps_param);
        let mut m = Matching::empty(n, n);
        let mut yb = vec![0i64; n];
        let mut ya = vec![0i64; n];
        let target = n - (eps_param * n as f64).floor() as usize;
        let mut augmentations = 0usize;
        while m.size() < target {
            if !augment_once(&q, &mut m, &mut yb, &mut ya) {
                return Err(OtprError::Infeasible(
                    "no augmenting path in a complete bipartite graph (bug)".into(),
                ));
            }
            augmentations += 1;
            if augmentations > 2 * n {
                return Err(OtprError::Infeasible("augmentation cap exceeded (bug)".into()));
            }
        }
        m.complete_arbitrarily();
        let cost = m.cost(&inst.costs);
        Ok(AssignmentSolution {
            matching: m,
            cost,
            // i64 SSP potentials are not ε-unit DualWeights
            duals: None,
            stats: SolveStats {
                phases: augmentations, // one Dijkstra per augmentation
                total_free_processed: augmentations as u64,
                rounds: 0,
                seconds: sw.elapsed_secs(),
                notes: vec![],
                ..Default::default()
            },
        })
    }
}

impl AssignmentSolver for LmrBaseline {
    fn name(&self) -> &'static str {
        "lmr-baseline"
    }

    fn solve_assignment(&self, inst: &AssignmentInstance, eps: f64) -> Result<AssignmentSolution> {
        self.solve_with_param(inst, eps / 2.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;
    use crate::solvers::hungarian::Hungarian;

    #[test]
    fn additive_guarantee() {
        for seed in 0..3 {
            let n = 40;
            let inst = Workload::Fig1 { n }.assignment(seed);
            let exact = Hungarian.solve_assignment(&inst, 0.0).unwrap();
            let eps = 0.1;
            let sol = LmrBaseline.solve_assignment(&inst, eps).unwrap();
            assert!(sol.matching.is_perfect());
            let budget = eps * n as f64 * inst.costs.max() as f64;
            assert!(
                sol.cost <= exact.cost + budget + 1e-6,
                "seed {seed}: {} > {} + {budget}",
                sol.cost,
                exact.cost
            );
        }
    }

    #[test]
    fn fine_eps_near_exact() {
        let inst = Workload::RandomCosts { n: 16 }.assignment(5);
        let exact = Hungarian.solve_assignment(&inst, 0.0).unwrap();
        let sol = LmrBaseline.solve_with_param(&inst, 0.005).unwrap();
        assert!(sol.cost >= exact.cost - 1e-9);
        assert!(sol.cost <= exact.cost + 2.0 * 0.005 * 16.0 + 1e-9);
    }

    #[test]
    fn augmentation_count_bounded() {
        // early termination: ≤ n − ⌊εn⌋ augmentations, each matching one b
        let n = 50;
        let inst = Workload::Fig1 { n }.assignment(2);
        let eps = 0.2;
        let sol = LmrBaseline.solve_with_param(&inst, eps).unwrap();
        assert!(sol.stats.phases <= n - (eps * n as f64).floor() as usize);
    }

    #[test]
    fn zero_cost_instance() {
        let inst =
            AssignmentInstance::new(crate::core::CostMatrix::zeros(8, 8)).unwrap();
        let sol = LmrBaseline.solve_assignment(&inst, 0.25).unwrap();
        assert!(sol.matching.is_perfect());
        assert_eq!(sol.cost, 0.0);
    }

    #[test]
    fn tiny_instances() {
        for n in [1usize, 2, 3] {
            let inst = Workload::RandomCosts { n }.assignment(7);
            let sol = LmrBaseline.solve_assignment(&inst, 0.3).unwrap();
            assert!(sol.matching.is_perfect(), "n={n}");
        }
    }
}
