//! Greedy baseline: each supply vertex takes its cheapest available demand
//! vertex. No approximation guarantee — used as a cost/runtime floor in the
//! ablation benches and as a smoke baseline in tests.

use crate::core::matching::Matching;
use crate::core::{AssignmentInstance, Result};
use crate::solvers::{AssignmentSolution, AssignmentSolver, SolveStats};
use crate::util::timer::Stopwatch;

#[derive(Debug, Clone, Default)]
pub struct GreedyMatcher;

impl AssignmentSolver for GreedyMatcher {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn solve_assignment(&self, inst: &AssignmentInstance, _eps: f64) -> Result<AssignmentSolution> {
        let sw = Stopwatch::start();
        let n = inst.n();
        let mut m = Matching::empty(n, n);
        let mut taken = vec![false; n];
        for b in 0..n {
            let row = inst.costs.row(b);
            let mut best = usize::MAX;
            let mut best_c = f32::INFINITY;
            for (a, &c) in row.iter().enumerate() {
                if !taken[a] && c < best_c {
                    best = a;
                    best_c = c;
                }
            }
            if best != usize::MAX {
                taken[best] = true;
                m.link(b, best);
            }
        }
        let cost = m.cost(&inst.costs);
        Ok(AssignmentSolution {
            matching: m,
            cost,
            duals: None,
            stats: SolveStats { seconds: sw.elapsed_secs(), ..Default::default() },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::CostMatrix;
    use crate::data::workloads::Workload;
    use crate::solvers::hungarian::Hungarian;

    #[test]
    fn perfect_and_consistent() {
        let i = Workload::Fig1 { n: 25 }.assignment(1);
        let sol = GreedyMatcher.solve_assignment(&i, 0.0).unwrap();
        assert!(sol.matching.is_perfect());
        assert!(sol.matching.check_consistent().is_ok());
    }

    #[test]
    fn never_beats_exact() {
        for seed in 0..5 {
            let i = Workload::RandomCosts { n: 12 }.assignment(seed);
            let g = GreedyMatcher.solve_assignment(&i, 0.0).unwrap();
            let h = Hungarian.solve_assignment(&i, 0.0).unwrap();
            assert!(g.cost >= h.cost - 1e-9, "greedy {} < exact {}", g.cost, h.cost);
        }
    }

    #[test]
    fn picks_cheapest_first_row() {
        let c = CostMatrix::from_vec(2, 2, vec![5.0, 1.0, 1.0, 5.0]).unwrap();
        let i = AssignmentInstance::new(c).unwrap();
        let sol = GreedyMatcher.solve_assignment(&i, 0.0).unwrap();
        assert_eq!(sol.matching.match_b, vec![1, 0]);
        assert!((sol.cost - 2.0).abs() < 1e-9);
    }
}
