//! Solvers: the paper's push-relabel algorithm (sequential, parallel, OT
//! extension — all thin drivers over the shared [`crate::core::kernel`]
//! flow kernel) plus every baseline the evaluation needs (exact
//! Hungarian, exact min-cost-flow OT, Sinkhorn, greedy).

pub mod greedy;
pub mod lmr;
pub mod hungarian;
pub mod ot_push_relabel;
pub mod parallel_pr;
pub mod push_relabel;
pub mod sinkhorn;
pub mod ssp_ot;

use crate::core::{AssignmentInstance, DualWeights, Matching, OtInstance, Result, TransportPlan};

/// Counters reported by every solve — the material for EXPERIMENTS.md.
#[derive(Debug, Clone, Default)]
pub struct SolveStats {
    /// Push-relabel phases (or Sinkhorn iterations) executed.
    pub phases: usize,
    /// Σ|B'| over phases — the quantity bounded by O(n/ε) in eq. (4).
    pub total_free_processed: u64,
    /// Propose–accept rounds (kernel-backed solvers), Σ over phases.
    pub rounds: usize,
    /// Wall-clock seconds.
    pub seconds: f64,
    /// True when this solve reused a warm kernel arena (batch path;
    /// counted into `coordinator::Metrics` as a reuse hit).
    pub arena_reused: bool,
    /// True when the solve warm-started: either an ε-scaling schedule
    /// (coarse→fine levels) or a batch dual carry-over from the previous
    /// same-shape instance. Counted per engine by `coordinator::Metrics`.
    pub warm_started: bool,
    /// ε levels the solve ran (1 = single-level; 0 for engines without
    /// the concept — exact oracles, Sinkhorn, XLA). Warm schedules may
    /// report fewer levels than requested: a coarse level that terminates
    /// in ≤ 1 phase early-stops the remaining intermediate levels.
    pub eps_levels: u32,
    /// Resident cost-derived kernel state in bytes at the end of the
    /// solve (quantized slab + lane mirror/minima;
    /// `KernelArena::cost_state_bytes`). An implicit-cost solve through
    /// the vector backend reports only the O(n²/8) block-min cache — the
    /// no-slab acceptance gate asserts on this. 0 for non-kernel engines.
    pub cost_state_bytes: u64,
    /// Resident bytes of the returned transport plan's representation
    /// (`TransportPlan::state_bytes`): O(nnz) for the kernel engines'
    /// CSR plans, O(nb+na) for the lazy cancelled-answer product, and
    /// the full nb·na·8 slab for the inherently-dense solvers (Sinkhorn,
    /// SSP, XLA). 0 for assignment solves, which return no plan.
    pub plan_state_bytes: u64,
    /// Free-form solver-specific notes (e.g. "underflow" for Sinkhorn).
    pub notes: Vec<String>,
}

/// Result of an assignment solve.
#[derive(Debug, Clone)]
pub struct AssignmentSolution {
    pub matching: Matching,
    /// Total cost under the *original* (unrounded) cost matrix.
    pub cost: f64,
    /// ε-unit dual weights certifying approximate optimality, when the
    /// solver maintains them (the push-relabel family does; exact/greedy
    /// baselines report `None`).
    pub duals: Option<DualWeights>,
    pub stats: SolveStats,
}

/// Result of an OT solve.
#[derive(Debug, Clone)]
pub struct OtSolution {
    pub plan: TransportPlan,
    pub cost: f64,
    /// ε-unit per-vertex dual weights certifying approximate optimality
    /// when the solver maintains them (the §4 push-relabel solver exports
    /// its compressed cluster duals; Sinkhorn and the exact oracles report
    /// `None`). In units of the solver's matching quantization ε/6.
    pub duals: Option<DualWeights>,
    pub stats: SolveStats,
}

/// An algorithm that solves the assignment problem to additive error
/// `eps · n · c_max` (exact solvers ignore `eps`).
pub trait AssignmentSolver {
    fn name(&self) -> &'static str;
    fn solve_assignment(&self, inst: &AssignmentInstance, eps: f64) -> Result<AssignmentSolution>;
}

/// An algorithm that computes a transport plan with cost within
/// `eps · c_max` of optimal (exact solvers ignore `eps`).
pub trait OtSolver {
    fn name(&self) -> &'static str;
    fn solve_ot(&self, inst: &OtInstance, eps: f64) -> Result<OtSolution>;
}

/// Convert a perfect matching into the uniform-mass transport plan it
/// induces (each matched edge carries 1/n mass). Built directly in CSR
/// form — a matching plan has at most one entry per supply row, so the
/// dense nb·na slab would be pure waste.
pub fn matching_to_plan(m: &Matching) -> TransportPlan {
    let (nb, na) = (m.nb(), m.na());
    let unit = 1.0 / nb as f64;
    let mut row_ptr = Vec::with_capacity(nb + 1);
    let mut col_idx = Vec::with_capacity(nb);
    let mut vals = Vec::with_capacity(nb);
    row_ptr.push(0);
    for &a in &m.match_b {
        if a >= 0 {
            col_idx.push(a as u32);
            vals.push(unit);
        }
        row_ptr.push(col_idx.len());
    }
    // a consistent matching always yields valid canonical CSR; an
    // inconsistent one (a ≥ na) falls back to the dense builder rather
    // than panicking in a conversion helper
    match TransportPlan::from_csr(nb, na, row_ptr, col_idx, vals) {
        Ok(plan) => plan,
        Err(_) => {
            let mut plan = TransportPlan::zeros(nb, na);
            for (b, &a) in m.match_b.iter().enumerate() {
                if a >= 0 {
                    plan.add(b, a as usize, unit);
                }
            }
            plan
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matching_to_plan_uniform() {
        let mut m = Matching::empty(2, 2);
        m.link(0, 1);
        m.link(1, 0);
        let p = matching_to_plan(&m);
        assert_eq!(p.repr_kind(), "csr", "matching plans are compact");
        assert_eq!(p.support_size(), 2);
        assert!((p.at(0, 1) - 0.5).abs() < 1e-12);
        assert!((p.at(1, 0) - 0.5).abs() < 1e-12);
        assert!((p.total_mass() - 1.0).abs() < 1e-12);
    }
}
