//! # The unified solve surface
//!
//! One push-relabel framework serves assignment *and* general OT,
//! sequential *and* parallel, native *and* device-resident — so the crate
//! exposes exactly one way to name, configure, and invoke a solver:
//!
//! ```no_run
//! use otpr::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
//! use otpr::data::workloads::Workload;
//!
//! let registry = SolverRegistry::with_defaults();
//! let config = SolverConfig::default();
//! let problem = Problem::Assignment(Workload::Fig1 { n: 200 }.assignment(42));
//! let request = SolveRequest::new(0.1)
//!     .with_budget(std::time::Duration::from_secs(5))
//!     .with_observer(|p| eprintln!("phase {}: {} free", p.phase, p.free));
//! let solution = registry.solve("native-seq", &config, &problem, &request).unwrap();
//! println!("cost {} in {} phases", solution.cost, solution.stats.phases);
//! ```
//!
//! * [`Problem`] / [`Solution`] — the one model for both workload kinds
//!   and both result shapes (matching or plan), with the dual certificate
//!   and [`crate::solvers::SolveStats`] attached.
//! * [`SolverRegistry`] — canonical engine names ([`registry::ENGINE_SPECS`])
//!   mapped to builder closures over a typed [`SolverConfig`].
//! * [`SolveRequest`] — per-solve accuracy, wall-clock budget,
//!   [`CancelToken`], and [`Progress`] observer, honored by the
//!   push-relabel family and Sinkhorn between phases.
//!
//! See `rust/src/api/README.md` for the migration table from the legacy
//! `AssignmentSolver`/`OtSolver` traits.

pub mod adapter;
pub mod problem;
pub mod registry;
pub mod request;

pub use adapter::{AssignmentAdapter, OtAdapter, Solver, WarmKernelSolver};
pub use problem::{Coupling, ImplicitInstance, Problem, ProblemKind, Solution};
// Implicit-cost building blocks are part of the public problem surface
// (`Problem::implicit_assignment` / `Problem::implicit_ot` take them).
pub use crate::core::provider::{
    CostProvider, CostSource, Costs, GeneratedCosts, L1PointCosts, SqEuclideanCosts,
};
// The certification entry points live in `core::certify`; re-exported here
// because `SolveRequest::certify` / `Solution::certificate` make them part
// of the public solve surface.
pub use crate::core::certify::{certify, Certificate};
pub use registry::{
    canonical_key, BatchReport, BucketPolicy, EngineSpec, SolverConfig, SolverRegistry,
    ENGINE_SPECS,
};
pub use request::{
    CancelToken, EpsSemantics, Progress, ProgressFn, SolveControl, SolveRequest, CANCELLED_NOTE,
};
