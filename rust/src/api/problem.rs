//! The unified problem/solution model.
//!
//! One [`Problem`] covers both workloads the paper's framework serves
//! (assignment §2–3, general OT §4); one [`Solution`] covers both result
//! shapes (perfect matching or transport plan) plus the dual certificate
//! and solve counters. This replaces the parallel
//! `AssignmentSolution`/`OtSolution` pair at the public boundary — those
//! remain as internal carrier types inside `solvers/`.

use crate::core::certify::Certificate;
use crate::core::provider::{CostSource, Costs};
use crate::core::{
    AssignmentInstance, CostMatrix, DualWeights, Matching, OtInstance, OtprError, Result,
    TransportPlan,
};
use crate::solvers::{matching_to_plan, AssignmentSolution, OtSolution, SolveStats};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProblemKind {
    Assignment,
    Ot,
}

impl ProblemKind {
    pub fn name(&self) -> &'static str {
        match self {
            ProblemKind::Assignment => "assignment",
            ProblemKind::Ot => "ot",
        }
    }
}

/// A provider-backed instance: costs are computed on demand from O(n)
/// data ([`Costs::Points`] / [`Costs::L1Points`] / [`Costs::Generated`]),
/// so neither the problem payload nor the kernel ever holds the O(n²)
/// slab. `masses = None` is the assignment case (square); `Some((supply,
/// demand))` is general OT.
#[derive(Debug, Clone)]
pub struct ImplicitInstance {
    pub costs: Costs,
    /// `(supply over rows, demand over columns)`; `None` = assignment.
    pub masses: Option<(Vec<f64>, Vec<f64>)>,
}

impl ImplicitInstance {
    /// Assignment instance over a cost provider (requires square costs).
    pub fn assignment(costs: Costs) -> Result<Self> {
        if costs.nb() != costs.na() {
            return Err(OtprError::InvalidInstance(format!(
                "assignment requires square costs, got {}x{} ({})",
                costs.nb(),
                costs.na(),
                costs.kind()
            )));
        }
        Ok(Self { costs, masses: None })
    }

    /// OT instance over a cost provider (the same mass validation as
    /// [`OtInstance::new`] — one shared checker, so dense and implicit
    /// representations accept exactly the same marginals).
    pub fn ot(costs: Costs, demand: Vec<f64>, supply: Vec<f64>) -> Result<Self> {
        crate::core::instance::validate_marginals(&demand, &supply, costs.na(), costs.nb())?;
        Ok(Self { costs, masses: Some((supply, demand)) })
    }

    pub fn kind(&self) -> ProblemKind {
        if self.masses.is_none() {
            ProblemKind::Assignment
        } else {
            ProblemKind::Ot
        }
    }

    pub fn n(&self) -> usize {
        self.costs.nb().max(self.costs.na())
    }
}

/// What to solve: an n×n assignment, a general discrete-OT instance, or
/// an implicit (provider-backed) instance of either kind.
#[derive(Debug, Clone)]
pub enum Problem {
    Assignment(AssignmentInstance),
    Ot(OtInstance),
    Implicit(ImplicitInstance),
}

impl Problem {
    /// Assignment problem from a square cost matrix.
    pub fn assignment(costs: CostMatrix) -> Result<Self> {
        Ok(Problem::Assignment(AssignmentInstance::new(costs)?))
    }

    /// OT problem from costs + probability masses (demand over columns,
    /// supply over rows).
    pub fn ot(costs: CostMatrix, demand: Vec<f64>, supply: Vec<f64>) -> Result<Self> {
        Ok(Problem::Ot(OtInstance::new(costs, demand, supply)?))
    }

    /// Assignment problem over an implicit cost provider: the kernel
    /// engines solve it without ever materializing the O(n²) slab.
    pub fn implicit_assignment(costs: Costs) -> Result<Self> {
        Ok(Problem::Implicit(ImplicitInstance::assignment(costs)?))
    }

    /// OT problem over an implicit cost provider.
    pub fn implicit_ot(costs: Costs, demand: Vec<f64>, supply: Vec<f64>) -> Result<Self> {
        Ok(Problem::Implicit(ImplicitInstance::ot(costs, demand, supply)?))
    }

    pub fn kind(&self) -> ProblemKind {
        match self {
            Problem::Assignment(_) => ProblemKind::Assignment,
            Problem::Ot(_) => ProblemKind::Ot,
            Problem::Implicit(i) => i.kind(),
        }
    }

    /// Instance size (max side for rectangular OT).
    pub fn n(&self) -> usize {
        match self {
            Problem::Assignment(i) => i.n(),
            Problem::Ot(i) => i.n(),
            Problem::Implicit(i) => i.n(),
        }
    }

    /// (nb, na) of the cost relation — works for every representation.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            Problem::Assignment(i) => (i.costs.nb, i.costs.na),
            Problem::Ot(i) => (i.costs.nb, i.costs.na),
            Problem::Implicit(i) => (i.costs.nb(), i.costs.na()),
        }
    }

    /// Largest cost (the normalization constant) — every representation.
    pub fn max_cost(&self) -> f64 {
        match self {
            Problem::Assignment(i) => i.costs.max() as f64,
            Problem::Ot(i) => i.costs.max() as f64,
            Problem::Implicit(i) => i.costs.max_cost() as f64,
        }
    }

    /// The per-call cost view the kernel drivers consume.
    pub fn cost_source(&self) -> CostSource<'_> {
        match self {
            Problem::Assignment(i) => CostSource::Dense(&i.costs),
            Problem::Ot(i) => CostSource::Dense(&i.costs),
            Problem::Implicit(i) => i.costs.source(),
        }
    }

    /// Dense cost matrix. **Panics** for implicit problems — those have no
    /// slab by design; use [`Problem::dims`] / [`Problem::max_cost`] /
    /// [`Problem::cost_source`] instead (or [`Problem::to_dense`] to
    /// materialize deliberately).
    pub fn costs(&self) -> &CostMatrix {
        match self {
            Problem::Assignment(i) => &i.costs,
            Problem::Ot(i) => &i.costs,
            Problem::Implicit(i) => panic!(
                "implicit-cost problem ({}) has no dense matrix; \
                 use dims()/max_cost()/cost_source() or to_dense()",
                i.costs.kind()
            ),
        }
    }

    /// Materialize an implicit problem into its dense form (O(n²) —
    /// deliberate, for baselines that genuinely need a slab). Dense
    /// problems return a clone of themselves.
    pub fn to_dense(&self) -> Result<Problem> {
        match self {
            Problem::Implicit(i) => {
                let dense = i.costs.to_dense();
                match &i.masses {
                    None => Problem::assignment(dense),
                    Some((supply, demand)) => Problem::ot(dense, demand.clone(), supply.clone()),
                }
            }
            other => Ok(other.clone()),
        }
    }

    pub fn as_assignment(&self) -> Option<&AssignmentInstance> {
        match self {
            Problem::Assignment(i) => Some(i),
            _ => None,
        }
    }

    pub fn as_ot(&self) -> Option<&OtInstance> {
        match self {
            Problem::Ot(i) => Some(i),
            _ => None,
        }
    }

    pub fn as_implicit(&self) -> Option<&ImplicitInstance> {
        match self {
            Problem::Implicit(i) => Some(i),
            _ => None,
        }
    }

    /// View the problem as OT: assignment instances become uniform-mass OT
    /// (how the paper benchmarks Sinkhorn on assignment inputs). Implicit
    /// problems refuse — engines that need a dense OT instance cannot run
    /// them (materialize deliberately with [`Problem::to_dense`]).
    pub fn to_ot_instance(&self) -> Result<OtInstance> {
        match self {
            Problem::Assignment(i) => OtInstance::uniform(i.costs.clone()),
            Problem::Ot(i) => Ok(i.clone()),
            Problem::Implicit(i) => Err(OtprError::InvalidInstance(format!(
                "implicit-cost problem ({}) has no dense OT form; \
                 route it to a kernel engine or materialize with to_dense()",
                i.costs.kind()
            ))),
        }
    }
}

/// The coupling a solver produced: a perfect matching (assignment engines)
/// or a transport plan (OT engines — including OT engines answering
/// assignment problems via uniform masses).
///
/// Since PR 8 a `Plan` may be *compact* — O(nnz) CSR from the kernel
/// engines, or the O(nb+na) lazy product for cancelled solves — rather
/// than a dense slab. Every read API (`at`, `cost`, marginals, `check`)
/// works on any representation; `TransportPlan::as_slice` still returns
/// the dense view but materializes (and caches) the nb·na slab on first
/// call. See "Plan memory model" in `api/README.md`.
#[derive(Debug, Clone)]
pub enum Coupling {
    Matching(Matching),
    Plan(TransportPlan),
}

/// Unified solve result: coupling + cost under the original costs +
/// optional ε-unit dual certificate + counters.
#[derive(Debug, Clone)]
pub struct Solution {
    pub coupling: Coupling,
    /// Total cost under the *original* (unrounded) cost matrix.
    pub cost: f64,
    /// Dual weights certifying approximate optimality, when the engine
    /// maintains them (the push-relabel family, assignment *and* OT).
    pub duals: Option<DualWeights>,
    /// Verified [`Certificate`] attached by the registry when the request
    /// asked for one ([`crate::api::SolveRequest::certify`]); `None`
    /// otherwise. Run [`crate::core::certify::certify`] directly to check
    /// an existing solution after the fact.
    pub certificate: Option<Certificate>,
    pub stats: SolveStats,
}

impl Solution {
    pub fn from_assignment(sol: AssignmentSolution) -> Self {
        Self {
            coupling: Coupling::Matching(sol.matching),
            cost: sol.cost,
            duals: sol.duals,
            certificate: None,
            stats: sol.stats,
        }
    }

    pub fn from_ot(sol: OtSolution) -> Self {
        let mut stats = sol.stats;
        // Every OT route reports its plan-memory footprint, whether the
        // solver filled the field or not: kernel engines return O(nnz)
        // CSR, Sinkhorn/SSP/XLA the dense slab, cancelled answers the
        // O(nb+na) lazy product.
        stats.plan_state_bytes = sol.plan.state_bytes();
        Self {
            coupling: Coupling::Plan(sol.plan),
            cost: sol.cost,
            duals: sol.duals,
            certificate: None,
            stats,
        }
    }

    pub fn matching(&self) -> Option<&Matching> {
        match &self.coupling {
            Coupling::Matching(m) => Some(m),
            _ => None,
        }
    }

    pub fn plan(&self) -> Option<&TransportPlan> {
        match &self.coupling {
            Coupling::Plan(p) => Some(p),
            _ => None,
        }
    }

    /// The solution as a transport plan regardless of coupling shape — a
    /// matching becomes the uniform-mass plan it induces (1/n per edge).
    pub fn to_plan(&self) -> TransportPlan {
        match &self.coupling {
            Coupling::Plan(p) => p.clone(),
            Coupling::Matching(m) => matching_to_plan(m),
        }
    }

    /// Require the matching form (typed accessor for assignment callers).
    pub fn expect_matching(&self) -> Result<&Matching> {
        self.matching().ok_or_else(|| {
            OtprError::Coordinator("solution carries a transport plan, not a matching".into())
        })
    }

    /// Require the plan form (typed accessor for OT callers).
    pub fn expect_plan(&self) -> Result<&TransportPlan> {
        self.plan().ok_or_else(|| {
            OtprError::Coordinator("solution carries a matching, not a transport plan".into())
        })
    }

    /// True when the solve stopped early on cancellation or budget.
    pub fn is_cancelled(&self) -> bool {
        self.stats.notes.iter().any(|n| n == crate::core::control::CANCELLED_NOTE)
    }

    /// When a deadline-pressured warm-ladder solve degraded to a coarser
    /// level, the matching-quantization ε the returned state is actually
    /// feasible for (see [`crate::core::control::DEGRADED_NOTE_PREFIX`]).
    /// `None` for solves that ran to their requested accuracy.
    pub fn degraded_eps_param(&self) -> Option<f64> {
        self.stats.notes.iter().find_map(|n| {
            n.strip_prefix(crate::core::control::DEGRADED_NOTE_PREFIX)?.parse::<f64>().ok()
        })
    }

    pub fn phases(&self) -> usize {
        self.stats.phases
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;

    #[test]
    fn problem_constructors_and_kind() {
        let p = Problem::assignment(Workload::RandomCosts { n: 4 }.costs(1)).unwrap();
        assert_eq!(p.kind(), ProblemKind::Assignment);
        assert_eq!(p.n(), 4);
        assert!(p.as_assignment().is_some());
        assert!(p.as_ot().is_none());

        let ot = p.to_ot_instance().unwrap();
        assert_eq!(ot.demand.len(), 4);
        assert!(Problem::assignment(CostMatrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn implicit_problems_expose_dims_without_a_slab() {
        use crate::core::provider::{Costs, GeneratedCosts};
        let costs =
            Costs::generated(GeneratedCosts::new(5, 5, |b, a| (b + a) as f32 / 8.0).unwrap());
        let p = Problem::implicit_assignment(costs.clone()).unwrap();
        assert_eq!(p.kind(), ProblemKind::Assignment);
        assert_eq!(p.dims(), (5, 5));
        assert_eq!(p.n(), 5);
        assert!((p.max_cost() - 1.0).abs() < 1e-9);
        assert!(p.cost_source().is_implicit());
        assert!(p.as_assignment().is_none() && p.as_implicit().is_some());
        assert!(p.to_ot_instance().is_err(), "no silent materialization");
        // deliberate materialization round-trips
        let dense = p.to_dense().unwrap();
        assert_eq!(dense.kind(), ProblemKind::Assignment);
        assert_eq!(dense.costs().at(4, 4), 1.0);

        let uni = vec![0.2; 5];
        let p = Problem::implicit_ot(costs.clone(), uni.clone(), uni.clone()).unwrap();
        assert_eq!(p.kind(), ProblemKind::Ot);
        assert!(Problem::implicit_ot(costs.clone(), vec![0.5; 5], uni).is_err());
        let rect =
            Costs::generated(GeneratedCosts::new(2, 3, |_, _| 0.1).unwrap());
        assert!(Problem::implicit_assignment(rect).is_err(), "square required");
    }

    #[test]
    fn solution_accessors_round_trip() {
        let mut m = Matching::empty(2, 2);
        m.link(0, 1);
        m.link(1, 0);
        let sol = Solution::from_assignment(AssignmentSolution {
            matching: m,
            cost: 1.5,
            duals: None,
            stats: SolveStats::default(),
        });
        assert!(sol.matching().is_some());
        assert!(sol.plan().is_none());
        assert!(sol.expect_matching().is_ok());
        assert!(sol.expect_plan().is_err());
        let plan = sol.to_plan();
        assert!((plan.total_mass() - 1.0).abs() < 1e-12);
        assert!((plan.at(0, 1) - 0.5).abs() < 1e-12);
        assert!(!sol.is_cancelled());
    }
}
