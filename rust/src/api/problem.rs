//! The unified problem/solution model.
//!
//! One [`Problem`] covers both workloads the paper's framework serves
//! (assignment §2–3, general OT §4); one [`Solution`] covers both result
//! shapes (perfect matching or transport plan) plus the dual certificate
//! and solve counters. This replaces the parallel
//! `AssignmentSolution`/`OtSolution` pair at the public boundary — those
//! remain as internal carrier types inside `solvers/`.

use crate::core::certify::Certificate;
use crate::core::{
    AssignmentInstance, CostMatrix, DualWeights, Matching, OtInstance, OtprError, Result,
    TransportPlan,
};
use crate::solvers::{matching_to_plan, AssignmentSolution, OtSolution, SolveStats};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProblemKind {
    Assignment,
    Ot,
}

impl ProblemKind {
    pub fn name(&self) -> &'static str {
        match self {
            ProblemKind::Assignment => "assignment",
            ProblemKind::Ot => "ot",
        }
    }
}

/// What to solve: an n×n assignment or a general discrete-OT instance.
#[derive(Debug, Clone)]
pub enum Problem {
    Assignment(AssignmentInstance),
    Ot(OtInstance),
}

impl Problem {
    /// Assignment problem from a square cost matrix.
    pub fn assignment(costs: CostMatrix) -> Result<Self> {
        Ok(Problem::Assignment(AssignmentInstance::new(costs)?))
    }

    /// OT problem from costs + probability masses (demand over columns,
    /// supply over rows).
    pub fn ot(costs: CostMatrix, demand: Vec<f64>, supply: Vec<f64>) -> Result<Self> {
        Ok(Problem::Ot(OtInstance::new(costs, demand, supply)?))
    }

    pub fn kind(&self) -> ProblemKind {
        match self {
            Problem::Assignment(_) => ProblemKind::Assignment,
            Problem::Ot(_) => ProblemKind::Ot,
        }
    }

    /// Instance size (max side for rectangular OT).
    pub fn n(&self) -> usize {
        match self {
            Problem::Assignment(i) => i.n(),
            Problem::Ot(i) => i.n(),
        }
    }

    pub fn costs(&self) -> &CostMatrix {
        match self {
            Problem::Assignment(i) => &i.costs,
            Problem::Ot(i) => &i.costs,
        }
    }

    pub fn as_assignment(&self) -> Option<&AssignmentInstance> {
        match self {
            Problem::Assignment(i) => Some(i),
            _ => None,
        }
    }

    pub fn as_ot(&self) -> Option<&OtInstance> {
        match self {
            Problem::Ot(i) => Some(i),
            _ => None,
        }
    }

    /// View the problem as OT: assignment instances become uniform-mass OT
    /// (how the paper benchmarks Sinkhorn on assignment inputs).
    pub fn to_ot_instance(&self) -> Result<OtInstance> {
        match self {
            Problem::Assignment(i) => OtInstance::uniform(i.costs.clone()),
            Problem::Ot(i) => Ok(i.clone()),
        }
    }
}

/// The coupling a solver produced: a perfect matching (assignment engines)
/// or a transport plan (OT engines — including OT engines answering
/// assignment problems via uniform masses).
#[derive(Debug, Clone)]
pub enum Coupling {
    Matching(Matching),
    Plan(TransportPlan),
}

/// Unified solve result: coupling + cost under the original costs +
/// optional ε-unit dual certificate + counters.
#[derive(Debug, Clone)]
pub struct Solution {
    pub coupling: Coupling,
    /// Total cost under the *original* (unrounded) cost matrix.
    pub cost: f64,
    /// Dual weights certifying approximate optimality, when the engine
    /// maintains them (the push-relabel family, assignment *and* OT).
    pub duals: Option<DualWeights>,
    /// Verified [`Certificate`] attached by the registry when the request
    /// asked for one ([`crate::api::SolveRequest::certify`]); `None`
    /// otherwise. Run [`crate::core::certify::certify`] directly to check
    /// an existing solution after the fact.
    pub certificate: Option<Certificate>,
    pub stats: SolveStats,
}

impl Solution {
    pub fn from_assignment(sol: AssignmentSolution) -> Self {
        Self {
            coupling: Coupling::Matching(sol.matching),
            cost: sol.cost,
            duals: sol.duals,
            certificate: None,
            stats: sol.stats,
        }
    }

    pub fn from_ot(sol: OtSolution) -> Self {
        Self {
            coupling: Coupling::Plan(sol.plan),
            cost: sol.cost,
            duals: sol.duals,
            certificate: None,
            stats: sol.stats,
        }
    }

    pub fn matching(&self) -> Option<&Matching> {
        match &self.coupling {
            Coupling::Matching(m) => Some(m),
            _ => None,
        }
    }

    pub fn plan(&self) -> Option<&TransportPlan> {
        match &self.coupling {
            Coupling::Plan(p) => Some(p),
            _ => None,
        }
    }

    /// The solution as a transport plan regardless of coupling shape — a
    /// matching becomes the uniform-mass plan it induces (1/n per edge).
    pub fn to_plan(&self) -> TransportPlan {
        match &self.coupling {
            Coupling::Plan(p) => p.clone(),
            Coupling::Matching(m) => matching_to_plan(m),
        }
    }

    /// Require the matching form (typed accessor for assignment callers).
    pub fn expect_matching(&self) -> Result<&Matching> {
        self.matching().ok_or_else(|| {
            OtprError::Coordinator("solution carries a transport plan, not a matching".into())
        })
    }

    /// Require the plan form (typed accessor for OT callers).
    pub fn expect_plan(&self) -> Result<&TransportPlan> {
        self.plan().ok_or_else(|| {
            OtprError::Coordinator("solution carries a matching, not a transport plan".into())
        })
    }

    /// True when the solve stopped early on cancellation or budget.
    pub fn is_cancelled(&self) -> bool {
        self.stats.notes.iter().any(|n| n == crate::core::control::CANCELLED_NOTE)
    }

    pub fn phases(&self) -> usize {
        self.stats.phases
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;

    #[test]
    fn problem_constructors_and_kind() {
        let p = Problem::assignment(Workload::RandomCosts { n: 4 }.costs(1)).unwrap();
        assert_eq!(p.kind(), ProblemKind::Assignment);
        assert_eq!(p.n(), 4);
        assert!(p.as_assignment().is_some());
        assert!(p.as_ot().is_none());

        let ot = p.to_ot_instance().unwrap();
        assert_eq!(ot.demand.len(), 4);
        assert!(Problem::assignment(CostMatrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn solution_accessors_round_trip() {
        let mut m = Matching::empty(2, 2);
        m.link(0, 1);
        m.link(1, 0);
        let sol = Solution::from_assignment(AssignmentSolution {
            matching: m,
            cost: 1.5,
            duals: None,
            stats: SolveStats::default(),
        });
        assert!(sol.matching().is_some());
        assert!(sol.plan().is_none());
        assert!(sol.expect_matching().is_ok());
        assert!(sol.expect_plan().is_err());
        let plan = sol.to_plan();
        assert!((plan.total_mass() - 1.0).abs() < 1e-12);
        assert!((plan.at(0, 1) - 0.5).abs() < 1e-12);
        assert!(!sol.is_cancelled());
    }
}
