//! Solve requests: accuracy target, wall-clock budget, cancellation, and
//! progress observation — the one configuration object every engine
//! understands.
//!
//! [`SolveRequest`] is what callers build; [`SolveControl`] (defined in
//! [`crate::core::control`] so the algorithm layer never depends on this
//! module) is the solver-facing snapshot of it, with the budget already
//! resolved into a deadline. The push-relabel family and Sinkhorn poll
//! [`SolveControl::should_stop`] between phases and report
//! (phase, free-mass-remaining) through [`SolveControl::report`], which is
//! how the coordinator implements job timeouts and live per-engine phase
//! metrics without reaching into solver internals.

// Re-exported here because they are part of the public request surface;
// they live in core so solvers can use them without an api dependency.
pub use crate::core::control::{CancelToken, Progress, ProgressFn, SolveControl, CANCELLED_NOTE};
use crate::api::problem::Problem;
use crate::api::registry::{BatchReport, SolverConfig, SolverRegistry};
use std::fmt;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How a request's `eps` is interpreted by the push-relabel assignment
/// engines (exact and Sinkhorn engines ignore the distinction).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EpsSemantics {
    /// `eps` is the overall additive target: error ≤ eps·n·c_max. The core
    /// routine runs at ε/3 (paper §1 "Organization"). Default.
    #[default]
    Overall,
    /// `eps` is the raw algorithm parameter (3ε guarantee) — what the
    /// experiment harnesses drive, matching the paper's own plots.
    AlgorithmParam,
}

/// Builder-style description of one solve.
#[derive(Clone)]
pub struct SolveRequest {
    /// Additive accuracy target (relative to c_max); see [`EpsSemantics`].
    pub eps: f64,
    pub eps_semantics: EpsSemantics,
    /// Wall-clock budget. When exceeded the solve stops at the next phase
    /// boundary, completes arbitrarily, and notes [`CANCELLED_NOTE`].
    pub budget: Option<Duration>,
    pub cancel: CancelToken,
    pub observer: Option<ProgressFn>,
    /// Attach a verified [`crate::core::certify::Certificate`] to the
    /// solution after the solve (registry path). O(n²) post-pass.
    pub want_certificate: bool,
    /// Deadline pressure degrades instead of cancelling: warm-ladder
    /// engines stop at a level boundary and return the last completed
    /// level's certified coarser-ε answer, noting
    /// [`crate::core::control::DEGRADED_NOTE_PREFIX`]. Engines without a
    /// ladder (single-level schedules) ignore the flag and keep the
    /// cancel-at-next-phase behavior. Off by default; the coordinator's
    /// `DegradePolicy` turns it on for deadline-carrying jobs.
    pub degrade_on_deadline: bool,
    /// Tenant this request bills to. The coordinator resolves it against
    /// its configured quotas: admission-queue depth, in-flight caps, and
    /// the tenant's default deadline (tighter of this and the request's
    /// own `budget`; see `coordinator::TenantQuota`). `None` uses the
    /// anonymous default quota.
    pub tenant: Option<String>,
}

impl Default for SolveRequest {
    fn default() -> Self {
        Self::new(0.1)
    }
}

impl fmt::Debug for SolveRequest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SolveRequest")
            .field("eps", &self.eps)
            .field("eps_semantics", &self.eps_semantics)
            .field("budget", &self.budget)
            .field("cancelled", &self.cancel.is_cancelled())
            .field("observer", &self.observer.is_some())
            .field("want_certificate", &self.want_certificate)
            .field("tenant", &self.tenant)
            .finish()
    }
}

impl SolveRequest {
    pub fn new(eps: f64) -> Self {
        Self {
            eps,
            eps_semantics: EpsSemantics::Overall,
            budget: None,
            cancel: CancelToken::new(),
            observer: None,
            want_certificate: false,
            degrade_on_deadline: false,
            tenant: None,
        }
    }

    /// Bill this request to `tenant` (see the field doc).
    pub fn for_tenant(mut self, tenant: impl Into<String>) -> Self {
        self.tenant = Some(tenant.into());
        self
    }

    /// Interpret `eps` as the raw algorithm parameter (harness mode).
    pub fn raw_eps(mut self) -> Self {
        self.eps_semantics = EpsSemantics::AlgorithmParam;
        self
    }

    /// Ask the registry to verify the solution post-solve and attach the
    /// resulting [`crate::core::certify::Certificate`] to
    /// `Solution::certificate`.
    pub fn certify(mut self, on: bool) -> Self {
        self.want_certificate = on;
        self
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = Some(budget);
        self
    }

    /// Prefer a certified coarser-ε answer over cancellation when the
    /// budget expires (see the field doc on `degrade_on_deadline`).
    pub fn degrade_on_deadline(mut self, on: bool) -> Self {
        self.degrade_on_deadline = on;
        self
    }

    /// The job's effective deadline: the tighter of the request's own
    /// budget and a per-tenant default, both measured from `submitted`.
    /// `None` only when neither bound exists.
    pub fn effective_deadline(
        &self,
        submitted: Instant,
        default: Option<Duration>,
    ) -> Option<Instant> {
        match (self.budget, default) {
            (Some(b), Some(d)) => Some(submitted + b.min(d)),
            (Some(b), None) => Some(submitted + b),
            (None, Some(d)) => Some(submitted + d),
            (None, None) => None,
        }
    }

    pub fn with_cancel(mut self, token: CancelToken) -> Self {
        self.cancel = token;
        self
    }

    pub fn with_observer(mut self, f: impl Fn(Progress) + Send + Sync + 'static) -> Self {
        self.observer = Some(Arc::new(f));
        self
    }

    /// Append `f` after any existing observer (both run, in order). The
    /// coordinator uses this to tee progress into its metrics.
    pub fn chain_observer(mut self, f: impl Fn(Progress) + Send + Sync + 'static) -> Self {
        self.observer = Some(match self.observer.take() {
            Some(prev) => Arc::new(move |p| {
                prev(p);
                f(p);
            }),
            None => Arc::new(f),
        });
        self
    }

    /// The eps the push-relabel core should run at.
    pub fn eps_param(&self, overall_divisor: f64) -> f64 {
        match self.eps_semantics {
            EpsSemantics::Overall => self.eps / overall_divisor,
            EpsSemantics::AlgorithmParam => self.eps,
        }
    }

    /// First-class batch entry: solve a slice of problems under **this**
    /// request through `registry`'s `engine`. Kernel-backed engines keep
    /// one arena warm across same-shape instances; the returned
    /// [`BatchReport`] counts the reuse hits. The request's cancellation
    /// token and budget are honored *between phases inside the batch* —
    /// cancelling stops the current item at its next phase boundary and
    /// short-circuits the remaining items into cancelled completions.
    pub fn solve_many(
        &self,
        registry: &SolverRegistry,
        engine: &str,
        config: &SolverConfig,
        problems: &[Problem],
    ) -> crate::core::Result<BatchReport> {
        registry.solve_batch(engine, config, problems, self)
    }

    /// Snapshot the request into a solver-facing control handle, resolving
    /// the budget into a deadline now.
    pub fn control(&self) -> SolveControl {
        SolveControl {
            cancel: Some(self.cancel.clone()),
            deadline: self.budget.map(|b| Instant::now() + b),
            observer: self.observer.clone(),
            degrade_on_deadline: self.degrade_on_deadline,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn control_stops_on_cancel_and_deadline() {
        let req = SolveRequest::new(0.1);
        let ctl = req.control();
        assert!(!ctl.should_stop());
        req.cancel.cancel();
        assert!(ctl.should_stop());

        let req = SolveRequest::new(0.1).with_budget(Duration::ZERO);
        assert!(req.control().should_stop(), "zero budget expires immediately");
    }

    #[test]
    fn observers_chain_in_order() {
        let count = Arc::new(AtomicUsize::new(0));
        let (c1, c2) = (count.clone(), count.clone());
        let req = SolveRequest::new(0.1)
            .with_observer(move |_| {
                c1.fetch_add(1, Ordering::Relaxed);
            })
            .chain_observer(move |p| {
                assert_eq!(p.phase, 3);
                c2.fetch_add(10, Ordering::Relaxed);
            });
        req.control().report(3, 7.0);
        assert_eq!(count.load(Ordering::Relaxed), 11);
    }

    #[test]
    fn certify_flag_defaults_off() {
        assert!(!SolveRequest::new(0.1).want_certificate);
        assert!(SolveRequest::new(0.1).certify(true).want_certificate);
        assert!(!SolveRequest::new(0.1).certify(true).certify(false).want_certificate);
    }

    #[test]
    fn effective_deadline_takes_the_tighter_bound() {
        let t0 = Instant::now();
        let short = Duration::from_millis(10);
        let long = Duration::from_secs(10);
        let req = SolveRequest::new(0.1);
        assert_eq!(req.effective_deadline(t0, None), None);
        assert_eq!(req.effective_deadline(t0, Some(long)), Some(t0 + long));
        let req = SolveRequest::new(0.1).with_budget(short);
        assert_eq!(req.effective_deadline(t0, None), Some(t0 + short));
        assert_eq!(req.effective_deadline(t0, Some(long)), Some(t0 + short));
        let req = SolveRequest::new(0.1).with_budget(long);
        assert_eq!(req.effective_deadline(t0, Some(short)), Some(t0 + short));
    }

    #[test]
    fn degrade_flag_snapshots_into_control() {
        assert!(!SolveRequest::new(0.1).control().degrade_on_deadline());
        assert!(SolveRequest::new(0.1).degrade_on_deadline(true).control().degrade_on_deadline());
    }

    #[test]
    fn eps_semantics() {
        let overall = SolveRequest::new(0.3);
        assert!((overall.eps_param(3.0) - 0.1).abs() < 1e-12);
        let raw = SolveRequest::new(0.3).raw_eps();
        assert!((raw.eps_param(3.0) - 0.3).abs() < 1e-12);
    }
}
