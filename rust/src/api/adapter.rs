//! The unified [`Solver`] trait and the adapter layer that lifts every
//! engine in `solvers/` and `runtime/` onto it.
//!
//! Two blanket adapters cover the legacy traits
//! ([`crate::solvers::AssignmentSolver`] / [`crate::solvers::OtSolver`]);
//! the four control-aware engines (sequential/parallel/OT push-relabel and
//! Sinkhorn) get dedicated impls that honor the request's cancellation
//! token, wall-clock budget, progress observer, and ε semantics. This is
//! the **only** module that is allowed to name the legacy solver traits —
//! everything above it (`coordinator`, `exp`, `examples/`, `main.rs`)
//! speaks [`Solver`] through the [`crate::api::SolverRegistry`].

use crate::api::problem::{Problem, ProblemKind, Solution};
use crate::api::request::SolveRequest;
use crate::core::control::CANCELLED_NOTE;
use crate::core::kernel::{
    ChunkedKernel, FlowKernel, HybridKernel, ScalarKernel, VectorKernel, WarmStart,
};
use crate::core::{Matching, OtInstance, OtprError, Result, TransportPlan};
use crate::runtime::{XlaAssignment, XlaRuntime, XlaSinkhorn};
use crate::solvers::ot_push_relabel::{drive_ot, drive_ot_src};
use crate::solvers::push_relabel::{drive_assignment, drive_assignment_src};
use crate::solvers::sinkhorn::{Sinkhorn, SinkhornConfig};
use crate::solvers::{AssignmentSolution, AssignmentSolver, OtSolution, OtSolver, SolveStats};
use std::sync::Arc;

/// One algorithm behind one name: solves any [`Problem`] kind it declares
/// support for, under one [`SolveRequest`].
pub trait Solver: Send + Sync {
    /// Descriptive algorithm name (the registry key is the canonical
    /// *engine* name; see [`crate::api::registry`]).
    fn name(&self) -> &'static str;

    fn supports(&self, kind: ProblemKind) -> bool;

    fn solve(&self, problem: &Problem, req: &SolveRequest) -> Result<Solution>;

    /// Solve a sequence of (problem, request) pairs, reusing whatever
    /// internal state the engine can between items — the kernel-backed
    /// engines keep **one arena** warm across same-shape instances
    /// (`Solution::stats.arena_reused` marks the hits). Each item's own
    /// budget/cancellation is honored between phases, so a shared
    /// [`crate::api::CancelToken`] stops the whole batch at the next
    /// phase boundary. The default implementation solves item-by-item
    /// with a per-item capability check.
    fn solve_each(&self, items: &[(&Problem, &SolveRequest)]) -> Vec<Result<Solution>> {
        items
            .iter()
            .map(|&(p, r)| {
                if !self.supports(p.kind()) {
                    Err(unsupported(self.name(), p.kind()))
                } else {
                    self.solve(p, r)
                }
            })
            .collect()
    }
}

fn unsupported(name: &str, kind: ProblemKind) -> OtprError {
    OtprError::Coordinator(format!("engine {name} does not support {} problems", kind.name()))
}

/// Error for slab-bound engines handed an implicit problem: the cause is
/// the cost representation, not the problem kind, so say so.
fn dense_required(name: &str, problem: &Problem) -> OtprError {
    match problem {
        Problem::Implicit(i) => OtprError::Coordinator(format!(
            "engine {name} requires dense costs: implicit-cost problem ({}) must be \
             materialized with Problem::to_dense() or routed to a kernel engine",
            i.costs.kind()
        )),
        _ => unsupported(name, problem.kind()),
    }
}

/// The coupling a cancelled-before-any-work solve returns, matching what
/// the native engines produce when stopped at phase 0: an arbitrary
/// perfect matching (assignment) or the feasible product plan ν⊗μ (OT) —
/// usable, feasible, no approximation guarantee, `"cancelled"` noted.
fn cancelled_assignment(n: usize, costs: &crate::core::CostMatrix) -> Solution {
    let m = Matching::arbitrary_complete(n, n);
    let cost = m.cost(costs);
    Solution::from_assignment(AssignmentSolution {
        matching: m,
        cost,
        duals: None,
        stats: SolveStats { notes: vec![CANCELLED_NOTE.to_string()], ..Default::default() },
    })
}

fn cancelled_ot(ot: &OtInstance) -> Solution {
    // Lazy product (PR 8): O(nb+na) resident — the cost fold streams the
    // entries without ever allocating the nb·na slab, so cancelling a
    // large solve costs no plan memory (regression-pinned at n=4096 in
    // tests/sparse_plan.rs).
    let plan = TransportPlan::product(&ot.supply, &ot.demand);
    let cost = plan.cost(&ot.costs);
    Solution::from_ot(OtSolution {
        plan,
        cost,
        duals: None,
        stats: SolveStats { notes: vec![CANCELLED_NOTE.to_string()], ..Default::default() },
    })
}

/// Blanket adapter: any [`AssignmentSolver`] as a [`Solver`] (assignment
/// problems only). `eps` passes through with the wrapped trait's overall
/// semantics; [`crate::api::EpsSemantics::AlgorithmParam`] is ignored, so
/// only wrap engines that ignore `eps` entirely (exact/greedy oracles) —
/// ε-sensitive engines need a dedicated impl (see [`LmrSolver`]).
pub struct AssignmentAdapter<S>(pub S);

impl<S: AssignmentSolver + Send + Sync> Solver for AssignmentAdapter<S> {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn supports(&self, kind: ProblemKind) -> bool {
        kind == ProblemKind::Assignment
    }

    fn solve(&self, problem: &Problem, req: &SolveRequest) -> Result<Solution> {
        let inst = problem
            .as_assignment()
            .ok_or_else(|| dense_required(self.name(), problem))?;
        Ok(Solution::from_assignment(self.0.solve_assignment(inst, req.eps)?))
    }
}

/// Blanket adapter: any [`OtSolver`] as a [`Solver`]. Assignment problems
/// are answered through their uniform-mass OT relaxation (how the paper
/// benchmarks Sinkhorn on assignment inputs).
pub struct OtAdapter<S>(pub S);

impl<S: OtSolver + Send + Sync> Solver for OtAdapter<S> {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn supports(&self, _kind: ProblemKind) -> bool {
        true
    }

    fn solve(&self, problem: &Problem, req: &SolveRequest) -> Result<Solution> {
        let ot = problem.to_ot_instance()?;
        Ok(Solution::from_ot(self.0.solve_ot(&ot, req.eps)?))
    }
}

/// Solve one (problem, request) item on an already-initialized kernel —
/// the shared body of every native engine. The kernel arena is reused
/// across calls; `init`/`warm_reinit` inside the drivers re-quantize in
/// place, and `warm` selects the ε-scaling schedule / batch dual reuse.
fn solve_one_on_kernel(
    kernel: &mut dyn FlowKernel,
    problem: &Problem,
    req: &SolveRequest,
    paranoid: bool,
    warm: WarmStart,
) -> Result<Solution> {
    match problem {
        Problem::Assignment(inst) => {
            drive_assignment(kernel, inst, req.eps_param(3.0), &req.control(), paranoid, warm)
                .map(Solution::from_assignment)
        }
        // OT ε is always the overall additive target (ε·c_max)
        Problem::Ot(inst) => {
            drive_ot(kernel, inst, req.eps, req.eps / 6.0, &req.control(), paranoid, warm)
                .map(Solution::from_ot)
        }
        // Implicit (provider-backed) instances run the same drivers over
        // a streamed CostSource — no O(n²) slab is ever materialized, and
        // results are byte-identical to the dense form of the instance.
        Problem::Implicit(inst) => match &inst.masses {
            None => drive_assignment_src(
                kernel,
                &inst.costs.source(),
                req.eps_param(3.0),
                &req.control(),
                paranoid,
                warm,
            )
            .map(Solution::from_assignment),
            Some((supply, demand)) => drive_ot_src(
                kernel,
                &inst.costs.source(),
                supply,
                demand,
                req.eps,
                req.eps / 6.0,
                &req.control(),
                paranoid,
                warm,
            )
            .map(Solution::from_ot),
        },
    }
}

/// Batch body: item 0 runs `warm` as requested (for warm engines, the
/// full ε schedule); later items additionally offer the drivers the
/// arena's current duals (`carry`) — the drivers take them only when the
/// shapes actually match, so mixed batches degrade gracefully.
fn solve_items_on_kernel(
    kernel: &mut dyn FlowKernel,
    items: &[(&Problem, &SolveRequest)],
    paranoid: bool,
    warm: WarmStart,
) -> Vec<Result<Solution>> {
    items
        .iter()
        .enumerate()
        .map(|(i, &(p, r))| {
            let w = WarmStart { carry: warm.carry && i > 0, ..warm };
            solve_one_on_kernel(kernel, p, r, paranoid, w)
        })
        .collect()
}

/// Warm-start policy shared by every kernel-backed engine: batch dual
/// carry gates on the same predicate as the engine's warm name, so
/// `warm_levels == 1` behaves exactly like the cold engine it reports
/// itself as.
fn kernel_warm(levels: u32) -> WarmStart {
    WarmStart { levels, carry: levels > 1 }
}

/// A kernel-backed engine holding its kernel (and therefore its arena)
/// **across calls** — the arena-affinity primitive behind the
/// coordinator's shape-keyed shards. A plain `Solver::solve_each` builds
/// a fresh kernel per call, so warm reuse stops at batch boundaries;
/// a `WarmKernelSolver` pinned by a shard worker keeps one arena alive
/// for that worker's whole lifetime, so every same-shape solve after the
/// very first reports `SolveStats::arena_reused`.
///
/// Only the six native kernel engines have one ([`WarmKernelSolver::
/// for_engine`] returns `None` for XLA/Sinkhorn/exact oracles — they own
/// no arena worth pinning). Holders must drop the instance if a solve
/// panics out from under them (`catch_unwind`): the arena state is then
/// unspecified and the next build starts cold, which is always correct.
pub struct WarmKernelSolver {
    kernel: Box<dyn FlowKernel>,
    paranoid: bool,
    warm: WarmStart,
    /// `"threads=N"` note prepended by the parallel/hybrid engines.
    note: Option<String>,
    /// Whether any item has run on this kernel yet — generalizes the
    /// `i > 0` dual-carry gate across call boundaries.
    solved: bool,
}

impl WarmKernelSolver {
    /// Build the persistent form of a native kernel engine, mirroring the
    /// registry's builders exactly (same kernel backend, same paranoia,
    /// same warm policy for the given canonical engine key).
    pub fn for_engine(key: &str, cfg: &crate::api::registry::SolverConfig) -> Option<Self> {
        let (kernel, warm, note): (Box<dyn FlowKernel>, WarmStart, Option<String>) = match key {
            "native-seq" => (Box::new(ScalarKernel::new()), kernel_warm(0), None),
            "native-seq-warm" => {
                (Box::new(ScalarKernel::new()), kernel_warm(cfg.warm_levels.max(2)), None)
            }
            "native-vector" => (Box::new(VectorKernel::new()), kernel_warm(0), None),
            "native-vector-warm" => {
                (Box::new(VectorKernel::new()), kernel_warm(cfg.warm_levels.max(2)), None)
            }
            "native-parallel" => (
                Box::new(ChunkedKernel::new(cfg.threads)),
                WarmStart::COLD,
                Some(format!("threads={}", cfg.threads.max(1))),
            ),
            "native-hybrid" => (
                Box::new(HybridKernel::new(cfg.threads)),
                WarmStart::COLD,
                Some(format!("threads={}", cfg.threads.max(1))),
            ),
            _ => return None,
        };
        Some(Self { kernel, paranoid: cfg.paranoid, warm, note, solved: false })
    }

    /// Solve a batch on the pinned kernel. Semantics match
    /// [`Solver::solve_each`] on the same engine, except the arena (and,
    /// for warm engines, the dual carry) persists from previous calls.
    pub fn solve_each(&mut self, items: &[(&Problem, &SolveRequest)]) -> Vec<Result<Solution>> {
        items
            .iter()
            .map(|&(p, r)| {
                let w = WarmStart { carry: self.warm.carry && self.solved, ..self.warm };
                let result = solve_one_on_kernel(self.kernel.as_mut(), p, r, self.paranoid, w);
                // The arena holds state after any attempt that reached the
                // drivers, successful or not.
                self.solved = true;
                match (result, &self.note) {
                    (Ok(mut sol), Some(note)) => {
                        sol.stats.notes.insert(0, note.clone());
                        Ok(sol)
                    }
                    (r, _) => r,
                }
            })
            .collect()
    }
}

fn kernel_engine_name(cold: &'static str, warm: &'static str, levels: u32) -> &'static str {
    if levels > 1 {
        warm
    } else {
        cold
    }
}

/// `native-seq` / `native-seq-warm`: the paper's sequential push-relabel
/// (§2.2) for assignment plus the §4 copy-compressed OT solver, behind
/// one engine key — both driven over the scalar kernel backend.
/// `warm_levels ≥ 2` adds the geometric ε-scaling schedule plus batch
/// dual reuse across same-shape items.
pub struct NativeSeqSolver {
    pub paranoid: bool,
    pub warm_levels: u32,
}

impl Solver for NativeSeqSolver {
    fn name(&self) -> &'static str {
        kernel_engine_name("native-seq", "native-seq-warm", self.warm_levels)
    }

    fn supports(&self, _kind: ProblemKind) -> bool {
        true
    }

    fn solve(&self, problem: &Problem, req: &SolveRequest) -> Result<Solution> {
        let mut kernel = ScalarKernel::new();
        solve_one_on_kernel(&mut kernel, problem, req, self.paranoid, kernel_warm(self.warm_levels))
    }

    fn solve_each(&self, items: &[(&Problem, &SolveRequest)]) -> Vec<Result<Solution>> {
        let mut kernel = ScalarKernel::new();
        solve_items_on_kernel(&mut kernel, items, self.paranoid, kernel_warm(self.warm_levels))
    }
}

/// `native-vector` / `native-vector-warm`: the lane-blocked
/// auto-vectorized kernel backend — byte-identical results to
/// `native-seq` (the kernel contract), ~1/8 the propose-sweep memory
/// traffic. `warm_levels ≥ 2` adds ε-scaling warm starts and batch dual
/// reuse on top.
pub struct NativeVectorSolver {
    pub paranoid: bool,
    pub warm_levels: u32,
}

impl Solver for NativeVectorSolver {
    fn name(&self) -> &'static str {
        kernel_engine_name("native-vector", "native-vector-warm", self.warm_levels)
    }

    fn supports(&self, _kind: ProblemKind) -> bool {
        true
    }

    fn solve(&self, problem: &Problem, req: &SolveRequest) -> Result<Solution> {
        let mut kernel = VectorKernel::new();
        solve_one_on_kernel(&mut kernel, problem, req, self.paranoid, kernel_warm(self.warm_levels))
    }

    fn solve_each(&self, items: &[(&Problem, &SolveRequest)]) -> Vec<Result<Solution>> {
        let mut kernel = VectorKernel::new();
        solve_items_on_kernel(&mut kernel, items, self.paranoid, kernel_warm(self.warm_levels))
    }
}

/// `native-parallel`: the chunked (thread-sweep) kernel backend for both
/// problem kinds — assignment *and* the §4 OT cluster state. Identical
/// results to `native-seq` at every thread count (the kernel contract);
/// only wall-clock differs.
pub struct NativeParallelSolver {
    pub threads: usize,
    pub paranoid: bool,
}

impl Solver for NativeParallelSolver {
    fn name(&self) -> &'static str {
        "native-parallel"
    }

    fn supports(&self, _kind: ProblemKind) -> bool {
        true
    }

    fn solve(&self, problem: &Problem, req: &SolveRequest) -> Result<Solution> {
        let mut kernel = ChunkedKernel::new(self.threads);
        let mut sol =
            solve_one_on_kernel(&mut kernel, problem, req, self.paranoid, WarmStart::COLD)?;
        sol.stats.notes.insert(0, format!("threads={}", self.threads.max(1)));
        Ok(sol)
    }

    fn solve_each(&self, items: &[(&Problem, &SolveRequest)]) -> Vec<Result<Solution>> {
        let mut kernel = ChunkedKernel::new(self.threads);
        let note = format!("threads={}", self.threads.max(1));
        solve_items_on_kernel(&mut kernel, items, self.paranoid, WarmStart::COLD)
            .into_iter()
            .map(|r| {
                r.map(|mut sol| {
                    sol.stats.notes.insert(0, note.clone());
                    sol
                })
            })
            .collect()
    }
}

/// `native-hybrid`: the lane-blocked propose sweep fanned over scoped
/// threads (vector × chunked) for both problem kinds, dense *and*
/// implicit costs — every core runs the block-min skip path. Identical
/// results to `native-seq` at every thread count (the kernel contract);
/// only wall-clock differs.
pub struct NativeHybridSolver {
    pub threads: usize,
    pub paranoid: bool,
}

impl Solver for NativeHybridSolver {
    fn name(&self) -> &'static str {
        "native-hybrid"
    }

    fn supports(&self, _kind: ProblemKind) -> bool {
        true
    }

    fn solve(&self, problem: &Problem, req: &SolveRequest) -> Result<Solution> {
        let mut kernel = HybridKernel::new(self.threads);
        let mut sol =
            solve_one_on_kernel(&mut kernel, problem, req, self.paranoid, WarmStart::COLD)?;
        sol.stats.notes.insert(0, format!("threads={}", self.threads.max(1)));
        Ok(sol)
    }

    fn solve_each(&self, items: &[(&Problem, &SolveRequest)]) -> Vec<Result<Solution>> {
        let mut kernel = HybridKernel::new(self.threads);
        let note = format!("threads={}", self.threads.max(1));
        solve_items_on_kernel(&mut kernel, items, self.paranoid, WarmStart::COLD)
            .into_iter()
            .map(|r| {
                r.map(|mut sol| {
                    sol.stats.notes.insert(0, note.clone());
                    sol
                })
            })
            .collect()
    }
}

/// `lmr`: the LMR'19 baseline, with proper ε semantics — overall requests
/// run the core at ε/2 (rounding + completion), raw requests drive the
/// algorithm parameter directly, mirroring the push-relabel engines so
/// one `--eps` means the same target across a comparison.
pub struct LmrSolver;

impl Solver for LmrSolver {
    fn name(&self) -> &'static str {
        "lmr"
    }

    fn supports(&self, kind: ProblemKind) -> bool {
        kind == ProblemKind::Assignment
    }

    fn solve(&self, problem: &Problem, req: &SolveRequest) -> Result<Solution> {
        let inst = problem
            .as_assignment()
            .ok_or_else(|| dense_required(self.name(), problem))?;
        let sol = crate::solvers::lmr::LmrBaseline.solve_with_param(inst, req.eps_param(2.0))?;
        Ok(Solution::from_assignment(sol))
    }
}

/// `sinkhorn-native`: the AWR'17-parameterized Sinkhorn baseline. Both
/// problem kinds (assignment via uniform masses).
pub struct SinkhornSolver {
    pub log_domain: bool,
    pub max_iters: usize,
}

impl Solver for SinkhornSolver {
    fn name(&self) -> &'static str {
        "sinkhorn-native"
    }

    fn supports(&self, _kind: ProblemKind) -> bool {
        true
    }

    fn solve(&self, problem: &Problem, req: &SolveRequest) -> Result<Solution> {
        let ot = problem.to_ot_instance()?;
        let solver = Sinkhorn {
            config: SinkhornConfig {
                log_domain: self.log_domain,
                max_iters: self.max_iters,
                ..Default::default()
            },
        };
        Ok(Solution::from_ot(solver.solve_ot_ctl(&ot, req.eps, &req.control())?))
    }
}

/// `xla`: device-resident push-relabel over the AOT artifacts. Assignment
/// only (the artifact set has no OT phase loop); jobs fail cleanly when no
/// runtime is loaded. Cancellation is honored at dispatch granularity: a
/// request already stopped at dispatch time returns the same
/// cancelled-at-phase-0 coupling the native engines produce; mid-solve
/// budget expiry is not yet polled between device round trips.
pub struct XlaEngineSolver {
    pub runtime: Option<Arc<XlaRuntime>>,
    /// Reject instances that are not an exact artifact size instead of
    /// padding up to the next bucket.
    pub require_exact_bucket: bool,
}

impl Solver for XlaEngineSolver {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn supports(&self, kind: ProblemKind) -> bool {
        kind == ProblemKind::Assignment
    }

    fn solve(&self, problem: &Problem, req: &SolveRequest) -> Result<Solution> {
        let rt = self
            .runtime
            .clone()
            .ok_or_else(|| OtprError::Coordinator("no XLA runtime loaded".into()))?;
        let inst = problem.as_assignment().ok_or_else(|| match problem {
            Problem::Implicit(_) => dense_required(self.name(), problem),
            _ => OtprError::Coordinator(
                "XLA engine supports assignment jobs only (OT runs native)".into(),
            ),
        })?;
        if req.control().should_stop() {
            return Ok(cancelled_assignment(inst.n(), &inst.costs));
        }
        if self.require_exact_bucket && !rt.registry.sizes.contains(&inst.n()) {
            return Err(OtprError::Artifact(format!(
                "bucket policy exact-only: no artifact of size {} (available: {:?})",
                inst.n(),
                rt.registry.sizes
            )));
        }
        let sol = XlaAssignment::new(rt).solve_costs(inst, req.eps_param(3.0))?;
        Ok(Solution::from_assignment(sol))
    }
}

/// `sinkhorn-xla`: device-resident Sinkhorn over the artifacts; both
/// problem kinds (assignment via uniform masses). Like [`XlaEngineSolver`],
/// cancellation is honored at dispatch granularity.
pub struct XlaSinkhornSolver {
    pub runtime: Option<Arc<XlaRuntime>>,
    pub max_iters: usize,
}

impl Solver for XlaSinkhornSolver {
    fn name(&self) -> &'static str {
        "sinkhorn-xla"
    }

    fn supports(&self, _kind: ProblemKind) -> bool {
        true
    }

    fn solve(&self, problem: &Problem, req: &SolveRequest) -> Result<Solution> {
        let rt = self
            .runtime
            .clone()
            .ok_or_else(|| OtprError::Coordinator("no XLA runtime loaded".into()))?;
        let ot = problem.to_ot_instance()?;
        if req.control().should_stop() {
            return Ok(cancelled_ot(&ot));
        }
        let mut solver = XlaSinkhorn::new(rt);
        solver.max_iters = self.max_iters;
        Ok(Solution::from_ot(solver.solve_ot(&ot, req.eps)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::request::CancelToken;
    use crate::data::workloads::Workload;
    use crate::solvers::hungarian::Hungarian;
    use crate::solvers::ssp_ot::SspExactOt;

    fn assignment(n: usize, seed: u64) -> Problem {
        Problem::Assignment(Workload::RandomCosts { n }.assignment(seed))
    }

    #[test]
    fn assignment_adapter_rejects_ot() {
        let s = AssignmentAdapter(Hungarian);
        assert!(s.supports(ProblemKind::Assignment));
        assert!(!s.supports(ProblemKind::Ot));
        let ot = Problem::Ot(Workload::Fig1 { n: 6 }.ot_with_random_masses(1));
        assert!(s.solve(&ot, &SolveRequest::new(0.1)).is_err());
        let sol = s.solve(&assignment(8, 1), &SolveRequest::new(0.0)).unwrap();
        assert!(sol.matching().unwrap().is_perfect());
    }

    #[test]
    fn ot_adapter_lifts_assignment_to_uniform_ot() {
        let s = OtAdapter(SspExactOt::default());
        let sol = s.solve(&assignment(6, 2), &SolveRequest::new(0.1)).unwrap();
        let plan = sol.plan().expect("OT adapter returns a plan");
        assert!((plan.total_mass() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn native_seq_solves_both_kinds_with_duals() {
        let s = NativeSeqSolver { paranoid: true, warm_levels: 0 };
        let sol = s.solve(&assignment(12, 3), &SolveRequest::new(0.3)).unwrap();
        assert!(sol.matching().unwrap().is_perfect());
        assert!(sol.duals.is_some(), "push-relabel emits its dual certificate");

        let ot = Problem::Ot(Workload::Fig1 { n: 10 }.ot_with_random_masses(3));
        let sol = s.solve(&ot, &SolveRequest::new(0.3)).unwrap();
        assert!((sol.plan().unwrap().total_mass() - 1.0).abs() < 1e-9);
        assert!(sol.duals.is_some(), "the §4 OT solver exports its cluster duals");
    }

    #[test]
    fn cancelled_request_noted() {
        let token = CancelToken::new();
        token.cancel();
        let req = SolveRequest::new(0.2).with_cancel(token);
        let s = NativeSeqSolver { paranoid: false, warm_levels: 0 };
        let sol = s.solve(&assignment(16, 4), &req).unwrap();
        assert!(sol.is_cancelled());
        assert_eq!(sol.stats.phases, 0, "cancelled before the first phase");
        assert!(sol.matching().unwrap().is_perfect(), "still completed arbitrarily");
    }

    #[test]
    fn solve_each_reuses_one_kernel_arena_across_same_shape_items() {
        let s = NativeSeqSolver { paranoid: false, warm_levels: 0 };
        let problems: Vec<Problem> = (0..4).map(|i| assignment(10, 100 + i)).collect();
        let req = SolveRequest::new(0.3);
        let items: Vec<(&Problem, &SolveRequest)> = problems.iter().map(|p| (p, &req)).collect();
        let sols: Vec<Solution> =
            s.solve_each(&items).into_iter().map(|r| r.unwrap()).collect();
        assert!(!sols[0].stats.arena_reused, "first item builds the arena");
        assert!(sols[1..].iter().all(|sol| sol.stats.arena_reused), "rest reuse it");
        // batch results identical to individual solves
        for (p, batched) in problems.iter().zip(&sols) {
            let single = s.solve(p, &req).unwrap();
            assert_eq!(single.matching(), batched.matching());
            assert_eq!(single.duals, batched.duals);
        }
        // a shape change breaks the reuse run, mixed kinds still solve
        let ot = Problem::Ot(Workload::Fig1 { n: 7 }.ot_with_random_masses(1));
        let mixed: Vec<(&Problem, &SolveRequest)> = vec![(&problems[0], &req), (&ot, &req)];
        let sols = s.solve_each(&mixed);
        assert!(sols[0].as_ref().unwrap().matching().is_some());
        assert!(sols[1].as_ref().unwrap().plan().is_some());
    }

    #[test]
    fn warm_kernel_solver_pins_the_arena_across_calls() {
        let cfg = crate::api::registry::SolverConfig::default();
        let mut pinned = WarmKernelSolver::for_engine("native-seq", &cfg).expect("native engine");
        let req = SolveRequest::new(0.3);
        let problems: Vec<Problem> = (0..4).map(|i| assignment(10, 400 + i)).collect();
        // four *separate* calls — a plain Solver would rebuild the kernel
        // each time and never report a reuse after the first call either
        let mut sols = Vec::new();
        for p in &problems {
            let items: Vec<(&Problem, &SolveRequest)> = vec![(p, &req)];
            sols.push(pinned.solve_each(&items).remove(0).unwrap());
        }
        assert!(!sols[0].stats.arena_reused, "first-ever solve builds the arena");
        assert!(
            sols[1..].iter().all(|s| s.stats.arena_reused),
            "every later same-shape call reuses the pinned arena"
        );
        // results identical to a throwaway solver (cold engine: pinning
        // only changes memory traffic, never answers)
        let throwaway = NativeSeqSolver { paranoid: false, warm_levels: 0 };
        for (p, pinned_sol) in problems.iter().zip(&sols) {
            let fresh = throwaway.solve(p, &req).unwrap();
            assert_eq!(fresh.matching(), pinned_sol.matching());
            assert_eq!(fresh.duals, pinned_sol.duals);
        }
        // non-kernel engines have nothing to pin
        assert!(WarmKernelSolver::for_engine("hungarian", &cfg).is_none());
        assert!(WarmKernelSolver::for_engine("sinkhorn-native", &cfg).is_none());
    }

    #[test]
    fn warm_kernel_solver_carries_duals_across_calls() {
        let cfg = crate::api::registry::SolverConfig::default();
        let mut pinned =
            WarmKernelSolver::for_engine("native-vector-warm", &cfg).expect("native engine");
        let req = SolveRequest::new(0.3);
        let first = {
            let p = assignment(12, 500);
            let items: Vec<(&Problem, &SolveRequest)> = vec![(&p, &req)];
            pinned.solve_each(&items).remove(0).unwrap()
        };
        assert!(first.stats.warm_started && first.stats.eps_levels >= 2, "full schedule");
        let p2 = assignment(12, 501);
        let items: Vec<(&Problem, &SolveRequest)> = vec![(&p2, &req)];
        let second = pinned.solve_each(&items).remove(0).unwrap();
        assert!(second.stats.arena_reused, "arena persisted across the call");
        assert_eq!(second.stats.eps_levels, 1, "dual carry crosses call boundaries");
        let cert = crate::core::certify::certify(&p2, &second, &req);
        assert!(cert.ok(), "{}", cert.summary());
    }

    #[test]
    fn vector_engine_matches_seq_byte_for_byte() {
        let seq = NativeSeqSolver { paranoid: false, warm_levels: 0 };
        let vec_ = NativeVectorSolver { paranoid: true, warm_levels: 0 };
        for seed in [11u64, 12] {
            let p = assignment(13, seed); // non-multiple-of-8 width
            let req = SolveRequest::new(0.3);
            let a = seq.solve(&p, &req).unwrap();
            let b = vec_.solve(&p, &req).unwrap();
            assert_eq!(a.matching(), b.matching());
            assert_eq!(a.duals, b.duals);
            assert_eq!(a.stats.phases, b.stats.phases);
            assert_eq!(a.stats.rounds, b.stats.rounds);
        }
        let ot = Problem::Ot(Workload::Fig1 { n: 9 }.ot_with_random_masses(5));
        let req = SolveRequest::new(0.25);
        let a = seq.solve(&ot, &req).unwrap();
        let b = vec_.solve(&ot, &req).unwrap();
        assert_eq!(a.plan().unwrap().as_slice(), b.plan().unwrap().as_slice());
        assert_eq!(a.duals, b.duals);
    }

    #[test]
    fn warm_engine_batch_carries_duals_across_same_shape_items() {
        let s = NativeVectorSolver { paranoid: true, warm_levels: 3 };
        let problems: Vec<Problem> = (0..3).map(|i| assignment(12, 200 + i)).collect();
        let req = SolveRequest::new(0.3);
        let items: Vec<(&Problem, &SolveRequest)> = problems.iter().map(|p| (p, &req)).collect();
        let sols: Vec<Solution> = s.solve_each(&items).into_iter().map(|r| r.unwrap()).collect();
        // item 0 runs the full schedule; later items carry duals instead
        assert!(sols[0].stats.warm_started);
        assert!(sols[0].stats.eps_levels >= 2);
        for sol in &sols[1..] {
            assert!(sol.stats.warm_started, "carried items report a warm start");
            assert_eq!(sol.stats.eps_levels, 1, "carry skips the coarse levels");
            assert!(sol.stats.arena_reused);
        }
        // every item is still a valid guaranteed solve
        for (p, sol) in problems.iter().zip(&sols) {
            assert!(sol.matching().unwrap().is_perfect());
            let cert = crate::core::certify::certify(p, sol, &req);
            assert!(cert.ok(), "{}", cert.summary());
        }
        // a shape change falls back to the schedule, not an error
        let bigger = assignment(16, 300);
        let mixed: Vec<(&Problem, &SolveRequest)> = vec![(&problems[0], &req), (&bigger, &req)];
        let out = s.solve_each(&mixed);
        let second = out[1].as_ref().unwrap();
        assert!(second.stats.warm_started);
        assert!(second.stats.eps_levels >= 2, "no carry across shapes — full schedule");
    }

    #[test]
    fn default_solve_each_checks_capability_per_item() {
        let s = AssignmentAdapter(Hungarian);
        let a = assignment(6, 1);
        let ot = Problem::Ot(Workload::Fig1 { n: 5 }.ot_with_random_masses(2));
        let req = SolveRequest::new(0.1);
        let out = s.solve_each(&[(&a, &req), (&ot, &req), (&a, &req)]);
        assert!(out[0].is_ok());
        assert!(out[1].as_ref().unwrap_err().to_string().contains("does not support ot"));
        assert!(out[2].is_ok(), "an unsupported item must not poison the batch");
    }

    #[test]
    fn xla_without_runtime_fails_cleanly() {
        let s = XlaEngineSolver { runtime: None, require_exact_bucket: false };
        let err = s.solve(&assignment(8, 5), &SolveRequest::new(0.3)).unwrap_err();
        assert!(err.to_string().contains("no XLA runtime"));
    }

    #[test]
    fn xla_engines_cancel_like_native_not_with_err() {
        // Same contract as the native engines: a stopped request yields a
        // usable coupling with a "cancelled" note, not a job failure.
        let dir = std::env::temp_dir().join("otpr_adapter_xla_cancel");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"version":2,"sizes":[],"artifacts":[]}"#)
            .unwrap();
        let rt = crate::runtime::XlaRuntime::open(&dir).unwrap();
        let token = CancelToken::new();
        token.cancel();
        let req = SolveRequest::new(0.2).with_cancel(token);

        let s = XlaEngineSolver { runtime: Some(rt.clone()), require_exact_bucket: false };
        let sol = s.solve(&assignment(8, 1), &req).unwrap();
        assert!(sol.is_cancelled());
        assert!(sol.matching().unwrap().is_perfect());

        let s = XlaSinkhornSolver { runtime: Some(rt), max_iters: 10 };
        let sol = s.solve(&assignment(8, 1), &req).unwrap();
        assert!(sol.is_cancelled());
        let plan = sol.plan().unwrap();
        assert!((plan.total_mass() - 1.0).abs() < 1e-9, "product plan stays feasible");
        std::fs::remove_dir_all(&dir).ok();
    }
}
