//! Typed solver registry: the single place where engine names, aliases,
//! capabilities, and construction live.
//!
//! [`ENGINE_SPECS`] is the canonical name table — `coordinator::Engine`
//! parses/prints through it, so an engine name accepted on the CLI, in a
//! job request, or in an experiment config is by construction a key this
//! registry can build. [`SolverRegistry::with_defaults`] attaches a builder
//! closure to every spec; callers may also [`SolverRegistry::register`]
//! their own keys (new backends need one registration, not five call-site
//! edits).

use crate::api::adapter::{
    AssignmentAdapter, LmrSolver, NativeHybridSolver, NativeParallelSolver, NativeSeqSolver,
    NativeVectorSolver, OtAdapter, SinkhornSolver, Solver, XlaEngineSolver, XlaSinkhornSolver,
};
use crate::api::problem::{Problem, ProblemKind, Solution};
use crate::api::request::SolveRequest;
use crate::core::{OtprError, Result};
use crate::runtime::XlaRuntime;
use crate::solvers::greedy::GreedyMatcher;
use crate::solvers::hungarian::Hungarian;
use crate::solvers::ssp_ot::SspExactOt;
use crate::util::pool;
use std::fmt;
use std::sync::Arc;

/// Canonical engine name + aliases + capability flags.
#[derive(Debug, Clone, Copy)]
pub struct EngineSpec {
    pub key: &'static str,
    pub aliases: &'static [&'static str],
    pub assignment: bool,
    pub ot: bool,
    pub doc: &'static str,
}

/// The default engine table. Keys are what [`crate::coordinator::Engine`]
/// round-trips through; aliases keep historical CLI/harness spellings
/// working.
pub const ENGINE_SPECS: &[EngineSpec] = &[
    EngineSpec {
        key: "native-seq",
        aliases: &["native", "seq", "pr", "pr-cpu", "pr-native"],
        assignment: true,
        ot: true,
        doc: "paper §2.2 sequential push-relabel + §4 OT solver (native Rust)",
    },
    EngineSpec {
        key: "native-parallel",
        aliases: &["parallel", "par", "pr-parallel"],
        assignment: true,
        ot: true,
        doc: "propose-accept multi-threaded push-relabel (§3.2)",
    },
    EngineSpec {
        key: "native-vector",
        aliases: &["vector", "simd", "pr-vector"],
        assignment: true,
        ot: true,
        doc: "lane-blocked auto-vectorized propose sweep (results byte-identical to native-seq)",
    },
    EngineSpec {
        key: "native-hybrid",
        aliases: &["hybrid", "pr-hybrid"],
        assignment: true,
        ot: true,
        doc: "lane-blocked propose sweep fanned over threads (vector × chunked; byte-identical to native-seq)",
    },
    EngineSpec {
        key: "native-vector-warm",
        aliases: &["vector-warm"],
        assignment: true,
        ot: true,
        doc: "vector kernel + geometric ε-scaling warm starts and batch dual reuse",
    },
    EngineSpec {
        key: "native-seq-warm",
        aliases: &["warm", "seq-warm"],
        assignment: true,
        ot: true,
        doc: "sequential kernel + geometric ε-scaling warm starts and batch dual reuse",
    },
    EngineSpec {
        key: "xla",
        aliases: &["gpu", "pr-xla", "pr-gpu"],
        assignment: true,
        ot: false,
        doc: "device-resident push-relabel over the AOT XLA artifacts",
    },
    EngineSpec {
        // no "sinkhorn-log" alias: the update rule is a SolverConfig
        // choice, and an alias promising log-domain could silently run
        // the standard kernel.
        key: "sinkhorn-native",
        aliases: &["sinkhorn", "sinkhorn-cpu"],
        assignment: true,
        ot: true,
        doc: "Sinkhorn baseline, AWR'17 additive parameterization (native Rust)",
    },
    EngineSpec {
        key: "sinkhorn-xla",
        aliases: &["sinkhorn-gpu"],
        assignment: true,
        ot: true,
        doc: "Sinkhorn baseline over the XLA artifacts",
    },
    EngineSpec {
        key: "hungarian",
        aliases: &["exact", "hungarian-exact"],
        assignment: true,
        ot: false,
        doc: "exact Hungarian (Jonker-Volgenant) assignment oracle",
    },
    EngineSpec {
        key: "greedy",
        aliases: &[],
        assignment: true,
        ot: false,
        doc: "greedy matching cost/runtime floor (no guarantee)",
    },
    EngineSpec {
        key: "lmr",
        aliases: &["lmr-baseline"],
        assignment: true,
        ot: false,
        doc: "LMR'19 Gabow-Tarjan-style additive baseline (NeurIPS 2019)",
    },
    EngineSpec {
        key: "ssp-exact",
        aliases: &["exact-ot", "ssp"],
        assignment: true,
        ot: true,
        doc: "exact min-cost-flow OT oracle (successive shortest paths)",
    },
];

/// Resolve any engine spelling (key or alias) to its canonical key using
/// the static table. `coordinator::Engine::parse` goes through here.
pub fn canonical_key(name: &str) -> Option<&'static str> {
    ENGINE_SPECS
        .iter()
        .find(|s| s.key == name || s.aliases.contains(&name))
        .map(|s| s.key)
}

/// How the XLA engine maps instance sizes onto fixed-shape artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BucketPolicy {
    /// Pad up to the smallest artifact bucket that fits (default).
    #[default]
    SmallestFit,
    /// Only accept instances whose size is an exact artifact size.
    ExactOnly,
}

/// Typed construction-time configuration shared by every builder.
///
/// Per-request knobs (accuracy, budget, cancellation, observer) live on
/// [`SolveRequest`]; this struct holds what is fixed when a solver is
/// built: resources (threads, XLA runtime), policies, and defaults.
#[derive(Clone)]
pub struct SolverConfig {
    /// Default accuracy target used by [`SolverConfig::request`].
    pub eps: f64,
    /// Threads for the native parallel engine.
    pub threads: usize,
    /// Seed reserved for stochastic engines / tie-breaking experiments.
    pub seed: u64,
    /// Verify solver invariants after every phase (tests, `otpr validate`).
    pub paranoid: bool,
    /// Geometric ε levels the warm-start engines solve (≥ 2; the `*-warm`
    /// engine keys read this, the cold keys ignore it).
    pub warm_levels: u32,
    /// Sinkhorn update rule: log-domain (robust, the service default) vs
    /// standard kernel (faster; underflows at small ε — ablation A5).
    pub sinkhorn_log_domain: bool,
    pub sinkhorn_max_iters: usize,
    /// Loaded PJRT runtime for the XLA engines (`None` ⇒ they fail cleanly).
    pub xla_runtime: Option<Arc<XlaRuntime>>,
    pub bucket_policy: BucketPolicy,
}

impl Default for SolverConfig {
    fn default() -> Self {
        Self {
            eps: 0.1,
            threads: pool::default_threads(),
            seed: 42,
            paranoid: false,
            warm_levels: 3,
            sinkhorn_log_domain: true,
            sinkhorn_max_iters: 100_000,
            xla_runtime: None,
            bucket_policy: BucketPolicy::default(),
        }
    }
}

impl fmt::Debug for SolverConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SolverConfig")
            .field("eps", &self.eps)
            .field("threads", &self.threads)
            .field("seed", &self.seed)
            .field("paranoid", &self.paranoid)
            .field("warm_levels", &self.warm_levels)
            .field("sinkhorn_log_domain", &self.sinkhorn_log_domain)
            .field("sinkhorn_max_iters", &self.sinkhorn_max_iters)
            .field("xla_runtime", &self.xla_runtime.is_some())
            .field("bucket_policy", &self.bucket_policy)
            .finish()
    }
}

impl SolverConfig {
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_runtime(mut self, runtime: Option<Arc<XlaRuntime>>) -> Self {
        self.xla_runtime = runtime;
        self
    }

    pub fn with_paranoid(mut self, paranoid: bool) -> Self {
        self.paranoid = paranoid;
        self
    }

    /// A request at this config's default accuracy.
    pub fn request(&self) -> SolveRequest {
        SolveRequest::new(self.eps)
    }
}

type BuilderFn = Box<dyn Fn(&SolverConfig) -> Box<dyn Solver> + Send + Sync>;

/// One registered engine.
pub struct RegistryEntry {
    pub key: &'static str,
    pub aliases: &'static [&'static str],
    pub assignment: bool,
    pub ot: bool,
    pub doc: &'static str,
    builder: BuilderFn,
}

impl RegistryEntry {
    pub fn supports(&self, kind: ProblemKind) -> bool {
        match kind {
            ProblemKind::Assignment => self.assignment,
            ProblemKind::Ot => self.ot,
        }
    }
}

impl fmt::Debug for RegistryEntry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("RegistryEntry")
            .field("key", &self.key)
            .field("aliases", &self.aliases)
            .field("assignment", &self.assignment)
            .field("ot", &self.ot)
            .finish()
    }
}

/// String key → boxed builder closure registry.
#[derive(Default)]
pub struct SolverRegistry {
    entries: Vec<RegistryEntry>,
}

impl SolverRegistry {
    pub fn empty() -> Self {
        Self::default()
    }

    /// All built-in engines of [`ENGINE_SPECS`] with their default builders.
    pub fn with_defaults() -> Self {
        let mut reg = Self::empty();
        for spec in ENGINE_SPECS {
            reg.register_spec(*spec, default_builder(spec.key));
        }
        reg
    }

    fn register_spec(&mut self, spec: EngineSpec, builder: BuilderFn) {
        self.entries.retain(|e| e.key != spec.key);
        self.entries.push(RegistryEntry {
            key: spec.key,
            aliases: spec.aliases,
            assignment: spec.assignment,
            ot: spec.ot,
            doc: spec.doc,
            builder,
        });
    }

    /// Register (or replace) an engine under `key`.
    pub fn register(
        &mut self,
        spec: EngineSpec,
        builder: impl Fn(&SolverConfig) -> Box<dyn Solver> + Send + Sync + 'static,
    ) {
        self.register_spec(spec, Box::new(builder));
    }

    /// Canonical keys, registration order.
    pub fn keys(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.key).collect()
    }

    /// Resolve a key-or-alias to this registry's canonical key.
    pub fn canonical(&self, name: &str) -> Option<&'static str> {
        self.entry(name).map(|e| e.key)
    }

    pub fn entry(&self, name: &str) -> Option<&RegistryEntry> {
        self.entries.iter().find(|e| e.key == name || e.aliases.contains(&name))
    }

    /// Build the engine registered under `name` (key or alias).
    pub fn build(&self, name: &str, config: &SolverConfig) -> Result<Box<dyn Solver>> {
        let entry = self.entry(name).ok_or_else(|| {
            OtprError::Coordinator(format!(
                "unknown engine {name:?} (registered: {})",
                self.keys().join(", ")
            ))
        })?;
        Ok((entry.builder)(config))
    }

    /// Build + solve in one step, with a capability pre-check so kind
    /// mismatches produce a uniform error before any work happens.
    pub fn solve(
        &self,
        name: &str,
        config: &SolverConfig,
        problem: &Problem,
        req: &SolveRequest,
    ) -> Result<Solution> {
        let entry = self.entry(name).ok_or_else(|| {
            OtprError::Coordinator(format!(
                "unknown engine {name:?} (registered: {})",
                self.keys().join(", ")
            ))
        })?;
        if !entry.supports(problem.kind()) {
            return Err(OtprError::Coordinator(format!(
                "engine {} does not support {} problems",
                entry.key,
                problem.kind().name()
            )));
        }
        let mut sol = (entry.builder)(config).solve(problem, req)?;
        if req.want_certificate {
            sol.certificate = Some(crate::core::certify::certify(problem, &sol, req));
        }
        Ok(sol)
    }

    /// Build the engine **once** and solve every (problem, request) pair
    /// on it, letting kernel-backed engines keep one arena warm across
    /// same-shape items ([`crate::api::adapter::Solver::solve_each`]).
    /// Per-item capability mismatches and solve failures land in that
    /// item's slot; only an unknown engine fails the whole call.
    /// Certificates are attached per item when its request asks.
    pub fn solve_each(
        &self,
        name: &str,
        config: &SolverConfig,
        items: &[(&Problem, &SolveRequest)],
    ) -> Result<Vec<Result<Solution>>> {
        let entry = self.entry(name).ok_or_else(|| {
            OtprError::Coordinator(format!(
                "unknown engine {name:?} (registered: {})",
                self.keys().join(", ")
            ))
        })?;
        let solver = (entry.builder)(config);
        let mut results = solver.solve_each(items);
        for (result, &(problem, req)) in results.iter_mut().zip(items) {
            if let Ok(sol) = result {
                if req.want_certificate {
                    sol.certificate = Some(crate::core::certify::certify(problem, sol, req));
                }
            }
        }
        Ok(results)
    }

    /// First-class batch path: solve `problems` under one shared request
    /// (see [`SolveRequest::solve_many`] for the caller-facing entry).
    /// Same-shape instances reuse one kernel arena; the report counts
    /// the hits so callers (and the coordinator's metrics) can assert
    /// the amortization actually happened.
    pub fn solve_batch(
        &self,
        name: &str,
        config: &SolverConfig,
        problems: &[Problem],
        req: &SolveRequest,
    ) -> Result<BatchReport> {
        let items: Vec<(&Problem, &SolveRequest)> = problems.iter().map(|p| (p, req)).collect();
        let results = self.solve_each(name, config, &items)?;
        Ok(BatchReport::new(results))
    }
}

/// Outcome of one [`SolverRegistry::solve_batch`] call.
#[derive(Debug)]
pub struct BatchReport {
    /// Per-problem outcomes, input order.
    pub results: Vec<Result<Solution>>,
    /// How many solves reused a warm kernel arena (≤ len − 1; equals it
    /// when every instance shares one shape on a kernel-backed engine).
    pub reuse_hits: u64,
}

impl BatchReport {
    fn new(results: Vec<Result<Solution>>) -> Self {
        let reuse_hits = results
            .iter()
            .filter(|r| matches!(r, Ok(s) if s.stats.arena_reused))
            .count() as u64;
        Self { results, reuse_hits }
    }

    /// All solutions, or the first error (convenience for callers that
    /// treat any per-item failure as fatal).
    pub fn into_solutions(self) -> Result<Vec<Solution>> {
        self.results.into_iter().collect()
    }
}

fn default_builder(key: &'static str) -> BuilderFn {
    match key {
        "native-seq" => {
            Box::new(|cfg| Box::new(NativeSeqSolver { paranoid: cfg.paranoid, warm_levels: 0 }))
        }
        "native-seq-warm" => Box::new(|cfg| {
            Box::new(NativeSeqSolver {
                paranoid: cfg.paranoid,
                warm_levels: cfg.warm_levels.max(2),
            })
        }),
        "native-vector" => {
            Box::new(|cfg| Box::new(NativeVectorSolver { paranoid: cfg.paranoid, warm_levels: 0 }))
        }
        "native-vector-warm" => Box::new(|cfg| {
            Box::new(NativeVectorSolver {
                paranoid: cfg.paranoid,
                warm_levels: cfg.warm_levels.max(2),
            })
        }),
        "native-parallel" => Box::new(|cfg| {
            Box::new(NativeParallelSolver { threads: cfg.threads, paranoid: cfg.paranoid })
        }),
        "native-hybrid" => Box::new(|cfg| {
            Box::new(NativeHybridSolver { threads: cfg.threads, paranoid: cfg.paranoid })
        }),
        "xla" => Box::new(|cfg| {
            Box::new(XlaEngineSolver {
                runtime: cfg.xla_runtime.clone(),
                require_exact_bucket: cfg.bucket_policy == BucketPolicy::ExactOnly,
            })
        }),
        "sinkhorn-native" => Box::new(|cfg| {
            Box::new(SinkhornSolver {
                log_domain: cfg.sinkhorn_log_domain,
                max_iters: cfg.sinkhorn_max_iters,
            })
        }),
        "sinkhorn-xla" => Box::new(|cfg| {
            Box::new(XlaSinkhornSolver {
                runtime: cfg.xla_runtime.clone(),
                max_iters: cfg.sinkhorn_max_iters,
            })
        }),
        "hungarian" => Box::new(|_| Box::new(AssignmentAdapter(Hungarian))),
        "greedy" => Box::new(|_| Box::new(AssignmentAdapter(GreedyMatcher))),
        "lmr" => Box::new(|_| Box::new(LmrSolver)),
        "ssp-exact" => Box::new(|_| Box::new(OtAdapter(SspExactOt::default()))),
        // panic-ok: the match arms mirror ENGINE_SPECS; a missing builder is
        // a compile-time drift bug the registry self-test pins, not input
        other => unreachable!("no default builder for engine key {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;

    #[test]
    fn keys_and_aliases_resolve_uniquely() {
        let mut seen: Vec<&str> = Vec::new();
        for spec in ENGINE_SPECS {
            assert_eq!(canonical_key(spec.key), Some(spec.key), "key must resolve to itself");
            assert!(!seen.contains(&spec.key), "duplicate key {}", spec.key);
            seen.push(spec.key);
            for alias in spec.aliases {
                assert_eq!(canonical_key(alias), Some(spec.key), "alias {alias}");
                assert!(!seen.contains(alias), "alias {alias} collides");
                seen.push(alias);
            }
        }
        assert_eq!(canonical_key("bogus"), None);
    }

    #[test]
    fn defaults_cover_every_spec() {
        let reg = SolverRegistry::with_defaults();
        assert_eq!(reg.keys().len(), ENGINE_SPECS.len());
        let cfg = SolverConfig::default();
        for spec in ENGINE_SPECS {
            let solver = reg.build(spec.key, &cfg).unwrap();
            assert_eq!(
                solver.supports(ProblemKind::Assignment),
                spec.assignment,
                "{} assignment capability",
                spec.key
            );
            assert_eq!(solver.supports(ProblemKind::Ot), spec.ot, "{} ot capability", spec.key);
        }
    }

    #[test]
    fn solve_through_registry_both_kinds() {
        let reg = SolverRegistry::with_defaults();
        let cfg = SolverConfig::default();
        let p = Problem::Assignment(Workload::RandomCosts { n: 10 }.assignment(1));
        // cfg.request() solves at the config's default accuracy target
        let sol = reg.solve("native-seq", &cfg, &p, &cfg.request()).unwrap();
        assert!(sol.matching().unwrap().is_perfect());
        let exact = reg.solve("hungarian", &cfg, &p, &SolveRequest::new(0.0)).unwrap();
        assert!(sol.cost >= exact.cost - 1e-9);

        let ot = Problem::Ot(Workload::Fig1 { n: 8 }.ot_with_random_masses(2));
        let sol = reg.solve("native-seq", &cfg, &ot, &SolveRequest::new(0.3)).unwrap();
        assert!((sol.plan().unwrap().total_mass() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn certified_requests_attach_certificates() {
        let reg = SolverRegistry::with_defaults();
        let cfg = SolverConfig::default();
        let p = Problem::Assignment(Workload::RandomCosts { n: 10 }.assignment(4));
        let sol = reg
            .solve("native-seq", &cfg, &p, &SolveRequest::new(0.3).certify(true))
            .unwrap();
        let cert = sol.certificate.as_ref().expect("certificate attached");
        assert!(cert.ok(), "{}", cert.summary());
        assert_eq!(cert.dual_ok, Some(true));
        let sol = reg.solve("native-seq", &cfg, &p, &SolveRequest::new(0.3)).unwrap();
        assert!(sol.certificate.is_none(), "no certificate unless requested");

        // OT plan path: duals now flow through and certify too.
        let ot = Problem::Ot(Workload::Fig1 { n: 8 }.ot_with_random_masses(2));
        let sol = reg
            .solve("native-seq", &cfg, &ot, &SolveRequest::new(0.25).certify(true))
            .unwrap();
        let cert = sol.certificate.as_ref().unwrap();
        assert_eq!(cert.dual_ok, Some(true), "{}", cert.summary());
        assert!(cert.gap_ok());
    }

    #[test]
    fn aliases_build_and_kind_mismatch_is_caught() {
        let reg = SolverRegistry::with_defaults();
        let cfg = SolverConfig::default();
        assert_eq!(reg.canonical("pr-cpu"), Some("native-seq"));
        assert_eq!(reg.canonical("gpu"), Some("xla"));
        let ot = Problem::Ot(Workload::Fig1 { n: 6 }.ot_with_random_masses(1));
        let err = reg.solve("hungarian", &cfg, &ot, &SolveRequest::new(0.1)).unwrap_err();
        assert!(err.to_string().contains("does not support ot"));
        assert!(reg.build("nope", &cfg).is_err());
    }

    #[test]
    fn solve_batch_reuses_arena_and_matches_single_solves() {
        let reg = SolverRegistry::with_defaults();
        let cfg = SolverConfig::default();
        let problems: Vec<Problem> = (0..8)
            .map(|i| Problem::Assignment(Workload::RandomCosts { n: 12 }.assignment(i)))
            .collect();
        let req = crate::api::SolveRequest::new(0.3);
        let report = reg.solve_batch("native-seq", &cfg, &problems, &req).unwrap();
        assert_eq!(report.results.len(), 8);
        assert_eq!(report.reuse_hits, 7, "8 same-shape instances share one arena");
        for (p, r) in problems.iter().zip(&report.results) {
            let batched = r.as_ref().unwrap();
            let single = reg.solve("native-seq", &cfg, p, &req).unwrap();
            assert_eq!(single.matching(), batched.matching());
            assert!((single.cost - batched.cost).abs() < 1e-12);
        }
        // certificates attach per item when requested
        let report = reg
            .solve_batch("native-seq", &cfg, &problems[..2], &req.clone().certify(true))
            .unwrap();
        for r in &report.results {
            assert!(r.as_ref().unwrap().certificate.as_ref().unwrap().ok());
        }
        // unknown engine fails the call; per-item capability errors don't
        assert!(reg.solve_batch("nope", &cfg, &problems, &req).is_err());
        let ot = Problem::Ot(Workload::Fig1 { n: 6 }.ot_with_random_masses(1));
        let mixed = vec![problems[0].clone(), ot];
        let report = reg.solve_batch("hungarian", &cfg, &mixed, &req).unwrap();
        assert!(report.results[0].is_ok());
        assert!(report.results[1].is_err());
    }

    #[test]
    fn vector_and_warm_engines_resolve_and_hold_their_contracts() {
        let reg = SolverRegistry::with_defaults();
        let cfg = SolverConfig::default();
        assert_eq!(reg.canonical("vector"), Some("native-vector"));
        assert_eq!(reg.canonical("simd"), Some("native-vector"));
        assert_eq!(reg.canonical("warm"), Some("native-seq-warm"));
        assert_eq!(reg.canonical("vector-warm"), Some("native-vector-warm"));
        let p = Problem::Assignment(Workload::RandomCosts { n: 11 }.assignment(7));
        let req = SolveRequest::new(0.3);
        // the vector backend is byte-identical to the scalar one
        let seq = reg.solve("native-seq", &cfg, &p, &req).unwrap();
        let vec_ = reg.solve("vector", &cfg, &p, &req).unwrap();
        assert_eq!(seq.matching(), vec_.matching());
        assert_eq!(seq.duals, vec_.duals);
        assert!(!vec_.stats.warm_started);
        // the warm engines certify like the cold ones
        for engine in ["native-seq-warm", "native-vector-warm"] {
            let warm = reg.solve(engine, &cfg, &p, &req.clone().certify(true)).unwrap();
            assert!(warm.stats.warm_started, "{engine}");
            assert!(warm.stats.eps_levels >= 2, "{engine}");
            let cert = warm.certificate.as_ref().unwrap();
            assert!(cert.ok(), "{engine}: {}", cert.summary());
        }
    }

    #[test]
    fn custom_registration_replaces_and_extends() {
        let mut reg = SolverRegistry::with_defaults();
        let n_before = reg.keys().len();
        reg.register(
            EngineSpec {
                key: "greedy",
                aliases: &["floor"],
                assignment: true,
                ot: false,
                doc: "re-registered",
            },
            |_| Box::new(AssignmentAdapter(GreedyMatcher)),
        );
        assert_eq!(reg.keys().len(), n_before, "re-registration replaces");
        assert_eq!(reg.canonical("floor"), Some("greedy"));
    }
}
