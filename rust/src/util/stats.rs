//! Small statistics helpers shared by the bench harness and the experiment
//! reports: robust summary statistics and a least-squares line fit used for
//! empirical scaling checks (e.g. verifying phase counts grow like 1/ε²).

/// Summary statistics over a sample of f64 measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Percentile (linear interpolation) of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Ordinary least squares fit y = a + b·x. Returns (intercept, slope, r²).
#[allow(clippy::float_cmp)] // exact-zero degenerate-fit guard, annotated inline
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    // float-eq-ok: syy is a sum of squares; exact 0 means constant ys
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (intercept, slope, r2)
}

/// Fit y ≈ c·x^k on log-log axes; returns (c, k, r²). Used to check
/// empirical complexity exponents against the paper's bounds.
pub fn power_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let (a, k, r2) = linear_fit(&lx, &ly);
    (a.exp(), k, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_singleton() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 95.0) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_exact() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0]; // y = 1 + 2x
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn power_fit_quadratic() {
        let xs: Vec<f64> = (1..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x * x).collect();
        let (c, k, r2) = power_fit(&xs, &ys);
        assert!((c - 3.0).abs() < 1e-6);
        assert!((k - 2.0).abs() < 1e-9);
        assert!(r2 > 0.999);
    }
}
