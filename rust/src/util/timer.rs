//! Monotonic timing utilities: a stopwatch, a scoped-section profiler used by
//! the performance pass, and human-friendly duration formatting.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Simple stopwatch around `Instant`.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Format a duration compactly: `1.23s`, `45.6ms`, `789µs`.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.0}µs", s * 1e6)
    }
}

/// Accumulating section profiler. Cheap enough to leave in the hot path
/// behind names; used by the §Perf pass to attribute per-phase time.
#[derive(Debug, Default)]
pub struct SectionProfiler {
    // name -> (total_secs, calls)
    sections: Mutex<BTreeMap<&'static str, (f64, u64)>>,
}

impl SectionProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` and attribute it to `name`.
    pub fn scope<T>(&self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(name, t.elapsed().as_secs_f64());
        out
    }

    pub fn add(&self, name: &'static str, secs: f64) {
        let mut map = self.sections.lock().unwrap();
        let e = map.entry(name).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += 1;
    }

    /// Snapshot: (name, total_secs, calls), sorted by descending total.
    pub fn snapshot(&self) -> Vec<(&'static str, f64, u64)> {
        let map = self.sections.lock().unwrap();
        let mut v: Vec<_> = map.iter().map(|(k, (s, c))| (*k, *s, *c)).collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }

    pub fn report(&self) -> String {
        let snap = self.snapshot();
        let total: f64 = snap.iter().map(|(_, s, _)| s).sum();
        let mut out = String::from("section                        total      calls   share\n");
        for (name, secs, calls) in snap {
            let share = if total > 0.0 { 100.0 * secs / total } else { 0.0 };
            out.push_str(&format!("{name:<28} {secs:>9.4}s {calls:>8}  {share:>5.1}%\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed_secs() >= 0.004);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000s");
        assert_eq!(fmt_duration(Duration::from_millis(45)), "45.00ms");
        assert_eq!(fmt_duration(Duration::from_micros(789)), "789µs");
    }

    #[test]
    fn profiler_accumulates() {
        let p = SectionProfiler::new();
        for _ in 0..3 {
            p.scope("a", || std::thread::sleep(Duration::from_millis(1)));
        }
        p.scope("b", || ());
        let snap = p.snapshot();
        assert_eq!(snap.len(), 2);
        let a = snap.iter().find(|(n, _, _)| *n == "a").unwrap();
        assert_eq!(a.2, 3);
        assert!(a.1 >= 0.002);
        assert!(p.report().contains("a"));
    }
}
