//! Benchmark harness (criterion is unavailable offline). Provides warmup +
//! sampled timing with robust statistics and table output shared by all
//! `rust/benches/*.rs` (which are `harness = false` binaries).

use super::stats::Summary;
use std::time::Instant;

/// Configuration for a benchmark run. Environment overrides let CI run fast
/// while `--reps`-style flags reproduce the paper's 30-run averages.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: usize,
    pub samples: usize,
    /// Hard wall-clock cap per measurement in seconds; sampling stops early
    /// once exceeded (slow configs still report with fewer samples).
    pub max_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup: 1, samples: 5, max_secs: 60.0 }
    }
}

impl BenchConfig {
    pub fn quick() -> Self {
        Self { warmup: 0, samples: 2, max_secs: 20.0 }
    }

    /// Read OTPR_BENCH_SAMPLES / OTPR_BENCH_MAXSECS overrides.
    pub fn from_env() -> Self {
        let mut cfg = Self::default();
        if let Ok(v) = std::env::var("OTPR_BENCH_SAMPLES") {
            if let Ok(n) = v.parse() {
                cfg.samples = n;
            }
        }
        if let Ok(v) = std::env::var("OTPR_BENCH_MAXSECS") {
            if let Ok(s) = v.parse() {
                cfg.max_secs = s;
            }
        }
        cfg
    }
}

/// One measured benchmark row.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    /// Free-form extra columns (e.g. phases, error) from the last run.
    pub extras: Vec<(String, String)>,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.summary.mean
    }
}

/// Run `f` under the config; `f` returns optional extra columns.
pub fn run_bench<F>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult
where
    F: FnMut() -> Vec<(String, String)>,
{
    for _ in 0..cfg.warmup {
        let t = Instant::now();
        let _ = f();
        if t.elapsed().as_secs_f64() > cfg.max_secs {
            break; // too slow to warm further
        }
    }
    let mut times = Vec::with_capacity(cfg.samples);
    let mut extras = Vec::new();
    let wall = Instant::now();
    for _ in 0..cfg.samples.max(1) {
        let t = Instant::now();
        extras = f();
        times.push(t.elapsed().as_secs_f64());
        if wall.elapsed().as_secs_f64() > cfg.max_secs {
            break;
        }
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&times), extras }
}

/// Render results as a markdown table (also CSV via `to_csv`).
pub fn to_markdown(results: &[BenchResult]) -> String {
    let mut extra_keys: Vec<String> = Vec::new();
    for r in results {
        for (k, _) in &r.extras {
            if !extra_keys.contains(k) {
                extra_keys.push(k.clone());
            }
        }
    }
    let mut out = String::new();
    out.push_str("| name | mean | median | stddev | n |");
    for k in &extra_keys {
        out.push_str(&format!(" {k} |"));
    }
    out.push('\n');
    out.push_str("|---|---|---|---|---|");
    for _ in &extra_keys {
        out.push_str("---|");
    }
    out.push('\n');
    for r in results {
        out.push_str(&format!(
            "| {} | {:.4}s | {:.4}s | {:.4}s | {} |",
            r.name, r.summary.mean, r.summary.median, r.summary.stddev, r.summary.n
        ));
        for k in &extra_keys {
            let v = r
                .extras
                .iter()
                .find(|(ek, _)| ek == k)
                .map(|(_, v)| v.as_str())
                .unwrap_or("-");
            out.push_str(&format!(" {v} |"));
        }
        out.push('\n');
    }
    out
}

pub fn to_csv(results: &[BenchResult]) -> String {
    let mut out = String::from("name,mean_s,median_s,stddev_s,samples\n");
    for r in results {
        out.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{}\n",
            r.name, r.summary.mean, r.summary.median, r.summary.stddev, r.summary.n
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let cfg = BenchConfig { warmup: 1, samples: 3, max_secs: 10.0 };
        let mut calls = 0;
        let r = run_bench("noop", &cfg, || {
            calls += 1;
            vec![("k".into(), "v".into())]
        });
        assert_eq!(calls, 4); // 1 warmup + 3 samples
        assert_eq!(r.summary.n, 3);
        assert_eq!(r.extras[0].1, "v");
    }

    #[test]
    fn respects_time_cap() {
        let cfg = BenchConfig { warmup: 0, samples: 1000, max_secs: 0.05 };
        let r = run_bench("sleepy", &cfg, || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            vec![]
        });
        assert!(r.summary.n < 10, "cap should stop sampling early, n={}", r.summary.n);
    }

    #[test]
    fn tables_render() {
        let cfg = BenchConfig::quick();
        let r = run_bench("x", &cfg, Vec::new);
        let md = to_markdown(&[r.clone()]);
        assert!(md.contains("| x |"));
        let csv = to_csv(&[r]);
        assert!(csv.starts_with("name,"));
        assert!(csv.lines().count() == 2);
    }
}
