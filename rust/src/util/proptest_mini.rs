//! Property-based testing mini-framework (proptest is unavailable offline).
//! Runs a property over many seeded random cases and reports the failing
//! seed so a counterexample is reproducible with `case_from_seed`.

use super::rng::Pcg32;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // OTPR_PROP_CASES trims CI time; seed override reproduces failures.
        let cases = std::env::var("OTPR_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(48);
        let seed =
            std::env::var("OTPR_PROP_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(0xC0FFEE);
        Self { cases, seed }
    }
}

/// Run `prop` over `cfg.cases` independently-seeded RNGs. The property
/// returns `Err(message)` to fail. Panics with the case seed on failure.
pub fn check<F>(name: &str, cfg: &PropConfig, mut prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Pcg32::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{} (OTPR_PROP_SEED base {}, case seed {case_seed}):\n  {msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Shorthand with default config.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    check(name, &PropConfig::default(), prop)
}

/// Assert helper producing property-style errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check_default("u32 in range", |rng| {
            let x = rng.next_below(100);
            prop_assert!(x < 100, "x={x} out of range");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failures() {
        check("always fails", &PropConfig { cases: 3, seed: 1 }, |_| Err("nope".into()));
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u32> = Vec::new();
        check("collect", &PropConfig { cases: 5, seed: 9 }, |rng| {
            first.push(rng.next_u32());
            Ok(())
        });
        let mut second: Vec<u32> = Vec::new();
        check("collect2", &PropConfig { cases: 5, seed: 9 }, |rng| {
            second.push(rng.next_u32());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
