//! Deterministic pseudo-random number generation.
//!
//! The offline registry has no `rand` crate, so we implement the two small
//! generators the library needs: [`SplitMix64`] (seed expansion, O(1) state)
//! and [`Pcg32`] (the main workhorse; PCG-XSH-RR 64/32). Both are
//! deterministic across platforms, which the experiment harness relies on for
//! reproducible workloads (`fig1 --seed 7` always builds the same instance).

/// SplitMix64: tiny, fast, passes BigCrush; used to expand user seeds into
/// full generator state and for cheap one-off streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: small-state generator with good statistical quality.
/// `stream` selects one of 2^63 independent sequences, so parallel workers
/// can derive non-overlapping generators from a shared seed.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(sm.next_u64());
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 32 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        self.next_u32() as f64 * (1.0 / 4_294_967_296.0)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / 16_777_216.0)
    }

    /// Unbiased uniform integer in [0, bound) (Lemire rejection).
    pub fn next_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(bound as u64);
            let lo = m as u32;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (one value per call; simple, adequate
    /// for workload generation).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-12 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg32::with_stream(1, 1);
        let mut b = Pcg32::with_stream(1, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be effectively independent");
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut rng = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg32::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        let mut any_low = false;
        let mut any_high = false;
        let mut r2 = Pcg32::new(9);
        for _ in 0..n {
            let v = r2.next_f64();
            assert!((0.0..1.0).contains(&v));
            any_low |= v < 0.1;
            any_high |= v > 0.9;
        }
        assert!(any_low && any_high);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(11);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::new(5);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
        assert!(t.iter().all(|&i| i < 50));
    }
}
