//! Leveled stderr logging (no `log`/`env_logger` offline). Level is read
//! once from `OTPR_LOG` (error|warn|info|debug|trace; default info).

use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: OnceLock<Level> = OnceLock::new();

pub fn level() -> Level {
    *LEVEL.get_or_init(|| match std::env::var("OTPR_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    })
}

pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

pub fn log(lvl: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        eprintln!("[{} {}] {}", lvl.tag(), module, msg);
    }
}

#[macro_export]
macro_rules! log_info {
    ($($fmt:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($fmt)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($fmt:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($fmt)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($fmt:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($fmt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info <= Level::Info);
    }

    #[test]
    fn tags() {
        assert_eq!(Level::Error.tag(), "ERROR");
        assert_eq!(Level::Debug.tag(), "DEBUG");
    }

    #[test]
    fn log_does_not_panic() {
        log(Level::Info, "test", format_args!("hello {}", 1));
        log(Level::Trace, "test", format_args!("filtered"));
    }
}
