//! Hand-rolled command-line parsing (clap is unavailable offline).
//! Supports `program SUBCOMMAND --key value --flag positional...` with typed
//! accessors and helpful errors.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Result<Self, String> {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        // first non-dash token is the subcommand
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    // `--` terminator: rest are positionals
                    args.positionals.extend(it);
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else {
                    // value if next token exists and isn't another option
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            args.opts.insert(name.to_string(), v);
                        }
                        _ => args.flags.push(name.to_string()),
                    }
                }
            } else if tok.starts_with('-') && tok.len() > 1 {
                return Err(format!("short options not supported: {tok}"));
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    pub fn parse_env() -> Result<Self, String> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("invalid value for --{name}: {v}")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get_parsed(name).ok().flatten().unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get_parsed(name).ok().flatten().unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get_parsed(name).ok().flatten().unwrap_or(default)
    }

    /// Parse a comma-separated list option, e.g. `--eps 0.1,0.01`.
    pub fn list_f64(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
        }
    }

    pub fn list_usize(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        // note: a bare token right after `--flag` would be taken as its
        // value, so positionals go before trailing flags
        let a = parse("fig1 --n 1000 --eps 0.1,0.01 file.csv --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("fig1"));
        assert_eq!(a.usize_or("n", 0), 1000);
        assert_eq!(a.list_f64("eps", &[]), vec![0.1, 0.01]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["file.csv"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("solve --seed=42 --out=x.json");
        assert_eq!(a.u64_or("seed", 0), 42);
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("serve --quiet");
        assert!(a.flag("quiet"));
        assert_eq!(a.get("quiet"), None);
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse("run --x 1 -- --not-an-option");
        assert_eq!(a.positionals, vec!["--not-an-option"]);
    }

    #[test]
    fn bad_parse_reported() {
        let a = parse("solve --n abc");
        assert!(a.get_parsed::<usize>("n").is_err());
        assert!(Args::parse_from(vec!["-x".to_string()]).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.f64_or("eps", 0.25), 0.25);
        assert_eq!(a.get_or("mode", "native"), "native");
        assert_eq!(a.list_usize("sizes", &[1, 2]), vec![1, 2]);
    }
}
