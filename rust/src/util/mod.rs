//! From-scratch substrate modules. The offline registry contains only the
//! `xla` crate's dependency closure, so the usual ecosystem crates (clap,
//! rayon, criterion, rand, serde_json, proptest, log) are re-implemented
//! here at the scale this library needs.

pub mod bench;
pub mod cli;
pub mod logging;
pub mod minijson;
pub mod pool;
pub mod proptest_mini;
pub mod rng;
pub mod stats;
pub mod timer;
