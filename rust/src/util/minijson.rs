//! Minimal JSON support (no serde offline): a value model, a strict-enough
//! parser for the artifact manifest written by `python/compile/aot.py`, and a
//! writer used by the experiment reports.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at offset {}", p.pos));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    #[allow(clippy::float_cmp)] // integral-f64 detection below, annotated inline
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                // float-eq-ok: fract() returns exactly 0.0 for integral f64s
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at offset {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected character at offset {}", self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| "invalid utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at offset {start}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at offset {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at offset {}", self.pos)),
            }
        }
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{
            "version": 1,
            "artifacts": [
                {"name": "phase_step", "n": 256, "file": "phase_step_256.hlo.txt",
                 "inputs": ["cq", "ya"], "ok": true, "pad": null}
            ]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("phase_step"));
        assert_eq!(arts[0].get("n").unwrap().as_usize(), Some(256));
        assert_eq!(arts[0].get("pad"), Some(&Json::Null));
        // round-trip through Display
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn escapes_strings() {
        let v = Json::Str("a\"b\\c\nd".to_string());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str(), Some("A"));
    }
}
