//! A tiny data-parallel layer over `std::thread::scope`, standing in for
//! `rayon` (unavailable offline). The parallel push-relabel solver and the
//! coordinator's worker pool are built on these primitives.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default: `OTPR_THREADS` env override,
/// otherwise available parallelism, capped at 16.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("OTPR_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Run `f(chunk_index, range)` over `n` items split into contiguous chunks,
/// one per thread. `f` runs on scoped threads and may borrow from the caller.
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n == 0 {
        f(0, 0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(t, lo..hi));
        }
    });
}

/// Dynamic work-stealing style map: items are claimed one-by-one from a
/// shared atomic counter, which balances irregular per-item cost (e.g. one
/// OT job per request in the coordinator tests).
pub fn parallel_for_each<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n == 0 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel map collecting results in order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for_each(n, threads, |i| {
            **slots[i].lock().unwrap() = f(i);
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_all_indices() {
        let hits = AtomicU64::new(0);
        parallel_chunks(1000, 4, |_, range| {
            for _ in range {
                hits.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn chunks_single_thread_path() {
        let mut seen = vec![false; 10];
        let cell = std::sync::Mutex::new(&mut seen);
        parallel_chunks(10, 1, |_, range| {
            let mut s = cell.lock().unwrap();
            for i in range {
                s[i] = true;
            }
        });
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn for_each_visits_every_index_once() {
        let counts: Vec<AtomicU64> = (0..257).map(|_| AtomicU64::new(0)).collect();
        parallel_for_each(257, 8, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn zero_items_is_fine() {
        parallel_for_each(0, 4, |_| panic!("should not run"));
        parallel_chunks(0, 4, |_, r| assert!(r.is_empty()));
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
