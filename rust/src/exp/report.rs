//! Table/series formatting shared by `otpr fig1|fig2|ablation`, the bench
//! binaries, and EXPERIMENTS.md generation.

/// One plotted series: label + (x, y) points with optional annotations.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<SeriesPoint>,
}

#[derive(Debug, Clone)]
pub struct SeriesPoint {
    pub x: f64,
    pub y: f64,
    pub note: Option<String>,
}

impl Series {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push(SeriesPoint { x, y, note: None });
    }

    pub fn push_note(&mut self, x: f64, y: f64, note: impl Into<String>) {
        self.points.push(SeriesPoint { x, y, note: Some(note.into()) });
    }
}

/// Render aligned series as a markdown table: first column = x, one column
/// per series (paper-figure style: "runtime vs n, one line per algorithm").
pub fn figure_table(title: &str, x_label: &str, series: &[Series]) -> String {
    let mut xs: Vec<f64> = series.iter().flat_map(|s| s.points.iter().map(|p| p.x)).collect();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs.dedup();
    let mut out = format!("## {title}\n\n| {x_label} |");
    for s in series {
        out.push_str(&format!(" {} |", s.label));
    }
    out.push_str("\n|---|");
    for _ in series {
        out.push_str("---|");
    }
    out.push('\n');
    for &x in &xs {
        out.push_str(&format!("| {} |", fmt_x(x)));
        for s in series {
            match s.points.iter().find(|p| p.x == x) {
                Some(p) => {
                    let mut cell = format!("{:.4}", p.y);
                    if let Some(n) = &p.note {
                        cell.push_str(&format!(" ({n})"));
                    }
                    out.push_str(&format!(" {cell} |"));
                }
                None => out.push_str(" - |"),
            }
        }
        out.push('\n');
    }
    out
}

/// CSV form of the same data (one row per (series, point)).
pub fn figure_csv(x_label: &str, series: &[Series]) -> String {
    let mut out = format!("series,{x_label},value,note\n");
    for s in series {
        for p in &s.points {
            out.push_str(&format!(
                "{},{},{:.6},{}\n",
                s.label,
                fmt_x(p.x),
                p.y,
                p.note.as_deref().unwrap_or("")
            ));
        }
    }
    out
}

#[allow(clippy::float_cmp)]
fn fmt_x(x: f64) -> String {
    // float-eq-ok: fract() returns exactly 0.0 for integral f64s
    if x.fract() == 0.0 && x.abs() < 1e12 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut a = Series::new("pr-cpu");
        a.push(500.0, 0.1);
        a.push(1000.0, 0.4);
        let mut b = Series::new("sinkhorn");
        b.push(1000.0, 0.9);
        b.push_note(500.0, 0.2, "diverged");
        let t = figure_table("Figure 1 (eps=0.1)", "n", &[a.clone(), b.clone()]);
        assert!(t.contains("| n | pr-cpu | sinkhorn |"));
        assert!(t.contains("| 500 | 0.1000 | 0.2000 (diverged) |"));
        assert!(t.contains("| 1000 | 0.4000 | 0.9000 |"));
        let csv = figure_csv("n", &[a, b]);
        assert!(csv.contains("pr-cpu,500,0.100000,"));
        assert!(csv.contains("sinkhorn,500,0.200000,diverged"));
    }

    #[test]
    fn missing_points_render_dash() {
        let mut a = Series::new("x");
        a.push(1.0, 2.0);
        let b = Series::new("y");
        let t = figure_table("t", "n", &[a, b]);
        assert!(t.contains("| 1 | 2.0000 | - |"));
    }
}
