//! `otpr analyze` — the in-tree static-analysis pass (zero dependencies).
//!
//! Walks `rust/src/**` and enforces the repo-specific rules clippy cannot
//! express, all centered on the kernel's correctness contracts:
//!
//! * `safety-comment` — every `unsafe` carries a `// SAFETY:` comment;
//! * `kernel-cast` — no bare narrowing `as` casts in `core/kernel/**`,
//!   `core/quantize.rs`, or `core/transport.rs` (truncation at large `n`
//!   silently corrupts slot indices and CSR column ids); use the checked
//!   helpers or annotate `// cast-ok: <reason>`;
//! * `float-eq` — no `f64`/`f32` `==`/`!=` outside annotated
//!   exact-replication sites (`// float-eq-ok: <reason>`);
//! * `no-panic` — no `unwrap`/`expect`/`panic!` family in library solve
//!   paths (`api`, `core`, `solvers`, `coordinator`, `runtime`, `data`);
//!   CLI, `exp`, `util`, tests, and benches are exempt; annotate
//!   `// panic-ok: <reason>` where a panic is the documented contract;
//! * `error-convention` — eps validation messages name their cost source
//!   (`provider=...`), the PR-5 diagnostics convention;
//! * `contract-marker` — the byte-identity tripwire: any function in
//!   `core/kernel/{arena,scalar,chunked,vector,hybrid}.rs` that stages or
//!   commits against the active worklist must carry a
//!   `// CONTRACT: round-structured accept order` marker, so a refactor
//!   that breaks determinism fails this gate instead of the golden suite
//!   several PRs later. A second marker guards the sparse-plan path: any
//!   function in `core/kernel/arena.rs` or `core/transport.rs` that
//!   builds or emits CSR plan data must carry a
//!   `// CONTRACT: sparse extraction order == dense fold order` marker —
//!   CSR entries must be visited (b asc, a asc) or the compact plan's
//!   cost/marginal folds silently drift from their dense twin.
//!
//! Findings can be suppressed through `rust/analyze-allow.toml`
//! (`[[allow]]` entries; a reason is mandatory, unused entries are flagged
//! as `stale-allow`), so the gate blocks from day one. Source views are
//! computed by a small classifier that strips comments and string-literal
//! contents, and `#[cfg(test)]` modules are skipped entirely.

use crate::util::minijson::{obj, Json};
use std::fs;
use std::path::{Path, PathBuf};

/// The marker the byte-identity tripwire requires.
pub const CONTRACT_MARKER: &str = "CONTRACT: round-structured accept order";

/// Body tokens that mean a function stages into or commits against the
/// round-structured active worklist (see `core/kernel/arena.rs`).
const CONTRACT_TRIGGERS: [&str; 4] =
    ["accept_one(", "sequential_sweep(", "vector_sweep", "hybrid_sweep"];

/// The marker the sparse-plan byte-identity tripwire requires: CSR
/// extraction and assembly must visit entries in the dense row-major
/// fold order (b ascending, a ascending), or `TransportPlan::cost` and
/// the certificates silently drift from the dense twin.
pub const SPARSE_CONTRACT_MARKER: &str = "CONTRACT: sparse extraction order == dense fold order";

/// Body tokens that mean a function builds or emits CSR plan data
/// (see `core/kernel/arena.rs` and `core/transport.rs`).
const SPARSE_CONTRACT_TRIGGERS: [&str; 2] = ["extract_plan_sparse(", "from_csr("];

/// Cast targets the kernel-cast rule rejects: the narrowing or
/// sign-changing targets plus `f32` (lossy), including `usize` so index
/// conversions go through the typed `idx()` helper. Casts to
/// `i64`/`u64`/`f64` stay allowed — they are widening (or exact) for
/// every value the kernel produces.
const CAST_TARGETS: [&str; 8] = ["u8", "u16", "u32", "usize", "i8", "i16", "i32", "f32"];

const PANIC_TOKENS: [&str; 6] =
    [".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub rule: &'static str,
    /// Path relative to the analyzed root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
    /// The trimmed offending source line (allowlist patterns match on it).
    pub snippet: String,
}

/// One `[[allow]]` entry from `analyze-allow.toml`.
#[derive(Debug, Clone, Default)]
pub struct AllowEntry {
    pub rule: String,
    pub file: String,
    /// Substring of the offending line; empty = any line in `file`.
    pub pattern: String,
    pub reason: String,
    /// 1-based line of the entry in the allowlist file (for diagnostics).
    pub line: usize,
}

impl AllowEntry {
    fn matches(&self, f: &Finding) -> bool {
        self.rule == f.rule
            && self.file == f.file
            && (self.pattern.is_empty() || f.snippet.contains(&self.pattern))
    }
}

/// The committed suppression list (TOML subset: `[[allow]]` tables with
/// `key = "value"` pairs and `#` comment lines).
#[derive(Debug, Clone, Default)]
pub struct Allowlist {
    pub entries: Vec<AllowEntry>,
}

impl Allowlist {
    pub fn empty() -> Self {
        Self::default()
    }

    pub fn parse(text: &str) -> Result<Self, String> {
        let mut entries: Vec<AllowEntry> = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line == "[[allow]]" {
                entries.push(AllowEntry { line: i + 1, ..AllowEntry::default() });
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return Err(format!("allowlist line {}: expected `key = \"value\"`", i + 1));
            };
            let value = value.trim();
            let value = value
                .strip_prefix('"')
                .and_then(|v| v.strip_suffix('"'))
                .ok_or_else(|| format!("allowlist line {}: value must be quoted", i + 1))?;
            let Some(entry) = entries.last_mut() else {
                return Err(format!("allowlist line {}: key before any [[allow]]", i + 1));
            };
            match key.trim() {
                "rule" => entry.rule = value.to_string(),
                "file" => entry.file = value.to_string(),
                "pattern" => entry.pattern = value.to_string(),
                "reason" => entry.reason = value.to_string(),
                other => return Err(format!("allowlist line {}: unknown key {other}", i + 1)),
            }
        }
        Ok(Self { entries })
    }
}

/// Result of one analyzer run.
#[derive(Debug, Clone)]
pub struct Report {
    pub files: usize,
    pub findings: Vec<Finding>,
    pub suppressed: usize,
}

impl Report {
    pub fn table(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "  {:<20} {}:{}  {}\n      {}\n",
                f.rule, f.file, f.line, f.message, f.snippet
            ));
        }
        out.push_str(&format!(
            "analyzed {} file(s): {} finding(s), {} suppressed by the allowlist",
            self.files,
            self.findings.len(),
            self.suppressed
        ));
        out
    }

    pub fn to_json(&self) -> Json {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                obj(vec![
                    ("rule", Json::Str(f.rule.to_string())),
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("message", Json::Str(f.message.clone())),
                    ("snippet", Json::Str(f.snippet.clone())),
                ])
            })
            .collect();
        obj(vec![
            ("files", Json::Num(self.files as f64)),
            ("findings", Json::Arr(findings)),
            ("suppressed", Json::Num(self.suppressed as f64)),
        ])
    }
}

/// Analyze every `.rs` file under `root`, then fold the allowlist in:
/// matched findings are suppressed (counted), entries without a reason or
/// matching nothing become findings themselves.
pub fn run(root: &Path, allow: &Allowlist) -> Result<Report, String> {
    let files = rust_files(root)?;
    let mut findings = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .map_err(|e| format!("{}: {e}", path.display()))?
            .to_string_lossy()
            .replace('\\', "/");
        let text =
            fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        findings.extend(analyze_source(&rel, &text));
    }
    let mut used = vec![0usize; allow.entries.len()];
    let mut kept = Vec::new();
    let mut suppressed = 0usize;
    for f in findings {
        match allow.entries.iter().position(|e| e.matches(&f)) {
            Some(i) => {
                used[i] += 1;
                suppressed += 1;
            }
            None => kept.push(f),
        }
    }
    for (i, e) in allow.entries.iter().enumerate() {
        if e.reason.trim().is_empty() {
            kept.push(Finding {
                rule: "allow-missing-reason",
                file: "analyze-allow.toml".to_string(),
                line: e.line,
                message: format!(
                    "allowlist entry (rule={}, file={}) has no reason — every suppression must \
                     be justified",
                    e.rule, e.file
                ),
                snippet: String::new(),
            });
        } else if used[i] == 0 {
            kept.push(Finding {
                rule: "stale-allow",
                file: "analyze-allow.toml".to_string(),
                line: e.line,
                message: format!(
                    "allowlist entry (rule={}, file={}) matched nothing — remove it",
                    e.rule, e.file
                ),
                snippet: e.pattern.clone(),
            });
        }
    }
    Ok(Report { files: files.len(), findings: kept, suppressed })
}

/// All rules over one file. `rel` is the `/`-separated path relative to
/// the analyzed root (rule scoping keys on it).
pub fn analyze_source(rel: &str, text: &str) -> Vec<Finding> {
    let raw: Vec<&str> = text.lines().collect();
    let (code, keepstr) = views(text);
    debug_assert_eq!(code.len(), raw.len());
    let masked = test_mod_mask(&code);
    let mut out = Vec::new();

    let finding = |rule: &'static str, line: usize, message: String| Finding {
        rule,
        file: rel.to_string(),
        line: line + 1,
        message,
        snippet: clip(raw.get(line).unwrap_or(&"").trim()),
    };
    // In-source suppressions sit on the offending line or anywhere in the
    // contiguous comment/attribute block directly above it (so multi-line
    // justifications can carry the tag on any of their lines).
    let annotated = |idx: usize, tag: &str| {
        if has_tag(&raw, idx, tag) {
            return true;
        }
        let mut k = idx;
        while k > 0 {
            k -= 1;
            let t = raw[k].trim_start();
            if !(t.starts_with("//") || t.starts_with("#[")) {
                return false;
            }
            if has_tag(&raw, k, tag) {
                return true;
            }
        }
        false
    };

    for idx in 0..code.len() {
        if masked[idx] {
            continue;
        }
        let line = &code[idx];

        // safety-comment: any `unsafe` needs a SAFETY note nearby.
        if has_word(line, "unsafe") && !comment_block_contains(&raw, idx, "SAFETY:") {
            out.push(finding(
                "safety-comment",
                idx,
                "`unsafe` without a `// SAFETY:` comment on it or the block above".to_string(),
            ));
        }

        // kernel-cast: no bare lossy `as` casts on the kernel hot paths.
        if kernel_cast_scope(rel) && !annotated(idx, "cast-ok:") {
            if let Some(ty) = bare_cast(line) {
                out.push(finding(
                    "kernel-cast",
                    idx,
                    format!(
                        "bare `as {ty}` cast in kernel scope — use a checked helper or \
                         annotate `// cast-ok: <reason>`"
                    ),
                ));
            }
        }

        // float-eq: literal float compared with == / !=.
        if (line.contains("==") || line.contains("!="))
            && has_float_token(line)
            && !annotated(idx, "float-eq-ok:")
        {
            out.push(finding(
                "float-eq",
                idx,
                "float `==`/`!=` comparison — annotate `// float-eq-ok: <reason>` if this is \
                 an exact-replication site"
                    .to_string(),
            ));
        }

        // no-panic: library solve paths return OtprError instead.
        if no_panic_scope(rel) && !annotated(idx, "panic-ok:") {
            if let Some(tok) = PANIC_TOKENS.iter().find(|t| line.contains(*t)) {
                out.push(finding(
                    "no-panic",
                    idx,
                    format!(
                        "`{}` in a library solve path — route through OtprError or annotate \
                         `// panic-ok: <reason>`",
                        tok.trim_start_matches('.')
                    ),
                ));
            }
        }

        // error-convention: eps diagnostics name their cost source.
        if rel.starts_with("core/") && keepstr[idx].contains("eps must be") {
            let near = keepstr[idx..(idx + 3).min(keepstr.len())]
                .iter()
                .any(|l| l.contains("provider="));
            if !near {
                out.push(finding(
                    "error-convention",
                    idx,
                    "eps validation message must name its cost source (`provider=...`)"
                        .to_string(),
                ));
            }
        }
    }

    // contract-marker: the byte-identity tripwire over the kernel backends.
    if contract_scope(rel) {
        for span in fn_spans(&code) {
            if masked[span.start] {
                continue;
            }
            let body = code[span.start..=span.end.min(code.len() - 1)].join("\n");
            if CONTRACT_TRIGGERS.iter().any(|t| body.contains(t))
                && !span_has_marker(&raw, span.start, span.end, CONTRACT_MARKER)
            {
                out.push(finding(
                    "contract-marker",
                    span.start,
                    format!(
                        "fn `{}` stages or commits against the active worklist but lacks a \
                         `// {CONTRACT_MARKER}` marker",
                        span.name
                    ),
                ));
            }
        }
    }

    // contract-marker (sparse): CSR extraction/assembly must declare the
    // dense-fold-order contract, same mechanics as the worklist tripwire.
    if sparse_contract_scope(rel) {
        for span in fn_spans(&code) {
            if masked[span.start] {
                continue;
            }
            let body = code[span.start..=span.end.min(code.len() - 1)].join("\n");
            if SPARSE_CONTRACT_TRIGGERS.iter().any(|t| body.contains(t))
                && !span_has_marker(&raw, span.start, span.end, SPARSE_CONTRACT_MARKER)
            {
                out.push(finding(
                    "contract-marker",
                    span.start,
                    format!(
                        "fn `{}` builds or emits CSR plan data but lacks a \
                         `// {SPARSE_CONTRACT_MARKER}` marker",
                        span.name
                    ),
                ));
            }
        }
    }

    out
}

// ---------------------------------------------------------------------
// rule scoping
// ---------------------------------------------------------------------

fn kernel_cast_scope(rel: &str) -> bool {
    rel.starts_with("core/kernel/") || rel == "core/quantize.rs" || rel == "core/transport.rs"
}

fn no_panic_scope(rel: &str) -> bool {
    let top = rel.split('/').next().unwrap_or(rel);
    matches!(top, "api" | "core" | "solvers" | "coordinator" | "runtime" | "data")
}

fn contract_scope(rel: &str) -> bool {
    matches!(
        rel,
        "core/kernel/arena.rs"
            | "core/kernel/scalar.rs"
            | "core/kernel/chunked.rs"
            | "core/kernel/vector.rs"
            | "core/kernel/hybrid.rs"
    )
}

/// Files where CSR plan data is extracted or assembled — the sparse
/// byte-identity contract's blast radius.
fn sparse_contract_scope(rel: &str) -> bool {
    matches!(rel, "core/kernel/arena.rs" | "core/transport.rs")
}

// ---------------------------------------------------------------------
// per-line predicates
// ---------------------------------------------------------------------

fn clip(s: &str) -> String {
    if s.len() > 120 {
        let mut end = 120;
        while !s.is_char_boundary(end) {
            end -= 1;
        }
        format!("{}…", &s[..end])
    } else {
        s.to_string()
    }
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Whole-word occurrence of `word` in `line`.
fn has_word(line: &str, word: &str) -> bool {
    let mut rest = line;
    let mut base = 0usize;
    while let Some(p) = rest.find(word) {
        let start = base + p;
        let end = start + word.len();
        let before_ok = start == 0 || !line[..start].ends_with(is_ident);
        let after_ok = !line[end..].starts_with(is_ident);
        if before_ok && after_ok {
            return true;
        }
        rest = &rest[p + word.len()..];
        base = end;
    }
    false
}

fn has_tag(raw: &[&str], idx: usize, tag: &str) -> bool {
    raw.get(idx).is_some_and(|l| {
        l.find(tag).is_some_and(|p| !l[p + tag.len()..].trim().is_empty() || !l.ends_with(tag))
    })
}

/// `needle` on the line itself or in the contiguous comment/attribute
/// block directly above it.
fn comment_block_contains(raw: &[&str], idx: usize, needle: &str) -> bool {
    if raw.get(idx).is_some_and(|l| l.contains(needle)) {
        return true;
    }
    let mut k = idx;
    while k > 0 {
        k -= 1;
        let t = raw[k].trim();
        if t.starts_with("//") || t.starts_with("#[") || t.starts_with("#![") {
            if t.contains(needle) {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

/// First lossy cast target on a code-view line, if any. Matches the
/// rustfmt spelling ` as <ty>` with the type at an identifier boundary.
fn bare_cast(code: &str) -> Option<&'static str> {
    let mut rest = code;
    while let Some(p) = rest.find(" as ") {
        let after = &rest[p + 4..];
        for ty in CAST_TARGETS {
            if after.starts_with(ty) && !after[ty.len()..].starts_with(is_ident) {
                return Some(ty);
            }
        }
        rest = &rest[p + 4..];
    }
    None
}

/// A float-typed token: a `1.5`-style literal (not tuple access like
/// `x.0.1`) or an `f64::`/`f32::` associated item.
fn has_float_token(code: &str) -> bool {
    if code.contains("f64::") || code.contains("f32::") {
        return true;
    }
    let chars: Vec<char> = code.chars().collect();
    for i in 0..chars.len() {
        if !chars[i].is_ascii_digit() {
            continue;
        }
        if i > 0 && (is_ident(chars[i - 1]) || chars[i - 1] == '.') {
            continue;
        }
        let mut j = i;
        while j < chars.len() && chars[j].is_ascii_digit() {
            j += 1;
        }
        if j + 1 < chars.len() && chars[j] == '.' && chars[j + 1].is_ascii_digit() {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------
// source views: comment / string classification, test-mod mask, fn spans
// ---------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq)]
enum Cls {
    Code,
    Comment,
    Str,
}

/// Per-line views of `text`: `(code, keepstr)` where `code` drops
/// comments and string-literal contents (delimiting quotes stay) and
/// `keepstr` drops only comments. Line counts match `text.lines()`.
fn views(text: &str) -> (Vec<String>, Vec<String>) {
    let classified = classify(text);
    let mut code = Vec::new();
    let mut keepstr = Vec::new();
    let mut cur_code = String::new();
    let mut cur_keep = String::new();
    for (c, cls) in classified {
        if c == '\n' {
            code.push(std::mem::take(&mut cur_code));
            keepstr.push(std::mem::take(&mut cur_keep));
            continue;
        }
        match cls {
            Cls::Code => {
                cur_code.push(c);
                cur_keep.push(c);
            }
            Cls::Str => cur_keep.push(c),
            Cls::Comment => {}
        }
    }
    if !cur_code.is_empty() || !cur_keep.is_empty() || text.ends_with('\n') {
        // text.lines() drops a trailing newline's empty line; mirror it.
        if !text.ends_with('\n') {
            code.push(cur_code);
            keepstr.push(cur_keep);
        }
    }
    (code, keepstr)
}

/// Classify every character as code, comment, or string content. Handles
/// line and nested block comments, plain/escaped/raw strings, char
/// literals vs lifetimes (`'a'` is a literal, `&'a` is not).
fn classify(text: &str) -> Vec<(char, Cls)> {
    let chars: Vec<char> = text.chars().collect();
    let mut out = Vec::with_capacity(chars.len());
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            while i < chars.len() && chars[i] != '\n' {
                out.push((chars[i], Cls::Comment));
                i += 1;
            }
            continue;
        }
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 0usize;
            while i < chars.len() {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push((chars[i], Cls::Comment));
                    out.push((chars[i + 1], Cls::Comment));
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth = depth.saturating_sub(1);
                    out.push((chars[i], Cls::Comment));
                    out.push((chars[i + 1], Cls::Comment));
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    out.push((chars[i], Cls::Comment));
                    i += 1;
                }
            }
            continue;
        }
        // raw string r"..." / r#"..."# (optionally byte-prefixed)
        let prev_ident = i > 0 && is_ident(chars[i - 1]);
        if (c == 'r' || (c == 'b' && chars.get(i + 1) == Some(&'r'))) && !prev_ident {
            let start = i + if c == 'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while chars.get(start + hashes) == Some(&'#') {
                hashes += 1;
            }
            if chars.get(start + hashes) == Some(&'"') {
                for &ch in &chars[i..=start + hashes] {
                    out.push((ch, Cls::Code));
                }
                i = start + hashes + 1;
                while i < chars.len() {
                    if chars[i] == '"'
                        && (0..hashes).all(|h| chars.get(i + 1 + h) == Some(&'#'))
                    {
                        for &ch in &chars[i..=i + hashes] {
                            out.push((ch, Cls::Code));
                        }
                        i += hashes + 1;
                        break;
                    }
                    out.push((chars[i], Cls::Str));
                    i += 1;
                }
                continue;
            }
        }
        if c == '"' {
            out.push((c, Cls::Code));
            i += 1;
            while i < chars.len() {
                if chars[i] == '\\' && i + 1 < chars.len() {
                    out.push((chars[i], Cls::Str));
                    out.push((chars[i + 1], Cls::Str));
                    i += 2;
                } else if chars[i] == '"' {
                    out.push((chars[i], Cls::Code));
                    i += 1;
                    break;
                } else {
                    out.push((chars[i], Cls::Str));
                    i += 1;
                }
            }
            continue;
        }
        if c == '\'' {
            let is_char_lit = match chars.get(i + 1) {
                Some('\\') => true,
                Some(_) => chars.get(i + 2) == Some(&'\''),
                None => false,
            };
            if is_char_lit {
                out.push((c, Cls::Code));
                i += 1;
                while i < chars.len() {
                    if chars[i] == '\\' && i + 1 < chars.len() {
                        out.push((chars[i], Cls::Str));
                        out.push((chars[i + 1], Cls::Str));
                        i += 2;
                    } else if chars[i] == '\'' {
                        out.push((chars[i], Cls::Code));
                        i += 1;
                        break;
                    } else {
                        out.push((chars[i], Cls::Str));
                        i += 1;
                    }
                }
                continue;
            }
        }
        out.push((c, Cls::Code));
        i += 1;
    }
    out
}

/// Mask over code lines marking `#[cfg(test)] mod ... { ... }` bodies
/// (tests are exempt from every rule).
fn test_mod_mask(code: &[String]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut skip_from: Option<i64> = None;
    for (idx, line) in code.iter().enumerate() {
        let t = line.trim();
        let opens = line.matches('{').count() as i64;
        let closes = line.matches('}').count() as i64;
        match skip_from {
            Some(d0) => {
                mask[idx] = true;
                depth += opens - closes;
                if depth <= d0 {
                    skip_from = None;
                }
            }
            None => {
                if t.starts_with("#[cfg(test)]") {
                    pending = true;
                } else if pending && (t.starts_with("mod ") || t.starts_with("pub mod ")) {
                    mask[idx] = true;
                    skip_from = Some(depth);
                    pending = false;
                } else if !t.is_empty() && !t.starts_with("#[") {
                    pending = false;
                }
                depth += opens - closes;
                if let Some(d0) = skip_from {
                    if depth <= d0 {
                        skip_from = None;
                    }
                }
            }
        }
    }
    mask
}

struct FnSpan {
    name: String,
    /// 0-based inclusive line range of the definition + body.
    start: usize,
    end: usize,
}

/// `fn` item spans over the code view (closures stay inside their
/// enclosing fn's span, which is exactly what the contract rule wants).
fn fn_spans(code: &[String]) -> Vec<FnSpan> {
    let mut spans = Vec::new();
    for (i, line) in code.iter().enumerate() {
        let Some(name) = fn_def_name(line) else {
            continue;
        };
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut end = i;
        'scan: for (j, body_line) in code.iter().enumerate().skip(i) {
            for ch in body_line.chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth <= 0 {
                            end = j;
                            break 'scan;
                        }
                    }
                    ';' if !opened && depth == 0 => {
                        end = j;
                        break 'scan; // bodyless trait declaration
                    }
                    _ => {}
                }
            }
            end = j;
        }
        spans.push(FnSpan { name, start: i, end });
    }
    spans
}

/// Name of the `fn` defined on a code-view line, if any.
fn fn_def_name(code: &str) -> Option<String> {
    let mut rest = code;
    let mut base = 0usize;
    while let Some(p) = rest.find("fn ") {
        let start = base + p;
        let before_ok = start == 0 || !code[..start].ends_with(is_ident);
        if before_ok {
            let name: String =
                code[start + 3..].chars().take_while(|&c| is_ident(c)).collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
        rest = &rest[p + 3..];
        base = start + 3;
    }
    None
}

/// Marker anywhere in the fn span or in its contiguous leading
/// comment/attribute block.
fn span_has_marker(raw: &[&str], start: usize, end: usize, marker: &str) -> bool {
    let hi = end.min(raw.len().saturating_sub(1));
    if raw[start..=hi].iter().any(|l| l.contains(marker)) {
        return true;
    }
    let mut k = start;
    while k > 0 {
        k -= 1;
        let t = raw[k].trim();
        if t.starts_with("//") || t.starts_with("#[") {
            if t.contains(marker) {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

// ---------------------------------------------------------------------
// file walking
// ---------------------------------------------------------------------

fn rust_files(root: &Path) -> Result<Vec<PathBuf>, String> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let entries =
            fs::read_dir(&dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|x| x == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classifier_strips_comments_and_strings() {
        let (code, keepstr) = views("let x = \"a // b\"; // tail\nlet y = 'c';\n");
        assert_eq!(code[0], "let x = \"\"; ");
        assert_eq!(keepstr[0], "let x = \"a // b\"; ");
        assert_eq!(code[1], "let y = '';");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let (code, _) = views("fn f<'a>(x: &'a str) -> &'a str { x }\n");
        assert_eq!(code[0], "fn f<'a>(x: &'a str) -> &'a str { x }");
        let (code, _) = views("let c = 'x'; let s: &'static str = \"y\";\n");
        assert_eq!(code[0], "let c = ''; let s: &'static str = \"\";");
    }

    #[test]
    fn raw_strings_and_escapes_classified() {
        let (code, keepstr) = views("let s = r#\"un\"closed // not a comment\"#;\n");
        assert_eq!(code[0], "let s = r#\"\"#;");
        assert!(keepstr[0].contains("not a comment"));
        let (code, _) = views("let q = \"a\\\"b\";\n");
        assert_eq!(code[0], "let q = \"\";");
    }

    #[test]
    fn test_mods_are_masked() {
        let src = "fn lib() { x.unwrap() }\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap() }\n}\nfn tail() {}\n";
        let (code, _) = views(src);
        let mask = test_mod_mask(&code);
        assert_eq!(mask, vec![false, false, true, true, true, false]);
    }

    #[test]
    fn no_panic_fires_in_scope_only() {
        let bad = "pub fn f(v: Option<u32>) -> u32 { v.unwrap() }\n";
        assert_eq!(analyze_source("core/foo.rs", bad).len(), 1);
        assert_eq!(analyze_source("core/foo.rs", bad)[0].rule, "no-panic");
        assert!(analyze_source("exp/foo.rs", bad).is_empty(), "exp is exempt");
        let ok = "// panic-ok: documented contract\npub fn f(v: Option<u32>) -> u32 { v.unwrap() }\n";
        assert!(analyze_source("core/foo.rs", ok).is_empty());
    }

    #[test]
    fn kernel_cast_scoped_and_annotatable() {
        let bad = "fn f(x: usize) -> u32 { x as u32 }\n";
        let hits = analyze_source("core/kernel/arena.rs", bad);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "kernel-cast");
        assert!(analyze_source("solvers/foo.rs", bad).is_empty(), "out of scope");
        let widen = "fn f(x: u32) -> f64 { x as f64 }\n";
        assert!(analyze_source("core/kernel/arena.rs", widen).is_empty(), "f64 widening ok");
        let ok = "fn f(x: usize) -> u32 { x as u32 } // cast-ok: x < nb <= u32::MAX\n";
        assert!(analyze_source("core/kernel/arena.rs", ok).is_empty());
    }

    #[test]
    fn float_eq_needs_a_float_token() {
        let bad = "let same = x == 0.0;\n";
        let hits = analyze_source("solvers/foo.rs", bad);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "float-eq");
        assert!(analyze_source("solvers/foo.rs", "let same = n == 0;\n").is_empty());
        assert!(
            analyze_source("solvers/foo.rs", "let t = v.0.1 == w;\n").is_empty(),
            "tuple access is not a float literal"
        );
        let ok = "let same = x == 0.0; // float-eq-ok: exact replication of the dense fold\n";
        assert!(analyze_source("solvers/foo.rs", ok).is_empty());
    }

    #[test]
    fn safety_comment_rule() {
        let bad = "fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
        let hits = analyze_source("runtime/foo.rs", bad);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "safety-comment");
        let ok = "// SAFETY: p is valid for reads by the caller contract\nfn f(p: *const u8) -> u8 { unsafe { *p } }\n";
        assert!(analyze_source("runtime/foo.rs", ok).is_empty());
    }

    #[test]
    fn error_convention_requires_provider() {
        let bad = "fn f(eps: f64) { assert!(eps > 0.0, \"eps must be in (0,1), got {eps}\"); }\n";
        let hits = analyze_source("core/quantize.rs", bad);
        assert!(hits.iter().any(|f| f.rule == "error-convention"));
        let ok =
            "fn f(eps: f64) { assert!(eps > 0.0, \"eps must be in (0,1), got {eps} (provider=dense)\"); }\n";
        assert!(analyze_source("core/quantize.rs", ok)
            .iter()
            .all(|f| f.rule != "error-convention"));
    }

    #[test]
    fn contract_marker_tripwire() {
        let bad = "pub fn run_phase(&mut self) {\n    self.accept_one(0);\n}\n";
        let hits = analyze_source("core/kernel/scalar.rs", bad);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "contract-marker");
        assert!(hits[0].message.contains("run_phase"));
        let ok = "// CONTRACT: round-structured accept order\npub fn run_phase(&mut self) {\n    self.accept_one(0);\n}\n";
        assert!(analyze_source("core/kernel/scalar.rs", ok).is_empty());
        // a fn that never touches the worklist needs no marker
        let other = "pub fn threshold(&self) -> u64 {\n    self.q.len()\n}\n";
        assert!(analyze_source("core/kernel/scalar.rs", other).is_empty());
    }

    #[test]
    fn sparse_contract_marker_tripwire() {
        let bad = "pub fn assemble(&self) -> UnitFlowCsr {\n    self.extract_plan_sparse()\n}\n";
        let hits = analyze_source("core/kernel/arena.rs", bad);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].rule, "contract-marker");
        assert!(hits[0].message.contains("assemble"));
        assert!(hits[0].message.contains(SPARSE_CONTRACT_MARKER));
        let ok = format!("// {SPARSE_CONTRACT_MARKER}\n{bad}");
        assert!(analyze_source("core/kernel/arena.rs", &ok).is_empty());
        // from_csr assembly in transport.rs is guarded by the same rule
        let bad2 = "pub fn build(v: Vec<f64>) -> TransportPlan {\n    TransportPlan::from_csr(1, 1, vec![0, 1], vec![0], v).unwrap_or_default()\n}\n";
        let hits2 = analyze_source("core/transport.rs", bad2);
        assert!(
            hits2.iter().any(|f| f.rule == "contract-marker"),
            "{hits2:?}"
        );
        // the worklist marker does not satisfy the sparse rule
        let wrong = format!("// {CONTRACT_MARKER}\n{bad}");
        assert_eq!(analyze_source("core/kernel/arena.rs", &wrong).len(), 1);
        // out of scope: the sparse triggers fire nowhere else
        assert!(analyze_source("solvers/mod.rs", bad).is_empty());
    }

    #[test]
    fn allowlist_parses_suppresses_and_flags_stale() {
        let toml = "# comment\n[[allow]]\nrule = \"no-panic\"\nfile = \"core/foo.rs\"\npattern = \"v.unwrap()\"\nreason = \"documented contract\"\n\n[[allow]]\nrule = \"no-panic\"\nfile = \"core/nothing.rs\"\nreason = \"dead entry\"\n";
        let allow = Allowlist::parse(toml).unwrap();
        assert_eq!(allow.entries.len(), 2);
        let f = Finding {
            rule: "no-panic",
            file: "core/foo.rs".to_string(),
            line: 1,
            message: String::new(),
            snippet: "let x = v.unwrap();".to_string(),
        };
        assert!(allow.entries[0].matches(&f));
        assert!(!allow.entries[1].matches(&f));
        assert!(Allowlist::parse("[[allow]]\nbogus\n").is_err());
        assert!(Allowlist::parse("rule = \"x\"\n").is_err(), "key before [[allow]]");
    }

    #[test]
    fn missing_reason_is_a_finding_via_run() {
        // exercised end-to-end in tests/analyze_rules.rs against a temp
        // tree; here just pin the entry-level predicate.
        let allow = Allowlist::parse("[[allow]]\nrule = \"no-panic\"\nfile = \"f.rs\"\n").unwrap();
        assert!(allow.entries[0].reason.is_empty());
    }
}
