//! Figure 2 reproduction (paper §5): runtime vs ε on MNIST-style image
//! inputs — n images per side, L1 distance between unit-normalized 28×28
//! images (max cost ≤ 2) — for ε ∈ {0.75, 0.5, 0.25, 0.1}.
//!
//! Engines run through the [`SolverRegistry`] exactly like
//! [`crate::exp::fig1`]; the same engine aliases and measurement note
//! apply — the `xla` series times the generic cost-upload path, not the
//! on-device `solve_images` construction (still available on
//! [`crate::runtime::XlaAssignment`] for the runtime benches).
//!
//! The paper fixes n = 10,000 with real MNIST; `data::mnist` loads the real
//! IDX files when present and otherwise substitutes synthetic digit images
//! (DESIGN.md §2). Default n here is CI-scale; `otpr fig2 --n 10000
//! --reps 30` reproduces the paper's point.

use crate::api::{Problem, SolverRegistry};
use crate::core::AssignmentInstance;
use crate::data::{images, mnist};
use crate::exp::report::Series;
use crate::runtime::XlaRuntime;
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct Fig2Config {
    pub n: usize,
    pub eps: Vec<f64>,
    pub reps: usize,
    pub seed: u64,
    pub engines: Vec<String>,
}

impl Default for Fig2Config {
    fn default() -> Self {
        Self {
            n: 1000,
            eps: vec![0.75, 0.5, 0.25, 0.1],
            reps: 3,
            seed: 7,
            engines: vec![
                "pr-cpu".into(),
                "pr-gpu".into(),
                "sinkhorn-cpu".into(),
                "sinkhorn-gpu".into(),
            ],
        }
    }
}

/// Build the Figure-2 instance once (shared across ε and reps, like the
/// paper's setup). Returns (instance, packed image features, used_real).
pub fn build_instance(n: usize, seed: u64) -> (AssignmentInstance, Vec<f32>, Vec<f32>, bool) {
    let (a_imgs, real_a) = mnist::load_or_synthesize(n, seed);
    let (b_imgs, _) = mnist::load_or_synthesize(n, seed.wrapping_add(0x5EED));
    let costs = images::l1_costs(&b_imgs, &a_imgs);
    let inst = AssignmentInstance::new(costs).expect("square");
    let fb = images::images_to_f32(&b_imgs);
    let fa = images::images_to_f32(&a_imgs);
    (inst, fb, fa, real_a)
}

/// Figure 2: one runtime series per algorithm, x = ε.
pub fn run(cfg: &Fig2Config, registry: Option<Arc<XlaRuntime>>) -> (Vec<Series>, bool) {
    let solvers = SolverRegistry::with_defaults();
    let (inst, _fb, _fa, real) = build_instance(cfg.n, cfg.seed);
    let problem = Problem::Assignment(inst);
    let mut series: Vec<Series> =
        cfg.engines.iter().map(|e| Series::new(e.clone())).collect();
    for &eps in &cfg.eps {
        for (ei, engine) in cfg.engines.iter().enumerate() {
            let mut times = Vec::new();
            let mut note = None;
            for _rep in 0..cfg.reps {
                let (secs, n2) =
                    crate::exp::timed_registry_solve(&solvers, engine, &problem, eps, registry.clone());
                if n2.is_some() {
                    note = n2;
                }
                match secs {
                    Some(s) => times.push(s),
                    None => break,
                }
            }
            if !times.is_empty() {
                let mean = times.iter().sum::<f64>() / times.len() as f64;
                match note {
                    Some(msg) => series[ei].push_note(eps, mean, msg),
                    None => series[ei].push(eps, mean),
                }
            } else if let Some(msg) = note {
                series[ei].push_note(eps, f64::NAN, msg);
            }
        }
    }
    (series, real)
}


#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_fig2_native() {
        let cfg = Fig2Config {
            n: 24,
            eps: vec![0.5, 0.25],
            reps: 1,
            seed: 3,
            engines: vec!["pr-cpu".into(), "sinkhorn-cpu".into()],
        };
        let (series, _real) = run(&cfg, None);
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].points.len(), 2);
        assert!(series[0].points.iter().all(|p| p.y >= 0.0));
    }

    #[test]
    fn instance_cost_range() {
        let (inst, fb, fa, _) = build_instance(12, 1);
        assert!(inst.costs.max() <= 2.0 + 1e-4);
        assert_eq!(fb.len(), 12 * 784);
        assert_eq!(fa.len(), 12 * 784);
    }
}
