//! `otpr bench --serve`: the serving-layer benchmark — whole-coordinator
//! throughput through the sharded dispatch path, per shape cell.
//!
//! Where `bench_kernel` times bare solves through the registry, this
//! harness measures what a deployment sees: jobs/s through admission,
//! shape-keyed shards, warm-arena pinned workers, and the `(digest, ε)`
//! result cache. Each cell reports client-observed latency percentiles
//! (queue + solve, from the job outcomes), the shard arena-reuse rate
//! (the tentpole metric: ≈(jobs−workers)/jobs for a same-shape stream),
//! and the cache hit rate (`1 − distinct/jobs` by construction when the
//! cache is enabled and payloads repeat).
//!
//! The artifact (`BENCH_serve.json`, schema `otpr-bench-serve/1`) rides
//! next to `BENCH_kernel*.json` in nightly CI so serving-path regressions
//! (a cold shard per batch, a dead cache) show up as a rate cliff even
//! when per-solve kernel numbers are unchanged.

use crate::api::SolveRequest;
use crate::coordinator::{Coordinator, CoordinatorConfig, Engine, JobKind, JobStatus};
use crate::data::workloads::Workload;
use crate::util::minijson::{obj, Json};
use crate::util::timer::Stopwatch;

#[derive(Debug, Clone)]
pub struct BenchServeConfig {
    /// Problem sizes; one serving cell (its own coordinator) per size.
    pub sizes: Vec<usize>,
    /// Jobs submitted per cell.
    pub jobs: usize,
    /// Workers per shard.
    pub workers: usize,
    pub eps: f64,
    pub seed: u64,
    /// Distinct payloads per cell; the remaining `jobs − distinct`
    /// submissions repeat earlier payloads and should hit the cache.
    pub distinct: usize,
    /// Result-cache byte budget (0 disables — every job solves fresh).
    pub cache_bytes: u64,
    pub engine: Engine,
}

impl Default for BenchServeConfig {
    fn default() -> Self {
        Self {
            sizes: vec![128, 256],
            jobs: 64,
            workers: 4,
            eps: 0.2,
            seed: 42,
            distinct: 16,
            cache_bytes: 4 << 20,
            engine: Engine::NativeSeq,
        }
    }
}

impl BenchServeConfig {
    /// The `--smoke` grid: one small cell, CI-sized.
    pub fn smoke() -> Self {
        Self { sizes: vec![48], jobs: 24, workers: 2, distinct: 8, ..Self::default() }
    }
}

/// One measured serving cell.
#[derive(Debug, Clone)]
pub struct ServeRecord {
    pub n: usize,
    pub jobs: usize,
    /// Wall clock submit-to-last-reply for the whole cell.
    pub wall_secs: f64,
    pub jobs_per_sec: f64,
    /// Client-observed per-job latency (queue + solve), milliseconds.
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// Jobs that reached `Served` (cache hits included).
    pub served: usize,
    pub cache_hits: u64,
    /// `cache_hits / jobs`.
    pub cache_hit_rate: f64,
    /// Σ shard arena-reuse hits / Σ shard jobs — the warm-affinity rate
    /// over jobs that actually executed (cache hits bypass shards).
    pub arena_reuse_rate: f64,
    pub error: Option<String>,
}

/// Nearest-rank percentile over an ascending-sorted slice.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run the sweep: one fresh coordinator per cell, `jobs` submissions over
/// `distinct` repeating payloads, all outcomes awaited.
pub fn run(cfg: &BenchServeConfig) -> Vec<ServeRecord> {
    let mut out = Vec::new();
    for &n in &cfg.sizes {
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: cfg.workers,
                cache_bytes: cfg.cache_bytes,
                ..Default::default()
            },
            None,
        );
        let sw = Stopwatch::start();
        let handles: Vec<_> = (0..cfg.jobs)
            .map(|i| {
                let seed = cfg.seed + (i % cfg.distinct.max(1)) as u64;
                let kind = JobKind::Assignment(Workload::Fig1 { n }.assignment(seed));
                coord.submit_request(kind, SolveRequest::new(cfg.eps), cfg.engine)
            })
            .collect();
        let mut latencies_ms = Vec::with_capacity(cfg.jobs);
        let mut served = 0usize;
        let mut error = None;
        for h in handles {
            match h.and_then(|h| h.wait()) {
                Ok(o) => {
                    latencies_ms.push((o.queued_secs + o.solve_secs) * 1e3);
                    if o.status == JobStatus::Served && o.result.is_ok() {
                        served += 1;
                    } else if error.is_none() {
                        error = Some(match o.result {
                            Err(e) => e,
                            Ok(_) => format!("terminal status {:?}", o.status),
                        });
                    }
                }
                Err(e) => {
                    if error.is_none() {
                        error = Some(e.to_string());
                    }
                }
            }
        }
        let wall = sw.elapsed_secs();
        let metrics = coord.metrics.clone();
        coord.shutdown();
        latencies_ms.sort_by(|a, b| a.total_cmp(b));
        let hits = metrics.cache_hits.load(std::sync::atomic::Ordering::Relaxed);
        let shards = metrics.shard_counters();
        let shard_jobs: u64 = shards.iter().map(|s| s.jobs).sum();
        let reuse: u64 = shards.iter().map(|s| s.arena_reuse_hits).sum();
        out.push(ServeRecord {
            n,
            jobs: cfg.jobs,
            wall_secs: wall,
            jobs_per_sec: if wall > 0.0 { cfg.jobs as f64 / wall } else { f64::NAN },
            p50_ms: percentile(&latencies_ms, 0.50),
            p95_ms: percentile(&latencies_ms, 0.95),
            served,
            cache_hits: hits,
            cache_hit_rate: hits as f64 / cfg.jobs.max(1) as f64,
            arena_reuse_rate: reuse as f64 / shard_jobs.max(1) as f64,
            error,
        });
    }
    out
}

/// The `BENCH_serve.json` document.
pub fn to_json(cfg: &BenchServeConfig, records: &[ServeRecord]) -> Json {
    let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
    let recs = records
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("n", Json::Num(r.n as f64)),
                ("jobs", Json::Num(r.jobs as f64)),
                ("wall_s", num(r.wall_secs)),
                ("jobs_per_sec", num(r.jobs_per_sec)),
                ("p50_ms", num(r.p50_ms)),
                ("p95_ms", num(r.p95_ms)),
                ("served", Json::Num(r.served as f64)),
                ("cache_hits", Json::Num(r.cache_hits as f64)),
                ("cache_hit_rate", num(r.cache_hit_rate)),
                ("arena_reuse_rate", num(r.arena_reuse_rate)),
            ];
            if let Some(e) = &r.error {
                fields.push(("error", Json::Str(e.clone())));
            }
            obj(fields)
        })
        .collect();
    obj(vec![
        ("schema", Json::Str("otpr-bench-serve/1".into())),
        ("engine", Json::Str(cfg.engine.name().to_string())),
        ("workers", Json::Num(cfg.workers as f64)),
        ("distinct", Json::Num(cfg.distinct as f64)),
        ("cache_bytes", Json::Num(cfg.cache_bytes as f64)),
        ("eps", Json::Num(cfg.eps)),
        ("seed", Json::Num(cfg.seed as f64)),
        ("records", Json::Arr(recs)),
    ])
}

/// Fixed-width table for CLI output.
pub fn table(records: &[ServeRecord]) -> String {
    let mut out = String::from(
        "n      jobs   jobs/s      p50 ms    p95 ms    reuse-rate  cache-hit-rate\n",
    );
    for r in records {
        out.push_str(&format!(
            "{:<6} {:<6} {:<11.1} {:<9.3} {:<9.3} {:<11.3} {:.3}{}\n",
            r.n,
            r.jobs,
            r.jobs_per_sec,
            r.p50_ms,
            r.p95_ms,
            r.arena_reuse_rate,
            r.cache_hit_rate,
            match &r.error {
                Some(e) => format!("  ERROR: {e}"),
                None => String::new(),
            }
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_cell_reports_throughput_reuse_and_cache_rates() {
        let cfg = BenchServeConfig {
            sizes: vec![20],
            jobs: 12,
            workers: 1,
            eps: 0.3,
            seed: 1,
            distinct: 4,
            cache_bytes: 1 << 20,
            engine: Engine::NativeSeq,
        };
        let records = run(&cfg);
        assert_eq!(records.len(), 1);
        let r = &records[0];
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.served, 12);
        assert!(r.jobs_per_sec > 0.0);
        assert!(r.p95_ms >= r.p50_ms);
        // 4 distinct payloads over 12 jobs: the 8 repeats can only miss
        // if they were admitted before the first solves landed — the
        // single worker serializes enough that at least one repeat hits.
        assert!(r.cache_hits > 0, "repeated payloads must hit the cache");
        assert!((0.0..=1.0).contains(&r.cache_hit_rate));
        assert!((0.0..=1.0).contains(&r.arena_reuse_rate));
        let json = to_json(&cfg, &records).to_string();
        let parsed = Json::parse(&json).expect("valid JSON");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("otpr-bench-serve/1"));
        assert_eq!(parsed.get("records").unwrap().as_arr().unwrap().len(), 1);
        assert!(table(&records).contains("jobs/s"));
    }

    #[test]
    fn disabled_cache_never_hits_and_reuse_stays_high() {
        let cfg = BenchServeConfig {
            sizes: vec![16],
            jobs: 10,
            workers: 1,
            eps: 0.3,
            seed: 2,
            distinct: 2,
            cache_bytes: 0,
            engine: Engine::NativeSeq,
        };
        let r = &run(&cfg)[0];
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.cache_hits, 0, "cache_bytes = 0 disables the cache");
        assert_eq!(r.served, 10);
        // every job executes on the one shard; its single pinned worker
        // reuses the arena on all but its first job
        assert!(
            r.arena_reuse_rate >= 0.9,
            "same-shape stream must stay warm: {}",
            r.arena_reuse_rate
        );
    }

    #[test]
    fn percentile_is_nearest_rank() {
        assert!(percentile(&[], 0.95).is_nan());
        let xs = [1.0, 2.0, 3.0, 4.0];
        // float-eq-ok: percentile returns elements of the input verbatim
        assert_eq!(percentile(&xs, 0.0), 1.0);
        // float-eq-ok: percentile returns elements of the input verbatim
        assert_eq!(percentile(&xs, 1.0), 4.0);
        // float-eq-ok: percentile returns elements of the input verbatim
        assert_eq!(percentile(&xs, 0.5), 3.0);
    }
}
