//! `otpr bench`: the fig1-style kernel timing sweep over
//! {engines} × {n} × {ε}, emitting a machine-readable `BENCH_kernel.json`
//! so the perf trajectory of the flow kernel is recorded run-over-run
//! (nightly CI uploads it as an artifact next to the gap histogram).
//!
//! Every cell times whole solves through the [`SolverRegistry`] with
//! raw-ε requests (the paper's parameterization) and reports robust
//! per-solve statistics plus the kernel's own counters (phases, rounds,
//! Σ|B'|), so a regression can be attributed to more work vs slower
//! work.

use crate::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
use crate::data::workloads::Workload;
use crate::util::minijson::{obj, Json};
use crate::util::stats::Summary;
use crate::util::timer::Stopwatch;

#[derive(Debug, Clone)]
pub struct BenchKernelConfig {
    /// Registry keys or aliases to sweep.
    pub engines: Vec<String>,
    pub sizes: Vec<usize>,
    /// Raw algorithm-parameter ε values.
    pub eps: Vec<f64>,
    /// Timed repetitions per cell.
    pub reps: usize,
    pub seed: u64,
    /// Solve the Fig1 workload through its implicit point-cloud
    /// `CostProvider` instead of the dense slab (`otpr bench --points`):
    /// byte-identical results, and each record's `cost_state_bytes`
    /// shows the block-min cache instead of the dense slab.
    pub points: bool,
}

impl Default for BenchKernelConfig {
    fn default() -> Self {
        Self {
            engines: vec![
                "native-seq".into(),
                "native-parallel".into(),
                "native-vector".into(),
                "native-vector-warm".into(),
            ],
            sizes: vec![200, 400, 800],
            eps: vec![0.1, 0.05],
            reps: 3,
            seed: 42,
            points: false,
        }
    }
}

impl BenchKernelConfig {
    /// The `--smoke` grid: small enough for CI, still covering both
    /// kernel backends.
    pub fn smoke() -> Self {
        Self {
            sizes: vec![64, 128],
            eps: vec![0.2],
            reps: 1,
            ..Self::default()
        }
    }
}

/// One measured (engine, n, ε) cell.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub engine: String,
    pub n: usize,
    pub eps: f64,
    /// Robust stats over per-solve wall clock (seconds).
    pub secs: Summary,
    /// Completed timed solves (0 for an error cell).
    pub samples: usize,
    /// Nanoseconds per solve (mean) — the headline ns/op number.
    pub ns_per_op: f64,
    pub phases: usize,
    pub rounds: usize,
    pub total_free_processed: u64,
    /// Peak resident cost-state bytes of the solve (dense slab + lane
    /// mirrors vs the implicit block-min cache) — the memory half of the
    /// bench trajectory.
    pub cost_state_bytes: u64,
    /// Resident bytes of the answer's transport plan (O(nnz) for the CSR
    /// plans kernel OT solves emit, nb·na·8 for dense baselines).
    /// Assignment cells report 0 — their answer is a matching, not a plan.
    pub plan_state_bytes: u64,
    /// Cost representation the cell solved ("dense" or "points").
    pub costs: &'static str,
    /// Error string when the cell could not run (engine unavailable).
    pub error: Option<String>,
}

/// Run the sweep. Cells that cannot run (e.g. XLA without artifacts)
/// report an error record rather than disappearing.
pub fn run(cfg: &BenchKernelConfig) -> Vec<BenchRecord> {
    let solvers = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let mut out = Vec::new();
    let costs_mode = if cfg.points { "points" } else { "dense" };
    for engine in &cfg.engines {
        for &n in &cfg.sizes {
            let workload = Workload::Fig1 { n };
            let problem = if cfg.points {
                Problem::implicit_assignment(
                    workload.implicit_costs(cfg.seed).expect("fig1 has an implicit form"),
                )
                .expect("fig1 is square")
            } else {
                Problem::Assignment(workload.assignment(cfg.seed))
            };
            for &eps in &cfg.eps {
                let req = SolveRequest::new(eps).raw_eps();
                let mut times = Vec::with_capacity(cfg.reps);
                let mut phases = 0;
                let mut rounds = 0;
                let mut free = 0;
                let mut cost_bytes = 0;
                let mut plan_bytes = 0;
                let mut error = None;
                for _ in 0..cfg.reps.max(1) {
                    let sw = Stopwatch::start();
                    match solvers.solve(engine, &config, &problem, &req) {
                        Ok(sol) => {
                            times.push(sw.elapsed_secs());
                            phases = sol.stats.phases;
                            rounds = sol.stats.rounds;
                            free = sol.stats.total_free_processed;
                            cost_bytes = sol.stats.cost_state_bytes;
                            plan_bytes = sol.stats.plan_state_bytes;
                        }
                        Err(e) => {
                            error = Some(e.to_string());
                            break;
                        }
                    }
                }
                let samples = times.len();
                let secs = if times.is_empty() { Summary::of(&[f64::NAN]) } else { Summary::of(&times) };
                let ns_per_op = if times.is_empty() { f64::NAN } else { secs.mean * 1e9 };
                out.push(BenchRecord {
                    engine: engine.clone(),
                    n,
                    eps,
                    secs,
                    samples,
                    ns_per_op,
                    phases,
                    rounds,
                    total_free_processed: free,
                    cost_state_bytes: cost_bytes,
                    plan_state_bytes: plan_bytes,
                    costs: costs_mode,
                    error,
                });
            }
        }
    }
    out
}

/// The `BENCH_kernel.json` document.
pub fn to_json(cfg: &BenchKernelConfig, records: &[BenchRecord]) -> Json {
    // non-finite (error cells) → null, so the artifact stays valid JSON
    let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
    let recs = records
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("engine", Json::Str(r.engine.clone())),
                ("n", Json::Num(r.n as f64)),
                ("eps", Json::Num(r.eps)),
                ("ns_per_op", num(r.ns_per_op)),
                ("mean_s", num(r.secs.mean)),
                ("median_s", num(r.secs.median)),
                ("stddev_s", num(r.secs.stddev)),
                ("samples", Json::Num(r.samples as f64)),
                ("phases", Json::Num(r.phases as f64)),
                ("rounds", Json::Num(r.rounds as f64)),
                ("total_free_processed", Json::Num(r.total_free_processed as f64)),
                ("cost_state_bytes", Json::Num(r.cost_state_bytes as f64)),
                ("plan_state_bytes", Json::Num(r.plan_state_bytes as f64)),
                ("costs", Json::Str(r.costs.to_string())),
            ];
            if let Some(e) = &r.error {
                fields.push(("error", Json::Str(e.clone())));
            }
            obj(fields)
        })
        .collect();
    obj(vec![
        ("schema", Json::Str("otpr-bench-kernel/1".into())),
        ("reps", Json::Num(cfg.reps as f64)),
        ("seed", Json::Num(cfg.seed as f64)),
        ("records", Json::Arr(recs)),
    ])
}

/// Engine every cell is normalized against for the regression gate:
/// absolute ns/op is not comparable across hosts, but each engine's ratio
/// to the scalar reference *is*, so that ratio is what gates.
pub const COMPARE_REFERENCE: &str = "native-seq";

/// Flat `(engine, n, eps, ns_per_op)` index of a bench artifact
/// (`BENCH_kernel*.json`); error cells (null ns) are skipped.
pub fn load_baseline(text: &str) -> Result<Vec<(String, usize, f64, f64)>, String> {
    let json = Json::parse(text)?;
    let records = json
        .get("records")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| "baseline has no records array".to_string())?;
    let mut out = Vec::new();
    for r in records {
        // the perf gate joins dense cells only — implicit (points) cells
        // share (engine, n, eps) keys and would corrupt the join
        if let Some(mode) = r.get("costs").and_then(|v| v.as_str()) {
            if mode != "dense" {
                continue;
            }
        }
        let engine = r
            .get("engine")
            .and_then(|v| v.as_str())
            .ok_or_else(|| "record missing engine".to_string())?;
        let n = r
            .get("n")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| "record missing n".to_string())?;
        let eps = r
            .get("eps")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| "record missing eps".to_string())?;
        if let Some(ns) = r.get("ns_per_op").and_then(|v| v.as_f64()) {
            if ns.is_finite() && ns > 0.0 {
                out.push((engine.to_string(), n, eps, ns));
            }
        }
    }
    Ok(out)
}

/// One (engine, n, eps) cell present in both the current run and the
/// baseline artifact.
#[derive(Debug, Clone)]
pub struct CompareCell {
    pub engine: String,
    pub n: usize,
    pub eps: f64,
    pub base_ns: f64,
    pub cur_ns: f64,
    /// baseline/current wall clock (>1 = faster now). Host-dependent.
    pub speedup: f64,
    /// (engine/reference) ns ratio, current vs baseline (>1 = this engine
    /// regressed relative to [`COMPARE_REFERENCE`]); `None` when either
    /// run lacks the reference cell, or for the reference itself.
    pub rel_change: Option<f64>,
}

/// Join the current records against a loaded baseline on (engine, n, eps).
pub fn compare(
    current: &[BenchRecord],
    baseline: &[(String, usize, f64, f64)],
) -> Vec<CompareCell> {
    let find_base = |e: &str, n: usize, eps: f64| {
        baseline
            .iter()
            .find(|(be, bn, beps, _)| be == e && *bn == n && (*beps - eps).abs() < 1e-12)
            .map(|t| t.3)
    };
    let find_cur = |e: &str, n: usize, eps: f64| {
        current
            .iter()
            .find(|r| r.engine == e && r.n == n && (r.eps - eps).abs() < 1e-12 && r.error.is_none())
            .map(|r| r.ns_per_op)
    };
    let mut out = Vec::new();
    for r in current {
        if r.error.is_some() || !r.ns_per_op.is_finite() || r.costs != "dense" {
            continue;
        }
        let Some(base_ns) = find_base(&r.engine, r.n, r.eps) else { continue };
        let rel_change = if r.engine == COMPARE_REFERENCE {
            None
        } else {
            match (
                find_cur(COMPARE_REFERENCE, r.n, r.eps),
                find_base(COMPARE_REFERENCE, r.n, r.eps),
            ) {
                (Some(cr), Some(br)) if cr > 0.0 && br > 0.0 => {
                    Some((r.ns_per_op / cr) / (base_ns / br))
                }
                _ => None,
            }
        };
        out.push(CompareCell {
            engine: r.engine.clone(),
            n: r.n,
            eps: r.eps,
            base_ns,
            cur_ns: r.ns_per_op,
            speedup: base_ns / r.ns_per_op,
            rel_change,
        });
    }
    out
}

/// Cells whose reference-relative cost grew more than `threshold`
/// (0.10 = 10%) — the nightly perf-gate failures.
pub fn regressions(cells: &[CompareCell], threshold: f64) -> Vec<String> {
    cells
        .iter()
        .filter_map(|c| match c.rel_change {
            Some(rc) if rc > 1.0 + threshold => Some(format!(
                "{} n={} eps={}: {:.1}% slower relative to {COMPARE_REFERENCE} \
                 (ratio {rc:.3}× baseline)",
                c.engine,
                c.n,
                c.eps,
                (rc - 1.0) * 100.0
            )),
            _ => None,
        })
        .collect()
}

/// Is the joined cell set actually able to gate? A perf gate that joins
/// zero dense cells, or joins cells but never computes a reference-
/// relative ratio (the [`COMPARE_REFERENCE`] cell missing from either
/// side), passes vacuously — `regressions` has nothing to inspect. That
/// exact failure shipped before PR 7: a baseline on a disjoint grid made
/// the nightly `--compare --gate` silently green. Callers must treat an
/// `Err` here as a distinct loud failure, not an empty-but-passing gate.
pub fn gate_health(cells: &[CompareCell]) -> Result<(), String> {
    if cells.is_empty() {
        return Err(
            "joined zero dense cells — current run and baseline share no (engine, n, eps) \
             grid point, so the gate is vacuous"
                .to_string(),
        );
    }
    if !cells.iter().any(|c| c.rel_change.is_some()) {
        return Err(format!(
            "no joined cell has a reference-relative ratio — the {COMPARE_REFERENCE} \
             reference cell is missing from the current run or the baseline, so the \
             gate is vacuous"
        ));
    }
    Ok(())
}

/// Per-config speedup table for `otpr bench --compare`.
pub fn compare_table(cells: &[CompareCell]) -> String {
    let mut out = String::from(
        "engine             n      eps    base ns/op      now ns/op       speedup  vs-ref\n",
    );
    for c in cells {
        let rel = match c.rel_change {
            Some(rc) => format!("{rc:.3}x"),
            None => "-".to_string(),
        };
        out.push_str(&format!(
            "{:<18} {:<6} {:<6} {:<15.0} {:<15.0} {:<8.2} {rel}\n",
            c.engine, c.n, c.eps, c.base_ns, c.cur_ns, c.speedup
        ));
    }
    out
}

/// Fixed-width table for CLI output.
pub fn table(records: &[BenchRecord]) -> String {
    let mut out = String::from(
        "engine           n      eps    ns/op           phases  rounds  cost-state-bytes  plan-state-bytes\n",
    );
    for r in records {
        match &r.error {
            Some(e) => out.push_str(&format!(
                "{:<16} {:<6} {:<6} unavailable: {e}\n",
                r.engine, r.n, r.eps
            )),
            None => out.push_str(&format!(
                "{:<16} {:<6} {:<6} {:<15.0} {:<7} {:<7} {:<11} ({})  {}\n",
                r.engine,
                r.n,
                r.eps,
                r.ns_per_op,
                r.phases,
                r.rounds,
                r.cost_state_bytes,
                r.costs,
                r.plan_state_bytes
            )),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_sweep_produces_valid_json() {
        let cfg = BenchKernelConfig {
            engines: vec!["native-seq".into(), "native-parallel".into()],
            sizes: vec![24],
            eps: vec![0.3],
            reps: 1,
            seed: 1,
            points: false,
        };
        let records = run(&cfg);
        assert_eq!(records.len(), 2);
        for r in &records {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.ns_per_op > 0.0);
            assert!(r.phases > 0);
        }
        let json = to_json(&cfg, &records).to_string();
        let parsed = Json::parse(&json).expect("valid JSON");
        assert_eq!(
            parsed.get("records").unwrap().as_arr().unwrap().len(),
            2
        );
        // assignment cells answer with a matching, not a plan — the
        // plan-bytes column exists but is honestly zero for them
        for rec in parsed.get("records").unwrap().as_arr().unwrap() {
            assert_eq!(
                rec.get("plan_state_bytes").and_then(|v| v.as_f64()),
                Some(0.0)
            );
        }
        assert!(table(&records).contains("native-seq"));
        assert!(table(&records).contains("plan-state-bytes"));
    }

    #[test]
    fn compare_round_trips_and_gates_on_relative_regression() {
        let cfg = BenchKernelConfig {
            engines: vec!["native-seq".into(), "native-vector".into()],
            sizes: vec![20],
            eps: vec![0.3],
            reps: 1,
            seed: 2,
            points: false,
        };
        let records = run(&cfg);
        let artifact = to_json(&cfg, &records).to_string();
        let baseline = load_baseline(&artifact).expect("artifact round-trips");
        assert_eq!(baseline.len(), 2);
        // self-comparison: speedup 1.0, relative ratio exactly 1.0, no gate
        let cells = compare(&records, &baseline);
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert!((c.speedup - 1.0).abs() < 1e-9);
            if c.engine == COMPARE_REFERENCE {
                assert!(c.rel_change.is_none(), "reference never gates on itself");
            } else {
                assert!((c.rel_change.unwrap() - 1.0).abs() < 1e-9);
            }
        }
        assert!(regressions(&cells, 0.10).is_empty());
        assert!(compare_table(&cells).contains("native-vector"));

        // a baseline where the vector engine used to be 2× faster relative
        // to native-seq than it is now → >10% relative regression fires
        let slowed: Vec<(String, usize, f64, f64)> = baseline
            .iter()
            .map(|(e, n, eps, ns)| {
                let ns = if e == "native-vector" { ns / 2.0 } else { *ns };
                (e.clone(), *n, *eps, ns)
            })
            .collect();
        let regs = regressions(&compare(&records, &slowed), 0.10);
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("native-vector"));
        // a healthy join passes the vacuity check
        gate_health(&cells).expect("self-compare is a usable gate");
        // mismatched grids produce no cells — that is a gate-health
        // FAILURE (pre-PR-7 this passed silently as "no regressions")
        let disjoint = compare(&records, &[("native-seq".into(), 999, 0.3, 1.0)]);
        assert!(disjoint.is_empty());
        let err = gate_health(&disjoint).expect_err("empty join must fail the gate");
        assert!(err.contains("zero dense cells"), "{err}");
    }

    /// The other vacuous-pass mode: cells join, but the `native-seq`
    /// reference is absent from the baseline, so every `rel_change` is
    /// `None` and `regressions` can never fire. The gate must refuse.
    #[test]
    fn gate_health_fails_when_reference_cell_is_missing() {
        let cfg = BenchKernelConfig {
            engines: vec!["native-seq".into(), "native-vector".into()],
            sizes: vec![20],
            eps: vec![0.3],
            reps: 1,
            seed: 2,
            points: false,
        };
        let records = run(&cfg);
        let baseline = load_baseline(&to_json(&cfg, &records).to_string()).unwrap();
        // strip the reference engine from the baseline: the vector cell
        // still joins (on its own key) but has no ratio to gate on
        let no_ref: Vec<(String, usize, f64, f64)> =
            baseline.into_iter().filter(|(e, ..)| e != COMPARE_REFERENCE).collect();
        let cells = compare(&records, &no_ref);
        assert!(!cells.is_empty(), "non-reference cells still join");
        assert!(cells.iter().all(|c| c.rel_change.is_none()));
        assert!(regressions(&cells, 0.10).is_empty(), "nothing to inspect");
        let err = gate_health(&cells).expect_err("ratio-less join must fail the gate");
        assert!(err.contains(COMPARE_REFERENCE), "{err}");
    }

    #[test]
    fn points_mode_runs_no_slab_cells_and_never_joins_the_gate() {
        let mut cfg = BenchKernelConfig {
            engines: vec!["native-vector".into()],
            sizes: vec![24],
            eps: vec![0.3],
            reps: 1,
            seed: 3,
            points: true,
        };
        let points = run(&cfg);
        assert_eq!(points.len(), 1);
        assert!(points[0].error.is_none(), "{:?}", points[0].error);
        assert_eq!(points[0].costs, "points");
        let dense_slab_bytes: u64 = 24 * 24 * 4;
        assert!(
            points[0].cost_state_bytes < dense_slab_bytes,
            "implicit cell holds {} bytes ≥ the dense slab",
            points[0].cost_state_bytes
        );
        // dense cells on the same grid report the slab + mirrors
        cfg.points = false;
        let dense = run(&cfg);
        assert_eq!(dense[0].costs, "dense");
        assert!(dense[0].cost_state_bytes >= dense_slab_bytes);
        assert_eq!(dense[0].phases, points[0].phases, "byte-identical solve");
        assert_eq!(dense[0].rounds, points[0].rounds);
        // a points artifact contributes no baseline cells (and no compare
        // cells), so it can never corrupt the dense perf gate
        let artifact = to_json(&cfg, &points).to_string();
        assert!(load_baseline(&artifact).unwrap().is_empty());
        assert!(compare(&points, &load_baseline(&to_json(&cfg, &dense).to_string()).unwrap())
            .is_empty());
    }

    #[test]
    fn unavailable_engine_reports_error_record() {
        let cfg = BenchKernelConfig {
            engines: vec!["xla".into()],
            sizes: vec![16],
            eps: vec![0.3],
            reps: 1,
            seed: 1,
            points: false,
        };
        let records = run(&cfg);
        assert_eq!(records.len(), 1);
        assert!(records[0].error.is_some(), "no runtime loaded here");
        assert_eq!(records[0].samples, 0, "error cells report zero completed solves");
        assert!(table(&records).contains("unavailable"));
        // error cells still serialize to valid JSON (NaN → null)
        assert!(Json::parse(&to_json(&cfg, &records).to_string()).is_ok());
    }
}
