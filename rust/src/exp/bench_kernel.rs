//! `otpr bench`: the fig1-style kernel timing sweep over
//! {engines} × {n} × {ε}, emitting a machine-readable `BENCH_kernel.json`
//! so the perf trajectory of the flow kernel is recorded run-over-run
//! (nightly CI uploads it as an artifact next to the gap histogram).
//!
//! Every cell times whole solves through the [`SolverRegistry`] with
//! raw-ε requests (the paper's parameterization) and reports robust
//! per-solve statistics plus the kernel's own counters (phases, rounds,
//! Σ|B'|), so a regression can be attributed to more work vs slower
//! work.

use crate::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
use crate::data::workloads::Workload;
use crate::util::minijson::{obj, Json};
use crate::util::stats::Summary;
use crate::util::timer::Stopwatch;

#[derive(Debug, Clone)]
pub struct BenchKernelConfig {
    /// Registry keys or aliases to sweep.
    pub engines: Vec<String>,
    pub sizes: Vec<usize>,
    /// Raw algorithm-parameter ε values.
    pub eps: Vec<f64>,
    /// Timed repetitions per cell.
    pub reps: usize,
    pub seed: u64,
}

impl Default for BenchKernelConfig {
    fn default() -> Self {
        Self {
            engines: vec!["native-seq".into(), "native-parallel".into()],
            sizes: vec![200, 400, 800],
            eps: vec![0.1, 0.05],
            reps: 3,
            seed: 42,
        }
    }
}

impl BenchKernelConfig {
    /// The `--smoke` grid: small enough for CI, still covering both
    /// kernel backends.
    pub fn smoke() -> Self {
        Self {
            sizes: vec![64, 128],
            eps: vec![0.2],
            reps: 1,
            ..Self::default()
        }
    }
}

/// One measured (engine, n, ε) cell.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub engine: String,
    pub n: usize,
    pub eps: f64,
    /// Robust stats over per-solve wall clock (seconds).
    pub secs: Summary,
    /// Completed timed solves (0 for an error cell).
    pub samples: usize,
    /// Nanoseconds per solve (mean) — the headline ns/op number.
    pub ns_per_op: f64,
    pub phases: usize,
    pub rounds: usize,
    pub total_free_processed: u64,
    /// Error string when the cell could not run (engine unavailable).
    pub error: Option<String>,
}

/// Run the sweep. Cells that cannot run (e.g. XLA without artifacts)
/// report an error record rather than disappearing.
pub fn run(cfg: &BenchKernelConfig) -> Vec<BenchRecord> {
    let solvers = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let mut out = Vec::new();
    for engine in &cfg.engines {
        for &n in &cfg.sizes {
            let problem = Problem::Assignment(Workload::Fig1 { n }.assignment(cfg.seed));
            for &eps in &cfg.eps {
                let req = SolveRequest::new(eps).raw_eps();
                let mut times = Vec::with_capacity(cfg.reps);
                let mut phases = 0;
                let mut rounds = 0;
                let mut free = 0;
                let mut error = None;
                for _ in 0..cfg.reps.max(1) {
                    let sw = Stopwatch::start();
                    match solvers.solve(engine, &config, &problem, &req) {
                        Ok(sol) => {
                            times.push(sw.elapsed_secs());
                            phases = sol.stats.phases;
                            rounds = sol.stats.rounds;
                            free = sol.stats.total_free_processed;
                        }
                        Err(e) => {
                            error = Some(e.to_string());
                            break;
                        }
                    }
                }
                let samples = times.len();
                let secs = if times.is_empty() { Summary::of(&[f64::NAN]) } else { Summary::of(&times) };
                let ns_per_op = if times.is_empty() { f64::NAN } else { secs.mean * 1e9 };
                out.push(BenchRecord {
                    engine: engine.clone(),
                    n,
                    eps,
                    secs,
                    samples,
                    ns_per_op,
                    phases,
                    rounds,
                    total_free_processed: free,
                    error,
                });
            }
        }
    }
    out
}

/// The `BENCH_kernel.json` document.
pub fn to_json(cfg: &BenchKernelConfig, records: &[BenchRecord]) -> Json {
    // non-finite (error cells) → null, so the artifact stays valid JSON
    let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
    let recs = records
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("engine", Json::Str(r.engine.clone())),
                ("n", Json::Num(r.n as f64)),
                ("eps", Json::Num(r.eps)),
                ("ns_per_op", num(r.ns_per_op)),
                ("mean_s", num(r.secs.mean)),
                ("median_s", num(r.secs.median)),
                ("stddev_s", num(r.secs.stddev)),
                ("samples", Json::Num(r.samples as f64)),
                ("phases", Json::Num(r.phases as f64)),
                ("rounds", Json::Num(r.rounds as f64)),
                ("total_free_processed", Json::Num(r.total_free_processed as f64)),
            ];
            if let Some(e) = &r.error {
                fields.push(("error", Json::Str(e.clone())));
            }
            obj(fields)
        })
        .collect();
    obj(vec![
        ("schema", Json::Str("otpr-bench-kernel/1".into())),
        ("reps", Json::Num(cfg.reps as f64)),
        ("seed", Json::Num(cfg.seed as f64)),
        ("records", Json::Arr(recs)),
    ])
}

/// Fixed-width table for CLI output.
pub fn table(records: &[BenchRecord]) -> String {
    let mut out =
        String::from("engine           n      eps    ns/op           phases  rounds\n");
    for r in records {
        match &r.error {
            Some(e) => out.push_str(&format!(
                "{:<16} {:<6} {:<6} unavailable: {e}\n",
                r.engine, r.n, r.eps
            )),
            None => out.push_str(&format!(
                "{:<16} {:<6} {:<6} {:<15.0} {:<7} {}\n",
                r.engine, r.n, r.eps, r.ns_per_op, r.phases, r.rounds
            )),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_sweep_produces_valid_json() {
        let cfg = BenchKernelConfig {
            engines: vec!["native-seq".into(), "native-parallel".into()],
            sizes: vec![24],
            eps: vec![0.3],
            reps: 1,
            seed: 1,
        };
        let records = run(&cfg);
        assert_eq!(records.len(), 2);
        for r in &records {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.ns_per_op > 0.0);
            assert!(r.phases > 0);
        }
        let json = to_json(&cfg, &records).to_string();
        let parsed = Json::parse(&json).expect("valid JSON");
        assert_eq!(
            parsed.get("records").unwrap().as_arr().unwrap().len(),
            2
        );
        assert!(table(&records).contains("native-seq"));
    }

    #[test]
    fn unavailable_engine_reports_error_record() {
        let cfg = BenchKernelConfig {
            engines: vec!["xla".into()],
            sizes: vec![16],
            eps: vec![0.3],
            reps: 1,
            seed: 1,
        };
        let records = run(&cfg);
        assert_eq!(records.len(), 1);
        assert!(records[0].error.is_some(), "no runtime loaded here");
        assert_eq!(records[0].samples, 0, "error cells report zero completed solves");
        assert!(table(&records).contains("unavailable"));
        // error cells still serialize to valid JSON (NaN → null)
        assert!(Json::parse(&to_json(&cfg, &records).to_string()).is_ok());
    }
}
