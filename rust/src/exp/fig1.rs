//! Figure 1 reproduction (paper §5): runtime vs n on synthetic inputs —
//! A, B ~ U([0,1]²)ⁿ with Euclidean costs — for each ε, comparing the
//! push-relabel algorithm against Sinkhorn, CPU and "GPU" (XLA artifact)
//! implementations of both.
//!
//! Paper grid: n ∈ {500, 1000, 2000, 4000, 8000, 10000},
//! ε ∈ {0.1, 0.01, 0.005}, 30 runs/point. Defaults here are a laptop-scale
//! slice (override: `otpr fig1 --sizes ... --eps ... --reps 30`).

use crate::core::{AssignmentInstance, OtInstance};
use crate::data::synthetic;
use crate::exp::report::Series;
use crate::runtime::{XlaAssignment, XlaRuntime, XlaSinkhorn};
use crate::solvers::parallel_pr::ParallelPushRelabel;
use crate::solvers::push_relabel::PushRelabel;
use crate::solvers::sinkhorn::Sinkhorn;
use crate::solvers::OtSolver;
use crate::util::rng::Pcg32;
use crate::util::timer::Stopwatch;
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct Fig1Config {
    pub sizes: Vec<usize>,
    pub eps: Vec<f64>,
    pub reps: usize,
    pub seed: u64,
    /// Skip a (n, algorithm) cell once a single rep exceeds this budget.
    pub max_secs_per_run: f64,
    /// Algorithms to include (default: all four of the paper's).
    pub engines: Vec<String>,
}

impl Default for Fig1Config {
    fn default() -> Self {
        Self {
            sizes: vec![500, 1000, 2000],
            eps: vec![0.1, 0.01, 0.005],
            reps: 3,
            seed: 42,
            max_secs_per_run: 120.0,
            engines: vec![
                "pr-cpu".into(),
                "pr-gpu".into(),
                "sinkhorn-cpu".into(),
                "sinkhorn-gpu".into(),
            ],
        }
    }
}

/// Figure 1 for one ε: one runtime series per algorithm, x = n.
/// `registry = None` skips the XLA ("GPU") columns.
pub fn run_eps(
    cfg: &Fig1Config,
    eps: f64,
    registry: Option<Arc<XlaRuntime>>,
) -> Vec<Series> {
    let mut series: Vec<Series> =
        cfg.engines.iter().map(|e| Series::new(e.clone())).collect();
    for &n in &cfg.sizes {
        for (ei, engine) in cfg.engines.iter().enumerate() {
            let mut times = Vec::new();
            let mut note: Option<String> = None;
            for rep in 0..cfg.reps {
                let seed = cfg.seed.wrapping_add(rep as u64 * 1001);
                let (secs, n2) = run_one(engine, n, eps, seed, registry.clone());
                match n2 {
                    Some(msg) => {
                        note = Some(msg);
                    }
                    None => {}
                }
                if let Some(s) = secs {
                    times.push(s);
                    if s > cfg.max_secs_per_run {
                        note.get_or_insert_with(|| "budget".into());
                        break;
                    }
                } else {
                    break; // engine unavailable
                }
            }
            if !times.is_empty() {
                let mean = times.iter().sum::<f64>() / times.len() as f64;
                match note {
                    Some(msg) => series[ei].push_note(n as f64, mean, msg),
                    None => series[ei].push(n as f64, mean),
                }
            } else if let Some(msg) = note {
                series[ei].push_note(n as f64, f64::NAN, msg);
            }
        }
    }
    series
}

/// One timed run. Returns (seconds, note). `None` seconds = unavailable.
fn run_one(
    engine: &str,
    n: usize,
    eps: f64,
    seed: u64,
    registry: Option<Arc<XlaRuntime>>,
) -> (Option<f64>, Option<String>) {
    // Build inputs outside the timed region (the paper times the solvers,
    // not the data generation).
    let mut rng_a = Pcg32::with_stream(seed, 1);
    let mut rng_b = Pcg32::with_stream(seed, 2);
    let a_pts = synthetic::uniform_points(n, &mut rng_a);
    let b_pts = synthetic::uniform_points(n, &mut rng_b);
    let costs = synthetic::euclidean_costs(&b_pts, &a_pts);
    let inst = AssignmentInstance::new(costs).expect("square");

    match engine {
        "pr-cpu" => {
            let sw = Stopwatch::start();
            let sol = PushRelabel::new().solve_with_param(&inst, eps);
            (sol.ok().map(|_| sw.elapsed_secs()), None)
        }
        "pr-parallel" => {
            let sw = Stopwatch::start();
            let sol = ParallelPushRelabel::default().solve_with_param(&inst, eps);
            (sol.ok().map(|_| sw.elapsed_secs()), None)
        }
        "pr-gpu" => {
            let Some(reg) = registry else {
                return (None, Some("no artifacts".into()));
            };
            let solver = XlaAssignment::new(reg);
            let pb = synthetic::points_to_f32(&b_pts);
            let pa = synthetic::points_to_f32(&a_pts);
            let sw = Stopwatch::start();
            let sol = solver.solve_points(&pb, &pa, &inst, eps);
            match sol {
                Ok(_) => (Some(sw.elapsed_secs()), None),
                Err(e) => (None, Some(format!("error: {e}"))),
            }
        }
        "sinkhorn-cpu" => {
            let ot = OtInstance::uniform(inst.costs.clone()).expect("uniform");
            let mut sk = Sinkhorn::new();
            sk.config.max_iters = 20_000;
            let sw = Stopwatch::start();
            match sk.solve_ot(&ot, eps) {
                Ok(_) => (Some(sw.elapsed_secs()), None),
                Err(_) => {
                    // the paper's observed instability at small ε: retry in
                    // log-domain and report that time with a note
                    let sw = Stopwatch::start();
                    let mut lg = Sinkhorn::log_domain();
                    lg.config.max_iters = 1000; // bound the sweep; noted below
                    match lg.solve_ot(&ot, eps) {
                        Ok(_) => (Some(sw.elapsed_secs()), Some("log-domain".into())),
                        Err(e) => (None, Some(format!("diverged: {e}"))),
                    }
                }
            }
        }
        "sinkhorn-gpu" => {
            let Some(reg) = registry else {
                return (None, Some("no artifacts".into()));
            };
            let ot = OtInstance::uniform(inst.costs.clone()).expect("uniform");
            let sw = Stopwatch::start();
            match XlaSinkhorn::new(reg).solve_ot(&ot, eps) {
                Ok(_) => (Some(sw.elapsed_secs()), None),
                Err(e) => (None, Some(format!("diverged: {e}"))),
            }
        }
        other => (None, Some(format!("unknown engine {other}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_grid_runs_native_only() {
        let cfg = Fig1Config {
            sizes: vec![32, 64],
            eps: vec![0.25],
            reps: 1,
            seed: 1,
            max_secs_per_run: 60.0,
            engines: vec!["pr-cpu".into(), "sinkhorn-cpu".into()],
        };
        let series = run_eps(&cfg, 0.25, None);
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].points.len(), 2);
        assert!(series[0].points.iter().all(|p| p.y > 0.0));
        assert!(series[1].points.iter().all(|p| p.y > 0.0));
    }

    #[test]
    fn unknown_engine_noted() {
        let (secs, note) = run_one("bogus", 8, 0.3, 1, None);
        assert!(secs.is_none());
        assert!(note.unwrap().contains("unknown"));
    }
}
