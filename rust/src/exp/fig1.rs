//! Figure 1 reproduction (paper §5): runtime vs n on synthetic inputs —
//! A, B ~ U([0,1]²)ⁿ with Euclidean costs — for each ε, comparing the
//! push-relabel algorithm against Sinkhorn, CPU and "GPU" (XLA artifact)
//! implementations of both.
//!
//! All engines run through the [`SolverRegistry`]; the engine strings in
//! [`Fig1Config::engines`] are registry keys or aliases (the historical
//! `pr-cpu`/`pr-gpu`/`sinkhorn-cpu`/`sinkhorn-gpu` spellings resolve to
//! `native-seq`/`xla`/`sinkhorn-native`/`sinkhorn-xla`). ε is driven as
//! the raw algorithm parameter, matching the paper's own plots.
//!
//! Measurement note: the `xla` series times the generic registry path
//! (host cost matrix uploaded, quantized on device) rather than the
//! on-device cost construction of `XlaAssignment::solve_points` — the
//! latter remains available and is exercised by
//! `tests/integration_runtime.rs` and `benches/runtime_xla.rs`, but is not
//! part of this figure's engine comparison.
//!
//! Paper grid: n ∈ {500, 1000, 2000, 4000, 8000, 10000},
//! ε ∈ {0.1, 0.01, 0.005}, 30 runs/point. Defaults here are a laptop-scale
//! slice (override: `otpr fig1 --sizes ... --eps ... --reps 30`).

use crate::api::{Problem, SolverRegistry};
use crate::core::AssignmentInstance;
use crate::data::synthetic;
use crate::exp::report::Series;
use crate::runtime::XlaRuntime;
use crate::util::rng::Pcg32;
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct Fig1Config {
    pub sizes: Vec<usize>,
    pub eps: Vec<f64>,
    pub reps: usize,
    pub seed: u64,
    /// Skip a (n, algorithm) cell once a single rep exceeds this budget.
    pub max_secs_per_run: f64,
    /// Registry keys/aliases to include (default: the paper's four).
    pub engines: Vec<String>,
}

impl Default for Fig1Config {
    fn default() -> Self {
        Self {
            sizes: vec![500, 1000, 2000],
            eps: vec![0.1, 0.01, 0.005],
            reps: 3,
            seed: 42,
            max_secs_per_run: 120.0,
            engines: vec![
                "pr-cpu".into(),
                "pr-gpu".into(),
                "sinkhorn-cpu".into(),
                "sinkhorn-gpu".into(),
            ],
        }
    }
}

/// Figure 1 for one ε: one runtime series per algorithm, x = n.
/// `registry = None` skips the XLA ("GPU") columns.
pub fn run_eps(
    cfg: &Fig1Config,
    eps: f64,
    registry: Option<Arc<XlaRuntime>>,
) -> Vec<Series> {
    let solvers = SolverRegistry::with_defaults();
    let mut series: Vec<Series> =
        cfg.engines.iter().map(|e| Series::new(e.clone())).collect();
    for &n in &cfg.sizes {
        for (ei, engine) in cfg.engines.iter().enumerate() {
            let mut times = Vec::new();
            let mut note: Option<String> = None;
            for rep in 0..cfg.reps {
                let seed = cfg.seed.wrapping_add(rep as u64 * 1001);
                let (secs, n2) = run_one(&solvers, engine, n, eps, seed, registry.clone());
                if let Some(msg) = n2 {
                    note = Some(msg);
                }
                if let Some(s) = secs {
                    times.push(s);
                    if s > cfg.max_secs_per_run {
                        note.get_or_insert_with(|| "budget".into());
                        break;
                    }
                } else {
                    break; // engine unavailable
                }
            }
            if !times.is_empty() {
                let mean = times.iter().sum::<f64>() / times.len() as f64;
                match note {
                    Some(msg) => series[ei].push_note(n as f64, mean, msg),
                    None => series[ei].push(n as f64, mean),
                }
            } else if let Some(msg) = note {
                series[ei].push_note(n as f64, f64::NAN, msg);
            }
        }
    }
    series
}

/// One timed run through the registry (shared comparator policy lives in
/// [`crate::exp::timed_registry_solve`]). Returns (seconds, note);
/// `None` seconds = engine unavailable or failed.
fn run_one(
    solvers: &SolverRegistry,
    engine: &str,
    n: usize,
    eps: f64,
    seed: u64,
    runtime: Option<Arc<XlaRuntime>>,
) -> (Option<f64>, Option<String>) {
    // Build inputs outside the timed region (the paper times the solvers,
    // not the data generation); SolveStats.seconds covers the solve only.
    let mut rng_a = Pcg32::with_stream(seed, 1);
    let mut rng_b = Pcg32::with_stream(seed, 2);
    let a_pts = synthetic::uniform_points(n, &mut rng_a);
    let b_pts = synthetic::uniform_points(n, &mut rng_b);
    let costs = synthetic::euclidean_costs(&b_pts, &a_pts);
    let problem = Problem::Assignment(AssignmentInstance::new(costs).expect("square"));
    crate::exp::timed_registry_solve(solvers, engine, &problem, eps, runtime)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_grid_runs_native_only() {
        let cfg = Fig1Config {
            sizes: vec![32, 64],
            eps: vec![0.25],
            reps: 1,
            seed: 1,
            max_secs_per_run: 60.0,
            engines: vec!["pr-cpu".into(), "sinkhorn-cpu".into()],
        };
        let series = run_eps(&cfg, 0.25, None);
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].points.len(), 2);
        assert!(series[0].points.iter().all(|p| p.y > 0.0));
        assert!(series[1].points.iter().all(|p| p.y > 0.0));
    }

    #[test]
    fn unknown_engine_noted() {
        let solvers = SolverRegistry::with_defaults();
        let (secs, note) = run_one(&solvers, "bogus", 8, 0.3, 1, None);
        assert!(secs.is_none());
        assert!(note.unwrap().contains("unknown"));
    }

    #[test]
    fn canonical_keys_also_accepted() {
        let cfg = Fig1Config {
            sizes: vec![16],
            eps: vec![0.3],
            reps: 1,
            seed: 2,
            max_secs_per_run: 60.0,
            engines: vec!["native-seq".into(), "native-parallel".into()],
        };
        let series = run_eps(&cfg, 0.3, None);
        assert!(series.iter().all(|s| s.points.len() == 1));
    }
}
