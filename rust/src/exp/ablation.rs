//! Ablations over the paper's analytical claims (DESIGN.md §4, A1–A6):
//!
//! * A1 `phases`   — phase count vs ε against the (1+2ε)/ε² bound and the
//!                   Σnᵢ = O(n/ε) work bound (Lemmas 3.2/3.3, eq. 4).
//! * A2 `rounds`   — propose–accept rounds per phase vs n (§3.2: O(log n)).
//! * A3 `accuracy` — measured additive error vs the 3εn·c_max guarantee,
//!                   push-relabel vs exact Hungarian / SSP OT.
//! * A4 `clusters` — max dual clusters per vertex in the OT solver
//!                   (Lemma 4.1: ≤ 2).
//! * A5 `sinkhorn-stability` — standard vs log-domain Sinkhorn at small ε
//!                   (the §5 numerical-instability observation).
//! * A6 `threads`  — parallel solver speedup vs thread count.
//!
//! Whole-solve measurements go through the [`SolverRegistry`] (raw-ε
//! requests, like the paper's plots); phase-level instrumentation (A2, A4)
//! drives the shared flow kernel ([`crate::core::kernel`]) directly since
//! it measures quantities below the solve API.

use crate::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
use crate::core::kernel::{ChunkedKernel, FlowKernel, ScalarKernel};
use crate::core::ScaledOtInstance;
use crate::data::workloads::Workload;
use crate::exp::report::Series;
use crate::solvers::ot_push_relabel::ot_phase_cap;
use crate::solvers::push_relabel::assignment_phase_cap;
use crate::util::stats::power_fit;

/// A1: phases and total work vs ε at fixed n.
pub fn phases_vs_eps(n: usize, eps_grid: &[f64], seed: u64) -> Vec<Series> {
    let solvers = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let problem = Problem::Assignment(Workload::Fig1 { n }.assignment(seed));
    let mut measured = Series::new("phases (measured)");
    let mut bound = Series::new("phase bound (1+2ε)/ε²");
    let mut work = Series::new("Σnᵢ / (n/ε)");
    for &eps in eps_grid {
        let sol = solvers
            .solve("native-seq", &config, &problem, &SolveRequest::new(eps).raw_eps())
            .expect("solve");
        measured.push(eps, sol.stats.phases as f64);
        bound.push(eps, (1.0 + 2.0 * eps) / (eps * eps));
        let norm = sol.stats.total_free_processed as f64 / (n as f64 / eps);
        work.push(eps, norm);
    }
    vec![measured, bound, work]
}

/// A2: mean propose–accept rounds per phase vs n (kernel-level).
pub fn rounds_vs_n(sizes: &[usize], eps: f64, seed: u64) -> Vec<Series> {
    let mut rounds = Series::new("rounds/phase");
    let mut log2n = Series::new("log2(n)");
    for &n in sizes {
        let inst = Workload::Fig1 { n }.assignment(seed);
        let mut k = ChunkedKernel::new(4);
        k.init(&inst.costs, eps, None);
        k.run_to_termination(assignment_phase_cap(eps)).expect("terminate");
        let per_phase = k.arena().rounds as f64 / k.arena().phases.max(1) as f64;
        rounds.push(n as f64, per_phase);
        log2n.push(n as f64, (n as f64).log2());
    }
    vec![rounds, log2n]
}

/// A3: measured additive error vs the 3·ε·n·c_max guarantee.
pub fn accuracy(n: usize, eps_grid: &[f64], seed: u64) -> Vec<Series> {
    let solvers = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let problem = Problem::Assignment(Workload::Fig1 { n }.assignment(seed));
    let exact = solvers
        .solve("hungarian", &config, &problem, &SolveRequest::new(0.0))
        .expect("exact");
    let c_max = problem.costs().max() as f64;
    let mut err = Series::new("measured error / (3εn·c_max)");
    let mut abs = Series::new("measured additive error");
    for &eps in eps_grid {
        let sol = solvers
            .solve("native-seq", &config, &problem, &SolveRequest::new(eps).raw_eps())
            .expect("solve");
        let e = (sol.cost - exact.cost).max(0.0);
        abs.push(eps, e);
        err.push(eps, e / (3.0 * eps * n as f64 * c_max));
    }
    vec![abs, err]
}

/// A3b: OT solver error vs exact SSP on random-mass instances.
pub fn ot_accuracy(n: usize, eps_grid: &[f64], seed: u64) -> Vec<Series> {
    let solvers = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let problem = Problem::Ot(Workload::Fig1 { n }.ot_with_random_masses(seed));
    let exact = solvers
        .solve("ssp-exact", &config, &problem, &SolveRequest::new(0.0))
        .expect("exact");
    let c_max = problem.costs().max() as f64;
    let mut abs = Series::new("OT additive error");
    let mut rel = Series::new("error / (ε·c_max)");
    for &eps in eps_grid {
        let sol = solvers
            .solve("native-seq", &config, &problem, &SolveRequest::new(eps))
            .expect("solve");
        let e = (sol.cost - exact.cost).max(0.0);
        abs.push(eps, e);
        rel.push(eps, e / (eps * c_max));
    }
    vec![abs, rel]
}

/// A4: observed max dual clusters per vertex (Lemma 4.1 says ≤ 2;
/// kernel-level).
pub fn clusters(sizes: &[usize], eps: f64, seed: u64) -> Vec<Series> {
    let mut s = Series::new("max clusters (bound = 2)");
    for &n in sizes {
        let inst = Workload::Fig1 { n }.ot_with_random_masses(seed);
        let scaled = ScaledOtInstance::build(&inst, eps);
        let mut k = ScalarKernel::new();
        k.init(
            &inst.costs,
            eps / 6.0,
            Some((&scaled.supply_units[..], &scaled.demand_units[..])),
        );
        k.run_to_termination(ot_phase_cap(eps / 6.0)).expect("terminate");
        s.push(n as f64, k.arena().max_classes_seen as f64);
    }
    vec![s]
}

/// A5: standard-kernel vs log-domain Sinkhorn across ε (status + time).
pub fn sinkhorn_stability(n: usize, eps_grid: &[f64], seed: u64) -> Vec<Series> {
    let solvers = SolverRegistry::with_defaults();
    let std_cfg = SolverConfig {
        sinkhorn_log_domain: false,
        sinkhorn_max_iters: 100_000,
        ..SolverConfig::default()
    };
    let log_cfg = SolverConfig {
        sinkhorn_log_domain: true,
        sinkhorn_max_iters: 20_000,
        ..SolverConfig::default()
    };
    let problem = Problem::Assignment(Workload::Fig1 { n }.assignment(seed));
    let mut std_s = Series::new("sinkhorn-std secs");
    let mut log_s = Series::new("sinkhorn-log secs");
    for &eps in eps_grid {
        let req = SolveRequest::new(eps);
        match solvers.solve("sinkhorn-native", &std_cfg, &problem, &req) {
            Ok(sol) => {
                std_s.push_note(eps, sol.stats.seconds, format!("{} iters", sol.stats.phases))
            }
            Err(_) => std_s.push_note(eps, f64::NAN, "UNDERFLOW"),
        }
        match solvers.solve("sinkhorn-native", &log_cfg, &problem, &req) {
            Ok(sol) => {
                log_s.push_note(eps, sol.stats.seconds, format!("{} iters", sol.stats.phases))
            }
            Err(e) => log_s.push_note(eps, f64::NAN, format!("{e}")),
        }
    }
    vec![std_s, log_s]
}

/// A6: parallel solver wall-clock vs thread count.
pub fn threads(n: usize, eps: f64, thread_grid: &[usize], seed: u64) -> Vec<Series> {
    let solvers = SolverRegistry::with_defaults();
    let problem = Problem::Assignment(Workload::Fig1 { n }.assignment(seed));
    let req = SolveRequest::new(eps).raw_eps();
    let solve_secs = |t: usize| -> f64 {
        let config = SolverConfig::default().with_threads(t);
        solvers
            .solve("native-parallel", &config, &problem, &req)
            .map(|sol| sol.stats.seconds)
            .unwrap_or(f64::NAN)
    };
    let base = solve_secs(1);
    let mut time_s = Series::new("seconds");
    let mut speedup = Series::new("speedup vs 1 thread");
    for &t in thread_grid {
        let secs = solve_secs(t);
        time_s.push(t as f64, secs);
        speedup.push(t as f64, base / secs.max(1e-12));
    }
    vec![time_s, speedup]
}

/// Empirical sequential-complexity exponent: time vs n at fixed ε should be
/// ~ n² (the paper's O(n²/ε)). Returns (exponent, r²).
pub fn complexity_exponent(sizes: &[usize], eps: f64, seed: u64) -> (f64, f64) {
    let solvers = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let req = SolveRequest::new(eps).raw_eps();
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &n in sizes {
        let problem = Problem::Assignment(Workload::Fig1 { n }.assignment(seed));
        let secs = solvers
            .solve("native-seq", &config, &problem, &req)
            .map(|sol| sol.stats.seconds)
            .unwrap_or(f64::NAN);
        xs.push(n as f64);
        ys.push(secs.max(1e-9));
    }
    let (_, k, r2) = power_fit(&xs, &ys);
    (k, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a1_phases_within_bound() {
        let series = phases_vs_eps(48, &[0.3, 0.15], 1);
        let measured = &series[0];
        let bound = &series[1];
        for (m, b) in measured.points.iter().zip(&bound.points) {
            assert!(m.y <= b.y + 1e-9, "phases {} > bound {}", m.y, b.y);
        }
        // work bound normalized to ≤ (1+2ε)
        for p in &series[2].points {
            assert!(p.y <= 1.0 + 2.0 * p.x + 1e-9);
        }
    }

    #[test]
    fn a2_rounds_small() {
        let series = rounds_vs_n(&[32, 64], 0.25, 2);
        for p in &series[0].points {
            assert!(p.y >= 1.0 && p.y < 20.0, "rounds/phase {}", p.y);
        }
    }

    #[test]
    fn a3_error_within_guarantee() {
        let series = accuracy(24, &[0.3, 0.1], 3);
        for p in &series[1].points {
            assert!(p.y <= 1.0 + 1e-9, "normalized error {} > 1", p.y);
        }
    }

    #[test]
    fn a4_clusters_at_most_two() {
        let series = clusters(&[12, 20], 0.25, 4);
        for p in &series[0].points {
            assert!(p.y <= 2.0, "Lemma 4.1 violated: {}", p.y);
        }
    }

    #[test]
    fn a6_threads_produces_points() {
        let series = threads(48, 0.25, &[1, 2], 5);
        assert_eq!(series[0].points.len(), 2);
        assert!(series[1].points[0].y > 0.0);
    }
}
