//! Experiment harnesses regenerating the paper's evaluation (Figures 1–2)
//! and the analytical ablations A1–A6. See DESIGN.md §4 for the index.

pub mod ablation;
pub mod fig1;
pub mod fig2;
pub mod report;
