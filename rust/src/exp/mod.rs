//! Experiment harnesses regenerating the paper's evaluation (Figures 1–2),
//! the analytical ablations A1–A6, and the golden-corpus conformance sweep
//! (`conformance`). See DESIGN.md §4 for the index.
//! All whole-solve measurements go through [`crate::api::SolverRegistry`].

pub mod ablation;
pub mod analyze;
pub mod bench_kernel;
pub mod bench_serve;
pub mod conformance;
pub mod fig1;
pub mod fig2;
pub mod report;

use crate::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
use crate::runtime::XlaRuntime;
use std::sync::Arc;

/// One timed figure-harness solve: resolve the engine name, solve through
/// the registry with the paper's comparator settings (standard-kernel
/// Sinkhorn, raw-ε requests), and fall back to log-domain Sinkhorn with a
/// note when the standard kernel diverges (the §5 instability).
///
/// Returns `(seconds, note)`; `None` seconds = engine unavailable/failed.
/// Shared by `fig1` and `fig2` so the two figures can never desynchronize
/// their comparator policy.
pub(crate) fn timed_registry_solve(
    solvers: &SolverRegistry,
    engine: &str,
    problem: &Problem,
    eps: f64,
    runtime: Option<Arc<XlaRuntime>>,
) -> (Option<f64>, Option<String>) {
    let Some(key) = solvers.canonical(engine) else {
        return (None, Some(format!("unknown engine {engine}")));
    };
    if matches!(key, "xla" | "sinkhorn-xla") && runtime.is_none() {
        return (None, Some("no artifacts".into()));
    }
    let config = SolverConfig {
        sinkhorn_log_domain: false,
        sinkhorn_max_iters: 20_000,
        ..SolverConfig::default()
    }
    .with_runtime(runtime);
    let request = SolveRequest::new(eps).raw_eps();
    match solvers.solve(key, &config, problem, &request) {
        Ok(sol) => (Some(sol.stats.seconds), None),
        Err(_) if key == "sinkhorn-native" => {
            let fallback = SolverConfig {
                sinkhorn_log_domain: true,
                sinkhorn_max_iters: 1000, // bound the sweep; noted by caller
                ..config
            };
            match solvers.solve(key, &fallback, problem, &request) {
                Ok(sol) => (Some(sol.stats.seconds), Some("log-domain".into())),
                Err(e) => (None, Some(format!("diverged: {e}"))),
            }
        }
        Err(e) => (None, Some(format!("error: {e}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::workloads::Workload;

    #[test]
    fn timed_solve_reports_time_or_note() {
        let solvers = SolverRegistry::with_defaults();
        let problem = Problem::Assignment(Workload::RandomCosts { n: 12 }.assignment(1));
        let (secs, note) = timed_registry_solve(&solvers, "pr-cpu", &problem, 0.3, None);
        assert!(secs.is_some() && note.is_none());
        let (secs, note) = timed_registry_solve(&solvers, "pr-gpu", &problem, 0.3, None);
        assert!(secs.is_none());
        assert_eq!(note.as_deref(), Some("no artifacts"));
        let (secs, note) = timed_registry_solve(&solvers, "nope", &problem, 0.3, None);
        assert!(secs.is_none());
        assert!(note.unwrap().contains("unknown"));
    }
}
