//! Golden-corpus conformance: sweep every registry engine × ε over the
//! committed fixtures of `rust/testdata/golden/`, certify each solution
//! ([`crate::core::certify`]), and differential-test costs against the
//! pinned exact optima (Theorem 1 / Theorem 4.2 as executable checks).
//!
//! One conformance contract for all engines:
//!
//! * **pins** — the in-repo exact oracles (Hungarian, SSP min-cost flow)
//!   must reproduce the fixture-pinned optima exactly
//!   ([`verify_golden_pins`]); the pins were computed offline in rational
//!   arithmetic with a duality-certificate proof, so a mismatch means an
//!   oracle regression, not a stale fixture;
//! * **certificates** — every solution must pass its [`Certificate`]
//!   (primal always; dual + gap whenever the engine exports duals);
//! * **Theorem 1** — every engine with an additive guarantee must land
//!   within `ε·U` of the pinned optimum, where `U` is the answer-shape
//!   scale (`n·c_max` for matchings, `c_max` for unit-mass plans — an OT
//!   engine answering an assignment case is compared against `OPT/n`,
//!   the uniform-relaxation optimum by Birkhoff).
//!
//! Consumed by `otpr certify`, `tests/conformance_golden.rs`, and the
//! nightly CI sweep (which uploads [`ConformanceReport::gap_histogram_json`]
//! as an artifact).

use crate::api::{Coupling, Problem, ProblemKind, SolveRequest, SolverConfig, SolverRegistry};
use crate::core::certify::{gap_ratio_bucket, Certificate, GAP_RATIO_BUCKETS};
use crate::core::Result;
use crate::data::workloads::{golden_corpus, GoldenCase};
use crate::solvers::hungarian;
use crate::solvers::ssp_ot::SspExactOt;
use crate::solvers::OtSolver;
use crate::util::minijson::{obj, Json};

#[derive(Debug, Clone)]
pub struct ConformanceConfig {
    /// Registry keys or aliases to sweep.
    pub engines: Vec<String>,
    /// Overall-semantics accuracy targets.
    pub eps: Vec<f64>,
}

impl Default for ConformanceConfig {
    fn default() -> Self {
        Self {
            engines: crate::api::ENGINE_SPECS.iter().map(|s| s.key.to_string()).collect(),
            eps: vec![0.4, 0.2, 0.1],
        }
    }
}

/// One (case, engine, ε) sweep cell.
#[derive(Debug, Clone)]
pub struct ConformanceRecord {
    pub case_name: String,
    pub engine: &'static str,
    pub eps: f64,
    pub cost: f64,
    /// Exact reference on the answer's own scale (see module docs).
    pub exact: f64,
    /// Additive budget `ε·U` the engine promises; `None` = no guarantee
    /// (the greedy floor).
    pub budget: Option<f64>,
    pub cert: Certificate,
    /// `cost ≤ exact + budget`? `None` when the engine promises nothing.
    pub theorem1_ok: Option<bool>,
}

impl ConformanceRecord {
    pub fn ok(&self) -> bool {
        self.cert.ok() && self.theorem1_ok != Some(false)
    }
}

#[derive(Debug, Clone, Default)]
pub struct ConformanceReport {
    pub records: Vec<ConformanceRecord>,
    /// (case, engine, reason) cells that legitimately cannot run here:
    /// capability mismatches and the XLA backends without a loaded runtime.
    pub skipped: Vec<(String, String, String)>,
    /// (case, engine, eps, error) — a native engine returning `Err` on a
    /// golden case is a conformance **failure**, never a skip.
    pub errors: Vec<(String, String, f64, String)>,
}

impl ConformanceReport {
    pub fn failures(&self) -> Vec<&ConformanceRecord> {
        self.records.iter().filter(|r| !r.ok()).collect()
    }

    /// Total failing cells: certificate/Theorem-1 failures plus solve errors.
    pub fn failure_count(&self) -> usize {
        self.failures().len() + self.errors.len()
    }

    /// Records that carried a usable dual certificate.
    pub fn certified_gaps(&self) -> Vec<&ConformanceRecord> {
        self.records.iter().filter(|r| r.cert.gap.is_some()).collect()
    }

    /// Histogram of gap/bound ratios over all dual-certified records plus
    /// the raw per-record gaps — the nightly CI artifact.
    pub fn gap_histogram_json(&self) -> Json {
        let mut counts = vec![0u64; GAP_RATIO_BUCKETS.len()];
        let mut gaps = Vec::new();
        for r in &self.records {
            if let Some(g) = r.cert.gap {
                counts[gap_ratio_bucket(g, r.cert.bound)] += 1;
                gaps.push(obj(vec![
                    ("case", Json::Str(r.case_name.clone())),
                    ("engine", Json::Str(r.engine.to_string())),
                    ("eps", Json::Num(r.eps)),
                    ("gap", Json::Num(g)),
                    ("bound", Json::Num(r.cert.bound)),
                ]));
            }
        }
        obj(vec![
            (
                "bucket_upper_bounds",
                Json::Arr(
                    GAP_RATIO_BUCKETS
                        .iter()
                        .map(|&b| if b.is_finite() { Json::Num(b) } else { Json::Null })
                        .collect(),
                ),
            ),
            ("counts", Json::Arr(counts.into_iter().map(|c| Json::Num(c as f64)).collect())),
            ("records", Json::Num(self.records.len() as f64)),
            ("failures", Json::Num(self.failure_count() as f64)),
            ("skipped", Json::Num(self.skipped.len() as f64)),
            ("gaps", Json::Arr(gaps)),
        ])
    }

    /// Fixed-width per-record table for CLI output.
    pub fn table(&self) -> String {
        let mut out = String::from(
            "case        engine           eps   cost      exact     gap       bound     verdict\n",
        );
        for r in &self.records {
            let gap = match r.cert.gap {
                Some(g) => format!("{g:.6}"),
                None => "-".to_string(),
            };
            let verdict = if r.ok() { "OK" } else { "FAIL" };
            let t1 = match r.theorem1_ok {
                Some(true) => "",
                Some(false) => " (Thm1 violated)",
                None => " (no guarantee)",
            };
            out.push_str(&format!(
                "{:<11} {:<16} {:<5} {:<9.6} {:<9.6} {:<9} {:<9.6} {verdict}{t1}\n",
                r.case_name, r.engine, r.eps, r.cost, r.exact, gap, r.cert.bound
            ));
        }
        out
    }

    pub fn summary(&self) -> String {
        format!(
            "{} records ({} dual-certified), {} failures, {} solve errors, {} skipped cells",
            self.records.len(),
            self.certified_gaps().len(),
            self.failures().len(),
            self.errors.len(),
            self.skipped.len()
        )
    }
}

/// Cross-check one fixture pin against the in-repo exact oracle.
#[derive(Debug, Clone)]
pub struct PinCheck {
    pub name: String,
    pub pinned: f64,
    pub computed: f64,
}

impl PinCheck {
    pub fn ok(&self) -> bool {
        (self.pinned - self.computed).abs() <= 1e-9
    }
}

/// Recompute every golden pin with the exact oracles (Hungarian for
/// assignment cases, SSP min-cost flow for OT cases).
pub fn verify_golden_pins() -> Result<Vec<PinCheck>> {
    let corpus = golden_corpus()?;
    let mut out = Vec::new();
    for case in &corpus {
        let computed = match case.ot() {
            Some(inst) => SspExactOt::default().solve_ot(&inst, 0.0)?.cost,
            None => hungarian::solve_exact(&case.costs)?.1,
        };
        out.push(PinCheck { name: case.name.clone(), pinned: case.exact_cost, computed });
    }
    Ok(out)
}

/// Additive budget engine `key` promises at accuracy `eps` on answer scale
/// `u`; `None` = no guarantee.
fn guarantee_budget(key: &str, eps: f64, u: f64) -> Option<f64> {
    match key {
        "greedy" => None,
        "hungarian" => Some(0.0),
        // exact up to the θ=2³² mass quantization (non-dyadic uniform
        // masses like 1/5 shift the optimum by ≤ n·c_max/θ ≈ 2e-9)
        "ssp-exact" => Some(1e-7),
        _ => Some(eps * u),
    }
}

/// Sweep the golden corpus. Engines that cannot run a cell (capability or
/// missing backend) are recorded under `skipped`, never silently dropped.
pub fn run(cfg: &ConformanceConfig) -> Result<ConformanceReport> {
    let corpus = golden_corpus()?;
    let registry = SolverRegistry::with_defaults();
    let config = SolverConfig::default();
    let mut report = ConformanceReport::default();
    for case in &corpus {
        let (problem, kind) = problem_for(case);
        for engine in &cfg.engines {
            let Some(entry) = registry.entry(engine) else {
                report.skipped.push((
                    case.name.clone(),
                    engine.clone(),
                    "unknown engine".to_string(),
                ));
                continue;
            };
            let key = entry.key;
            if !entry.supports(kind) {
                report.skipped.push((
                    case.name.clone(),
                    key.to_string(),
                    format!("does not support {} problems", kind.name()),
                ));
                continue;
            }
            for &eps in &cfg.eps {
                let req = SolveRequest::new(eps).certify(true);
                match registry.solve(key, &config, &problem, &req) {
                    // The XLA backends cannot load a runtime in this
                    // environment — an unavailable backend is a skip. Any
                    // other engine erroring on a golden case is a failure.
                    Err(e) if matches!(key, "xla" | "sinkhorn-xla") => {
                        let already = report
                            .skipped
                            .iter()
                            .any(|(c, k, _)| c == &case.name && k == key);
                        if !already {
                            report.skipped.push((case.name.clone(), key.to_string(), e.to_string()));
                        }
                    }
                    Err(e) => {
                        report.errors.push((
                            case.name.clone(),
                            key.to_string(),
                            eps,
                            e.to_string(),
                        ));
                    }
                    Ok(sol) => {
                        let cert =
                            sol.certificate.clone().expect("certify(true) attaches a certificate");
                        let c_max = case.costs.max() as f64;
                        let n = case.costs.na as f64;
                        let (exact, u) = match &sol.coupling {
                            Coupling::Matching(_) => (case.exact_cost, n * c_max),
                            // plan answer to an assignment case: compare on
                            // the uniform-relaxation scale OPT/n
                            Coupling::Plan(_) if !case.is_ot() => (case.exact_cost / n, c_max),
                            Coupling::Plan(_) => (case.exact_cost, c_max),
                        };
                        let budget = guarantee_budget(key, eps, u);
                        let theorem1_ok = budget.map(|b| sol.cost <= exact + b + 1e-9);
                        report.records.push(ConformanceRecord {
                            case_name: case.name.clone(),
                            engine: key,
                            eps,
                            cost: sol.cost,
                            exact,
                            budget,
                            cert,
                            theorem1_ok,
                        });
                    }
                }
            }
        }
    }
    Ok(report)
}

fn problem_for(case: &GoldenCase) -> (Problem, ProblemKind) {
    match case.ot() {
        Some(inst) => (Problem::Ot(inst), ProblemKind::Ot),
        None => (
            Problem::Assignment(case.assignment().expect("golden assignment cases are square")),
            ProblemKind::Assignment,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pins_match_oracles() {
        for pin in verify_golden_pins().unwrap() {
            assert!(
                pin.ok(),
                "{}: pinned {} vs oracle {}",
                pin.name,
                pin.pinned,
                pin.computed
            );
        }
    }

    #[test]
    fn small_sweep_has_no_failures() {
        let cfg = ConformanceConfig {
            engines: vec!["native-seq".into(), "hungarian".into(), "greedy".into()],
            eps: vec![0.25],
        };
        let report = run(&cfg).unwrap();
        assert!(report.failures().is_empty(), "{}", report.table());
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        assert_eq!(report.failure_count(), 0);
        assert!(!report.records.is_empty());
        // hungarian/greedy are assignment-only: 4 OT cases skipped each
        assert_eq!(report.skipped.len(), 8, "{:?}", report.skipped);
        // native-seq exports duals on every cell it ran
        assert!(report
            .records
            .iter()
            .filter(|r| r.engine == "native-seq")
            .all(|r| r.cert.dual_ok == Some(true)));
        // greedy carries no guarantee
        assert!(report
            .records
            .iter()
            .filter(|r| r.engine == "greedy")
            .all(|r| r.theorem1_ok.is_none() && r.budget.is_none()));
    }

    #[test]
    fn histogram_json_is_valid_and_consistent() {
        let cfg = ConformanceConfig {
            engines: vec!["native-seq".into()],
            eps: vec![0.3],
        };
        let report = run(&cfg).unwrap();
        let j = report.gap_histogram_json();
        let parsed = Json::parse(&j.to_string()).expect("valid JSON (no bare inf)");
        let counts: f64 = parsed
            .get("counts")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|c| c.as_f64().unwrap())
            .sum();
        assert_eq!(counts as usize, report.certified_gaps().len());
        assert_eq!(
            parsed.get("gaps").unwrap().as_arr().unwrap().len(),
            report.certified_gaps().len()
        );
    }
}
