//! `otpr` — CLI for the push-relabel OT reproduction.
//!
//! Subcommands:
//!   solve     solve one assignment instance (any registry engine)
//!   ot        solve one OT instance with random masses
//!   serve     run the coordinator service on a synthetic job stream
//!             (--deadline-ms/--max-retries/--degrade arm fault tolerance;
//!             --fault-seed + --fault-{panics,transients,delays} inject a
//!             deterministic chaos storm; --shapes N1,N2 drives multiple
//!             shape-keyed shards; --tenants K admits through per-tenant
//!             quotas [--quota-in-flight/--quota-queue/--tenant-deadline-ms]
//!             with client-side backpressure retries; --cache-bytes B arms
//!             the (digest, ε, engine) result cache over --distinct D
//!             repeating payloads)
//!   engines   list the registered solver engines + aliases
//!   bench     kernel timing sweep {engines}×{n}×{ε} → BENCH_kernel.json
//!             (--compare <baseline.json> adds the perf regression gate);
//!             --serve switches to the serving-layer benchmark (jobs/s,
//!             p50/p95 latency, arena-reuse + cache hit rates per cell)
//!   fig1      regenerate Figure 1 (runtime vs n, synthetic points)
//!   fig2      regenerate Figure 2 (runtime vs ε, MNIST-style images)
//!   ablation  analytical ablations A1–A6 (see DESIGN.md §4)
//!   validate  certify solver output against exact baselines + invariants
//!   certify   golden-corpus conformance sweep: certificates + Theorem 1
//!   analyze   in-tree static analysis: SAFETY/cast/float-eq/no-panic rules
//!             + the kernel byte-identity CONTRACT tripwire (--gate for CI)
//!   info      environment/artifact status
//!
//! Every solve goes through `otpr::api::SolverRegistry` + `SolveRequest`;
//! engine names are the registry keys (aliases like `pr-cpu`, `gpu`,
//! `sinkhorn` are accepted everywhere).

use otpr::api::{Problem, SolveRequest, SolverConfig, SolverRegistry, ENGINE_SPECS};
use otpr::coordinator::{
    Admission, Coordinator, CoordinatorConfig, DegradePolicy, Engine, FaultPlan, JobKind,
    JobStatus, TenantQuota,
};
use otpr::data::workloads::Workload;
use otpr::exp::report::{figure_csv, figure_table};
use otpr::exp::{ablation, fig1, fig2};
use otpr::runtime::XlaRuntime;
use otpr::util::cli::Args;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = match Args::parse_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("solve") => cmd_solve(&args),
        Some("ot") => cmd_ot(&args),
        Some("serve") => cmd_serve(&args),
        Some("engines") => cmd_engines(),
        Some("bench") => cmd_bench(&args),
        Some("fig1") => cmd_fig1(&args),
        Some("fig2") => cmd_fig2(&args),
        Some("ablation") => cmd_ablation(&args),
        Some("validate") => cmd_validate(&args),
        Some("certify") => cmd_certify(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            0
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "otpr — push-relabel additive approximation for optimal transport\n\
         usage: otpr <solve|ot|serve|engines|bench|fig1|fig2|ablation|validate|certify|analyze|info> [--options]\n\
         common options: --n N --eps E --seed S --engine KEY (see `otpr engines`)\n\
         implicit costs: --workload points (solve/serve; O(n) payload, no n² slab), bench --points\n\
         see README.md for the full matrix"
    );
}

fn registry(args: &Args) -> Option<Arc<XlaRuntime>> {
    if args.flag("no-artifacts") {
        return None;
    }
    match XlaRuntime::open_default() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("note: artifacts unavailable ({e}); XLA engines disabled");
            None
        }
    }
}

fn workload(args: &Args, n: usize) -> Workload {
    match args.get_or("workload", "fig1") {
        "fig2" | "images" => Workload::Fig2 { n },
        "random" => Workload::RandomCosts { n },
        "clustered" => Workload::Clustered { n, k: 8, sigma: 0.05 },
        _ => Workload::Fig1 { n },
    }
}

fn cmd_engines() -> i32 {
    println!("registered solver engines (key — aliases — problems):");
    for spec in ENGINE_SPECS {
        let kinds = match (spec.assignment, spec.ot) {
            (true, true) => "assignment+ot",
            (true, false) => "assignment",
            (false, true) => "ot",
            (false, false) => "none",
        };
        let aliases =
            if spec.aliases.is_empty() { "-".to_string() } else { spec.aliases.join(", ") };
        println!("  {:<16} [{kinds:<13}] aliases: {aliases}\n    {}", spec.key, spec.doc);
    }
    println!(
        "  {:<16} [router decides] size- and artifact-aware (serve subcommand only)",
        "auto"
    );
    0
}

fn cmd_solve(args: &Args) -> i32 {
    let n = args.usize_or("n", 1000);
    let eps = args.f64_or("eps", 0.1);
    let seed = args.u64_or("seed", 42);
    let engine = args.get_or("engine", "native");
    if engine == "auto" {
        eprintln!("engine auto is routed by the coordinator — use `otpr serve --engine auto`");
        return 2;
    }
    let solvers = SolverRegistry::with_defaults();
    let Some(key) = solvers.canonical(engine) else {
        eprintln!("unknown engine {engine} (try `otpr engines`)");
        return 2;
    };
    let config = SolverConfig::default()
        .with_runtime(if key == "xla" || key == "sinkhorn-xla" { registry(args) } else { None });
    // `--workload points` (alias `implicit`) solves the Fig1 point cloud
    // through its CostProvider: the job payload and the kernel hold O(n)
    // data — no n² slab is ever materialized.
    let wl_name = args.get_or("workload", "fig1");
    let problem = if wl_name == "points" || wl_name == "implicit" {
        let costs = Workload::Fig1 { n }.implicit_costs(seed).expect("fig1 has an implicit form");
        Problem::implicit_assignment(costs).expect("fig1 is square")
    } else {
        Problem::Assignment(workload(args, n).assignment(seed))
    };
    // ε is the raw algorithm parameter here, matching the paper's plots.
    let request = SolveRequest::new(eps).raw_eps();
    match solvers.solve(key, &config, &problem, &request) {
        Ok(sol) => {
            println!(
                "n={n} eps={eps} engine={key}: cost={:.6} phases={} rounds={} time={:.3}s \
                 cost-state-bytes={}",
                sol.cost,
                sol.stats.phases,
                sol.stats.rounds,
                sol.stats.seconds,
                sol.stats.cost_state_bytes
            );
            if args.flag("exact") {
                let dense = problem.to_dense().expect("materializable for the exact oracle");
                let ex = solvers
                    .solve("hungarian", &config, &dense, &SolveRequest::new(0.0))
                    .expect("exact baseline");
                let c_max = problem.max_cost();
                println!(
                    "exact={:.6} additive-error={:.6} (guarantee 3εn·c_max = {:.6})",
                    ex.cost,
                    sol.cost - ex.cost,
                    3.0 * eps * n as f64 * c_max
                );
            }
            0
        }
        Err(e) => {
            eprintln!("solve failed: {e}");
            1
        }
    }
}

fn cmd_ot(args: &Args) -> i32 {
    let n = args.usize_or("n", 200);
    let eps = args.f64_or("eps", 0.1);
    let seed = args.u64_or("seed", 42);
    let engine = args.get_or("engine", "pr");
    let solvers = SolverRegistry::with_defaults();
    // For the OT subcommand `exact` means the exact OT oracle, not Hungarian.
    let key = match engine {
        "exact" => "ssp-exact",
        "auto" => {
            eprintln!("engine auto is routed by the coordinator — use `otpr serve --engine auto`");
            return 2;
        }
        other => match solvers.canonical(other) {
            Some(k) => k,
            None => {
                eprintln!("unknown OT engine {other} (try `otpr engines`)");
                return 2;
            }
        },
    };
    let config = SolverConfig::default();
    // `--workload points` (alias `implicit`) solves the Fig1 point cloud
    // through its CostProvider with the same random masses: the kernel
    // holds O(n²/8) block-min bytes and the answer is an O(nnz) CSR plan —
    // no nb·na slab on either side of the solve.
    let wl_name = args.get_or("workload", "fig1");
    let problem = if wl_name == "points" || wl_name == "implicit" {
        let (costs, demand, supply) = Workload::Fig1 { n }
            .implicit_ot_with_random_masses(seed)
            .expect("fig1 has an implicit form");
        Problem::implicit_ot(costs, demand, supply).expect("valid masses")
    } else {
        Problem::Ot(workload(args, n).ot_with_random_masses(seed))
    };
    match solvers.solve(key, &config, &problem, &SolveRequest::new(eps)) {
        Ok(sol) => {
            let support = sol.plan().map(|p| p.support_size()).unwrap_or(0);
            let repr = sol.plan().map(|p| p.repr_kind()).unwrap_or("-");
            println!(
                "OT n={n} eps={eps} engine={key}: cost={:.6} phases={} support={} plan={repr} \
                 time={:.3}s cost-state-bytes={} plan-state-bytes={} {}",
                sol.cost,
                sol.stats.phases,
                support,
                sol.stats.seconds,
                sol.stats.cost_state_bytes,
                sol.stats.plan_state_bytes,
                sol.stats.notes.join(" ")
            );
            if args.flag("exact") && key != "ssp-exact" {
                // the exact oracle is slab-bound: hand it a dense twin
                let dense = problem.to_dense().expect("materializable for the exact oracle");
                let ex = solvers
                    .solve("ssp-exact", &config, &dense, &SolveRequest::new(0.0))
                    .expect("exact baseline");
                println!(
                    "exact={:.6} additive-error={:.6} (guarantee ε·c_max = {:.6})",
                    ex.cost,
                    sol.cost - ex.cost,
                    eps * problem.max_cost()
                );
            }
            0
        }
        Err(e) => {
            eprintln!("OT solve failed: {e}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let jobs = args.usize_or("jobs", 32);
    let workers = args.usize_or("workers", 4);
    let n = args.usize_or("n", 200);
    let eps = args.f64_or("eps", 0.2);
    let engine = match Engine::try_parse(args.get_or("engine", "auto")) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let budget_ms = args.u64_or("budget-ms", 0);
    let audit = args.u64_or("audit", 0);
    // serving-layer knobs: multi-shape traffic (one shard per shape),
    // per-tenant quotas with client-side backpressure retries, the
    // (digest, ε, engine) result cache over repeating payloads
    let shapes = args.list_usize("shapes", &[n]);
    let tenants_n = args.usize_or("tenants", 0);
    let quota_in_flight = args.usize_or("quota-in-flight", usize::MAX);
    let quota_queue = args.usize_or("quota-queue", usize::MAX);
    let tenant_deadline_ms = args.u64_or("tenant-deadline-ms", 0);
    let cache_bytes = args.u64_or("cache-bytes", 0);
    let distinct = args.usize_or("distinct", jobs.max(1));
    // fault-tolerance knobs: per-tenant deadline, retry budget, degraded-ε
    // answers under deadline pressure, and a seeded chaos plan
    let deadline_ms = args.u64_or("deadline-ms", 0);
    let max_retries = args.u64_or("max-retries", 2) as u32;
    let restart_budget = args.u64_or("restart-budget", 4) as u32;
    let degrade_enabled = args.flag("degrade");
    let grace_ms = args.u64_or("grace-ms", 100);
    let fault_panics = args.usize_or("fault-panics", 0);
    let fault_transients = args.usize_or("fault-transients", 0);
    let fault_delays = args.usize_or("fault-delays", 0);
    let faults = if fault_panics + fault_transients + fault_delays > 0 {
        let plan = FaultPlan::seeded(
            args.u64_or("fault-seed", 42),
            jobs as u64,
            fault_panics,
            fault_transients,
            fault_delays,
            Duration::from_millis(args.u64_or("fault-delay-ms", 5)),
        );
        println!("fault plan: {} scheduled fault(s) across {jobs} jobs", plan.len());
        Some(Arc::new(plan))
    } else {
        None
    };
    let reg = registry(args);
    println!(
        "coordinator: {workers} workers/shard, {jobs} jobs over shapes {shapes:?} (engine={}{}{}{})",
        engine.name(),
        if audit > 0 { format!(", auditing every {audit}th job") } else { String::new() },
        if tenants_n > 0 { format!(", {tenants_n} tenants") } else { String::new() },
        if cache_bytes > 0 { format!(", {cache_bytes}B result cache") } else { String::new() }
    );
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers,
            audit_sample_every: audit,
            default_deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
            max_retries,
            restart_budget,
            degrade: DegradePolicy {
                enabled: degrade_enabled,
                grace: Duration::from_millis(grace_ms),
                ..Default::default()
            },
            faults,
            max_shards: args.usize_or("max-shards", 8),
            shard_idle_ttl: Duration::from_millis(args.u64_or("shard-ttl-ms", 30_000)),
            cache_bytes,
            tenants: (0..tenants_n)
                .map(|t| {
                    (
                        format!("t{t}"),
                        TenantQuota {
                            max_in_flight: quota_in_flight,
                            max_queue_depth: quota_queue,
                            default_deadline: (tenant_deadline_ms > 0)
                                .then(|| Duration::from_millis(tenant_deadline_ms)),
                        },
                    )
                })
                .collect(),
            ..Default::default()
        },
        reg,
    );
    let implicit_jobs = matches!(args.get_or("workload", "fig1"), "points" | "implicit");
    // With --tenants, submissions go through admit(): a saturated quota
    // answers Backpressure{retry_after} instead of enqueueing, and this
    // client loop honors the hint — sleep, retry, count. Without tenants
    // the legacy blocking submit_request() path is exercised instead.
    let mut backpressured_admissions = 0u64;
    let admission_stall = std::time::Instant::now() + Duration::from_secs(120);
    let mut handles = Vec::with_capacity(jobs);
    for i in 0..jobs {
        let n_i = shapes[i % shapes.len()];
        // repeating seeds (i mod --distinct) make later payloads exact
        // duplicates of earlier ones — result-cache traffic
        let seed = (i % distinct.max(1)) as u64;
        // implicit job payloads ship O(n) point data, not the n² slab
        let kind = if implicit_jobs {
            JobKind::implicit_assignment(
                Workload::Fig1 { n: n_i }.implicit_costs(seed).expect("fig1 implicit"),
            )
            .expect("fig1 is square")
        } else {
            JobKind::Assignment(workload(args, n_i).assignment(seed))
        };
        let mut request = SolveRequest::new(eps);
        if budget_ms > 0 {
            request = request.with_budget(Duration::from_millis(budget_ms));
        }
        let handle = if tenants_n > 0 {
            let request = request.for_tenant(format!("t{}", i % tenants_n));
            loop {
                match coord.admit(kind.clone(), request.clone(), engine).expect("admit") {
                    Admission::Accepted(h) => break h,
                    Admission::Backpressure { retry_after } => {
                        backpressured_admissions += 1;
                        if std::time::Instant::now() >= admission_stall {
                            eprintln!("admission starved for 120s; giving up");
                            return 1;
                        }
                        std::thread::sleep(retry_after);
                    }
                }
            }
        } else {
            coord.submit_request(kind, request, engine).expect("submit")
        };
        handles.push(handle);
    }
    let mut ok = 0;
    let mut cancelled = 0;
    let mut degraded = 0;
    let mut shed = 0;
    for h in handles {
        match h.wait() {
            Ok(out) => match (out.status, out.result) {
                (JobStatus::Shed { retry_after }, _) => {
                    shed += 1;
                    eprintln!("job {} shed: deadline passed (retry after {retry_after:?})", out.id);
                }
                (status, Ok(sol)) => {
                    ok += 1;
                    if let JobStatus::Degraded { eps } = status {
                        degraded += 1;
                        println!("job {} answered at degraded eps={eps:.4}", out.id);
                    }
                    if sol.is_cancelled() {
                        cancelled += 1;
                    }
                }
                (_, Err(e)) => eprintln!("job {} failed: {e}", out.id),
            },
            Err(e) => eprintln!("join error: {e}"),
        }
    }
    if cancelled > 0 {
        println!("{cancelled}/{jobs} jobs hit the {budget_ms}ms budget");
    }
    if degraded + shed > 0 {
        println!(
            "degraded answers: {degraded}/{jobs}, shed past deadline: {shed}/{jobs} \
             (shed jobs are a contract outcome, not failures)"
        );
    }
    if backpressured_admissions > 0 {
        println!(
            "{backpressured_admissions} admission(s) backpressured and retried \
             (quota: {quota_in_flight} in flight, {quota_queue} queued per tenant)"
        );
    }
    // Shut down BEFORE exporting: audit certificates are recorded after
    // each reply is sent, so the export is only complete once the worker
    // threads have been joined.
    let metrics = coord.metrics.clone();
    coord.shutdown();
    println!("{ok}/{jobs} jobs succeeded\n{}", metrics.snapshot());
    // the service's /metrics document: job counters, per-key batch
    // occupancy, kernel-arena reuse hits, audit section
    if let Some(path) = args.get("metrics-out") {
        let json = metrics.to_json().to_string();
        match std::fs::write(path, json) {
            Ok(()) => println!("metrics JSON written to {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
    // Every job must reach a contract outcome: served/degraded (ok) or
    // shed with a retry hint. Only Failed jobs make the exit nonzero.
    if ok + shed == jobs {
        0
    } else {
        1
    }
}

fn cmd_bench(args: &Args) -> i32 {
    use otpr::exp::bench_kernel::{
        compare, compare_table, gate_health, load_baseline, regressions, run, table, to_json,
        BenchKernelConfig,
    };
    // `--serve` measures the serving path (coordinator + shards + cache),
    // not the bare kernel — a different harness and artifact schema.
    if args.flag("serve") {
        return cmd_bench_serve(args);
    }
    let mut cfg = if args.flag("smoke") {
        BenchKernelConfig::smoke()
    } else {
        BenchKernelConfig::default()
    };
    if let Some(engines) = args.get("engines") {
        cfg.engines = engines.split(',').map(|s| s.trim().to_string()).collect();
    }
    if args.get("sizes").is_some() {
        cfg.sizes = args.list_usize("sizes", &cfg.sizes.clone());
    }
    if args.get("eps").is_some() {
        cfg.eps = args.list_f64("eps", &cfg.eps.clone());
    }
    cfg.reps = args.usize_or("reps", cfg.reps);
    cfg.seed = args.u64_or("seed", cfg.seed);
    cfg.points = args.flag("points");
    println!(
        "kernel bench: {} engines × sizes {:?} × eps {:?}, {} reps ({} costs)",
        cfg.engines.len(),
        cfg.sizes,
        cfg.eps,
        cfg.reps,
        if cfg.points { "implicit point-cloud" } else { "dense" }
    );
    let records = run(&cfg);
    println!("{}", table(&records));
    let out = args.get_or("out", "BENCH_kernel.json");
    let json = to_json(&cfg, &records).to_string();
    if let Err(e) = std::fs::write(out, json) {
        eprintln!("could not write {out}: {e}");
        return 1;
    }
    println!("bench records written to {out}");
    // unavailable XLA cells are expected offline; only native failures gate
    let native_errors = records
        .iter()
        .filter(|r| r.error.is_some() && !r.engine.contains("xla") && !r.engine.contains("gpu"))
        .count();
    if native_errors > 0 {
        eprintln!("{native_errors} native bench cell(s) failed");
        return 1;
    }
    // perf regression gate: --compare <baseline.json> joins on
    // (engine, n, eps) and fails on a >--gate (default 10%) regression of
    // each engine's ns/op *relative to native-seq in the same run* — the
    // host-independent ratio, so a committed baseline from another
    // machine still gates meaningfully.
    if let Some(base_path) = args.get("compare") {
        let threshold = args.f64_or("gate", 0.10);
        let baseline = match std::fs::read_to_string(base_path)
            .map_err(|e| e.to_string())
            .and_then(|t| load_baseline(&t))
        {
            Ok(b) => b,
            Err(e) => {
                eprintln!("could not load baseline {base_path}: {e}");
                return 1;
            }
        };
        let cells = compare(&records, &baseline);
        // A gate that cannot inspect anything must fail loudly, not pass
        // with zero joined cells (the pre-PR-7 vacuous-green bug).
        if let Err(e) = gate_health(&cells) {
            eprintln!("PERF GATE UNUSABLE vs {base_path}: {e}");
            return 1;
        }
        println!("comparison vs {base_path}:\n{}", compare_table(&cells));
        let regs = regressions(&cells, threshold);
        if !regs.is_empty() {
            for r in &regs {
                eprintln!("PERF REGRESSION: {r}");
            }
            return 1;
        }
        println!(
            "perf gate: no regression > {:.0}% vs {base_path} ({} cells)",
            threshold * 100.0,
            cells.len()
        );
    }
    0
}

fn cmd_bench_serve(args: &Args) -> i32 {
    use otpr::exp::bench_serve::{run, table, to_json, BenchServeConfig};
    let mut cfg =
        if args.flag("smoke") { BenchServeConfig::smoke() } else { BenchServeConfig::default() };
    if args.get("sizes").is_some() {
        cfg.sizes = args.list_usize("sizes", &cfg.sizes.clone());
    }
    cfg.jobs = args.usize_or("jobs", cfg.jobs);
    cfg.workers = args.usize_or("workers", cfg.workers);
    cfg.eps = args.f64_or("eps", cfg.eps);
    cfg.seed = args.u64_or("seed", cfg.seed);
    cfg.distinct = args.usize_or("distinct", cfg.distinct);
    cfg.cache_bytes = args.u64_or("cache-bytes", cfg.cache_bytes);
    cfg.engine = match Engine::try_parse(args.get_or("engine", cfg.engine.name())) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    println!(
        "serving bench: sizes {:?} × {} jobs ({} distinct payloads), {} workers/shard, \
         {}B cache, engine={}",
        cfg.sizes,
        cfg.jobs,
        cfg.distinct,
        cfg.workers,
        cfg.cache_bytes,
        cfg.engine.name()
    );
    let records = run(&cfg);
    println!("{}", table(&records));
    let out = args.get_or("out", "BENCH_serve.json");
    let json = to_json(&cfg, &records).to_string();
    if let Err(e) = std::fs::write(out, json) {
        eprintln!("could not write {out}: {e}");
        return 1;
    }
    println!("serving bench records written to {out}");
    let failures = records.iter().filter(|r| r.error.is_some()).count();
    if failures > 0 {
        eprintln!("{failures} serving cell(s) had failing jobs");
        return 1;
    }
    0
}

fn cmd_fig1(args: &Args) -> i32 {
    let cfg = fig1::Fig1Config {
        sizes: args.list_usize("sizes", &[500, 1000, 2000]),
        eps: args.list_f64("eps", &[0.1, 0.01, 0.005]),
        reps: args.usize_or("reps", 3),
        seed: args.u64_or("seed", 42),
        max_secs_per_run: args.f64_or("max-secs", 120.0),
        engines: args
            .get("engines")
            .map(|s| s.split(',').map(String::from).collect())
            .unwrap_or_else(|| fig1::Fig1Config::default().engines),
    };
    let reg = registry(args);
    for &eps in &cfg.eps {
        let series = fig1::run_eps(&cfg, eps, reg.clone());
        println!(
            "{}",
            figure_table(&format!("Figure 1 — runtime (s) vs n, ε = {eps}"), "n", &series)
        );
        if args.flag("csv") {
            println!("{}", figure_csv("n", &series));
        }
    }
    0
}

fn cmd_fig2(args: &Args) -> i32 {
    let cfg = fig2::Fig2Config {
        n: args.usize_or("n", 1000),
        eps: args.list_f64("eps", &[0.75, 0.5, 0.25, 0.1]),
        reps: args.usize_or("reps", 3),
        seed: args.u64_or("seed", 7),
        engines: args
            .get("engines")
            .map(|s| s.split(',').map(String::from).collect())
            .unwrap_or_else(|| fig2::Fig2Config::default().engines),
    };
    let reg = registry(args);
    let (series, real) = fig2::run(&cfg, reg);
    let src = if real { "real MNIST" } else { "synthetic MNIST-like" };
    println!(
        "{}",
        figure_table(&format!("Figure 2 — runtime (s) vs ε, n = {} ({src})", cfg.n), "eps", &series)
    );
    if args.flag("csv") {
        println!("{}", figure_csv("eps", &series));
    }
    0
}

fn cmd_ablation(args: &Args) -> i32 {
    let which = args.get_or("which", "all");
    let seed = args.u64_or("seed", 42);
    let n = args.usize_or("n", 300);
    if which == "phases" || which == "all" {
        let series =
            ablation::phases_vs_eps(n, &args.list_f64("eps", &[0.3, 0.2, 0.1, 0.05, 0.02]), seed);
        println!("{}", figure_table("A1 — phases vs ε (bound: (1+2ε)/ε²)", "eps", &series));
    }
    if which == "rounds" || which == "all" {
        let series =
            ablation::rounds_vs_n(&args.list_usize("sizes", &[64, 128, 256, 512, 1024]), 0.1, seed);
        println!("{}", figure_table("A2 — propose-accept rounds/phase vs n", "n", &series));
    }
    if which == "accuracy" || which == "all" {
        let series =
            ablation::accuracy(n.min(500), &args.list_f64("eps", &[0.3, 0.1, 0.05, 0.02]), seed);
        println!("{}", figure_table("A3 — additive error vs guarantee", "eps", &series));
        let series = ablation::ot_accuracy(40, &[0.4, 0.2, 0.1], seed);
        println!("{}", figure_table("A3b — OT additive error", "eps", &series));
    }
    if which == "clusters" || which == "all" {
        let series = ablation::clusters(&args.list_usize("sizes", &[20, 50, 100, 200]), 0.2, seed);
        println!("{}", figure_table("A4 — max dual clusters (Lemma 4.1 bound: 2)", "n", &series));
    }
    if which == "sinkhorn-stability" || which == "all" {
        let series = ablation::sinkhorn_stability(
            n.min(200),
            &args.list_f64("eps", &[0.5, 0.1, 0.01, 0.001]),
            seed,
        );
        println!("{}", figure_table("A5 — Sinkhorn stability (std vs log-domain)", "eps", &series));
    }
    if which == "threads" || which == "all" {
        let series =
            ablation::threads(n.max(512), 0.05, &args.list_usize("threads", &[1, 2, 4, 8]), seed);
        println!("{}", figure_table("A6 — parallel solver scaling", "threads", &series));
    }
    if which == "complexity" || which == "all" {
        let (k, r2) = ablation::complexity_exponent(
            &args.list_usize("sizes", &[128, 256, 512, 1024]),
            0.1,
            seed,
        );
        println!("## A7 — sequential time ~ n^k at fixed ε\n\nk = {k:.2} (r² = {r2:.3}); paper bound: k = 2\n");
    }
    0
}

fn cmd_validate(args: &Args) -> i32 {
    let n = args.usize_or("n", 100);
    let eps = args.f64_or("eps", 0.1);
    let seed = args.u64_or("seed", 42);
    let solvers = SolverRegistry::with_defaults();
    let config = SolverConfig::default().with_paranoid(true);
    let mut failures = 0;
    println!("validating push-relabel against exact baselines (n={n}, eps={eps}, seed={seed})");
    for (name, wl) in [
        ("fig1", Workload::Fig1 { n }),
        ("random", Workload::RandomCosts { n }),
        ("fig2", Workload::Fig2 { n }),
    ] {
        let problem = Problem::Assignment(wl.assignment(seed));
        let c_max = problem.costs().max() as f64;
        let pr = solvers
            .solve("native-seq", &config, &problem, &SolveRequest::new(eps).raw_eps())
            .unwrap();
        let ex = solvers
            .solve("hungarian", &config, &problem, &SolveRequest::new(0.0))
            .unwrap();
        let budget = 3.0 * eps * n as f64 * c_max;
        let err = pr.cost - ex.cost;
        let ok = err <= budget + 1e-9;
        println!(
            "  {name:<9} pr={:.5} exact={:.5} err={:.5} budget={:.5} [{}]",
            pr.cost,
            ex.cost,
            err,
            budget,
            if ok { "OK" } else { "FAIL" }
        );
        if !ok {
            failures += 1;
        }
    }
    // OT spot-check
    let problem = Problem::Ot(Workload::Fig1 { n: n.min(60) }.ot_with_random_masses(seed));
    let pr = solvers.solve("native-seq", &config, &problem, &SolveRequest::new(eps)).unwrap();
    let ex = solvers.solve("ssp-exact", &config, &problem, &SolveRequest::new(0.0)).unwrap();
    let budget = eps * problem.costs().max() as f64;
    let err = pr.cost - ex.cost;
    let ok = err <= budget + 1e-9;
    println!(
        "  ot        pr={:.5} exact={:.5} err={:.5} budget={:.5} [{}]",
        pr.cost,
        ex.cost,
        err,
        budget,
        if ok { "OK" } else { "FAIL" }
    );
    if !ok {
        failures += 1;
    }
    if failures == 0 {
        println!("all validations passed");
        0
    } else {
        eprintln!("{failures} validation(s) FAILED");
        1
    }
}

fn cmd_certify(args: &Args) -> i32 {
    use otpr::exp::conformance::{run, verify_golden_pins, ConformanceConfig};
    let mut cfg = ConformanceConfig::default();
    if let Some(engines) = args.get("engines") {
        cfg.engines = engines.split(',').map(|s| s.trim().to_string()).collect();
    }
    cfg.eps = args.list_f64("eps", &[0.4, 0.2, 0.1]);
    println!(
        "golden-corpus conformance sweep ({} engines × eps {:?}, fixtures in {})",
        cfg.engines.len(),
        cfg.eps,
        otpr::data::workloads::golden_dir().display()
    );
    let mut failures = 0usize;
    match verify_golden_pins() {
        Err(e) => {
            eprintln!("pin verification failed: {e}");
            return 1;
        }
        Ok(pins) => {
            for pin in &pins {
                let ok = pin.ok();
                println!(
                    "  pin {:<11} fixture={:<12} oracle={:<12} [{}]",
                    pin.name,
                    pin.pinned,
                    pin.computed,
                    if ok { "OK" } else { "FAIL" }
                );
                if !ok {
                    failures += 1;
                }
            }
        }
    }
    let report = match run(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("conformance run failed: {e}");
            return 1;
        }
    };
    println!("\n{}", report.table());
    for (case, engine, why) in &report.skipped {
        println!("  skipped {case} × {engine}: {why}");
    }
    for (case, engine, eps, err) in &report.errors {
        eprintln!("  ERROR {case} × {engine} at eps={eps}: {err}");
    }
    println!("\n{}", report.summary());
    failures += report.failure_count();
    if let Some(out) = args.get("out") {
        let json = report.gap_histogram_json().to_string();
        if let Err(e) = std::fs::write(out, json) {
            eprintln!("could not write {out}: {e}");
            return 1;
        }
        println!("gap histogram written to {out}");
    }
    if failures == 0 {
        println!("all certificates and differential checks passed");
        0
    } else {
        eprintln!("{failures} conformance failure(s)");
        1
    }
}

fn cmd_analyze(args: &Args) -> i32 {
    use otpr::exp::analyze::{run, Allowlist};
    use std::path::{Path, PathBuf};
    // default root works both from the repo top (`rust/src`) and from
    // inside `rust/` (`src`), matching how the other subcommands locate
    // their fixtures
    let root = PathBuf::from(
        args.get_or("root", if Path::new("rust/src").is_dir() { "rust/src" } else { "src" }),
    );
    let default_allow = root
        .parent()
        .map(|p| p.join("analyze-allow.toml"))
        .unwrap_or_else(|| PathBuf::from("analyze-allow.toml"));
    let allow_path = args.get("allow").map(PathBuf::from).unwrap_or(default_allow);
    let allow = if allow_path.exists() {
        match std::fs::read_to_string(&allow_path)
            .map_err(|e| e.to_string())
            .and_then(|t| Allowlist::parse(&t))
        {
            Ok(a) => a,
            Err(e) => {
                eprintln!("could not load allowlist {}: {e}", allow_path.display());
                return 2;
            }
        }
    } else {
        Allowlist::empty()
    };
    let report = match run(&root, &allow) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("analyze failed: {e}");
            return 2;
        }
    };
    println!("{}", report.table());
    if let Some(out) = args.get("json") {
        let json = report.to_json().to_string();
        if let Err(e) = std::fs::write(out, json) {
            eprintln!("could not write {out}: {e}");
            return 2;
        }
        println!("analyze report written to {out}");
    }
    if report.findings.is_empty() {
        0
    } else {
        if args.flag("gate") {
            eprintln!(
                "analyze gate: {} finding(s) — fix, annotate in-source, or add a justified \
                 allowlist entry",
                report.findings.len()
            );
        }
        1
    }
}

fn cmd_info(args: &Args) -> i32 {
    println!("otpr {} — push-relabel OT reproduction", env!("CARGO_PKG_VERSION"));
    println!("threads available: {}", otpr::util::pool::default_threads());
    println!("engines registered: {}", SolverRegistry::with_defaults().keys().join(", "));
    match registry(args) {
        Some(reg) => {
            println!(
                "artifacts: {} specs, sizes {:?} (dir {})",
                reg.registry.specs.len(),
                reg.registry.sizes,
                reg.registry.dir.display()
            );
        }
        None => println!("artifacts: none (run `make artifacts`)"),
    }
    match registry(args)
        .ok_or_else(|| otpr::core::OtprError::Runtime("no runtime".into()))
        .and_then(|r| r.call(|ctx| Ok((ctx.client.platform_name(), ctx.client.device_count()))))
    {
        Ok((platform, devices)) => println!("pjrt: platform={platform} devices={devices}"),
        Err(e) => println!("pjrt: unavailable ({e})"),
    }
    0
}
