//! # otpr — push-relabel additive approximation for optimal transport
//!
//! Production-oriented reproduction of Lahn–Raghvendra–Zhang,
//! *"A Push-Relabel Based Additive Approximation for Optimal Transport"*
//! (2022), as a three-layer Rust + JAX/Pallas stack:
//!
//! * [`solvers`] — the paper's algorithm (sequential §2.2, parallel §3.2,
//!   OT extension §4) and every baseline (exact Hungarian, exact SSP OT,
//!   Sinkhorn, greedy), over [`core`] domain types.
//! * [`runtime`] — PJRT execution of the AOT-compiled XLA artifacts
//!   produced by `python/compile/aot.py` (JAX model + Pallas kernels); the
//!   "GPU implementation" analog of the paper on this CPU-only testbed.
//! * [`coordinator`] — the serving layer: job router, batcher, worker pool
//!   and metrics, so OT solves are consumable as a service.
//! * [`exp`] — harnesses that regenerate the paper's Figure 1 / Figure 2
//!   series and the analytical ablations (see DESIGN.md §4).
//!
//! See `examples/quickstart.rs` for the 20-line tour.

pub mod coordinator;
pub mod core;
pub mod data;
pub mod exp;
pub mod runtime;
pub mod solvers;
pub mod util;
