//! # otpr — push-relabel additive approximation for optimal transport
//!
//! Production-oriented reproduction of Lahn–Raghvendra–Zhang,
//! *"A Push-Relabel Based Additive Approximation for Optimal Transport"*
//! (2022), as a three-layer Rust + JAX/Pallas stack:
//!
//! * [`api`] — **the public solve surface**: one [`api::Problem`] /
//!   [`api::Solution`] model, a typed [`api::SolverRegistry`] of named
//!   engines, and an [`api::SolveRequest`] builder carrying accuracy,
//!   wall-clock budget, cancellation, and progress observation. Every
//!   consumer (CLI, coordinator, experiment harnesses, examples) invokes
//!   solvers through this layer.
//! * [`solvers`] — the paper's algorithm (sequential §2.2, parallel §3.2,
//!   OT extension §4) and every baseline (exact Hungarian, exact SSP OT,
//!   Sinkhorn, greedy, LMR'19), over [`core`] domain types. Reached via
//!   the registry; the legacy per-kind traits remain for algorithm-level
//!   tests.
//! * [`runtime`] — PJRT execution of the AOT-compiled XLA artifacts
//!   produced by `python/compile/aot.py` (JAX model + Pallas kernels); the
//!   "GPU implementation" analog of the paper on this CPU-only testbed.
//!   Builds against an in-tree stub unless the `xla` feature is enabled.
//! * [`coordinator`] — the serving layer: job router (registry-backed),
//!   batcher, worker pool and metrics, so OT solves are consumable as a
//!   service with backpressure, per-job budgets, and live phase metrics.
//! * [`exp`] — harnesses that regenerate the paper's Figure 1 / Figure 2
//!   series and the analytical ablations (see DESIGN.md §4), driving every
//!   engine through the registry.
//!
//! ```no_run
//! use otpr::api::{Problem, SolveRequest, SolverConfig, SolverRegistry};
//! use otpr::data::workloads::Workload;
//!
//! let registry = SolverRegistry::with_defaults();
//! let problem = Problem::Assignment(Workload::Fig1 { n: 500 }.assignment(42));
//! let sol = registry
//!     .solve("native-seq", &SolverConfig::default(), &problem, &SolveRequest::new(0.1))
//!     .unwrap();
//! assert!(sol.matching().unwrap().is_perfect());
//! ```
//!
//! See `examples/quickstart.rs` for the full tour and
//! `rust/src/api/README.md` for the registry/request reference.

pub mod api;
pub mod coordinator;
pub mod core;
pub mod data;
pub mod exp;
pub mod runtime;
pub mod solvers;
pub mod util;
