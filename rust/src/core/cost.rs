//! Dense cost matrices.
//!
//! Layout convention throughout the crate: **rows are supply vertices b ∈ B,
//! columns are demand vertices a ∈ A**, row-major. The inner loop of every
//! solver scans "all a for a fixed b", so this keeps the hot scan contiguous.
//! The paper's costs satisfy c(a,b) ∈ [0, 1] after scaling; [`CostMatrix`]
//! stores raw costs and exposes [`CostMatrix::max`] so solvers can normalize.

use crate::core::error::{OtprError, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct CostMatrix {
    /// |B| — number of supply vertices (rows).
    pub nb: usize,
    /// |A| — number of demand vertices (columns).
    pub na: usize,
    data: Vec<f32>,
}

impl CostMatrix {
    pub fn zeros(nb: usize, na: usize) -> Self {
        Self { nb, na, data: vec![0.0; nb * na] }
    }

    pub fn from_vec(nb: usize, na: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != nb * na {
            return Err(OtprError::InvalidInstance(format!(
                "cost data length {} != {}x{}",
                data.len(),
                nb,
                na
            )));
        }
        if data.iter().any(|c| !c.is_finite() || *c < 0.0) {
            return Err(OtprError::InvalidInstance(
                "costs must be finite and non-negative".into(),
            ));
        }
        Ok(Self { nb, na, data })
    }

    /// Build from a function of (b, a).
    pub fn from_fn(nb: usize, na: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(nb * na);
        for b in 0..nb {
            for a in 0..na {
                data.push(f(b, a));
            }
        }
        Self { nb, na, data }
    }

    #[inline]
    pub fn at(&self, b: usize, a: usize) -> f32 {
        debug_assert!(b < self.nb && a < self.na);
        self.data[b * self.na + a]
    }

    #[inline]
    pub fn row(&self, b: usize) -> &[f32] {
        &self.data[b * self.na..(b + 1) * self.na]
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Largest entry (0 for an empty matrix).
    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(0.0, f32::max)
    }

    /// Transposed copy (rows become A). Only used by baselines that want the
    /// opposite orientation.
    pub fn transposed(&self) -> CostMatrix {
        let mut data = vec![0.0; self.data.len()];
        for b in 0..self.nb {
            for a in 0..self.na {
                data[a * self.nb + b] = self.at(b, a);
            }
        }
        CostMatrix { nb: self.na, na: self.nb, data }
    }

    /// Pad to (nb2, na2) with `fill` (used by the runtime router to fit
    /// fixed-shape artifacts).
    pub fn padded(&self, nb2: usize, na2: usize, fill: f32) -> CostMatrix {
        assert!(nb2 >= self.nb && na2 >= self.na);
        let mut out = CostMatrix { nb: nb2, na: na2, data: vec![fill; nb2 * na2] };
        for b in 0..self.nb {
            out.data[b * na2..b * na2 + self.na].copy_from_slice(self.row(b));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_fn_and_at() {
        let c = CostMatrix::from_fn(2, 3, |b, a| (10 * b + a) as f32);
        assert_eq!(c.at(0, 0), 0.0);
        assert_eq!(c.at(1, 2), 12.0);
        assert_eq!(c.row(1), &[10.0, 11.0, 12.0]);
        assert_eq!(c.max(), 12.0);
    }

    #[test]
    fn from_vec_validates() {
        assert!(CostMatrix::from_vec(2, 2, vec![0.0; 3]).is_err());
        assert!(CostMatrix::from_vec(1, 2, vec![0.0, -1.0]).is_err());
        assert!(CostMatrix::from_vec(1, 2, vec![0.0, f32::NAN]).is_err());
        assert!(CostMatrix::from_vec(1, 2, vec![0.5, 1.0]).is_ok());
    }

    #[test]
    fn transpose_roundtrip() {
        let c = CostMatrix::from_fn(3, 4, |b, a| (b * 4 + a) as f32);
        let t = c.transposed();
        assert_eq!(t.nb, 4);
        assert_eq!(t.at(2, 1), c.at(1, 2));
        assert_eq!(t.transposed(), c);
    }

    #[test]
    fn padding_keeps_block_and_fills() {
        let c = CostMatrix::from_fn(2, 2, |b, a| (b + a) as f32);
        let p = c.padded(3, 4, 9.0);
        assert_eq!(p.at(1, 1), 2.0);
        assert_eq!(p.at(2, 3), 9.0);
        assert_eq!(p.at(0, 2), 9.0);
    }

    #[test]
    fn empty_max_is_zero() {
        assert_eq!(CostMatrix::zeros(0, 0).max(), 0.0);
    }
}
