//! Matchings between B (supply, rows) and A (demand, columns).
//!
//! `-1` encodes "free" on both sides so the representation is bit-identical
//! to the int32 match arrays used by the XLA `phase_step` artifact.

use crate::core::cost::CostMatrix;

pub const FREE: i32 = -1;

#[derive(Debug, Clone, PartialEq)]
pub struct Matching {
    /// match_b[b] = a or FREE.
    pub match_b: Vec<i32>,
    /// match_a[a] = b or FREE.
    pub match_a: Vec<i32>,
}

impl Matching {
    pub fn empty(nb: usize, na: usize) -> Self {
        Self { match_b: vec![FREE; nb], match_a: vec![FREE; na] }
    }

    /// An arbitrary complete matching (index order) — the answer shape
    /// every layer returns for a solve stopped at phase 0, defined once
    /// (see `api::adapter` and the kernel drivers).
    pub fn arbitrary_complete(nb: usize, na: usize) -> Self {
        let mut m = Self::empty(nb, na);
        m.complete_arbitrarily();
        m
    }

    pub fn nb(&self) -> usize {
        self.match_b.len()
    }

    pub fn na(&self) -> usize {
        self.match_a.len()
    }

    /// Number of matched edges.
    pub fn size(&self) -> usize {
        self.match_b.iter().filter(|&&a| a != FREE).count()
    }

    #[inline]
    pub fn is_b_free(&self, b: usize) -> bool {
        self.match_b[b] == FREE
    }

    #[inline]
    pub fn is_a_free(&self, a: usize) -> bool {
        self.match_a[a] == FREE
    }

    /// Match (b, a), detaching any previous partners of either endpoint.
    pub fn link(&mut self, b: usize, a: usize) {
        let old_a = self.match_b[b];
        if old_a != FREE {
            self.match_a[old_a as usize] = FREE;
        }
        let old_b = self.match_a[a];
        if old_b != FREE {
            self.match_b[old_b as usize] = FREE;
        }
        self.match_b[b] = a as i32;
        self.match_a[a] = b as i32;
    }

    pub fn unlink_b(&mut self, b: usize) {
        let a = self.match_b[b];
        if a != FREE {
            self.match_a[a as usize] = FREE;
            self.match_b[b] = FREE;
        }
    }

    /// Indices of free B vertices.
    pub fn free_b(&self) -> Vec<usize> {
        (0..self.nb()).filter(|&b| self.is_b_free(b)).collect()
    }

    pub fn free_a(&self) -> Vec<usize> {
        (0..self.na()).filter(|&a| self.is_a_free(a)).collect()
    }

    /// A matching is perfect when every B vertex is matched (for balanced
    /// instances this implies every A vertex too).
    pub fn is_perfect(&self) -> bool {
        self.match_b.iter().all(|&a| a != FREE)
    }

    /// Total cost under `costs` (costs indexed (b, a)).
    pub fn cost(&self, costs: &CostMatrix) -> f64 {
        self.match_b
            .iter()
            .enumerate()
            .filter(|(_, &a)| a != FREE)
            .map(|(b, &a)| costs.at(b, a as usize) as f64)
            .sum()
    }

    /// Paper §2.1: "convert into a perfect matching simply by arbitrarily
    /// matching the remaining free vertices" — pair free b's with free a's
    /// in index order. Each arbitrary edge costs ≤ c_max, bounding the added
    /// error by ε·n·c_max when ≤ εn vertices are free.
    pub fn complete_arbitrarily(&mut self) {
        let free_a = self.free_a();
        let mut it = free_a.into_iter();
        for b in 0..self.nb() {
            if self.is_b_free(b) {
                match it.next() {
                    Some(a) => self.link(b, a),
                    None => break, // unbalanced: no demand capacity left
                }
            }
        }
    }

    /// Internal consistency: match_a and match_b mirror each other and no
    /// vertex appears twice.
    pub fn check_consistent(&self) -> Result<(), String> {
        for (b, &a) in self.match_b.iter().enumerate() {
            if a != FREE {
                let a = a as usize;
                if a >= self.na() {
                    return Err(format!("match_b[{b}]={a} out of range"));
                }
                if self.match_a[a] != b as i32 {
                    return Err(format!(
                        "mirror mismatch: match_b[{b}]={a} but match_a[{a}]={}",
                        self.match_a[a]
                    ));
                }
            }
        }
        for (a, &b) in self.match_a.iter().enumerate() {
            if b != FREE {
                let b = b as usize;
                if b >= self.nb() || self.match_b[b] != a as i32 {
                    return Err(format!("mirror mismatch at a={a}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_unlink_mirror() {
        let mut m = Matching::empty(3, 3);
        m.link(0, 2);
        m.link(1, 1);
        assert_eq!(m.size(), 2);
        assert!(m.check_consistent().is_ok());
        // stealing: b=2 takes a=2 away from b=0
        m.link(2, 2);
        assert!(m.is_b_free(0));
        assert_eq!(m.match_a[2], 2);
        assert!(m.check_consistent().is_ok());
        m.unlink_b(2);
        assert!(m.is_b_free(2) && m.is_a_free(2));
        assert!(m.check_consistent().is_ok());
    }

    #[test]
    fn free_lists() {
        let mut m = Matching::empty(3, 4);
        m.link(1, 3);
        assert_eq!(m.free_b(), vec![0, 2]);
        assert_eq!(m.free_a(), vec![0, 1, 2]);
        assert!(!m.is_perfect());
    }

    #[test]
    fn completion_is_perfect_balanced() {
        let mut m = Matching::empty(4, 4);
        m.link(0, 1);
        m.link(2, 3);
        m.complete_arbitrarily();
        assert!(m.is_perfect());
        assert!(m.check_consistent().is_ok());
        assert_eq!(m.size(), 4);
    }

    #[test]
    fn completion_unbalanced_fills_min_side() {
        let mut m = Matching::empty(5, 3);
        m.complete_arbitrarily();
        assert_eq!(m.size(), 3);
    }

    #[test]
    fn cost_sums_matched_edges() {
        let c = CostMatrix::from_fn(2, 2, |b, a| (1 + b * 2 + a) as f32);
        let mut m = Matching::empty(2, 2);
        m.link(0, 0); // 1
        m.link(1, 1); // 4
        assert!((m.cost(&c) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn detects_corruption() {
        let mut m = Matching::empty(2, 2);
        m.link(0, 0);
        m.match_a[0] = 1; // corrupt
        assert!(m.check_consistent().is_err());
    }
}
