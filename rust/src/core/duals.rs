//! Integer dual weights and the ε-feasibility / invariant checker.
//!
//! Duals live in ε-units (see [`crate::core::quantize`]). The checker
//! verifies exactly the conditions the paper's analysis relies on:
//!
//! * (2): `y(a)+y(b) ≤ cq(a,b)+1` for every non-matching edge,
//! * (3): `y(a)+y(b) = cq(a,b)` for every matching edge,
//! * (I1): `y(b) ≥ 0` ∀b, `y(a) ≤ 0` ∀a, and `y(a)=0` for free a,
//! * Lemma 3.2: `|y(v)| ≤ ⌈1/ε⌉+2` (units form of `1+2ε`).
//!
//! Tests and the `otpr validate` command run this after every solve (and the
//! property suite after *every phase*), so invariant regressions are caught
//! structurally rather than through cost regressions.

use crate::core::matching::Matching;
use crate::core::quantize::QuantizedCosts;

#[derive(Debug, Clone, PartialEq)]
pub struct DualWeights {
    /// Duals for A (demand) vertices; non-positive in units.
    pub ya: Vec<i32>,
    /// Duals for B (supply) vertices; non-negative in units.
    pub yb: Vec<i32>,
}

impl DualWeights {
    /// Paper §2.2 initialization: y(b) = ε (1 unit), y(a) = 0.
    pub fn init(nb: usize, na: usize) -> Self {
        Self { ya: vec![0; na], yb: vec![1; nb] }
    }

    /// Sum of magnitudes (the potential used by Lemma 3.3).
    pub fn magnitude(&self) -> i64 {
        self.ya.iter().map(|&y| (y as i64).abs()).sum::<i64>()
            + self.yb.iter().map(|&y| (y as i64).abs()).sum::<i64>()
    }
}

/// Full ε-feasibility + invariant check. `O(na·nb)` — test/validation only.
pub fn check_feasible(
    q: &QuantizedCosts,
    m: &Matching,
    y: &DualWeights,
) -> Result<(), String> {
    if y.yb.len() != q.nb || y.ya.len() != q.na {
        return Err("dual dimensions mismatch".into());
    }
    m.check_consistent()?;
    // (I1) signs
    for (b, &yb) in y.yb.iter().enumerate() {
        if yb < 0 {
            return Err(format!("I1 violated: y(b={b}) = {yb} < 0"));
        }
    }
    for (a, &ya) in y.ya.iter().enumerate() {
        if ya > 0 {
            return Err(format!("I1 violated: y(a={a}) = {ya} > 0"));
        }
        if m.is_a_free(a) && ya != 0 {
            return Err(format!("I1 violated: free a={a} has y={ya} != 0"));
        }
    }
    // (2) and (3) — rows stream through one scratch buffer so implicit
    // (provider-backed) quantizations check without a resident slab
    let mut rowbuf: Vec<i32> = Vec::new();
    for b in 0..q.nb {
        let row = q.row_units(b, &mut rowbuf);
        let yb = y.yb[b];
        let matched_a = m.match_b[b];
        for (a, &cq) in row.iter().enumerate() {
            let s = cq as i64 + 1 - (y.ya[a] + yb) as i64; // slack against (2)
            if matched_a == a as i32 {
                if (y.ya[a] + yb) != cq {
                    // Report units *and* dequantized values: a failing
                    // property seed is debuggable without re-deriving the
                    // quantization by hand.
                    return Err(format!(
                        "(3) violated on matching edge (b={b},a={a}): \
                         y(a)+y(b)={} units, cq={cq} units \
                         (dequantized: {:.6} vs c̄={:.6}, eps_abs={:.3e}, provider={})",
                        y.ya[a] + yb,
                        (y.ya[a] + yb) as f64 * q.eps_abs,
                        cq as f64 * q.eps_abs,
                        q.eps_abs,
                        q.kind()
                    ));
                }
            } else if s < 0 {
                return Err(format!(
                    "(2) violated on edge (b={b},a={a}): \
                     y(a)+y(b)={} units > cq+1={} units \
                     (dequantized: {:.6} > {:.6}, eps_abs={:.3e}, provider={})",
                    y.ya[a] + yb,
                    cq + 1,
                    (y.ya[a] + yb) as f64 * q.eps_abs,
                    (cq + 1) as f64 * q.eps_abs,
                    q.eps_abs,
                    q.kind()
                ));
            }
        }
    }
    // Lemma 3.2 bound, in units: |y| ≤ 1/ε + 2 units (= (1+2ε)/ε · ε).
    let bound = (1.0 / q.eps).ceil() as i32 + 2;
    for &v in y.ya.iter().chain(y.yb.iter()) {
        if v.abs() > bound {
            return Err(format!(
                "Lemma 3.2 violated: |y|={} units > {bound} units \
                 (dequantized: {:.6} > {:.6})",
                v.abs(),
                v.abs() as f64 * q.eps_abs,
                bound as f64 * q.eps_abs
            ));
        }
    }
    Ok(())
}

/// Lemma 3.1 certificate: for a feasible (M, y) with all free-B duals
/// ≥ 0 and free-A duals = 0, the rounded cost of M is within εn of the
/// rounded-optimal. Returns the dual lower bound Σy − n (in units) that the
/// optimal rounded cost cannot beat; used by tests to bound OPT from below
/// without running an exact solver.
pub fn dual_lower_bound_units(y: &DualWeights) -> i64 {
    let total: i64 =
        y.ya.iter().map(|&v| v as i64).sum::<i64>() + y.yb.iter().map(|&v| v as i64).sum::<i64>();
    let n = y.yb.len().min(y.ya.len()) as i64;
    total - n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::cost::CostMatrix;

    fn small() -> (QuantizedCosts, Matching, DualWeights) {
        let c = CostMatrix::from_vec(2, 2, vec![0.0, 0.5, 0.5, 1.0]).unwrap();
        let q = QuantizedCosts::new(&c, 0.5); // cq = [[0,1],[1,2]]
        let m = Matching::empty(2, 2);
        let y = DualWeights::init(2, 2);
        (q, m, y)
    }

    #[test]
    fn initial_state_feasible() {
        let (q, m, y) = small();
        check_feasible(&q, &m, &y).unwrap();
    }

    #[test]
    fn catches_condition2_violation() {
        let (q, m, mut y) = small();
        y.yb[0] = 5; // edge (0,0): 0+5 > cq+1 = 1
        assert!(check_feasible(&q, &m, &y).unwrap_err().contains("(2)"));
    }

    #[test]
    fn catches_condition3_violation() {
        let (q, mut m, y) = small();
        m.link(0, 0); // y(a)+y(b) = 1 but cq = 0
        assert!(check_feasible(&q, &m, &y).unwrap_err().contains("(3)"));
    }

    #[test]
    fn matching_edge_exact_ok() {
        let (q, mut m, mut y) = small();
        // admissible edge (b=0, a=0): ya+yb = 1 = cq+1; after push ya -= 1
        m.link(0, 0);
        y.ya[0] = -1;
        check_feasible(&q, &m, &y).unwrap();
    }

    #[test]
    fn catches_sign_violations() {
        let (q, m, mut y) = small();
        y.ya[1] = 1;
        assert!(check_feasible(&q, &m, &y).unwrap_err().contains("I1"));
        let (q, m, mut y) = small();
        y.yb[1] = -1;
        assert!(check_feasible(&q, &m, &y).unwrap_err().contains("I1"));
    }

    #[test]
    fn catches_free_a_nonzero() {
        let (q, m, mut y) = small();
        y.ya[0] = -1; // a=0 free but y != 0
        assert!(check_feasible(&q, &m, &y).unwrap_err().contains("free a"));
    }

    #[test]
    fn error_strings_carry_units_and_dequantized_values() {
        // Regression: failing property seeds must show both the ε-unit
        // identity that broke and the original-cost-scale values.
        let (q, m, mut y) = small(); // eps_abs = 0.5
        y.yb[0] = 5; // (2) violation on edge (0,0): 0+5 > cq+1 = 1
        let msg = check_feasible(&q, &m, &y).unwrap_err();
        assert!(msg.contains("5 units"), "{msg}");
        assert!(msg.contains("dequantized"), "{msg}");
        assert!(msg.contains("2.500000"), "dequantized y-sum 5·0.5 missing: {msg}");
        assert!(msg.contains("0.500000"), "dequantized cq+1 = 1·0.5 missing: {msg}");

        let (q, mut m, y) = small();
        m.link(0, 0); // (3) violation: y sum 1 vs cq 0
        let msg = check_feasible(&q, &m, &y).unwrap_err();
        assert!(msg.contains("1 units, cq=0 units"), "{msg}");
        assert!(msg.contains("c̄=0.000000"), "{msg}");
        assert!(msg.contains("provider=dense"), "{msg}");
    }

    #[test]
    fn implicit_quantizations_check_and_name_their_provider() {
        use crate::core::provider::{Costs, GeneratedCosts};
        let costs =
            Costs::generated(GeneratedCosts::new(2, 2, |b, a| (b + a) as f32 / 2.0).unwrap());
        let q = QuantizedCosts::from_source(&costs.source(), 0.5);
        let m = Matching::empty(2, 2);
        let mut y = DualWeights::init(2, 2);
        check_feasible(&q, &m, &y).unwrap();
        y.yb[0] = 9; // (2) violation
        let msg = check_feasible(&q, &m, &y).unwrap_err();
        assert!(msg.contains("provider=generated"), "{msg}");
    }

    #[test]
    fn magnitude_and_bound() {
        let y = DualWeights { ya: vec![-2, 0], yb: vec![3, 1] };
        assert_eq!(y.magnitude(), 6);
        assert_eq!(dual_lower_bound_units(&y), 2 - 2);
    }
}
