//! Implicit cost matrices: the [`CostProvider`] abstraction that breaks
//! the O(n²) memory wall.
//!
//! The push-relabel solver only ever *reads* costs inside the propose
//! sweep, yet historically every layer — [`crate::core::QuantizedCosts`],
//! the kernel arena, the API problem model, the coordinator job payloads —
//! materialized and shipped the dense O(n²) slab. For geometric OT
//! instances (point clouds under (squared-)Euclidean or L1 cost — exactly
//! the workloads the experimental literature benchmarks) the cost is a
//! pure function of O(n) data, so nothing above the propose sweep needs
//! the slab at all.
//!
//! * [`CostProvider`] — the read contract: dimensions, per-edge
//!   [`CostProvider::cost_at`], row streaming via
//!   [`CostProvider::fill_row`], the normalization constant
//!   [`CostProvider::max_cost`], and an optional dense fast path.
//! * [`DenseCosts`] / the blanket impl on [`CostMatrix`] — the existing
//!   slab, byte-identical behavior preserved (the kernel detects the
//!   [`CostProvider::dense`] fast path and runs the historical code).
//! * [`SqEuclideanCosts`] — 2-D point clouds under squared-Euclidean or
//!   plain Euclidean distance (the latter reproduces
//!   `data::synthetic::euclidean_costs` bit-for-bit).
//! * [`L1PointCosts`] — d-dimensional f32 vectors under L1 distance
//!   (reproduces `data::images::l1_costs` bit-for-bit).
//! * [`GeneratedCosts`] — an arbitrary pure closure `(b, a) → cost`
//!   (the `data::workloads` golden-corpus generator uses this).
//! * [`Costs`] — the cheaply-clonable owned representation
//!   (`Dense | Points | L1Points | Generated`, all behind `Arc`) that
//!   `api::Problem` threads through requests, the registry, and the
//!   coordinator — an implicit job payload is O(n) bytes, not O(n²).
//! * [`CostSource`] — the borrowed per-call view the kernel and the
//!   drivers take: either a dense slab reference or an owned provider
//!   handle the arena can keep across phases.
//!
//! **Byte-identity contract.** A provider must be a *pure function* of its
//! construction data, and [`CostProvider::max_cost`] must equal the
//! row-major f32 max-fold [`CostMatrix::max`] would compute over the
//! materialized matrix. Under that contract the implicit path quantizes
//! every entry to exactly the dense path's integer units, so matchings,
//! plans, duals, and round/phase counts are **byte-identical** dense vs
//! implicit on every kernel backend (pinned by `tests/implicit_costs.rs`
//! and the golden corpus).

use crate::core::cost::CostMatrix;
use crate::core::error::{OtprError, Result};
use crate::core::matching::{Matching, FREE};
use crate::core::transport::TransportPlan;
use std::fmt;
use std::sync::Arc;

/// Read-only cost oracle: everything the solver stack needs from a cost
/// matrix, without requiring the O(n²) slab to exist.
pub trait CostProvider: Send + Sync {
    /// |B| — number of supply vertices (rows).
    fn nb(&self) -> usize;

    /// |A| — number of demand vertices (columns).
    fn na(&self) -> usize;

    /// Cost of edge (b, a). Must be pure and deterministic: the same
    /// (b, a) always yields the same f32.
    fn cost_at(&self, b: usize, a: usize) -> f32;

    /// Fill `out` (length ≥ [`CostProvider::na`]) with row `b`.
    fn fill_row(&self, b: usize, out: &mut [f32]) {
        for (a, slot) in out.iter_mut().take(self.na()).enumerate() {
            *slot = self.cost_at(b, a);
        }
    }

    /// Largest cost of the instance — the quantization normalization
    /// constant. Must equal [`CostMatrix::max`] of the materialized
    /// matrix (providers compute it once at construction by streaming).
    fn max_cost(&self) -> f32;

    /// Dense fast path: when the provider is backed by a real slab the
    /// kernel keeps the historical in-place requantize/lane-mirror code,
    /// byte-identical to pre-provider behavior.
    fn dense(&self) -> Option<&CostMatrix> {
        None
    }

    /// Short provider kind for diagnostics ("dense", "points",
    /// "l1-points", "generated") — quoted by quantize/feasibility error
    /// strings so failures on streamed costs are attributable.
    fn kind(&self) -> &'static str;
}

impl CostProvider for CostMatrix {
    fn nb(&self) -> usize {
        self.nb
    }

    fn na(&self) -> usize {
        self.na
    }

    #[inline]
    fn cost_at(&self, b: usize, a: usize) -> f32 {
        self.at(b, a)
    }

    fn fill_row(&self, b: usize, out: &mut [f32]) {
        out[..self.na].copy_from_slice(self.row(b));
    }

    fn max_cost(&self) -> f32 {
        self.max()
    }

    fn dense(&self) -> Option<&CostMatrix> {
        Some(self)
    }

    fn kind(&self) -> &'static str {
        "dense"
    }
}

/// Named wrapper for an owned dense matrix behind the provider trait —
/// every method forwards to the canonical [`CostMatrix`] impl above, so
/// there is exactly one dense provider implementation to maintain.
#[derive(Debug, Clone)]
pub struct DenseCosts(pub CostMatrix);

impl CostProvider for DenseCosts {
    fn nb(&self) -> usize {
        CostProvider::nb(&self.0)
    }

    fn na(&self) -> usize {
        CostProvider::na(&self.0)
    }

    #[inline]
    fn cost_at(&self, b: usize, a: usize) -> f32 {
        self.0.cost_at(b, a)
    }

    fn fill_row(&self, b: usize, out: &mut [f32]) {
        self.0.fill_row(b, out)
    }

    fn max_cost(&self) -> f32 {
        CostProvider::max_cost(&self.0)
    }

    fn dense(&self) -> Option<&CostMatrix> {
        Some(&self.0)
    }

    fn kind(&self) -> &'static str {
        CostProvider::kind(&self.0)
    }
}

/// Stream the row-major f32 max-fold a dense materialization would
/// produce ([`CostMatrix::max`] folds with 0.0), validating entries along
/// the way. O(nb·na) time, O(1) memory — run once at construction.
fn stream_max(
    nb: usize,
    na: usize,
    kind: &'static str,
    mut f: impl FnMut(usize, usize) -> f32,
) -> Result<f32> {
    let mut max = 0.0f32;
    for b in 0..nb {
        for a in 0..na {
            let c = f(b, a);
            if !c.is_finite() || c < 0.0 {
                return Err(OtprError::InvalidInstance(format!(
                    "{kind} cost provider yields invalid cost {c} at ({b},{a}): \
                     costs must be finite and non-negative"
                )));
            }
            max = max.max(c);
        }
    }
    Ok(max)
}

/// 2-D point-cloud costs: squared Euclidean (the benchmark-literature
/// default) or plain Euclidean (the paper's Figure-1 workload). O(n)
/// resident data; `cost_at` reproduces `Point2::dist` arithmetic
/// bit-for-bit, so the Euclidean form matches
/// `data::synthetic::euclidean_costs` exactly.
#[derive(Debug, Clone)]
pub struct SqEuclideanCosts {
    /// Supply points (rows), (x, y).
    b_pts: Vec<[f64; 2]>,
    /// Demand points (columns), (x, y).
    a_pts: Vec<[f64; 2]>,
    /// Take the square root (plain Euclidean) instead of squared.
    take_sqrt: bool,
    max: f32,
}

impl SqEuclideanCosts {
    /// Squared-Euclidean costs over (supply, demand) point clouds.
    pub fn new(b_pts: Vec<[f64; 2]>, a_pts: Vec<[f64; 2]>) -> Result<Self> {
        Self::build(b_pts, a_pts, false)
    }

    /// Plain Euclidean distance — byte-identical to
    /// `data::synthetic::euclidean_costs` on the same points.
    pub fn euclidean(b_pts: Vec<[f64; 2]>, a_pts: Vec<[f64; 2]>) -> Result<Self> {
        Self::build(b_pts, a_pts, true)
    }

    fn build(b_pts: Vec<[f64; 2]>, a_pts: Vec<[f64; 2]>, take_sqrt: bool) -> Result<Self> {
        let mut s = Self { b_pts, a_pts, take_sqrt, max: 0.0 };
        s.max = stream_max(s.b_pts.len(), s.a_pts.len(), s.kind(), |b, a| s.eval(b, a))?;
        Ok(s)
    }

    #[inline]
    fn eval(&self, b: usize, a: usize) -> f32 {
        let dx = self.b_pts[b][0] - self.a_pts[a][0];
        let dy = self.b_pts[b][1] - self.a_pts[a][1];
        let d2 = dx * dx + dy * dy;
        (if self.take_sqrt { d2.sqrt() } else { d2 }) as f32
    }

    /// Read-only views of the canonical payload — what a digest or a
    /// cross-node shipper hashes/serializes instead of the O(n²) costs
    /// the points imply (see `coordinator::digest`).
    pub fn points_b(&self) -> &[[f64; 2]] {
        &self.b_pts
    }

    pub fn points_a(&self) -> &[[f64; 2]] {
        &self.a_pts
    }

    /// Whether this instance takes the square root (plain Euclidean) —
    /// part of the canonical payload: same points, different metric.
    pub fn takes_sqrt(&self) -> bool {
        self.take_sqrt
    }
}

impl CostProvider for SqEuclideanCosts {
    fn nb(&self) -> usize {
        self.b_pts.len()
    }

    fn na(&self) -> usize {
        self.a_pts.len()
    }

    #[inline]
    fn cost_at(&self, b: usize, a: usize) -> f32 {
        self.eval(b, a)
    }

    fn max_cost(&self) -> f32 {
        self.max
    }

    fn kind(&self) -> &'static str {
        "points"
    }
}

/// d-dimensional f32 vectors under L1 distance — the image workload
/// (normalized 28×28 images are 784-d points). O(n·d) resident data;
/// `cost_at` reproduces `data::images::l1_distance`'s sequential f32
/// accumulation bit-for-bit.
#[derive(Debug, Clone)]
pub struct L1PointCosts {
    b_vecs: Vec<Vec<f32>>,
    a_vecs: Vec<Vec<f32>>,
    max: f32,
}

impl L1PointCosts {
    pub fn new(b_vecs: Vec<Vec<f32>>, a_vecs: Vec<Vec<f32>>) -> Result<Self> {
        // every vector on both sides must share one dimension — a ragged
        // vector would silently truncate the zip in eval() otherwise
        let dim = b_vecs.first().or(a_vecs.first()).map(Vec::len).unwrap_or(0);
        for (side, vecs) in [("b", &b_vecs), ("a", &a_vecs)] {
            if let Some(i) = vecs.iter().position(|v| v.len() != dim) {
                return Err(OtprError::InvalidInstance(format!(
                    "l1-points dimension mismatch: {side}[{i}] has {} entries, expected {dim}",
                    vecs[i].len()
                )));
            }
        }
        let mut s = Self { b_vecs, a_vecs, max: 0.0 };
        s.max = stream_max(s.b_vecs.len(), s.a_vecs.len(), s.kind(), |b, a| s.eval(b, a))?;
        Ok(s)
    }

    #[inline]
    fn eval(&self, b: usize, a: usize) -> f32 {
        // same zip/fold order as data::images::l1_distance(b_vec, a_vec)
        self.b_vecs[b].iter().zip(&self.a_vecs[a]).map(|(&x, &y)| (x - y).abs()).sum()
    }

    /// Read-only views of the canonical payload (see
    /// [`SqEuclideanCosts::points_b`]).
    pub fn vecs_b(&self) -> &[Vec<f32>] {
        &self.b_vecs
    }

    pub fn vecs_a(&self) -> &[Vec<f32>] {
        &self.a_vecs
    }
}

impl CostProvider for L1PointCosts {
    fn nb(&self) -> usize {
        self.b_vecs.len()
    }

    fn na(&self) -> usize {
        self.a_vecs.len()
    }

    #[inline]
    fn cost_at(&self, b: usize, a: usize) -> f32 {
        self.eval(b, a)
    }

    fn max_cost(&self) -> f32 {
        self.max
    }

    fn kind(&self) -> &'static str {
        "l1-points"
    }
}

/// Arbitrary pure-closure costs: `(b, a) → cost`. The closure must be
/// deterministic; construction streams every entry once to validate and
/// compute the max.
pub struct GeneratedCosts {
    nb: usize,
    na: usize,
    f: Box<dyn Fn(usize, usize) -> f32 + Send + Sync>,
    max: f32,
}

impl GeneratedCosts {
    pub fn new(
        nb: usize,
        na: usize,
        f: impl Fn(usize, usize) -> f32 + Send + Sync + 'static,
    ) -> Result<Self> {
        let max = stream_max(nb, na, "generated", |b, a| f(b, a))?;
        Ok(Self { nb, na, f: Box::new(f), max })
    }
}

impl fmt::Debug for GeneratedCosts {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("GeneratedCosts")
            .field("nb", &self.nb)
            .field("na", &self.na)
            .field("max", &self.max)
            .finish()
    }
}

impl CostProvider for GeneratedCosts {
    fn nb(&self) -> usize {
        self.nb
    }

    fn na(&self) -> usize {
        self.na
    }

    #[inline]
    fn cost_at(&self, b: usize, a: usize) -> f32 {
        (self.f)(b, a)
    }

    fn max_cost(&self) -> f32 {
        self.max
    }

    fn kind(&self) -> &'static str {
        "generated"
    }
}

/// Owned, cheaply-clonable cost representation threaded through
/// `api::Problem`, the registry, and the coordinator. Cloning clones an
/// `Arc`, never a slab — an implicit job payload is O(n) bytes.
#[derive(Clone)]
pub enum Costs {
    /// The historical dense slab (O(n²) resident).
    Dense(Arc<CostMatrix>),
    /// 2-D point clouds under (squared-)Euclidean distance (O(n)).
    Points(Arc<SqEuclideanCosts>),
    /// d-dimensional vectors under L1 distance (O(n·d)).
    L1Points(Arc<L1PointCosts>),
    /// Pure-closure costs (O(1) + captured data).
    Generated(Arc<GeneratedCosts>),
}

impl fmt::Debug for Costs {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Costs::{}({}x{})", self.kind(), self.nb(), self.na())
    }
}

impl Costs {
    pub fn dense(costs: CostMatrix) -> Self {
        Costs::Dense(Arc::new(costs))
    }

    pub fn points(p: SqEuclideanCosts) -> Self {
        Costs::Points(Arc::new(p))
    }

    pub fn l1_points(p: L1PointCosts) -> Self {
        Costs::L1Points(Arc::new(p))
    }

    pub fn generated(p: GeneratedCosts) -> Self {
        Costs::Generated(Arc::new(p))
    }

    /// The provider view (trait object) of whichever representation this is.
    pub fn provider(&self) -> &dyn CostProvider {
        match self {
            Costs::Dense(m) => &**m,
            Costs::Points(p) => &**p,
            Costs::L1Points(p) => &**p,
            Costs::Generated(p) => &**p,
        }
    }

    /// Owned provider handle (Arc clone + unsize coercion).
    pub fn provider_arc(&self) -> Arc<dyn CostProvider> {
        match self {
            Costs::Dense(m) => m.clone(),
            Costs::Points(p) => p.clone(),
            Costs::L1Points(p) => p.clone(),
            Costs::Generated(p) => p.clone(),
        }
    }

    /// The per-call view the kernel and the drivers consume: dense stays a
    /// borrowed slab (historical fast path), everything else becomes an
    /// owned provider handle.
    pub fn source(&self) -> CostSource<'_> {
        match self {
            Costs::Dense(m) => CostSource::Dense(&**m),
            other => CostSource::Implicit(other.provider_arc()),
        }
    }

    pub fn as_dense(&self) -> Option<&CostMatrix> {
        match self {
            Costs::Dense(m) => Some(m),
            _ => None,
        }
    }

    /// Materialize the O(n²) slab (baselines that genuinely need one).
    pub fn to_dense(&self) -> CostMatrix {
        match self {
            Costs::Dense(m) => (**m).clone(),
            other => {
                let p = other.provider();
                CostMatrix::from_fn(p.nb(), p.na(), |b, a| p.cost_at(b, a))
            }
        }
    }

    pub fn nb(&self) -> usize {
        self.provider().nb()
    }

    pub fn na(&self) -> usize {
        self.provider().na()
    }

    pub fn max_cost(&self) -> f32 {
        self.provider().max_cost()
    }

    pub fn kind(&self) -> &'static str {
        self.provider().kind()
    }

    #[inline]
    pub fn at(&self, b: usize, a: usize) -> f32 {
        self.provider().cost_at(b, a)
    }
}

/// Borrowed per-call cost view for the kernel and the drivers: either the
/// historical dense slab (byte-identical fast path) or an owned provider
/// handle the arena keeps across phases/rescales.
#[derive(Clone)]
pub enum CostSource<'a> {
    Dense(&'a CostMatrix),
    Implicit(Arc<dyn CostProvider>),
}

impl fmt::Debug for CostSource<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "CostSource::{}({}x{})", self.kind(), self.nb(), self.na())
    }
}

impl<'a> From<&'a CostMatrix> for CostSource<'a> {
    fn from(m: &'a CostMatrix) -> Self {
        CostSource::Dense(m)
    }
}

impl CostSource<'_> {
    pub fn nb(&self) -> usize {
        match self {
            CostSource::Dense(m) => m.nb,
            CostSource::Implicit(p) => p.nb(),
        }
    }

    pub fn na(&self) -> usize {
        match self {
            CostSource::Dense(m) => m.na,
            CostSource::Implicit(p) => p.na(),
        }
    }

    pub fn max_cost(&self) -> f32 {
        match self {
            CostSource::Dense(m) => m.max(),
            CostSource::Implicit(p) => p.max_cost(),
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            CostSource::Dense(_) => "dense",
            CostSource::Implicit(p) => p.kind(),
        }
    }

    pub fn is_implicit(&self) -> bool {
        matches!(self, CostSource::Implicit(_))
    }

    #[inline]
    pub fn at(&self, b: usize, a: usize) -> f32 {
        match self {
            CostSource::Dense(m) => m.at(b, a),
            CostSource::Implicit(p) => p.cost_at(b, a),
        }
    }

    /// Total matching cost under the original costs — same iteration and
    /// accumulation order as [`Matching::cost`], so dense and implicit
    /// report bit-identical totals.
    pub fn matching_cost(&self, m: &Matching) -> f64 {
        match self {
            CostSource::Dense(c) => m.cost(c),
            CostSource::Implicit(p) => m
                .match_b
                .iter()
                .enumerate()
                .filter(|(_, &a)| a != FREE)
                .map(|(b, &a)| p.cost_at(b, a as usize) as f64)
                .sum(),
        }
    }

    /// Total plan cost — the representation-aware fold
    /// [`TransportPlan::cost_with`], which replicates the dense row-major
    /// accumulation order per representation (CSR plans skip only
    /// exact-`+0.0` terms), so dense and implicit costs stay bit-identical
    /// without ever materializing a compact plan.
    pub fn plan_cost(&self, plan: &TransportPlan) -> f64 {
        match self {
            CostSource::Dense(c) => plan.cost(c),
            CostSource::Implicit(p) => plan.cost_with(|b, a| p.cost_at(b, a) as f64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_provider_round_trips() {
        let c = CostMatrix::from_fn(3, 4, |b, a| (b * 4 + a) as f32 / 11.0);
        assert_eq!(c.dense().unwrap(), &c);
        assert_eq!(CostProvider::max_cost(&c), c.max());
        assert_eq!(c.cost_at(2, 3), c.at(2, 3));
        let mut row = vec![0.0f32; 4];
        CostProvider::fill_row(&c, 1, &mut row);
        assert_eq!(&row[..], c.row(1));
        assert_eq!(CostProvider::kind(&c), "dense");
        let wrapped = DenseCosts(c.clone());
        assert_eq!(wrapped.dense().unwrap(), &c);
    }

    #[test]
    fn sq_euclidean_matches_materialization() {
        let b_pts = vec![[0.0, 0.0], [0.5, 0.25], [1.0, 1.0]];
        let a_pts = vec![[0.25, 0.75], [0.125, 0.5]];
        for provider in [
            SqEuclideanCosts::new(b_pts.clone(), a_pts.clone()).unwrap(),
            SqEuclideanCosts::euclidean(b_pts.clone(), a_pts.clone()).unwrap(),
        ] {
            let dense = CostMatrix::from_fn(3, 2, |b, a| provider.cost_at(b, a));
            assert_eq!(provider.max_cost(), dense.max(), "max must match the slab fold");
            let mut row = vec![0.0f32; 2];
            provider.fill_row(2, &mut row);
            assert_eq!(&row[..], dense.row(2));
        }
        // euclidean = sqrt of squared, bit-for-bit
        let sq = SqEuclideanCosts::new(b_pts.clone(), a_pts.clone()).unwrap();
        let eu = SqEuclideanCosts::euclidean(b_pts, a_pts).unwrap();
        for b in 0..3 {
            for a in 0..2 {
                let d2 = sq.cost_at(b, a) as f64;
                assert!(((eu.cost_at(b, a) as f64).powi(2) - d2).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn l1_points_match_materialization() {
        let b_vecs = vec![vec![0.5f32, 0.5, 0.0], vec![0.0, 0.25, 0.75]];
        let a_vecs = vec![vec![1.0f32, 0.0, 0.0], vec![0.25, 0.25, 0.5]];
        let p = L1PointCosts::new(b_vecs, a_vecs).unwrap();
        let dense = CostMatrix::from_fn(2, 2, |b, a| p.cost_at(b, a));
        assert_eq!(p.max_cost(), dense.max());
        // |0.5−1| + |0.5−0| + |0−0| = 1.0
        assert!((p.cost_at(0, 0) - 1.0).abs() < 1e-6);
        assert!(L1PointCosts::new(vec![vec![0.0; 3]], vec![vec![0.0; 2]]).is_err());
        // ragged inner vectors must be rejected, not silently truncated
        assert!(L1PointCosts::new(vec![vec![0.0; 3], vec![0.0; 2]], vec![vec![0.0; 3]]).is_err());
    }

    #[test]
    fn generated_validates_and_computes_max() {
        let g = GeneratedCosts::new(4, 4, |b, a| ((b * 7 + a * 3) % 5) as f32 / 4.0).unwrap();
        assert_eq!(g.max_cost(), 1.0);
        assert_eq!(g.kind(), "generated");
        assert!(GeneratedCosts::new(2, 2, |_, _| -1.0).is_err());
        assert!(GeneratedCosts::new(2, 2, |_, _| f32::NAN).is_err());
    }

    #[test]
    fn costs_enum_sources_and_materializes() {
        let g = GeneratedCosts::new(3, 3, |b, a| (b + a) as f32 / 4.0).unwrap();
        let costs = Costs::generated(g);
        assert_eq!((costs.nb(), costs.na()), (3, 3));
        assert_eq!(costs.kind(), "generated");
        assert!(costs.as_dense().is_none());
        assert!(costs.source().is_implicit());
        let dense = costs.to_dense();
        assert_eq!(dense.at(2, 2), 1.0);
        let dc = Costs::dense(dense.clone());
        assert!(!dc.source().is_implicit());
        assert_eq!(dc.as_dense().unwrap(), &dense);
        assert_eq!(format!("{costs:?}"), "Costs::generated(3x3)");
    }

    #[test]
    fn source_cost_folds_match_dense() {
        let g = GeneratedCosts::new(3, 3, |b, a| ((b * 5 + a * 2) % 7) as f32 / 6.0).unwrap();
        let costs = Costs::generated(g);
        let dense = costs.to_dense();
        let mut m = Matching::empty(3, 3);
        m.link(0, 2);
        m.link(1, 0);
        m.link(2, 1);
        let src = costs.source();
        assert_eq!(src.matching_cost(&m), m.cost(&dense), "bit-identical matching cost");
        let mut plan = TransportPlan::zeros(3, 3);
        plan.add(0, 1, 0.5);
        plan.add(2, 2, 0.5);
        assert_eq!(src.plan_cost(&plan), plan.cost(&dense), "bit-identical plan cost");
        assert_eq!(CostSource::from(&dense).matching_cost(&m), m.cost(&dense));
    }
}
