//! Vectorized flow-kernel backend: the propose sweep runs over the
//! arena's lane-blocked cost mirror ([`crate::core::quantize::LANES`]-wide
//! `i32` blocks, padded with `i32::MAX`, plus per-block minima) so a
//! whole block is skipped with one compare whenever nothing in it can be
//! admissible, and the remaining fixed-width inner loops auto-vectorize
//! on stable Rust — no intrinsics, no new dependencies.
//!
//! The skip predicate only discards entries the scalar scan would have
//! rejected without touching state, so the staged proposals — and
//! therefore matchings, plans, duals, and round/phase counts — are
//! **byte-identical** to [`crate::core::kernel::ScalarKernel`]
//! (`tests/conformance_golden.rs` pins this on the golden corpus,
//! including non-multiple-of-[`crate::core::quantize::LANES`] widths that
//! exercise the padding path). Only the memory traffic changes: a
//! propose-dominated sweep reads ~1/8 of the cost slab.

// Kernel-scope lint wall: all narrowing index math must go through the
// checked helpers in `arena` (`idx`/`to_u32`/`to_u8`).
#![deny(clippy::cast_possible_truncation, clippy::cast_sign_loss)]

use crate::core::kernel::arena::{
    idx, to_u8, KernelArena, KernelPhase, KernelView, PlanItem, PLAN_WIDTH,
};
use crate::core::kernel::FlowKernel;

/// The lane-blocked sweep body: identical proposals to
/// [`crate::core::kernel::arena::sequential_sweep`], staged through
/// [`KernelView::propose_one_lanes`].
// CONTRACT: round-structured accept order — this sweep only stages
// proposals against the round snapshot; commits happen sequentially in
// KernelArena::run_phase in ascending rank order.
pub fn vector_sweep(
    view: &KernelView<'_>,
    actives: &[u32],
    plans: &mut [PlanItem],
    plan_len: &mut [u8],
    exhausted: &mut [bool],
) {
    for (i, &wi) in actives.iter().enumerate() {
        let out = &mut plans[i * PLAN_WIDTH..(i + 1) * PLAN_WIDTH];
        let (len, ex) = view.propose_one_lanes(idx(wi), out);
        plan_len[i] = to_u8(len);
        exhausted[i] = ex;
    }
}

#[derive(Debug)]
pub struct VectorKernel {
    arena: KernelArena,
}

impl VectorKernel {
    pub fn new() -> Self {
        Self { arena: KernelArena::with_lanes() }
    }
}

impl Default for VectorKernel {
    fn default() -> Self {
        Self::new()
    }
}

impl FlowKernel for VectorKernel {
    fn name(&self) -> &'static str {
        "kernel-vector"
    }

    fn arena(&self) -> &KernelArena {
        &self.arena
    }

    fn arena_mut(&mut self) -> &mut KernelArena {
        &mut self.arena
    }

    // CONTRACT: round-structured accept order — see vector_sweep; commits
    // stay sequential inside KernelArena::run_phase.
    fn run_phase(&mut self) -> KernelPhase {
        self.arena.run_phase(vector_sweep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::kernel::ScalarKernel;
    use crate::core::CostMatrix;
    use crate::util::rng::Pcg32;

    fn random_costs(n: usize, seed: u64) -> CostMatrix {
        let mut rng = Pcg32::new(seed);
        CostMatrix::from_fn(n, n, |_, _| rng.next_f32())
    }

    #[test]
    fn vector_identical_to_scalar_including_padding_widths() {
        // n = 8 exercises the exact-multiple path, the rest the padding.
        for n in [5usize, 8, 11, 20, 24] {
            for seed in [1u64, 3] {
                let costs = random_costs(n, seed);
                let mut ks = ScalarKernel::new();
                ks.init(&costs, 0.2, None);
                ks.run_to_termination(10_000).unwrap();
                let mut kv = VectorKernel::new();
                kv.init(&costs, 0.2, None);
                kv.run_to_termination(10_000).unwrap();
                kv.check_invariants().unwrap();
                assert_eq!(ks.extract_matching(), kv.extract_matching(), "n={n} seed={seed}");
                assert_eq!(ks.duals(), kv.duals(), "n={n} seed={seed}");
                assert_eq!(ks.arena().rounds, kv.arena().rounds, "n={n} seed={seed}");
                assert_eq!(ks.arena().phases, kv.arena().phases, "n={n} seed={seed}");
            }
        }
    }

    #[test]
    fn vector_identical_to_scalar_on_ot_masses() {
        let n = 13; // non-multiple-of-8 demand side
        let costs = random_costs(n, 9);
        let supply: Vec<u64> = (0..n).map(|b| 2 + (b % 5) as u64).collect();
        let demand: Vec<u64> = (0..n).map(|a| 4 + (a % 3) as u64).collect();
        assert!(demand.iter().sum::<u64>() >= supply.iter().sum::<u64>());
        let mut ks = ScalarKernel::new();
        ks.init(&costs, 0.15, Some((&supply[..], &demand[..])));
        ks.run_to_termination(100_000).unwrap();
        let mut kv = VectorKernel::new();
        kv.init(&costs, 0.15, Some((&supply[..], &demand[..])));
        kv.run_to_termination(100_000).unwrap();
        assert_eq!(ks.unit_flow(), kv.unit_flow());
        assert_eq!(ks.duals(), kv.duals());
        assert_eq!(ks.arena().rounds, kv.arena().rounds);
    }

    #[test]
    fn lane_mirrors_track_rescale() {
        let costs = random_costs(12, 4);
        let mut kv = VectorKernel::new();
        kv.init(&costs, 0.4, None);
        kv.run_to_termination(10_000).unwrap();
        kv.arena_mut().rescale(&costs, 0.2);
        kv.check_invariants().unwrap();
        kv.run_to_termination(10_000).unwrap();
        kv.check_invariants().unwrap();
        // rescaled solve terminated at the finer ε's threshold
        assert!(kv.arena().free_units() <= kv.arena().threshold());
        assert_eq!(kv.arena().rescales, 1);

        // …and matches a scalar kernel warmed through the same schedule
        let mut ks = ScalarKernel::new();
        ks.init(&costs, 0.4, None);
        ks.run_to_termination(10_000).unwrap();
        ks.arena_mut().rescale(&costs, 0.2);
        ks.run_to_termination(10_000).unwrap();
        assert_eq!(ks.extract_matching(), kv.extract_matching());
        assert_eq!(ks.duals(), kv.duals());
    }

    #[test]
    fn implicit_costs_identical_to_dense_without_lane_mirror() {
        use crate::core::provider::{Costs, GeneratedCosts};
        // n = 11 exercises the lane-padding path under implicit costs.
        for n in [8usize, 11] {
            let dense = random_costs(n, 21);
            let grid = dense.clone();
            let costs = Costs::generated(
                GeneratedCosts::new(n, n, move |b, a| grid.at(b, a)).unwrap(),
            );
            let mut kd = VectorKernel::new();
            kd.init(&dense, 0.2, None);
            kd.run_to_termination(10_000).unwrap();
            let mut ki = VectorKernel::new();
            ki.init_src(&costs.source(), 0.2, None);
            ki.run_to_termination(10_000).unwrap();
            ki.check_invariants().unwrap();
            assert_eq!(kd.extract_matching(), ki.extract_matching(), "n={n}");
            assert_eq!(kd.duals(), ki.duals(), "n={n}");
            assert_eq!(kd.arena().rounds, ki.arena().rounds, "n={n}");
            assert_eq!(kd.arena().phases, ki.arena().phases, "n={n}");
            // dense holds cq + lane mirror + minima; implicit only minima
            assert!(ki.arena().cost_state_bytes() < kd.arena().cost_state_bytes() / 4);
            assert!(ki.arena().q.is_implicit() && ki.arena().q.cq.is_empty());
        }
    }

    #[test]
    fn implicit_rescale_restreams_and_matches_dense_schedule() {
        use crate::core::provider::{Costs, GeneratedCosts};
        let dense = random_costs(12, 4);
        let grid = dense.clone();
        let costs =
            Costs::generated(GeneratedCosts::new(12, 12, move |b, a| grid.at(b, a)).unwrap());
        let mut kd = VectorKernel::new();
        kd.init(&dense, 0.4, None);
        kd.run_to_termination(10_000).unwrap();
        kd.arena_mut().rescale(&dense, 0.2);
        kd.run_to_termination(10_000).unwrap();
        let mut ki = VectorKernel::new();
        ki.init_src(&costs.source(), 0.4, None);
        ki.run_to_termination(10_000).unwrap();
        ki.arena_mut().rescale_src(&costs.source(), 0.2);
        ki.check_invariants().unwrap();
        ki.run_to_termination(10_000).unwrap();
        assert_eq!(kd.extract_matching(), ki.extract_matching());
        assert_eq!(kd.duals(), ki.duals());
        assert_eq!(ki.arena().rescales, 1);
        assert!(ki.arena().q.cq.is_empty(), "rescale must not materialize a slab");
    }

    #[test]
    fn arena_reuse_works_for_vector_backend() {
        let mut kv = VectorKernel::new();
        kv.init(&random_costs(10, 1), 0.2, None);
        kv.run_to_termination(10_000).unwrap();
        kv.init(&random_costs(10, 2), 0.2, None);
        assert!(kv.arena().last_init_reused);
        kv.run_to_termination(10_000).unwrap();
        kv.check_invariants().unwrap();
    }
}
