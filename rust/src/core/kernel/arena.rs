//! The flat SoA flow arena: all mutable push-relabel state for one
//! instance, in contiguous buffers that are *reused* across `init` calls
//! so batched solves over same-shape instances pay allocation cost once.
//!
//! State model (the §4 copy-compressed form; assignment is the unit-mass
//! special case):
//!
//! * supply side — `b_free[b]` free units, `y_free[b]` the dual shared by
//!   all of b's free copies (the §4 free-copies-at-max invariant);
//! * demand side — `a_free[a]` free units at dual 0, plus up to
//!   [`SLOTS`] *cluster slots* per vertex (`cls_y` / `cls_count` /
//!   `cls_head`, fixed-width — Lemma 4.1 bounds live clusters by 2, the
//!   extra slots absorb the transient values one phase can create);
//! * flow — a pooled singly-linked edge list per cluster slot
//!   (`edge_b` / `edge_units` / `edge_next`, recycled through
//!   `edge_free`), so there is no `Vec<Vec<_>>` anywhere on the phase
//!   loop;
//! * worklists — `worklist` / `need` / `cursor` (`Vec<u32>`/`Vec<u64>`),
//!   rebuilt per phase without reallocating, plus a bitset
//!   (`active_bits`) over still-active proposers that each round
//!   prefix-expands into the dense ascending rank list the sweep and
//!   accept passes share;
//! * lane mirrors (vector backend only) — `lane_cq` / `lane_min`, the
//!   [`LANES`]-padded cost slab and per-block minima behind
//!   [`KernelView::propose_one_lanes`].
//!
//! The phase itself ([`KernelArena::run_phase`]) is *round-structured*:
//! every active free supply vertex proposes a take-plan against a stable
//! snapshot (capacities only shrink inside a phase, so the pre-round
//! state is the snapshot), then an accept pass commits grants
//! sequentially in ascending vertex order. Because proposals depend only
//! on the snapshot and commits are ordered, the result is **identical
//! for every thread count** — the scalar backend runs the sweep inline,
//! the chunked backend fans it out over `std::thread::scope`, and both
//! produce byte-identical matchings, plans, and duals.

// Kernel-scope lint wall: a truncating cast here silently corrupts slot
// indices at large n, so every lossy cast goes through the checked
// helpers below (see the `kernel-cast` rule in `otpr analyze`).
#![deny(clippy::cast_possible_truncation, clippy::cast_sign_loss)]

use crate::core::cost::CostMatrix;
use crate::core::duals::DualWeights;
use crate::core::matching::Matching;
use crate::core::provider::CostSource;
use crate::core::quantize::{QuantizedCosts, LANES};

/// Cluster slots per demand vertex. Lemma 4.1 bounds *live* clusters by
/// 2; one phase can transiently add values `{v−1 : v live} ∪ {−1}`, so 8
/// slots can never overflow while the lemma holds (and overflowing a
/// cold solve is a bug, reported loudly by
/// [`KernelArena::check_invariants`]). Warm-started (rescaled) states may
/// transiently exceed the lemma's budget; [`KernelArena::slot_for`] then
/// releases the smallest cluster instead of panicking.
pub const SLOTS: usize = 8;

/// Slot id used in a [`PlanItem`] for the free-copy pool (dual 0).
pub const SLOT_FREE: u8 = u8::MAX;

/// Sentinel for "no edge" in the pooled linked lists.
const NIL: u32 = u32::MAX;

/// Take-plan entries a proposing vertex may stage per round. Assignment
/// needs 1 (unit budgets); OT budgets occasionally span several demand
/// sources — anything beyond the width simply continues next round.
pub const PLAN_WIDTH: usize = 4;

/// Widen a stored `u32` id (vertex, edge, worklist rank) to a `usize`
/// index — lossless on every supported target; the typed helper is what
/// keeps the analyzer's kernel-cast rule meaningful for real narrowings.
#[inline]
pub(crate) fn idx(x: u32) -> usize {
    x as usize // cast-ok: u32→usize is lossless on 32/64-bit targets
}

/// Narrow a vertex/edge index into the arena's `u32` id space. The
/// instance shape bounds every caller's argument; the debug assert
/// catches any future violation before it can corrupt an index.
#[inline]
#[allow(clippy::cast_possible_truncation)]
pub(crate) fn to_u32(x: usize) -> u32 {
    debug_assert!(u32::try_from(x).is_ok(), "index {x} exceeds the u32 id space");
    // cast-ok: debug-asserted in range; indices are bounded by the instance shape
    x as u32
}

/// Narrow a staged-plan length to its `u8` slot (`PLAN_WIDTH`/`SLOTS`-bounded).
#[inline]
#[allow(clippy::cast_possible_truncation)]
pub(crate) fn to_u8(x: usize) -> u8 {
    debug_assert!(x <= usize::from(u8::MAX), "plan/slot width {x} exceeds u8");
    // cast-ok: plan lengths and slot ids are ≤ PLAN_WIDTH/SLOTS, far below 255
    x as u8
}

/// Narrow a band-clamped dual back into the `i32` dual representation.
#[inline]
#[allow(clippy::cast_possible_truncation)]
fn narrow_i32(v: i64) -> i32 {
    debug_assert!(i32::try_from(v).is_ok(), "dual {v} exceeds i32 range");
    // cast-ok: callers clamp into the Lemma 3.2 band before narrowing
    v as i32
}

/// `x.round()` as an integer — the dual re-scaling step.
#[inline]
#[allow(clippy::cast_possible_truncation)]
fn round_i64(x: f64) -> i64 {
    // cast-ok: rescale ratios keep duals far inside i64 range, and float→int
    // casts saturate (defined behavior) since Rust 1.45
    x.round() as i64
}

/// `⌊x⌋` as `u64` for non-negative `x` — the phase-termination threshold.
#[inline]
#[allow(clippy::cast_possible_truncation, clippy::cast_sign_loss)]
fn floor_u64(x: f64) -> u64 {
    debug_assert!(x >= 0.0, "threshold must be non-negative, got {x}");
    // cast-ok: ε·U ≥ 0 and far below 2^53; saturating float→int semantics
    x.floor() as u64
}

/// The dual band bound `⌈1/ε⌉ + 2` in ε-units — the same bound
/// `core::duals::check_feasible` enforces; shared by `rescale_src` and
/// `warm_reinit_src` so the two warm-start paths can never disagree.
#[allow(clippy::cast_possible_truncation)]
fn dual_band(eps: f64) -> i64 {
    // cast-ok: ε ∈ (0,1) is validated at requantize, so ⌈1/ε⌉ ∈ [1, 2^53)
    (1.0 / eps).ceil() as i64 + 2
}

/// Compact unit-flow export: the CSR twin of [`KernelArena::unit_flow`].
/// Rows are supply vertices b (ascending), columns demand vertices a
/// (strictly ascending within a row), values integer flow units — the
/// canonical order `TransportPlan::from_csr` requires, produced straight
/// from the cluster edge lists with no nb·na densification.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct UnitFlowCsr {
    /// `row_ptr.len() == nb + 1`; row b occupies `row_ptr[b]..row_ptr[b+1]`.
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub units: Vec<u64>,
}

/// One staged take: `units` from demand vertex `a`, out of the free pool
/// (`slot == SLOT_FREE`) or matched cluster slot `slot`.
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanItem {
    pub a: u32,
    pub slot: u8,
    pub units: u64,
}

/// Outcome of one kernel phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelPhase {
    /// Free supply units at the start of the phase (the |B'| of Lemma 3.4).
    pub free_at_start: u64,
    /// Units matched by this phase's maximal M'.
    pub matched_units: u64,
    /// Propose–accept rounds used.
    pub rounds: usize,
    /// True when the termination threshold held and no work was done.
    pub terminated: bool,
}

/// A pending M' match recorded during the accept pass and applied (with
/// the a-side relabel to `y_pre − 1`) once the phase's rounds finish.
#[derive(Debug, Clone, Copy)]
struct Pending {
    a: u32,
    b: u32,
    units: u64,
    y_pre: i32,
}

/// Read-only view of the arena state a propose sweep scans. `Sync`, so
/// the chunked backend can share it across scoped threads.
pub struct KernelView<'k> {
    pub q: &'k QuantizedCosts,
    pub y_free: &'k [i32],
    pub a_free: &'k [u64],
    pub cls_y: &'k [i32],
    pub cls_count: &'k [u64],
    pub worklist: &'k [u32],
    pub need: &'k [u64],
    pub cursor: &'k [u32],
    /// Lane-padded cost mirror (`nb × na_pad`, pads = `i32::MAX`); empty
    /// unless the arena was built with [`KernelArena::with_lanes`].
    pub lane_cq: &'k [i32],
    /// Per-row block minima over [`LANES`]-wide blocks of `lane_cq`.
    pub lane_min: &'k [i32],
    /// `na` padded to the lane width (0 when lanes are disabled).
    pub na_pad: usize,
}

/// Per-entry quantized-unit reader the shared stage body is generic over:
/// dense sweeps read a slice (identical codegen to the historical loop),
/// implicit sweeps quantize from the provider on demand.
trait RowUnits {
    fn get(&self, a: usize) -> i32;
}

struct SliceRow<'a>(&'a [i32]);

impl RowUnits for SliceRow<'_> {
    #[inline]
    fn get(&self, a: usize) -> i32 {
        self.0[a]
    }
}

struct ImplicitRow<'a> {
    q: &'a QuantizedCosts,
    b: usize,
}

impl RowUnits for ImplicitRow<'_> {
    #[inline]
    fn get(&self, a: usize) -> i32 {
        self.q.at(self.b, a)
    }
}

/// Per-backend row-window LRU for the implicit scalar/chunked propose
/// path: a handful of quantized rows ([`RowScratch::CAP`], O(CAP·na)
/// resident) cached across rounds *and* phases, keyed by
/// `QuantizedCosts::epoch` so any requantize/rescale/new-instance
/// self-invalidates the cache. Values are exactly the dense `cq` row, so
/// caching never affects results — only how often the provider streams.
#[derive(Debug, Default)]
pub struct RowScratch {
    epoch: u64,
    /// (b, quantized row), least-recently-used first.
    slots: Vec<(u32, Vec<i32>)>,
}

impl RowScratch {
    const CAP: usize = 32;

    pub fn new() -> Self {
        Self::default()
    }

    fn row(&mut self, q: &QuantizedCosts, b: usize) -> &[i32] {
        if self.epoch != q.epoch {
            self.slots.clear();
            self.epoch = q.epoch;
        }
        if let Some(i) = self.slots.iter().position(|(bb, _)| *bb == to_u32(b)) {
            let hit = self.slots.remove(i);
            self.slots.push(hit);
        } else {
            let mut buf =
                if self.slots.len() >= Self::CAP { self.slots.remove(0).1 } else { Vec::new() };
            q.fill_row_units(b, &mut buf);
            self.slots.push((to_u32(b), buf));
        }
        // panic-ok: the branch above pushed a slot unconditionally
        &self.slots.last().expect("slot just pushed").1
    }
}

impl KernelView<'_> {
    /// Scan demand vertices from `cursor[wi]` and stage up to
    /// [`PLAN_WIDTH`] takes for worklist entry `wi` against the snapshot
    /// capacities. Returns `(plan_len, exhausted)`; `exhausted` means the
    /// scan reached the end of the row with need remaining — no capacity
    /// is left anywhere for this vertex this phase. Dense mode only; the
    /// implicit scalar path is [`KernelView::propose_one_cached`].
    ///
    /// Per (b, a) at most **one** source can be admissible: the free pool
    /// needs `y_free[b] == cq+1` while a cluster at dual `v ≤ −1` needs
    /// `y_free[b] == cq+1−v > cq+1`, and no two live clusters share a
    /// dual. So the cursor is just a demand-vertex index.
    pub fn propose_one(&self, wi: usize, out: &mut [PlanItem]) -> (usize, bool) {
        let b = idx(self.worklist[wi]);
        self.propose_over_row(wi, self.q.row(b), out)
    }

    /// [`KernelView::propose_one`] for implicit costs: the quantized row
    /// streams through the backend's [`RowScratch`] row-window LRU, then
    /// the identical dense stage body runs over it — byte-identical
    /// proposals, O(CAP·na) resident state instead of the cq slab.
    pub fn propose_one_cached(
        &self,
        wi: usize,
        out: &mut [PlanItem],
        scratch: &mut RowScratch,
    ) -> (usize, bool) {
        let b = idx(self.worklist[wi]);
        let row = scratch.row(self.q, b);
        self.propose_over_row(wi, row, out)
    }

    /// The one scalar-propose body both row sources share — any change to
    /// the propose epilogue lands in dense and implicit sweeps alike.
    fn propose_over_row(&self, wi: usize, row: &[i32], out: &mut [PlanItem]) -> (usize, bool) {
        let b = idx(self.worklist[wi]);
        let mut need = self.need[wi];
        let yb = self.y_free[b] as i64;
        let na = row.len();
        let mut len = 0usize;
        let mut a = idx(self.cursor[wi]);
        if self.stage_segment(&SliceRow(row), yb, na, &mut a, &mut need, &mut len, out) {
            return (len, false);
        }
        (len, need > 0)
    }

    /// The one admissibility/take body both sweeps share: stage takes for
    /// a proposer at dual `yb` while scanning `row[a..end]`. Returns true
    /// when the caller must return early (`need` satisfied or the plan
    /// window full) — checked *before* each entry, exactly like the
    /// historical scalar loop, so both sweeps stay byte-identical by
    /// construction.
    #[inline]
    fn stage_segment<R: RowUnits>(
        &self,
        row: &R,
        yb: i64,
        end: usize,
        a: &mut usize,
        need: &mut u64,
        len: &mut usize,
        out: &mut [PlanItem],
    ) -> bool {
        while *a < end {
            if *need == 0 || *len == out.len() {
                return true;
            }
            let want = row.get(*a) as i64 + 1 - yb;
            if want == 0 {
                let cap = self.a_free[*a];
                if cap > 0 {
                    let take = (*need).min(cap);
                    out[*len] = PlanItem { a: to_u32(*a), slot: SLOT_FREE, units: take };
                    *len += 1;
                    *need -= take;
                }
            } else if want < 0 {
                let base = *a * SLOTS;
                for s in 0..SLOTS {
                    if self.cls_count[base + s] > 0 && self.cls_y[base + s] as i64 == want {
                        let take = (*need).min(self.cls_count[base + s]);
                        out[*len] = PlanItem { a: to_u32(*a), slot: to_u8(s), units: take };
                        *len += 1;
                        *need -= take;
                        break;
                    }
                }
            }
            *a += 1;
        }
        false
    }

    /// [`KernelView::propose_one`] over the lane-blocked cost mirror: a
    /// whole [`LANES`]-wide block is skipped with one compare against its
    /// precomputed minimum whenever nothing in it can be admissible
    /// (`min cq + 1 − y(b) > 0` — admissibility at either the free pool
    /// or any cluster requires `cq + 1 − y(b) ≤ 0`; pad lanes hold
    /// `i32::MAX` and can never pass). Skipped entries are exactly the
    /// ones the scalar scan would reject without touching any state, so
    /// the staged proposals are **identical** to the scalar sweep's —
    /// only the memory traffic changes.
    pub fn propose_one_lanes(&self, wi: usize, out: &mut [PlanItem]) -> (usize, bool) {
        let b = idx(self.worklist[wi]);
        let mut need = self.need[wi];
        let yb = self.y_free[b] as i64;
        let na = self.q.na;
        let na_pad = self.na_pad;
        debug_assert!(na_pad >= na, "lane mirror not built for this arena");
        let nblk = na_pad / LANES;
        let bmin = &self.lane_min[b * nblk..(b + 1) * nblk];
        let mut len = 0usize;
        let mut a = idx(self.cursor[wi]);
        if self.q.is_implicit() {
            // Implicit costs: the block-min cache is the only resident
            // lane state (no lane_cq mirror); blocks that pass the skip
            // filter quantize their entries on demand from the provider.
            // Same skip decisions, same per-entry units ⇒ identical
            // proposals to the dense lane sweep.
            let prow = ImplicitRow { q: self.q, b };
            while a < na {
                if need == 0 || len == out.len() {
                    return (len, false);
                }
                let blk = a / LANES;
                if bmin[blk] as i64 + 1 - yb > 0 {
                    a = (blk + 1) * LANES;
                    continue;
                }
                let end = ((blk + 1) * LANES).min(na);
                if self.stage_segment(&prow, yb, end, &mut a, &mut need, &mut len, out) {
                    return (len, false);
                }
            }
            return (len, need > 0);
        }
        let lrow = &self.lane_cq[b * na_pad..(b + 1) * na_pad];
        while a < na {
            if need == 0 || len == out.len() {
                return (len, false);
            }
            let blk = a / LANES;
            if bmin[blk] as i64 + 1 - yb > 0 {
                a = (blk + 1) * LANES;
                continue;
            }
            let end = ((blk + 1) * LANES).min(na);
            if self.stage_segment(&SliceRow(lrow), yb, end, &mut a, &mut need, &mut len, out) {
                return (len, false);
            }
        }
        (len, need > 0)
    }

    /// [`KernelView::propose_one_lanes`] with a per-thread row cache for
    /// implicit costs: the hybrid backend fans this over scoped threads,
    /// each thread owning one [`RowScratch`] LRU so repeat rows stream
    /// from the provider once per window instead of once per block.
    /// Dense costs delegate straight to the lane mirror (no cache needed
    /// — the mirror *is* resident). The cached row holds exactly the
    /// dense `cq` units and the block-min skip filter is shared, so skip
    /// decisions and staged takes are identical to both the dense lane
    /// sweep and the scalar sweep — byte-identity by construction.
    pub fn propose_one_lanes_cached(
        &self,
        wi: usize,
        out: &mut [PlanItem],
        scratch: &mut RowScratch,
    ) -> (usize, bool) {
        if !self.q.is_implicit() {
            return self.propose_one_lanes(wi, out);
        }
        let b = idx(self.worklist[wi]);
        let mut need = self.need[wi];
        let yb = self.y_free[b] as i64;
        let na = self.q.na;
        let na_pad = self.na_pad;
        debug_assert!(na_pad >= na, "lane mirror not built for this arena");
        let nblk = na_pad / LANES;
        let bmin = &self.lane_min[b * nblk..(b + 1) * nblk];
        let row = scratch.row(self.q, b);
        let mut len = 0usize;
        let mut a = idx(self.cursor[wi]);
        while a < na {
            if need == 0 || len == out.len() {
                return (len, false);
            }
            let blk = a / LANES;
            if bmin[blk] as i64 + 1 - yb > 0 {
                a = (blk + 1) * LANES;
                continue;
            }
            // `end ≤ na` always (the cached row is na-wide, not padded).
            let end = ((blk + 1) * LANES).min(na);
            if self.stage_segment(&SliceRow(row), yb, end, &mut a, &mut need, &mut len, out) {
                return (len, false);
            }
        }
        (len, need > 0)
    }
}

/// Propose sequentially for a window of the active list: `plans` /
/// `plan_len` / `exhausted` are the window's aligned output slices
/// (`plans.len() == actives.len() * PLAN_WIDTH`). This is **the** sweep
/// body — the scalar backend runs it over the full active list, the
/// chunked backend over per-thread windows — so every backend stages
/// identical proposals by construction. `scratch` is the backend's
/// row-window LRU, touched only for implicit costs.
// CONTRACT: round-structured accept order — this sweep stages against the
// stable snapshot only; commits happen sequentially in `accept_one`.
pub fn sequential_sweep(
    view: &KernelView<'_>,
    actives: &[u32],
    plans: &mut [PlanItem],
    plan_len: &mut [u8],
    exhausted: &mut [bool],
    scratch: &mut RowScratch,
) {
    let implicit = view.q.is_implicit();
    for (i, &wi) in actives.iter().enumerate() {
        let out = &mut plans[i * PLAN_WIDTH..(i + 1) * PLAN_WIDTH];
        let (len, ex) = if implicit {
            view.propose_one_cached(idx(wi), out, &mut *scratch)
        } else {
            view.propose_one(idx(wi), out)
        };
        plan_len[i] = to_u8(len);
        exhausted[i] = ex;
    }
}

/// The flat arena. Construct once, [`KernelArena::init`] per instance —
/// a same-shape re-init reuses every buffer and bumps `reuse_hits`.
#[derive(Debug)]
pub struct KernelArena {
    pub q: QuantizedCosts,
    nb: usize,
    na: usize,
    /// Free supply units per b.
    b_free: Vec<u64>,
    /// Dual of b's free copies (ε-units; all free copies share it).
    y_free: Vec<i32>,
    /// Free demand units per a (dual 0).
    a_free: Vec<u64>,
    /// Cluster slots, `SLOTS` per demand vertex: dual value, unit count,
    /// and the head of the slot's partner edge list.
    cls_y: Vec<i32>,
    cls_count: Vec<u64>,
    cls_head: Vec<u32>,
    /// Pooled partner edges (supply vertex, units, next edge).
    edge_b: Vec<u32>,
    edge_units: Vec<u64>,
    edge_next: Vec<u32>,
    edge_free: u32,
    /// Phase worklist: free b's at phase start, their remaining need and
    /// scan cursor, index-aligned.
    worklist: Vec<u32>,
    need: Vec<u64>,
    cursor: Vec<u32>,
    /// Bitset over worklist indices marking still-active proposers; the
    /// per-round dense rank list (`active`) is prefix-expanded from it in
    /// ascending order, which is what keeps the accept pass committing in
    /// ascending vertex order at any lane or thread count.
    active_bits: Vec<u64>,
    /// Scratch reused across rounds (taken/restored around the borrow).
    active: Vec<u32>,
    plans: Vec<PlanItem>,
    plan_len: Vec<u8>,
    plan_exhausted: Vec<bool>,
    pending: Vec<Pending>,
    /// Lane-blocked mirrors for the vector backend (see
    /// [`QuantizedCosts::build_lane_blocks`]); rebuilt by
    /// `init`/`rescale`/`warm_reinit` when `lanes_enabled`.
    lanes_enabled: bool,
    lane_cq: Vec<i32>,
    lane_min: Vec<i32>,
    /// A forced slot release happened mid-apply; run
    /// [`KernelArena::enforce_feasibility`] at the end of the phase.
    release_fixup_needed: bool,
    /// Lemma 4.1's live-cluster bound (≤ 2) is proven for cold starts;
    /// a rescaled (warm-started) state can transiently exceed it, so the
    /// strict assertions relax and [`KernelArena::slot_for`] falls back
    /// to releasing flow instead of panicking on slot exhaustion.
    pub lemma41_strict: bool,
    // --- counters ---
    pub total_supply_units: u64,
    /// Total demand units of the current instance (θ-scaled); together
    /// with `total_supply_units` this anchors the phase-boundary
    /// conservation asserts.
    pub total_demand_units: u64,
    pub phases: usize,
    pub rounds: usize,
    pub total_free_processed: u64,
    /// Largest number of distinct simultaneous dual values on any demand
    /// vertex (Lemma 4.1 says ≤ 2 for cold starts).
    pub max_classes_seen: usize,
    /// In-place ε re-targets ([`KernelArena::rescale`]) since the last init.
    pub rescales: u64,
    /// Clusters force-released because a warm-started vertex ran out of
    /// slots (never happens on cold solves; bounded recovery on warm ones).
    pub slot_evictions: u64,
    /// Arena lifetime counters for the batch path.
    pub inits: u64,
    pub reuse_hits: u64,
    /// Dual-carrying re-inits ([`KernelArena::warm_reinit`]) over the
    /// arena's lifetime (not reset by `init`, like `inits`/`reuse_hits`).
    pub warm_reinits: u64,
    pub last_init_reused: bool,
}

impl Default for KernelArena {
    fn default() -> Self {
        Self {
            q: QuantizedCosts::empty(),
            nb: 0,
            na: 0,
            b_free: Vec::new(),
            y_free: Vec::new(),
            a_free: Vec::new(),
            cls_y: Vec::new(),
            cls_count: Vec::new(),
            cls_head: Vec::new(),
            edge_b: Vec::new(),
            edge_units: Vec::new(),
            edge_next: Vec::new(),
            edge_free: NIL,
            worklist: Vec::new(),
            need: Vec::new(),
            cursor: Vec::new(),
            active_bits: Vec::new(),
            active: Vec::new(),
            plans: Vec::new(),
            plan_len: Vec::new(),
            plan_exhausted: Vec::new(),
            pending: Vec::new(),
            lanes_enabled: false,
            lane_cq: Vec::new(),
            lane_min: Vec::new(),
            release_fixup_needed: false,
            lemma41_strict: true,
            total_supply_units: 0,
            total_demand_units: 0,
            phases: 0,
            rounds: 0,
            total_free_processed: 0,
            max_classes_seen: 0,
            rescales: 0,
            slot_evictions: 0,
            inits: 0,
            reuse_hits: 0,
            warm_reinits: 0,
            last_init_reused: false,
        }
    }
}

impl KernelArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Arena with the vector backend's lane mirrors enabled; `init`,
    /// [`KernelArena::rescale`], and [`KernelArena::warm_reinit`] keep
    /// them in sync with the quantized costs.
    pub fn with_lanes() -> Self {
        Self { lanes_enabled: true, ..Self::default() }
    }

    /// Prepare the arena for a new instance, reusing every allocation.
    /// `masses = None` means the assignment special case (one unit per
    /// vertex on both sides); `Some((supply_units, demand_units))` is the
    /// θ-scaled §4 transport instance. Dense entry — implicit providers go
    /// through [`KernelArena::init_src`].
    pub fn init(&mut self, costs: &CostMatrix, eps: f64, masses: Option<(&[u64], &[u64])>) {
        self.init_src(&CostSource::Dense(costs), eps, masses);
    }

    /// [`KernelArena::init`] over either cost representation. The dense
    /// arm is byte-identical to the historical path; the implicit arm
    /// materializes **no** per-entry cost state — only the block-min cache
    /// when lanes are enabled.
    pub fn init_src(&mut self, costs: &CostSource<'_>, eps: f64, masses: Option<(&[u64], &[u64])>) {
        let (cnb, cna) = (costs.nb(), costs.na());
        let reused = self.inits > 0 && self.nb == cnb && self.na == cna;
        self.inits += 1;
        if reused {
            self.reuse_hits += 1;
        }
        self.last_init_reused = reused;
        self.nb = cnb;
        self.na = cna;
        self.q.requantize_src(costs, eps);
        self.b_free.clear();
        self.a_free.clear();
        match masses {
            Some((supply, demand)) => {
                assert_eq!(supply.len(), self.nb, "supply units / cost rows mismatch");
                assert_eq!(demand.len(), self.na, "demand units / cost cols mismatch");
                self.b_free.extend_from_slice(supply);
                self.a_free.extend_from_slice(demand);
            }
            None => {
                self.b_free.resize(self.nb, 1);
                self.a_free.resize(self.na, 1);
            }
        }
        self.y_free.clear();
        self.y_free.resize(self.nb, 1); // paper init: y(b) = 1 unit, y(a) = 0
        self.total_supply_units = self.b_free.iter().sum();
        self.total_demand_units = self.a_free.iter().sum();
        self.cls_y.clear();
        self.cls_y.resize(SLOTS * self.na, 0);
        self.cls_count.clear();
        self.cls_count.resize(SLOTS * self.na, 0);
        self.cls_head.clear();
        self.cls_head.resize(SLOTS * self.na, NIL);
        self.edge_b.clear();
        self.edge_units.clear();
        self.edge_next.clear();
        self.edge_free = NIL;
        self.worklist.clear();
        self.need.clear();
        self.cursor.clear();
        self.active_bits.clear();
        self.active.clear();
        self.plans.clear();
        self.plan_len.clear();
        self.plan_exhausted.clear();
        self.pending.clear();
        self.phases = 0;
        self.rounds = 0;
        self.total_free_processed = 0;
        self.max_classes_seen = 0;
        self.rescales = 0;
        self.slot_evictions = 0;
        self.release_fixup_needed = false;
        self.lemma41_strict = true;
        self.rebuild_lanes();
    }

    /// (Re)build the vector backend's lane state for the current
    /// quantization: dense keeps the full `lane_cq` mirror + block minima;
    /// implicit keeps **only** the block minima (the O(n²/[`LANES`])
    /// cache), streamed row-by-row from the provider.
    fn rebuild_lanes(&mut self) {
        if self.lanes_enabled {
            if self.q.is_implicit() {
                self.lane_cq = Vec::new();
                self.q.build_lane_min_implicit(&mut self.lane_min);
            } else {
                self.q.build_lane_blocks(&mut self.lane_cq, &mut self.lane_min);
            }
        }
    }

    /// Resident cost-derived state in bytes: the quantized slab (dense
    /// mode) plus the lane mirror/minima (vector backend). This is the
    /// number the no-slab acceptance gate asserts on — an implicit solve
    /// through the vector backend holds only the block-min cache,
    /// `nb · na_padded/LANES · 4` bytes, never an O(n²) slab.
    pub fn cost_state_bytes(&self) -> u64 {
        ((self.q.cq.len() + self.lane_cq.len() + self.lane_min.len())
            * std::mem::size_of::<i32>()) as u64
    }

    /// Re-target the arena to a new quantization **without discarding the
    /// solve state** — the ε-scaling warm-start step. Costs requantize in
    /// place, all duals scale into the new ε-units (clamped back into the
    /// Lemma 3.2 band), the free-side duals clamp back into ε-feasibility
    /// (2) against every surviving demand copy, and whatever flow the new
    /// units can no longer support exactly is released to the free pools.
    /// The result is a valid mid-algorithm state: phases continue as if
    /// the solve had always run at the new ε, and every exported
    /// dual/invariant contract (`check_invariants`,
    /// `core::duals::check_feasible`, `certify`) keeps holding.
    ///
    /// Note: the drivers' geometric schedules make the old/new ε ratio an
    /// exact power of two, which is what keeps the kept matched edges on
    /// exact (3) equality in the unit-mass case (a non-integer ratio
    /// would still be feasible for OT, but could strand unit-mass edges
    /// below their free-copy dual and fail the strict matching check).
    pub fn rescale(&mut self, costs: &CostMatrix, eps_next: f64) {
        self.rescale_src(&CostSource::Dense(costs), eps_next);
    }

    /// [`KernelArena::rescale`] over either cost representation: the
    /// implicit arm requantizes by **re-streaming rows from the provider**
    /// (constant extra memory), never by re-reading an O(n²) slab.
    pub fn rescale_src(&mut self, costs: &CostSource<'_>, eps_next: f64) {
        assert_eq!(costs.nb(), self.nb, "rescale requires the same instance shape");
        assert_eq!(costs.na(), self.na, "rescale requires the same instance shape");
        assert!(self.inits > 0, "rescale needs an initialized arena");
        let old_abs = self.q.eps_abs;
        self.q.requantize_src(costs, eps_next);
        self.rescales += 1;
        // Lemma 4.1 is proven from the cold init; a rescaled state can
        // transiently hold more live clusters (the slot pool absorbs
        // them, with forced release as the backstop).
        self.lemma41_strict = false;
        let f = old_abs / self.q.eps_abs;
        let scale = |y: i32| round_i64(f64::from(y) * f);
        // Dual band in the new units (same bound `check_feasible` enforces).
        let band = dual_band(self.q.eps);

        // 1) supply duals into the new units.
        for y in &mut self.y_free {
            *y = narrow_i32(scale(*y).clamp(0, band));
        }
        // 2) cluster duals; a cluster pushed below the band releases its
        // flow entirely (only near-extremal duals, if ever) — the evicted
        // demand copies return to the free pool at dual 0, so demand
        // capacity is conserved exactly.
        for idx in 0..SLOTS * self.na {
            if self.cls_count[idx] == 0 {
                continue;
            }
            let v = scale(self.cls_y[idx]).min(0);
            if v < -band {
                let n = self.cls_count[idx];
                self.steal_from_slot(idx, n);
                self.a_free[idx / SLOTS] += n;
            } else {
                self.cls_y[idx] = narrow_i32(v);
            }
        }
        // 3) clamp the supply duals back into (2) and release whatever
        // flow the new units cannot support exactly, to a fixpoint.
        self.enforce_feasibility();
        // worklists and round scratch rebuild per phase; lane mirrors
        // track the requantized costs.
        self.rebuild_lanes();
    }

    /// Restore ε-feasibility after out-of-band releases or dual
    /// re-scaling, alternating two monotone passes to a fixpoint:
    ///
    /// * **clamp** — every supply dual drops into (2) against the
    ///   max-dual copy of every demand vertex that has copies
    ///   (`y(b) ≤ cq+1 − ymax(a)`; free pool ⇒ ymax = 0);
    /// * **release** — every matched edge whose implied supply dual
    ///   `cq − y_cls` exceeds its vertex's free-copy dual is released:
    ///   supply units rejoin `b_free` at `y_free[b]`, demand units rejoin
    ///   `a_free` at dual 0 (capacity on both sides is conserved).
    ///
    /// A release can put free copies at dual 0 on a previously all-matched
    /// vertex, which tightens the clamp, which can force more releases —
    /// hence the loop. Both passes only shrink duals/matched flow, so it
    /// terminates (in practice 1–2 iterations).
    fn enforce_feasibility(&mut self) {
        // one-row scratch so implicit costs stream instead of materializing
        let mut rowbuf: Vec<i32> = Vec::new();
        loop {
            // clamp: each a's max copy dual, computed once per pass
            let mut ymax: Vec<Option<i64>> = Vec::with_capacity(self.na);
            for a in 0..self.na {
                let base = a * SLOTS;
                ymax.push(if self.a_free[a] > 0 {
                    Some(0)
                } else {
                    (0..SLOTS)
                        .filter(|&s| self.cls_count[base + s] > 0)
                        .map(|s| self.cls_y[base + s] as i64)
                        .max()
                });
            }
            for b in 0..self.nb {
                let row = self.q.row_units(b, &mut rowbuf);
                let mut bound = i64::MAX;
                for (a, ym) in ymax.iter().enumerate() {
                    if let Some(y) = ym {
                        bound = bound.min(row[a] as i64 + 1 - y);
                    }
                }
                if bound < self.y_free[b] as i64 {
                    self.y_free[b] = narrow_i32(bound.max(0));
                }
            }
            // release pass
            let mut released = false;
            for a in 0..self.na {
                for s in 0..SLOTS {
                    let idx = a * SLOTS + s;
                    if self.cls_count[idx] == 0 {
                        continue;
                    }
                    let v = self.cls_y[idx] as i64;
                    let mut prev = NIL;
                    let mut e = self.cls_head[idx];
                    while e != NIL {
                        let next = self.edge_next[idx(e)];
                        let b = idx(self.edge_b[idx(e)]);
                        if self.q.at(b, a) as i64 - v > self.y_free[b] as i64 {
                            let units = self.edge_units[idx(e)];
                            self.b_free[b] += units;
                            self.a_free[a] += units;
                            self.cls_count[idx] -= units;
                            self.edge_units[idx(e)] = 0;
                            if prev == NIL {
                                self.cls_head[idx] = next;
                            } else {
                                self.edge_next[idx(prev)] = next;
                            }
                            self.edge_next[idx(e)] = self.edge_free;
                            self.edge_free = e;
                            released = true;
                        } else {
                            prev = e;
                        }
                        e = next;
                    }
                }
            }
            if !released {
                return;
            }
        }
    }

    /// Re-initialize for a **new** instance while carrying the previous
    /// instance's supply duals — the batch warm start. All flow and
    /// masses reset; the duals scale into the new quantization and clamp
    /// into ε-feasibility against the all-free demand side
    /// (`y(b) ≤ min_a cq(b,a) + 1`), so the state is exactly a cold init
    /// whose relabel counters start near where a similar instance ended.
    pub fn warm_reinit(&mut self, costs: &CostMatrix, eps: f64, masses: Option<(&[u64], &[u64])>) {
        self.warm_reinit_src(&CostSource::Dense(costs), eps, masses);
    }

    /// [`KernelArena::warm_reinit`] over either cost representation (the
    /// per-row minima stream from the provider in implicit mode).
    pub fn warm_reinit_src(
        &mut self,
        costs: &CostSource<'_>,
        eps: f64,
        masses: Option<(&[u64], &[u64])>,
    ) {
        assert_eq!(costs.nb(), self.nb, "warm_reinit requires the same shape");
        assert_eq!(costs.na(), self.na, "warm_reinit requires the same shape");
        assert!(self.inits > 0, "warm_reinit needs a previously initialized arena");
        let old_abs = self.q.eps_abs;
        let carried: Vec<i32> = std::mem::take(&mut self.y_free);
        self.init_src(costs, eps, masses);
        self.warm_reinits += 1;
        // Lemma 4.1's ≤2-live-cluster proof assumes the cold y(b)=1 init;
        // carried (heterogeneous) supply duals can transiently stack more
        // values on a multi-unit demand vertex, so relax the strict
        // assertions like `rescale` does.
        self.lemma41_strict = false;
        let f = old_abs / self.q.eps_abs;
        let band = dual_band(self.q.eps);
        // Per-row minima: the vector backend's fresh block-min cache
        // already holds them (pads are i32::MAX, so the block fold IS the
        // row min) — reusing it avoids re-streaming an implicit provider's
        // whole cost relation a second time right after init_src did.
        let nblk = self.q.na_padded() / LANES;
        for b in 0..self.nb {
            let scaled = round_i64(f64::from(carried[b]) * f);
            let row_min = if self.lanes_enabled {
                self.lane_min[b * nblk..(b + 1) * nblk].iter().copied().min().unwrap_or(0) as i64
            } else {
                self.q.row_min(b) as i64
            };
            self.y_free[b] = narrow_i32(scaled.clamp(1, (row_min + 1).min(band).max(1)));
        }
    }

    pub fn nb(&self) -> usize {
        self.nb
    }

    pub fn na(&self) -> usize {
        self.na
    }

    pub fn b_free(&self) -> &[u64] {
        &self.b_free
    }

    pub fn a_free(&self) -> &[u64] {
        &self.a_free
    }

    pub fn y_free(&self) -> &[i32] {
        &self.y_free
    }

    /// Free supply units remaining.
    pub fn free_units(&self) -> u64 {
        self.b_free.iter().sum()
    }

    /// Phase-termination threshold: run only while free units > ε·U.
    pub fn threshold(&self) -> u64 {
        // cast-ok: u64→f64 loses precision only above 2^53 total units
        floor_u64(self.q.eps * self.total_supply_units as f64)
    }

    /// One phase, with the propose sweep run by `sweep`. Backends pass
    /// either an inline sequential sweep or a scoped-thread fan-out; both
    /// receive the same view + scratch and must fill the same outputs
    /// (see [`KernelView::propose_one`]), which is what makes every
    /// backend result-identical.
    // CONTRACT: round-structured accept order — proposals read only the
    // pre-round snapshot; the accept pass commits in ascending vertex
    // order, so every backend and thread count is byte-identical.
    pub fn run_phase<S>(&mut self, mut sweep: S) -> KernelPhase
    where
        S: FnMut(&KernelView<'_>, &[u32], &mut [PlanItem], &mut [u8], &mut [bool]),
    {
        let free_now = self.free_units();
        if free_now <= self.threshold() {
            return KernelPhase {
                free_at_start: free_now,
                matched_units: 0,
                rounds: 0,
                terminated: true,
            };
        }
        self.phases += 1;
        self.total_free_processed += free_now;
        #[cfg(debug_assertions)]
        let y_before: Vec<i32> = self.y_free.clone();
        #[cfg(debug_assertions)]
        let evictions_before = self.slot_evictions;

        // Worklist: free b's at phase start; evicted units arriving during
        // the phase join b_free but not this phase's budget.
        self.worklist.clear();
        self.need.clear();
        self.cursor.clear();
        for b in 0..self.nb {
            if self.b_free[b] > 0 {
                self.worklist.push(to_u32(b));
                self.need.push(self.b_free[b]);
                self.cursor.push(0);
            }
        }
        self.pending.clear();

        let mut active = std::mem::take(&mut self.active);
        let mut bits = std::mem::take(&mut self.active_bits);
        let mut plans = std::mem::take(&mut self.plans);
        let mut plan_len = std::mem::take(&mut self.plan_len);
        let mut exhausted = std::mem::take(&mut self.plan_exhausted);
        // Every worklist entry starts active; the tail word masks off the
        // bits beyond the worklist length.
        let wl = self.worklist.len();
        bits.clear();
        bits.resize(wl.div_ceil(64), !0u64);
        if wl % 64 != 0 {
            if let Some(last) = bits.last_mut() {
                *last = (1u64 << (wl % 64)) - 1;
            }
        }

        let mut rounds = 0usize;
        loop {
            // Prefix-expand the bitset into the dense rank list, ascending:
            // rank i is where the sweep writes entry i's plan and the order
            // the accept pass walks, so commits stay in ascending vertex
            // order at any lane or thread count.
            active.clear();
            for (w, &word) in bits.iter().enumerate() {
                let mut m = word;
                while m != 0 {
                    active.push(to_u32(w * 64 + idx(m.trailing_zeros())));
                    m &= m - 1;
                }
            }
            if active.is_empty() {
                break;
            }
            rounds += 1;
            plans.clear();
            plans.resize(active.len() * PLAN_WIDTH, PlanItem::default());
            plan_len.clear();
            plan_len.resize(active.len(), 0);
            exhausted.clear();
            exhausted.resize(active.len(), false);

            // --- propose: reads only the snapshot view ---
            {
                let view = KernelView {
                    q: &self.q,
                    y_free: &self.y_free,
                    a_free: &self.a_free,
                    cls_y: &self.cls_y,
                    cls_count: &self.cls_count,
                    worklist: &self.worklist,
                    need: &self.need,
                    cursor: &self.cursor,
                    lane_cq: &self.lane_cq,
                    lane_min: &self.lane_min,
                    na_pad: if self.lanes_enabled { self.q.na_padded() } else { 0 },
                };
                sweep(&view, &active, &mut plans, &mut plan_len, &mut exhausted);
            }

            // --- accept: sequential, ascending b (worklist order) ---
            for (i, &wi) in active.iter().enumerate() {
                let plan = &plans[i * PLAN_WIDTH..i * PLAN_WIDTH + usize::from(plan_len[i])];
                if !self.accept_one(idx(wi), plan, exhausted[i]) {
                    bits[idx(wi) / 64] &= !(1u64 << (idx(wi) % 64));
                }
            }
        }

        self.active = active;
        self.active_bits = bits;
        self.plans = plans;
        self.plan_len = plan_len;
        self.plan_exhausted = exhausted;

        // --- apply M': matched a-copies relabel down to y_pre − 1 ---
        let matched_units: u64 = self.pending.iter().map(|p| p.units).sum();
        let pending = std::mem::take(&mut self.pending);
        for p in &pending {
            let slot = self.slot_for(idx(p.a), p.y_pre - 1);
            self.cls_count[slot] += p.units;
            self.add_edge(slot, p.b, p.units);
        }
        self.pending = pending;

        // --- relabel: b's whose budget wasn't fully matched move up ---
        for wi in 0..self.worklist.len() {
            if self.need[wi] > 0 {
                let b = idx(self.worklist[wi]);
                self.y_free[b] += 1;
            }
        }

        self.rounds += rounds;
        // A forced slot release freed demand copies at dual 0 mid-apply;
        // restore (2) before anything proposes against this state.
        if self.release_fixup_needed {
            self.release_fixup_needed = false;
            self.enforce_feasibility();
        }
        self.track_classes();
        #[cfg(debug_assertions)]
        self.assert_phase_boundary(&y_before, evictions_before);
        KernelPhase { free_at_start: free_now, matched_units, rounds, terminated: false }
    }

    /// Phase-boundary invariants, checked in debug builds only (Miri and
    /// TSan runs exercise them for free): unit conservation on both
    /// sides, dual monotonicity within a scale, and Lemma-4.1 slot
    /// occupancy.
    #[cfg(debug_assertions)]
    fn assert_phase_boundary(&self, y_before: &[i32], evictions_before: u64) {
        // conservation: free + matched units account for every θ-scaled
        // unit on each side (each matched unit pairs one supply and one
        // demand copy, so the cluster counts serve both equations)
        let matched: u64 = self.cls_count.iter().sum();
        debug_assert_eq!(
            self.free_units() + matched,
            self.total_supply_units,
            "supply units leaked across a phase"
        );
        let a_free: u64 = self.a_free.iter().sum();
        debug_assert_eq!(
            a_free + matched,
            self.total_demand_units,
            "demand units leaked across a phase"
        );
        // dual monotonicity within a scale: relabels only raise supply
        // duals; only a forced slot release (and its feasibility fixup)
        // may lower them
        if evictions_before == self.slot_evictions {
            for (b, (&y0, &y1)) in y_before.iter().zip(&self.y_free).enumerate() {
                debug_assert!(y1 >= y0, "y_free[{b}] decreased {y0} -> {y1} within a scale");
            }
        }
        // Lemma-4.1 slot occupancy (strict only for cold solves)
        for a in 0..self.na {
            let base = a * SLOTS;
            let live = (0..SLOTS).filter(|&s| self.cls_count[base + s] > 0).count();
            debug_assert!(
                !self.lemma41_strict || live <= 2,
                "Lemma 4.1 violated at a={a}: {live} matched clusters"
            );
        }
    }

    /// Commit worklist entry `wi`'s staged plan against current
    /// capacities. Returns true while the vertex stays active. Inside a
    /// phase capacities only shrink, so when need survives the walk every
    /// plan target is exhausted and the cursor can skip past them all.
    // CONTRACT: round-structured accept order — called sequentially in
    // ascending rank order; reordering commits breaks byte-identity.
    fn accept_one(&mut self, wi: usize, plan: &[PlanItem], exhausted: bool) -> bool {
        if plan.is_empty() {
            // A non-exhausted propose always stages ≥ 1 item, so an empty
            // plan means the row holds nothing for this vertex: deactivate.
            return false;
        }
        let b32 = self.worklist[wi];
        let b = idx(b32);
        let budget_left = self.need[wi];
        let mut need = budget_left;
        let mut last_a: Option<usize> = None;
        for item in plan {
            if need == 0 {
                break;
            }
            last_a = Some(idx(item.a));
            if item.slot == SLOT_FREE {
                let g = need.min(self.a_free[idx(item.a)]);
                if g > 0 {
                    self.a_free[idx(item.a)] -= g;
                    self.pending.push(Pending { a: item.a, b: b32, units: g, y_pre: 0 });
                    need -= g;
                }
            } else {
                let ci = idx(item.a) * SLOTS + usize::from(item.slot);
                let g = need.min(self.cls_count[ci]);
                if g > 0 {
                    let y_pre = self.cls_y[ci];
                    self.steal_from_slot(ci, g);
                    self.pending.push(Pending { a: item.a, b: b32, units: g, y_pre });
                    need -= g;
                }
            }
        }
        // Matched units leave b's free pool now, so eviction bookkeeping
        // stays exact (b_free may also grow through evictions).
        self.b_free[b] -= budget_left - need;
        self.need[wi] = need;
        if need == 0 {
            return false; // fully matched
        }
        if let Some(a) = last_a {
            self.cursor[wi] = to_u32(a + 1);
        }
        !exhausted
    }

    /// Remove `take` matched units from a cluster slot, evicting their
    /// supply partners back into `b_free` (raised to `y_free[b]`, the
    /// free-copies-at-max invariant).
    fn steal_from_slot(&mut self, idx: usize, mut take: u64) {
        debug_assert!(self.cls_count[idx] >= take);
        self.cls_count[idx] -= take;
        let mut prev = NIL;
        let mut e = self.cls_head[idx];
        while e != NIL && take > 0 {
            let k = take.min(self.edge_units[idx(e)]);
            self.edge_units[idx(e)] -= k;
            take -= k;
            // evicted copies of the old partner become free again (raised
            // to its y_free — the max-dual invariant)
            let b_old = idx(self.edge_b[idx(e)]);
            self.b_free[b_old] += k;
            let next = self.edge_next[idx(e)];
            if self.edge_units[idx(e)] == 0 {
                // unlink + recycle
                if prev == NIL {
                    self.cls_head[idx] = next;
                } else {
                    self.edge_next[idx(prev)] = next;
                }
                self.edge_next[idx(e)] = self.edge_free;
                self.edge_free = e;
            } else {
                prev = e;
            }
            e = next;
        }
        debug_assert_eq!(take, 0, "cluster flow accounting out of sync");
    }

    /// Find the live slot of `a` at dual `y`, or claim an empty one.
    fn slot_for(&mut self, a: usize, y: i32) -> usize {
        let base = a * SLOTS;
        let mut empty = None;
        for s in 0..SLOTS {
            if self.cls_count[base + s] > 0 {
                if self.cls_y[base + s] == y {
                    return base + s;
                }
            } else if empty.is_none() {
                empty = Some(base + s);
            }
        }
        let slot = match empty {
            Some(s) => s,
            None if self.lemma41_strict => {
                // Slot exhaustion on a cold solve means the Lemma 4.1
                // proof was violated — an algorithm bug, not a recoverable
                // input error.
                // panic-ok: algorithm-invariant violations must fail loudly
                panic!("cluster slots exhausted at a={a}: >{SLOTS} distinct dual values (Lemma 4.1 violated)")
            }
            None => {
                // Warm-started states can transiently exceed the Lemma 4.1
                // live budget; release the smallest cluster back to the
                // free pools on *both* sides (capacity conserved) and
                // reuse its slot. Freed dual-0 demand copies may tighten
                // (2), so a feasibility fixup runs at the end of this
                // phase, before the next phase proposes. (Later rounds of
                // the current phase see the freed capacity but stay
                // conservative: an over-dual supply simply skips it.)
                let mut s = base;
                for t in base + 1..base + SLOTS {
                    if self.cls_count[t] < self.cls_count[s] {
                        s = t;
                    }
                }
                let n = self.cls_count[s];
                self.steal_from_slot(s, n);
                self.a_free[a] += n;
                self.release_fixup_needed = true;
                self.slot_evictions += 1;
                s
            }
        };
        debug_assert_eq!(self.cls_head[slot], NIL, "reused slot with stale edges");
        self.cls_y[slot] = y;
        slot
    }

    /// Add `units` of flow (slot → b), merging into an existing partner
    /// edge when present.
    fn add_edge(&mut self, slot: usize, b: u32, units: u64) {
        let mut e = self.cls_head[slot];
        while e != NIL {
            if self.edge_b[idx(e)] == b {
                self.edge_units[idx(e)] += units;
                return;
            }
            e = self.edge_next[idx(e)];
        }
        let e = if self.edge_free != NIL {
            let e = self.edge_free;
            self.edge_free = self.edge_next[idx(e)];
            self.edge_b[idx(e)] = b;
            self.edge_units[idx(e)] = units;
            self.edge_next[idx(e)] = self.cls_head[slot];
            e
        } else {
            let e = to_u32(self.edge_b.len());
            self.edge_b.push(b);
            self.edge_units.push(units);
            self.edge_next.push(self.cls_head[slot]);
            e
        };
        self.cls_head[slot] = e;
    }

    /// Update `max_classes_seen` (distinct dual values per demand vertex;
    /// Lemma 4.1 bounds it by 2).
    fn track_classes(&mut self) {
        for a in 0..self.na {
            let base = a * SLOTS;
            let live = (0..SLOTS).filter(|&s| self.cls_count[base + s] > 0).count();
            let distinct = live + usize::from(self.a_free[a] > 0);
            if distinct > self.max_classes_seen {
                self.max_classes_seen = distinct;
            }
            debug_assert!(
                !self.lemma41_strict || live <= 2,
                "Lemma 4.1 violated at a={a}: {live} matched clusters"
            );
        }
    }

    /// Export one ε-unit dual per *original* vertex for certification:
    /// the maximum dual among a vertex's conceptual copies. For supply b
    /// that is `y_free[b]`; for demand a it is 0 while free copies
    /// remain, else the largest cluster dual; a zero-mass demand vertex
    /// gets the largest edge-feasible value clamped to the sign
    /// invariant, so the exported vector stays checkable.
    pub fn export_duals(&self) -> DualWeights {
        let ya = (0..self.na)
            .map(|a| {
                if self.a_free[a] > 0 {
                    return 0;
                }
                let base = a * SLOTS;
                let live_max = (0..SLOTS)
                    .filter(|&s| self.cls_count[base + s] > 0)
                    .map(|s| self.cls_y[base + s])
                    .max();
                match live_max {
                    Some(y) => y,
                    None => (0..self.nb)
                        .map(|b| self.q.at(b, a) + 1 - self.y_free[b])
                        .min()
                        .unwrap_or(0)
                        .min(0),
                }
            })
            .collect();
        DualWeights { ya, yb: self.y_free.clone() }
    }

    /// Extract the unit flow as a dense (b, a) matrix.
    pub fn unit_flow(&self) -> Vec<u64> {
        let mut flow = vec![0u64; self.nb * self.na];
        for a in 0..self.na {
            let base = a * SLOTS;
            for s in 0..SLOTS {
                if self.cls_count[base + s] == 0 {
                    continue;
                }
                let mut e = self.cls_head[base + s];
                while e != NIL {
                    flow[idx(self.edge_b[idx(e)]) * self.na + a] +=
                        self.edge_units[idx(e)];
                    e = self.edge_next[idx(e)];
                }
            }
        }
        flow
    }

    /// Extract the unit flow as CSR in canonical (b-ascending rows,
    /// strictly a-ascending columns) order — the sparse twin of
    /// [`KernelArena::unit_flow`] with no nb·na densification: resident
    /// state is O(nnz), and nnz is bounded by the live cluster edges.
    ///
    /// Counting sort by supply row over the same a-major cluster-edge
    /// walk `unit_flow` performs. Because the outer loop ascends `a`,
    /// each row's columns arrive non-decreasing; the only duplicates a
    /// row can see are the *adjacent* kind — the same (b, a) pair held
    /// by two different slots of one demand vertex (`add_edge` merges
    /// within a slot only) — and those fold into one entry in place.
    // CONTRACT: sparse extraction order == dense fold order — rows emit
    // b-ascending with strictly a-ascending columns, so a fold over this
    // CSR visits exactly the positive entries of `unit_flow` in dense
    // row-major order and downstream bit-identity claims hold.
    pub fn extract_plan_sparse(&self) -> UnitFlowCsr {
        // pass 1: per-row entry upper bounds (slot-duplicate pairs count
        // twice here; the write pass merges them and rows compact after)
        let mut counts = vec![0usize; self.nb];
        for a in 0..self.na {
            let base = a * SLOTS;
            for s in 0..SLOTS {
                if self.cls_count[base + s] == 0 {
                    continue;
                }
                let mut e = self.cls_head[base + s];
                while e != NIL {
                    counts[idx(self.edge_b[idx(e)])] += 1;
                    e = self.edge_next[idx(e)];
                }
            }
        }
        let mut start = vec![0usize; self.nb + 1];
        for b in 0..self.nb {
            start[b + 1] = start[b] + counts[b];
        }
        let cap = start[self.nb];
        let mut col_idx = vec![0u32; cap];
        let mut units = vec![0u64; cap];
        let mut cursor = start.clone();
        // pass 2: scatter edges to their rows, merging adjacent duplicates
        for a in 0..self.na {
            let base = a * SLOTS;
            let ac = to_u32(a);
            for s in 0..SLOTS {
                if self.cls_count[base + s] == 0 {
                    continue;
                }
                let mut e = self.cls_head[base + s];
                while e != NIL {
                    let b = idx(self.edge_b[idx(e)]);
                    let u = self.edge_units[idx(e)];
                    let c = cursor[b];
                    if c > start[b] && col_idx[c - 1] == ac {
                        units[c - 1] += u;
                    } else {
                        col_idx[c] = ac;
                        units[c] = u;
                        cursor[b] = c + 1;
                    }
                    e = self.edge_next[idx(e)];
                }
            }
        }
        // pass 3: close the merge gaps (writes never overtake reads —
        // w ≤ start[b] ≤ lo for every row) and finalize row_ptr
        let mut row_ptr = vec![0usize; self.nb + 1];
        let mut w = 0usize;
        for b in 0..self.nb {
            for r in start[b]..cursor[b] {
                col_idx[w] = col_idx[r];
                units[w] = units[r];
                w += 1;
            }
            row_ptr[b + 1] = w;
        }
        col_idx.truncate(w);
        units.truncate(w);
        UnitFlowCsr { row_ptr, col_idx, units }
    }

    /// Extract the matching (unit-mass instances: every vertex carries
    /// one unit, so each live edge is one matched pair).
    pub fn extract_matching(&self) -> Matching {
        let mut m = Matching::empty(self.nb, self.na);
        for a in 0..self.na {
            let base = a * SLOTS;
            for s in 0..SLOTS {
                if self.cls_count[base + s] == 0 {
                    continue;
                }
                let mut e = self.cls_head[base + s];
                while e != NIL {
                    debug_assert_eq!(
                        self.edge_units[idx(e)], 1,
                        "extract_matching on a multi-unit instance"
                    );
                    m.link(idx(self.edge_b[idx(e)]), a);
                    e = self.edge_next[idx(e)];
                }
            }
        }
        m
    }

    /// Structural feasibility of the cluster state: counts consistent,
    /// dual signs, ε-feasibility (2)/(3) of every cluster pair, and the
    /// free-copies-at-max invariant. O(n²) — tests and paranoid mode.
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        for b in 0..self.nb {
            if self.y_free[b] < 0 {
                return Err(format!("y_free[{b}] = {} < 0", self.y_free[b]));
            }
        }
        for a in 0..self.na {
            let base = a * SLOTS;
            let live = (0..SLOTS).filter(|&s| self.cls_count[base + s] > 0).count();
            if live > 2 && self.lemma41_strict {
                return Err(format!("Lemma 4.1 violated at a={a}: {live} matched clusters"));
            }
            for s in 0..SLOTS {
                let idx = base + s;
                if self.cls_count[idx] == 0 {
                    if self.cls_head[idx] != NIL {
                        return Err(format!("empty slot with live edges at a={a}"));
                    }
                    continue;
                }
                if self.cls_y[idx] > 0 {
                    return Err(format!("matched cluster at a={a} has positive dual"));
                }
                let mut total = 0u64;
                let mut e = self.cls_head[idx];
                while e != NIL {
                    total += self.edge_units[idx(e)];
                    // (3) for matched copies: implicit b-copy dual
                    // cq − y_cls must not exceed y_free[b] (free copies
                    // sit at the max).
                    let b = idx(self.edge_b[idx(e)]);
                    let implied_yb = self.q.at(b, a) - self.cls_y[idx];
                    if implied_yb > self.y_free[b] {
                        return Err(format!(
                            "max-dual invariant violated: b={b} matched copy dual {implied_yb} > y_free {}",
                            self.y_free[b]
                        ));
                    }
                    e = self.edge_next[idx(e)];
                }
                if total != self.cls_count[idx] {
                    return Err(format!(
                        "cluster count mismatch at a={a}: edges {total} != count {}",
                        self.cls_count[idx]
                    ));
                }
            }
            // (2) for free b copies against free a copies (dual 0) and
            // against matched clusters.
            for b in 0..self.nb {
                let cq1 = self.q.at(b, a) + 1;
                if self.a_free[a] > 0 && self.b_free[b] > 0 && self.y_free[b] > cq1 {
                    return Err(format!(
                        "(2) violated free-free at (b={b},a={a}): y_free {} > cq+1 {cq1}",
                        self.y_free[b]
                    ));
                }
                if self.b_free[b] > 0 {
                    for s in 0..SLOTS {
                        if self.cls_count[base + s] > 0
                            && self.cls_y[base + s] + self.y_free[b] > cq1
                        {
                            return Err(format!(
                                "(2) violated free-b vs cluster at (b={b},a={a},y={})",
                                self.cls_y[base + s]
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }
}
