//! The shared flow kernel behind every push-relabel engine.
//!
//! The paper's framework (§2–§4) is one algorithm instantiated three
//! ways; this module is the one tuned implementation all three drivers
//! (`solvers/push_relabel`, `solvers/parallel_pr`,
//! `solvers/ot_push_relabel`) sit on, and the layer any future backend
//! (SIMD, GPU) plugs into:
//!
//! * [`KernelArena`] — the flat SoA state (quantized costs, duals,
//!   residual units, fixed-width cluster slots, pooled flow edges,
//!   bitset-backed worklists) with allocation reuse across `init` calls
//!   and in-place ε re-targeting ([`KernelArena::rescale`] /
//!   [`KernelArena::warm_reinit`]) for warm starts;
//! * [`FlowKernel`] — the backend contract: `init` / `run_phase` /
//!   `duals` / `extract_matching` / `unit_flow`;
//! * [`ScalarKernel`] — sequential propose sweep;
//! * [`ChunkedKernel`] — the same sweep fanned out over scoped threads;
//! * [`VectorKernel`] — the sweep over a lane-blocked cost mirror with
//!   block-min skipping (auto-vectorized, cache-tiled);
//! * [`HybridKernel`] — the lane-blocked sweep fanned out over scoped
//!   threads: every core runs the fast path (vector × chunked).
//!
//! **Backend equivalence is a hard contract**: a phase proposes against a
//! stable snapshot and commits sequentially in ascending vertex order,
//! so scalar, chunked, vector, and hybrid produce *identical* matchings,
//! plans, duals, and round counts at every thread or lane count
//! (`tests/conformance_golden.rs` pins this on the golden corpus).
//!
//! Drivers own policy — ε semantics, θ-scaling, phase caps, completion,
//! and the [`WarmStart`] ε-scaling schedule — while invariant checks live
//! here ([`KernelArena::check_invariants`], plus `debug_assertions` on
//! the phase loop) so `certify` keeps working against any backend
//! unchanged.

pub mod arena;
pub mod chunked;
pub mod hybrid;
pub mod scalar;
pub mod vector;

pub use arena::{
    KernelArena, KernelPhase, KernelView, PlanItem, RowScratch, UnitFlowCsr, PLAN_WIDTH, SLOTS,
    SLOT_FREE,
};
pub use chunked::ChunkedKernel;
pub use hybrid::HybridKernel;
pub use scalar::ScalarKernel;
pub use vector::VectorKernel;

/// ε-scaling warm-start policy the drivers (`drive_assignment` /
/// `drive_ot`) execute: solve a geometric ε schedule coarse→fine
/// (e.g. 4ε → 2ε → ε), carrying the arena's duals and still-tight flow
/// across levels via [`KernelArena::rescale`]; in batched solves,
/// additionally reuse the previous same-shape instance's duals via
/// [`KernelArena::warm_reinit`] instead of re-running the coarse levels.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WarmStart {
    /// Geometric ε levels (…4ε, 2ε, ε). 0 or 1 = single-level cold solve.
    pub levels: u32,
    /// Reuse the arena's duals from the previous same-shape solve instead
    /// of running the coarse levels (the batch path; silently falls back
    /// to the schedule when the arena holds no compatible state).
    pub carry: bool,
}

impl WarmStart {
    /// Single-level solve, no dual reuse — the historical behavior.
    pub const COLD: WarmStart = WarmStart { levels: 0, carry: false };

    /// A `levels`-deep geometric schedule with batch dual reuse enabled.
    pub fn geometric(levels: u32) -> Self {
        Self { levels, carry: true }
    }

    /// The ε schedule ending at `eps`, coarsest first. Levels at or above
    /// 1.0 are dropped (quantization requires ε < 1), so a coarse target
    /// simply gets a shorter schedule.
    pub fn schedule(&self, eps: f64) -> Vec<f64> {
        let l = self.levels.max(1);
        let mut v: Vec<f64> = (0..l)
            // cast-ok: levels are a small user-facing u32 count, far below i32::MAX
            .map(|i| eps * f64::powi(2.0, (l - 1 - i) as i32))
            .filter(|e| *e < 1.0)
            .collect();
        if v.is_empty() {
            v.push(eps);
        }
        v
    }

    /// Resolve the level plan for one solve against the arena's current
    /// state — the single policy both drivers (`drive_assignment` /
    /// `drive_ot`) share, so the carry predicate and schedule semantics
    /// cannot drift apart. Returns `(schedule, carried, warm_started)`:
    /// a batch carry (duals reused via [`KernelArena::warm_reinit`])
    /// requires a previously initialized arena of exactly the instance's
    /// shape and jumps straight to the target ε; otherwise the geometric
    /// schedule runs.
    pub fn plan(
        &self,
        arena: &KernelArena,
        nb: usize,
        na: usize,
        eps: f64,
    ) -> (Vec<f64>, bool, bool) {
        let carried = self.carry && arena.inits > 0 && arena.nb() == nb && arena.na() == na;
        let schedule = if carried { vec![eps] } else { self.schedule(eps) };
        let warm_started = carried || schedule.len() > 1;
        (schedule, carried, warm_started)
    }
}

use crate::core::cost::CostMatrix;
use crate::core::duals::DualWeights;
use crate::core::matching::Matching;
use crate::core::provider::CostSource;

/// One flow-kernel backend: owns an arena and decides how the per-phase
/// propose sweep executes. Everything else — state layout, accept order,
/// relabels, extraction — is shared arena code, which is what guarantees
/// backend-identical results.
pub trait FlowKernel: Send {
    /// Backend name (for notes/metrics).
    fn name(&self) -> &'static str;

    /// Worker threads the sweep uses (1 for the scalar backend).
    fn threads(&self) -> usize {
        1
    }

    fn arena(&self) -> &KernelArena;

    fn arena_mut(&mut self) -> &mut KernelArena;

    /// Prepare for a new instance (reusing the arena's allocations).
    /// `masses = None` is the unit-mass assignment case.
    fn init(&mut self, costs: &CostMatrix, eps: f64, masses: Option<(&[u64], &[u64])>) {
        self.arena_mut().init(costs, eps, masses);
    }

    /// [`FlowKernel::init`] over either cost representation — implicit
    /// providers never materialize the O(n²) slab (see
    /// [`KernelArena::init_src`]).
    fn init_src(&mut self, costs: &CostSource<'_>, eps: f64, masses: Option<(&[u64], &[u64])>) {
        self.arena_mut().init_src(costs, eps, masses);
    }

    /// Run one phase; `terminated` means the ε-threshold held.
    fn run_phase(&mut self) -> KernelPhase;

    /// Run phases until termination or `phase_cap` is exceeded (the cap
    /// bounds are Lemma 3.2/3.3; exceeding one is a bug, not slowness).
    fn run_to_termination(&mut self, phase_cap: usize) -> std::result::Result<(), String> {
        loop {
            if self.run_phase().terminated {
                return Ok(());
            }
            if self.arena().phases > phase_cap {
                return Err(format!(
                    "phase cap {phase_cap} exceeded — phase-count bound violated (bug)"
                ));
            }
        }
    }

    /// Exported ε-unit duals (max copy dual per vertex).
    fn duals(&self) -> DualWeights {
        self.arena().export_duals()
    }

    /// Extract the matching (unit-mass instances only).
    fn extract_matching(&self) -> Matching {
        self.arena().extract_matching()
    }

    /// Extract the unit flow as a dense (b, a) matrix.
    fn unit_flow(&self) -> Vec<u64> {
        self.arena().unit_flow()
    }

    /// Extract the unit flow as canonical-order CSR — O(nnz) resident,
    /// no nb·na slab (see [`KernelArena::extract_plan_sparse`]). The OT
    /// driver builds its `TransportPlan` from this.
    fn extract_plan_sparse(&self) -> arena::UnitFlowCsr {
        self.arena().extract_plan_sparse()
    }

    /// O(n²) structural invariant check (tests / paranoid mode).
    fn check_invariants(&self) -> std::result::Result<(), String> {
        self.arena().check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::CostMatrix;
    use crate::util::rng::Pcg32;

    fn random_costs(n: usize, seed: u64) -> CostMatrix {
        let mut rng = Pcg32::new(seed);
        CostMatrix::from_fn(n, n, |_, _| rng.next_f32())
    }

    #[test]
    fn scalar_terminates_and_extracts_consistent_matching() {
        let costs = random_costs(24, 1);
        let mut k = ScalarKernel::new();
        k.init(&costs, 0.15, None);
        k.run_to_termination(10_000).unwrap();
        k.check_invariants().unwrap();
        let m = k.extract_matching();
        m.check_consistent().unwrap();
        // ≤ ε·n free vertices remain
        assert!(k.arena().free_units() <= k.arena().threshold());
        // duals export with the paper's sign invariants
        let y = k.duals();
        assert!(y.yb.iter().all(|&v| v >= 0));
        assert!(y.ya.iter().all(|&v| v <= 0));
    }

    #[test]
    fn scalar_and_chunked_are_result_identical() {
        for seed in 0..4u64 {
            let costs = random_costs(20, seed);
            let mut ks = ScalarKernel::new();
            ks.init(&costs, 0.2, None);
            ks.run_to_termination(10_000).unwrap();
            for threads in [1usize, 2, 5] {
                let mut kc = ChunkedKernel::new(threads);
                kc.init(&costs, 0.2, None);
                kc.run_to_termination(10_000).unwrap();
                assert_eq!(ks.extract_matching(), kc.extract_matching(), "seed {seed} t{threads}");
                assert_eq!(ks.duals(), kc.duals(), "seed {seed} t{threads}");
                assert_eq!(ks.arena().rounds, kc.arena().rounds);
                assert_eq!(ks.arena().phases, kc.arena().phases);
            }
        }
    }

    #[test]
    fn ot_masses_flow_conserved() {
        let costs = random_costs(10, 7);
        let supply: Vec<u64> = (0..10).map(|b| 3 + (b % 4) as u64).collect();
        let demand: Vec<u64> = (0..10).map(|a| 5 + (a % 3) as u64).collect();
        // total demand ≥ total supply so the transport is feasible
        assert!(demand.iter().sum::<u64>() >= supply.iter().sum::<u64>());
        let mut k = ScalarKernel::new();
        k.init(&costs, 0.1, Some((&supply[..], &demand[..])));
        k.run_to_termination(100_000).unwrap();
        k.check_invariants().unwrap();
        let flow = k.unit_flow();
        // matched + free units account for all supply, per vertex
        for b in 0..10 {
            let shipped: u64 = (0..10).map(|a| flow[b * 10 + a]).sum();
            assert_eq!(shipped + k.arena().b_free()[b], supply[b], "b={b}");
        }
        // no demand vertex over capacity
        for a in 0..10 {
            let got: u64 = (0..10).map(|b| flow[b * 10 + a]).sum();
            assert!(got + k.arena().a_free()[a] == demand[a], "a={a}");
        }
        assert!(k.arena().max_classes_seen <= 2, "Lemma 4.1");
    }

    #[test]
    fn warm_start_schedule_shapes() {
        assert_eq!(WarmStart::COLD.schedule(0.1), vec![0.1]);
        assert_eq!(WarmStart::geometric(3).schedule(0.1), vec![0.4, 0.2, 0.1]);
        // coarse levels at or above 1.0 drop off the front
        assert_eq!(WarmStart::geometric(3).schedule(0.3), vec![0.6, 0.3]);
        assert_eq!(WarmStart::geometric(1).schedule(0.2), vec![0.2]);
        assert!(WarmStart::geometric(3).carry);
        assert!(!WarmStart::COLD.carry);

        // plan(): a batch carry needs an initialized arena of the exact
        // instance shape; anything else falls back to the schedule.
        let mut k = ScalarKernel::new();
        let w = WarmStart::geometric(3);
        let (sched, carried, warm) = w.plan(k.arena(), 6, 6, 0.1);
        assert!(!carried, "uninitialized arena cannot carry");
        assert!(warm && sched.len() == 3);
        k.init(&random_costs(6, 1), 0.2, None);
        let (sched, carried, warm) = w.plan(k.arena(), 6, 6, 0.1);
        assert!(carried && warm);
        assert_eq!(sched, vec![0.1], "carry jumps straight to the target ε");
        let (sched, carried, _) = w.plan(k.arena(), 7, 7, 0.1);
        assert!(!carried && sched.len() == 3, "shape mismatch falls back");
    }

    #[test]
    fn rescale_preserves_feasibility_and_reaches_fine_threshold() {
        use crate::core::duals::check_feasible;
        for seed in 0..3u64 {
            let costs = random_costs(22, seed);
            let mut k = ScalarKernel::new();
            k.init(&costs, 0.4, None);
            k.run_to_termination(10_000).unwrap();
            let coarse_phases = k.arena().phases;
            k.arena_mut().rescale(&costs, 0.1);
            // immediately after the rescale the state is ε-feasible…
            k.check_invariants().unwrap();
            k.run_to_termination(100_000).unwrap();
            k.check_invariants().unwrap();
            // …and the continued solve meets the fine ε's free threshold
            assert!(k.arena().free_units() <= k.arena().threshold(), "seed {seed}");
            check_feasible(&k.arena().q, &k.extract_matching(), &k.duals()).unwrap();
            assert!(k.arena().phases >= coarse_phases);
            assert_eq!(k.arena().rescales, 1);
        }
    }

    #[test]
    fn warm_reinit_carries_clamped_duals_to_a_new_instance() {
        use crate::core::duals::check_feasible;
        let (c1, c2) = (random_costs(12, 1), random_costs(12, 2));
        let mut k = ScalarKernel::new();
        k.init(&c1, 0.2, None);
        k.run_to_termination(10_000).unwrap();
        k.arena_mut().warm_reinit(&c2, 0.2, None);
        for b in 0..12 {
            let y = k.arena().y_free()[b];
            assert!(y >= 1, "carried duals stay in the paper's init band");
            let bound = k.arena().q.row(b).iter().min().unwrap() + 1;
            assert!(y <= bound, "b={b}: y={y} violates (2) against free demand");
        }
        k.check_invariants().unwrap();
        k.run_to_termination(10_000).unwrap();
        let m = k.extract_matching();
        m.check_consistent().unwrap();
        assert!(k.arena().free_units() <= k.arena().threshold());
        check_feasible(&k.arena().q, &m, &k.duals()).unwrap();
        assert_eq!(k.arena().warm_reinits, 1);
        assert!(k.arena().last_init_reused, "warm_reinit reuses the arena allocations");
    }

    #[test]
    fn implicit_costs_identical_across_scalar_and_chunked() {
        use crate::core::provider::{Costs, GeneratedCosts};
        let dense = random_costs(18, 5);
        let grid = dense.clone();
        let costs =
            Costs::generated(GeneratedCosts::new(18, 18, move |b, a| grid.at(b, a)).unwrap());
        let mut kd = ScalarKernel::new();
        kd.init(&dense, 0.2, None);
        kd.run_to_termination(10_000).unwrap();
        let mut ki = ScalarKernel::new();
        ki.init_src(&costs.source(), 0.2, None);
        ki.run_to_termination(10_000).unwrap();
        ki.check_invariants().unwrap();
        assert_eq!(kd.extract_matching(), ki.extract_matching());
        assert_eq!(kd.duals(), ki.duals());
        assert_eq!(kd.arena().rounds, ki.arena().rounds);
        assert_eq!(ki.arena().cost_state_bytes(), 0, "scalar implicit holds no cost state");
        for threads in [2usize, 5] {
            let mut kc = ChunkedKernel::new(threads);
            kc.init_src(&costs.source(), 0.2, None);
            kc.run_to_termination(10_000).unwrap();
            assert_eq!(kd.extract_matching(), kc.extract_matching(), "t{threads}");
            assert_eq!(kd.duals(), kc.duals(), "t{threads}");
        }
        // OT masses through the implicit path
        let supply: Vec<u64> = (0..18).map(|b| 2 + (b % 3) as u64).collect();
        let demand: Vec<u64> = (0..18).map(|a| 3 + (a % 2) as u64).collect();
        let mut od = ScalarKernel::new();
        od.init(&dense, 0.15, Some((&supply[..], &demand[..])));
        od.run_to_termination(100_000).unwrap();
        let mut oi = ScalarKernel::new();
        oi.init_src(&costs.source(), 0.15, Some((&supply[..], &demand[..])));
        oi.run_to_termination(100_000).unwrap();
        assert_eq!(od.unit_flow(), oi.unit_flow());
        assert_eq!(od.duals(), oi.duals());
    }

    /// Stale-row-cache regression (PR 7 audit): one backend reused across
    /// two *different* implicit instances of the same shape must not serve
    /// quantized rows from the first instance to the second. Every
    /// arena-reuse path (`init_src` reuse, `rescale_src`, `warm_reinit`)
    /// routes through `requantize`/`requantize_implicit`, which bump the
    /// `QuantizedCosts::epoch` keying the per-thread `RowScratch` LRUs —
    /// this pins that the reused solve is byte-identical to a cold one.
    #[test]
    fn implicit_row_cache_invalidates_across_reused_instances() {
        use crate::core::provider::{Costs, GeneratedCosts};
        let n = 16;
        let mk = |seed: u64| {
            let dense = random_costs(n, seed);
            let grid = dense.clone();
            (dense, Costs::generated(GeneratedCosts::new(n, n, move |b, a| grid.at(b, a)).unwrap()))
        };
        let (_, c1) = mk(31);
        let (_, c2) = mk(32);
        // warm: one kernel solves instance 1, then is re-inited on
        // instance 2 (same shape → arena + row caches are reused)
        let mut warm = ChunkedKernel::new(4);
        warm.init_src(&c1.source(), 0.2, None);
        warm.run_to_termination(10_000).unwrap();
        let epoch1 = warm.arena().q.epoch;
        warm.init_src(&c2.source(), 0.2, None);
        assert!(warm.arena().last_init_reused, "same shape must reuse the arena");
        assert_ne!(warm.arena().q.epoch, epoch1, "reuse must bump the row-cache epoch");
        warm.run_to_termination(10_000).unwrap();
        warm.check_invariants().unwrap();
        // cold: a fresh kernel solves instance 2 from scratch
        let mut cold = ChunkedKernel::new(4);
        cold.init_src(&c2.source(), 0.2, None);
        cold.run_to_termination(10_000).unwrap();
        assert_eq!(warm.extract_matching(), cold.extract_matching());
        assert_eq!(warm.duals(), cold.duals());
        assert_eq!(warm.arena().rounds, cold.arena().rounds);
        // same audit for the hybrid backend's per-thread lane/LRU path
        let mut hwarm = HybridKernel::new(4);
        hwarm.init_src(&c1.source(), 0.2, None);
        hwarm.run_to_termination(10_000).unwrap();
        hwarm.init_src(&c2.source(), 0.2, None);
        hwarm.run_to_termination(10_000).unwrap();
        assert_eq!(hwarm.extract_matching(), cold.extract_matching());
        assert_eq!(hwarm.duals(), cold.duals());
    }

    #[test]
    fn arena_reuse_counts_same_shape_inits() {
        let mut k = ScalarKernel::new();
        k.init(&random_costs(8, 1), 0.2, None);
        assert!(!k.arena().last_init_reused);
        k.init(&random_costs(8, 2), 0.2, None);
        assert!(k.arena().last_init_reused);
        k.init(&random_costs(9, 3), 0.2, None);
        assert!(!k.arena().last_init_reused, "shape change is not a reuse");
        assert_eq!(k.arena().reuse_hits, 1);
        assert_eq!(k.arena().inits, 3);
        // the re-inited arena still solves correctly
        k.run_to_termination(10_000).unwrap();
        k.check_invariants().unwrap();
    }
}
