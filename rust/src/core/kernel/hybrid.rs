//! Hybrid flow-kernel backend: the lane-blocked propose sweep of
//! [`crate::core::kernel::VectorKernel`] fanned over scoped threads in
//! contiguous chunks of the active worklist, exactly as
//! [`crate::core::kernel::ChunkedKernel`] fans the scalar sweep. Every
//! core runs the fast path: per-block-min skip over
//! [`crate::core::quantize::LANES`]-wide `i32` blocks, fixed-width inner
//! loops that auto-vectorize on stable Rust.
//!
//! Byte-identity holds by construction at every thread count: workers
//! stage proposals only against the round snapshot into disjoint plan
//! windows, and commits happen sequentially in ascending rank order
//! inside `KernelArena::run_phase` — the same contract Scalar, Chunked,
//! and Vector already share (`tests/conformance_golden.rs` pins it on
//! the golden corpus; `tests/sanitizer_small.rs` `tsan_hybrid_*` runs it
//! under ThreadSanitizer).
//!
//! Implicit costs keep the vector backend's memory model — the streamed
//! per-block-min cache is the only n²-shaped state — and add one
//! [`RowScratch`] row-window LRU *per sweep thread*: blocks that survive
//! the skip filter read their quantized row from the thread's cache
//! (filled from the provider once per window) instead of re-quantizing
//! per block. Cached rows are exactly the dense `cq` rows, so caching
//! never changes results, only how often the provider streams.

// Kernel-scope lint wall: all narrowing index math must go through the
// checked helpers in `arena` (`idx`/`to_u32`/`to_u8`).
#![deny(clippy::cast_possible_truncation, clippy::cast_sign_loss)]

use crate::core::kernel::arena::{
    idx, to_u8, KernelArena, KernelPhase, KernelView, PlanItem, RowScratch, PLAN_WIDTH,
};
use crate::core::kernel::FlowKernel;

/// The lane-blocked sweep body with a per-thread row cache: identical
/// proposals to [`crate::core::kernel::vector::vector_sweep`] (and hence
/// to `sequential_sweep`), staged through
/// [`KernelView::propose_one_lanes_cached`]. Each worker thread of the
/// hybrid backend runs this over its contiguous window of the active
/// worklist with its own `scratch`.
// CONTRACT: round-structured accept order — this sweep only stages
// proposals against the round snapshot; commits happen sequentially in
// KernelArena::run_phase in ascending rank order.
pub fn hybrid_sweep(
    view: &KernelView<'_>,
    actives: &[u32],
    plans: &mut [PlanItem],
    plan_len: &mut [u8],
    exhausted: &mut [bool],
    scratch: &mut RowScratch,
) {
    for (i, &wi) in actives.iter().enumerate() {
        let out = &mut plans[i * PLAN_WIDTH..(i + 1) * PLAN_WIDTH];
        let (len, ex) = view.propose_one_lanes_cached(idx(wi), out, &mut *scratch);
        plan_len[i] = to_u8(len);
        exhausted[i] = ex;
    }
}

#[derive(Debug)]
pub struct HybridKernel {
    arena: KernelArena,
    threads: usize,
    /// One row-window LRU per sweep thread for implicit costs (values are
    /// pure per-row quantizations, so per-thread caching cannot perturb
    /// the thread-invariant result contract).
    scratch: Vec<RowScratch>,
}

impl HybridKernel {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let mut scratch = Vec::with_capacity(threads);
        scratch.resize_with(threads, RowScratch::default);
        Self { arena: KernelArena::with_lanes(), threads, scratch }
    }
}

impl FlowKernel for HybridKernel {
    fn name(&self) -> &'static str {
        "kernel-hybrid"
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn arena(&self) -> &KernelArena {
        &self.arena
    }

    fn arena_mut(&mut self) -> &mut KernelArena {
        &mut self.arena
    }

    // CONTRACT: round-structured accept order — worker threads only stage
    // proposals into disjoint plan windows against the round snapshot;
    // commits happen inside KernelArena::run_phase in ascending rank order,
    // so the result is identical to the scalar backend at any thread count.
    fn run_phase(&mut self) -> KernelPhase {
        let threads = self.threads;
        let scratch = &mut self.scratch;
        self.arena.run_phase(|view, active, plans, plan_len, exhausted| {
            let n = active.len();
            let workers = threads.min(n.max(1));
            if workers <= 1 {
                hybrid_sweep(view, active, plans, plan_len, exhausted, &mut scratch[0]);
                return;
            }
            let chunk = n.div_ceil(workers);
            std::thread::scope(|s| {
                // chunks/chunks_mut yield disjoint windows, so each worker
                // owns its slice of the plan buffers (and its own row
                // scratch) and runs the one shared lane-sweep body over it
                for ((((acts, pl), ll), el), rs) in active
                    .chunks(chunk)
                    .zip(plans.chunks_mut(chunk * PLAN_WIDTH))
                    .zip(plan_len.chunks_mut(chunk))
                    .zip(exhausted.chunks_mut(chunk))
                    .zip(scratch.iter_mut())
                {
                    s.spawn(move || hybrid_sweep(view, acts, pl, ll, el, rs));
                }
            });
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::kernel::ScalarKernel;
    use crate::core::provider::{Costs, GeneratedCosts};
    use crate::core::CostMatrix;
    use crate::util::rng::Pcg32;

    fn random_costs(n: usize, seed: u64) -> CostMatrix {
        let mut rng = Pcg32::new(seed);
        CostMatrix::from_fn(n, n, |_, _| rng.next_f32())
    }

    fn generated_mirror(dense: &CostMatrix, n: usize) -> Costs {
        let grid = dense.clone();
        Costs::generated(GeneratedCosts::new(n, n, move |b, a| grid.at(b, a)).unwrap())
    }

    #[test]
    fn hybrid_identical_to_scalar_across_threads_and_padding_widths() {
        // n = 8, 24 exercise the exact-multiple path, the rest the padding.
        for n in [5usize, 8, 11, 20, 24] {
            for seed in [1u64, 3] {
                let costs = random_costs(n, seed);
                let mut ks = ScalarKernel::new();
                ks.init(&costs, 0.2, None);
                ks.run_to_termination(10_000).unwrap();
                for threads in [1usize, 2, 4, 8] {
                    let mut kh = HybridKernel::new(threads);
                    kh.init(&costs, 0.2, None);
                    kh.run_to_termination(10_000).unwrap();
                    kh.check_invariants().unwrap();
                    let tag = format!("n={n} seed={seed} t{threads}");
                    assert_eq!(ks.extract_matching(), kh.extract_matching(), "{tag}");
                    assert_eq!(ks.duals(), kh.duals(), "{tag}");
                    assert_eq!(ks.arena().rounds, kh.arena().rounds, "{tag}");
                    assert_eq!(ks.arena().phases, kh.arena().phases, "{tag}");
                }
            }
        }
    }

    #[test]
    fn hybrid_identical_to_scalar_on_ot_masses() {
        let n = 13; // non-multiple-of-8 demand side
        let costs = random_costs(n, 9);
        let supply: Vec<u64> = (0..n).map(|b| 2 + (b % 5) as u64).collect();
        let demand: Vec<u64> = (0..n).map(|a| 4 + (a % 3) as u64).collect();
        assert!(demand.iter().sum::<u64>() >= supply.iter().sum::<u64>());
        let mut ks = ScalarKernel::new();
        ks.init(&costs, 0.15, Some((&supply[..], &demand[..])));
        ks.run_to_termination(100_000).unwrap();
        for threads in [2usize, 4] {
            let mut kh = HybridKernel::new(threads);
            kh.init(&costs, 0.15, Some((&supply[..], &demand[..])));
            kh.run_to_termination(100_000).unwrap();
            assert_eq!(ks.unit_flow(), kh.unit_flow(), "t{threads}");
            assert_eq!(ks.duals(), kh.duals(), "t{threads}");
            assert_eq!(ks.arena().rounds, kh.arena().rounds, "t{threads}");
        }
    }

    #[test]
    fn hybrid_implicit_identical_to_dense_with_per_thread_caches() {
        // n = 11 exercises the lane-padding path under implicit costs.
        for n in [8usize, 11, 20] {
            let dense = random_costs(n, 21);
            let costs = generated_mirror(&dense, n);
            let mut kd = HybridKernel::new(4);
            kd.init(&dense, 0.2, None);
            kd.run_to_termination(10_000).unwrap();
            let mut ki = HybridKernel::new(4);
            ki.init_src(&costs.source(), 0.2, None);
            ki.run_to_termination(10_000).unwrap();
            ki.check_invariants().unwrap();
            assert_eq!(kd.extract_matching(), ki.extract_matching(), "n={n}");
            assert_eq!(kd.duals(), ki.duals(), "n={n}");
            assert_eq!(kd.arena().rounds, ki.arena().rounds, "n={n}");
            assert_eq!(kd.arena().phases, ki.arena().phases, "n={n}");
            // implicit mode keeps only the streamed block minima resident
            assert!(ki.arena().q.is_implicit() && ki.arena().q.cq.is_empty(), "n={n}");
            assert!(ki.arena().cost_state_bytes() < kd.arena().cost_state_bytes() / 4, "n={n}");
        }
    }

    #[test]
    fn hybrid_rescale_matches_scalar_schedule() {
        let costs = random_costs(12, 4);
        let mut kh = HybridKernel::new(4);
        kh.init(&costs, 0.4, None);
        kh.run_to_termination(10_000).unwrap();
        kh.arena_mut().rescale(&costs, 0.2);
        kh.check_invariants().unwrap();
        kh.run_to_termination(10_000).unwrap();
        assert!(kh.arena().free_units() <= kh.arena().threshold());
        assert_eq!(kh.arena().rescales, 1);
        let mut ks = ScalarKernel::new();
        ks.init(&costs, 0.4, None);
        ks.run_to_termination(10_000).unwrap();
        ks.arena_mut().rescale(&costs, 0.2);
        ks.run_to_termination(10_000).unwrap();
        assert_eq!(ks.extract_matching(), kh.extract_matching());
        assert_eq!(ks.duals(), kh.duals());
    }

    #[test]
    fn arena_reuse_works_for_hybrid_backend() {
        let mut kh = HybridKernel::new(2);
        kh.init(&random_costs(10, 1), 0.2, None);
        kh.run_to_termination(10_000).unwrap();
        kh.init(&random_costs(10, 2), 0.2, None);
        assert!(kh.arena().last_init_reused);
        kh.run_to_termination(10_000).unwrap();
        kh.check_invariants().unwrap();
    }
}
