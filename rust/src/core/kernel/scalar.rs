//! Sequential flow-kernel backend: the propose sweep runs inline on the
//! calling thread. This is the reference semantics every other backend
//! must reproduce bit-for-bit (see the module docs of
//! [`crate::core::kernel`]).

// Kernel-scope lint wall: all narrowing index math must go through the
// checked helpers in `arena` (`idx`/`to_u32`/`to_u8`).
#![deny(clippy::cast_possible_truncation, clippy::cast_sign_loss)]

use crate::core::kernel::arena::{sequential_sweep, KernelArena, KernelPhase, RowScratch};
use crate::core::kernel::FlowKernel;

#[derive(Debug, Default)]
pub struct ScalarKernel {
    arena: KernelArena,
    /// Row-window LRU for implicit costs (untouched on dense solves).
    scratch: RowScratch,
}

impl ScalarKernel {
    pub fn new() -> Self {
        Self::default()
    }
}

impl FlowKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "kernel-scalar"
    }

    fn arena(&self) -> &KernelArena {
        &self.arena
    }

    fn arena_mut(&mut self) -> &mut KernelArena {
        &mut self.arena
    }

    // CONTRACT: round-structured accept order — proposals stage against the
    // round snapshot via sequential_sweep; commits happen inside
    // KernelArena::run_phase in ascending rank order.
    fn run_phase(&mut self) -> KernelPhase {
        let scratch = &mut self.scratch;
        self.arena.run_phase(|view, active, plans, plan_len, exhausted| {
            sequential_sweep(view, active, plans, plan_len, exhausted, &mut *scratch)
        })
    }
}
