//! Sequential flow-kernel backend: the propose sweep runs inline on the
//! calling thread. This is the reference semantics every other backend
//! must reproduce bit-for-bit (see the module docs of
//! [`crate::core::kernel`]).

use crate::core::kernel::arena::{sequential_sweep, KernelArena, KernelPhase, RowScratch};
use crate::core::kernel::FlowKernel;

#[derive(Debug, Default)]
pub struct ScalarKernel {
    arena: KernelArena,
    /// Row-window LRU for implicit costs (untouched on dense solves).
    scratch: RowScratch,
}

impl ScalarKernel {
    pub fn new() -> Self {
        Self::default()
    }
}

impl FlowKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "kernel-scalar"
    }

    fn arena(&self) -> &KernelArena {
        &self.arena
    }

    fn arena_mut(&mut self) -> &mut KernelArena {
        &mut self.arena
    }

    fn run_phase(&mut self) -> KernelPhase {
        let scratch = &mut self.scratch;
        self.arena.run_phase(|view, active, plans, plan_len, exhausted| {
            sequential_sweep(view, active, plans, plan_len, exhausted, &mut *scratch)
        })
    }
}
