//! Chunked flow-kernel backend: the per-phase propose sweep fans out
//! over scoped threads in contiguous chunks of the active worklist —
//! the `parallel_pr` thread-sweep generalized to the OT cluster state.
//!
//! Proposals read only the round snapshot and the accept pass stays
//! sequential in ascending vertex order, so the result is identical to
//! [`crate::core::kernel::ScalarKernel`] for every thread count; only
//! wall-clock changes. §3.2's O(log n) expected round bound applies
//! unchanged (ablation A2 measures it).

// Kernel-scope lint wall: all narrowing index math must go through the
// checked helpers in `arena` (`idx`/`to_u32`/`to_u8`).
#![deny(clippy::cast_possible_truncation, clippy::cast_sign_loss)]

use crate::core::kernel::arena::{
    sequential_sweep, KernelArena, KernelPhase, RowScratch, PLAN_WIDTH,
};
use crate::core::kernel::FlowKernel;

#[derive(Debug)]
pub struct ChunkedKernel {
    arena: KernelArena,
    threads: usize,
    /// One row-window LRU per sweep thread for implicit costs (values are
    /// pure per-row quantizations, so per-thread caching cannot perturb
    /// the thread-invariant result contract).
    scratch: Vec<RowScratch>,
}

impl ChunkedKernel {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let mut scratch = Vec::with_capacity(threads);
        scratch.resize_with(threads, RowScratch::default);
        Self { arena: KernelArena::new(), threads, scratch }
    }
}

impl FlowKernel for ChunkedKernel {
    fn name(&self) -> &'static str {
        "kernel-chunked"
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn arena(&self) -> &KernelArena {
        &self.arena
    }

    fn arena_mut(&mut self) -> &mut KernelArena {
        &mut self.arena
    }

    // CONTRACT: round-structured accept order — worker threads only stage
    // proposals into disjoint plan windows against the round snapshot;
    // commits happen inside KernelArena::run_phase in ascending rank order,
    // so the result is identical to the scalar backend at any thread count.
    fn run_phase(&mut self) -> KernelPhase {
        let threads = self.threads;
        let scratch = &mut self.scratch;
        self.arena.run_phase(|view, active, plans, plan_len, exhausted| {
            let n = active.len();
            let workers = threads.min(n.max(1));
            if workers <= 1 {
                sequential_sweep(view, active, plans, plan_len, exhausted, &mut scratch[0]);
                return;
            }
            let chunk = n.div_ceil(workers);
            std::thread::scope(|s| {
                // chunks/chunks_mut yield disjoint windows, so each worker
                // owns its slice of the plan buffers (and its own row
                // scratch) and runs the one shared sweep body over it
                for ((((acts, pl), ll), el), rs) in active
                    .chunks(chunk)
                    .zip(plans.chunks_mut(chunk * PLAN_WIDTH))
                    .zip(plan_len.chunks_mut(chunk))
                    .zip(exhausted.chunks_mut(chunk))
                    .zip(scratch.iter_mut())
                {
                    s.spawn(move || sequential_sweep(view, acts, pl, ll, el, rs));
                }
            });
        })
    }
}
